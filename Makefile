# diagonal-scale build entry points. Everything except `artifacts` is
# pure offline cargo; `artifacts` AOT-lowers the JAX/Pallas kernels to
# HLO text and needs a python environment with jax installed (see
# python/compile/aot.py).

.DEFAULT_GOAL := help

.PHONY: help build test doc bench-compile examples lint-sim fleet-demo placement-demo explain-demo serverless-demo fleet-scale-demo metrics-demo scenario-demo artifacts

help: ## list the available targets
	@grep -E '^[a-zA-Z_-]+:.*?## ' $(MAKEFILE_LIST) | awk 'BEGIN {FS = ":.*?## "}; {printf "  %-14s %s\n", $$1, $$2}'

build: ## release build of the library, binary, and examples
	cargo build --release

test: ## tier-1 verify: release build + full test suite
	cargo build --release
	cargo test -q

doc: ## build the API docs with warnings denied (the CI doc gate)
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

bench-compile: ## compile every bench target without running it
	cargo bench --no-run

lint-sim: ## simlint gate: determinism (D1-D3), money-in-f64 (N1), schema additivity (S1/S2), test registration (T1)
	cargo run -q -p simlint
	@cargo run -q -p simlint -- --json | grep -q '"schema":"diagonal-scale/simlint-v1"' && echo "lint-sim: --json smoke ok"

examples: ## run the quickstart and fleet_budget smoke examples
	cargo run --release --example quickstart
	cargo run --release --example fleet_budget

fleet-demo: ## budget-aware fleet demo: envelopes + forecasting + planning-vs-flat A/B
	cargo run --release --example fleet_budget

placement-demo: ## cross-tenant bin-packing demo: packed-vs-dedicated A/B with priced migrations
	cargo run --release --example placement_packing

explain-demo: ## ranked-proposal explain demo: top-k candidates + versioned JSON on the paper trace
	cargo run --release --example proposal_explain

serverless-demo: ## scale-to-zero demo: suspend/wake lifecycle + priced cold starts vs always-on
	cargo run --release --example scale_to_zero

fleet-scale-demo: ## 2048-tenant dirty-queue smoke: per-tick planning_micros must be reported
	cargo run --release -- fleet --tenants 2048 --serverless true --idle-fraction 0.95 --steps 60 > /tmp/fleet-scale-demo.out
	@tail -n 5 /tmp/fleet-scale-demo.out
	@grep -q 'planning_micros' /tmp/fleet-scale-demo.out && echo "fleet-scale-demo: planning_micros reported"

metrics-demo: ## streaming-metrics smoke: bounded recorders + sampled ticks + prometheus/JSON export
	cargo run --release -- fleet --tenants 256 --serverless true --steps 60 \
		--stream-metrics 32 --ticks-sample 10 \
		--metrics-out /tmp/metrics-demo.prom --metrics-json /tmp/metrics-demo.json > /tmp/metrics-demo.out
	@grep -q 'ticks sampled' /tmp/metrics-demo.out && echo "metrics-demo: tick output bounded"
	@grep -q '^fleet_spend_hourly' /tmp/metrics-demo.prom && echo "metrics-demo: prometheus exposition ok"
	@grep -q '"schema":"diagonal-scale/metrics-v1"' /tmp/metrics-demo.json && echo "metrics-demo: metrics-v1 JSON ok"

scenario-demo: ## named-scenario smoke: presets drive fleet runs with scenario-stamped explain + metrics
	cargo run --release -- fleet --tenants 6 --scenario flash-crowd --budget 8.0 \
		--explain 3 --explain-out /tmp/scenario-demo.json \
		--metrics-json /tmp/scenario-demo-metrics.json > /tmp/scenario-demo.out
	cargo run --release -- fleet --tenants 6 --scenario zone-outage --budget 8.0 >> /tmp/scenario-demo.out
	@grep -q 'scenario `flash-crowd`' /tmp/scenario-demo.out && echo "scenario-demo: flash-crowd preset ran"
	@grep -q 'scenario `zone-outage`' /tmp/scenario-demo.out && echo "scenario-demo: zone-outage preset ran"
	@grep -q 'fault events scheduled' /tmp/scenario-demo.out && echo "scenario-demo: fault schedule reported"
	@grep -q '"scenario":"flash-crowd"' /tmp/scenario-demo.json && echo "scenario-demo: explain stamped"
	@grep -q 'scenario_active' /tmp/scenario-demo-metrics.json && echo "scenario-demo: metrics stamped"

artifacts: ## AOT-lower the JAX/Pallas kernels to artifacts/ (needs jax)
	cd python && python3 -m compile.aot --out-dir ../artifacts
