"""Neighbor-scoring Pallas kernel vs its oracle + Algorithm-1 invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import defaults as D
from compile.kernels import ref
from compile.kernels.neighbor import neighbor_scores

SETTINGS = dict(max_examples=25, deadline=None)


def make_cand(rows):
    """Pad a list of 9-feature rows to the kernel's padded batch shape."""
    cand = np.zeros((D.NEIGHBOR_ROWS, D.NEIGHBOR_COLS), np.float32)
    for i, r in enumerate(rows):
        cand[i, : len(r)] = r
    return cand


def default_rows():
    """The full 9-candidate neighborhood of (H=2, medium)."""
    hs = D.H_VALUES
    tiers = [D.TIERS[n] for n in D.TIER_NAMES]
    rows = []
    for dh in (-1, 0, 1):
        for dv in (-1, 0, 1):
            hi, vi = 1 + dh, 1 + dv
            rows.append([hs[hi], *tiers[vi], abs(dh), abs(dv), 1.0])
    return rows


class TestNeighborKernel:
    def test_matches_ref(self):
        cand = make_cand(default_rows())
        params = D.params_vec()
        s_got, f_got = neighbor_scores(cand, params)
        s_want, f_want = ref.neighbor_scores_ref(cand, params)
        assert_allclose(np.asarray(s_got), np.asarray(s_want), rtol=1e-5)
        assert_allclose(np.asarray(f_got), np.asarray(f_want))

    def test_invalid_rows_are_infeasible(self):
        cand = make_cand(default_rows())
        params = D.params_vec()
        _, feas = neighbor_scores(cand, params)
        assert np.all(np.asarray(feas)[9:] == 0.0)
        scores, _ = neighbor_scores(cand, params)
        assert np.all(np.asarray(scores)[9:] >= D.INFEASIBLE * 0.5)

    def test_latency_sla_filters(self):
        """With l_max below every candidate latency, nothing is feasible."""
        cand = make_cand(default_rows())
        params = D.params_vec(l_max=0.0)
        scores, feas = neighbor_scores(cand, params)
        assert np.all(np.asarray(feas) == 0.0)
        assert np.all(np.asarray(scores) >= D.INFEASIBLE * 0.5)

    def test_throughput_sla_filters(self):
        """With an absurd required throughput, nothing is feasible."""
        cand = make_cand(default_rows())
        params = D.params_vec(lambda_req=1e9)
        _, feas = neighbor_scores(cand, params)
        assert np.all(np.asarray(feas) == 0.0)

    def test_rebalance_penalty_applied(self):
        """Identical configs at different index distances differ by R."""
        tier = D.TIERS["xlarge"]
        rows = [
            [4.0, *tier, 0.0, 0.0, 1.0],
            [4.0, *tier, 1.0, 0.0, 1.0],
            [4.0, *tier, 0.0, 1.0, 1.0],
            [4.0, *tier, 1.0, 1.0, 1.0],
        ]
        params = D.params_vec(lambda_req=100.0)
        scores = np.asarray(neighbor_scores(make_cand(rows), params)[0])
        reb_h, reb_v = params[D.P_REB_H], params[D.P_REB_V]
        assert_allclose(scores[1] - scores[0], reb_h, rtol=1e-4)
        assert_allclose(scores[2] - scores[0], reb_v, rtol=1e-4)
        assert_allclose(scores[3] - scores[0], reb_h + reb_v, rtol=1e-4)

    def test_h_change_penalized_more_than_v(self):
        """Paper IV.D: changing H costs more than changing V."""
        params = D.params_vec()
        assert params[D.P_REB_H] > params[D.P_REB_V]


class TestNeighborProperty:
    @settings(**SETTINGS)
    @given(data=st.data())
    def test_matches_ref_random(self, data):
        n = D.NEIGHBOR_ROWS
        pos = st.floats(min_value=0.5, max_value=64.0)
        cand = np.zeros((n, D.NEIGHBOR_COLS), np.float32)
        for i in range(n):
            cand[i, D.C_H] = data.draw(st.sampled_from([1.0, 2.0, 4.0, 8.0]))
            for j in (D.C_CPU, D.C_RAM, D.C_BW, D.C_IOPS_K):
                cand[i, j] = data.draw(pos)
            cand[i, D.C_COST] = data.draw(
                st.floats(min_value=0.01, max_value=10.0))
            cand[i, D.C_ADH] = data.draw(st.sampled_from([0.0, 1.0]))
            cand[i, D.C_ADV] = data.draw(st.sampled_from([0.0, 1.0]))
            cand[i, D.C_VALID] = data.draw(st.sampled_from([0.0, 1.0]))
        lam = data.draw(st.floats(min_value=1.0, max_value=1e6))
        params = D.params_vec(lambda_req=lam)
        s_got, f_got = neighbor_scores(cand, params)
        s_want, f_want = ref.neighbor_scores_ref(cand, params)
        assert_allclose(np.asarray(s_got), np.asarray(s_want), rtol=2e-4,
                        atol=1e-5)
        assert_allclose(np.asarray(f_got), np.asarray(f_want))

    @settings(**SETTINGS)
    @given(lam=st.floats(min_value=1.0, max_value=1e6))
    def test_feasible_iff_score_finite(self, lam):
        cand = make_cand(default_rows())
        params = D.params_vec(lambda_req=lam)
        scores, feas = neighbor_scores(cand, params)
        scores, feas = np.asarray(scores), np.asarray(feas)
        assert np.all((feas > 0.5) == (scores < D.INFEASIBLE * 0.5))
