"""policy_trace (the in-XLA Algorithm-1 simulation) vs the independent
numpy simulator, plus the paper's headline Table-I assertions."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import calibrate as C, defaults as D, model

np.seterr(all="ignore")


def run_jax(adh, adv, trace=None, start=None, **over):
    hs, tiers, mask = D.grid_arrays()
    params = D.params_vec(allow_dh=adh, allow_dv=adv, **over)
    trace = D.paper_trace() if trace is None else trace.astype(np.float32)
    start = np.array(D.START if start is None else start, np.float32)
    return np.asarray(
        model.policy_trace(hs, tiers, params, mask, trace, start),
        np.float64)


def run_numpy(adh, adv, trace=None, start=None, **over):
    hs, tiers, mask = D.grid_arrays(np.float64)
    params = D.params_vec(allow_dh=adh, allow_dv=adv, dtype=np.float64,
                          **over)
    trace = D.paper_trace(np.float64) if trace is None else trace
    start = np.array(D.START if start is None else start)
    return C.simulate(params, hs, tiers, mask, trace, start)


POLICIES = {"diag": (1, 1), "horiz": (1, 0), "vert": (0, 1)}


class TestTraceVsNumpyOracle:
    @pytest.mark.parametrize("name", list(POLICIES))
    def test_trajectory_identical(self, name):
        adh, adv = POLICIES[name]
        jrec, nrec = run_jax(adh, adv), run_numpy(adh, adv)
        assert np.array_equal(jrec[:, :2], nrec[:, :2])
        assert np.array_equal(jrec[:, 6:8], nrec[:, 6:8])

    @pytest.mark.parametrize("name", list(POLICIES))
    def test_metrics_allclose(self, name):
        adh, adv = POLICIES[name]
        jrec, nrec = run_jax(adh, adv), run_numpy(adh, adv)
        assert_allclose(jrec[:, 2:6], nrec[:, 2:6], rtol=1e-3)

    @pytest.mark.parametrize("start", [(0, 0), (3, 3), (2, 0), (0, 3)])
    def test_trajectory_identical_other_starts(self, start):
        jrec = run_jax(1, 1, start=start)
        nrec = run_numpy(1, 1, start=start)
        assert np.array_equal(jrec[:, :2], nrec[:, :2])

    def test_queueing_planner_extension_matches(self):
        jrec = run_jax(1, 1, plan_queue=1.0)
        nrec = run_numpy(1, 1, plan_queue=1.0)
        assert np.array_equal(jrec[:, :2], nrec[:, :2])


class TestPolicyInvariants:
    def test_horizontal_only_never_changes_tier(self):
        rec = run_jax(1, 0)
        assert np.all(rec[:, model.REC_V_IDX] == D.START[1])

    def test_vertical_only_never_changes_nodes(self):
        rec = run_jax(0, 1)
        assert np.all(rec[:, model.REC_H_IDX] == D.START[0])

    def test_configs_stay_in_bounds(self):
        for adh, adv in POLICIES.values():
            rec = run_jax(adh, adv)
            assert np.all(rec[:, 0] >= 0) and np.all(rec[:, 0] <= 3)
            assert np.all(rec[:, 1] >= 0) and np.all(rec[:, 1] <= 3)

    def test_moves_are_single_step(self):
        """Local search: at most one index step per axis per timestep."""
        for adh, adv in POLICIES.values():
            rec = run_jax(adh, adv)
            assert np.all(np.abs(np.diff(rec[:, 0])) <= 1)
            assert np.all(np.abs(np.diff(rec[:, 1])) <= 1)

    def test_diagonal_uses_both_axes(self):
        """Fig 5: DiagonalScale actually moves in both dimensions."""
        rec = run_jax(1, 1)
        assert len(np.unique(rec[:, 0])) > 1
        assert len(np.unique(rec[:, 1])) > 1

    def test_fallback_scales_up_when_nothing_feasible(self):
        """Impossible demand: diagonal fallback climbs to the top corner."""
        trace = np.full((10, 2), 1e9, np.float32)
        trace[:, 1] *= 0.3
        rec = run_jax(1, 1, trace=trace, start=(0, 0))
        assert rec[-1, model.REC_H_IDX] == 3
        assert rec[-1, model.REC_V_IDX] == 3
        # every step violates the throughput SLA
        assert np.all(rec[:, model.REC_THR_VIOL] == 1.0)

    def test_steady_low_load_scales_down(self):
        """From the top corner under tiny load, the policy walks down."""
        trace = np.full((12, 2), 100.0, np.float32)
        trace[:, 1] = 30.0
        rec = run_jax(1, 1, trace=trace, start=(3, 3))
        assert rec[-1, model.REC_H_IDX] < 3
        assert rec[-1, model.REC_V_IDX] < 3
        assert rec[-1, model.REC_COST] < rec[0, model.REC_COST]


class TestTableOne:
    """The paper's headline result (Table I), shape-level assertions."""

    @pytest.fixture(scope="class")
    def summaries(self):
        out = {}
        for name, (adh, adv) in POLICIES.items():
            out[name] = C.summarize(run_jax(adh, adv))
        return out

    def test_violation_ordering(self, summaries):
        assert (summaries["diag"][4] < summaries["vert"][4]
                < summaries["horiz"][4])

    def test_diagonal_few_violations(self, summaries):
        assert summaries["diag"][4] <= 5          # paper: 3 / 50

    def test_horizontal_many_violations(self, summaries):
        assert summaries["horiz"][4] >= 25        # paper: 32 / 50

    def test_latency_ordering(self, summaries):
        assert (summaries["diag"][0] < summaries["vert"][0]
                < summaries["horiz"][0])

    def test_objective_ordering(self, summaries):
        assert (summaries["diag"][3] < summaries["vert"][3]
                < summaries["horiz"][3])

    def test_diagonal_pays_cost_premium(self, summaries):
        assert summaries["diag"][2] >= summaries["vert"][2]
        assert summaries["diag"][2] >= summaries["horiz"][2]

    def test_diagonal_best_throughput(self, summaries):
        assert summaries["diag"][1] > summaries["horiz"][1]
