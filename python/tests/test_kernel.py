"""Pallas surfaces kernel vs the pure-jnp oracle — the CORE correctness
signal for L1.  Hypothesis sweeps tier tables, workloads, and constants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import defaults as D
from compile.kernels import ref
from compile.kernels.surfaces import surfaces

SETTINGS = dict(max_examples=25, deadline=None)

pos = st.floats(min_value=0.5, max_value=64.0, allow_nan=False)
cost_s = st.floats(min_value=0.01, max_value=10.0, allow_nan=False)


def tiers_strategy():
    row = st.tuples(pos, pos, pos, pos, cost_s).map(list)
    return st.lists(row, min_size=D.GRID, max_size=D.GRID).map(
        lambda r: np.array(r, np.float32))


def run_both(hs, tiers, params, mask):
    got = surfaces(hs, tiers, params, mask)
    want = ref.surfaces_ref(hs, tiers, params, mask)
    return got, want


class TestSurfacesDefaults:
    def setup_method(self):
        self.hs, self.tiers, self.mask = D.grid_arrays()
        self.params = D.params_vec()

    def test_matches_ref(self):
        got, want = run_both(self.hs, self.tiers, self.params, self.mask)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5)

    def test_output_arity_and_shape(self):
        got, _ = run_both(self.hs, self.tiers, self.params, self.mask)
        assert len(got) == 5
        for g in got:
            assert g.shape == (D.GRID, D.GRID)
            assert g.dtype == np.float32

    def test_padding_cells_zeroed(self):
        got, _ = run_both(self.hs, self.tiers, self.params, self.mask)
        inv = self.mask < 0.5
        for g in got:
            assert np.all(np.asarray(g)[inv] == 0.0)

    def test_cost_surface_monotone_fig1(self):
        """Fig 1: cost increases in both dimensions."""
        _, _, cost, _, _ = run_both(self.hs, self.tiers, self.params,
                                    self.mask)[0]
        c = np.asarray(cost)[:4, :4]
        assert np.all(np.diff(c, axis=0) > 0)
        assert np.all(np.diff(c, axis=1) > 0)

    def test_latency_surface_shape_fig2(self):
        """Fig 2: latency falls with tier, rises with node count."""
        lat = np.asarray(run_both(self.hs, self.tiers, self.params,
                                  self.mask)[0][0])[:4, :4]
        assert np.all(np.diff(lat, axis=1) < 0)   # better tier -> lower
        assert np.all(np.diff(lat, axis=0) > 0)   # more nodes -> higher

    def test_throughput_diminishing_returns(self):
        """phi(H) < 1 for H > 1: doubling nodes less than doubles T."""
        thr = np.asarray(run_both(self.hs, self.tiers, self.params,
                                  self.mask)[0][1])[:4, :4]
        for j in range(4):
            ratios = thr[1:, j] / thr[:-1, j]
            assert np.all(ratios < 2.0)
            assert np.all(ratios > 1.0)

    def test_coordination_grows_with_h(self):
        coord = np.asarray(run_both(self.hs, self.tiers, self.params,
                                    self.mask)[0][3])[:4, :4]
        assert np.all(np.diff(coord, axis=0) > 0)

    def test_single_node_no_coordination_latency_log_term(self):
        """H=1: ln(1)=0, so L = L_node + mu."""
        lat = np.asarray(run_both(self.hs, self.tiers, self.params,
                                  self.mask)[0][0])
        p = self.params
        l_node = (p[D.P_A] / self.tiers[:, 0] + p[D.P_B] / self.tiers[:, 1]
                  + p[D.P_C] / self.tiers[:, 2] + p[D.P_D] / self.tiers[:, 3])
        expect = l_node[:4] + p[D.P_MU]
        assert_allclose(lat[0, :4], expect, rtol=1e-5)


class TestSurfacesProperty:
    @settings(**SETTINGS)
    @given(tiers=tiers_strategy(),
           lam=st.floats(min_value=1.0, max_value=1e6),
           wr=st.floats(min_value=0.0, max_value=1.0))
    def test_kernel_matches_ref_random_tiers(self, tiers, lam, wr):
        hs, _, mask = D.grid_arrays()
        params = D.params_vec(lambda_req=lam, write_ratio=wr)
        got = surfaces(hs, tiers, params, mask)
        want = ref.surfaces_ref(hs, tiers, params, mask)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4,
                            atol=1e-6)

    @settings(**SETTINGS)
    @given(kappa=st.floats(min_value=1.0, max_value=5000.0),
           omega=st.floats(min_value=0.01, max_value=2.0),
           mu=st.floats(min_value=0.0, max_value=2.0),
           theta=st.floats(min_value=0.5, max_value=2.0))
    def test_kernel_matches_ref_random_constants(self, kappa, omega, mu,
                                                 theta):
        hs, tiers, mask = D.grid_arrays()
        params = D.params_vec(kappa=kappa, omega=omega, mu=mu, theta=theta)
        got = surfaces(hs, tiers, params, mask)
        want = ref.surfaces_ref(hs, tiers, params, mask)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4,
                            atol=1e-6)

    @settings(**SETTINGS)
    @given(lam=st.floats(min_value=0.0, max_value=1e7))
    def test_all_finite_on_valid_cells(self, lam):
        hs, tiers, mask = D.grid_arrays()
        params = D.params_vec(lambda_req=lam)
        got = surfaces(hs, tiers, params, mask)
        for g in got:
            assert np.all(np.isfinite(np.asarray(g)))
