"""The calibration tool itself: the numpy simulator is the independent
oracle for policy_trace, and the Table-I scoring must reward exactly the
paper's shape."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import calibrate as C, defaults as D

np.seterr(all="ignore")

SETTINGS = dict(max_examples=15, deadline=None)


class TestDefaultCalibration:
    @pytest.fixture(scope="class")
    def result(self):
        return C.run_policies()

    def test_all_orderings_hold(self, result):
        assert np.isfinite(C.score_setting(result))

    def test_diagonal_best_on_violations(self, result):
        assert result["diag"][4] < result["vert"][4] < result["horiz"][4]

    def test_paper_magnitudes(self, result):
        ds = result["diag"]
        assert ds[4] <= 5                      # paper: 3
        assert 3.0 <= ds[0] <= 7.0             # paper: 4.05
        assert (25 <= result["horiz"][4] <= 40)  # paper: 32

    def test_score_is_sum_of_relative_errors(self, result):
        err = C.score_setting(result)
        assert 0.0 < err < 15.0


class TestScoreSetting:
    def test_broken_ordering_scores_infinite(self):
        good = C.run_policies()
        bad = dict(good)
        # swap diag and horiz: every ordering breaks
        bad["diag"], bad["horiz"] = good["horiz"], good["diag"]
        assert C.score_setting(bad) == float("inf")

    def test_perfect_match_scores_zero(self):
        exact = {k: v for k, v in C.PAPER.items()}
        assert C.score_setting(exact) < 1e-9


class TestSimulateProperties:
    def _sim(self, adh, adv, trace, start=(1, 1), **over):
        hs, tiers, mask = D.grid_arrays(np.float64)
        p = D.params_vec(allow_dh=adh, allow_dv=adv, dtype=np.float64, **over)
        return C.simulate(p, hs, tiers, mask, trace, np.array(start))

    @settings(**SETTINGS)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_random_trace_stays_in_bounds(self, seed):
        rng = np.random.default_rng(seed)
        lam = rng.uniform(100.0, 40_000.0, size=(30,))
        trace = np.stack([lam, 0.3 * lam], axis=1)
        rec = self._sim(1.0, 1.0, trace)
        assert rec[:, 0].min() >= 0 and rec[:, 0].max() <= 3
        assert rec[:, 1].min() >= 0 and rec[:, 1].max() <= 3
        # local search: one index step per axis per timestep
        assert np.abs(np.diff(rec[:, 0])).max() <= 1
        assert np.abs(np.diff(rec[:, 1])).max() <= 1

    @settings(**SETTINGS)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_axis_restrictions_respected(self, seed):
        rng = np.random.default_rng(seed)
        lam = rng.uniform(100.0, 40_000.0, size=(20,))
        trace = np.stack([lam, 0.3 * lam], axis=1)
        horiz = self._sim(1.0, 0.0, trace)
        assert (horiz[:, 1] == 1).all()
        vert = self._sim(0.0, 1.0, trace)
        assert (vert[:, 0] == 1).all()

    def test_impossible_demand_all_violations(self):
        trace = np.full((10, 2), 1e9)
        trace[:, 1] *= 0.3
        rec = self._sim(1.0, 1.0, trace)
        assert rec[:, 7].sum() == 10  # throughput violation every step
        # fallback climbs to the top corner
        assert rec[-1, 0] == 3 and rec[-1, 1] == 3
