"""Queueing-latency Pallas kernel (paper VIII extension) vs its oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import defaults as D, model
from compile.kernels import ref
from compile.kernels.queueing import queueing_latency
from compile.kernels.surfaces import surfaces

SETTINGS = dict(max_examples=25, deadline=None)


def grids(lambda_req=10000.0, **over):
    hs, tiers, mask = D.grid_arrays()
    params = D.params_vec(lambda_req=lambda_req, **over)
    lat, thr, *_ = surfaces(hs, tiers, params, mask)
    return np.asarray(lat), np.asarray(thr), mask, params


class TestQueueingKernel:
    def test_matches_ref(self):
        lat, thr, mask, params = grids()
        got = queueing_latency(lat, thr, mask, params)
        want = ref.queueing_ref(lat, thr, mask, params)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-5)

    def test_low_utilization_barely_inflates(self):
        lat, thr, mask, params = grids(lambda_req=1.0)
        lf, sat = queueing_latency(lat, thr, mask, params)
        lf = np.asarray(lf)[:4, :4]
        raw = lat[:4, :4]
        assert np.all(lf >= raw)
        assert_allclose(lf, raw, rtol=1e-2)
        assert np.all(np.asarray(sat) == 0.0)

    def test_saturation_clamped_and_flagged(self):
        """Demand far above capacity: clamp at u_max, flag saturated."""
        lat, thr, mask, params = grids(lambda_req=1e9)
        lf, sat = queueing_latency(lat, thr, mask, params)
        lf, sat = np.asarray(lf), np.asarray(sat)
        u_max = params[D.P_U_MAX]
        assert np.all(sat[:4, :4] == 1.0)
        assert_allclose(lf[:4, :4], lat[:4, :4] / (1.0 - u_max), rtol=1e-5)
        assert np.all(np.isfinite(lf))

    def test_padding_cells_zeroed(self):
        lat, thr, mask, params = grids()
        lf, sat = queueing_latency(lat, thr, mask, params)
        inv = mask < 0.5
        assert np.all(np.asarray(lf)[inv] == 0.0)
        assert np.all(np.asarray(sat)[inv] == 0.0)

    def test_monotone_in_demand(self):
        lat, thr, mask, params_lo = grids(lambda_req=2000.0)
        _, _, _, params_hi = grids(lambda_req=8000.0)
        lo = np.asarray(queueing_latency(lat, thr, mask, params_lo)[0])
        hi = np.asarray(queueing_latency(lat, thr, mask, params_hi)[0])
        valid = mask > 0.5
        assert np.all(hi[valid] >= lo[valid])


class TestQueueingProperty:
    @settings(**SETTINGS)
    @given(lam=st.floats(min_value=0.0, max_value=1e8),
           u_max=st.floats(min_value=0.1, max_value=0.99))
    def test_matches_ref_random(self, lam, u_max):
        lat, thr, mask, params = grids(lambda_req=lam, u_max=u_max)
        got = queueing_latency(lat, thr, mask, params)
        want = ref.queueing_ref(lat, thr, mask, params)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-4,
                            atol=1e-6)

    @settings(**SETTINGS)
    @given(lam=st.floats(min_value=0.0, max_value=1e8))
    def test_never_divides_to_inf(self, lam):
        lat, thr, mask, params = grids(lambda_req=lam)
        lf, _ = queueing_latency(lat, thr, mask, params)
        assert np.all(np.isfinite(np.asarray(lf)))


class TestQueueingGridModel:
    def test_queueing_grid_composition(self):
        """L2 queueing_grid = surfaces + correction, consistently."""
        hs, tiers, mask = D.grid_arrays()
        params = D.params_vec()
        lf, sat, lat, thr, cost, coord, obj = model.queueing_grid(
            hs, tiers, params, mask)
        want_lf, want_sat = ref.queueing_ref(
            np.asarray(lat), np.asarray(thr), mask, params)
        assert_allclose(np.asarray(lf), np.asarray(want_lf), rtol=1e-5)
        assert_allclose(np.asarray(sat), np.asarray(want_sat))
