"""AOT lowering: every entry point lowers to parseable HLO text and the
manifest describes it accurately."""

import json
import os

import pytest

from compile import aot, defaults as D, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out), trace_lens=(50,))
    return str(out), manifest


class TestLowering:
    def test_all_entry_points_emitted(self, artifacts):
        out, manifest = artifacts
        names = set(manifest["entry_points"])
        assert {"surfaces", "surfaces_wide", "neighbor", "queueing",
                "policy_trace_50"} == names
        for info in manifest["entry_points"].values():
            assert os.path.exists(os.path.join(out, info["file"]))

    def test_hlo_is_text_not_proto(self, artifacts):
        out, manifest = artifacts
        for info in manifest["entry_points"].values():
            with open(os.path.join(out, info["file"])) as f:
                head = f.read(200)
            assert "HloModule" in head  # textual HLO, parseable by xla 0.1.6

    def test_no_unrunnable_custom_calls(self, artifacts):
        """interpret=True Pallas must lower to plain HLO ops: a Mosaic
        custom-call would be unloadable on the CPU PJRT plugin."""
        out, manifest = artifacts
        for info in manifest["entry_points"].values():
            with open(os.path.join(out, info["file"])) as f:
                text = f.read()
            assert "mosaic" not in text.lower()
            assert "tpu_custom_call" not in text.lower()

    def test_manifest_arg_shapes(self, artifacts):
        _, manifest = artifacts
        g, p = D.GRID, D.PARAMS_LEN
        eps = manifest["entry_points"]
        assert eps["surfaces"]["args"] == [[g], [g, 5], [p], [g, g]]
        assert eps["surfaces"]["num_outputs"] == 5
        assert eps["surfaces_wide"]["args"] == [
            [g], [D.WIDE, 5], [p], [g, D.WIDE]]
        assert eps["surfaces_wide"]["num_outputs"] == 5
        assert eps["neighbor"]["args"] == [
            [D.NEIGHBOR_ROWS, D.NEIGHBOR_COLS], [p]]
        assert eps["neighbor"]["num_outputs"] == 2
        assert eps["queueing"]["num_outputs"] == 7
        assert eps["policy_trace_50"]["args"][-2] == [50, 2]
        assert eps["policy_trace_50"]["num_outputs"] == 1

    def test_manifest_abi(self, artifacts):
        out, manifest = artifacts
        assert manifest["abi_version"] == aot.ABI_VERSION
        assert manifest["rec_len"] == model.REC_LEN
        with open(os.path.join(out, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk == manifest

    def test_entry_points_parameterized_not_baked(self, artifacts):
        """Constants must arrive as runtime parameters: the HLO for the
        surfaces entry point takes 4 parameters."""
        out, manifest = artifacts
        with open(os.path.join(out, "surfaces.hlo.txt")) as f:
            text = f.read()
        main = text[text.index("ENTRY"):]
        assert main.count("parameter(") == 4
