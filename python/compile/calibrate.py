"""Calibration tool: fit the synthetic constants to the paper's Table I.

The paper gives the functional forms but not the constants.  This tool
implements the simulation in plain numpy (mirroring model.policy_trace
semantics exactly — it doubles as an independent oracle in pytest) and
random-searches the constant space for a setting that reproduces the
*shape* of Table I:

    violations:  DiagonalScale < Vertical-only < Horizontal-only
    latency:     DiagonalScale < Vertical-only < Horizontal-only
    objective:   DiagonalScale < Vertical-only < Horizontal-only
    cost:        DiagonalScale highest (spends where it matters)

and minimizes relative distance to the paper's reported values
(DS 4.05/13506/1.624/65.53/3, H 13.06/10293/1.560/180.94/32,
 V 4.89/12069/1.416/77.70/21).

Usage:  cd python && python -m compile.calibrate [--samples 20000]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from . import defaults as D

PAPER = {  # policy -> (avg_lat, avg_thr, avg_cost, avg_obj, violations)
    "diag": (4.05, 13506.13, 1.624, 65.53, 3),
    "horiz": (13.06, 10293.20, 1.560, 180.94, 32),
    "vert": (4.89, 12068.66, 1.416, 77.70, 21),
}
POLICY_MOVES = {"diag": (1.0, 1.0), "horiz": (1.0, 0.0), "vert": (0.0, 1.0)}


def simulate(p, hs, tiers, mask, trace, start):
    """Numpy mirror of model.policy_trace (same record layout)."""
    g = len(hs)
    h = hs[:, None]
    cpu, ram, bw, iops_k, cost_node = (tiers[None, :, i] for i in range(5))
    log_h = np.log(h)

    l_node = (p[D.P_A] / cpu + p[D.P_B] / ram + p[D.P_C] / bw
              + p[D.P_D] / iops_k)
    l_coord = p[D.P_ETA] * log_h + p[D.P_MU] * np.exp(p[D.P_THETA] * log_h)
    lat = l_node + l_coord
    mins = np.minimum(np.minimum(cpu, ram), np.minimum(bw, iops_k))
    thr = h * (p[D.P_KAPPA] * mins) / (1.0 + p[D.P_OMEGA] * log_h)
    cost = h * cost_node

    rows, cols = np.indices((g, g))
    n_h, n_v = int(p[D.P_N_H]), int(p[D.P_N_V])
    adh, adv = p[D.P_ALLOW_DH] > 0.5, p[D.P_ALLOW_DV] > 0.5

    h_idx, v_idx = int(start[0]), int(start[1])
    recs = np.zeros((len(trace), 8), dtype=np.float64)
    for t, (lam_req, lam_w) in enumerate(trace):
        coord = p[D.P_RHO] * l_coord * lam_w / thr
        obj = (p[D.P_ALPHA] * lat + p[D.P_BETA] * cost
               + p[D.P_GAMMA] * coord - p[D.P_DELTA] * thr)
        u = np.minimum(lam_req / thr, p[D.P_U_MAX])
        lat_eff = lat / (1.0 - u)
        obj_eff = (p[D.P_ALPHA] * lat_eff + p[D.P_BETA] * cost
                   + p[D.P_GAMMA] * coord - p[D.P_DELTA] * thr)

        # serve + measure
        recs[t] = (h_idx, v_idx, lat_eff[h_idx, v_idx], thr[h_idx, v_idx],
                   cost[h_idx, v_idx], obj_eff[h_idx, v_idx],
                   float(lat[h_idx, v_idx] > p[D.P_L_MAX]),
                   float(thr[h_idx, v_idx] < lam_req))

        # decide (Algorithm 1)
        di = np.abs(rows - h_idx)
        dj = np.abs(cols - v_idx)
        allowed = (di <= 1) & (dj <= 1) & (mask > 0.5)
        if not adh:
            allowed &= di == 0
        if not adv:
            allowed &= dj == 0
        plan_lat = lat_eff if p[D.P_PLAN_QUEUE] > 0.5 else lat
        plan_obj = obj_eff if p[D.P_PLAN_QUEUE] > 0.5 else obj
        feasible = (allowed & (plan_lat <= p[D.P_L_MAX])
                    & (thr >= lam_req * p[D.P_B_SLA]))
        score = np.where(feasible,
                         plan_obj + p[D.P_REB_H] * di + p[D.P_REB_V] * dj,
                         D.INFEASIBLE)
        best = int(np.argmin(score))      # row-major first-min, as in jax
        if score.flat[best] < D.INFEASIBLE * 0.5:
            h_idx, v_idx = best // g, best % g
        else:
            h_idx = min(h_idx + int(adh), n_h - 1)
            v_idx = min(v_idx + int(adv), n_v - 1)
    return recs


def summarize(recs):
    viol = int(((recs[:, 6] + recs[:, 7]) > 0).sum())
    return (recs[:, 2].mean(), recs[:, 3].mean(), recs[:, 4].mean(),
            recs[:, 5].mean(), viol)


def run_policies(overrides=None, start=(1, 1), tiers_table=None):
    """Simulate the three paper policies; returns {name: summary}."""
    hs, tiers, mask = D.grid_arrays(np.float64)
    if tiers_table is not None:
        tiers[: len(tiers_table)] = tiers_table
    trace = D.paper_trace(np.float64)
    out = {}
    for name, (adh, adv) in POLICY_MOVES.items():
        p = D.params_vec(allow_dh=adh, allow_dv=adv, dtype=np.float64,
                         **(overrides or {}))
        out[name] = summarize(simulate(p, hs, tiers, mask, trace,
                                       np.array(start)))
    return out


def score_setting(res):
    """Lower is better; +inf if a required ordering is broken."""
    ds, hz, vt = res["diag"], res["horiz"], res["vert"]
    orderings = [
        ds[4] < vt[4] < hz[4],            # violations
        ds[0] < vt[0] < hz[0],            # latency
        ds[3] < vt[3] < hz[3],            # objective
        ds[2] >= vt[2] and ds[2] >= hz[2],  # DS pays the premium
        ds[1] > hz[1],                    # DS best throughput
    ]
    if not all(orderings):
        return float("inf")
    err = 0.0
    for k in PAPER:
        got, want = res[k], PAPER[k]
        for i in range(5):
            w = max(abs(want[i]), 1e-9)
            err += abs(got[i] - want[i]) / w
    return err


def random_search(samples, seed=0):
    rng = np.random.default_rng(seed)
    best, best_err = None, float("inf")
    for s in range(samples):
        over = dict(
            kappa=float(rng.uniform(350, 700)),
            omega=float(rng.choice([0.10, 0.15, 0.20, 0.25])),
            mu=float(rng.uniform(0.2, 0.6)),
            theta=float(rng.uniform(1.05, 1.35)),
            alpha=float(rng.choice([5.0, 8.0, 10.0, 15.0])),
            beta=float(rng.choice([10.0, 20.0, 30.0, 40.0])),
            gamma=float(rng.choice([1.0, 2.0, 5.0, 10.0])),
            delta=float(rng.choice([0.0005, 0.001, 0.002, 0.003])),
            b_sla=float(rng.choice([1.05, 1.1, 1.15, 1.2])),
            l_max=float(rng.choice([5.0, 6.0, 6.5, 7.0, 8.0])),
            u_max=float(rng.choice([0.80, 0.85, 0.90, 0.95])),
        )
        start = (1, 1) if rng.random() < 0.5 else (2, 1)
        try:
            res = run_policies(over, start=start)
        except FloatingPointError:
            continue
        err = score_setting(res)
        if err < best_err:
            best_err, best = err, (over, start, res)
            print(f"[{s}] err={err:.3f} start={start} {json.dumps(over)}")
            for k, v in res.items():
                print(f"    {k:6s} lat={v[0]:7.2f} thr={v[1]:9.1f} "
                      f"cost={v[2]:6.3f} obj={v[3]:8.2f} viol={v[4]}")
    return best, best_err


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--samples", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    np.seterr(all="ignore")
    print("current defaults:")
    res = run_policies()
    for k, v in res.items():
        print(f"    {k:6s} lat={v[0]:7.2f} thr={v[1]:9.1f} "
              f"cost={v[2]:6.3f} obj={v[3]:8.2f} viol={v[4]}")
    print(f"    err={score_setting(res):.3f}")
    best, err = random_search(args.samples, args.seed)
    if best:
        over, start, res = best
        print(f"\nBEST err={err:.3f} start={start}\n{json.dumps(over, indent=2)}")


if __name__ == "__main__":
    main()
