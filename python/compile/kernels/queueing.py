"""L1 Pallas kernel: utilization-sensitive queueing latency (paper VIII).

Implements the paper's future-work extension: u = T_req / T(H,V),
L_final = L / (1 - u), with u clamped at u_max so latency spikes (but
stays finite) as utilization approaches capacity.  ``saturated`` marks
cells whose raw utilization reached/exceeded the clamp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import defaults as D


def _queueing_kernel(lat_ref, thr_ref, mask_ref, params_ref,
                     lf_ref, sat_ref):
    p = params_ref[...]
    lat = lat_ref[...]
    thr = thr_ref[...]
    mask = mask_ref[...]

    safe_thr = jnp.where(thr > 0.0, thr, jnp.ones_like(thr))
    u_raw = p[D.P_LAMBDA_REQ] / safe_thr
    sat = (u_raw >= p[D.P_U_MAX]) & (mask > 0.5)
    u = jnp.minimum(u_raw, p[D.P_U_MAX])
    l_final = lat / (1.0 - u)

    zero = jnp.zeros_like(lat)
    lf_ref[...] = jnp.where(mask > 0.5, l_final, zero)
    sat_ref[...] = sat.astype(jnp.float32)


def queueing_latency(lat, thr, mask, params):
    """Apply the 1/(1-u) correction; returns (L_final, saturated)."""
    out = jax.ShapeDtypeStruct(lat.shape, jnp.float32)
    return pl.pallas_call(
        _queueing_kernel,
        out_shape=(out, out),
        interpret=True,
    )(lat, thr, mask, params)
