"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: pytest asserts each Pallas kernel
(interpret=True) matches these reference functions with ``assert_allclose``
over hypothesis-generated workloads and parameter vectors.

All functions take the same padded arrays the kernels take; see
``defaults.py`` for the packed-parameter layout.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import defaults as D


def node_latency(cpu, ram, bw, iops_k, p):
    """L_node(V) = a/cpu + b/ram + c/bw + d/(iops/1000)   (paper III.C)."""
    return (p[D.P_A] / cpu + p[D.P_B] / ram + p[D.P_C] / bw
            + p[D.P_D] / iops_k)


def coord_latency(h, p):
    """L_coord(H) = eta * ln H + mu * H^theta   (paper III.C)."""
    return p[D.P_ETA] * jnp.log(h) + p[D.P_MU] * h ** p[D.P_THETA]


def node_throughput(cpu, ram, bw, iops_k, p):
    """T_node(V) = kappa * min(cpu, ram, bw, iops/1000)   (paper III.D)."""
    m = jnp.minimum(jnp.minimum(cpu, ram), jnp.minimum(bw, iops_k))
    return p[D.P_KAPPA] * m


def horiz_efficiency(h, p):
    """phi(H) = 1 / (1 + omega * ln H)   (paper III.D)."""
    return 1.0 / (1.0 + p[D.P_OMEGA] * jnp.log(h))


def surfaces_ref(hs, tiers, params, mask):
    """All five analytical surfaces over the padded (H, V) grid.

    hs:     f32[G]      node count for grid row i
    tiers:  f32[G, 5]   (cpu, ram, bw, iops_k, cost_node) for grid col j
    params: f32[P]      packed constants + workload
    mask:   f32[G, G]   1.0 on real cells, 0.0 on padding

    Returns (L, T, C, K, F), each f32[G, G], zeroed on padding cells.
    """
    p = params
    h = hs[:, None]                       # [G, 1]
    cpu = tiers[None, :, 0]               # [1, G]
    ram = tiers[None, :, 1]
    bw = tiers[None, :, 2]
    iops_k = tiers[None, :, 3]
    cost_node = tiers[None, :, 4]

    l_node = node_latency(cpu, ram, bw, iops_k, p)
    l_coord = coord_latency(h, p)
    lat = l_node + l_coord                            # L(H,V)
    thr = h * node_throughput(cpu, ram, bw, iops_k, p) * horiz_efficiency(h, p)
    cost = h * cost_node                              # C(H,V)
    coord = p[D.P_RHO] * l_coord * p[D.P_LAMBDA_W] / thr   # K(H,V)
    obj = (p[D.P_ALPHA] * lat + p[D.P_BETA] * cost
           + p[D.P_GAMMA] * coord - p[D.P_DELTA] * thr)    # F(H,V)

    z = jnp.zeros_like(lat)
    return tuple(jnp.where(mask > 0.5, s, z)
                 for s in (lat, thr, cost, coord, obj))


def neighbor_scores_ref(cand, params):
    """SLA-filtered, rebalance-penalized scores for a candidate batch.

    cand:   f32[N, >=9] rows (h, cpu, ram, bw, iops_k, cost_node,
            |dH idx|, |dV idx|, valid) — see defaults.C_*.
    params: f32[P]

    Returns (scores f32[N], feasible f32[N]).  Invalid or infeasible rows
    score ``defaults.INFEASIBLE``; feasible is 1.0 only for valid rows
    that satisfy both SLA conditions (paper IV.C).
    """
    p = params
    h = cand[:, D.C_H]
    cpu, ram = cand[:, D.C_CPU], cand[:, D.C_RAM]
    bw, iops_k = cand[:, D.C_BW], cand[:, D.C_IOPS_K]
    cost_node = cand[:, D.C_COST]
    adh, adv = cand[:, D.C_ADH], cand[:, D.C_ADV]
    valid = cand[:, D.C_VALID]

    l_coord = coord_latency(h, p)
    lat = node_latency(cpu, ram, bw, iops_k, p) + l_coord
    thr = h * node_throughput(cpu, ram, bw, iops_k, p) * horiz_efficiency(h, p)
    cost = h * cost_node
    coord = p[D.P_RHO] * l_coord * p[D.P_LAMBDA_W] / thr
    obj = (p[D.P_ALPHA] * lat + p[D.P_BETA] * cost
           + p[D.P_GAMMA] * coord - p[D.P_DELTA] * thr)

    t_min = p[D.P_LAMBDA_REQ] * p[D.P_B_SLA]
    ok = ((valid > 0.5)
          & (lat <= p[D.P_L_MAX])
          & (thr >= t_min))
    penalty = p[D.P_REB_H] * adh + p[D.P_REB_V] * adv   # R (paper IV.D)
    score = jnp.where(ok, obj + penalty, D.INFEASIBLE)
    return score, ok.astype(cand.dtype)


def queueing_ref(lat, thr, mask, params):
    """Utilization-sensitive latency (paper VIII, future-work model).

    u = lambda_req / T, clamped to u_max;  L_final = L / (1 - u).

    Returns (L_final f32[G,G], saturated f32[G,G]) where ``saturated`` is
    1.0 on cells whose raw utilization reached/exceeded u_max.
    """
    p = params
    safe_thr = jnp.where(thr > 0.0, thr, 1.0)
    u_raw = p[D.P_LAMBDA_REQ] / safe_thr
    sat = (u_raw >= p[D.P_U_MAX]) & (mask > 0.5)
    u = jnp.minimum(u_raw, p[D.P_U_MAX])
    l_final = lat / (1.0 - u)
    z = jnp.zeros_like(lat)
    return (jnp.where(mask > 0.5, l_final, z), sat.astype(lat.dtype))
