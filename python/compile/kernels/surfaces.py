"""L1 Pallas kernel: all five analytical surfaces over the Scaling Plane.

One fused kernel evaluates L (latency), T (throughput), C (cluster cost),
K (coordination cost) and F (objective) over the padded (H, V) grid in a
single pass — one HBM->VMEM round trip per decision instead of five
elementwise launches.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the real plane is 4x4;
the grid is padded to 8x8 f32 so each surface tile is one VMEM-resident
block. BlockSpec covers the whole (tiny) arrays; total VMEM footprint is
~8 KiB. interpret=True is mandatory for CPU-PJRT execution — real-TPU
lowering emits Mosaic custom-calls the CPU plugin cannot run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import defaults as D


def _surfaces_kernel(hs_ref, tiers_ref, params_ref, mask_ref,
                     l_ref, t_ref, c_ref, k_ref, f_ref):
    p = params_ref[...]                   # [P] in VMEM (tiny)
    h = hs_ref[...][:, None]              # [G, 1]
    tiers = tiers_ref[...]                # [G, 5]
    cpu = tiers[:, 0][None, :]            # [1, G] broadcast over rows
    ram = tiers[:, 1][None, :]
    bw = tiers[:, 2][None, :]
    iops_k = tiers[:, 3][None, :]
    cost_node = tiers[:, 4][None, :]
    mask = mask_ref[...]

    # L_node(V) + L_coord(H)  — computed once, reused by K and F.
    l_node = (p[D.P_A] / cpu + p[D.P_B] / ram + p[D.P_C] / bw
              + p[D.P_D] / iops_k)
    log_h = jnp.log(h)
    l_coord = p[D.P_ETA] * log_h + p[D.P_MU] * jnp.exp(p[D.P_THETA] * log_h)
    lat = l_node + l_coord

    # T(H,V) = H * kappa * min(resources) * phi(H)
    mins = jnp.minimum(jnp.minimum(cpu, ram), jnp.minimum(bw, iops_k))
    phi = 1.0 / (1.0 + p[D.P_OMEGA] * log_h)
    thr = h * (p[D.P_KAPPA] * mins) * phi

    cost = h * cost_node
    coord = p[D.P_RHO] * l_coord * p[D.P_LAMBDA_W] / thr
    obj = (p[D.P_ALPHA] * lat + p[D.P_BETA] * cost
           + p[D.P_GAMMA] * coord - p[D.P_DELTA] * thr)

    zero = jnp.zeros_like(lat)
    keep = mask > 0.5
    l_ref[...] = jnp.where(keep, lat, zero)
    t_ref[...] = jnp.where(keep, thr, zero)
    c_ref[...] = jnp.where(keep, cost, zero)
    k_ref[...] = jnp.where(keep, coord, zero)
    f_ref[...] = jnp.where(keep, obj, zero)


def surfaces(hs, tiers, params, mask):
    """Evaluate (L, T, C, K, F) over the padded grid.

    Shapes: hs f32[G], tiers f32[W,5], params f32[P], mask f32[G,W].
    Returns a 5-tuple of f32[G,W].  W == G for the paper's square plane;
    W == 64 for the disaggregated wide plane (paper VIII).
    """
    g = hs.shape[0]
    w = tiers.shape[0]
    out = jax.ShapeDtypeStruct((g, w), jnp.float32)
    return pl.pallas_call(
        _surfaces_kernel,
        out_shape=(out,) * 5,
        interpret=True,
    )(hs, tiers, params, mask)
