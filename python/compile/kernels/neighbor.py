"""L1 Pallas kernel: SLA-filtered neighbor scoring (Algorithm 1 core).

Scores a padded batch of candidate configurations: computes the four
surfaces per row, applies the SLA feasibility filter (paper IV.C), adds
the rebalance penalty R = reb_h*|dH| + reb_v*|dV| (paper IV.D), and
emits ``INFEASIBLE`` for filtered rows.  The argmin stays on the caller's
side (rust / L2) so tie-breaking order is explicit and shared.

The candidate matrix is padded to 16x16 f32 (9 real columns, <=9 real
rows) so the whole batch is one VMEM block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import defaults as D


def _neighbor_kernel(cand_ref, params_ref, score_ref, feas_ref):
    p = params_ref[...]
    cand = cand_ref[...]                  # [N, >=9]
    h = cand[:, D.C_H]
    cpu, ram = cand[:, D.C_CPU], cand[:, D.C_RAM]
    bw, iops_k = cand[:, D.C_BW], cand[:, D.C_IOPS_K]
    cost_node = cand[:, D.C_COST]
    adh, adv = cand[:, D.C_ADH], cand[:, D.C_ADV]
    valid = cand[:, D.C_VALID]

    log_h = jnp.log(h)
    l_coord = p[D.P_ETA] * log_h + p[D.P_MU] * jnp.exp(p[D.P_THETA] * log_h)
    lat = (p[D.P_A] / cpu + p[D.P_B] / ram + p[D.P_C] / bw
           + p[D.P_D] / iops_k) + l_coord
    mins = jnp.minimum(jnp.minimum(cpu, ram), jnp.minimum(bw, iops_k))
    thr = h * (p[D.P_KAPPA] * mins) / (1.0 + p[D.P_OMEGA] * log_h)
    cost = h * cost_node
    coord = p[D.P_RHO] * l_coord * p[D.P_LAMBDA_W] / thr
    obj = (p[D.P_ALPHA] * lat + p[D.P_BETA] * cost
           + p[D.P_GAMMA] * coord - p[D.P_DELTA] * thr)

    t_min = p[D.P_LAMBDA_REQ] * p[D.P_B_SLA]
    ok = ((valid > 0.5) & (lat <= p[D.P_L_MAX]) & (thr >= t_min))
    penalty = p[D.P_REB_H] * adh + p[D.P_REB_V] * adv
    score_ref[...] = jnp.where(ok, obj + penalty,
                               jnp.full_like(obj, D.INFEASIBLE))
    feas_ref[...] = ok.astype(jnp.float32)


def neighbor_scores(cand, params):
    """Score a candidate batch; returns (scores f32[N], feasible f32[N])."""
    n = cand.shape[0]
    return pl.pallas_call(
        _neighbor_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(cand, params)
