# L1 Pallas kernels: surfaces, neighbor scoring, queueing latency.
