"""Default model constants for the Diagonal Scaling surfaces.

Single source of truth on the python side, mirroring
``config/default.toml`` (the rust side's source of truth).  The kernels
never bake these in — every entry point takes the tier table and the
packed parameter vector as *runtime arguments* so the rust coordinator
can drive both the native and the HLO path from the same TOML file.

Packed parameter vector layout (f32[PARAMS_LEN], padded with zeros):

    idx  name        meaning
    ---  ----        -------
      0  a           L_node cpu coefficient
      1  b           L_node ram coefficient
      2  c           L_node bandwidth coefficient
      3  d           L_node iops coefficient
      4  eta         L_coord log coefficient
      5  mu          L_coord power coefficient
      6  theta       L_coord power exponent
      7  kappa       T_node scale
      8  omega       horizontal efficiency decay
      9  rho         coordination-cost scale
     10  alpha       objective latency weight
     11  beta        objective cost weight
     12  gamma       objective coordination weight
     13  delta       objective throughput reward
     14  lambda_w    write arrival rate        (workload, per step)
     15  lambda_req  required throughput       (workload, per step)
     16  b_sla       throughput SLA buffer
     17  l_max       latency SLA bound
     18  reb_h       rebalance penalty per |dH index|
     19  reb_v       rebalance penalty per |dV index|
     20  n_h         number of real H values in the (padded) grid
     21  n_v         number of real V tiers in the (padded) grid
     22  allow_dh    policy may change H (1.0) or not (0.0)
     23  allow_dv    policy may change V (1.0) or not (0.0)
     24  u_max       utilization clamp for the queueing extension
     25  write_ratio workload write fraction (informational)
     26  plan_queue  planner also uses queueing latency (1.0) or the
                     paper's raw Phase-1 surfaces (0.0, default)

Simulation semantics (shared by model.policy_trace, the numpy
calibrator, and the rust simulator — they must agree bit-for-bit in
structure):

  * serve-then-move: the config carried into step t serves workload t;
    per-step metrics are measured at that config; the Algorithm-1
    decision made with workload t takes effect at step t+1.
  * planner feasibility uses the paper's raw analytical surfaces
    (L <= l_max, T >= lambda_req * b_sla) unless plan_queue is set.
  * *measured* latency is utilization-corrected (paper §VIII):
    u = lambda_req / T clamped to u_max; L_eff = L / (1 - u).  The
    reported objective uses L_eff; violation accounting uses raw L for
    the latency SLA (planner-consistent) and raw lambda_req for the
    throughput SLA (the b_sla buffer is planning headroom only).
"""

from __future__ import annotations

import numpy as np

# Padded grid edge: the real plane is 4x4 (H in {1,2,4,8} x 4 tiers) but
# the kernels operate on an 8x8 f32 grid so one surface tile is a single
# VMEM-resident block on TPU.  Padding cells are masked out.
GRID = 8
# Wide grid for the disaggregated 4-D plane (paper VIII): 4x4x4 = 64
# (compute, memory, storage) combos as columns, H as rows.
WIDE = 64
PARAMS_LEN = 32
NEIGHBOR_ROWS = 16  # candidate rows, padded (real neighborhood is <= 9)
NEIGHBOR_COLS = 16  # candidate feature columns, padded (9 used)

# -- parameter indices -------------------------------------------------
P_A, P_B, P_C, P_D = 0, 1, 2, 3
P_ETA, P_MU, P_THETA = 4, 5, 6
P_KAPPA, P_OMEGA, P_RHO = 7, 8, 9
P_ALPHA, P_BETA, P_GAMMA, P_DELTA = 10, 11, 12, 13
P_LAMBDA_W, P_LAMBDA_REQ = 14, 15
P_B_SLA, P_L_MAX = 16, 17
P_REB_H, P_REB_V = 18, 19
P_N_H, P_N_V = 20, 21
P_ALLOW_DH, P_ALLOW_DV = 22, 23
P_U_MAX, P_WRITE_RATIO = 24, 25
P_PLAN_QUEUE = 26  # planner feasibility/objective use queueing latency

# -- candidate row feature columns (neighbor kernel) -------------------
C_H, C_CPU, C_RAM, C_BW, C_IOPS_K, C_COST, C_ADH, C_ADV, C_VALID = range(9)

# Sentinel score for infeasible / invalid candidates.
INFEASIBLE = 1.0e30

# -- default plane ------------------------------------------------------
H_VALUES = [1.0, 2.0, 4.0, 8.0]

# tier -> (cpu, ram, bandwidth, iops/1000, cost_node)
TIERS = {
    "small": (2.0, 4.0, 2.5, 3.0, 0.08),
    "medium": (4.0, 8.0, 5.0, 6.0, 0.20),
    "large": (8.0, 16.0, 10.0, 12.0, 0.45),
    "xlarge": (16.0, 32.0, 20.0, 24.0, 1.00),
}
TIER_NAMES = list(TIERS)

# -- default constants (calibrated; see EXPERIMENTS.md) -----------------
DEFAULTS = dict(
    a=4.0, b=4.0, c=2.0, d=3.0,
    eta=1.0, mu=0.24, theta=1.125,
    kappa=585.0, omega=0.25, rho=1.0,
    alpha=5.0, beta=30.0, gamma=1.0, delta=0.0005,
    b_sla=1.15, l_max=5.0,
    reb_h=2.0, reb_v=1.0,
    u_max=0.75,
)

# Paper simulation start config: (H=2, medium) as grid indices.
START = (1, 1)

TRACE_LEN = 50  # the paper's 50-step dynamic workload timeline
THR_FACTOR = 100.0  # required throughput = intensity * factor
WRITE_RATIO = 0.3


def grid_arrays(dtype=np.float32):
    """Padded (hs[GRID], tiers[GRID,5], mask[GRID,GRID]) arrays."""
    hs = np.zeros(GRID, dtype=dtype)
    hs[: len(H_VALUES)] = H_VALUES
    hs[len(H_VALUES):] = 1.0  # benign padding (log/pow stay finite)
    tiers = np.ones((GRID, 5), dtype=dtype)  # benign padding (no div-by-0)
    for j, name in enumerate(TIER_NAMES):
        tiers[j] = TIERS[name]
    mask = np.zeros((GRID, GRID), dtype=dtype)
    mask[: len(H_VALUES), : len(TIER_NAMES)] = 1.0
    return hs, tiers, mask


def params_vec(lambda_req=10000.0, write_ratio=WRITE_RATIO,
               allow_dh=1.0, allow_dv=1.0, plan_queue=0.0,
               dtype=np.float32, **over):
    """Packed parameter vector with defaults, overridable per test."""
    d = dict(DEFAULTS)
    d.update(over)
    p = np.zeros(PARAMS_LEN, dtype=dtype)
    p[P_A], p[P_B], p[P_C], p[P_D] = d["a"], d["b"], d["c"], d["d"]
    p[P_ETA], p[P_MU], p[P_THETA] = d["eta"], d["mu"], d["theta"]
    p[P_KAPPA], p[P_OMEGA], p[P_RHO] = d["kappa"], d["omega"], d["rho"]
    p[P_ALPHA], p[P_BETA] = d["alpha"], d["beta"]
    p[P_GAMMA], p[P_DELTA] = d["gamma"], d["delta"]
    p[P_LAMBDA_W] = lambda_req * write_ratio
    p[P_LAMBDA_REQ] = lambda_req
    p[P_B_SLA], p[P_L_MAX] = d["b_sla"], d["l_max"]
    p[P_REB_H], p[P_REB_V] = d["reb_h"], d["reb_v"]
    p[P_N_H], p[P_N_V] = float(len(H_VALUES)), float(len(TIER_NAMES))
    p[P_ALLOW_DH], p[P_ALLOW_DV] = allow_dh, allow_dv
    p[P_U_MAX], p[P_WRITE_RATIO] = d["u_max"], write_ratio
    p[P_PLAN_QUEUE] = plan_queue
    return p


def paper_trace(dtype=np.float32):
    """The paper's 50-step workload timeline as (lambda_req, lambda_w)."""
    intensity = np.array(
        [60.0] * 10 + [100.0] * 10 + [160.0] * 10 + [100.0] * 10 + [60.0] * 10,
        dtype=dtype,
    )
    lam_req = intensity * THR_FACTOR
    lam_w = lam_req * WRITE_RATIO
    return np.stack([lam_req, lam_w], axis=1)
