"""AOT lowering: jax/Pallas entry points -> HLO *text* artifacts.

Interchange format is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids, so text round-trips cleanly (see
/opt/xla-example/gen_hlo.py and /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one .hlo.txt per entry point plus a manifest describing argument
shapes, output arity, and the packed-parameter layout version, which the
rust runtime validates at load time.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import defaults as D
from . import model

# Bump when the packed-parameter layout or record layout changes; the
# rust runtime refuses to load artifacts with a different version.
ABI_VERSION = 1

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def entry_points(trace_lens=(50, 200)):
    """(name, fn, example_args) for every AOT artifact."""
    g, p = D.GRID, D.PARAMS_LEN
    n, c = D.NEIGHBOR_ROWS, D.NEIGHBOR_COLS
    eps = [
        ("surfaces", model.surface_grid,
         (_spec(g), _spec(g, 5), _spec(p), _spec(g, g))),
        # the disaggregated 4-D plane (paper VIII): H x (C,M,S) combos
        # flattened into a wide tier table — same kernel, wider grid
        ("surfaces_wide", model.surface_grid,
         (_spec(g), _spec(D.WIDE, 5), _spec(p), _spec(g, D.WIDE))),
        ("neighbor", model.neighbor_batch,
         (_spec(n, c), _spec(p))),
        ("queueing", model.queueing_grid,
         (_spec(g), _spec(g, 5), _spec(p), _spec(g, g))),
    ]
    for t in trace_lens:
        eps.append((f"policy_trace_{t}", model.policy_trace,
                    (_spec(g), _spec(g, 5), _spec(p), _spec(g, g),
                     _spec(t, 2), _spec(2))))
    return eps


def lower_all(out_dir: str, trace_lens=(50, 200)) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "abi_version": ABI_VERSION,
        "grid": D.GRID,
        "params_len": D.PARAMS_LEN,
        "neighbor_rows": D.NEIGHBOR_ROWS,
        "neighbor_cols": D.NEIGHBOR_COLS,
        "rec_len": model.REC_LEN,
        "entry_points": {},
    }
    for name, fn, args in entry_points(trace_lens):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        n_out = len(jax.tree_util.tree_leaves(lowered.out_info))
        manifest["entry_points"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [list(a.shape) for a in args],
            "num_outputs": n_out,
        }
        print(f"  {name}: {len(text)} chars, {n_out} outputs -> {path}")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  manifest -> {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="compat: also copy surfaces artifact to this path")
    ap.add_argument("--trace-lens", type=int, nargs="*", default=[50, 200])
    args = ap.parse_args()
    lower_all(args.out_dir, tuple(args.trace_lens))
    if args.out:
        import shutil
        shutil.copy(os.path.join(args.out_dir, "surfaces.hlo.txt"), args.out)


if __name__ == "__main__":
    main()
