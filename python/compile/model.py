"""L2: the Diagonal Scaling compute graph, composed from the L1 kernels.

Entry points (each AOT-lowered to HLO text by ``aot.py``):

  surface_grid     — (L, T, C, K, F) over the padded Scaling Plane
  neighbor_batch   — SLA-filtered scores for a candidate batch
  queueing_grid    — surfaces + the 1/(1-u) queueing correction (VIII)
  policy_trace     — the ENTIRE Phase-1 policy simulation (Algorithm 1
                     over a workload trace) as a single lax.scan: at each
                     step, evaluate the surface grid with the Pallas
                     kernel, mask the local neighborhood, SLA-filter,
                     add the rebalance penalty, argmin, and move.

Everything here runs ONCE at build time; the rust coordinator executes
the lowered HLO via PJRT on the decision path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import defaults as D
from .kernels.neighbor import neighbor_scores
from .kernels.queueing import queueing_latency
from .kernels.surfaces import surfaces

# Per-step record layout emitted by policy_trace (f32[T, REC_LEN]).
REC_H_IDX, REC_V_IDX, REC_LAT, REC_THR = 0, 1, 2, 3
REC_COST, REC_OBJ, REC_LAT_VIOL, REC_THR_VIOL = 4, 5, 6, 7
REC_LEN = 8


def surface_grid(hs, tiers, params, mask):
    """All five surfaces over the padded plane (tuple of f32[G,G])."""
    return surfaces(hs, tiers, params, mask)


def neighbor_batch(cand, params):
    """(scores, feasible) for a padded candidate batch."""
    return neighbor_scores(cand, params)


def queueing_grid(hs, tiers, params, mask):
    """Surfaces with the utilization-corrected latency (paper VIII).

    Returns (L_final, saturated, L, T, C, K, F).
    """
    lat, thr, cost, coord, obj = surfaces(hs, tiers, params, mask)
    l_final, sat = queueing_latency(lat, thr, mask, params)
    return l_final, sat, lat, thr, cost, coord, obj


def _step(hs, tiers, params, mask, carry, lam):
    """One simulation step: serve, measure, then decide (Algorithm 1).

    The config carried into the step serves the step's workload; the
    decision made here takes effect at the next step (reconfiguration is
    not instantaneous).  See defaults.py for the full semantics note.
    """
    h_idx, v_idx = carry
    lam_req, lam_w = lam[0], lam[1]
    p = params.at[D.P_LAMBDA_W].set(lam_w).at[D.P_LAMBDA_REQ].set(lam_req)

    lat, thr, cost, coord, obj = surfaces(hs, tiers, p, mask)

    # Measured latency is utilization-corrected (paper VIII): the planner
    # may model latency analytically, but the served latency spikes as
    # utilization approaches capacity.
    safe_thr = jnp.where(thr > 0.0, thr, jnp.ones_like(thr))
    u = jnp.minimum(lam_req / safe_thr, p[D.P_U_MAX])
    lat_eff = lat / (1.0 - u)
    obj_eff = (p[D.P_ALPHA] * lat_eff + p[D.P_BETA] * cost
               + p[D.P_GAMMA] * coord - p[D.P_DELTA] * thr)

    # ---- measurement at the serving configuration --------------------
    srv_lat_raw = lat[h_idx, v_idx]
    srv_thr = thr[h_idx, v_idx]
    rec = jnp.stack([
        h_idx.astype(jnp.float32),
        v_idx.astype(jnp.float32),
        lat_eff[h_idx, v_idx],
        srv_thr,
        cost[h_idx, v_idx],
        obj_eff[h_idx, v_idx],
        (srv_lat_raw > p[D.P_L_MAX]).astype(jnp.float32),
        (srv_thr < lam_req).astype(jnp.float32),
    ])

    # ---- Algorithm 1 decision -----------------------------------------
    g = hs.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (g, g), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (g, g), 1)
    di = jnp.abs(rows - h_idx)
    dj = jnp.abs(cols - v_idx)

    # Neighborhood: previous/next valid index on each axis (paper IV.B),
    # restricted to the moves the policy is allowed to make.
    allowed = (di <= 1) & (dj <= 1) & (mask > 0.5)
    allowed &= jnp.where(p[D.P_ALLOW_DH] > 0.5, True, di == 0)
    allowed &= jnp.where(p[D.P_ALLOW_DV] > 0.5, True, dj == 0)

    # SLA feasibility (paper IV.C) — raw Phase-1 surfaces unless the
    # queueing-aware-planner extension is enabled.
    plan_lat = jnp.where(p[D.P_PLAN_QUEUE] > 0.5, lat_eff, lat)
    plan_obj = jnp.where(p[D.P_PLAN_QUEUE] > 0.5, obj_eff, obj)
    t_min = lam_req * p[D.P_B_SLA]
    feasible = allowed & (plan_lat <= p[D.P_L_MAX]) & (thr >= t_min)

    penalty = (p[D.P_REB_H] * di.astype(jnp.float32)
               + p[D.P_REB_V] * dj.astype(jnp.float32))
    score = jnp.where(feasible, plan_obj + penalty,
                      jnp.full_like(obj, D.INFEASIBLE))

    flat = score.reshape(-1)
    best = jnp.argmin(flat)               # first minimum — row-major order
    any_feasible = flat[best] < D.INFEASIBLE * 0.5
    best_h = (best // g).astype(jnp.int32)
    best_v = (best % g).astype(jnp.int32)

    # Fallback (Algorithm 1 line 18): one-step scale-up along the axes the
    # policy may move on — diagonal for DiagonalScale, axis for baselines.
    n_h = p[D.P_N_H].astype(jnp.int32)
    n_v = p[D.P_N_V].astype(jnp.int32)
    step_h = (p[D.P_ALLOW_DH] > 0.5).astype(jnp.int32)
    step_v = (p[D.P_ALLOW_DV] > 0.5).astype(jnp.int32)
    fb_h = jnp.minimum(h_idx + step_h, n_h - 1)
    fb_v = jnp.minimum(v_idx + step_v, n_v - 1)

    new_h = jnp.where(any_feasible, best_h, fb_h).astype(jnp.int32)
    new_v = jnp.where(any_feasible, best_v, fb_v).astype(jnp.int32)
    return (new_h, new_v), rec


def policy_trace(hs, tiers, params, mask, trace, start):
    """Run Algorithm 1 over a whole workload trace in one XLA program.

    hs f32[G], tiers f32[G,5], params f32[P], mask f32[G,G],
    trace f32[T,2] rows (lambda_req, lambda_w), start f32[2] (h_idx, v_idx).

    Returns f32[T, REC_LEN]; see the REC_* constants.
    """
    params = jnp.asarray(params)
    start = jnp.asarray(start)
    h0 = start[0].astype(jnp.int32)
    v0 = start[1].astype(jnp.int32)

    def body(carry, lam):
        return _step(hs, tiers, params, mask, carry, lam)

    _, recs = jax.lax.scan(body, (h0, v0), trace)
    return recs
