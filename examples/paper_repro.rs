//! Full paper reproduction: regenerates **Table I and every figure
//! (1–8)** of "Diagonal Scaling" into `out/`, prints the measured
//! Table I next to the paper's reported values, and cross-checks the
//! whole simulation against the AOT-compiled `policy_trace` kernel on
//! PJRT when artifacts are present.
//!
//! ```text
//! make artifacts && cargo run --release --example paper_repro
//! ```
//!
//! The reproduction bar (DESIGN.md §4): orderings and rough factors
//! must match — absolute synthetic units need not.

use diagonal_scale::config::{ModelConfig, MoveFlags};
use diagonal_scale::report;
use diagonal_scale::runtime::{Engine, SurfaceEngine};
use diagonal_scale::simulator::Simulator;
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::workload::TraceBuilder;

/// Paper Table I values: (avg latency, avg thr, avg cost, total cost,
/// avg objective, SLA violations).
const PAPER: [(&str, f64, f64, f64, f64, f64, usize); 3] = [
    ("DiagonalScale", 4.05, 13506.13, 1.624, 81.2, 65.53, 3),
    ("Horizontal-only", 13.06, 10293.20, 1.560, 78.0, 180.94, 32),
    ("Vertical-only", 4.89, 12068.66, 1.416, 70.8, 77.70, 21),
];

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);
    let model = SurfaceModel::from_config(&cfg);

    println!("== Phase-1 analytical simulation (50-step paper trace) ==\n");
    let runs = sim.run_paper_set(&trace);

    println!("{:<18} {:>22} {:>22} {:>22} {:>18}", "", "avg latency", "avg cost", "avg objective", "SLA violations");
    println!("{:<18} {:>10} {:>11} {:>10} {:>11} {:>10} {:>11} {:>8} {:>9}",
        "policy", "paper", "measured", "paper", "measured", "paper", "measured", "paper", "measured");
    for (run, paper) in runs.iter().zip(&PAPER) {
        let s = &run.summary;
        println!(
            "{:<18} {:>10.2} {:>11.2} {:>10.3} {:>11.3} {:>10.2} {:>11.2} {:>8} {:>9}",
            run.policy, paper.1, s.avg_latency, paper.3, s.avg_cost, paper.5,
            s.avg_objective, paper.6, s.violations
        );
    }

    // the shape checks the test suite enforces, restated for the reader
    let (ds, hz, vt) = (&runs[0].summary, &runs[1].summary, &runs[2].summary);
    println!("\nshape checks (paper section VI):");
    println!(
        "  violations  DiagonalScale < Vertical-only < Horizontal-only : {} < {} < {}  [paper: 3 < 21 < 32]",
        ds.violations, vt.violations, hz.violations
    );
    println!(
        "  latency     DiagonalScale < Vertical-only < Horizontal-only : {:.2} < {:.2} < {:.2}  [paper: 4.05 < 4.89 < 13.06]",
        ds.avg_latency, vt.avg_latency, hz.avg_latency
    );
    println!(
        "  objective   DiagonalScale < Vertical-only < Horizontal-only : {:.2} < {:.2} < {:.2}  [paper: 65.53 < 77.70 < 180.94]",
        ds.avg_objective, vt.avg_objective, hz.avg_objective
    );
    println!(
        "  cost        DiagonalScale pays the premium                  : {:.3} >= max({:.3}, {:.3})  [paper: 1.624 highest]",
        ds.avg_cost, vt.avg_cost, hz.avg_cost
    );

    // Table I + figures 1-8 to disk
    let files = report::write_all_figures("out", &model, &runs, 10_000.0)?;
    println!("\n== artifacts written ==");
    for f in &files {
        println!("  {f}");
    }

    // cross-check: the entire Algorithm-1 loop inside XLA
    let artifacts = Engine::default_dir();
    if artifacts.join("manifest.json").exists() {
        println!("\n== PJRT cross-check (policy_trace artifact) ==");
        let eng = SurfaceEngine::new(Engine::load(&artifacts)?, &cfg)?;
        let start = (cfg.policy.start[0], cfg.policy.start[1]);
        for (run, moves) in runs.iter().zip([
            MoveFlags::DIAGONAL,
            MoveFlags::HORIZONTAL_ONLY,
            MoveFlags::VERTICAL_ONLY,
        ]) {
            let recs = eng.policy_trace(&trace, moves, start)?;
            let diverge = run
                .records
                .iter()
                .zip(&recs)
                .filter(|(n, h)| (n.config.h_idx, n.config.v_idx) != (h.h_idx, h.v_idx))
                .count();
            let viol = recs
                .iter()
                .filter(|r| r.latency_violation || r.throughput_violation)
                .count();
            println!(
                "  {:<18} trajectory divergence: {} / {} steps  violations: native {} vs HLO {}",
                run.policy,
                diverge,
                recs.len(),
                run.summary.violations,
                viol
            );
        }
    } else {
        println!("\n(run `make artifacts` to enable the PJRT cross-check)");
    }
    Ok(())
}
