//! Lookahead extension (paper §VIII, third extension): multi-step
//! search reduces the transient SLA violations that one-step local
//! search suffers during sudden spikes (paper §VII limitation 3).
//!
//! ```text
//! cargo run --release --example lookahead
//! ```
//!
//! Sweeps lookahead depth 1–3 against spike traces of increasing
//! severity and prints violations / latency / cost per depth.

use diagonal_scale::config::ModelConfig;
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::workload::TraceBuilder;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let b = TraceBuilder::from_config(&cfg);

    println!("== sudden-spike traces: greedy one-step search vs lookahead ==\n");
    for (label, base, peak) in [
        ("mild   (60 -> 120)", 60.0, 120.0),
        ("paper  (60 -> 160)", 60.0, 160.0),
        ("severe (40 -> 160)", 40.0, 160.0),
    ] {
        let trace = b.spike(base, peak, 15, 10, 40);
        println!("spike {label}:");
        println!(
            "  {:<22} {:>10} {:>10} {:>10} {:>10}",
            "policy", "violations", "avg lat", "avg cost", "fallbacks"
        );
        let greedy = sim.run(PolicyKind::Diagonal, &trace);
        println!(
            "  {:<22} {:>10} {:>10.2} {:>10.3} {:>10}",
            "greedy (depth 1)",
            greedy.summary.violations,
            greedy.summary.avg_latency,
            greedy.summary.avg_cost,
            greedy.fallbacks
        );
        for depth in [2usize, 3] {
            let run = sim.run(PolicyKind::Lookahead(depth), &trace);
            println!(
                "  {:<22} {:>10} {:>10.2} {:>10.3} {:>10}",
                format!("lookahead depth {depth}"),
                run.summary.violations,
                run.summary.avg_latency,
                run.summary.avg_cost,
                run.fallbacks
            );
        }
        println!();
    }

    println!("note: lookahead trades cost for SLA compliance — it pre-scales\n\
              before the spike arrives, paying for capacity it does not yet\n\
              need. The paper's rebalance penalty makes this explicit: the\n\
              pre-scaled path pays R earlier but avoids the infeasible window.");
    Ok(())
}
