//! Forecast-driven autoscaling: lookahead (paper §VIII) without an
//! oracle future. The coordinator forecasts demand from its own
//! observations (moving average / Holt / seasonal-naive) and expands
//! the lookahead tree over the forecast.
//!
//! ```text
//! cargo run --release --example forecast_autoscale
//! ```

use diagonal_scale::config::{ModelConfig, MoveFlags};
use diagonal_scale::forecast::{mape_one_step, Holt, MovingAverage, SeasonalNaive};
use diagonal_scale::policy::ForecastLookahead;
use diagonal_scale::simulator::{PolicyKind, RunResult, Simulator};
use diagonal_scale::workload::{Trace, TraceBuilder};

fn row(label: &str, r: &RunResult) {
    println!(
        "  {:<30} violations={:<3} lat={:>6.2} cost={:>6.3} obj={:>8.2}",
        label, r.summary.violations, r.summary.avg_latency, r.summary.avg_cost,
        r.summary.avg_objective
    );
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let b = TraceBuilder::from_config(&cfg);

    // a repeating daily-like cycle: three repetitions of the paper trace
    let one = TraceBuilder::paper(&cfg);
    let mut points = one.points.clone();
    points.extend(one.points.iter().copied());
    points.extend(one.points.iter().copied());
    let cycle = Trace { name: "paper-x3".into(), points };

    println!("== forecast quality (one-step MAPE on the repeating trace) ==\n");
    let series: Vec<f64> = cycle.points.iter().map(|p| p.lambda_req as f64).collect();
    println!(
        "  moving-average(8): {:.3}   holt(0.7,0.3): {:.3}   seasonal-naive(50): {:.3}\n",
        mape_one_step(&mut MovingAverage::new(8), &series),
        mape_one_step(&mut Holt::default_tuned(), &series),
        mape_one_step(&mut SeasonalNaive::new(50), &series),
    );

    println!("== policies on the repeating trace (150 steps) ==\n");
    row("reactive DiagonalScale", &sim.run(PolicyKind::Diagonal, &cycle));
    row("oracle-future lookahead d=3", &sim.run(PolicyKind::Lookahead(3), &cycle));
    let wr = cfg.write_ratio();
    let mut ma = ForecastLookahead::new(MoveFlags::DIAGONAL, 3, MovingAverage::new(8), wr);
    row("forecast lookahead (MA-8)", &sim.run_boxed(&mut ma, "fl-ma", &cycle));
    let mut holt = ForecastLookahead::new(MoveFlags::DIAGONAL, 3, Holt::default_tuned(), wr);
    row("forecast lookahead (Holt)", &sim.run_boxed(&mut holt, "fl-holt", &cycle));
    let mut sn = ForecastLookahead::new(MoveFlags::DIAGONAL, 3, SeasonalNaive::new(50), wr);
    row("forecast lookahead (seasonal)", &sim.run_boxed(&mut sn, "fl-sn", &cycle));

    println!("\n== sudden spike (no seasonality to learn) ==\n");
    let spike = b.spike(40.0, 160.0, 15, 10, 40);
    row("reactive DiagonalScale", &sim.run(PolicyKind::Diagonal, &spike));
    row("oracle-future lookahead d=3", &sim.run(PolicyKind::Lookahead(3), &spike));
    let mut holt2 = ForecastLookahead::new(MoveFlags::DIAGONAL, 3, Holt::default_tuned(), wr);
    row("forecast lookahead (Holt)", &sim.run_boxed(&mut holt2, "fl-holt", &spike));
    println!(
        "\nreading: with a true future, lookahead nearly eliminates the ramp\n\
         transients (serve-then-move alignment: level-0 candidates are scored\n\
         against the demand they will actually serve). A seasonal forecaster\n\
         earns most of that benefit once it has seen one cycle; a lagging\n\
         moving average is actively harmful; and an unforecastable spike is\n\
         exactly the paper's §VII limitation — only oracle knowledge (or\n\
         over-provisioning) removes those transients."
    );
    Ok(())
}
