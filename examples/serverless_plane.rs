//! Disaggregated / serverless Scaling Plane (paper §VIII, final
//! extension): compute, memory, and storage scale independently —
//! a 4-D plane `(H, C, M, S)` with 256 configurations instead of 16.
//!
//! ```text
//! make artifacts && cargo run --release --example serverless_plane
//! ```
//!
//! Runs the paper's 50-step trace on both planes, shows the coupled
//! ladder is a strict subspace (matched combos reproduce Table I
//! exactly), quantifies the cost savings disaggregation buys, and
//! cross-checks the 4-D surfaces against the `surfaces_wide` AOT
//! Pallas kernel on PJRT.

use diagonal_scale::config::ModelConfig;
use diagonal_scale::disagg::{wide_grid_arrays, DisaggModel, WIDE};
use diagonal_scale::runtime::{Engine, SurfaceEngine};
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::workload::TraceBuilder;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::default_paper();
    let trace = TraceBuilder::paper(&cfg);

    println!("== coupled 2-D plane (paper) vs disaggregated 4-D plane (paper VIII) ==\n");
    let coupled = Simulator::new(&cfg).run(PolicyKind::Diagonal, &trace);

    let model = DisaggModel::from_config(&cfg);
    let start = model.plane().matched(cfg.policy.start[0], cfg.policy.start[1]);
    let (records, summary, fallbacks) = model.simulate(&trace, start);

    println!(
        "{:<26} {:>6} {:>10} {:>9} {:>10} {:>10}",
        "plane", "viol.", "avg lat", "avg cost", "total cost", "avg obj"
    );
    println!(
        "{:<26} {:>6} {:>10.2} {:>9.3} {:>10.1} {:>10.2}",
        "coupled (H, V) — 16 cfgs",
        coupled.summary.violations,
        coupled.summary.avg_latency,
        coupled.summary.avg_cost,
        coupled.summary.total_cost,
        coupled.summary.avg_objective
    );
    println!(
        "{:<26} {:>6} {:>10.2} {:>9.3} {:>10.1} {:>10.2}",
        "disagg (H,C,M,S) — 256",
        summary.violations,
        summary.avg_latency,
        summary.avg_cost,
        summary.total_cost,
        summary.avg_objective
    );
    let saving = 100.0 * (1.0 - summary.total_cost / coupled.summary.total_cost);
    println!(
        "\ncost saving from independent axes: {saving:.1}%  (fallbacks: {fallbacks})\n"
    );

    // where the savings come from: the final high-load configuration
    let peak = &records[25];
    println!(
        "peak-phase example: disagg serves the high phase at cost {:.3}/step while\n\
         the coupled plane pays {:.3}/step — the 4-D policy buys the bottleneck\n\
         resource (compute for throughput) without the bundled memory/storage.\n",
        peak.cost,
        coupled.records[25].cost
    );

    // PJRT cross-check over all 256 configs through the wide kernel
    let artifacts = Engine::default_dir();
    if artifacts.join("manifest.json").exists() {
        let eng = SurfaceEngine::new(Engine::load(&artifacts)?, &cfg)?;
        let (hs, tiers, mask, combos) = wide_grid_arrays(model.plane());
        let grids = eng.surfaces_wide(&hs, &tiers, &mask, 9600.0)?;
        let mut max_rel = 0.0f32;
        for h in 0..4 {
            for (j, combo) in combos.iter().enumerate() {
                let c = diagonal_scale::disagg::DisaggConfig::new(
                    h, combo.c_idx, combo.m_idx, combo.s_idx,
                );
                let native = model.evaluate(&c, 9600.0).objective;
                let hlo = grids[4][h * WIDE + j];
                let rel = (native - hlo).abs() / native.abs().max(1.0);
                max_rel = max_rel.max(rel);
            }
        }
        println!(
            "PJRT `surfaces_wide` cross-check over 256 configs: max relative error {max_rel:.2e}"
        );
    } else {
        println!("(run `make artifacts` to enable the PJRT cross-check)");
    }
    Ok(())
}
