//! Scale-to-zero demo: a mostly-idle fleet on the serverless tier
//! against the same fleet always-on.
//!
//! ```text
//! cargo run --release --example scale_to_zero
//! ```
//!
//! 16 tenants, 75% of them idle except one short burst per cycle. With
//! the serverless tier on, idle tenants drain to the shared storage
//! service (per-GB-hour pricing, no compute), and each burst wakes its
//! tenant through a priced cold-start window on the fleet's DES
//! calendar. The A/B at the end shows the cost cut and the bounded
//! violation ticks the cold starts introduce.

use diagonal_scale::fleet::{self, FleetResult, FleetSimulator};
use diagonal_scale::serverless::{mostly_idle_specs, ServerlessParams};
use diagonal_scale::ModelConfig;

fn total_cost(res: &FleetResult) -> f64 {
    res.ticks.iter().map(|t| t.spend as f64).sum()
}

fn total_violations(res: &FleetResult) -> usize {
    res.report.tenants.iter().map(|t| t.summary.violations).sum()
}

fn main() {
    let cfg = ModelConfig::default_paper();
    let (n, idle_fraction, steps) = (16usize, 0.75f32, 100usize);
    let budget = 1.0e6f32; // uncapped: the demo is about pricing, not admission

    let mut always_on =
        FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, n, idle_fraction), budget, 3);
    let base = always_on.run(steps);

    let mut fleet =
        FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, n, idle_fraction), budget, 3);
    fleet.enable_serverless(ServerlessParams::default());
    let res = fleet.run(steps);

    let storage = fleet.storage().expect("serverless mode is on");
    println!(
        "storage service: {:.1} GB parked @ {:.4}/GB-hour = {:.4}/h floor\n",
        storage.total_gb(),
        storage.params().storage_price_gb_hour,
        storage.total_storage_cost(),
    );

    // lifecycle timeline: print the ticks where the fleet's suspended /
    // resuming mix changes or a cold-start window closes
    println!("tick  suspended  resuming  wakes  spend/h");
    let mut last = (usize::MAX, usize::MAX);
    for t in &res.ticks {
        if (t.suspended, t.resuming) != last || t.resume_ends > 0 {
            println!(
                "{:>4}  {:>9}  {:>8}  {:>5}  {:>7.3}",
                t.step, t.suspended, t.resuming, t.resume_ends, t.spend
            );
            last = (t.suspended, t.resuming);
        }
    }

    println!("\n{}", fleet::report::table(&res.report));

    let wakes: usize = res.ticks.iter().map(|t| t.resume_ends).sum();
    let (base_cost, sv_cost) = (total_cost(&base), total_cost(&res));
    println!(
        "A/B: serverless {sv_cost:.1} vs always-on {base_cost:.1} \
         ({:.0}% of always-on) | violations {} vs {} | {wakes} cold starts",
        100.0 * sv_cost / base_cost.max(1e-9),
        total_violations(&res),
        total_violations(&base),
    );
    assert!(sv_cost < base_cost, "scale-to-zero must undercut always-on");
}
