//! Proposal-first explain: watch DIAGONALSCALE *rank* its whole
//! neighborhood on the paper trace instead of answering with a single
//! move.
//!
//! ```text
//! cargo run --release --example proposal_explain
//! ```
//!
//! 1. Run the Phase-1 simulation with top-3 explain capture: every
//!    step records the proposal's ranked candidates (target, ranking
//!    score, hourly cost, claimed gain, SLA feasibility).
//! 2. Print the dump for the interesting steps (phase changes, where
//!    the ranking actually reorders).
//! 3. Emit the whole run as versioned JSON
//!    (`diagonal-scale/explain-v1`) — the machine-readable twin the
//!    `simulate --explain-out` flag writes.
//! 4. Cross-check the API contract: the explained trajectory is
//!    bit-identical to the plain `decide` run.

use diagonal_scale::config::ModelConfig;
use diagonal_scale::report;
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::workload::TraceBuilder;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);

    // 1. one run, proposals captured (top-3 of each step's ranking)
    let (run, steps) = sim.run_explained(PolicyKind::Diagonal, &trace, 3);

    // 2. the ranked vocabulary at every step where the choice moved
    println!("ranked candidates on the paper trace (steps that moved):\n");
    let mut shown = 0usize;
    for s in &steps {
        let moved = s
            .candidates
            .first()
            .map(|c| c.to != run.records[s.step].config)
            .unwrap_or(false);
        if !moved && !s.fallback {
            continue;
        }
        shown += 1;
        print!(
            "step {:>3}  demand {:>6.0}  -> ({},{}){}  ",
            s.step,
            s.demand,
            s.chosen.h_idx,
            s.chosen.v_idx,
            if s.fallback { " FALLBACK" } else { "" }
        );
        for (rank, c) in s.candidates.iter().enumerate() {
            print!(
                "{}#{rank} ({},{}) score {:.1} cost {:.2} gain {:.1}{}",
                if rank == 0 { "" } else { "  " },
                c.to.h_idx,
                c.to.v_idx,
                c.score,
                c.cost_to,
                c.gain,
                if c.feasible() { "" } else { " [infeasible]" }
            );
        }
        println!();
    }
    println!("\n({} of {} steps proposed a move)", shown, steps.len());

    // 3. the versioned JSON twin
    let json = report::explain_json(&run.policy, &steps);
    let out = std::path::Path::new("out");
    std::fs::create_dir_all(out)?;
    let path = out.join("proposal_explain.json");
    std::fs::write(&path, &json)?;
    println!(
        "wrote {} ({} bytes, schema {})",
        path.display(),
        json.len(),
        report::EXPLAIN_SCHEMA
    );

    // 4. contract check: explain capture never changes the trajectory
    let plain = sim.run(PolicyKind::Diagonal, &trace);
    assert_eq!(plain.records, run.records, "explain capture changed the run");
    println!(
        "parity: explained trajectory identical to decide() run ({} steps, {} violations)",
        run.summary.steps, run.summary.violations
    );
    Ok(())
}
