//! Cross-tenant bin-packing demo: 12 small tenants co-locate onto
//! shared clusters, with migrations priced as DES-calendar windows.
//!
//! ```text
//! cargo run --release --example placement_packing   # or: make placement-demo
//! ```
//!
//! 1. The pinned scenario — 12 small tenants with constant demands —
//!    A/B: packed placement must strictly lower fleet cost at no more
//!    SLA-violation ticks than one-cluster-per-tenant, with real
//!    migrations (priced windows: degraded ticks observed).
//! 2. The staggered scenario — the paper timeline scaled to 10% and
//!    phase-shifted per tenant — where demand moves and the packer
//!    replans on its cadence; packing must still cost strictly less.

use anyhow::{bail, Result};

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{FleetSimulator, TenantSpec};
use diagonal_scale::placement::{
    constant_tenant_specs, small_tenant_specs, PlacementConfig, PlacementSim,
};

const STEPS: usize = 100;
const FAIRNESS_K: usize = 3;
const BUDGET: f32 = 1.0e9; // uncapped: this demo is about cost, not budget

fn ab(
    cfg: &ModelConfig,
    label: &str,
    specs: impl Fn() -> Vec<TenantSpec>,
) -> Result<(f64, f64, usize, usize, usize)> {
    let pcfg = PlacementConfig::default();
    let mut dedicated = PlacementSim::dedicated(cfg, specs(), BUDGET, FAIRNESS_K, pcfg);
    let ded = dedicated.run(STEPS);
    // the tentpole entry point: a placement-mode fleet
    let mut packed = FleetSimulator::with_placement(cfg, specs(), BUDGET, FAIRNESS_K, pcfg);
    let pk = packed.run(STEPS);

    println!("=== {label} ===");
    println!("dedicated: {}", ded.report.table());
    println!("packed:    {}", pk.report.table());
    println!(
        "{label}: packed cost {:.1} vs dedicated {:.1} ({:.0}%), violations {} vs {}, \
         migrations {}, degraded ticks observed: {}",
        pk.total_cost(),
        ded.total_cost(),
        100.0 * pk.total_cost() / ded.total_cost().max(1e-9),
        pk.total_violations(),
        ded.total_violations(),
        pk.total_migrations(),
        pk.any_degraded_tick(),
    );
    Ok((
        pk.total_cost(),
        ded.total_cost(),
        pk.total_violations(),
        ded.total_violations(),
        pk.total_migrations(),
    ))
}

fn main() -> Result<()> {
    let cfg = ModelConfig::default_paper();

    // 1. pinned constant-demand scenario: the hard acceptance checks
    let (pc, dc, pv, dv, migrations) = ab(&cfg, "12 small tenants, constant demand", || {
        constant_tenant_specs(&cfg, 12)
    })?;
    if pc >= dc {
        bail!("FAIL: packed placement must cost strictly less ({pc:.1} >= {dc:.1})");
    }
    if pv > dv {
        bail!("FAIL: packed placement violated more than dedicated ({pv} > {dv})");
    }
    if migrations == 0 {
        bail!("FAIL: consolidation without migrations — nothing was priced");
    }
    println!(
        "CHECK pinned scenario: packed {pc:.1} < dedicated {dc:.1} at {pv} <= {dv} violations, \
         {migrations} migrations priced\n"
    );

    // 2. staggered scaled paper traces: demand moves, the packer keeps
    //    the fleet packed; cost must still come out strictly lower
    let (pc, dc, pv, dv, _) = ab(&cfg, "12 small tenants, staggered paper traces", || {
        small_tenant_specs(&cfg, 12, 0.1)
    })?;
    if pc >= dc {
        bail!("FAIL: packed placement must cost strictly less ({pc:.1} >= {dc:.1})");
    }
    println!(
        "CHECK staggered scenario: packed {pc:.1} < dedicated {dc:.1} \
         (violations {pv} vs {dv})\n"
    );

    println!("all checks passed: co-location wins cost at equal-or-better SLA outcomes");
    Ok(())
}
