//! End-to-end driver (DESIGN.md E2E): the full system on a real small
//! workload — the Phase-2 discrete-event distributed-database cluster
//! (consistent-hash sharding, quorum writes, rolling restarts,
//! bandwidth-limited rebalances) driven by the autoscaling coordinator
//! through the paper's 50-step trace, with the decision path running
//! through the AOT-compiled Pallas kernels on PJRT when artifacts are
//! available.
//!
//! ```text
//! make artifacts && cargo run --release --example cluster_autoscale
//! ```
//!
//! Reports per-phase measured latency/throughput, compares policies on
//! *measured* (not analytical) metrics, and closes the loop with the
//! paper's §VIII "empirical calibration": fitting the analytical
//! surfaces back from cluster measurements.

use diagonal_scale::calibrate::{Calibrator, Observation};
use diagonal_scale::cluster::{ClusterParams, ClusterSim};
use diagonal_scale::config::{ModelConfig, MoveFlags};
use diagonal_scale::coordinator::{self, native_coordinator, Backend, Coordinator, TickReport};
use diagonal_scale::policy::{DiagonalScale, StaticPolicy, Threshold};
use diagonal_scale::runtime::{Engine, SurfaceEngine};
use diagonal_scale::workload::{TraceBuilder, WorkloadPoint};

fn phase_name(step: usize) -> &'static str {
    match step {
        0..=9 => "low-1",
        10..=19 => "med-1",
        20..=29 => "high",
        30..=39 => "med-2",
        _ => "low-2",
    }
}

fn print_run(label: &str, reports: &[TickReport]) {
    let s = coordinator::summarize(reports);
    println!(
        "{label:<22} violations={:<3} avg_lat={:.4}s p99={:.4}s completed={:>5.1}% moved_shards={:<4} reconfigs={}",
        s.violations,
        s.avg_latency,
        s.avg_p99,
        100.0 * s.completed_ratio,
        s.total_moved_shards,
        s.reconfigurations
    );
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::default_paper();
    let trace = TraceBuilder::paper(&cfg);
    let params = ClusterParams::default();
    let seed = 42;

    println!("== Phase-2 DES cluster + DiagonalScale coordinator ==\n");
    let mut coord = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        params,
        seed,
    );
    let reports = coord.run_trace(&trace)?;

    // per-phase report
    println!(
        "{:<7} {:>8} {:>12} {:>11} {:>10} {:>9} {:>6}",
        "phase", "demand", "completed/s", "avg lat(s)", "p99(s)", "config", "viol"
    );
    for chunk in reports.chunks(10) {
        let n = chunk.len() as f64;
        let avg = |f: &dyn Fn(&TickReport) -> f64| chunk.iter().map(|r| f(r)).sum::<f64>() / n;
        let last = chunk.last().unwrap();
        println!(
            "{:<7} {:>8.0} {:>12.0} {:>11.4} {:>10.4} {:>9} {:>6}",
            phase_name(last.step),
            avg(&|r| r.demand as f64),
            avg(&|r| r.metrics.completed),
            avg(&|r| r.metrics.avg_latency),
            avg(&|r| r.metrics.p99_latency),
            format!("({},{})", last.served_config.h_idx, last.served_config.v_idx),
            chunk.iter().filter(|r| r.violation).count()
        );
    }

    // policy comparison on measured metrics
    println!("\n== policy comparison (measured on the DES cluster) ==\n");
    print_run("DiagonalScale", &reports);
    let mut hz = native_coordinator(&cfg, Box::new(DiagonalScale::horizontal_only()), params, seed);
    print_run("Horizontal-only", &hz.run_trace(&trace)?);
    let mut vt = native_coordinator(&cfg, Box::new(DiagonalScale::vertical_only()), params, seed);
    print_run("Vertical-only", &vt.run_trace(&trace)?);
    let mut th = native_coordinator(&cfg, Box::new(Threshold::default()), params, seed);
    print_run("Threshold (HPA-like)", &th.run_trace(&trace)?);
    let mut st = native_coordinator(&cfg, Box::new(StaticPolicy), params, seed);
    print_run("Static (no scaling)", &st.run_trace(&trace)?);

    // PJRT decision path: the same coordinator with neighbor scoring on
    // the AOT-compiled Pallas kernel
    let artifacts = Engine::default_dir();
    if artifacts.join("manifest.json").exists() {
        let engine = SurfaceEngine::new(Engine::load(&artifacts)?, &cfg)?;
        let cluster = ClusterSim::new(&cfg, params, seed);
        let mut hlo = Coordinator::new(
            &cfg,
            cluster,
            Backend::Hlo { engine, moves: MoveFlags::DIAGONAL },
        );
        print_run("DiagonalScale (PJRT)", &hlo.run_trace(&trace)?);
    }

    // paper §VIII: empirical calibration — benchmark each plane point on
    // the cluster and fit the surfaces from measurements
    println!("\n== online calibration from cluster measurements (paper VIII) ==\n");
    let plane = cfg.plane();
    let mut cal = Calibrator::new(cfg.surfaces);
    for c in plane.iter() {
        let mut cluster = ClusterSim::new(&cfg, params, seed);
        cluster.apply(c);
        for _ in 0..3 {
            cluster.step(WorkloadPoint::new(100.0, cfg.write_ratio()));
        }
        let probe = cluster.capacity() as f32 * 0.3;
        let m = cluster.step(WorkloadPoint::new(probe, cfg.write_ratio()));
        cal.observe(
            &plane,
            Observation { config: c, latency: m.avg_latency, throughput: cluster.capacity() },
        );
    }
    if let Some(lat) = cal.fit_latency() {
        println!(
            "latency fit:     node_scale={:.4}  eta={:.5}  mu={:.5}  theta={:.2}  rmse={:.6}",
            lat.node_scale, lat.eta, lat.mu, lat.theta, lat.rmse
        );
    }
    if let Some(thr) = cal.fit_throughput() {
        println!(
            "throughput fit:  kappa={:.1} (prior {})  omega={:.4} (prior {})  rmse={:.6}",
            thr.kappa, cfg.surfaces.kappa, thr.omega, cfg.surfaces.omega, thr.rmse
        );
        println!(
            "\ninterpretation: the DES cluster's capacity is linear in H (no phi(H)\n\
             penalty on raw capacity), so the fitted omega ~ 0 while kappa matches\n\
             the configured {} — the calibration recovers the substrate's truth\n\
             rather than the analytical prior, exactly what paper VIII wants.",
            cfg.surfaces.kappa
        );
    }
    Ok(())
}
