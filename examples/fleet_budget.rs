//! Fleet under a shared cost budget: 8 tenants, 3 priority classes.
//!
//! ```text
//! cargo run --release --example fleet_budget
//! ```
//!
//! 1. Run the fleet unconstrained to find its natural peak spend.
//! 2. Re-run with a budget at ~65% of that peak: the arbiter's greedy
//!    knapsack + priority classes decide who scales.
//! 3. Verify, tick by tick, that total fleet spend never exceeds the
//!    budget; that Gold tenants keep their p95 (raw) latency within the
//!    SLA bound; and that Bronze absorbs the bulk of the denials.

use anyhow::{bail, Result};

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{self, FleetSimulator, PriorityClass, TenantSpec};
use diagonal_scale::workload::TraceBuilder;

const TENANTS: usize = 8;
const STEPS: usize = 100;
const FAIRNESS_K: usize = 3;

fn specs(cfg: &ModelConfig) -> Vec<TenantSpec> {
    let base = TraceBuilder::paper(cfg);
    // 2 Gold, 3 Silver, 3 Bronze; each tenant's demand is the paper
    // timeline phase-shifted so peaks stagger across the fleet.
    let classes = [
        PriorityClass::Gold,
        PriorityClass::Gold,
        PriorityClass::Silver,
        PriorityClass::Silver,
        PriorityClass::Silver,
        PriorityClass::Bronze,
        PriorityClass::Bronze,
        PriorityClass::Bronze,
    ];
    classes
        .iter()
        .enumerate()
        .map(|(i, &class)| {
            TenantSpec::from_config(
                cfg,
                format!("{}-{i}", class.label()),
                class,
                base.shifted(i * base.len() / TENANTS),
            )
        })
        .collect()
}

fn main() -> Result<()> {
    let cfg = ModelConfig::default_paper();

    // 1. unconstrained baseline: what would the fleet naturally spend?
    let mut free = FleetSimulator::new(&cfg, specs(&cfg), 1.0e9, FAIRNESS_K);
    let free_res = free.run(STEPS);
    let free_peak = free_res.peak_spend();
    println!(
        "unconstrained fleet: peak spend {free_peak:.2}/h, total cost {:.1}, denials {}",
        free_res.report.total_cost, free_res.report.denied_moves
    );

    // 2. the same fleet under a budget at ~65% of the natural peak
    let budget = (free_peak * 0.65 * 10.0).round() / 10.0;
    println!("\nshared budget: {budget:.2}/h  ({TENANTS} tenants, K={FAIRNESS_K})\n");
    let mut fleet = FleetSimulator::new(&cfg, specs(&cfg), budget, FAIRNESS_K);
    let res = fleet.run(STEPS);

    for t in &res.ticks {
        let ok = t.spend <= budget + 1e-3;
        println!(
            "tick {:>3}  spend {:>6.2} / {budget:<6.2} {}  admitted {:>2}  denied {:>2}  rescues {}",
            t.step,
            t.spend,
            if ok { "ok  " } else { "OVER" },
            t.admitted_moves,
            t.denied_moves,
            t.rescues
        );
    }
    println!("\n{}", fleet::report::table(&res.report));

    // 3. the three acceptance checks
    if !res.within_budget(budget) {
        bail!("FAIL: fleet spend exceeded the budget (peak {:.2})", res.peak_spend());
    }
    println!("CHECK spend: every tick within budget (peak {:.2} <= {budget:.2})", res.peak_spend());

    for t in res.report.tenants.iter().filter(|t| t.class == PriorityClass::Gold) {
        if !t.p95_within_sla() {
            bail!(
                "FAIL: gold tenant {} p95 raw latency {:.3} exceeds its SLA bound {:.2}",
                t.name,
                t.p95_latency_raw,
                t.sla_l_max
            );
        }
        println!(
            "CHECK gold SLA: {} p95 raw latency {:.3} <= {:.2}",
            t.name, t.p95_latency_raw, t.sla_l_max
        );
    }

    let denied = |c: PriorityClass| res.report.class(c).map_or(0, |r| r.denied);
    let (gold_d, silver_d, bronze_d) =
        (denied(PriorityClass::Gold), denied(PriorityClass::Silver), denied(PriorityClass::Bronze));
    println!("CHECK denials by class: gold {gold_d}  silver {silver_d}  bronze {bronze_d}");
    if res.report.denied_moves == 0 {
        bail!("FAIL: the budget never bit — no contention was exercised");
    }
    if bronze_d < gold_d {
        bail!("FAIL: bronze ({bronze_d}) should absorb at least as many denials as gold ({gold_d})");
    }
    println!("\nall checks passed: budget respected, gold SLAs held, bronze absorbed contention");
    Ok(())
}
