//! Fleet under a shared cost budget: 8 tenants, 3 priority classes,
//! budget-aware planning (candidate lists + shed re-negotiation +
//! class envelopes + per-tenant forecasting).
//!
//! ```text
//! cargo run --release --example fleet_budget    # or: make fleet-demo
//! ```
//!
//! 1. Run the fleet unconstrained to find its natural peak spend.
//! 2. Re-run with a budget at ~65% of that peak, with planning fully
//!    enabled: Gold/Silver/Bronze envelopes (burst credits on) and
//!    seasonal per-tenant demand forecasting behind the proposals.
//! 3. Verify, tick by tick, that total fleet spend never exceeds the
//!    budget; that Gold tenants keep their p95 (raw) latency within the
//!    SLA bound; that Bronze absorbs the bulk of the denials; and that
//!    planning admission does not violate more than the PR-2
//!    flat-denial arbiter at the same budget.

use anyhow::{bail, Result};

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{
    self, BudgetArbiter, ClassEnvelopes, FleetSimulator, ForecastKind, PriorityClass, TenantSpec,
};
use diagonal_scale::workload::TraceBuilder;

const TENANTS: usize = 8;
const STEPS: usize = 100;
const FAIRNESS_K: usize = 3;

fn specs(cfg: &ModelConfig) -> Vec<TenantSpec> {
    let base = TraceBuilder::paper(cfg);
    // 2 Gold, 3 Silver, 3 Bronze; each tenant's demand is the paper
    // timeline phase-shifted so peaks stagger across the fleet.
    let classes = [
        PriorityClass::Gold,
        PriorityClass::Gold,
        PriorityClass::Silver,
        PriorityClass::Silver,
        PriorityClass::Silver,
        PriorityClass::Bronze,
        PriorityClass::Bronze,
        PriorityClass::Bronze,
    ];
    classes
        .iter()
        .enumerate()
        .map(|(i, &class)| {
            TenantSpec::from_config(
                cfg,
                format!("{}-{i}", class.label()),
                class,
                base.shifted(i * base.len() / TENANTS),
            )
        })
        .collect()
}

/// A fleet with planning fully enabled: envelopes + burst credits and
/// seasonal per-tenant forecasting.
fn planning_fleet(cfg: &ModelConfig, budget: f32) -> FleetSimulator {
    let arb = BudgetArbiter::new(budget, FAIRNESS_K)
        .with_envelopes(ClassEnvelopes::default_split());
    let mut fleet = FleetSimulator::with_arbiter(cfg, specs(cfg), arb);
    fleet.enable_forecasts(ForecastKind::Seasonal, 3);
    fleet
}

fn main() -> Result<()> {
    let cfg = ModelConfig::default_paper();

    // 1. unconstrained baseline: what would the fleet naturally spend?
    let mut free = FleetSimulator::new(&cfg, specs(&cfg), 1.0e9, FAIRNESS_K);
    let free_res = free.run(STEPS);
    let free_peak = free_res.peak_spend();
    println!(
        "unconstrained fleet: peak spend {free_peak:.2}/h, total cost {:.1}, denials {}",
        free_res.report.total_cost, free_res.report.denied_moves
    );

    // 2. the same fleet under a budget at ~65% of the natural peak,
    //    with envelopes + forecasting enabled
    let budget = (free_peak * 0.65 * 10.0).round() / 10.0;
    println!(
        "\nshared budget: {budget:.2}/h  ({TENANTS} tenants, K={FAIRNESS_K}, \
         envelopes gold/silver/bronze = 0.5/0.3/0.2, seasonal forecast)\n"
    );
    let mut fleet = planning_fleet(&cfg, budget);
    let res = fleet.run(STEPS);

    for t in &res.ticks {
        let ok = t.spend <= budget + 1e-3;
        println!(
            "tick {:>3}  spend {:>6.2} / {budget:<6.2} {}  admitted {:>2}  denied {:>2}  rescues {}  degraded {}  sheds {}",
            t.step,
            t.spend,
            if ok { "ok  " } else { "OVER" },
            t.admitted_moves,
            t.denied_moves,
            t.rescues,
            t.degraded_moves,
            t.shed_moves
        );
    }
    println!("\n{}", fleet::report::table(&res.report));

    // 3. the acceptance checks
    if !res.within_budget(budget) {
        bail!("FAIL: fleet spend exceeded the budget (peak {:.2})", res.peak_spend());
    }
    println!("CHECK spend: every tick within budget (peak {:.2} <= {budget:.2})", res.peak_spend());

    for t in res.report.tenants.iter().filter(|t| t.class == PriorityClass::Gold) {
        if !t.p95_within_sla() {
            bail!(
                "FAIL: gold tenant {} p95 raw latency {:.3} exceeds its SLA bound {:.2}",
                t.name,
                t.p95_latency_raw,
                t.sla_l_max
            );
        }
        println!(
            "CHECK gold SLA: {} p95 raw latency {:.3} <= {:.2}",
            t.name, t.p95_latency_raw, t.sla_l_max
        );
    }

    let denied = |c: PriorityClass| res.report.class(c).map_or(0, |r| r.denied);
    let (gold_d, silver_d, bronze_d) =
        (denied(PriorityClass::Gold), denied(PriorityClass::Silver), denied(PriorityClass::Bronze));
    println!("CHECK denials by class: gold {gold_d}  silver {silver_d}  bronze {bronze_d}");
    if res.report.denied_moves == 0 {
        bail!("FAIL: the budget never bit — no contention was exercised");
    }
    if bronze_d < gold_d {
        bail!("FAIL: bronze ({bronze_d}) should absorb at least as many denials as gold ({gold_d})");
    }

    // planning vs the PR-2 flat-denial arbiter at the same budget
    let mut flat =
        FleetSimulator::with_arbiter(&cfg, specs(&cfg), BudgetArbiter::flat(budget, FAIRNESS_K));
    let flat_res = flat.run(STEPS);
    let (pv, fv) = (res.total_violations(), flat_res.total_violations());
    println!(
        "CHECK planning vs flat denial: {pv} violation ticks vs {fv} \
         (sheds actuated: {})",
        res.ticks.iter().map(|t| t.shed_moves).sum::<usize>()
    );
    if pv > fv {
        bail!("FAIL: planning admission violated more than flat denial ({pv} > {fv})");
    }

    println!(
        "\nall checks passed: budget respected, gold SLAs held, bronze absorbed \
         contention, planning beat flat denial"
    );
    Ok(())
}
