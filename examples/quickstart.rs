//! Quickstart: the Scaling Plane in five minutes.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Load the paper's calibrated model configuration.
//! 2. Print the latency/cost surfaces (figures 1–2) as ASCII heatmaps.
//! 3. Ask DIAGONALSCALE for one decision.
//! 4. Run the full Phase-1 simulation and print Table I.
//! 5. If `make artifacts` has run, do the same decision through the
//!    AOT-compiled Pallas kernel on PJRT and show they agree.

use diagonal_scale::config::ModelConfig;
use diagonal_scale::plane::Configuration;
use diagonal_scale::policy::{DiagonalScale, Policy, PolicyContext};
use diagonal_scale::report::{self, Surface};
use diagonal_scale::runtime::{Engine, SurfaceEngine};
use diagonal_scale::simulator::Simulator;
use diagonal_scale::sla::SlaSpec;
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::workload::{TraceBuilder, WorkloadPoint};

fn main() -> anyhow::Result<()> {
    // 1. the model: 4 node counts x 4 vertical tiers = 16 configs
    let cfg = ModelConfig::default_paper();
    let model = SurfaceModel::from_config(&cfg);
    let sla = SlaSpec::from_config(&cfg);
    println!(
        "Scaling Plane: H in {:?} x tiers {:?}  ({} configurations)\n",
        cfg.plane.h_values,
        cfg.plane.tiers.iter().map(|t| t.name.as_str()).collect::<Vec<_>>(),
        model.plane().len()
    );

    // 2. the analytical surfaces (paper figures 1 and 2)
    println!("{}", report::heatmap_ascii(&model, Surface::Cost, 10_000.0));
    println!("{}", report::heatmap_ascii(&model, Surface::Latency, 10_000.0));

    // 3. one SLA-aware decision (Algorithm 1)
    let current = Configuration::new(1, 1); // (H=2, medium)
    let demand = WorkloadPoint::new(10_000.0, cfg.write_ratio());
    let ctx = PolicyContext {
        model: &model,
        sla: &sla,
        reb_h: cfg.policy.reb_h,
        reb_v: cfg.policy.reb_v,
        plan_queue: false,
        future: &[],
        budget: None,
    };
    let d = DiagonalScale::diagonal().decide(current, demand, &ctx);
    println!(
        "decision at (H={}, {}) under lambda_req={}: move to (H={}, {})  score={:.2}  fallback={}\n",
        model.plane().h_value(&current),
        model.plane().tier(&current).name,
        demand.lambda_req,
        model.plane().h_value(&d.next),
        model.plane().tier(&d.next).name,
        d.score,
        d.fallback
    );

    // 4. the paper's headline experiment (Table I)
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);
    let runs = sim.run_paper_set(&trace);
    let rows: Vec<_> = runs.iter().map(|r| (r.policy.clone(), r.summary)).collect();
    println!("{}", report::table1(&rows));

    // 5. the same surfaces through the AOT Pallas kernel on PJRT
    let artifacts = Engine::default_dir();
    if artifacts.join("manifest.json").exists() {
        let eng = SurfaceEngine::new(Engine::load(&artifacts)?, &cfg)?;
        let grids = eng.surfaces(demand.lambda_req)?;
        let native = model.evaluate(&d.next, demand.lambda_req);
        let hlo = diagonal_scale::runtime::grid_at(&grids.latency, d.next.h_idx, d.next.v_idx);
        println!(
            "PJRT cross-check at the chosen config: native latency {:.4} vs HLO {:.4}  (platform: {})",
            native.latency,
            hlo,
            eng.engine().platform_name()
        );
    } else {
        println!("(run `make artifacts` to enable the PJRT cross-check)");
    }
    Ok(())
}
