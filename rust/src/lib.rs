//! # diagonal-scale
//!
//! A production-shaped reproduction of *"Diagonal Scaling: A
//! Multi-Dimensional Resource Model and Optimization Framework for
//! Distributed Databases"* (CS.DC 2025).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer
//! rust + JAX + Pallas stack:
//!
//! * [`plane`] — the Scaling Plane: configurations `(H, V)` over node
//!   counts and vertical resource tiers (paper §III.A).
//! * [`surfaces`] — the five analytical surfaces (latency, throughput,
//!   cost, coordination cost, objective) in native rust (paper §III.B–F),
//!   plus the §VIII utilization-sensitive queueing extension.
//! * [`sla`] — SLA feasibility and violation accounting (paper §IV.C).
//! * [`policy`] — the **proposal-first** decision vocabulary:
//!   [`policy::Policy::propose`] returns a ranked [`policy::Proposal`]
//!   (every scored candidate, best first; `decide` is derived as its
//!   top entry). [`policy::DiagonalScale`] (Algorithm 1) plus the
//!   horizontal-only / vertical-only / threshold / oracle / lookahead /
//!   forecast-lookahead baselines and extensions all speak it natively.
//! * [`workload`] — the paper's 50-step trace plus synthetic families.
//! * [`simulator`] — the Phase-1 analytical simulator (paper §V), plus
//!   [`simulator::AnalyticalSubstrate`], the analytical surfaces behind
//!   the [`cluster::Substrate`] trait.
//! * [`cluster`] — the Phase-2 distributed-database substrate
//!   (sharding, replication, rebalance, queueing) standing in for the
//!   real deployments the paper defers to future work (§VII). Two
//!   engines implement the [`cluster::Substrate`] trait: the legacy
//!   per-op sampling [`cluster::ClusterSim`] and the event-driven
//!   [`cluster::EventSim`] (binary-heap event calendar, allocation-free
//!   hot path, no arrival thinning).
//! * [`coordinator`] — the autoscaler control loop that drives any
//!   [`cluster::Substrate`] with any policy: walks the ranked proposal
//!   when a [`coordinator::MoveGuard`] vetoes the first choice
//!   (degradation-aware stepping) and can refit the planning surfaces
//!   online from `observe()` snapshots
//!   ([`coordinator::Coordinator::enable_online_calibration`]).
//! * [`fleet`] — multi-tenant fleet control: N tenant clusters (each a
//!   full plane/SLA/policy/trace stack, optionally backed by any
//!   substrate engine — mixable within one run, each audited against
//!   its *own* SLA) scaling concurrently under a shared monetary
//!   budget. Admission is a two-sided negotiation: tenants shape
//!   ranked candidate lists to a per-tick budget hint (optionally with
//!   forecast-driven lookahead per tenant), and the budget arbiter
//!   walks the lists — degrading first choices to cheaper feasible
//!   alternatives, actuating volunteered sheds to fund SLA repairs,
//!   and confining discretionary spending to Gold/Silver/Bronze
//!   envelopes with burst credits (optionally re-weighted each tick
//!   from observed per-class contention,
//!   [`fleet::EnvelopeAdapter`]) — on top of priority classes and
//!   the starvation guard.
//! * [`placement`] — cross-tenant bin-packing onto shared clusters:
//!   [`placement::SharedCluster`] splits one host's capacity by
//!   weighted fair shares with a contention penalty past a utilization
//!   knee, [`placement::Packer`] runs FFD seeding + local search over
//!   {migrate, merge, split, resize} under per-tenant SLAs, and
//!   [`placement::MigrationPlanner`] prices each tenant move as a
//!   degradation window on the cluster's DES calendar. Placement
//!   actions are admitted by the fleet's budget arbiter
//!   ([`fleet::FleetSimulator::with_placement`]); the pinned tests
//!   show packing strictly lowering fleet cost at no more
//!   SLA-violation ticks than dedicated clusters.
//! * [`serverless`] — the serverless tier (paper §VIII's "serverless
//!   and disaggregated architectures"): a shared
//!   [`serverless::StorageService`] detaches storage cost from compute,
//!   tenants gain the `Active → Draining → Suspended → Resuming`
//!   scale-to-zero lifecycle, and wakes are priced *cold-start windows*
//!   on the fleet's DES calendar. Suspends ride the proposal pipeline
//!   as pass-0 shrinks; wakes are class-ordered emergency repairs. The
//!   pinned scenarios show a 64-tenant mostly-idle fleet cutting cost
//!   strictly below always-on packing, and a correlated wake storm
//!   resolving without starving Gold tenants.
//! * [`scenario`] — the deterministic scenario subsystem, the single
//!   source of workloads and fault schedules for fleet, placement, and
//!   serverless runs: composable trace generators (diurnal+weekly
//!   composites, flash crowds with a realized cross-tenant correlation
//!   coefficient, heavy-tailed Pareto tenant sizes), the
//!   hypergraph-flavored [`scenario::ShardModel`] that turns flat
//!   per-tenant migration GB into which-shards-actually-move pricing
//!   (default off; [`placement::PlacementSim::set_shard_model`] opts
//!   in), fault-schedule generators (zone outages, failure storms,
//!   rolling restarts) layered onto the fleet DES calendars, and the
//!   named presets behind `fleet --scenario <name>` /
//!   `placement --scenario <name>` — each preset ships with a pinned
//!   comparison test in `tests/prop_scenario.rs`.
//! * [`runtime`] — the PJRT bridge: loads the AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes the
//!   Pallas-backed surface kernels on the decision path.
//! * [`calibrate`] — online surface calibration from observations
//!   (paper §VIII).
//! * [`metrics`] / [`report`] — time-series recording, the Table I /
//!   Figure 1–8 emitters, and the sublinear observability layer:
//!   [`metrics::StreamingRecorder`] replaces the exact
//!   [`metrics::Recorder`] with O(1)-memory summary accumulators,
//!   latency sketches, and a seeded Algorithm-R exemplar reservoir
//!   (the exact recorder stays as the oracle it is property-pinned
//!   against); [`metrics::hll`] is a dependency-free HyperLogLog for
//!   distinct-active-tenants / configurations-visited / hosts-touched
//!   counting; and [`metrics::registry`] is the pull-based export
//!   surface every subsystem registers into, rendered as Prometheus
//!   text (`fleet --metrics-out`) or versioned
//!   `diagonal-scale/metrics-v1` JSON (`fleet --metrics-json`) with
//!   the metric name set pinned in `config/metrics_v1.names`.
//!
//! Python never runs at request time: `make artifacts` lowers the
//! JAX/Pallas model once, and this crate is self-contained afterwards.
//!
//! ## Invariants (machine-checked by `simlint`)
//!
//! Every pinned result in this repo — dirty-queue decision identity,
//! bitwise spend equality, packed-vs-dedicated cost ratios — rests on
//! invariants that `rust/tools/simlint` (run by `make lint-sim` and CI
//! before the build) enforces on every push:
//!
//! * **No wall clock in decision code** (`d1-no-wall-clock`): a
//!   decision that reads `Instant::now` cannot be replayed. Time is
//!   injected through [`fleet::FleetSimulator::set_planning_clock`];
//!   the deterministic default is a constant zero, and
//!   [`fleet::FleetSimulator::use_wall_clock`] is the one sanctioned
//!   opt-in (telemetry only). [`benchkit`] is allowlisted — measuring
//!   wall time is its job.
//! * **No unordered iteration** (`d2-no-unordered-iteration`):
//!   `HashMap`/`HashSet` iteration order varies per process, so
//!   decision code uses `BTreeMap`/`BTreeSet`/indexed `Vec`s. The
//!   [`runtime`] PJRT stub is allowlisted (keyed lookups only).
//! * **Total float order** (`d3-total-order-floats`): float sort and
//!   heap keys go through `total_cmp`; hand-rolled `PartialOrd` impls
//!   must delegate to a total `Ord`.
//! * **Money accumulates in f64** (`n1-money-in-f64`): PR 7's mirror
//!   caught a real f32 spend-drift bug. Reporting structs still carry
//!   f32, narrowed exactly once at [`util::money::narrow`].
//! * **`diagonal-scale/explain-v1` is additive-only**
//!   (`s1-explain-additivity`): the emitted JSON key set is pinned in
//!   `config/explain_v1.keys` (runtime complement:
//!   `rust/tests/explain_schema.rs`).
//! * **`metrics-v1` names are additive-only** (`s2-metrics-additivity`):
//!   the metric families declared in `rust/src/metrics/names.rs` must
//!   reconcile exactly with `config/metrics_v1.names`, so renaming or
//!   dropping a metric breaks the lint, not a dashboard (runtime
//!   complement: `rust/tests/metrics_export.rs`).
//! * **Every test/bench is registered** (`t1-registration`):
//!   auto-discovery is off (custom paths), so `Cargo.toml` must
//!   reconcile with `rust/tests`/`rust/benches` or a dropped file
//!   silently never runs.
//!
//! See `CONTRIBUTING.md` for rule details and the inline
//! justification-required escape hatch (budgeted tree-wide).

pub mod benchkit;
pub mod calibrate;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod disagg;
pub mod fleet;
pub mod forecast;
pub mod metrics;
pub mod placement;
pub mod plane;
pub mod policy;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod serverless;
pub mod simulator;
pub mod sla;
pub mod surfaces;
pub mod testkit;
pub mod util;
pub mod workload;

pub use cluster::{ClusterSim, EventSim, Substrate, SubstrateKind};
pub use config::ModelConfig;
pub use plane::{Configuration, ScalingPlane, Tier};
pub use policy::{Candidate, Decision, Policy, Proposal};
pub use simulator::{AnalyticalSubstrate, PolicyKind, Simulator};
pub use surfaces::SurfaceModel;

/// Score assigned to SLA-infeasible candidates (shared with the python
/// kernels; see `python/compile/defaults.py::INFEASIBLE`).
pub const INFEASIBLE: f32 = 1.0e30;

/// Padded grid edge shared with the kernels (`defaults.GRID`).
pub const GRID: usize = 8;

/// Packed parameter-vector length shared with the kernels.
pub const PARAMS_LEN: usize = 32;

/// Per-step record length emitted by the `policy_trace` artifacts.
pub const REC_LEN: usize = 8;
