//! Serverless tier: storage–compute disaggregation with scale-to-zero
//! and priced cold starts (paper §VIII's "serverless and disaggregated
//! architectures" extension of the Scaling Plane).
//!
//! The always-on model charges every tenant its cheapest `(H, V)`
//! configuration forever, even at zero demand. This module detaches
//! storage from compute: a shared [`StorageService`] holds each
//! tenant's pages durably at a per-GB-hour price *independent of
//! compute*, so compute can scale to zero while the data — and its
//! cost — survive. Contrast with [`crate::disagg`], where the storage
//! axis is still bundled per *node* (its cost scales with `H`); here
//! storage is priced per tenant working set and is the floor cost that
//! remains at `H = 0`.
//!
//! Tenants gain a lifecycle:
//!
//! ```text
//! Active → Draining → Suspended → Resuming → Active
//! ```
//!
//! * **Suspend** is an ordinary policy candidate: an idle,
//!   non-violating tenant proposes a move to its *own* configuration at
//!   storage-only cost. Admission proposals treat any non-empty
//!   candidate list as a move, so the PR-5 proposal pipeline and the
//!   [`crate::fleet::BudgetArbiter`] apply unchanged — the cost
//!   decrease is admitted in pass 0 as a shrink, with the claimed
//!   savings in the candidate's `gain`.
//! * **Draining** is one visible tick at storage-only cost while
//!   compute flushes and tears down; the projected-spend invariant
//!   (admitted cost takes effect exactly next tick) is preserved.
//! * **Suspended** accrues *only* storage cost. Demand above the idle
//!   threshold is a throughput violation (nothing serves) and triggers
//!   a wake; a trickle at or below the threshold is treated as noise.
//! * **Resume** is an emergency repair proposal priced at full compute
//!   plus storage, funded in the arbiter's class-ordered repair pass
//!   (Gold wakes first). An admitted wake opens a *cold-start window*
//!   on the fleet's DES calendar — an
//!   [`Event::ResumeEnd`](crate::cluster::events::Event) whose duration
//!   is the working-set GB over the storage read bandwidth — during
//!   which requests queue and violate the SLA, exactly like the PR-4
//!   migration windows.
//!
//! Idle detection combines an observed idle streak with a one-step
//! [`Holt`](crate::forecast::Holt) forecast, so a tenant whose demand
//! is about to return does not flap into suspension.
//!
//! [`mostly_idle_specs`] and [`wake_storm_specs`] build the two pinned
//! scenarios: a 64-tenant mostly-idle fleet where serverless mode cuts
//! cost strictly below always-on packing at bounded extra violation
//! ticks, and a correlated burst that wakes a suspended cohort at once
//! without starving Gold tenants. [`sparse_activity_specs`] builds the
//! scale scenario — a fixed active/bursty cohort in an arbitrarily
//! large sea of permanently idle tenants — behind the 10k-tenant
//! dirty-queue bench.

use crate::forecast::{Forecaster, Holt};

/// Pricing and timing constants of the serverless tier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerlessParams {
    /// Durable page storage price per GB-hour — the cost that survives
    /// compute scale-to-zero. Well below the cheapest compute step
    /// (0.08/h) so suspension is worth proposing.
    pub storage_price_gb_hour: f32,
    /// Storage read bandwidth in GB per tick: a cold start lasts
    /// `ceil(working_set_gb / read_bw_gb_per_tick)` ticks (min 1).
    pub read_bw_gb_per_tick: f32,
    /// Demand at or below this rate counts as idle; above it wakes a
    /// suspended tenant.
    pub idle_lambda: f32,
    /// Consecutive idle ticks before suspension becomes a candidate.
    pub idle_ticks: usize,
    /// Working-set floor every tenant stores regardless of demand.
    pub base_gb: f32,
    /// Working-set growth per 1000 req/tick of average demand.
    pub gb_per_kilo_lambda: f32,
}

impl Default for ServerlessParams {
    fn default() -> Self {
        Self {
            storage_price_gb_hour: 0.004,
            read_bw_gb_per_tick: 4.0,
            idle_lambda: 1.0,
            idle_ticks: 3,
            base_gb: 2.0,
            gb_per_kilo_lambda: 1.0,
        }
    }
}

impl ServerlessParams {
    /// Working-set size for a tenant with the given average demand.
    pub fn working_set_gb(&self, avg_lambda: f32) -> f32 {
        self.base_gb + self.gb_per_kilo_lambda * avg_lambda.max(0.0) / 1000.0
    }

    /// Hourly storage cost of a `gb`-sized working set.
    pub fn storage_cost(&self, gb: f32) -> f32 {
        gb * self.storage_price_gb_hour
    }

    /// Cold-start window length in ticks: reading the working set back
    /// from the storage tier at its read bandwidth, never instant.
    pub fn cold_start_ticks(&self, gb: f32) -> usize {
        ((gb / self.read_bw_gb_per_tick).ceil() as usize).max(1)
    }
}

/// Scale-to-zero lifecycle of a serverless tenant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// Compute deployed and serving (pays compute + storage).
    Active,
    /// Suspension admitted: one tick at storage-only cost while compute
    /// flushes and tears down; becomes [`Lifecycle::Suspended`] after
    /// serving it.
    Draining,
    /// Compute released; only the storage tier holds the tenant.
    Suspended,
    /// A wake was admitted: compute is re-provisioned and paid for, but
    /// nothing serves until the cold-start window closes at tick
    /// `until` (the fleet calendar's `ResumeEnd`).
    Resuming { until: usize },
}

impl Lifecycle {
    pub fn label(&self) -> &'static str {
        match self {
            Lifecycle::Active => "active",
            Lifecycle::Draining => "draining",
            Lifecycle::Suspended => "suspended",
            Lifecycle::Resuming { .. } => "resuming",
        }
    }
}

/// Per-tenant serverless state: lifecycle, storage terms, idle
/// detection, and lifetime counters. Owned by
/// [`crate::fleet::Tenant`]; built by
/// [`crate::fleet::FleetSimulator::enable_serverless`] via the shared
/// [`StorageService`].
pub struct ServerlessState {
    pub params: ServerlessParams,
    pub working_set_gb: f32,
    pub lifecycle: Lifecycle,
    /// Completed suspensions (Active → Draining transitions).
    pub suspends: usize,
    /// Admitted wakes (Suspended → Resuming transitions).
    pub resumes: usize,
    /// Ticks served from storage only (draining + suspended).
    pub suspended_ticks: usize,
    /// Ticks spent inside cold-start windows.
    pub cold_start_ticks_total: usize,
    /// Set when a suspend candidate was proposed this tick; `apply`
    /// turns the admitted no-op move into the Draining transition.
    pub(crate) pending_suspend: bool,
    idle_streak: usize,
    forecast: Holt,
}

impl ServerlessState {
    pub fn new(params: ServerlessParams, working_set_gb: f32) -> Self {
        Self {
            params,
            working_set_gb,
            lifecycle: Lifecycle::Active,
            suspends: 0,
            resumes: 0,
            suspended_ticks: 0,
            cold_start_ticks_total: 0,
            pending_suspend: false,
            idle_streak: 0,
            forecast: Holt::default_tuned(),
        }
    }

    /// Hourly cost of this tenant's pages in the storage tier.
    pub fn storage_cost(&self) -> f32 {
        self.params.storage_cost(self.working_set_gb)
    }

    /// Cold-start ticks a wake of this tenant takes.
    pub fn cold_start_ticks(&self) -> usize {
        self.params.cold_start_ticks(self.working_set_gb)
    }

    /// Fold one tick's observed demand into the idle detector.
    pub fn observe_demand(&mut self, lambda: f32) {
        self.forecast.observe(lambda as f64);
        if lambda <= self.params.idle_lambda {
            self.idle_streak += 1;
        } else {
            self.idle_streak = 0;
        }
    }

    /// Whether suspension is justified: the observed idle streak is
    /// long enough *and* the one-step forecast predicts idleness too.
    pub fn idle_enough(&self) -> bool {
        self.idle_streak >= self.params.idle_ticks
            && self.forecast.forecast(1) <= self.params.idle_lambda as f64
    }

    /// Reset the idle streak (after a wake, so a tenant does not
    /// re-suspend mid-burst).
    pub(crate) fn reset_idle(&mut self) {
        self.idle_streak = 0;
    }
}

/// The shared durable storage tier: every tenant's pages at a
/// per-GB-hour price independent of compute. One instance per fleet;
/// tenants register at [`crate::fleet::FleetSimulator::enable_serverless`]
/// time and keep a copy of their terms in [`ServerlessState`].
#[derive(Debug, Clone)]
pub struct StorageService {
    params: ServerlessParams,
    /// Stored working set per tenant id (0.0 = not registered).
    stored_gb: Vec<f32>,
}

impl StorageService {
    pub fn new(params: ServerlessParams) -> Self {
        Self { params, stored_gb: Vec::new() }
    }

    pub fn params(&self) -> &ServerlessParams {
        &self.params
    }

    /// Register tenant `id` with a `gb`-sized working set; returns the
    /// registered size.
    pub fn register(&mut self, id: usize, gb: f32) -> f32 {
        assert!(gb > 0.0, "working set must be positive");
        if id >= self.stored_gb.len() {
            self.stored_gb.resize(id + 1, 0.0);
        }
        self.stored_gb[id] = gb;
        gb
    }

    pub fn stored_gb(&self, id: usize) -> f32 {
        self.stored_gb.get(id).copied().unwrap_or(0.0)
    }

    pub fn total_gb(&self) -> f32 {
        self.stored_gb.iter().sum()
    }

    /// Fleet-wide hourly storage cost — the floor that survives every
    /// tenant scaling its compute to zero.
    pub fn total_storage_cost(&self) -> f32 {
        self.params.storage_cost(self.total_gb())
    }

    /// Cold-start ticks a wake of tenant `id` takes.
    pub fn cold_start_ticks(&self, id: usize) -> usize {
        self.params.cold_start_ticks(self.stored_gb(id))
    }

    /// Tenants with a registered (positive) working set.
    pub fn registered_tenants(&self) -> usize {
        self.stored_gb.iter().filter(|&&gb| gb > 0.0).count()
    }

    /// Register the storage tier's gauges into the pull-based export
    /// registry (`fleet --metrics-out`).
    pub fn export_metrics(&self, reg: &mut crate::metrics::MetricsRegistry) {
        use crate::metrics::names;
        reg.set(names::SERVERLESS_STORAGE_GB, &[], self.total_gb() as f64);
        reg.set(names::SERVERLESS_STORAGE_COST_HOURLY, &[], self.total_storage_cost() as f64);
        reg.set(names::SERVERLESS_REGISTERED_TENANTS, &[], self.registered_tenants() as f64);
    }
}

// The fleet-shape builders moved into the scenario subsystem, where
// all scenario construction now lives; re-exported here so existing
// call sites (tests, benches, CLI) are unchanged.
pub use crate::scenario::{mostly_idle_specs, sparse_activity_specs, wake_storm_specs};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_start_scales_with_working_set_over_bandwidth() {
        let p = ServerlessParams::default();
        assert_eq!(p.cold_start_ticks(2.0), 1);
        assert_eq!(p.cold_start_ticks(4.0), 1);
        assert_eq!(p.cold_start_ticks(4.1), 2);
        assert_eq!(p.cold_start_ticks(16.0), 4);
        // never instant, even for a tiny working set
        assert_eq!(p.cold_start_ticks(0.01), 1);
    }

    #[test]
    fn working_set_grows_with_demand() {
        let p = ServerlessParams::default();
        assert!((p.working_set_gb(0.0) - p.base_gb).abs() < 1e-6);
        assert!((p.working_set_gb(9600.0) - (p.base_gb + 9.6)).abs() < 1e-4);
        // negative demand never shrinks below the floor
        assert!((p.working_set_gb(-5.0) - p.base_gb).abs() < 1e-6);
    }

    #[test]
    fn storage_price_is_below_cheapest_compute_step() {
        // the whole point of suspension: storage-only cost for a small
        // working set undercuts even one small-tier node (0.08/h)
        let p = ServerlessParams::default();
        assert!(p.storage_cost(p.working_set_gb(0.0)) < 0.08);
    }

    #[test]
    fn storage_service_registers_and_totals() {
        let mut s = StorageService::new(ServerlessParams::default());
        s.register(0, 2.0);
        s.register(2, 6.0);
        assert_eq!(s.stored_gb(0), 2.0);
        assert_eq!(s.stored_gb(1), 0.0);
        assert_eq!(s.stored_gb(2), 6.0);
        assert!((s.total_gb() - 8.0).abs() < 1e-6);
        assert!((s.total_storage_cost() - s.params().storage_cost(8.0)).abs() < 1e-6);
        assert_eq!(s.cold_start_ticks(2), 2);
    }

    #[test]
    fn idle_detection_needs_streak_and_forecast() {
        let mut st = ServerlessState::new(ServerlessParams::default(), 2.0);
        assert!(!st.idle_enough());
        for _ in 0..3 {
            st.observe_demand(0.0);
        }
        assert!(st.idle_enough());
        // one busy tick resets the streak and lifts the forecast
        st.observe_demand(5000.0);
        assert!(!st.idle_enough());
        st.observe_demand(0.0);
        assert!(!st.idle_enough(), "streak must rebuild after a burst");
    }

    #[test]
    fn lifecycle_labels() {
        assert_eq!(Lifecycle::Active.label(), "active");
        assert_eq!(Lifecycle::Draining.label(), "draining");
        assert_eq!(Lifecycle::Suspended.label(), "suspended");
        assert_eq!(Lifecycle::Resuming { until: 7 }.label(), "resuming");
    }

}
