//! Native implementation of the five analytical surfaces (paper §III)
//! and the §VIII queueing extension.
//!
//! This is the rust twin of the Pallas kernels in
//! `python/compile/kernels/`; the integration tests assert the two agree
//! to float tolerance on every grid cell (native vs HLO-executed).
//! All math is `f32` and uses `exp(theta * ln H)` for the power term,
//! exactly like the kernels, so the trajectories match bit-for-bit in
//! structure.

pub mod queueing;

use crate::config::{ModelConfig, SurfaceConfig};
use crate::plane::{Configuration, ScalingPlane, Tier};
use crate::sla::SlaSpec;

/// Point evaluation of every surface at one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SurfacePoint {
    /// L(H,V): node-intrinsic + coordination latency (paper III.C).
    pub latency: f32,
    /// T(H,V): aggregate throughput with diminishing returns (III.D).
    pub throughput: f32,
    /// C(H,V) = H * C_node(V): cluster cost (III.B).
    pub cost: f32,
    /// K(H,V): coordination cost under write pressure (III.E).
    pub coordination: f32,
    /// F(H,V) = alpha*L + beta*C + gamma*K - delta*T (III.F).
    pub objective: f32,
}

/// The analytical surface model over a [`ScalingPlane`].
#[derive(Debug, Clone)]
pub struct SurfaceModel {
    plane: ScalingPlane,
    consts: SurfaceConfig,
    write_ratio: f32,
    // §Perf: the plane is tiny and fixed, so every per-axis term is
    // precomputed at construction — the per-decision hot path does no
    // ln/exp/pow at all.
    l_node_cache: Vec<f32>,
    t_node_cache: Vec<f32>,
    l_coord_cache: Vec<f32>,
    phi_cache: Vec<f32>,
}

impl SurfaceModel {
    pub fn new(plane: ScalingPlane, consts: SurfaceConfig, write_ratio: f32) -> Self {
        let mut m = Self {
            plane,
            consts,
            write_ratio,
            l_node_cache: Vec::new(),
            t_node_cache: Vec::new(),
            l_coord_cache: Vec::new(),
            phi_cache: Vec::new(),
        };
        m.l_node_cache = m.plane.tiers().iter().map(|t| m.node_latency(t)).collect();
        m.t_node_cache = m
            .plane
            .tiers()
            .iter()
            .map(|t| m.node_throughput(t))
            .collect();
        m.l_coord_cache = m
            .plane
            .h_values()
            .iter()
            .map(|&h| m.coord_latency(h))
            .collect();
        m.phi_cache = m
            .plane
            .h_values()
            .iter()
            .map(|&h| m.horiz_efficiency(h))
            .collect();
        m
    }

    pub fn from_config(cfg: &ModelConfig) -> Self {
        Self::new(cfg.plane(), cfg.surfaces, cfg.write_ratio())
    }

    pub fn plane(&self) -> &ScalingPlane {
        &self.plane
    }

    pub fn constants(&self) -> &SurfaceConfig {
        &self.consts
    }

    /// L_node(V) = a/cpu + b/ram + c/bw + d/(iops/1000)   (paper III.C).
    pub fn node_latency(&self, tier: &Tier) -> f32 {
        let s = &self.consts;
        s.a / tier.cpu + s.b / tier.ram + s.c / tier.bandwidth + s.d / tier.iops_k()
    }

    /// L_coord(H) = eta ln H + mu H^theta   (paper III.C).
    pub fn coord_latency(&self, h: u32) -> f32 {
        let s = &self.consts;
        let log_h = (h as f32).ln();
        s.eta * log_h + s.mu * (s.theta * log_h).exp()
    }

    /// T_node(V) = kappa * min(cpu, ram, bw, iops/1000)   (paper III.D).
    pub fn node_throughput(&self, tier: &Tier) -> f32 {
        self.consts.kappa * tier.min_resource()
    }

    /// phi(H) = 1 / (1 + omega ln H)   (paper III.D).
    pub fn horiz_efficiency(&self, h: u32) -> f32 {
        1.0 / (1.0 + self.consts.omega * (h as f32).ln())
    }

    /// Latency surface L(H,V).
    #[inline]
    pub fn latency(&self, cfg: &Configuration) -> f32 {
        self.l_node_cache[cfg.v_idx] + self.l_coord_cache[cfg.h_idx]
    }

    /// Throughput surface T(H,V).
    #[inline]
    pub fn throughput(&self, cfg: &Configuration) -> f32 {
        self.plane.h_value(cfg) as f32 * self.t_node_cache[cfg.v_idx] * self.phi_cache[cfg.h_idx]
    }

    /// Cost surface C(H,V).
    pub fn cost(&self, cfg: &Configuration) -> f32 {
        self.plane.h_value(cfg) as f32 * self.plane.tier(cfg).cost
    }

    /// Coordination-cost surface K(H,V) for a write arrival rate.
    #[inline]
    pub fn coordination(&self, cfg: &Configuration, lambda_w: f32) -> f32 {
        self.consts.rho * self.l_coord_cache[cfg.h_idx] * lambda_w / self.throughput(cfg)
    }

    /// Objective surface F(H,V) for a workload (paper III.F).
    pub fn objective(&self, cfg: &Configuration, lambda_w: f32) -> f32 {
        let s = &self.consts;
        s.alpha * self.latency(cfg) + s.beta * self.cost(cfg)
            + s.gamma * self.coordination(cfg, lambda_w)
            - s.delta * self.throughput(cfg)
    }

    /// Every surface at one configuration for a required throughput
    /// `lambda_req` (write rate derived via the configured write ratio).
    #[inline]
    pub fn evaluate(&self, cfg: &Configuration, lambda_req: f32) -> SurfacePoint {
        let lambda_w = lambda_req * self.write_ratio;
        let latency = self.latency(cfg);
        let throughput = self.throughput(cfg);
        let cost = self.cost(cfg);
        let coordination =
            self.consts.rho * self.l_coord_cache[cfg.h_idx] * lambda_w / throughput;
        let s = &self.consts;
        let objective = s.alpha * latency + s.beta * cost + s.gamma * coordination
            - s.delta * throughput;
        SurfacePoint { latency, throughput, cost, coordination, objective }
    }

    /// Evaluate the whole plane in row-major order (the heatmap figures).
    pub fn evaluate_grid(&self, lambda_req: f32) -> Vec<(Configuration, SurfacePoint)> {
        self.plane
            .iter()
            .map(|c| (c, self.evaluate(&c, lambda_req)))
            .collect()
    }

    /// Measured (utilization-corrected) latency at a configuration
    /// (paper VIII): `L / (1 - min(lambda_req / T, u_max))`.
    pub fn effective_latency(&self, cfg: &Configuration, lambda_req: f32) -> f32 {
        queueing::effective_latency(
            self.latency(cfg),
            self.throughput(cfg),
            lambda_req,
            self.consts.u_max,
        )
    }

    /// Objective with the measured latency substituted for the raw one —
    /// what the simulator reports per served step.
    pub fn effective_objective(&self, cfg: &Configuration, lambda_req: f32) -> f32 {
        let s = &self.consts;
        let p = self.evaluate(cfg, lambda_req);
        let l_eff = queueing::effective_latency(p.latency, p.throughput, lambda_req, s.u_max);
        s.alpha * l_eff + s.beta * p.cost + s.gamma * p.coordination - s.delta * p.throughput
    }

    /// SLA feasibility of a configuration (paper IV.C), optionally using
    /// the queueing-corrected latency (the §VIII planner extension).
    pub fn feasible(
        &self,
        cfg: &Configuration,
        lambda_req: f32,
        sla: &SlaSpec,
        plan_queue: bool,
    ) -> bool {
        let lat = if plan_queue {
            self.effective_latency(cfg, lambda_req)
        } else {
            self.latency(cfg)
        };
        lat <= sla.l_max && self.throughput(cfg) >= lambda_req * sla.b_sla
    }

    /// The global optimum over the *whole* plane for one workload point
    /// (the oracle policy / objective-heatmap minimum). Returns `None`
    /// if nothing is feasible.
    pub fn best_feasible(
        &self,
        lambda_req: f32,
        sla: &SlaSpec,
        plan_queue: bool,
    ) -> Option<(Configuration, SurfacePoint)> {
        let mut best: Option<(Configuration, SurfacePoint)> = None;
        for c in self.plane.iter() {
            if !self.feasible(&c, lambda_req, sla, plan_queue) {
                continue;
            }
            let p = self.evaluate(&c, lambda_req);
            let score = if plan_queue {
                self.effective_objective(&c, lambda_req)
            } else {
                p.objective
            };
            let better = match &best {
                None => true,
                Some((bc, _)) => {
                    let bs = if plan_queue {
                        self.effective_objective(bc, lambda_req)
                    } else {
                        self.evaluate(bc, lambda_req).objective
                    };
                    score < bs
                }
            };
            if better {
                best = Some((c, p));
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SurfaceModel {
        SurfaceModel::from_config(&ModelConfig::default_paper())
    }

    #[test]
    fn cost_monotone_in_both_axes_fig1() {
        let m = model();
        for i in 0..3 {
            for j in 0..3 {
                let c = m.cost(&Configuration::new(i, j));
                assert!(m.cost(&Configuration::new(i + 1, j)) > c);
                assert!(m.cost(&Configuration::new(i, j + 1)) > c);
            }
        }
    }

    #[test]
    fn latency_tradeoff_fig2() {
        let m = model();
        for i in 0..4 {
            for j in 0..3 {
                // better tier -> lower latency
                assert!(
                    m.latency(&Configuration::new(i, j + 1))
                        < m.latency(&Configuration::new(i, j))
                );
            }
        }
        for j in 0..4 {
            for i in 0..3 {
                // more nodes -> higher latency (coordination)
                assert!(
                    m.latency(&Configuration::new(i + 1, j))
                        > m.latency(&Configuration::new(i, j))
                );
            }
        }
    }

    #[test]
    fn single_node_has_no_log_coordination() {
        let m = model();
        assert!((m.coord_latency(1) - m.constants().mu).abs() < 1e-6);
    }

    #[test]
    fn throughput_diminishing_returns() {
        let m = model();
        for j in 0..4 {
            for i in 0..3 {
                let lo = m.throughput(&Configuration::new(i, j));
                let hi = m.throughput(&Configuration::new(i + 1, j));
                assert!(hi > lo, "more nodes should add throughput");
                assert!(hi < 2.0 * lo, "but sublinearly (phi < 1)");
            }
        }
    }

    #[test]
    fn effective_latency_inflates_under_load() {
        let m = model();
        let c = Configuration::new(1, 1);
        let raw = m.latency(&c);
        assert!(m.effective_latency(&c, 1.0) >= raw);
        assert!(m.effective_latency(&c, 1e9) > m.effective_latency(&c, 1.0));
        // clamped: never infinite
        assert!(m.effective_latency(&c, 1e9).is_finite());
    }

    #[test]
    fn feasibility_matches_manual_check() {
        let cfg = ModelConfig::default_paper();
        let m = SurfaceModel::from_config(&cfg);
        let sla = SlaSpec::from_config(&cfg);
        let c = Configuration::new(0, 3); // (H=1, xlarge)
        let t = m.throughput(&c);
        assert!(m.feasible(&c, t / cfg.sla.b_sla - 1.0, &sla, false));
        assert!(!m.feasible(&c, t / cfg.sla.b_sla + 1.0, &sla, false));
    }

    #[test]
    fn best_feasible_none_under_impossible_load() {
        let cfg = ModelConfig::default_paper();
        let m = SurfaceModel::from_config(&cfg);
        let sla = SlaSpec::from_config(&cfg);
        assert!(m.best_feasible(1e9, &sla, false).is_none());
        assert!(m.best_feasible(100.0, &sla, false).is_some());
    }

    #[test]
    fn evaluate_consistent_with_point_functions() {
        let cfg = ModelConfig::default_paper();
        let m = SurfaceModel::from_config(&cfg);
        let lam = 10_000.0;
        for c in m.plane().iter().collect::<Vec<_>>() {
            let p = m.evaluate(&c, lam);
            assert_eq!(p.latency, m.latency(&c));
            assert_eq!(p.throughput, m.throughput(&c));
            assert_eq!(p.cost, m.cost(&c));
            let lw = lam * cfg.write_ratio();
            assert!((p.coordination - m.coordination(&c, lw)).abs() < 1e-4);
            assert!((p.objective - m.objective(&c, lw)).abs() < 1e-2);
        }
    }
}
