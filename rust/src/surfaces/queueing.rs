//! Utilization-sensitive queueing latency (paper §VIII, future work):
//! `u = lambda_req / T`, `L_final = L / (1 - u)` with `u` clamped at
//! `u_max` so latency spikes but stays finite at saturation.
//!
//! Twin of `python/compile/kernels/queueing.py`.

/// Raw utilization `lambda_req / throughput` (unclamped).
pub fn utilization(throughput: f32, lambda_req: f32) -> f32 {
    if throughput > 0.0 {
        lambda_req / throughput
    } else {
        lambda_req // mirrors the kernel's safe-divide placeholder of 1.0
    }
}

/// `L / (1 - min(u, u_max))`.
pub fn effective_latency(latency: f32, throughput: f32, lambda_req: f32, u_max: f32) -> f32 {
    let u = utilization(throughput, lambda_req).min(u_max);
    latency / (1.0 - u)
}

/// Whether the raw utilization reached/exceeded the clamp (the cell is
/// saturated — the 1/(1-u) model is out of its validity range).
pub fn saturated(throughput: f32, lambda_req: f32, u_max: f32) -> bool {
    utilization(throughput, lambda_req) >= u_max
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_load_is_raw_latency() {
        assert_eq!(effective_latency(2.0, 100.0, 0.0, 0.75), 2.0);
    }

    #[test]
    fn half_load_doubles_latency() {
        assert!((effective_latency(2.0, 100.0, 50.0, 0.75) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn clamped_at_u_max() {
        let at_clamp = effective_latency(2.0, 100.0, 75.0, 0.75);
        let beyond = effective_latency(2.0, 100.0, 1e9, 0.75);
        assert!((at_clamp - beyond).abs() < 1e-3);
        assert!(beyond.is_finite());
        assert!((beyond - 8.0).abs() < 1e-3); // 2 / (1 - 0.75)
    }

    #[test]
    fn saturation_flag() {
        assert!(!saturated(100.0, 74.0, 0.75));
        assert!(saturated(100.0, 75.0, 0.75));
        assert!(saturated(100.0, 200.0, 0.75));
    }

    #[test]
    fn monotone_in_load() {
        let mut prev = 0.0;
        for lam in [0.0, 10.0, 30.0, 60.0, 74.0, 90.0] {
            let l = effective_latency(1.0, 100.0, lam, 0.9);
            assert!(l >= prev);
            prev = l;
        }
    }
}
