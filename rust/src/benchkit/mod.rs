//! Micro-benchmark harness (offline substitute for `criterion`): warms
//! up, auto-calibrates the batch size to a target sample duration,
//! collects wall-clock samples, and prints mean / median / p95 with
//! throughput. Used by every `rust/benches/*.rs` target
//! (`harness = false`).

// Measuring wall time is this module's whole job: it is the one
// rust/src module allowlisted from simlint's d1-no-wall-clock rule and
// clippy's disallowed_methods (simulation/decision code injects time
// through `FleetSimulator::set_planning_clock` instead).
#![allow(clippy::disallowed_methods)]

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Collected statistics for one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        if self.mean.as_secs_f64() > 0.0 {
            1.0 / self.mean.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }
}

/// Benchmark runner with criterion-ish defaults.
pub struct Bench {
    samples: usize,
    target_sample: Duration,
    warmup: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            samples: 30,
            target_sample: Duration::from_millis(20),
            warmup: Duration::from_millis(200),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            samples: 10,
            target_sample: Duration::from_millis(5),
            warmup: Duration::from_millis(50),
        }
    }

    pub fn with_samples(mut self, samples: usize) -> Self {
        self.samples = samples.max(3);
        self
    }

    /// Run one benchmark; `f` should return a value, which is
    /// black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup + calibration: how many iterations fill target_sample?
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let batch = ((self.target_sample.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed() / batch as u32);
        }
        samples.sort();
        let stats = Stats {
            iters: batch * self.samples as u64,
            mean: samples.iter().sum::<Duration>() / self.samples as u32,
            median: samples[self.samples / 2],
            p95: samples[(self.samples * 95 / 100).min(self.samples - 1)],
            min: samples[0],
        };
        println!(
            "bench {name:<42} mean {:>12?}  median {:>12?}  p95 {:>12?}  min {:>12?}  ({} iters)",
            stats.mean, stats.median, stats.p95, stats.min, stats.iters
        );
        stats
    }

    /// Print a named derived metric next to a benchmark (e.g. rows/s).
    pub fn report_metric(&self, name: &str, value: f64, unit: &str) {
        println!("bench {name:<42} {value:>14.2} {unit}");
    }
}

/// Section header for grouped bench output (one group per paper table
/// or figure).
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering_holds() {
        let b = Bench::quick();
        let s = b.run("noop", || 1 + 1);
        assert!(s.min <= s.median);
        assert!(s.median <= s.p95);
        assert!(s.iters > 0);
    }

    #[test]
    fn measures_real_work() {
        let b = Bench::quick();
        let fast = b.run("fast", || (0..10u64).sum::<u64>());
        let slow = b.run("slow", || (0..100_000u64).map(black_box).sum::<u64>());
        assert!(slow.mean > fast.mean);
    }
}
