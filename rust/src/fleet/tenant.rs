//! A fleet tenant: one database cluster with its own Scaling-Plane
//! state, SLA contract, phase-shifted demand trace, and Algorithm-1
//! policy, plus the admission bookkeeping the budget arbiter needs
//! (per-tick proposals, denial streaks, violation state).
//!
//! Since PR 3 a proposal is a *ranked candidate list*, not a single
//! move: the policy's best move first (budget-shaped via the
//! [`BudgetHint`] in its [`PolicyContext`]), then cheaper feasible
//! alternatives, then — for SLA-repair proposals — a *stepping stone*
//! that strictly reduces Chebyshev distance to the cheapest
//! audit-clearing configuration (monotone, so multi-tick walks toward
//! a repair target cannot cycle). Non-repairing tenants additionally
//! publish *shed offers*: feasible cost-decreasing moves the arbiter
//! may actuate to fund another tenant's SLA repair (online budget
//! re-negotiation).
//!
//! Since PR 5 the ranked enumeration lives in the *policy*
//! ([`Policy::propose`] returns a [`crate::policy::Proposal`] carrying
//! every scored neighbor); [`Tenant::propose`] no longer re-walks the
//! neighborhood. It distills the policy's proposal into the
//! admission-side view — strict moves only, alternatives capped at
//! [`MAX_ALTERNATIVES`], the repair stepping stone, shed offers — and
//! layers on the SLA-audit bookkeeping only the tenant knows
//! (measured violations, escalation after K violating holds, class and
//! denial-streak stamps). Exactly one policy enumeration happens per
//! tick, pinned by `planner_enumerates_exactly_once_per_tick`.
//!
//! Tenants share one [`SurfaceModel`] (the plane geometry and surface
//! constants are fleet-wide), so adding a tenant costs state, not model
//! construction — the fleet bench leans on this.
//!
//! A tenant can optionally be backed by any boxed
//! [`Substrate`] — the sampling [`ClusterSim`], the event-driven
//! [`EventSim`], or an analytical wrapper — and substrates of
//! different kinds mix freely within one fleet run. Physical
//! substrates audit against *this tenant's* SLA: the shared
//! [`ClusterParams::sla_latency`] is rescaled by the ratio of the
//! tenant's `l_max` to the fleet config's default, so heterogeneous
//! per-tenant SLAs survive the analytical-to-substrate unit mapping.

use std::sync::Arc;

use crate::cluster::{ClusterParams, ClusterSim, EventSim, Substrate};
use crate::config::{ModelConfig, MoveFlags};
use crate::forecast::{Forecaster, Holt, SeasonalNaive};
use crate::metrics::{LatencyHistogram, Recorder, StepRecord, StreamingRecorder, Summary};
use crate::plane::Configuration;
use crate::policy::{BudgetHint, DiagonalScale, ForecastLookahead, Policy, PolicyContext};
use crate::serverless::{Lifecycle, ServerlessParams, ServerlessState};
use crate::sla::{SlaSpec, Violation};
use crate::surfaces::SurfaceModel;
use crate::workload::{Trace, WorkloadPoint};
use crate::INFEASIBLE;

// The decision vocabulary moved into `policy` in PR 5; these re-exports
// keep `fleet::{Candidate, Proposal, PriorityClass}` paths working.
pub use crate::policy::{Candidate, PriorityClass, Proposal, MAX_ALTERNATIVES};

/// Resolution floor of the per-tenant latency histograms (latencies are
/// in model units, O(1); segments must share a floor to merge — the
/// canonical value lives in `metrics` so registry rollups merge too).
const HIST_FLOOR: f64 = crate::metrics::LATENCY_FLOOR;

/// Per-tenant demand predictor choice for forecast-driven proposals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForecastKind {
    /// Holt double exponential smoothing (tracks ramps).
    Holt,
    /// Seasonal naive with the tenant's trace length as the period
    /// (exact for the cyclically repeated fleet traces after one cycle).
    Seasonal,
}

impl ForecastKind {
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "holt" => Some(ForecastKind::Holt),
            "seasonal" => Some(ForecastKind::Seasonal),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ForecastKind::Holt => "holt",
            ForecastKind::Seasonal => "seasonal",
        }
    }
}

/// Static description of one tenant.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    pub class: PriorityClass,
    pub sla: SlaSpec,
    pub trace: Trace,
    pub start: Configuration,
}

impl TenantSpec {
    /// Spec with the model-config defaults for SLA and start config.
    pub fn from_config(
        cfg: &ModelConfig,
        name: impl Into<String>,
        class: PriorityClass,
        trace: Trace,
    ) -> Self {
        Self {
            name: name.into(),
            class,
            sla: SlaSpec::from_config(cfg),
            trace,
            start: Configuration::new(cfg.policy.start[0], cfg.policy.start[1]),
        }
    }
}

/// The planner driving a tenant's proposals: reactive DIAGONALSCALE by
/// default, or forecast-driven lookahead over a boxed predictor.
pub type TenantPlanner = Box<dyn Policy + Send>;

/// A cached hold proposal plus the explicit invalidation set that
/// guards its replay — the heart of the fleet's dirty queue.
///
/// `Tenant::propose` issues a ticket whenever it returns a *hold*
/// (empty candidate list) that a pure planner would reproduce verbatim,
/// and [`Tenant::replay_hold`] hands the cached proposal back only
/// while every guarded input still matches:
///
/// * **age** — `issued_at` plus the fleet's `refresh_k` bounds ticket
///   lifetime (the mandatory K-tick re-propose safety net);
/// * **lifecycle** — any serverless edge (`Active → Draining →
///   Suspended → Resuming`, including the `Resuming { until }` payload)
///   invalidates;
/// * **violation flag / denial streak** — both are stamped into the
///   proposal the arbiter sees, and a violating *active* hold must
///   re-run to advance the escalation counter, so exact equality is
///   required;
/// * **workload** — bitwise equality for active tenants (the planner
///   scores against it); parked tenants only need demand to stay at or
///   below the wake threshold, and draining/cold-starting holds ignore
///   demand entirely;
/// * **budget hint** — equal effective headroom, or both hints roomy
///   enough to afford `max_move_delta` (the priciest enumerated
///   neighbor), in which case no candidate's `BUDGET_PENALTY` term can
///   differ and the scored neighborhood is bitwise unchanged;
/// * **idle detection** — an active serverless hold is replayed only
///   while `idle_enough()` stays false, because flipping true would
///   turn the fresh hold into a suspend candidate.
///
/// Anything that actuates state (`apply`, resume edges, planner or
/// substrate swaps, scheduled failures) drops the ticket outright. The
/// cache may only skip work, never change answers: a replayed hold is
/// bit-identical to what a fresh propose would have produced, which
/// `tests/prop_dirty.rs` pins fleet-wide.
#[derive(Debug, Clone)]
struct HoldTicket {
    issued_at: usize,
    lifecycle: Option<Lifecycle>,
    violating: bool,
    streak: usize,
    workload: WorkloadPoint,
    hint: Option<BudgetHint>,
    /// Max `cost(candidate) - cost(current)` over the planner's full
    /// scored neighborhood: if both the cached and the offered hint
    /// afford this, every `BudgetHint::fits` test resolves identically.
    max_move_delta: f32,
    proposal: Proposal,
}

/// Budget hints are equivalent for replay when the effective headroom
/// (`min(fleet, class)` — the only value [`BudgetHint::fits`] reads) is
/// bitwise equal, or when both hints afford the priciest enumerated
/// move so no candidate's penalty term can differ.
fn hint_equivalent(now: Option<BudgetHint>, then: Option<BudgetHint>, max_delta: f32) -> bool {
    match (now, then) {
        (None, None) => true,
        (Some(a), Some(b)) => {
            a.headroom().to_bits() == b.headroom().to_bits()
                || (a.headroom() >= max_delta && b.headroom() >= max_delta)
        }
        _ => false,
    }
}

fn workload_bits_eq(a: WorkloadPoint, b: WorkloadPoint) -> bool {
    a.lambda_req.to_bits() == b.lambda_req.to_bits() && a.lambda_w.to_bits() == b.lambda_w.to_bits()
}

/// Runtime state of one tenant cluster.
pub struct Tenant {
    pub id: usize,
    spec: TenantSpec,
    model: Arc<SurfaceModel>,
    planner: TenantPlanner,
    current: Configuration,
    recorder: Recorder,
    /// When set, recording streams into bounded sketches instead of the
    /// exact recorder's unbounded `Vec` (the 10k-tenant mode).
    streaming: Option<StreamingRecorder>,
    recording: bool,
    last_violation: bool,
    /// Consecutive denials while SLA-violating (fairness counter).
    pub denial_streak: usize,
    pub max_denial_streak: usize,
    pub denied_total: usize,
    pub rescued_total: usize,
    /// Rescue attempts the arbiter could not afford (the move did not
    /// fit the budget left after cost cuts and more-starved rescues).
    pub rescue_unaffordable_total: usize,
    /// Moves admitted as a lower-ranked candidate (first choice did not
    /// fit; the tenant degraded instead of being denied).
    pub degraded_total: usize,
    /// Shed offers the arbiter actuated to fund other tenants' repairs.
    pub shed_total: usize,
    /// Consecutive ticks the tenant held still while SLA-violating
    /// (substrate-measured violations the analytical planner cannot
    /// see); at `escalate_k` the tenant escalates to an emergency
    /// scale-up so it cannot starve silently.
    violating_holds: usize,
    escalate_k: usize,
    reb_h: f32,
    reb_v: f32,
    plan_queue: bool,
    /// Optional physical substrate backing this tenant (any engine).
    substrate: Option<Box<dyn Substrate + Send>>,
    /// Optional scale-to-zero lifecycle (None = always-on tenant).
    serverless: Option<ServerlessState>,
    /// Live latency histogram of the current active segment.
    hist: LatencyHistogram,
    /// Segments archived at each suspension; merged with the live
    /// segment for fleet p95/p99 across suspend/resume histories.
    hist_segments: Vec<LatencyHistogram>,
    /// Cached hold + invalidation set for the fleet's dirty queue.
    ticket: Option<HoldTicket>,
}

impl Tenant {
    pub fn new(id: usize, spec: TenantSpec, model: Arc<SurfaceModel>, cfg: &ModelConfig) -> Self {
        assert!(!spec.trace.is_empty(), "tenant {} has an empty trace", spec.name);
        assert!(model.plane().contains(&spec.start), "tenant start outside plane");
        let current = spec.start;
        Self {
            id,
            spec,
            model,
            planner: Box::new(DiagonalScale::diagonal()),
            current,
            recorder: Recorder::new(),
            streaming: None,
            recording: true,
            last_violation: false,
            denial_streak: 0,
            max_denial_streak: 0,
            denied_total: 0,
            rescued_total: 0,
            rescue_unaffordable_total: 0,
            degraded_total: 0,
            shed_total: 0,
            violating_holds: 0,
            escalate_k: 3,
            reb_h: cfg.policy.reb_h,
            reb_v: cfg.policy.reb_v,
            plan_queue: cfg.policy.plan_queue,
            substrate: None,
            serverless: None,
            hist: LatencyHistogram::new(HIST_FLOOR),
            hist_segments: Vec::new(),
            ticket: None,
        }
    }

    /// Replace the reactive planner with forecast-driven lookahead
    /// (`depth` >= 1; the paper suggests 2-3). Seasonal predictors use
    /// the tenant's trace length as their period — exact once the
    /// cyclic trace has repeated.
    pub fn enable_forecast(&mut self, kind: ForecastKind, depth: usize) {
        let predictor: Box<dyn Forecaster + Send> = match kind {
            ForecastKind::Holt => Box::new(Holt::default_tuned()),
            ForecastKind::Seasonal => Box::new(SeasonalNaive::new(self.spec.trace.len())),
        };
        let write_ratio = {
            let w = self.spec.trace.points[0];
            if w.lambda_req > 0.0 {
                w.lambda_w / w.lambda_req
            } else {
                0.0
            }
        };
        self.planner = Box::new(ForecastLookahead::new(
            MoveFlags::DIAGONAL,
            depth,
            predictor,
            write_ratio,
        ));
    }

    /// Ticks a violating-but-holding tenant waits before escalating to
    /// an emergency scale-up (the fleet wires its fairness K here).
    pub fn set_escalation(&mut self, k: usize) {
        assert!(k > 0, "escalation threshold must be at least 1");
        self.escalate_k = k;
    }

    /// Replace the planner outright (test orchestration and custom
    /// policies; [`Self::enable_forecast`] is the production path).
    pub fn set_planner(&mut self, planner: TenantPlanner) {
        self.planner = planner;
        self.ticket = None;
    }

    /// The shared [`ClusterParams`] rescaled to this tenant's SLA: the
    /// fleet-wide `sla_latency` bound corresponds to the config-default
    /// `l_max`, so a tenant whose contract is k times looser is audited
    /// (and timed out) against a k-times-looser substrate bound. This
    /// keeps substrate latencies on one fleet-wide unit while each
    /// tenant is audited against its *own* contract.
    pub fn tenant_params(&self, cfg: &ModelConfig, params: ClusterParams) -> ClusterParams {
        let mut p = params;
        p.sla_latency = params.sla_latency * (self.spec.sla.l_max / cfg.sla.l_max) as f64;
        p
    }

    /// Back this tenant with a boxed substrate (any engine); metrics
    /// then come from measurement, not the model. The substrate is
    /// fast-forwarded to the tenant's current configuration.
    pub fn attach_substrate(&mut self, mut sub: Box<dyn Substrate + Send>) {
        if sub.current() != self.current {
            sub.apply(self.current);
        }
        self.substrate = Some(sub);
        self.ticket = None;
    }

    /// Back this tenant with its own sampling-engine cluster
    /// (per-tenant [`ClusterSim`], mirroring the single-cluster
    /// coordinator), audited against *this tenant's* SLA bound.
    pub fn attach_cluster(&mut self, cfg: &ModelConfig, params: ClusterParams, seed: u64) {
        let params = self.tenant_params(cfg, params);
        self.attach_substrate(Box::new(ClusterSim::new(cfg, params, seed)));
    }

    /// Back this tenant with its own event-driven cluster
    /// ([`EventSim`] — the bench-speed engine for large fleets),
    /// audited against *this tenant's* SLA bound.
    pub fn attach_event_cluster(&mut self, cfg: &ModelConfig, params: ClusterParams, seed: u64) {
        let params = self.tenant_params(cfg, params);
        self.attach_substrate(Box::new(EventSim::new(cfg, params, seed)));
    }

    /// Back this tenant with an analytical substrate built from the
    /// fleet-shared surface model and audited against *this tenant's*
    /// SLA latency bound.
    pub fn attach_analytical(&mut self, cfg: &ModelConfig, params: ClusterParams) {
        let params = self.tenant_params(cfg, params);
        self.attach_substrate(Box::new(crate::simulator::AnalyticalSubstrate::from_model(
            Arc::clone(&self.model),
            params,
            self.current,
            self.spec.sla.l_max,
        )));
    }

    /// The substrate-scale SLA bound this tenant is audited against, if
    /// a substrate backs it.
    pub fn substrate_sla(&self) -> Option<f64> {
        self.substrate.as_ref().map(|s| s.params().sla_latency)
    }

    /// Opt this tenant into the serverless tier: its pages live in the
    /// fleet's shared [`crate::serverless::StorageService`] (which
    /// registered `working_set_gb` for it) and scale-to-zero lifecycle
    /// moves become available to the policy pipeline.
    pub fn enable_serverless(&mut self, params: ServerlessParams, working_set_gb: f32) {
        self.serverless = Some(ServerlessState::new(params, working_set_gb));
        self.ticket = None;
    }

    /// The tenant's serverless state, if it is in the serverless tier.
    pub fn serverless(&self) -> Option<&ServerlessState> {
        self.serverless.as_ref()
    }

    /// Current lifecycle, if this is a serverless tenant.
    pub fn lifecycle(&self) -> Option<Lifecycle> {
        self.serverless.as_ref().map(|s| s.lifecycle)
    }

    /// Hourly storage-tier cost (zero for always-on tenants).
    pub fn storage_cost(&self) -> f32 {
        self.serverless.as_ref().map_or(0.0, |s| s.storage_cost())
    }

    /// Cold-start window length a wake of this tenant takes, in ticks.
    pub fn cold_start_ticks(&self) -> usize {
        self.serverless.as_ref().map_or(0, |s| s.cold_start_ticks())
    }

    /// Open the cold-start window of an admitted wake: Suspended →
    /// Resuming until the fleet calendar's `ResumeEnd` fires at `until`.
    pub fn begin_resume(&mut self, until: usize) {
        let s = self.serverless.as_mut().expect("begin_resume on an always-on tenant");
        debug_assert_eq!(s.lifecycle, Lifecycle::Suspended);
        s.lifecycle = Lifecycle::Resuming { until };
        s.resumes += 1;
        self.ticket = None;
    }

    /// Close the cold-start window (fired by the fleet calendar's
    /// `ResumeEnd`); resets idle detection so the tenant does not
    /// re-suspend mid-burst.
    pub fn finish_resume(&mut self) {
        if let Some(s) = &mut self.serverless {
            if matches!(s.lifecycle, Lifecycle::Resuming { .. }) {
                s.lifecycle = Lifecycle::Active;
                s.reset_idle();
                self.ticket = None;
            }
        }
    }

    /// Latency history across suspend/resume segments merged with the
    /// live segment — the fleet aggregates p95/p99 from this, so a
    /// suspended-then-resumed tenant's pre-suspension history still
    /// counts.
    pub fn merged_histogram(&self) -> LatencyHistogram {
        let mut merged = LatencyHistogram::new(HIST_FLOOR);
        for seg in &self.hist_segments {
            merged.merge(seg);
        }
        merged.merge(&self.hist);
        merged
    }

    /// Schedule a node failure at simulated time `at` on the backing
    /// substrate's event calendar, if it has one (DES failure
    /// injection). Returns whether the failure was scheduled.
    pub fn schedule_node_failure(&mut self, at: f64, node: usize) -> bool {
        // the failure will surface through serve() as measured
        // violations; conservatively dirty the tenant right away
        self.ticket = None;
        self.substrate.as_mut().map_or(false, |s| s.schedule_failure(at, node))
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn class(&self) -> PriorityClass {
        self.spec.class
    }

    pub fn sla(&self) -> &SlaSpec {
        &self.spec.sla
    }

    pub fn trace(&self) -> &Trace {
        &self.spec.trace
    }

    pub fn current(&self) -> Configuration {
        self.current
    }

    /// Hourly cost this tenant pays right now: the current
    /// configuration's compute price, plus the storage tier for
    /// serverless tenants — which is *all* a draining or suspended
    /// tenant pays (scale-to-zero's whole point).
    pub fn cost(&self) -> f32 {
        match self.lifecycle() {
            None => self.model.cost(&self.current),
            Some(Lifecycle::Draining) | Some(Lifecycle::Suspended) => self.storage_cost(),
            Some(Lifecycle::Active) | Some(Lifecycle::Resuming { .. }) => {
                self.model.cost(&self.current) + self.storage_cost()
            }
        }
    }

    /// The tenant's last served step violated its SLA.
    pub fn violating(&self) -> bool {
        self.last_violation
    }

    pub fn set_recording(&mut self, on: bool) {
        self.recording = on;
    }

    /// Switch recording to the O(1)-memory [`StreamingRecorder`]:
    /// summary accumulators, latency sketches, and a `cap`-bounded
    /// Algorithm-R exemplar reservoir replace the exact recorder's
    /// unbounded `Vec<StepRecord>`. The reservoir seed is derived from
    /// the tenant id, so fleets replay bit-identically. Observation
    /// only — decisions never read the recorder.
    pub fn enable_streaming_metrics(&mut self, cap: usize) {
        let seed = 0x5EED_0B5Eu64 ^ (self.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.streaming = Some(StreamingRecorder::new(cap, seed));
    }

    /// The bounded recorder, when streaming mode is on.
    pub fn streaming(&self) -> Option<&StreamingRecorder> {
        self.streaming.as_ref()
    }

    /// In exact mode: every recorded step. In streaming mode: the
    /// exemplar reservoir (a uniform sample of the stream).
    pub fn records(&self) -> &[StepRecord] {
        match &self.streaming {
            Some(s) => s.sample(),
            None => self.recorder.records(),
        }
    }

    /// Step records currently held in memory for this tenant — the
    /// observation-memory proxy pinned constant-in-ticks under
    /// streaming by `rust/tests/metrics_stream.rs`.
    pub fn retained_records(&self) -> usize {
        match &self.streaming {
            Some(s) => s.retained(),
            None => self.recorder.len(),
        }
    }

    pub fn summary(&self) -> Summary {
        match &self.streaming {
            Some(s) => s.summary(),
            None => self.recorder.summary(),
        }
    }

    /// Route one served step into whichever recorder is active.
    fn record_step(&mut self, rec: StepRecord) {
        if !self.recording {
            return;
        }
        match &mut self.streaming {
            Some(s) => s.push(rec),
            None => self.recorder.push(rec),
        }
    }

    /// Demand at fleet tick `t` (traces repeat cyclically).
    pub fn workload_at(&self, t: usize) -> WorkloadPoint {
        self.spec.trace.points[t % self.spec.trace.len()]
    }

    /// Serve tick `t` at the carried-in configuration and record the
    /// step (serve-then-move, mirroring [`crate::simulator::Simulator`]).
    pub fn serve(&mut self, t: usize) -> StepRecord {
        let w = self.workload_at(t);
        match self.lifecycle() {
            None | Some(Lifecycle::Active) => {}
            // storage-only lifecycle states serve nothing: demand above
            // the idle threshold goes unserved (a throughput violation
            // that triggers or sustains a wake); a trickle at or below
            // it is absorbed as noise. Resuming additionally pays for
            // the re-provisioned compute while the cold start blocks.
            Some(lc) => {
                let s = self.serverless.as_mut().expect("lifecycle without state");
                let idle = s.params.idle_lambda;
                let cost = match lc {
                    Lifecycle::Resuming { .. } => {
                        s.cold_start_ticks_total += 1;
                        self.model.cost(&self.current) + s.storage_cost()
                    }
                    _ => {
                        s.suspended_ticks += 1;
                        if lc == Lifecycle::Draining {
                            s.lifecycle = Lifecycle::Suspended;
                        }
                        s.storage_cost()
                    }
                };
                let s = self.serverless.as_mut().expect("lifecycle without state");
                s.observe_demand(w.lambda_req);
                let rec = StepRecord {
                    step: t,
                    config: self.current,
                    lambda_req: w.lambda_req,
                    latency: 0.0,
                    latency_raw: 0.0,
                    throughput: 0.0,
                    cost,
                    objective: 0.0,
                    violation: Violation {
                        latency: false,
                        throughput: w.lambda_req > idle,
                    },
                };
                self.last_violation = rec.violation.any();
                self.record_step(rec);
                return rec;
            }
        }
        let mut rec = match &mut self.substrate {
            None => {
                let point = self.model.evaluate(&self.current, w.lambda_req);
                let lat_eff = self.model.effective_latency(&self.current, w.lambda_req);
                let obj_eff = self.model.effective_objective(&self.current, w.lambda_req);
                StepRecord {
                    step: t,
                    config: self.current,
                    lambda_req: w.lambda_req,
                    latency: lat_eff,
                    latency_raw: point.latency,
                    throughput: point.throughput,
                    cost: point.cost,
                    objective: obj_eff,
                    violation: self.spec.sla.audit(
                        point.latency,
                        point.throughput,
                        w.lambda_req,
                    ),
                }
            }
            Some(sim) => {
                let m = sim.step(w);
                let point = self.model.evaluate(&self.current, w.lambda_req);
                StepRecord {
                    step: t,
                    config: self.current,
                    lambda_req: w.lambda_req,
                    latency: m.p99_latency as f32,
                    latency_raw: point.latency,
                    throughput: m.completed as f32,
                    cost: point.cost,
                    objective: self.model.effective_objective(&self.current, w.lambda_req),
                    violation: Violation {
                        // each substrate carries this tenant's rescaled
                        // SLA bound (see `tenant_params`)
                        latency: m.p99_latency > sim.params().sla_latency,
                        throughput: m.completed < m.offered * 0.999,
                    },
                }
            }
        };
        if let Some(s) = &mut self.serverless {
            s.observe_demand(w.lambda_req);
            // an active serverless tenant pays the storage tier on top
            // of compute, exactly as its proposals price it — the
            // projected-spend invariant depends on the two agreeing
            rec.cost += s.storage_cost();
        }
        self.last_violation = rec.violation.any();
        if self.recording && rec.throughput > 0.0 && rec.latency > 0.0 {
            self.hist.record(rec.latency as f64);
        }
        self.record_step(rec);
        rec
    }

    /// The cheapest configuration that clears this tenant's *audit* for
    /// demand `lambda` (raw latency within `l_max`, throughput at least
    /// the raw requirement), if one exists anywhere on the plane.
    fn cheapest_clearing(&self, lambda: f32) -> Option<Configuration> {
        let mut best: Option<Configuration> = None;
        for c in self.model.plane().iter() {
            if self.model.latency(&c) <= self.spec.sla.l_max
                && self.model.throughput(&c) >= lambda
            {
                if best.map_or(true, |b| self.model.cost(&c) < self.model.cost(&b)) {
                    best = Some(c);
                }
            }
        }
        best
    }

    /// The tenant's ranked admission proposal for tick `t`, shaped to
    /// the fleet budget hint. The *policy* enumerates and scores the
    /// neighborhood exactly once ([`Policy::propose`]); this method
    /// only distills that proposal — preferred move, cheaper feasible
    /// alternatives, and for SLA repairs a stepping stone toward the
    /// cheapest clearing configuration — and layers on the SLA-audit
    /// bookkeeping (measured violations, escalation, class stamps), so
    /// the arbiter can degrade the tenant instead of denying it
    /// outright.
    pub fn propose(&mut self, t: usize, hint: Option<BudgetHint>) -> Proposal {
        let w = self.workload_at(t);
        self.ticket = None;
        if let Some(s) = &mut self.serverless {
            // a suspend intent not actuated last tick (denied, or the
            // fleet skipped actuation) is stale — never carry it over
            s.pending_suspend = false;
            let idle = s.params.idle_lambda;
            match s.lifecycle {
                Lifecycle::Active => {}
                Lifecycle::Suspended if w.lambda_req > idle => return self.wake_proposal(w),
                // draining, cold-starting, or suspended-and-idle
                // tenants cannot move this tick; the hold is cacheable
                // — it ignores the planner, the budget hint, and (past
                // the parked wake threshold) demand
                _ => {
                    let p = self.lifecycle_hold();
                    self.issue_ticket(t, w, hint, 0.0, &p);
                    return p;
                }
            }
        }
        // the context borrows a cheap Arc clone + copied SLA so `self`
        // stays free for the bookkeeping below
        let model = Arc::clone(&self.model);
        let sla = self.spec.sla;
        let ctx = PolicyContext {
            model: model.as_ref(),
            sla: &sla,
            reb_h: self.reb_h,
            reb_v: self.reb_v,
            plan_queue: self.plan_queue,
            future: &[],
            budget: hint,
        };
        let current = self.current;
        // the ONE neighborhood enumeration this tick: every scored
        // neighbor, budget-blind myopic scores included
        let planned = self.planner.propose(current, w, &ctx);
        let current_score = planned.current_score;
        // priciest enumerated neighbor, for the hold ticket's
        // hint-equivalence guard (any hint affording this leaves every
        // candidate's budget penalty at zero)
        let max_move_delta = planned
            .candidates
            .iter()
            .map(|c| c.cost_to - planned.cost_from)
            .fold(0.0f32, f32::max);
        // row-major view of the scored neighborhood, so ties in the
        // alternative/shed/stone walks keep the kernel's candidate
        // order exactly as the pre-PR-5 re-enumeration did
        let mut scored: Vec<Candidate> = planned.candidates.clone();
        scored.sort_by_key(|c| (c.to.h_idx, c.to.v_idx));
        let current_feasible = scored
            .iter()
            .find(|c| c.to == current)
            .map_or_else(
                || model.feasible(&current, w.lambda_req, &sla, self.plan_queue),
                Candidate::feasible,
            );
        let best = planned.decision();
        let mut emergency = planned.fallback || !current_feasible;
        let repair = emergency || self.last_violation;

        let mut candidates: Vec<Candidate> = Vec::new();
        if best.next != current {
            let top = *planned.top().expect("a move decision has a top candidate");
            candidates.push(top);
            let best_cost = top.cost_to;

            // cheaper feasible alternatives, ranked by myopic score
            // (stable sort: ties keep row-major order): economic
            // proposals only list strict improvements over holding;
            // repair proposals accept any clearing neighbor
            let mut alts: Vec<Candidate> = Vec::new();
            for c in &scored {
                if c.to == current || c.to == top.to || c.cost_to >= best_cost {
                    continue;
                }
                if !c.feasible() {
                    continue;
                }
                if repair || c.raw < current_score {
                    alts.push(*c);
                }
            }
            alts.sort_by(|a, b| a.raw.total_cmp(&b.raw));
            alts.truncate(MAX_ALTERNATIVES);
            candidates.extend(alts);

            // stepping stone for repairs: the cheapest neighbor that
            // strictly reduces Chebyshev distance to the cheapest
            // audit-clearing configuration — monotone progress, so
            // multi-tick walks toward the repair target cannot cycle
            if repair {
                if let Some(target) = self.cheapest_clearing(w.lambda_req) {
                    let dist = |c: &Configuration| {
                        let (dh, dv) = c.index_distance(&target);
                        dh.max(dv)
                    };
                    let d0 = dist(&current);
                    let mut stone: Option<Candidate> = None;
                    for c in &scored {
                        if c.to == current || candidates.iter().any(|k| k.to == c.to) {
                            continue;
                        }
                        if dist(&c.to) < d0
                            && stone.map_or(true, |s: Candidate| c.cost_to < s.cost_to)
                        {
                            stone = Some(*c);
                        }
                    }
                    if let Some(s) = stone {
                        candidates.push(Candidate { gain: 0.0, ..s });
                    }
                }
            }
            self.violating_holds = 0;
        } else if self.last_violation {
            // holding while violating: the model sees no better config
            // (substrate-measured violations the planner cannot see, or
            // the top corner). After `escalate_k` such ticks escalate
            // to an emergency scale-up so the fairness machinery — not
            // silence — owns the outcome.
            self.violating_holds += 1;
            if self.violating_holds >= self.escalate_k {
                let up = self.model.plane().fallback_up(&current, true, true);
                if up != current {
                    // beyond what the model justifies: sentinel scores,
                    // no claimed gain
                    candidates.push(Candidate {
                        to: up,
                        cost_to: model.cost(&up),
                        score: INFEASIBLE,
                        raw: INFEASIBLE,
                        gain: 0.0,
                    });
                    emergency = true;
                }
            }
        } else {
            self.violating_holds = 0;
        }

        // shed offers: feasible cost-decreasing moves a non-repairing
        // tenant volunteers as funding for other tenants' SLA repairs
        let mut sheds: Vec<Candidate> = Vec::new();
        if !repair {
            let mut offers: Vec<Candidate> = Vec::new();
            for c in &scored {
                if c.to == current || c.cost_to >= planned.cost_from {
                    continue;
                }
                if c.feasible() {
                    offers.push(*c);
                }
            }
            // least objective sacrifice first (stable: ties keep
            // row-major order); the gain field carries the sacrifice
            // so the arbiter's funding order matches this ranking
            offers.sort_by(|a, b| a.raw.total_cmp(&b.raw));
            offers.truncate(MAX_ALTERNATIVES);
            for c in offers {
                sheds.push(Candidate { gain: (c.raw - current_score).max(0.0), ..c });
            }
        }

        let mut cost_from = planned.cost_from;
        if let Some(s) = &mut self.serverless {
            // serverless pricing: every configuration carries the
            // storage tier on top of compute — a uniform shift, so
            // rankings and cost deltas are untouched and projected
            // spend still equals next tick's spend
            let storage = s.storage_cost();
            cost_from += storage;
            for c in candidates.iter_mut().chain(sheds.iter_mut()) {
                c.cost_to += storage;
            }
            // suspend candidate: an idle, non-repairing tenant whose
            // planner holds proposes its *own* configuration at
            // storage-only cost — admitted as a pass-0 shrink, with the
            // released compute spend as the claimed gain
            if !repair && candidates.is_empty() && s.idle_enough() {
                s.pending_suspend = true;
                sheds.clear();
                candidates.push(Candidate {
                    to: current,
                    cost_to: storage,
                    score: current_score,
                    raw: current_score,
                    gain: (cost_from - storage).max(0.0),
                });
            }
        }
        let proposal = Proposal {
            tenant: self.id,
            class: self.spec.class,
            from: current,
            cost_from,
            current_score,
            emergency,
            sla_violating: self.last_violation,
            denial_streak: self.denial_streak,
            fallback: planned.fallback,
            candidates,
            sheds,
        };
        // cache clean pure-planner holds for the dirty queue; violating
        // holds are never cached (the escalation counter must advance),
        // and a stateful planner must be re-run every tick
        if proposal.candidates.is_empty() && !self.last_violation && self.planner.cacheable() {
            self.issue_ticket(t, w, hint, max_move_delta, &proposal);
        }
        proposal
    }

    /// Cache a hold proposal for [`Tenant::replay_hold`].
    fn issue_ticket(
        &mut self,
        t: usize,
        w: WorkloadPoint,
        hint: Option<BudgetHint>,
        max_move_delta: f32,
        proposal: &Proposal,
    ) {
        debug_assert!(proposal.candidates.is_empty(), "only holds are cached");
        self.ticket = Some(HoldTicket {
            issued_at: t,
            lifecycle: self.lifecycle(),
            violating: self.last_violation,
            streak: self.denial_streak,
            workload: w,
            hint,
            max_move_delta,
            proposal: proposal.clone(),
        });
    }

    /// Replay the cached hold for fleet tick `t` if its invalidation
    /// set ([`HoldTicket`]) is untouched; `None` means the tenant is
    /// dirty and must re-run [`Tenant::propose`]. Replay mirrors the
    /// fresh path's bookkeeping (stale suspend intents dropped, the
    /// escalation counter of a clean active hold reset) so tenant state
    /// evolves bit-identically to an always-replan fleet.
    pub fn replay_hold(
        &mut self,
        t: usize,
        hint: Option<BudgetHint>,
        refresh_k: usize,
    ) -> Option<Proposal> {
        let w = self.workload_at(t);
        let tk = self.ticket.as_ref()?;
        if t - tk.issued_at >= refresh_k
            || self.lifecycle() != tk.lifecycle
            || self.last_violation != tk.violating
            || self.denial_streak != tk.streak
        {
            return None;
        }
        let valid = match tk.lifecycle {
            // parked: a fresh propose only looks at whether demand
            // crosses the wake threshold
            Some(Lifecycle::Suspended) => {
                let idle =
                    self.serverless.as_ref().expect("parked implies serverless").params.idle_lambda;
                w.lambda_req <= idle
            }
            // draining / cold-starting holds ignore demand entirely
            Some(Lifecycle::Draining) | Some(Lifecycle::Resuming { .. }) => true,
            // active (always-on or serverless): the planner scores this
            // exact workload under this hint, and a non-repair hold
            // turning idle-capable would become a suspend candidate
            None | Some(Lifecycle::Active) => {
                workload_bits_eq(w, tk.workload)
                    && hint_equivalent(hint, tk.hint, tk.max_move_delta)
                    && (tk.proposal.emergency
                        || self.serverless.as_ref().map_or(true, |s| !s.idle_enough()))
            }
        };
        if !valid {
            return None;
        }
        if let Some(s) = &mut self.serverless {
            s.pending_suspend = false;
        }
        let tk = self.ticket.as_ref().expect("validity checked above");
        if matches!(tk.lifecycle, None | Some(Lifecycle::Active)) {
            self.violating_holds = 0;
        }
        Some(tk.proposal.clone())
    }

    /// The emergency repair proposal of a suspended tenant seeing real
    /// demand: wake to the cheapest configuration that clears the
    /// observed load (re-provisioning from the storage tier is not
    /// neighbor-constrained), priced at compute plus storage. Funded in
    /// the arbiter's class-ordered repair pass, so Gold tenants wake
    /// first under contention; denials feed the fairness streak.
    fn wake_proposal(&mut self, w: WorkloadPoint) -> Proposal {
        let storage = self.storage_cost();
        let to = self
            .cheapest_clearing(w.lambda_req)
            .unwrap_or_else(|| self.model.plane().fallback_up(&self.current, true, true));
        Proposal {
            tenant: self.id,
            class: self.spec.class,
            from: self.current,
            cost_from: storage,
            current_score: INFEASIBLE,
            emergency: true,
            sla_violating: self.last_violation,
            denial_streak: self.denial_streak,
            fallback: false,
            candidates: vec![Candidate {
                to,
                cost_to: self.model.cost(&to) + storage,
                score: INFEASIBLE,
                raw: INFEASIBLE,
                gain: 0.0,
            }],
            sheds: Vec::new(),
        }
    }

    /// A hold proposal for lifecycle states that cannot move this tick
    /// (draining, cold-starting, or suspended without wake-worthy
    /// demand): an empty candidate list, so the arbiter holds.
    fn lifecycle_hold(&self) -> Proposal {
        Proposal {
            tenant: self.id,
            class: self.spec.class,
            from: self.current,
            cost_from: self.cost(),
            current_score: 0.0,
            emergency: false,
            sla_violating: self.last_violation,
            denial_streak: self.denial_streak,
            fallback: false,
            candidates: Vec::new(),
            sheds: Vec::new(),
        }
    }

    /// Actuate an admitted move (resets the fairness counter).
    pub fn apply(&mut self, to: Configuration) {
        assert!(self.model.plane().contains(&to));
        self.ticket = None;
        if let Some(s) = &mut self.serverless {
            if s.pending_suspend && to == self.current {
                // the admitted "move" was this tick's suspend
                // candidate: start draining instead of reconfiguring,
                // and archive the live latency segment — a resumed
                // tenant records into a fresh one and the fleet's
                // percentiles merge the segments
                s.lifecycle = Lifecycle::Draining;
                s.suspends += 1;
                s.pending_suspend = false;
                let live =
                    std::mem::replace(&mut self.hist, LatencyHistogram::new(HIST_FLOOR));
                if !live.is_empty() {
                    self.hist_segments.push(live);
                }
                self.denial_streak = 0;
                return;
            }
        }
        if let Some(sim) = &mut self.substrate {
            if to != self.current {
                sim.apply(to);
            }
        }
        self.current = to;
        self.denial_streak = 0;
    }

    /// The tenant proposed no change this tick.
    pub fn note_no_move(&mut self) {
        self.denial_streak = 0;
    }

    /// The arbiter denied this tick's move.
    pub fn note_denied(&mut self) {
        self.denied_total += 1;
        if self.last_violation {
            self.denial_streak += 1;
            self.max_denial_streak = self.max_denial_streak.max(self.denial_streak);
        } else {
            self.denial_streak = 0;
        }
    }

    /// The fairness guard fired but the move did not fit the budget
    /// left after cost cuts and more-starved rescues.
    pub fn note_rescue_unaffordable(&mut self) {
        self.rescue_unaffordable_total += 1;
        self.note_denied();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceBuilder;

    fn fixture() -> (ModelConfig, Arc<SurfaceModel>) {
        let cfg = ModelConfig::default_paper();
        let model = Arc::new(SurfaceModel::from_config(&cfg));
        (cfg, model)
    }

    fn tenant(class: PriorityClass) -> Tenant {
        let (cfg, model) = fixture();
        let spec = TenantSpec::from_config(&cfg, "t0", class, TraceBuilder::paper(&cfg));
        Tenant::new(0, spec, model, &cfg)
    }

    #[test]
    fn class_order_and_rank_agree() {
        assert!(PriorityClass::Bronze < PriorityClass::Silver);
        assert!(PriorityClass::Silver < PriorityClass::Gold);
        assert!(PriorityClass::Gold.rank() > PriorityClass::Bronze.rank());
        assert_eq!(PriorityClass::ALL[0], PriorityClass::Gold);
    }

    #[test]
    fn serve_records_cost_of_current_config() {
        let mut t = tenant(PriorityClass::Gold);
        let rec = t.serve(0);
        assert_eq!(rec.config, t.current());
        assert!((rec.cost - t.cost()).abs() < 1e-6);
        assert_eq!(t.records().len(), 1);
    }

    #[test]
    fn proposal_candidates_are_neighbors_with_consistent_costs() {
        let mut t = tenant(PriorityClass::Silver);
        for tick in 0..50 {
            t.serve(tick);
            let p = t.propose(tick, None);
            for c in p.candidates.iter().chain(&p.sheds) {
                let (dh, dv) = p.from.index_distance(&c.to);
                assert!(dh <= 1 && dv <= 1);
                assert!(c.gain >= 0.0);
            }
            assert!((p.cost_delta()
                - p.best().map_or(0.0, |c| c.cost_to - p.cost_from))
            .abs()
                < 1e-6);
            // candidate targets are unique (no duplicate walk entries)
            for (i, a) in p.candidates.iter().enumerate() {
                for b in &p.candidates[i + 1..] {
                    assert_ne!(a.to, b.to);
                }
            }
            if let Some(best) = p.best().copied() {
                t.apply(best.to);
            }
        }
    }

    /// The PR-5 bugfix pin: `Tenant::propose` used to re-enumerate and
    /// re-score the whole neighborhood after the policy already had —
    /// now the policy's proposal is the single enumeration and the
    /// tenant only distills it. A counting planner proves the policy is
    /// consulted exactly once per tick, and the distilled lists still
    /// come out ranked and duplicate-free.
    #[test]
    fn planner_enumerates_exactly_once_per_tick() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct CountingPlanner {
            inner: DiagonalScale,
            calls: Arc<AtomicUsize>,
        }
        impl Policy for CountingPlanner {
            fn name(&self) -> &'static str {
                "counting"
            }
            fn propose(
                &mut self,
                current: Configuration,
                workload: WorkloadPoint,
                ctx: &PolicyContext<'_>,
            ) -> Proposal {
                self.calls.fetch_add(1, Ordering::SeqCst);
                self.inner.propose(current, workload, ctx)
            }
        }

        let mut t = tenant(PriorityClass::Silver);
        let calls = Arc::new(AtomicUsize::new(0));
        t.set_planner(Box::new(CountingPlanner {
            inner: DiagonalScale::diagonal(),
            calls: Arc::clone(&calls),
        }));
        for tick in 0..30 {
            t.serve(tick);
            let p = t.propose(tick, None);
            assert_eq!(
                calls.load(Ordering::SeqCst),
                tick + 1,
                "exactly one policy enumeration per tick"
            );
            for (i, a) in p.candidates.iter().enumerate() {
                for b in &p.candidates[i + 1..] {
                    assert_ne!(a.to, b.to, "distilled list has duplicates");
                }
            }
            if let Some(best) = p.best().copied() {
                t.apply(best.to);
            }
        }
    }

    #[test]
    fn gain_nonnegative_when_current_feasible() {
        let (cfg, model) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        // lambda 3000 at (H=2, medium): T ≈ 3988 ≥ 3000 * 1.15 — feasible
        let spec = TenantSpec::from_config(
            &cfg,
            "calm",
            PriorityClass::Gold,
            b.constant(30.0, 10),
        );
        let mut t = Tenant::new(0, spec, model, &cfg);
        t.serve(0);
        let p = t.propose(0, None);
        assert!(!p.emergency);
        for c in &p.candidates {
            assert!(c.gain >= 0.0, "gain={}", c.gain);
        }
    }

    #[test]
    fn emergency_flagged_when_infeasible() {
        let (cfg, model) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        let spec = TenantSpec {
            start: Configuration::new(0, 0),
            ..TenantSpec::from_config(&cfg, "hot", PriorityClass::Bronze, b.constant(160.0, 10))
        };
        let mut t = Tenant::new(0, spec, model, &cfg);
        t.serve(0);
        let p = t.propose(0, None);
        assert!(p.emergency);
        assert!(p.is_repair());
        assert_eq!(p.density(), INFEASIBLE);
        assert!(p.sheds.is_empty(), "repairing tenants offer no sheds");
    }

    #[test]
    fn repair_proposal_includes_a_stepping_stone_toward_clearing() {
        let (cfg, model) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        // (H=2, medium) at lambda 16000: only (H=4, xlarge) clears, two
        // steps away — the candidate list must contain a move that gets
        // strictly closer to it than the current config is.
        let spec = TenantSpec::from_config(
            &cfg,
            "peak",
            PriorityClass::Gold,
            b.constant(160.0, 10),
        );
        let mut t = Tenant::new(0, spec, model.clone(), &cfg);
        t.serve(0);
        let p = t.propose(0, None);
        assert!(p.is_repair());
        let target = Configuration::new(2, 3);
        let d0 = {
            let (dh, dv) = p.from.index_distance(&target);
            dh.max(dv)
        };
        assert!(p.candidates.iter().any(|c| {
            let (dh, dv) = c.to.index_distance(&target);
            dh.max(dv) < d0
        }));
    }

    #[test]
    fn nonviolating_holder_offers_cheaper_feasible_sheds() {
        let (cfg, model) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        // start at (H=1, xlarge) under calm demand: holding is optimal,
        // and (H=2, large) is the feasible cheaper fallback
        let spec = TenantSpec {
            start: Configuration::new(0, 3),
            ..TenantSpec::from_config(&cfg, "idle", PriorityClass::Silver, b.constant(60.0, 10))
        };
        let mut t = Tenant::new(0, spec, model.clone(), &cfg);
        t.serve(0);
        let p = t.propose(0, None);
        assert!(!p.is_repair());
        assert!(!p.sheds.is_empty(), "an idle tenant must offer sheds");
        for s in &p.sheds {
            assert!(s.cost_to < p.cost_from);
            assert!(model.feasible(&s.to, t.workload_at(0).lambda_req, t.sla(), false));
        }
    }

    #[test]
    fn holding_while_violating_escalates_after_k_ticks() {
        let (cfg, model) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        // (H=1, xlarge) at lambda 6000 is the objective optimum — the
        // planner holds. Force the measured-violation flag a substrate
        // would set: after K violating holds the tenant must escalate.
        let spec = TenantSpec {
            start: Configuration::new(0, 3),
            ..TenantSpec::from_config(&cfg, "stuck", PriorityClass::Bronze, b.constant(60.0, 10))
        };
        let mut t = Tenant::new(0, spec, model, &cfg);
        t.set_escalation(3);
        t.serve(0);
        t.last_violation = true;
        let mut escalated_at = None;
        for tick in 0..5 {
            let p = t.propose(tick, None);
            if p.is_move() {
                assert!(p.emergency, "escalated move must be an emergency");
                escalated_at = Some(tick);
                break;
            }
        }
        assert_eq!(escalated_at, Some(2), "must escalate exactly at the K-th violating hold");
    }

    #[test]
    fn denial_streak_counts_only_while_violating() {
        let mut t = tenant(PriorityClass::Bronze);
        t.last_violation = true;
        t.note_denied();
        t.note_denied();
        assert_eq!(t.denial_streak, 2);
        t.last_violation = false;
        t.note_denied();
        assert_eq!(t.denial_streak, 0);
        assert_eq!(t.denied_total, 3);
        assert_eq!(t.max_denial_streak, 2);
    }

    #[test]
    fn apply_resets_streak() {
        let mut t = tenant(PriorityClass::Bronze);
        t.last_violation = true;
        t.note_denied();
        assert_eq!(t.denial_streak, 1);
        t.apply(Configuration::new(2, 2));
        assert_eq!(t.denial_streak, 0);
        assert_eq!(t.current(), Configuration::new(2, 2));
    }

    #[test]
    fn recording_off_keeps_no_records() {
        let mut t = tenant(PriorityClass::Gold);
        t.set_recording(false);
        for tick in 0..20 {
            t.serve(tick);
        }
        assert!(t.records().is_empty());
    }

    #[test]
    fn cluster_backed_tenant_measures() {
        let (cfg, model) = fixture();
        let spec =
            TenantSpec::from_config(&cfg, "des", PriorityClass::Gold, TraceBuilder::paper(&cfg));
        let mut t = Tenant::new(0, spec, model, &cfg);
        t.attach_cluster(&cfg, ClusterParams::default(), 7);
        let rec = t.serve(0);
        // measured latency comes from the DES, not the analytical model
        assert!(rec.latency > 0.0);
        assert!(rec.throughput > 0.0);
    }

    #[test]
    fn substrate_audits_against_the_tenants_own_sla() {
        let (cfg, model) = fixture();
        let trace = TraceBuilder::paper(&cfg);
        let mk = |name: &str, l_max: f32| TenantSpec {
            sla: SlaSpec::new(l_max, cfg.sla.b_sla),
            ..TenantSpec::from_config(&cfg, name, PriorityClass::Gold, trace.clone())
        };
        // two tenants whose SLA bounds differ by 4x: the physical
        // substrates must carry bounds in the same 4x ratio (this is
        // the regression for the shared-`sla_latency` bug — DES and
        // sampling tenants used to audit against the fleet default)
        let mut strict = Tenant::new(0, mk("strict", cfg.sla.l_max), Arc::clone(&model), &cfg);
        let mut loose =
            Tenant::new(1, mk("loose", cfg.sla.l_max * 4.0), Arc::clone(&model), &cfg);
        strict.attach_event_cluster(&cfg, ClusterParams::default(), 7);
        loose.attach_event_cluster(&cfg, ClusterParams::default(), 7);
        let (s_sla, l_sla) = (strict.substrate_sla().unwrap(), loose.substrate_sla().unwrap());
        assert!(
            (l_sla / s_sla - 4.0).abs() < 1e-9,
            "substrate bounds must scale with the tenant SLA: {s_sla} vs {l_sla}"
        );
        assert!((s_sla - ClusterParams::default().sla_latency).abs() < 1e-12);

        // analytical substrates share one latency unit per the rescale,
        // so the two tenants *measure* identically while only the
        // audit bound differs: the looser contract can never see more
        // violations than the strict one
        let mut strict = Tenant::new(0, mk("strict-a", cfg.sla.l_max), Arc::clone(&model), &cfg);
        let mut loose = Tenant::new(1, mk("loose-a", cfg.sla.l_max * 4.0), model, &cfg);
        strict.attach_analytical(&cfg, ClusterParams::default());
        loose.attach_analytical(&cfg, ClusterParams::default());
        let (mut sv, mut lv) = (0usize, 0usize);
        for tick in 0..30 {
            let a = strict.serve(tick);
            let b = loose.serve(tick);
            assert!(
                (a.latency - b.latency).abs() <= 1e-6 * a.latency.abs().max(1e-6),
                "analytical measurements must share one unit: {} vs {}",
                a.latency,
                b.latency
            );
            sv += a.violation.any() as usize;
            lv += b.violation.any() as usize;
        }
        assert!(lv <= sv, "loose SLA violated more ({lv}) than strict ({sv})");
    }

    #[test]
    fn event_backed_tenant_matches_sampling_measurements() {
        let (cfg, model) = fixture();
        let spec = |name: &str| {
            TenantSpec::from_config(&cfg, name, PriorityClass::Gold, TraceBuilder::paper(&cfg))
        };
        let mut sampling = Tenant::new(0, spec("sampling"), Arc::clone(&model), &cfg);
        sampling.attach_cluster(&cfg, ClusterParams::default(), 7);
        let mut event = Tenant::new(1, spec("event"), model, &cfg);
        event.attach_event_cluster(&cfg, ClusterParams::default(), 7);
        // same seed, same trace, no reconfigurations: below the
        // sampling cap the two engines measure identically
        for tick in 0..5 {
            let a = sampling.serve(tick);
            let b = event.serve(tick);
            assert!((a.latency - b.latency).abs() <= 1e-6 * a.latency.abs().max(1.0));
            assert!((a.throughput - b.throughput).abs() <= 1e-3 * a.throughput.abs().max(1.0));
        }
    }

    /// A serverless tenant at the cheapest feasible config — the state
    /// an idle tenant drifts into before suspension becomes attractive.
    fn serverless_tenant(trace: Trace) -> Tenant {
        let (cfg, model) = fixture();
        let spec = TenantSpec {
            start: Configuration::new(0, 1),
            ..TenantSpec::from_config(&cfg, "sv", PriorityClass::Gold, trace)
        };
        let mut t = Tenant::new(0, spec, model, &cfg);
        t.enable_serverless(ServerlessParams::default(), 2.0);
        t
    }

    #[test]
    fn idle_serverless_tenant_proposes_suspend_then_drains() {
        let (cfg, _) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        let mut t = serverless_tenant(b.spike(0.0, 30.0, 10, 3, 20));
        let storage = t.storage_cost();
        assert!(storage > 0.0);
        let mut suspended_at = None;
        for tick in 0..6 {
            t.serve(tick);
            let p = t.propose(tick, None);
            if let Some(best) = p.best().copied() {
                if best.to == t.current() && (best.cost_to - storage).abs() < 1e-6 {
                    assert!(p.cost_delta() <= 0.0, "suspend must be a shrink");
                    assert!(best.gain > 0.0, "claimed savings are the released compute");
                    t.apply(best.to);
                    suspended_at = Some(tick);
                    break;
                }
                t.apply(best.to);
            }
        }
        let at = suspended_at.expect("idle tenant never proposed suspension");
        assert_eq!(t.lifecycle(), Some(Lifecycle::Draining));
        // the draining tick costs storage only, then the tenant sleeps
        let rec = t.serve(at + 1);
        assert!((rec.cost - storage).abs() < 1e-6, "drain cost {}", rec.cost);
        assert!(!rec.violation.any());
        assert_eq!(t.lifecycle(), Some(Lifecycle::Suspended));
        assert!((t.cost() - storage).abs() < 1e-6);
        assert_eq!(t.serverless().unwrap().suspends, 1);
    }

    #[test]
    fn suspended_tenant_wakes_as_an_emergency_repair() {
        let (cfg, model) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        let spec = TenantSpec {
            start: Configuration::new(0, 1),
            ..TenantSpec::from_config(
                &cfg,
                "sv",
                PriorityClass::Gold,
                b.spike(0.0, 30.0, 4, 3, 20),
            )
        };
        let mut t = Tenant::new(0, spec, Arc::clone(&model), &cfg);
        t.enable_serverless(ServerlessParams::default(), 2.0);
        let storage = t.storage_cost();
        t.serverless.as_mut().unwrap().lifecycle = Lifecycle::Suspended;
        t.serve(3);
        assert!(!t.propose(3, None).is_move(), "no wake without demand");
        // tick 4: the burst arrives — serving nothing violates, and the
        // proposal is an emergency wake to a clearing configuration
        let rec = t.serve(4);
        assert_eq!(rec.throughput, 0.0);
        assert!(rec.violation.throughput, "unserved demand must violate");
        assert!((rec.cost - storage).abs() < 1e-6);
        let p = t.propose(4, None);
        assert!(p.emergency && p.is_repair());
        let best = p.best().copied().unwrap();
        let lambda = t.workload_at(4).lambda_req;
        // the wake target clears the observed load outright
        // (re-provisioning is not neighbor-constrained)
        assert!(model.latency(&best.to) <= t.sla().l_max);
        assert!(model.throughput(&best.to) >= lambda);
        assert!((best.cost_to - (model.cost(&best.to) + storage)).abs() < 1e-6);
        // actuate the wake the way the fleet does
        t.apply(best.to);
        t.begin_resume(7);
        assert_eq!(t.lifecycle(), Some(Lifecycle::Resuming { until: 7 }));
        // cold-starting: compute is paid for but nothing serves yet
        let rec = t.serve(5);
        assert_eq!(rec.throughput, 0.0);
        assert!((rec.cost - (model.cost(&best.to) + storage)).abs() < 1e-6);
        assert!(!t.propose(5, None).is_move(), "no moves inside the cold-start window");
        t.finish_resume();
        assert_eq!(t.lifecycle(), Some(Lifecycle::Active));
        let rec = t.serve(6);
        assert!(rec.throughput > 0.0, "resumed tenant serves again");
        assert_eq!(t.serverless().unwrap().resumes, 1);
    }

    #[test]
    fn serverless_cost_tracks_lifecycle() {
        let (cfg, _) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        let mut t = serverless_tenant(b.constant(0.0, 10));
        let storage = t.storage_cost();
        let active = t.cost();
        assert!(active > storage, "active pays compute on top of storage");
        for lc in [Lifecycle::Draining, Lifecycle::Suspended] {
            t.serverless.as_mut().unwrap().lifecycle = lc;
            assert!((t.cost() - storage).abs() < 1e-6, "{lc:?}");
        }
        t.serverless.as_mut().unwrap().lifecycle = Lifecycle::Resuming { until: 3 };
        assert!((t.cost() - active).abs() < 1e-6, "resuming pays full freight");
    }

    #[test]
    fn suspension_archives_the_latency_segment() {
        let (cfg, _) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        let mut t = serverless_tenant(b.constant(30.0, 10));
        for tick in 0..5 {
            t.serve(tick);
        }
        let before = t.merged_histogram().len();
        assert!(before > 0, "active ticks must record latencies");
        t.serverless.as_mut().unwrap().pending_suspend = true;
        t.apply(t.current());
        assert_eq!(t.lifecycle(), Some(Lifecycle::Draining));
        assert_eq!(t.merged_histogram().len(), before, "history survives suspension");
        // wake up and keep serving: the merged view spans both segments
        t.serverless.as_mut().unwrap().lifecycle = Lifecycle::Active;
        for tick in 5..10 {
            t.serve(tick);
        }
        assert!(t.merged_histogram().len() > before);
    }
}
