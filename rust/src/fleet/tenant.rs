//! A fleet tenant: one database cluster with its own Scaling-Plane
//! state, SLA contract, phase-shifted demand trace, and Algorithm-1
//! policy, plus the admission bookkeeping the budget arbiter needs
//! (per-tick proposals, denial streaks, violation state).
//!
//! Tenants share one [`SurfaceModel`] (the plane geometry and surface
//! constants are fleet-wide), so adding a tenant costs state, not model
//! construction — the fleet bench leans on this.
//!
//! A tenant can optionally be backed by any boxed
//! [`Substrate`] — the sampling [`ClusterSim`], the event-driven
//! [`EventSim`], or an analytical wrapper — and substrates of
//! different kinds mix freely within one fleet run.

use std::sync::Arc;

use crate::cluster::{ClusterParams, ClusterSim, EventSim, Substrate};
use crate::config::ModelConfig;
use crate::metrics::{Recorder, StepRecord, Summary};
use crate::plane::Configuration;
use crate::policy::{DiagonalScale, Policy, PolicyContext};
use crate::sla::{SlaSpec, Violation};
use crate::surfaces::SurfaceModel;
use crate::workload::{Trace, WorkloadPoint};
use crate::INFEASIBLE;

/// Admission priority of a tenant. Ties in the arbiter's knapsack break
/// toward the higher class (`Bronze < Silver < Gold`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PriorityClass {
    Bronze,
    Silver,
    Gold,
}

impl PriorityClass {
    /// All classes, highest priority first.
    pub const ALL: [PriorityClass; 3] =
        [PriorityClass::Gold, PriorityClass::Silver, PriorityClass::Bronze];

    pub fn label(&self) -> &'static str {
        match self {
            PriorityClass::Gold => "gold",
            PriorityClass::Silver => "silver",
            PriorityClass::Bronze => "bronze",
        }
    }

    /// Numeric rank; higher admits first.
    pub fn rank(&self) -> u8 {
        match self {
            PriorityClass::Gold => 2,
            PriorityClass::Silver => 1,
            PriorityClass::Bronze => 0,
        }
    }
}

/// Static description of one tenant.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub class: PriorityClass,
    pub sla: SlaSpec,
    pub trace: Trace,
    pub start: Configuration,
}

impl TenantSpec {
    /// Spec with the model-config defaults for SLA and start config.
    pub fn from_config(
        cfg: &ModelConfig,
        name: impl Into<String>,
        class: PriorityClass,
        trace: Trace,
    ) -> Self {
        Self {
            name: name.into(),
            class,
            sla: SlaSpec::from_config(cfg),
            trace,
            start: Configuration::new(cfg.policy.start[0], cfg.policy.start[1]),
        }
    }
}

/// One tenant's proposed move for a tick, as the arbiter sees it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Proposal {
    pub tenant: usize,
    pub class: PriorityClass,
    pub from: Configuration,
    pub to: Configuration,
    /// Hourly cost of the configuration currently serving.
    pub cost_from: f32,
    /// Hourly cost of the proposed configuration.
    pub cost_to: f32,
    /// Objective improvement the move claims (positive = better).
    pub gain: f32,
    /// SLA emergency: the Algorithm-1 fallback fired, or the current
    /// configuration is planner-infeasible for this tick's demand.
    pub emergency: bool,
    /// The tenant's last served step violated its SLA.
    pub sla_violating: bool,
    /// Consecutive ticks this tenant has been denied while
    /// SLA-violating (the fairness guard's counter).
    pub denial_streak: usize,
}

impl Proposal {
    /// Marginal fleet cost of admitting this move.
    pub fn cost_delta(&self) -> f32 {
        self.cost_to - self.cost_from
    }

    /// Whether the proposal changes the configuration at all.
    pub fn is_move(&self) -> bool {
        self.to != self.from
    }

    /// Greedy-knapsack value density: claimed gain per added dollar.
    /// SLA emergencies outrank any economic move.
    pub fn density(&self) -> f32 {
        if self.emergency {
            return INFEASIBLE;
        }
        self.gain / self.cost_delta().max(1e-6)
    }
}

/// Runtime state of one tenant cluster.
pub struct Tenant {
    pub id: usize,
    spec: TenantSpec,
    model: Arc<SurfaceModel>,
    policy: DiagonalScale,
    current: Configuration,
    recorder: Recorder,
    recording: bool,
    last_violation: bool,
    /// Consecutive denials while SLA-violating (fairness counter).
    pub denial_streak: usize,
    pub max_denial_streak: usize,
    pub denied_total: usize,
    pub rescued_total: usize,
    /// Rescue attempts the arbiter could not afford (the move did not
    /// fit the budget left after cost cuts and more-starved rescues).
    pub rescue_unaffordable_total: usize,
    reb_h: f32,
    reb_v: f32,
    plan_queue: bool,
    /// Optional physical substrate backing this tenant (any engine).
    substrate: Option<Box<dyn Substrate + Send>>,
}

impl Tenant {
    pub fn new(id: usize, spec: TenantSpec, model: Arc<SurfaceModel>, cfg: &ModelConfig) -> Self {
        assert!(!spec.trace.is_empty(), "tenant {} has an empty trace", spec.name);
        assert!(model.plane().contains(&spec.start), "tenant start outside plane");
        let current = spec.start;
        Self {
            id,
            spec,
            model,
            policy: DiagonalScale::diagonal(),
            current,
            recorder: Recorder::new(),
            recording: true,
            last_violation: false,
            denial_streak: 0,
            max_denial_streak: 0,
            denied_total: 0,
            rescued_total: 0,
            rescue_unaffordable_total: 0,
            reb_h: cfg.policy.reb_h,
            reb_v: cfg.policy.reb_v,
            plan_queue: cfg.policy.plan_queue,
            substrate: None,
        }
    }

    /// Back this tenant with a boxed substrate (any engine); metrics
    /// then come from measurement, not the model. The substrate is
    /// fast-forwarded to the tenant's current configuration.
    pub fn attach_substrate(&mut self, mut sub: Box<dyn Substrate + Send>) {
        if sub.current() != self.current {
            sub.apply(self.current);
        }
        self.substrate = Some(sub);
    }

    /// Back this tenant with its own sampling-engine cluster
    /// (per-tenant [`ClusterSim`], mirroring the single-cluster
    /// coordinator).
    pub fn attach_cluster(&mut self, cfg: &ModelConfig, params: ClusterParams, seed: u64) {
        self.attach_substrate(Box::new(ClusterSim::new(cfg, params, seed)));
    }

    /// Back this tenant with its own event-driven cluster
    /// ([`EventSim`] — the bench-speed engine for large fleets).
    pub fn attach_event_cluster(&mut self, cfg: &ModelConfig, params: ClusterParams, seed: u64) {
        self.attach_substrate(Box::new(EventSim::new(cfg, params, seed)));
    }

    /// Back this tenant with an analytical substrate built from the
    /// fleet-shared surface model and audited against *this tenant's*
    /// SLA latency bound.
    pub fn attach_analytical(&mut self, params: ClusterParams) {
        self.attach_substrate(Box::new(crate::simulator::AnalyticalSubstrate::from_model(
            (*self.model).clone(),
            params,
            self.current,
            self.spec.sla.l_max,
        )));
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn class(&self) -> PriorityClass {
        self.spec.class
    }

    pub fn sla(&self) -> &SlaSpec {
        &self.spec.sla
    }

    pub fn trace(&self) -> &Trace {
        &self.spec.trace
    }

    pub fn current(&self) -> Configuration {
        self.current
    }

    /// Hourly cost of the configuration currently serving.
    pub fn cost(&self) -> f32 {
        self.model.cost(&self.current)
    }

    /// The tenant's last served step violated its SLA.
    pub fn violating(&self) -> bool {
        self.last_violation
    }

    pub fn set_recording(&mut self, on: bool) {
        self.recording = on;
    }

    pub fn records(&self) -> &[StepRecord] {
        self.recorder.records()
    }

    pub fn summary(&self) -> Summary {
        self.recorder.summary()
    }

    /// Demand at fleet tick `t` (traces repeat cyclically).
    pub fn workload_at(&self, t: usize) -> WorkloadPoint {
        self.spec.trace.points[t % self.spec.trace.len()]
    }

    /// Serve tick `t` at the carried-in configuration and record the
    /// step (serve-then-move, mirroring [`crate::simulator::Simulator`]).
    pub fn serve(&mut self, t: usize) -> StepRecord {
        let w = self.workload_at(t);
        let rec = match &mut self.substrate {
            None => {
                let point = self.model.evaluate(&self.current, w.lambda_req);
                let lat_eff = self.model.effective_latency(&self.current, w.lambda_req);
                let obj_eff = self.model.effective_objective(&self.current, w.lambda_req);
                StepRecord {
                    step: t,
                    config: self.current,
                    lambda_req: w.lambda_req,
                    latency: lat_eff,
                    latency_raw: point.latency,
                    throughput: point.throughput,
                    cost: point.cost,
                    objective: obj_eff,
                    violation: self.spec.sla.audit(
                        point.latency,
                        point.throughput,
                        w.lambda_req,
                    ),
                }
            }
            Some(sim) => {
                let m = sim.step(w);
                let point = self.model.evaluate(&self.current, w.lambda_req);
                StepRecord {
                    step: t,
                    config: self.current,
                    lambda_req: w.lambda_req,
                    latency: m.p99_latency as f32,
                    latency_raw: point.latency,
                    throughput: m.completed as f32,
                    cost: point.cost,
                    objective: self.model.effective_objective(&self.current, w.lambda_req),
                    violation: Violation {
                        latency: m.p99_latency > sim.params().sla_latency,
                        throughput: m.completed < m.offered * 0.999,
                    },
                }
            }
        };
        self.last_violation = rec.violation.any();
        if self.recording {
            self.recorder.push(rec);
        }
        rec
    }

    /// The tenant's best local move for tick `t`, packaged for the
    /// arbiter. The policy is the paper's DIAGONALSCALE; the claimed
    /// gain is the score improvement over holding still.
    pub fn propose(&mut self, t: usize) -> Proposal {
        let w = self.workload_at(t);
        // field-disjoint borrows: the context reads model/spec while the
        // policy below needs `&mut self.policy`
        let ctx = PolicyContext {
            model: self.model.as_ref(),
            sla: &self.spec.sla,
            reb_h: self.reb_h,
            reb_v: self.reb_v,
            plan_queue: self.plan_queue,
            future: &[],
        };
        let current_feasible =
            self.model
                .feasible(&self.current, w.lambda_req, &self.spec.sla, self.plan_queue);
        let current_score = if self.plan_queue {
            self.model.effective_objective(&self.current, w.lambda_req)
        } else {
            self.model.evaluate(&self.current, w.lambda_req).objective
        };
        let d = self.policy.decide(self.current, w, &ctx);
        let gain = if d.fallback { 0.0 } else { current_score - d.score };
        Proposal {
            tenant: self.id,
            class: self.spec.class,
            from: self.current,
            to: d.next,
            cost_from: self.model.cost(&self.current),
            cost_to: self.model.cost(&d.next),
            gain,
            emergency: d.fallback || !current_feasible,
            sla_violating: self.last_violation,
            denial_streak: self.denial_streak,
        }
    }

    /// Actuate an admitted move (resets the fairness counter).
    pub fn apply(&mut self, to: Configuration) {
        assert!(self.model.plane().contains(&to));
        if let Some(sim) = &mut self.substrate {
            if to != self.current {
                sim.apply(to);
            }
        }
        self.current = to;
        self.denial_streak = 0;
    }

    /// The tenant proposed no change this tick.
    pub fn note_no_move(&mut self) {
        self.denial_streak = 0;
    }

    /// The arbiter denied this tick's move.
    pub fn note_denied(&mut self) {
        self.denied_total += 1;
        if self.last_violation {
            self.denial_streak += 1;
            self.max_denial_streak = self.max_denial_streak.max(self.denial_streak);
        } else {
            self.denial_streak = 0;
        }
    }

    /// The fairness guard fired but the move did not fit the budget
    /// left after cost cuts and more-starved rescues.
    pub fn note_rescue_unaffordable(&mut self) {
        self.rescue_unaffordable_total += 1;
        self.note_denied();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceBuilder;

    fn fixture() -> (ModelConfig, Arc<SurfaceModel>) {
        let cfg = ModelConfig::default_paper();
        let model = Arc::new(SurfaceModel::from_config(&cfg));
        (cfg, model)
    }

    fn tenant(class: PriorityClass) -> Tenant {
        let (cfg, model) = fixture();
        let spec = TenantSpec::from_config(&cfg, "t0", class, TraceBuilder::paper(&cfg));
        Tenant::new(0, spec, model, &cfg)
    }

    #[test]
    fn class_order_and_rank_agree() {
        assert!(PriorityClass::Bronze < PriorityClass::Silver);
        assert!(PriorityClass::Silver < PriorityClass::Gold);
        assert!(PriorityClass::Gold.rank() > PriorityClass::Bronze.rank());
        assert_eq!(PriorityClass::ALL[0], PriorityClass::Gold);
    }

    #[test]
    fn serve_records_cost_of_current_config() {
        let mut t = tenant(PriorityClass::Gold);
        let rec = t.serve(0);
        assert_eq!(rec.config, t.current());
        assert!((rec.cost - t.cost()).abs() < 1e-6);
        assert_eq!(t.records().len(), 1);
    }

    #[test]
    fn proposal_is_a_neighbor_with_consistent_costs() {
        let mut t = tenant(PriorityClass::Silver);
        for tick in 0..50 {
            t.serve(tick);
            let p = t.propose(tick);
            let (dh, dv) = p.from.index_distance(&p.to);
            assert!(dh <= 1 && dv <= 1);
            assert!((p.cost_delta() - (p.cost_to - p.cost_from)).abs() < 1e-6);
            t.apply(p.to);
        }
    }

    #[test]
    fn gain_nonnegative_when_current_feasible() {
        let (cfg, model) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        // lambda 3000 at (H=2, medium): T ≈ 3988 ≥ 3000 * 1.15 — feasible
        let spec = TenantSpec::from_config(
            &cfg,
            "calm",
            PriorityClass::Gold,
            b.constant(30.0, 10),
        );
        let mut t = Tenant::new(0, spec, model, &cfg);
        t.serve(0);
        let p = t.propose(0);
        assert!(!p.emergency);
        assert!(p.gain >= 0.0, "gain={}", p.gain);
    }

    #[test]
    fn emergency_flagged_when_infeasible() {
        let (cfg, model) = fixture();
        let b = TraceBuilder::from_config(&cfg);
        let spec = TenantSpec {
            start: Configuration::new(0, 0),
            ..TenantSpec::from_config(&cfg, "hot", PriorityClass::Bronze, b.constant(160.0, 10))
        };
        let mut t = Tenant::new(0, spec, model, &cfg);
        t.serve(0);
        let p = t.propose(0);
        assert!(p.emergency);
        assert_eq!(p.density(), INFEASIBLE);
    }

    #[test]
    fn denial_streak_counts_only_while_violating() {
        let mut t = tenant(PriorityClass::Bronze);
        t.last_violation = true;
        t.note_denied();
        t.note_denied();
        assert_eq!(t.denial_streak, 2);
        t.last_violation = false;
        t.note_denied();
        assert_eq!(t.denial_streak, 0);
        assert_eq!(t.denied_total, 3);
        assert_eq!(t.max_denial_streak, 2);
    }

    #[test]
    fn apply_resets_streak() {
        let mut t = tenant(PriorityClass::Bronze);
        t.last_violation = true;
        t.note_denied();
        assert_eq!(t.denial_streak, 1);
        t.apply(Configuration::new(2, 2));
        assert_eq!(t.denial_streak, 0);
        assert_eq!(t.current(), Configuration::new(2, 2));
    }

    #[test]
    fn recording_off_keeps_no_records() {
        let mut t = tenant(PriorityClass::Gold);
        t.set_recording(false);
        for tick in 0..20 {
            t.serve(tick);
        }
        assert!(t.records().is_empty());
    }

    #[test]
    fn cluster_backed_tenant_measures() {
        let (cfg, model) = fixture();
        let spec =
            TenantSpec::from_config(&cfg, "des", PriorityClass::Gold, TraceBuilder::paper(&cfg));
        let mut t = Tenant::new(0, spec, model, &cfg);
        t.attach_cluster(&cfg, ClusterParams::default(), 7);
        let rec = t.serve(0);
        // measured latency comes from the DES, not the analytical model
        assert!(rec.latency > 0.0);
        assert!(rec.throughput > 0.0);
    }

    #[test]
    fn event_backed_tenant_matches_sampling_measurements() {
        let (cfg, model) = fixture();
        let spec = |name: &str| {
            TenantSpec::from_config(&cfg, name, PriorityClass::Gold, TraceBuilder::paper(&cfg))
        };
        let mut sampling = Tenant::new(0, spec("sampling"), Arc::clone(&model), &cfg);
        sampling.attach_cluster(&cfg, ClusterParams::default(), 7);
        let mut event = Tenant::new(1, spec("event"), model, &cfg);
        event.attach_event_cluster(&cfg, ClusterParams::default(), 7);
        // same seed, same trace, no reconfigurations: below the
        // sampling cap the two engines measure identically
        for tick in 0..5 {
            let a = sampling.serve(tick);
            let b = event.serve(tick);
            assert!((a.latency - b.latency).abs() <= 1e-6 * a.latency.abs().max(1.0));
            assert!((a.throughput - b.throughput).abs() <= 1e-3 * a.throughput.abs().max(1.0));
        }
    }
}
