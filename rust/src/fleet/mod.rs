//! Multi-tenant fleet control: N tenant databases scaling concurrently
//! under a shared monetary budget — the first cross-cluster layer on
//! the road from the paper's single-cluster optimizer to a
//! production-scale service.
//!
//! Every existing layer composes N-way behind this API: each
//! [`Tenant`] owns a Scaling-Plane position, an [`crate::sla::SlaSpec`],
//! a phase-shifted [`crate::workload::Trace`], and the paper's
//! DIAGONALSCALE policy (optionally backed by any boxed
//! [`crate::cluster::Substrate`] — sampling, event-driven, or
//! analytical engines mix within one fleet); the [`BudgetArbiter`] admits the
//! per-tick moves via greedy knapsack over marginal cost with priority
//! classes and a starvation guard; [`report`] aggregates fleet-level
//! metrics (per-class p95, total cost, denial counts).
//!
//! Tick semantics are serve-then-move, exactly like
//! [`crate::simulator::Simulator`]: the configuration carried into tick
//! *t* serves demand *t*; admitted moves take effect at *t + 1*. The
//! budget invariant follows: projected spend after admission **is**
//! the next tick's spend, so fleet spend never exceeds the budget once
//! under it.

pub mod arbiter;
pub mod report;
pub mod tenant;

pub use arbiter::{Admission, BudgetArbiter, Verdict};
pub use report::{ClassReport, FleetReport, TenantReport};
pub use tenant::{PriorityClass, Proposal, Tenant, TenantSpec};

use std::sync::Arc;

use crate::cluster::{ClusterParams, SubstrateKind};
use crate::config::ModelConfig;
use crate::simulator::build_substrate;
use crate::surfaces::SurfaceModel;

/// Tolerance for float drift when comparing fleet spend to the budget
/// (spend is re-summed per tick; the arbiter sums base + deltas).
pub const BUDGET_EPS: f32 = 1e-3;

/// One tick's fleet-level outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetTick {
    pub step: usize,
    /// Σ hourly cost of the configurations that served this tick.
    pub spend: f32,
    /// Projected spend once the admitted moves take effect (== next
    /// tick's spend).
    pub projected_spend: f32,
    pub admitted_moves: usize,
    pub denied_moves: usize,
    pub rescues: usize,
    pub rescue_denials: usize,
}

/// A complete fleet run: the per-tick timeline plus the final report.
#[derive(Debug, Clone)]
pub struct FleetResult {
    pub ticks: Vec<FleetTick>,
    pub report: FleetReport,
}

impl FleetResult {
    /// Highest per-tick spend observed.
    pub fn peak_spend(&self) -> f32 {
        self.report.peak_spend
    }

    /// Whether every tick stayed within the budget.
    pub fn within_budget(&self, budget: f32) -> bool {
        self.peak_spend() <= budget + BUDGET_EPS
    }
}

/// Drives N tenants and the budget arbiter over their traces.
pub struct FleetSimulator {
    tenants: Vec<Tenant>,
    arbiter: BudgetArbiter,
    step: usize,
}

impl FleetSimulator {
    /// Build a fleet. All tenants share one [`SurfaceModel`] (the plane
    /// geometry and surface constants are fleet-wide), so construction
    /// cost is independent of tenant count.
    pub fn new(
        cfg: &ModelConfig,
        specs: Vec<TenantSpec>,
        budget: f32,
        fairness_k: usize,
    ) -> Self {
        assert!(!specs.is_empty(), "fleet needs at least one tenant");
        let model = Arc::new(SurfaceModel::from_config(cfg));
        let tenants = specs
            .into_iter()
            .enumerate()
            .map(|(i, s)| Tenant::new(i, s, Arc::clone(&model), cfg))
            .collect();
        Self { tenants, arbiter: BudgetArbiter::new(budget, fairness_k), step: 0 }
    }

    /// Back every tenant with its own sampling-engine cluster (seeded
    /// per tenant for reproducibility).
    pub fn attach_clusters(&mut self, cfg: &ModelConfig, params: ClusterParams, seed: u64) {
        self.attach_substrates(cfg, params, seed, SubstrateKind::Sampling);
    }

    /// Back every tenant with a substrate of the given kind (seeded per
    /// tenant). [`SubstrateKind::Des`] is the bench-speed choice for
    /// large fleets. Analytical tenants reuse the fleet-shared surface
    /// model and their own SLA bound; all kinds emit latencies on the
    /// substrate scale, so fleet reports aggregate one unit.
    pub fn attach_substrates(
        &mut self,
        cfg: &ModelConfig,
        params: ClusterParams,
        seed: u64,
        kind: SubstrateKind,
    ) {
        self.attach_mixed_substrates(cfg, params, seed, |_| kind);
    }

    /// Back each tenant with the substrate kind chosen per tenant id —
    /// analytical, sampling, and event-driven tenants mix in one run.
    pub fn attach_mixed_substrates(
        &mut self,
        cfg: &ModelConfig,
        params: ClusterParams,
        seed: u64,
        choose: impl Fn(usize) -> SubstrateKind,
    ) {
        for t in &mut self.tenants {
            match choose(t.id) {
                SubstrateKind::Analytical => t.attach_analytical(params),
                kind => t.attach_substrate(build_substrate(
                    kind,
                    cfg,
                    params,
                    seed.wrapping_add(t.id as u64),
                )),
            }
        }
    }

    /// Disable per-step recording (benchmark mode: bounded memory).
    pub fn set_recording(&mut self, on: bool) {
        for t in &mut self.tenants {
            t.set_recording(on);
        }
    }

    pub fn arbiter(&self) -> &BudgetArbiter {
        &self.arbiter
    }

    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }

    /// Current fleet spend (Σ hourly cost of serving configurations).
    pub fn spend(&self) -> f32 {
        self.tenants.iter().map(Tenant::cost).sum()
    }

    /// Longest tenant trace (the natural run length).
    pub fn longest_trace(&self) -> usize {
        self.tenants.iter().map(|t| t.trace().len()).max().unwrap_or(0)
    }

    /// One fleet tick: every tenant serves, proposes; the arbiter
    /// admits under the budget; admitted moves actuate for next tick.
    pub fn tick(&mut self) -> FleetTick {
        let t = self.step;
        let mut spend = 0.0f32;
        for tn in &mut self.tenants {
            spend += tn.serve(t).cost;
        }

        let proposals: Vec<Proposal> =
            self.tenants.iter_mut().map(|tn| tn.propose(t)).collect();
        let adm = self.arbiter.admit(&proposals);

        for (p, v) in proposals.iter().zip(&adm.verdicts) {
            let tn = &mut self.tenants[p.tenant];
            match v {
                Verdict::Hold => tn.note_no_move(),
                Verdict::AdmittedShrink | Verdict::Admitted => tn.apply(p.to),
                Verdict::AdmittedRescue => {
                    tn.rescued_total += 1;
                    tn.apply(p.to);
                }
                Verdict::DeniedBudget => tn.note_denied(),
                Verdict::DeniedRescueUnaffordable => tn.note_rescue_unaffordable(),
            }
        }

        self.step += 1;
        FleetTick {
            step: t,
            spend,
            projected_spend: adm.projected_spend,
            admitted_moves: adm.admitted_moves,
            denied_moves: adm.denied_moves,
            rescues: adm.rescues,
            rescue_denials: adm.rescue_denials,
        }
    }

    /// Run `steps` ticks (traces repeat cyclically) and aggregate.
    pub fn run(&mut self, steps: usize) -> FleetResult {
        let ticks: Vec<FleetTick> = (0..steps).map(|_| self.tick()).collect();
        let report = report::fleet_report(&self.tenants, &ticks, self.arbiter.budget);
        FleetResult { ticks, report }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceBuilder;

    fn specs(cfg: &ModelConfig, n: usize) -> Vec<TenantSpec> {
        let base = TraceBuilder::paper(cfg);
        (0..n)
            .map(|i| {
                let class = match i % 3 {
                    0 => PriorityClass::Gold,
                    1 => PriorityClass::Silver,
                    _ => PriorityClass::Bronze,
                };
                TenantSpec::from_config(
                    cfg,
                    format!("t{i}"),
                    class,
                    base.shifted(i * base.len() / n.max(1)),
                )
            })
            .collect()
    }

    #[test]
    fn generous_budget_never_denies() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 4), 1.0e6, 3);
        let res = fleet.run(50);
        assert!(res.ticks.iter().all(|t| t.denied_moves == 0));
        assert!(res.within_budget(1.0e6));
    }

    #[test]
    fn spend_stays_within_budget_every_tick() {
        let cfg = ModelConfig::default_paper();
        let budget = 8.0f32; // tight: unconstrained peaks exceed this
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 6), budget, 3);
        let res = fleet.run(100);
        assert!(res.within_budget(budget), "peak {}", res.peak_spend());
        // contention must actually bite for the test to mean anything
        assert!(res.ticks.iter().any(|t| t.denied_moves > 0));
    }

    #[test]
    fn projected_spend_is_next_ticks_spend() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 5), 9.0, 3);
        let res = fleet.run(60);
        for w in res.ticks.windows(2) {
            assert!(
                (w[0].projected_spend - w[1].spend).abs() < 1e-3,
                "projected {} vs served {}",
                w[0].projected_spend,
                w[1].spend
            );
        }
    }

    #[test]
    fn constrained_fleet_never_outperforms_unconstrained_on_spend() {
        let cfg = ModelConfig::default_paper();
        let mut free = FleetSimulator::new(&cfg, specs(&cfg, 6), 1.0e6, 3);
        let free_res = free.run(50);
        let budget = free_res.peak_spend() * 0.7;
        let mut tight = FleetSimulator::new(&cfg, specs(&cfg, 6), budget, 3);
        let tight_res = tight.run(50);
        assert!(tight_res.peak_spend() <= budget + 1e-3);
        assert!(tight_res.peak_spend() < free_res.peak_spend());
    }

    #[test]
    fn deterministic() {
        let cfg = ModelConfig::default_paper();
        let a = FleetSimulator::new(&cfg, specs(&cfg, 4), 7.0, 3).run(50);
        let b = FleetSimulator::new(&cfg, specs(&cfg, 4), 7.0, 3).run(50);
        assert_eq!(a.ticks, b.ticks);
    }

    #[test]
    fn cluster_backed_fleet_runs() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 3), 1.0e6, 3);
        fleet.attach_clusters(&cfg, ClusterParams::default(), 42);
        let res = fleet.run(20);
        assert_eq!(res.ticks.len(), 20);
        // measured throughput flows into the summaries
        assert!(res.report.tenants.iter().all(|t| t.summary.avg_throughput > 0.0));
    }

    #[test]
    fn event_backed_fleet_runs() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 6), 1.0e6, 3);
        fleet.attach_substrates(&cfg, ClusterParams::default(), 42, SubstrateKind::Des);
        let res = fleet.run(20);
        assert_eq!(res.ticks.len(), 20);
        assert!(res.report.tenants.iter().all(|t| t.summary.avg_throughput > 0.0));
    }

    #[test]
    fn mixed_substrate_fleet_runs_in_one_pass() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 6), 1.0e6, 3);
        fleet.attach_mixed_substrates(&cfg, ClusterParams::default(), 42, |id| match id % 3 {
            0 => SubstrateKind::Analytical,
            1 => SubstrateKind::Sampling,
            _ => SubstrateKind::Des,
        });
        let res = fleet.run(20);
        assert_eq!(res.ticks.len(), 20);
        assert!(res.report.tenants.iter().all(|t| t.summary.avg_throughput > 0.0));
    }
}
