//! Multi-tenant fleet control: N tenant databases scaling concurrently
//! under a shared monetary budget — the first cross-cluster layer on
//! the road from the paper's single-cluster optimizer to a
//! production-scale service.
//!
//! Every existing layer composes N-way behind this API: each
//! [`Tenant`] owns a Scaling-Plane position, an [`crate::sla::SlaSpec`],
//! a phase-shifted [`crate::workload::Trace`], and the paper's
//! DIAGONALSCALE policy — optionally upgraded to forecast-driven
//! lookahead per tenant ([`FleetSimulator::enable_forecasts`]) and
//! optionally backed by any boxed [`crate::cluster::Substrate`]
//! (sampling, event-driven, or analytical engines mix within one
//! fleet).
//!
//! ## Admission: a two-sided negotiation (PR 3)
//!
//! Admission is no longer a one-shot filter. Each tick the fleet hands
//! every tenant a [`BudgetHint`] (remaining fleet headroom plus its
//! class-envelope headroom) so the policy shapes its proposal to what
//! is affordable; tenants answer with *ranked candidate lists* (best
//! move, cheaper feasible alternatives, stepping stones toward an SLA
//! repair) plus *shed offers* (feasible downgrades a non-violating
//! tenant volunteers). The [`BudgetArbiter`] walks each list so a
//! tenant whose first choice does not fit degrades to its cheapest
//! feasible improvement instead of being denied, actuates sheds to
//! fund SLA repairs (online budget re-negotiation), freezes economic
//! upgrades while any repair is starving, and confines discretionary
//! spending to per-class envelopes with burst credits
//! ([`ClassEnvelopes`], [`arbiter::BURST_FRACTION`]).
//! [`BudgetArbiter::flat`] keeps the PR-2 flat-denial baseline; the
//! tests pin that planning strictly reduces SLA-violation ticks
//! against it on the contended 6-tenant scenario at the same budget.
//!
//! Since PR 5 the ranked enumeration itself comes from the *policy*
//! ([`crate::policy::Policy::propose`]); [`Tenant::propose`] distills
//! that proposal instead of re-walking the neighborhood, so exactly one
//! enumeration happens per tenant per tick.
//!
//! Tick semantics are serve-then-move, exactly like
//! [`crate::simulator::Simulator`]: the configuration carried into tick
//! *t* serves demand *t*; admitted moves take effect at *t + 1*. The
//! budget invariant follows: projected spend after admission **is**
//! the next tick's spend, so fleet spend never exceeds the budget once
//! under it.
//!
//! ## Serverless tier (PR 6)
//!
//! [`FleetSimulator::enable_serverless`] detaches storage from compute
//! (see [`crate::serverless`]): tenants register their working sets in
//! a shared [`StorageService`] and gain the scale-to-zero lifecycle.
//! Suspension rides the existing pipeline as a pass-0 shrink; wakes are
//! class-ordered emergency repairs whose admitted moves open
//! *cold-start windows* on the fleet's own [`EventCalendar`] — an
//! [`Event::ResumeEnd`] closes each window, and until it fires the
//! tenant pays for compute without serving. The serve-then-move and
//! projected-spend invariants hold unchanged because every lifecycle
//! state prices exactly what the next tick will pay.
//!
//! ## Activity-proportional planning (PR 7)
//!
//! At fleet scale the dominant cost is no longer the resource math but
//! the control loop itself: re-proposing all N tenants and re-sorting
//! all N proposals every tick is O(fleet), even when almost nothing
//! changed. The fleet therefore runs a **dirty queue** by default
//! ([`Self::set_dirty_planning`] to opt out): a tenant whose last
//! proposal was a cacheable hold keeps a `HoldTicket` and *replays* it
//! instead of re-running its policy, for as long as every member of
//! the ticket's **invalidation set** is unchanged —
//!
//! * observed demand (bitwise),
//! * serverless lifecycle, including imminent park-downs,
//! * SLA-violation flag and denial streak (so fairness escalation
//!   still advances),
//! * the budget hint, up to *materiality*: hints whose headroom
//!   exceeds the policy's own maximum candidate cost delta cannot
//!   change its scoring, so they count as equivalent,
//! * the policy and substrate themselves (swapping either dirties the
//!   tenant, as do placement node failures and actuated moves).
//!
//! Every ticket also expires after [`REFRESH_K`] ticks — a mandatory
//! re-propose safety net bounding how long any staleness the set
//! missed can survive. Only holds from pure ([`cacheable`]) policies
//! are ever cached, and the cache may only skip work, never change
//! answers: `tests/prop_dirty.rs` pins the dirty-queue fleet
//! decision-identical (verdicts, configurations, spend trajectory) to
//! an always-replan fleet across wake storms, node failures, and
//! adaptive envelopes. Admission indexes only the proposals that can
//! move (per-class heaps in [`BudgetArbiter`]) and base spend comes
//! from an incrementally maintained [`arbiter::SpendLedger`], so
//! per-tick planning cost tracks the *active* tenant count — the
//! 10240-tenant mostly-idle bench in `benches/fleet.rs` pins it
//! sublinear in fleet size. [`FleetTick::planning_micros`] and
//! [`FleetTick::fresh_proposals`] surface the per-tick cost.
//!
//! [`cacheable`]: crate::policy::Policy::cacheable

pub mod arbiter;
pub mod report;
pub mod tenant;

pub use arbiter::{
    Admission, BudgetArbiter, ClassEnvelopes, EnvelopeAdapter, SpendLedger, Verdict,
};
pub use report::{ClassReport, FleetReport, FleetRollup, TenantReport};
pub use tenant::{
    Candidate, ForecastKind, PriorityClass, Proposal, Tenant, TenantPlanner, TenantSpec,
};

use std::sync::Arc;

use crate::cluster::{ClusterParams, Event, EventCalendar, SubstrateKind};
use crate::config::ModelConfig;
use crate::metrics::{names as metric_names, Hll, HllWindowRing, LatencyHistogram, MetricsRegistry};
use crate::scenario::FaultEvent;
use crate::placement::{PlacementConfig, PlacementSim};
use crate::plane::Configuration;
use crate::policy::BudgetHint;
use crate::serverless::{Lifecycle, ServerlessParams, StorageService};
use crate::surfaces::SurfaceModel;
use crate::util::money;
use crate::workload::XorShift64;

/// Tolerance for float drift when comparing fleet spend to the budget.
/// Spend is re-summed from tenant configurations every tick while the
/// arbiter tracks base + admitted deltas; the two walks accumulate
/// different f32 rounding, so exact comparison would flag phantom
/// overruns. 1e-3 is ~4 orders below the cheapest tier step (0.08/h),
/// so no real overspend can hide inside it. Admission itself compares
/// exactly (no epsilon): the arbiter never *plans* past the budget.
pub const BUDGET_EPS: f32 = 1e-3;

/// Default mandatory re-propose interval for cached holds (ticks): the
/// dirty queue's safety net against invalidation-set gaps. 256 keeps
/// the steady-state refresh load under 0.4% of the fleet per tick —
/// small enough that the 10k-tenant bench's 4× planning-work bound
/// holds with slack — while bounding any missed staleness to ~4 hours
/// of 1-minute ticks. [`FleetSimulator::set_refresh_k`] overrides.
pub const REFRESH_K: usize = 256;

/// Window length (ticks) for the `fleet_active_tenants_window` HLL
/// gauge: the sketch of recently-active tenant ids is snapshotted and
/// cleared every this-many ticks, so the gauge tracks *current*
/// activity instead of the whole run's union.
pub const METRICS_WINDOW: usize = 64;

/// Closed [`METRICS_WINDOW`]-tick windows the fleet retains in its
/// [`HllWindowRing`]: the `fleet_active_tenants_ring` gauge is the
/// merged distinct-actives estimate over the last this-many closed
/// windows (≈ 8.5 hours of 1-minute ticks at the defaults).
pub const METRICS_WINDOW_RING: usize = 8;

/// One tick's fleet-level outcome.
///
/// Equality ignores [`Self::planning_micros`] (wall-clock, varies run
/// to run) and [`Self::fresh_proposals`] (a dirty-queue fleet proposes
/// less than an always-replan fleet *by design*), so determinism tests
/// and the dirty-vs-full equivalence property can compare tick
/// timelines directly on what the control plane decided.
#[derive(Debug, Clone, Copy)]
pub struct FleetTick {
    pub step: usize,
    /// Σ hourly cost of the configurations that served this tick.
    pub spend: f32,
    /// Projected spend once the admitted moves take effect (== next
    /// tick's spend).
    pub projected_spend: f32,
    pub admitted_moves: usize,
    pub denied_moves: usize,
    pub rescues: usize,
    pub rescue_denials: usize,
    /// Moves admitted as a lower-ranked candidate (degradations).
    pub degraded_moves: usize,
    /// Shed offers actuated to fund SLA repairs.
    pub shed_moves: usize,
    /// Tenants at storage-only cost after this tick (draining or
    /// suspended); 0 unless serverless mode is on.
    pub suspended: usize,
    /// Tenants inside a cold-start window after this tick.
    pub resuming: usize,
    /// Cold-start windows that closed at the start of this tick
    /// (`Event::ResumeEnd` fired from the fleet calendar).
    pub resume_ends: usize,
    /// Tenants that actually ran [`crate::policy::Policy::propose`]
    /// this tick (the rest replayed cached holds) — the
    /// machine-independent proxy for per-tick planning work.
    pub fresh_proposals: usize,
    /// Microseconds spent planning this tick (budget hints +
    /// propose/replay + admission), from the fleet's injectable
    /// monotonic clock. Deterministically zero by default;
    /// [`FleetSimulator::use_wall_clock`] opts in to real wall-clock
    /// telemetry (CLI + benches), and
    /// [`FleetSimulator::set_planning_clock`] injects counters for
    /// tests.
    pub planning_micros: u64,
}

impl PartialEq for FleetTick {
    fn eq(&self, o: &Self) -> bool {
        // planning_micros and fresh_proposals are measurement, not
        // decision — see the struct docs
        self.step == o.step
            && self.spend == o.spend
            && self.projected_spend == o.projected_spend
            && self.admitted_moves == o.admitted_moves
            && self.denied_moves == o.denied_moves
            && self.rescues == o.rescues
            && self.rescue_denials == o.rescue_denials
            && self.degraded_moves == o.degraded_moves
            && self.shed_moves == o.shed_moves
            && self.suspended == o.suspended
            && self.resuming == o.resuming
            && self.resume_ends == o.resume_ends
    }
}

/// A complete fleet run: the per-tick timeline plus the final report.
#[derive(Debug, Clone)]
pub struct FleetResult {
    pub ticks: Vec<FleetTick>,
    pub report: FleetReport,
}

impl FleetResult {
    /// Highest per-tick spend observed.
    pub fn peak_spend(&self) -> f32 {
        self.report.peak_spend
    }

    /// Whether every tick stayed within the budget.
    pub fn within_budget(&self, budget: f32) -> bool {
        self.peak_spend() <= budget + BUDGET_EPS
    }

    /// Total SLA-violation ticks across all tenants.
    pub fn total_violations(&self) -> usize {
        self.report.tenants.iter().map(|t| t.summary.violations).sum()
    }
}

/// One tenant's ranked candidates at one tick, captured for the CLI's
/// `--explain` dump (enable with [`FleetSimulator::enable_explain`];
/// holds are skipped — only proposals that requested a move record).
#[derive(Debug, Clone)]
pub struct ExplainRecord {
    pub step: usize,
    pub tenant: usize,
    pub class: PriorityClass,
    pub verdict: Verdict,
    pub from: Configuration,
    /// Top-k ranked candidates of the admission proposal.
    pub candidates: Vec<Candidate>,
    /// How many shed offers the tenant published alongside.
    pub sheds: usize,
    /// Serverless lifecycle at proposal time (None for always-on
    /// tenants) — additive explain-v1 field.
    pub lifecycle: Option<&'static str>,
    /// Tick the cold-start window opened by this verdict closes at
    /// (wakes only) — additive explain-v1 field.
    pub resume_end: Option<usize>,
}

/// Drives N tenants and the budget arbiter over their traces.
pub struct FleetSimulator {
    tenants: Vec<Tenant>,
    arbiter: BudgetArbiter,
    /// Dynamic envelope re-weighting from observed per-class contention
    /// (None = fixed configuration-time shares).
    adapter: Option<EnvelopeAdapter>,
    /// Top-k explain capture (0 = off).
    explain_k: usize,
    explain: Vec<ExplainRecord>,
    /// Reservoir cap on the explain log (0 = unbounded): at scale the
    /// log would grow O(moving tenants · ticks), so the CLI's
    /// `--explain-sample` bounds it to a uniform sample.
    explain_cap: usize,
    /// Move records offered to the explain log so far (reservoir
    /// denominator).
    explain_seen: u64,
    /// Deterministic reservoir RNG (fixed seed: sampled runs replay).
    explain_rng: XorShift64,
    /// Shared storage tier (Some = serverless mode).
    serverless: Option<StorageService>,
    /// Fleet-level DES calendar: cold-start windows live here.
    calendar: EventCalendar,
    /// Dirty-queue planning (default on): tenants replay cached holds
    /// while their invalidation set is untouched (module docs).
    dirty_planning: bool,
    /// Mandatory re-propose interval for cached holds.
    refresh_k: usize,
    /// Incrementally maintained per-slot `cost_from` ledger feeding
    /// [`BudgetArbiter::admit_ledgered`] in dirty mode.
    ledger: SpendLedger,
    /// Monotonic microsecond source for `planning_micros`. Defaults to
    /// a constant zero (deterministic, wall-clock-free); the CLI and
    /// benches opt in to real time via [`Self::use_wall_clock`].
    clock: Box<dyn FnMut() -> u64>,
    step: usize,
    /// Pull-based export registry: per-tick counters/gauges land here
    /// during [`Self::tick`]; [`Self::export_metrics`] finalizes the
    /// run-level gauges and sketch rollups. Observation only — nothing
    /// on the decision path reads it.
    registry: MetricsRegistry,
    /// Distinct tenants that served real throughput, whole run.
    active_hll: Hll,
    /// Same, windowed: an open [`METRICS_WINDOW`]-tick sketch plus the
    /// last [`METRICS_WINDOW_RING`] closed windows for merged lookback.
    active_window_ring: HllWindowRing,
    /// Scenario stamp when a named preset drives the run: `(name,
    /// scheduled fault count)`. Stamped additively into metrics-v1 by
    /// [`Self::export_metrics`] and into explain-v1 by the CLI.
    scenario: Option<(String, usize)>,
    /// Distinct `(tenant, configuration)` pairs served.
    config_hll: Hll,
    /// Guards [`Self::export_metrics`] against double-merging sketches.
    exported: bool,
}

impl FleetSimulator {
    /// Build a fleet with the planning arbiter (candidate walks, shed
    /// re-negotiation, budget hints; envelopes off until
    /// [`Self::set_envelopes`]). All tenants share one [`SurfaceModel`]
    /// (the plane geometry and surface constants are fleet-wide), so
    /// construction cost is independent of tenant count.
    pub fn new(
        cfg: &ModelConfig,
        specs: Vec<TenantSpec>,
        budget: f32,
        fairness_k: usize,
    ) -> Self {
        Self::with_arbiter(cfg, specs, BudgetArbiter::new(budget, fairness_k))
    }

    /// Build a fleet around an explicit arbiter — the PR-2 flat-denial
    /// baseline ([`BudgetArbiter::flat`]), or a planning arbiter with
    /// envelopes pre-applied.
    pub fn with_arbiter(cfg: &ModelConfig, specs: Vec<TenantSpec>, arbiter: BudgetArbiter) -> Self {
        assert!(!specs.is_empty(), "fleet needs at least one tenant");
        let model = Arc::new(SurfaceModel::from_config(cfg));
        let tenants: Vec<Tenant> = specs
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                let mut t = Tenant::new(i, s, Arc::clone(&model), cfg);
                t.set_escalation(arbiter.fairness_k);
                t
            })
            .collect();
        Self {
            tenants,
            arbiter,
            adapter: None,
            explain_k: 0,
            explain: Vec::new(),
            explain_cap: 0,
            explain_seen: 0,
            explain_rng: XorShift64::new(0x5EED_EC0A),
            serverless: None,
            calendar: EventCalendar::new(),
            dirty_planning: true,
            refresh_k: REFRESH_K,
            ledger: SpendLedger::new(),
            clock: Box::new(|| 0),
            step: 0,
            registry: MetricsRegistry::new(),
            active_hll: Hll::default(),
            active_window_ring: HllWindowRing::new(
                METRICS_WINDOW_RING,
                crate::metrics::hll::DEFAULT_PRECISION,
            ),
            scenario: None,
            config_hll: Hll::default(),
            exported: false,
        }
    }

    /// Opt the whole fleet into the serverless tier: build the shared
    /// storage service, size each tenant's working set from its average
    /// demand, and register it. Suspend/resume lifecycle moves then
    /// flow through the unchanged proposal pipeline (see
    /// [`crate::serverless`]).
    pub fn enable_serverless(&mut self, params: ServerlessParams) {
        let mut storage = StorageService::new(params);
        for t in &mut self.tenants {
            let trace = t.trace();
            let avg = trace.points.iter().map(|w| w.lambda_req).sum::<f32>()
                / trace.len().max(1) as f32;
            let gb = storage.register(t.id, params.working_set_gb(avg));
            t.enable_serverless(params, gb);
        }
        self.serverless = Some(storage);
    }

    /// The shared storage tier, when serverless mode is on.
    pub fn storage(&self) -> Option<&StorageService> {
        self.serverless.as_ref()
    }

    /// Cold-start windows currently open on the fleet calendar.
    pub fn pending_resumes(&self) -> usize {
        self.calendar.len()
    }

    /// The fleet's DES calendar (cold-start `ResumeEnd` events).
    pub fn calendar(&self) -> &EventCalendar {
        &self.calendar
    }

    /// Record every moving tenant's top-`k` ranked candidates per tick
    /// in [`Self::explain_log`] (0 disables; CLI `fleet --explain`).
    pub fn enable_explain(&mut self, k: usize) {
        self.explain_k = k;
    }

    /// The captured explain records (empty unless
    /// [`Self::enable_explain`] was called before running).
    pub fn explain_log(&self) -> &[ExplainRecord] {
        &self.explain
    }

    /// Cap the explain log at `cap` records via deterministic reservoir
    /// sampling (0 restores the unbounded log): every move record ever
    /// offered has equal probability of surviving, so a 10k-tenant run
    /// keeps a representative sample in O(cap) memory instead of
    /// O(moving tenants × ticks). CLI `fleet --explain-sample`.
    pub fn set_explain_sample(&mut self, cap: usize) {
        self.explain_cap = cap;
    }

    /// The reservoir cap (0 = unbounded), echoed into the explain-v1
    /// JSON as `sample_cap` so consumers know the steps are a sample.
    pub fn explain_sample_cap(&self) -> usize {
        self.explain_cap
    }

    /// Move records offered to the explain log across the run — the
    /// reservoir denominator (equals the log length when unbounded).
    pub fn explain_seen(&self) -> u64 {
        self.explain_seen
    }

    /// Reservoir-insert one explain record (plain push when unbounded).
    fn push_explain(&mut self, r: ExplainRecord) {
        self.explain_seen += 1;
        if self.explain_cap == 0 || self.explain.len() < self.explain_cap {
            self.explain.push(r);
        } else {
            // algorithm R: replace a random slot with probability
            // cap/seen, keeping the sample uniform over all offers
            let j = (self.explain_rng.next_u64() % self.explain_seen) as usize;
            if j < self.explain_cap {
                self.explain[j] = r;
            }
        }
    }

    /// Toggle dirty-queue planning (on by default; module docs). `false`
    /// restores the always-replan loop — the reference behavior
    /// `tests/prop_dirty.rs` pins the dirty queue against, and the CLI
    /// `--no-dirty-planning` escape hatch.
    pub fn set_dirty_planning(&mut self, on: bool) {
        self.dirty_planning = on;
    }

    /// Whether the dirty queue is active.
    pub fn dirty_planning(&self) -> bool {
        self.dirty_planning
    }

    /// Override the mandatory re-propose interval for cached holds
    /// (default [`REFRESH_K`]; must be ≥ 1 — 1 disables caching
    /// entirely, every tick is a refresh).
    pub fn set_refresh_k(&mut self, k: usize) {
        assert!(k >= 1, "refresh interval must be at least 1 tick");
        self.refresh_k = k;
    }

    /// Inject the monotonic microsecond source behind
    /// [`FleetTick::planning_micros`] (tests inject a counter so tick
    /// timelines stay bit-for-bit reproducible; the default clock is a
    /// constant zero so a fresh fleet never reads the wall clock —
    /// callers that want real latency telemetry opt in via
    /// [`Self::use_wall_clock`]).
    pub fn set_planning_clock(&mut self, clock: Box<dyn FnMut() -> u64>) {
        self.clock = clock;
    }

    /// Opt in to real wall-clock planning latency: points the planning
    /// clock at a process-monotonic microsecond source (the CLI and
    /// benches call this so `planning_micros` is meaningful). This is
    /// the one sanctioned wall-clock seam in decision code — the clock
    /// feeds only [`FleetTick::planning_micros`], which is excluded
    /// from [`FleetTick`] equality, so simulation results stay
    /// bit-identical either way.
    #[allow(clippy::disallowed_methods)]
    pub fn use_wall_clock(&mut self) {
        // simlint: allow(d1-no-wall-clock): sanctioned opt-in telemetry seam; never read by decision state.
        let epoch = std::time::Instant::now();
        self.set_planning_clock(Box::new(move || epoch.elapsed().as_micros() as u64));
    }

    /// Placement-mode fleet: co-locate tenants on shared clusters under
    /// the same budget machinery. Returns a [`PlacementSim`] — a
    /// different control loop (clusters are shared, tenants are demand
    /// sources) that routes every placement action through the
    /// [`BudgetArbiter`]. See [`crate::placement`] for the model;
    /// [`PlacementSim::dedicated`] builds the one-cluster-per-tenant
    /// baseline for A/B runs.
    pub fn with_placement(
        cfg: &ModelConfig,
        specs: Vec<TenantSpec>,
        budget: f32,
        fairness_k: usize,
        pcfg: PlacementConfig,
    ) -> PlacementSim {
        PlacementSim::packed(cfg, specs, budget, fairness_k, pcfg)
    }

    /// Apply (or clear) per-class budget envelopes with burst credits.
    pub fn set_envelopes(&mut self, envelopes: Option<ClassEnvelopes>) {
        self.arbiter.envelopes = envelopes;
    }

    /// Switch the class envelopes to dynamic re-weighting: shares are
    /// re-derived every tick from an EWMA of observed per-class
    /// contention (denials + SLA-violation ticks) instead of staying at
    /// the configuration-time split. The current envelopes (or the
    /// default split when none are set) become the base the adapter
    /// bends. ROADMAP open item; CLI `--adaptive-envelopes`.
    pub fn enable_adaptive_envelopes(&mut self) {
        let base = self.arbiter.envelopes.unwrap_or_else(ClassEnvelopes::default_split);
        self.arbiter.envelopes = Some(base);
        self.adapter = Some(EnvelopeAdapter::new(base));
    }

    /// The envelopes currently governing economic admission (changes
    /// tick to tick when adaptive re-weighting is on).
    pub fn envelopes(&self) -> Option<ClassEnvelopes> {
        self.arbiter.envelopes
    }

    /// Upgrade every tenant to forecast-driven lookahead proposals
    /// (`depth` >= 1; seasonal predictors use each tenant's own trace
    /// length as their period).
    pub fn enable_forecasts(&mut self, kind: ForecastKind, depth: usize) {
        for t in &mut self.tenants {
            t.enable_forecast(kind, depth);
        }
    }

    /// Back every tenant with its own sampling-engine cluster (seeded
    /// per tenant for reproducibility).
    pub fn attach_clusters(&mut self, cfg: &ModelConfig, params: ClusterParams, seed: u64) {
        self.attach_substrates(cfg, params, seed, SubstrateKind::Sampling);
    }

    /// Back every tenant with a substrate of the given kind (seeded per
    /// tenant). [`SubstrateKind::Des`] is the bench-speed choice for
    /// large fleets. Every kind audits against the owning tenant's own
    /// SLA bound (the shared [`ClusterParams::sla_latency`] is rescaled
    /// per tenant) and emits latencies on the substrate scale, so fleet
    /// reports aggregate one unit.
    pub fn attach_substrates(
        &mut self,
        cfg: &ModelConfig,
        params: ClusterParams,
        seed: u64,
        kind: SubstrateKind,
    ) {
        self.attach_mixed_substrates(cfg, params, seed, |_| kind);
    }

    /// Back each tenant with the substrate kind chosen per tenant id —
    /// analytical, sampling, and event-driven tenants mix in one run.
    pub fn attach_mixed_substrates(
        &mut self,
        cfg: &ModelConfig,
        params: ClusterParams,
        seed: u64,
        choose: impl Fn(usize) -> SubstrateKind,
    ) {
        for t in &mut self.tenants {
            match choose(t.id) {
                SubstrateKind::Analytical => t.attach_analytical(cfg, params),
                SubstrateKind::Sampling => {
                    t.attach_cluster(cfg, params, seed.wrapping_add(t.id as u64))
                }
                SubstrateKind::Des => {
                    t.attach_event_cluster(cfg, params, seed.wrapping_add(t.id as u64))
                }
            }
        }
    }

    /// Disable per-step recording (benchmark mode: bounded memory).
    pub fn set_recording(&mut self, on: bool) {
        for t in &mut self.tenants {
            t.set_recording(on);
        }
    }

    /// Switch every tenant to the bounded [`crate::metrics::
    /// StreamingRecorder`]: summary accumulators + latency sketches +
    /// a `cap`-record exemplar reservoir per tenant, so observation
    /// memory is O(cap · N) regardless of tick count (the honest
    /// 10k-tenant mode — reports still work, nothing grows with run
    /// length). Observation only: tick timelines are bit-identical to
    /// exact-recording runs.
    pub fn enable_streaming_metrics(&mut self, cap: usize) {
        for t in &mut self.tenants {
            t.enable_streaming_metrics(cap);
        }
    }

    /// The pull-based export registry as populated so far (per-tick
    /// series only until [`Self::export_metrics`] runs).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// Finalize the export registry: pre-declare every pinned name
    /// (`config/metrics_v1.names`), set the run-level HLL estimates and
    /// observation-memory gauge, merge per-class latency sketches, and
    /// fold in the arbiter/serverless gauges. Idempotent — repeated
    /// calls (e.g. `--metrics-out` plus `--metrics-json`) render the
    /// same snapshot.
    pub fn export_metrics(&mut self) -> &MetricsRegistry {
        if self.exported {
            return &self.registry;
        }
        self.exported = true;
        self.registry.declare_all();
        self.registry.set(metric_names::FLEET_ACTIVE_TENANTS_ESTIMATE, &[], self.active_hll.estimate());
        self.registry.set(metric_names::FLEET_CONFIGS_VISITED_ESTIMATE, &[], self.config_hll.estimate());
        if !self.active_window_ring.open_is_empty() {
            // expose the still-open window rather than a stale gauge
            self.registry.set(
                metric_names::FLEET_ACTIVE_TENANTS_WINDOW,
                &[],
                self.active_window_ring.open_estimate(),
            );
        }
        if self.active_window_ring.rotations() > 0 {
            self.registry.set(
                metric_names::FLEET_ACTIVE_TENANTS_RING,
                &[],
                self.active_window_ring.merged_estimate(),
            );
        }
        if let Some((name, faults)) = &self.scenario {
            self.registry.set(metric_names::SCENARIO_ACTIVE, &[("name", name.as_str())], 1.0);
            self.registry.set(metric_names::SCENARIO_FAULTS_TOTAL, &[], *faults as f64);
        }
        let retained: usize = self.tenants.iter().map(|t| t.retained_records()).sum();
        self.registry.set(metric_names::FLEET_RETAINED_RECORDS, &[], retained as f64);
        for class in PriorityClass::ALL {
            let mut hist = LatencyHistogram::new(crate::metrics::LATENCY_FLOOR);
            for t in self.tenants.iter().filter(|t| t.class() == class) {
                hist.merge(&t.merged_histogram());
            }
            self.registry.merge_sketch(
                metric_names::FLEET_LATENCY_SECONDS,
                &[("class", class.label())],
                &hist,
            );
        }
        self.arbiter.export_metrics(&mut self.registry);
        if let Some(storage) = &self.serverless {
            storage.export_metrics(&mut self.registry);
            let (mut cold, mut resumes, mut suspends) = (0u64, 0u64, 0u64);
            for t in &self.tenants {
                if let Some(s) = t.serverless() {
                    cold += s.cold_start_ticks_total as u64;
                    resumes += s.resumes as u64;
                    suspends += s.suspends as u64;
                }
            }
            self.registry.set(metric_names::SERVERLESS_COLD_START_TICKS, &[], cold as f64);
            self.registry.set(metric_names::SERVERLESS_RESUMES, &[], resumes as f64);
            self.registry.set(metric_names::SERVERLESS_SUSPENDS, &[], suspends as f64);
        }
        &self.registry
    }

    /// Per-tick registry updates (cheap: a handful of keyed counter
    /// bumps; the expensive rollups wait for [`Self::export_metrics`]).
    fn record_tick_metrics(&mut self, tick: &FleetTick, violating_steps: usize) {
        let reg = &mut self.registry;
        reg.inc(metric_names::FLEET_TICKS_TOTAL, &[], 1);
        reg.set(metric_names::FLEET_TENANTS, &[], self.tenants.len() as f64);
        reg.set(metric_names::FLEET_SPEND_HOURLY, &[], tick.spend as f64);
        reg.set(metric_names::FLEET_PROJECTED_SPEND_HOURLY, &[], tick.projected_spend as f64);
        reg.inc(metric_names::FLEET_MOVES_ADMITTED_TOTAL, &[], tick.admitted_moves as u64);
        reg.inc(metric_names::FLEET_MOVES_DENIED_TOTAL, &[], tick.denied_moves as u64);
        reg.inc(metric_names::FLEET_RESCUES_TOTAL, &[], tick.rescues as u64);
        reg.inc(metric_names::FLEET_RESCUE_DENIALS_TOTAL, &[], tick.rescue_denials as u64);
        reg.inc(metric_names::FLEET_MOVES_DEGRADED_TOTAL, &[], tick.degraded_moves as u64);
        reg.inc(metric_names::FLEET_SHEDS_TOTAL, &[], tick.shed_moves as u64);
        reg.inc(metric_names::FLEET_FRESH_PROPOSALS_TOTAL, &[], tick.fresh_proposals as u64);
        reg.inc(metric_names::FLEET_VIOLATION_TICKS_TOTAL, &[], violating_steps as u64);
        reg.set(metric_names::FLEET_SUSPENDED_TENANTS, &[], tick.suspended as f64);
        reg.set(metric_names::FLEET_RESUMING_TENANTS, &[], tick.resuming as f64);
        reg.inc(metric_names::FLEET_RESUME_ENDS_TOTAL, &[], tick.resume_ends as u64);
        reg.observe(
            metric_names::FLEET_PLANNING_SECONDS,
            &[],
            metric_names::PLANNING_FLOOR,
            tick.planning_micros as f64 * 1e-6,
        );
        if (tick.step + 1) % METRICS_WINDOW == 0 {
            let closed = self.active_window_ring.rotate();
            reg.set(metric_names::FLEET_ACTIVE_TENANTS_WINDOW, &[], closed);
            reg.set(
                metric_names::FLEET_ACTIVE_TENANTS_RING,
                &[],
                self.active_window_ring.merged_estimate(),
            );
        }
    }

    /// Stamp the run with the scenario preset driving it. Additive
    /// observability only: [`Self::export_metrics`] gains the
    /// `scenario_active{name=...}` / `scenario_faults_total` gauges and
    /// the CLI threads the name into the explain-v1 dump — decisions
    /// are untouched.
    pub fn set_scenario(&mut self, name: impl Into<String>, faults: usize) {
        self.scenario = Some((name.into(), faults));
    }

    /// The scenario stamp, if [`Self::set_scenario`] was called.
    pub fn scenario(&self) -> Option<(&str, usize)> {
        self.scenario.as_ref().map(|(n, f)| (n.as_str(), *f))
    }

    /// Layer a scenario fault schedule onto the tenants' DES calendars
    /// via [`Tenant::schedule_node_failure`]: each event lands
    /// mid-interval of its tick (`(at_tick + 0.5) × interval`), so the
    /// tick's serve sees the node down. Returns how many events were
    /// accepted — an event is not scheduled when its tenant index is
    /// out of range (a no-op) or the tenant has no failure-capable
    /// substrate (attach [`SubstrateKind::Des`] /
    /// [`SubstrateKind::Sampling`] engines first; the tenant is still
    /// conservatively dirtied).
    pub fn schedule_faults(&mut self, faults: &[FaultEvent], interval: f64) -> usize {
        let mut scheduled = 0usize;
        for f in faults {
            if let Some(t) = self.tenants.get_mut(f.tenant) {
                if t.schedule_node_failure((f.at_tick as f64 + 0.5) * interval, f.node) {
                    scheduled += 1;
                }
            }
        }
        scheduled
    }

    pub fn arbiter(&self) -> &BudgetArbiter {
        &self.arbiter
    }

    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }

    /// Mutable tenant access for test orchestration (custom substrates,
    /// per-tenant planner tweaks).
    pub fn tenants_mut(&mut self) -> &mut [Tenant] {
        &mut self.tenants
    }

    /// Current fleet spend (Σ hourly cost of serving configurations).
    /// Accumulated in f64 — an f32 running sum loses real pennies by
    /// 10k tenants — and narrowed at the edge.
    pub fn spend(&self) -> f32 {
        money::narrow(self.spend_f64())
    }

    fn spend_f64(&self) -> f64 {
        self.tenants.iter().map(|t| t.cost() as f64).sum()
    }

    /// Longest tenant trace (the natural run length).
    pub fn longest_trace(&self) -> usize {
        self.tenants.iter().map(|t| t.trace().len()).max().unwrap_or(0)
    }

    /// Per-tenant budget hints: remaining fleet headroom plus each
    /// tenant's class-envelope headroom (burst credits included).
    /// Fleet and class spend are summed once, so the whole batch is
    /// O(N). All `None` under the flat (PR-2) arbiter — its tenants
    /// plan budget-blind.
    fn hints(&self) -> Vec<Option<BudgetHint>> {
        if !self.arbiter.planning {
            return vec![None; self.tenants.len()];
        }
        let spend = self.spend_f64();
        let fleet_headroom = money::narrow((self.arbiter.budget as f64 - spend).max(0.0));
        let class_spend: [f32; 3] = if self.arbiter.envelopes.is_some() {
            let mut cs = [0.0f64; 3];
            for t in &self.tenants {
                cs[t.class().rank() as usize] += t.cost() as f64;
            }
            [money::narrow(cs[0]), money::narrow(cs[1]), money::narrow(cs[2])]
        } else {
            [0.0; 3]
        };
        self.tenants
            .iter()
            .map(|tenant| {
                let class_headroom = match &self.arbiter.envelopes {
                    None => fleet_headroom,
                    Some(env) => env
                        .class_headroom(tenant.class(), &class_spend, self.arbiter.budget)
                        .max(0.0),
                };
                Some(BudgetHint::new(fleet_headroom, class_headroom))
            })
            .collect()
    }

    /// One fleet tick: every tenant serves, proposes (budget-hinted);
    /// the arbiter admits under the budget (walking candidate lists,
    /// re-negotiating via sheds); admitted moves actuate for next tick.
    /// Actuate one admitted candidate. A suspended tenant's admitted
    /// move is a *wake*: apply the target configuration, then open a
    /// cold-start window on the fleet calendar — the tenant is Resuming
    /// (paying, not serving) until `Event::ResumeEnd` fires. Everyone
    /// else reconfigures directly.
    fn actuate(&mut self, tenant: usize, to: Configuration, t: usize) {
        let waking = matches!(self.tenants[tenant].lifecycle(), Some(Lifecycle::Suspended));
        let tn = &mut self.tenants[tenant];
        tn.apply(to);
        if waking {
            // the move takes effect at t+1 (serve-then-move), so the
            // window spans the cold-start ticks after that
            let until = t + 1 + tn.cold_start_ticks();
            tn.begin_resume(until);
            self.calendar.schedule(until as f64, Event::ResumeEnd { tenant });
        }
    }

    pub fn tick(&mut self) -> FleetTick {
        let t = self.step;
        // close cold-start windows due *before* serving: a window
        // scheduled to end at t means the tenant serves tick t
        let mut resume_ends = 0usize;
        while let Some((_, ev)) = self.calendar.pop_due(t as f64) {
            if let Event::ResumeEnd { tenant } = ev {
                self.tenants[tenant].finish_resume();
                resume_ends += 1;
            }
        }
        let mut spend = 0.0f64;
        let mut violating_steps = 0usize;
        for tn in &mut self.tenants {
            let rec = tn.serve(t);
            spend += rec.cost as f64;
            if rec.violation.any() {
                violating_steps += 1;
            }
            if rec.throughput > 0.0 {
                self.active_hll.insert_u64(tn.id as u64);
                self.active_window_ring.insert_u64(tn.id as u64);
            }
            // distinct (tenant, configuration) pairs actually served
            let code = ((tn.id as u64) << 16)
                ^ ((rec.config.h_idx as u64) << 8)
                ^ rec.config.v_idx as u64;
            self.config_hll.insert_u64(code);
        }

        // planning = hints + propose/replay + admission; the window is
        // measured on the injectable monotonic clock
        let planning_start = (self.clock)();
        let hints = self.hints();
        // dirty queue: a tenant whose hold ticket's invalidation set is
        // untouched replays its cached proposal; everyone else runs the
        // policy and re-records their spend-ledger slot (clean slots
        // keep bitwise-identical entries, so the ledger fold equals the
        // full proposal walk and decisions cannot differ)
        let dirty = self.dirty_planning && self.arbiter.planning;
        let refresh_k = self.refresh_k;
        let ledger = &mut self.ledger;
        let mut fresh_proposals = 0usize;
        let proposals: Vec<Proposal> = self
            .tenants
            .iter_mut()
            .zip(hints)
            .enumerate()
            .map(|(i, (tn, hint))| {
                if dirty {
                    if let Some(p) = tn.replay_hold(t, hint, refresh_k) {
                        return p;
                    }
                }
                fresh_proposals += 1;
                let p = tn.propose(t, hint);
                ledger.record(i, p.cost_from, p.class);
                p
            })
            .collect();
        let adm = if dirty {
            self.arbiter.admit_ledgered(&proposals, &self.ledger)
        } else {
            self.arbiter.admit(&proposals)
        };
        let planning_micros = (self.clock)().saturating_sub(planning_start);

        // collect this tick's explain records before actuation (the
        // reservoir may scatter them, so resume windows are stamped on
        // the batch below, not by scanning the log tail)
        let mut tick_records: Vec<ExplainRecord> = Vec::new();
        if self.explain_k > 0 {
            for (p, v) in proposals.iter().zip(&adm.verdicts) {
                if p.is_move() {
                    tick_records.push(ExplainRecord {
                        step: t,
                        tenant: p.tenant,
                        class: p.class,
                        verdict: *v,
                        from: p.from,
                        candidates: p.candidates.iter().take(self.explain_k).copied().collect(),
                        sheds: p.sheds.len(),
                        lifecycle: self.tenants[p.tenant].lifecycle().map(|l| l.label()),
                        resume_end: None,
                    });
                }
            }
        }

        for (i, (p, v)) in proposals.iter().zip(&adm.verdicts).enumerate() {
            match v {
                Verdict::Hold => self.tenants[p.tenant].note_no_move(),
                Verdict::AdmittedShrink | Verdict::Admitted => {
                    let to = p.candidates[adm.chosen[i].expect("admitted move has a choice")].to;
                    self.actuate(p.tenant, to, t);
                }
                Verdict::AdmittedDegraded => {
                    self.tenants[p.tenant].degraded_total += 1;
                    let to = p.candidates[adm.chosen[i].expect("degraded move has a choice")].to;
                    self.actuate(p.tenant, to, t);
                }
                Verdict::AdmittedRescue => {
                    self.tenants[p.tenant].rescued_total += 1;
                    let to = p.candidates[adm.chosen[i].expect("rescue has a choice")].to;
                    self.actuate(p.tenant, to, t);
                }
                Verdict::AdmittedShed => {
                    self.tenants[p.tenant].shed_total += 1;
                    let to = p.sheds[adm.chosen[i].expect("shed has a choice")].to;
                    self.actuate(p.tenant, to, t);
                }
                Verdict::DeniedBudget => self.tenants[p.tenant].note_denied(),
                Verdict::DeniedRescueUnaffordable => {
                    self.tenants[p.tenant].note_rescue_unaffordable()
                }
            }
        }

        // stamp cold-start windows opened this tick into the explain
        // records (wakes actuate after the capture above), then hand
        // the batch to the reservoir
        if self.explain_k > 0 {
            for r in &mut tick_records {
                if let Some(Lifecycle::Resuming { until }) = self.tenants[r.tenant].lifecycle() {
                    r.resume_end = Some(until);
                }
            }
            for r in tick_records {
                self.push_explain(r);
            }
        }

        // dynamic envelope re-weighting: fold this tick's per-class
        // contention (denials + violation ticks) into the adapter and
        // install the bent shares for the next admission
        if let Some(adapter) = &mut self.adapter {
            let mut contention = [0.0f32; 3];
            for (p, v) in proposals.iter().zip(&adm.verdicts) {
                let r = p.class.rank() as usize;
                if v.denied() {
                    contention[r] += 1.0;
                }
                if self.tenants[p.tenant].violating() {
                    contention[r] += 1.0;
                }
            }
            self.arbiter.envelopes = Some(adapter.observe(contention));
        }

        let (mut suspended, mut resuming) = (0usize, 0usize);
        for tn in &self.tenants {
            match tn.lifecycle() {
                Some(Lifecycle::Draining) | Some(Lifecycle::Suspended) => suspended += 1,
                Some(Lifecycle::Resuming { .. }) => resuming += 1,
                _ => {}
            }
        }

        self.step += 1;
        let tick = FleetTick {
            step: t,
            spend: money::narrow(spend),
            projected_spend: adm.projected_spend,
            admitted_moves: adm.admitted_moves,
            denied_moves: adm.denied_moves,
            rescues: adm.rescues,
            rescue_denials: adm.rescue_denials,
            degraded_moves: adm.degraded_moves,
            shed_moves: adm.shed_moves,
            suspended,
            resuming,
            resume_ends,
            fresh_proposals,
            planning_micros,
        };
        self.record_tick_metrics(&tick, violating_steps);
        tick
    }

    /// Run `steps` ticks (traces repeat cyclically) and aggregate.
    pub fn run(&mut self, steps: usize) -> FleetResult {
        let ticks: Vec<FleetTick> = (0..steps).map(|_| self.tick()).collect();
        let report = report::fleet_report(&self.tenants, &ticks, self.arbiter.budget);
        FleetResult { ticks, report }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceBuilder;

    fn specs(cfg: &ModelConfig, n: usize) -> Vec<TenantSpec> {
        let base = TraceBuilder::paper(cfg);
        (0..n)
            .map(|i| {
                let class = match i % 3 {
                    0 => PriorityClass::Gold,
                    1 => PriorityClass::Silver,
                    _ => PriorityClass::Bronze,
                };
                TenantSpec::from_config(
                    cfg,
                    format!("t{i}"),
                    class,
                    base.shifted(i * base.len() / n.max(1)),
                )
            })
            .collect()
    }

    #[test]
    fn generous_budget_never_denies() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 4), 1.0e6, 3);
        let res = fleet.run(50);
        assert!(res.ticks.iter().all(|t| t.denied_moves == 0));
        assert!(res.ticks.iter().all(|t| t.shed_moves == 0), "no re-negotiation without pressure");
        assert!(res.within_budget(1.0e6));
    }

    #[test]
    fn spend_stays_within_budget_every_tick() {
        let cfg = ModelConfig::default_paper();
        let budget = 8.0f32; // tight: unconstrained peaks exceed this
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 6), budget, 3);
        let res = fleet.run(100);
        assert!(res.within_budget(budget), "peak {}", res.peak_spend());
        // contention must actually bite for the test to mean anything
        assert!(res.ticks.iter().any(|t| t.denied_moves > 0));
    }

    #[test]
    fn projected_spend_is_next_ticks_spend() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 5), 9.0, 3);
        let res = fleet.run(60);
        for w in res.ticks.windows(2) {
            assert!(
                (w[0].projected_spend - w[1].spend).abs() < 1e-3,
                "projected {} vs served {}",
                w[0].projected_spend,
                w[1].spend
            );
        }
    }

    #[test]
    fn constrained_fleet_never_outperforms_unconstrained_on_spend() {
        let cfg = ModelConfig::default_paper();
        let mut free = FleetSimulator::new(&cfg, specs(&cfg, 6), 1.0e6, 3);
        let free_res = free.run(50);
        let budget = free_res.peak_spend() * 0.7;
        let mut tight = FleetSimulator::new(&cfg, specs(&cfg, 6), budget, 3);
        let tight_res = tight.run(50);
        assert!(tight_res.peak_spend() <= budget + 1e-3);
        assert!(tight_res.peak_spend() < free_res.peak_spend());
    }

    #[test]
    fn deterministic() {
        let cfg = ModelConfig::default_paper();
        let a = FleetSimulator::new(&cfg, specs(&cfg, 4), 7.0, 3).run(50);
        let b = FleetSimulator::new(&cfg, specs(&cfg, 4), 7.0, 3).run(50);
        assert_eq!(a.ticks, b.ticks);
    }

    /// The PR-3 acceptance pin: on the contended 6-tenant scenario at
    /// the same 8.0/h budget, budget-aware planning (candidate lists +
    /// shed re-negotiation + envelopes + per-tenant forecasting) must
    /// yield strictly fewer total SLA-violation ticks than the PR-2
    /// flat-denial arbiter, stay within budget on every tick, and stay
    /// deterministic. (A python mirror of the analytical model puts
    /// planning at ~196 violation ticks vs ~244 for flat — the strict
    /// inequality has a wide margin.)
    #[test]
    fn planning_beats_flat_denial_on_violations() {
        let cfg = ModelConfig::default_paper();
        let budget = 8.0f32;

        let mut flat =
            FleetSimulator::with_arbiter(&cfg, specs(&cfg, 6), BudgetArbiter::flat(budget, 3));
        let flat_res = flat.run(100);

        let build_planning = || {
            let arb = BudgetArbiter::new(budget, 3)
                .with_envelopes(ClassEnvelopes::default_split());
            let mut fleet = FleetSimulator::with_arbiter(&cfg, specs(&cfg, 6), arb);
            fleet.enable_forecasts(ForecastKind::Seasonal, 3);
            fleet
        };
        let plan_res = build_planning().run(100);

        assert!(flat_res.within_budget(budget));
        assert!(plan_res.within_budget(budget), "peak {}", plan_res.peak_spend());
        assert!(
            plan_res.total_violations() < flat_res.total_violations(),
            "planning must strictly beat flat denial: {} vs {}",
            plan_res.total_violations(),
            flat_res.total_violations()
        );
        // re-negotiation actually engaged (the win is not incidental)
        let sheds: usize = plan_res.ticks.iter().map(|t| t.shed_moves).sum();
        assert!(sheds > 0, "planning run never re-negotiated");
        // planning runs stay deterministic
        let again = build_planning().run(100);
        assert_eq!(plan_res.ticks, again.ticks);
    }

    /// Adaptive envelopes (ROADMAP open item): under the contended
    /// 6-tenant scenario the adapter must actually bend the shares
    /// away from the fixed split, keep them a distribution, stay
    /// within budget, and stay deterministic.
    #[test]
    fn adaptive_envelopes_track_observed_contention() {
        let cfg = ModelConfig::default_paper();
        let budget = 8.0f32;
        let base = ClassEnvelopes::default_split();
        let build = || {
            let arb = BudgetArbiter::new(budget, 3).with_envelopes(base);
            let mut fleet = FleetSimulator::with_arbiter(&cfg, specs(&cfg, 6), arb);
            fleet.enable_adaptive_envelopes();
            fleet
        };
        let mut fleet = build();
        let res = fleet.run(100);
        assert!(res.within_budget(budget), "peak {}", res.peak_spend());
        // contention was real, so the shares moved off the base split
        assert!(res.ticks.iter().any(|t| t.denied_moves > 0), "budget never bit");
        let env = fleet.envelopes().expect("adaptive envelopes installed");
        assert_ne!(env, base, "adapter never re-weighted the shares");
        let sum: f32 = PriorityClass::ALL.iter().map(|&c| env.share(c)).sum();
        assert!((sum - 1.0).abs() < 1e-5);
        // deterministic
        let again = build().run(100);
        assert_eq!(res.ticks, again.ticks);
    }

    #[test]
    fn cluster_backed_fleet_runs() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 3), 1.0e6, 3);
        fleet.attach_clusters(&cfg, ClusterParams::default(), 42);
        let res = fleet.run(20);
        assert_eq!(res.ticks.len(), 20);
        // measured throughput flows into the summaries
        assert!(res.report.tenants.iter().all(|t| t.summary.avg_throughput > 0.0));
    }

    #[test]
    fn event_backed_fleet_runs() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 6), 1.0e6, 3);
        fleet.attach_substrates(&cfg, ClusterParams::default(), 42, SubstrateKind::Des);
        let res = fleet.run(20);
        assert_eq!(res.ticks.len(), 20);
        assert!(res.report.tenants.iter().all(|t| t.summary.avg_throughput > 0.0));
    }

    #[test]
    fn mixed_substrate_fleet_runs_in_one_pass() {
        let cfg = ModelConfig::default_paper();
        let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 6), 1.0e6, 3);
        fleet.attach_mixed_substrates(&cfg, ClusterParams::default(), 42, |id| match id % 3 {
            0 => SubstrateKind::Analytical,
            1 => SubstrateKind::Sampling,
            _ => SubstrateKind::Des,
        });
        let res = fleet.run(20);
        assert_eq!(res.ticks.len(), 20);
        assert!(res.report.tenants.iter().all(|t| t.summary.avg_throughput > 0.0));
    }

    #[test]
    fn serverless_fleet_suspends_idle_tenants_and_wakes_them() {
        let cfg = ModelConfig::default_paper();
        let specs = crate::serverless::mostly_idle_specs(&cfg, 8, 0.75);
        let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
        fleet.enable_serverless(ServerlessParams::default());
        let res = fleet.run(100);
        // idle tenants actually scale to zero...
        assert!(res.ticks.iter().any(|t| t.suspended > 0), "no tenant ever suspended");
        // ...and their bursts wake them through priced cold starts
        assert!(res.ticks.iter().any(|t| t.resuming > 0), "no cold-start window opened");
        let wakes: usize = res.ticks.iter().map(|t| t.resume_ends).sum();
        assert!(wakes > 0, "no cold-start window ever closed");
        let resumes: usize =
            fleet.tenants().iter().filter_map(Tenant::serverless).map(|s| s.resumes).sum();
        assert_eq!(wakes, resumes, "every admitted wake closes exactly once");
        assert!(fleet.storage().unwrap().total_gb() > 0.0);
    }

    /// The PR-3 projected-spend invariant must survive the serverless
    /// lifecycle: every state (draining, suspended, cold-starting,
    /// active-with-storage) prices exactly what the next tick pays.
    #[test]
    fn serverless_keeps_the_projected_spend_invariant() {
        let cfg = ModelConfig::default_paper();
        let specs = crate::serverless::mostly_idle_specs(&cfg, 8, 0.75);
        let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
        fleet.enable_serverless(ServerlessParams::default());
        let res = fleet.run(80);
        for w in res.ticks.windows(2) {
            assert!(
                (w[0].projected_spend - w[1].spend).abs() < 1e-3,
                "tick {}: projected {} vs served {}",
                w[0].step,
                w[0].projected_spend,
                w[1].spend
            );
        }
    }

    #[test]
    fn forecasting_fleet_runs_and_stays_within_budget() {
        let cfg = ModelConfig::default_paper();
        let budget = 8.0f32;
        for kind in [ForecastKind::Holt, ForecastKind::Seasonal] {
            let mut fleet = FleetSimulator::new(&cfg, specs(&cfg, 6), budget, 3);
            fleet.enable_forecasts(kind, 3);
            let res = fleet.run(60);
            assert!(res.within_budget(budget), "{kind:?} peak {}", res.peak_spend());
        }
    }
}
