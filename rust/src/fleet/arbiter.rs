//! Fleet budget arbiter: admission control for per-tenant scaling moves
//! under a shared monetary budget.
//!
//! Each tick every tenant proposes its best Algorithm-1 move; the
//! arbiter admits a subset so projected fleet spend never exceeds the
//! budget:
//!
//! 1. **Holds and shrinks** — no-ops and cost-non-increasing moves are
//!    always admitted (they free headroom before anything is spent).
//! 2. **Fairness rescues** — a tenant denied `fairness_k`+ consecutive
//!    ticks while SLA-violating goes to the front of the queue, ahead
//!    of every economic move; it is denied again only if its move does
//!    not fit the remaining budget after the cost cuts and any
//!    more-starved rescues.
//! 3. **Greedy knapsack** — remaining cost-increasing moves, ordered by
//!    priority class, then gain-per-dollar density, then smaller cost,
//!    admitted while they fit.
//!
//! The order is total (tenant id is the last tie-break), so admission is
//! deterministic and independent of proposal arrival order — a property
//! `rust/tests/prop_fleet.rs` asserts.

use super::tenant::Proposal;

/// Why a proposal was admitted or denied this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// No configuration change requested.
    Hold,
    /// Cost-non-increasing move: always admitted.
    AdmittedShrink,
    /// Admitted by the fairness guard (denial streak ≥ K while
    /// SLA-violating).
    AdmittedRescue,
    /// Admitted by the greedy knapsack.
    Admitted,
    /// Denied: admitting would push projected fleet spend over budget.
    DeniedBudget,
    /// The fairness guard applied, but the move does not fit the
    /// budget remaining after cost cuts and more-starved rescues.
    DeniedRescueUnaffordable,
}

impl Verdict {
    /// Whether the tenant may actuate its proposal.
    pub fn admitted(&self) -> bool {
        matches!(
            self,
            Verdict::Hold | Verdict::AdmittedShrink | Verdict::AdmittedRescue | Verdict::Admitted
        )
    }

    pub fn denied(&self) -> bool {
        !self.admitted()
    }
}

/// The arbiter's decision for one tick.
#[derive(Debug, Clone)]
pub struct Admission {
    /// Verdict per proposal, in input order.
    pub verdicts: Vec<Verdict>,
    /// Fleet spend before any admission (Σ cost of serving configs).
    pub base_spend: f32,
    /// Projected fleet spend after every admitted move takes effect
    /// (this is the next tick's spend).
    pub projected_spend: f32,
    /// Admitted configuration *changes* (holds excluded).
    pub admitted_moves: usize,
    pub denied_moves: usize,
    pub rescues: usize,
    pub rescue_denials: usize,
}

impl Admission {
    pub fn verdict_for(&self, proposals: &[Proposal], tenant: usize) -> Option<Verdict> {
        proposals
            .iter()
            .position(|p| p.tenant == tenant)
            .map(|i| self.verdicts[i])
    }
}

/// Fleet-level admission control under a shared budget.
#[derive(Debug, Clone, Copy)]
pub struct BudgetArbiter {
    /// Global hourly-cost budget the fleet must stay under.
    pub budget: f32,
    /// Fairness guard K: an SLA-violating tenant is denied at most K
    /// consecutive ticks before jumping ahead of every economic move
    /// (only budget exhaustion by more-starved rescues can extend it).
    pub fairness_k: usize,
}

impl BudgetArbiter {
    pub fn new(budget: f32, fairness_k: usize) -> Self {
        assert!(budget > 0.0, "budget must be positive");
        assert!(fairness_k > 0, "fairness K must be at least 1");
        Self { budget, fairness_k }
    }

    /// Decide every proposal for one tick. Projected spend starts at
    /// Σ `cost_from` and never exceeds `budget` through admissions
    /// (if the fleet already overspends — e.g. the budget was lowered
    /// mid-run — only shrinks are admitted until it fits again).
    pub fn admit(&self, proposals: &[Proposal]) -> Admission {
        let base_spend: f32 = proposals.iter().map(|p| p.cost_from).sum();
        let mut spend = base_spend;
        let mut verdicts = vec![Verdict::DeniedBudget; proposals.len()];

        // pass 0: holds + cost-non-increasing moves
        for (i, p) in proposals.iter().enumerate() {
            if !p.is_move() {
                verdicts[i] = Verdict::Hold;
            } else if p.cost_delta() <= 0.0 {
                verdicts[i] = Verdict::AdmittedShrink;
                spend += p.cost_delta();
            }
        }

        // pass 1: fairness rescues, most-starved first
        let mut rescue: Vec<usize> = (0..proposals.len())
            .filter(|&i| {
                verdicts[i] == Verdict::DeniedBudget
                    && proposals[i].sla_violating
                    && proposals[i].denial_streak >= self.fairness_k
            })
            .collect();
        rescue.sort_by(|&a, &b| {
            let (pa, pb) = (&proposals[a], &proposals[b]);
            pb.denial_streak
                .cmp(&pa.denial_streak)
                .then(pb.class.rank().cmp(&pa.class.rank()))
                .then(pb.density().total_cmp(&pa.density()))
                .then(pa.tenant.cmp(&pb.tenant))
        });
        for i in rescue {
            if spend + proposals[i].cost_delta() <= self.budget {
                verdicts[i] = Verdict::AdmittedRescue;
                spend += proposals[i].cost_delta();
            } else {
                verdicts[i] = Verdict::DeniedRescueUnaffordable;
            }
        }

        // pass 2: greedy knapsack over the remaining cost increases
        let mut rest: Vec<usize> = (0..proposals.len())
            .filter(|&i| verdicts[i] == Verdict::DeniedBudget)
            .collect();
        rest.sort_by(|&a, &b| {
            let (pa, pb) = (&proposals[a], &proposals[b]);
            pb.class
                .rank()
                .cmp(&pa.class.rank())
                .then(pb.density().total_cmp(&pa.density()))
                .then(pa.cost_delta().total_cmp(&pb.cost_delta()))
                .then(pa.tenant.cmp(&pb.tenant))
        });
        for i in rest {
            if spend + proposals[i].cost_delta() <= self.budget {
                verdicts[i] = Verdict::Admitted;
                spend += proposals[i].cost_delta();
            }
        }

        let admitted_moves = proposals
            .iter()
            .zip(&verdicts)
            .filter(|(p, v)| v.admitted() && p.is_move())
            .count();
        let denied_moves = verdicts.iter().filter(|v| v.denied()).count();
        Admission {
            rescues: verdicts.iter().filter(|&&v| v == Verdict::AdmittedRescue).count(),
            rescue_denials: verdicts
                .iter()
                .filter(|&&v| v == Verdict::DeniedRescueUnaffordable)
                .count(),
            verdicts,
            base_spend,
            projected_spend: spend,
            admitted_moves,
            denied_moves,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::tenant::PriorityClass;
    use crate::plane::Configuration;

    fn proposal(tenant: usize, class: PriorityClass, cost_from: f32, cost_to: f32) -> Proposal {
        Proposal {
            tenant,
            class,
            from: Configuration::new(0, 0),
            to: Configuration::new(1, 1),
            cost_from,
            cost_to,
            gain: 10.0,
            emergency: false,
            sla_violating: false,
            denial_streak: 0,
        }
    }

    fn hold(tenant: usize, cost: f32) -> Proposal {
        let c = Configuration::new(1, 1);
        Proposal {
            tenant,
            class: PriorityClass::Silver,
            from: c,
            to: c,
            cost_from: cost,
            cost_to: cost,
            gain: 0.0,
            emergency: false,
            sla_violating: false,
            denial_streak: 0,
        }
    }

    #[test]
    fn holds_and_shrinks_always_admitted() {
        let arb = BudgetArbiter::new(1.0, 3);
        let ps = vec![hold(0, 0.4), proposal(1, PriorityClass::Bronze, 0.5, 0.3)];
        let adm = arb.admit(&ps);
        assert_eq!(adm.verdicts[0], Verdict::Hold);
        assert_eq!(adm.verdicts[1], Verdict::AdmittedShrink);
        assert!((adm.projected_spend - 0.7).abs() < 1e-6);
    }

    #[test]
    fn budget_is_never_exceeded() {
        let arb = BudgetArbiter::new(2.0, 3);
        let ps = vec![
            proposal(0, PriorityClass::Gold, 0.5, 1.2),
            proposal(1, PriorityClass::Gold, 0.5, 1.2),
            proposal(2, PriorityClass::Gold, 0.5, 1.2),
        ];
        let adm = arb.admit(&ps);
        assert!(adm.projected_spend <= 2.0 + 1e-6);
        // only one 0.7 increase fits on top of the 1.5 base
        assert_eq!(adm.admitted_moves, 0);
        let arb = BudgetArbiter::new(2.3, 3);
        let adm = arb.admit(&ps);
        assert_eq!(adm.admitted_moves, 1);
        assert_eq!(adm.denied_moves, 2);
    }

    #[test]
    fn higher_class_wins_the_last_slot() {
        let arb = BudgetArbiter::new(1.7, 3);
        let ps = vec![
            proposal(0, PriorityClass::Bronze, 0.5, 1.2),
            proposal(1, PriorityClass::Gold, 0.5, 1.2),
        ];
        let adm = arb.admit(&ps);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
        assert_eq!(adm.verdicts[1], Verdict::Admitted);
    }

    #[test]
    fn rescue_preempts_higher_class_greedy() {
        // Bronze has starved past K while violating; Gold's economic move
        // competes for the same headroom — the rescue goes first.
        let arb = BudgetArbiter::new(1.7, 2);
        let mut bronze = proposal(0, PriorityClass::Bronze, 0.5, 1.2);
        bronze.sla_violating = true;
        bronze.denial_streak = 2;
        let gold = proposal(1, PriorityClass::Gold, 0.5, 1.2);
        let adm = arb.admit(&[bronze, gold]);
        assert_eq!(adm.verdicts[0], Verdict::AdmittedRescue);
        assert_eq!(adm.verdicts[1], Verdict::DeniedBudget);
        assert_eq!(adm.rescues, 1);
    }

    #[test]
    fn unaffordable_rescue_is_reported() {
        let arb = BudgetArbiter::new(1.0, 1);
        let mut p = proposal(0, PriorityClass::Bronze, 0.8, 4.0);
        p.sla_violating = true;
        p.denial_streak = 5;
        let adm = arb.admit(&[p]);
        assert_eq!(adm.verdicts[0], Verdict::DeniedRescueUnaffordable);
        assert_eq!(adm.rescue_denials, 1);
        assert!(adm.projected_spend <= 1.0);
    }

    #[test]
    fn emergencies_outrank_economic_moves_within_class() {
        let arb = BudgetArbiter::new(1.7, 3);
        let mut emergency = proposal(0, PriorityClass::Silver, 0.5, 1.2);
        emergency.emergency = true;
        emergency.gain = 0.1;
        let economic = proposal(1, PriorityClass::Silver, 0.5, 1.2);
        let adm = arb.admit(&[economic, emergency]);
        assert_eq!(adm.verdicts[1], Verdict::Admitted);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
    }

    #[test]
    fn overspent_fleet_admits_only_shrinks() {
        let arb = BudgetArbiter::new(1.0, 3);
        let ps = vec![
            proposal(0, PriorityClass::Gold, 1.0, 1.5),
            proposal(1, PriorityClass::Gold, 0.8, 0.4),
        ];
        let adm = arb.admit(&ps);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
        assert_eq!(adm.verdicts[1], Verdict::AdmittedShrink);
        assert!(adm.projected_spend < adm.base_spend);
    }
}
