//! Fleet budget arbiter: admission control for per-tenant scaling moves
//! under a shared monetary budget.
//!
//! Each tick every tenant proposes a *ranked candidate list* (best move
//! first, cheaper alternatives and stepping stones behind it) plus —
//! for tenants not repairing their own SLA — *shed offers* the arbiter
//! may actuate to fund someone else's repair. Admission walks, in
//! order:
//!
//! 1. **Holds and shrinks** — no-ops and cost-non-increasing best moves
//!    are always admitted (they free headroom before anything is
//!    spent).
//! 2. **Fairness rescues** — a tenant denied `fairness_k`+ consecutive
//!    ticks while SLA-violating goes to the front of the queue, ahead
//!    of every economic move; its candidate list is walked and may draw
//!    shed funding; it is denied again only when nothing fits even
//!    after re-negotiation.
//! 3. **SLA repairs** — remaining emergency/violating proposals,
//!    ordered by class, density, cost, id. Repairs outrank economic
//!    moves *fleet-wide* (a Bronze repair beats a Gold economic move),
//!    walk their candidate lists, may draw shed funding, and are
//!    exempt from class envelopes (envelopes shape discretionary
//!    spending, never SLA repair).
//! 4. **Economic knapsack** — remaining cost-increasing moves, ordered
//!    by priority class, then gain-per-dollar density, then smaller
//!    cost. Checked against both the budget and the class envelopes
//!    (with burst credits), and **frozen** for the tick whenever some
//!    SLA repair went unmet — freed headroom then accrues to the
//!    starving repair next tick instead of being re-consumed.
//!
//! The order is total (tenant id is the last tie-break), so admission
//! is deterministic and independent of proposal arrival order — a
//! property `rust/tests/prop_fleet.rs` asserts.
//!
//! [`BudgetArbiter::flat`] preserves the PR-2 baseline: first candidate
//! only, no re-negotiation, no envelopes — kept for A/B comparisons
//! (the fleet tests pin that planning strictly beats it on violations).

use std::collections::BinaryHeap;

use crate::policy::{PriorityClass, Proposal};
use crate::util::money;

/// Why a proposal was admitted or denied this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// No configuration change requested.
    Hold,
    /// Cost-non-increasing move: always admitted.
    AdmittedShrink,
    /// Admitted by the fairness guard (denial streak ≥ K while
    /// SLA-violating).
    AdmittedRescue,
    /// The preferred candidate was admitted.
    Admitted,
    /// A lower-ranked candidate was admitted: the first choice did not
    /// fit, the tenant degraded instead of being denied.
    AdmittedDegraded,
    /// A holding tenant's shed offer was actuated to fund another
    /// tenant's SLA repair (online budget re-negotiation).
    AdmittedShed,
    /// Denied: admitting would push projected fleet spend over budget
    /// (or past the class envelope, for economic moves).
    DeniedBudget,
    /// The fairness guard applied, but no candidate fit the budget
    /// remaining after cost cuts, more-starved rescues, and shed
    /// funding.
    DeniedRescueUnaffordable,
}

impl Verdict {
    /// Whether the tenant actuates a configuration change (or hold).
    pub fn admitted(&self) -> bool {
        !self.denied()
    }

    pub fn denied(&self) -> bool {
        matches!(self, Verdict::DeniedBudget | Verdict::DeniedRescueUnaffordable)
    }
}

/// Fraction of another class's *unused* envelope headroom a class may
/// borrow as burst credits. Borrowing everything would make envelopes
/// vacuous (envelope + full burst is never tighter than the plain
/// budget check when shares sum to 1); half keeps the other half
/// reserved for its owner within the tick.
pub const BURST_FRACTION: f32 = 0.5;

/// Per-class budget envelopes: each priority class owns a share of the
/// fleet budget for *economic* (discretionary) scaling. A class may
/// borrow up to [`BURST_FRACTION`] of each other class's unused
/// envelope headroom — burst credits — within a tick; because
/// envelopes are re-derived from actual class spend every tick,
/// borrowed headroom is implicitly reclaimed at the next tick: a class
/// left above its envelope can only shrink (or repair SLAs) until it
/// fits its share again. SLA repairs and rescues ignore envelopes by
/// design.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassEnvelopes {
    /// Budget share per class, indexed by [`PriorityClass::rank`]
    /// (bronze, silver, gold). Normalized to sum to 1.
    shares: [f32; 3],
}

impl ClassEnvelopes {
    /// Shares in Gold/Silver/Bronze order; must be positive. They are
    /// normalized, so any positive weights work.
    pub fn new(gold: f32, silver: f32, bronze: f32) -> Self {
        assert!(
            gold > 0.0 && silver > 0.0 && bronze > 0.0,
            "envelope shares must be positive"
        );
        let total = gold + silver + bronze;
        Self { shares: [bronze / total, silver / total, gold / total] }
    }

    /// The configuration-time baseline: fixed shares that never adapt
    /// (alias of [`Self::new`], named for the A/B against
    /// [`EnvelopeAdapter`]-driven re-weighting).
    pub fn fixed(gold: f32, silver: f32, bronze: f32) -> Self {
        Self::new(gold, silver, bronze)
    }

    /// The default split: half the budget for Gold, 30% Silver, 20%
    /// Bronze.
    pub fn default_split() -> Self {
        Self::new(0.5, 0.3, 0.2)
    }

    /// This class's share of the budget.
    pub fn share(&self, class: PriorityClass) -> f32 {
        self.shares[class.rank() as usize]
    }

    /// This class's envelope in absolute budget units.
    pub fn envelope(&self, class: PriorityClass, budget: f32) -> f32 {
        self.share(class) * budget
    }

    /// Economic headroom of `class` given the current per-class spend
    /// (indexed by rank): its envelope plus [`BURST_FRACTION`] of each
    /// other class's unused envelope headroom, minus its own spend.
    /// May be negative when the class sits above its envelope — the
    /// single formula both the arbiter's admission check and the
    /// fleet's per-tenant [`crate::policy::BudgetHint`] derive from.
    pub fn class_headroom(
        &self,
        class: PriorityClass,
        class_spend: &[f32; 3],
        budget: f32,
    ) -> f32 {
        let rank = class.rank() as usize;
        // the burst pool folds per-class headrooms in f64 (money
        // accumulates in f64, narrowed once — see `util::money`)
        let pool: f64 = (0..3)
            .filter(|&r| r != rank)
            .map(|r| {
                (self.envelope(PriorityClass::from_rank(r as u8), budget) - class_spend[r])
                    .max(0.0) as f64
            })
            .sum();
        let burst = money::narrow(BURST_FRACTION as f64 * pool);
        self.envelope(class, budget) + burst - class_spend[rank]
    }

    /// Parse `"g:s:b"` (e.g. `"0.5:0.3:0.2"`) or the `"default"`
    /// keyword.
    pub fn parse(text: &str) -> Option<Self> {
        if text == "default" {
            return Some(Self::default_split());
        }
        let parts: Vec<f32> =
            text.split(':').map(|p| p.trim().parse().ok()).collect::<Option<_>>()?;
        match parts[..] {
            [g, s, b] if g > 0.0 && s > 0.0 && b > 0.0 => Some(Self::new(g, s, b)),
            _ => None,
        }
    }
}

/// EWMA smoothing for adaptive envelope re-weighting: how fast the
/// per-class contention estimate tracks the latest tick.
pub const ADAPT_ALPHA: f32 = 0.2;

/// How strongly observed contention bends the envelope shares: a class
/// carrying *all* the fleet's contention grows its share by at most
/// this fraction of its base share (before renormalization).
pub const ADAPT_STRENGTH: f32 = 1.0;

/// Dynamic envelope re-weighting: instead of fixing class shares at
/// configuration time ([`ClassEnvelopes::fixed`]), derive them from an
/// EWMA of observed per-class *contention* — denials plus
/// SLA-violation ticks. A class that keeps getting denied while
/// violating earns a larger slice of the discretionary budget; calm
/// classes cede theirs. With zero observed contention the shares sit
/// exactly at the base split, so the adapter is a no-op until pressure
/// appears.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnvelopeAdapter {
    base: ClassEnvelopes,
    /// Contention EWMA, indexed by [`PriorityClass::rank`].
    ewma: [f32; 3],
}

impl EnvelopeAdapter {
    pub fn new(base: ClassEnvelopes) -> Self {
        Self { base, ewma: [0.0; 3] }
    }

    /// The configuration-time split the adapter bends.
    pub fn base(&self) -> ClassEnvelopes {
        self.base
    }

    /// Current contention estimate (rank-indexed; diagnostics/tests).
    pub fn ewma(&self) -> [f32; 3] {
        self.ewma
    }

    /// Fold one tick's per-class contention (rank-indexed counts of
    /// denials + violation ticks) into the EWMA and return the
    /// re-weighted envelopes.
    pub fn observe(&mut self, contention: [f32; 3]) -> ClassEnvelopes {
        for r in 0..3 {
            self.ewma[r] = (1.0 - ADAPT_ALPHA) * self.ewma[r] + ADAPT_ALPHA * contention[r];
        }
        let total: f32 = self.ewma.iter().sum();
        if total <= 1e-9 {
            return self.base;
        }
        let share = |class: PriorityClass| {
            let r = class.rank() as usize;
            self.base.share(class) * (1.0 + ADAPT_STRENGTH * self.ewma[r] / total)
        };
        // ClassEnvelopes::new renormalizes, so only the relative bend
        // matters; every share stays strictly positive
        ClassEnvelopes::new(
            share(PriorityClass::Gold),
            share(PriorityClass::Silver),
            share(PriorityClass::Bronze),
        )
    }
}

/// The arbiter's decision for one tick.
#[derive(Debug, Clone)]
pub struct Admission {
    /// Verdict per proposal, in input order.
    pub verdicts: Vec<Verdict>,
    /// For each admitted proposal, which option was actuated: an index
    /// into `candidates` (moves) or into `sheds` (for
    /// [`Verdict::AdmittedShed`]). `None` for holds and denials.
    pub chosen: Vec<Option<usize>>,
    /// Fleet spend before any admission (Σ cost of serving configs).
    pub base_spend: f32,
    /// Projected fleet spend after every admitted move takes effect
    /// (this is the next tick's spend).
    pub projected_spend: f32,
    /// Admitted configuration *changes* (holds and sheds excluded).
    pub admitted_moves: usize,
    pub denied_moves: usize,
    pub rescues: usize,
    pub rescue_denials: usize,
    /// Moves admitted as a lower-ranked candidate.
    pub degraded_moves: usize,
    /// Shed offers actuated to fund SLA repairs.
    pub shed_moves: usize,
}

impl Admission {
    pub fn verdict_for(&self, proposals: &[Proposal], tenant: usize) -> Option<Verdict> {
        proposals
            .iter()
            .position(|p| p.tenant == tenant)
            .map(|i| self.verdicts[i])
    }
}

/// Per-slot `cost_from` ledger the fleet maintains *incrementally*:
/// each tick only the slots whose tenants re-proposed (the dirty set)
/// are re-recorded; clean slots keep their entry, since a replayed hold
/// carries a bitwise-unchanged `cost_from`.
///
/// Totals are produced by folding the flat entry array in slot order —
/// bitwise identical to walking the proposal slice itself, which is
/// exactly what keeps a dirty-queue fleet's admission decisions
/// bit-equal to an always-replan fleet's (`tests/prop_dirty.rs`). The
/// fold touches 5 bytes per tenant instead of each `Proposal`, so
/// envelope accounting no longer re-reads every ranked candidate list.
#[derive(Debug, Clone, Default)]
pub struct SpendLedger {
    /// `(cost_from, class rank)` per proposal slot.
    entries: Vec<(f32, u8)>,
}

impl SpendLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record slot `i`'s serving cost and class (growing the ledger on
    /// first sight of the slot).
    pub fn record(&mut self, i: usize, cost_from: f32, class: PriorityClass) {
        if i >= self.entries.len() {
            self.entries.resize(i + 1, (0.0, 0));
        }
        self.entries[i] = (cost_from, class.rank());
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// `(fleet spend, per-class spend by rank)` — an f64 slot-order
    /// fold, matching the proposal-walk fold bit for bit.
    pub fn totals(&self) -> (f64, [f64; 3]) {
        let mut spend = 0.0f64;
        let mut class_spend = [0.0f64; 3];
        for &(cost, rank) in &self.entries {
            spend += cost as f64;
            class_spend[rank as usize] += cost as f64;
        }
        (spend, class_spend)
    }
}

/// Max-heap key reproducing [`BudgetArbiter::knapsack_key`] *within one
/// class*: the greatest element is the densest proposal, cheaper first,
/// then smaller tenant id. Tenant ids are unique, so the order is
/// strict and the heap's pop sequence equals the sorted sequence.
#[derive(Debug, Clone, Copy)]
struct HeapKey {
    density: f32,
    cost_delta: f32,
    tenant: usize,
    idx: usize,
}

impl HeapKey {
    fn of(idx: usize, p: &Proposal) -> Self {
        Self { density: p.density(), cost_delta: p.cost_delta(), tenant: p.tenant, idx }
    }
}

impl Ord for HeapKey {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.density
            .total_cmp(&o.density)
            .then(o.cost_delta.total_cmp(&self.cost_delta))
            .then(o.tenant.cmp(&self.tenant))
    }
}

impl PartialOrd for HeapKey {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

impl PartialEq for HeapKey {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == std::cmp::Ordering::Equal
    }
}

impl Eq for HeapKey {}

/// Max-heap key reproducing the rescue order: most-starved first, then
/// class, density, tenant id (see [`BudgetArbiter::rescue_order`]).
#[derive(Debug, Clone, Copy)]
struct RescueKey {
    streak: usize,
    class_rank: u8,
    density: f32,
    tenant: usize,
    idx: usize,
}

impl Ord for RescueKey {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.streak
            .cmp(&o.streak)
            .then(self.class_rank.cmp(&o.class_rank))
            .then(self.density.total_cmp(&o.density))
            .then(o.tenant.cmp(&self.tenant))
    }
}

impl PartialOrd for RescueKey {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

impl PartialEq for RescueKey {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == std::cmp::Ordering::Equal
    }
}

impl Eq for RescueKey {}

/// Fleet-level admission control under a shared budget.
#[derive(Debug, Clone, Copy)]
pub struct BudgetArbiter {
    /// Global hourly-cost budget the fleet must stay under.
    pub budget: f32,
    /// Fairness guard K: an SLA-violating tenant is denied at most K
    /// consecutive ticks before jumping ahead of every economic move
    /// (only budget exhaustion by more-starved rescues can extend it).
    pub fairness_k: usize,
    /// Walk ranked candidate lists and re-negotiate via sheds (the PR-3
    /// planning admission). `false` restores the PR-2 flat-denial
    /// baseline: first candidate only, one knapsack.
    pub planning: bool,
    /// Optional per-class envelopes with burst credits, applied to
    /// economic moves when `planning` is on.
    pub envelopes: Option<ClassEnvelopes>,
    /// Indexed admission (default): per-class priority heaps built from
    /// the cost-increasing movers in the single pass-0 walk, popped
    /// lazily, instead of three global `sort_by` passes over all N
    /// slots. The pop sequence is provably the sorted sequence (the
    /// knapsack order is strict), and `sorted_reference()` keeps the
    /// sort-based path alive for differential testing.
    pub indexed: bool,
}

impl BudgetArbiter {
    /// The planning arbiter (candidate walks + re-negotiation), no
    /// envelopes.
    pub fn new(budget: f32, fairness_k: usize) -> Self {
        assert!(budget > 0.0, "budget must be positive");
        assert!(fairness_k > 0, "fairness K must be at least 1");
        Self { budget, fairness_k, planning: true, envelopes: None, indexed: true }
    }

    /// Register the arbiter's configuration gauges into the pull-based
    /// export registry (`fleet --metrics-out`): the budget every
    /// admission runs against, the starvation guard, whether planning
    /// admission is on, and the per-class envelope shares when set.
    pub fn export_metrics(&self, reg: &mut crate::metrics::MetricsRegistry) {
        use crate::metrics::names;
        reg.set(names::ARBITER_BUDGET_HOURLY, &[], self.budget as f64);
        reg.set(names::ARBITER_FAIRNESS_K, &[], self.fairness_k as f64);
        reg.set(names::ARBITER_PLANNING, &[], if self.planning { 1.0 } else { 0.0 });
        if let Some(env) = &self.envelopes {
            for class in PriorityClass::ALL {
                reg.set(
                    names::ARBITER_ENVELOPE_SHARE,
                    &[("class", class.label())],
                    env.share(class) as f64,
                );
            }
        }
    }

    /// The PR-2 flat-denial baseline (first candidate only).
    pub fn flat(budget: f32, fairness_k: usize) -> Self {
        Self { planning: false, ..Self::new(budget, fairness_k) }
    }

    /// Builder: apply per-class envelopes (planning mode only).
    pub fn with_envelopes(mut self, envelopes: ClassEnvelopes) -> Self {
        self.envelopes = Some(envelopes);
        self
    }

    /// Builder: use the pre-index global-sort passes (the reference
    /// implementation the heap path is differentially tested against).
    pub fn sorted_reference(mut self) -> Self {
        self.indexed = false;
        self
    }

    /// Decide every proposal for one tick. Projected spend starts at
    /// Σ `cost_from` and never exceeds `budget` through admissions
    /// (if the fleet already overspends — e.g. the budget was lowered
    /// mid-run — only shrinks are admitted until it fits again).
    pub fn admit(&self, proposals: &[Proposal]) -> Admission {
        if self.planning {
            let mut spend = 0.0f64;
            let mut class_spend = [0.0f64; 3];
            for p in proposals {
                spend += p.cost_from as f64;
                class_spend[p.class.rank() as usize] += p.cost_from as f64;
            }
            self.admit_planning(proposals, spend, class_spend)
        } else {
            self.admit_flat(proposals)
        }
    }

    /// [`Self::admit`] with base spend taken from an incrementally
    /// maintained [`SpendLedger`] instead of a fresh walk over every
    /// proposal's `cost_from`. The ledger's slot-order fold is bitwise
    /// identical to the walk, so decisions cannot differ.
    pub fn admit_ledgered(&self, proposals: &[Proposal], ledger: &SpendLedger) -> Admission {
        debug_assert_eq!(ledger.len(), proposals.len(), "ledger must cover every slot");
        if self.planning {
            let (spend, class_spend) = ledger.totals();
            self.admit_planning(proposals, spend, class_spend)
        } else {
            self.admit_flat(proposals)
        }
    }

    /// Exact PR-2 admission: first candidate only, one knapsack, no
    /// envelopes, no re-negotiation. Spend accumulates in f64 (10k
    /// f32-summed tenants lose real pennies) and narrows at the edge.
    fn admit_flat(&self, proposals: &[Proposal]) -> Admission {
        let base_spend: f64 = proposals.iter().map(|p| p.cost_from as f64).sum();
        let budget = self.budget as f64;
        let mut spend = base_spend;
        let mut verdicts = vec![Verdict::DeniedBudget; proposals.len()];
        let mut chosen: Vec<Option<usize>> = vec![None; proposals.len()];

        // pass 0: holds + cost-non-increasing moves
        for (i, p) in proposals.iter().enumerate() {
            if !p.is_move() {
                verdicts[i] = Verdict::Hold;
            } else if p.cost_delta() <= 0.0 {
                verdicts[i] = Verdict::AdmittedShrink;
                chosen[i] = Some(0);
                spend += p.cost_delta() as f64;
            }
        }

        // pass 1: fairness rescues, most-starved first
        for i in self.rescue_order(proposals, &verdicts) {
            if spend + proposals[i].cost_delta() as f64 <= budget {
                verdicts[i] = Verdict::AdmittedRescue;
                chosen[i] = Some(0);
                spend += proposals[i].cost_delta() as f64;
            } else {
                verdicts[i] = Verdict::DeniedRescueUnaffordable;
            }
        }

        // pass 2: greedy knapsack over the remaining cost increases
        let mut rest: Vec<usize> = (0..proposals.len())
            .filter(|&i| verdicts[i] == Verdict::DeniedBudget)
            .collect();
        rest.sort_by(|&a, &b| Self::knapsack_key(&proposals[a], &proposals[b]));
        for i in rest {
            if spend + proposals[i].cost_delta() as f64 <= budget {
                verdicts[i] = Verdict::Admitted;
                chosen[i] = Some(0);
                spend += proposals[i].cost_delta() as f64;
            }
        }

        Self::tally(proposals, verdicts, chosen, base_spend, spend)
    }

    /// PR-3 planning admission: candidate-list walks, shed funding,
    /// repair-before-economic ordering, envelopes with burst credits.
    ///
    /// `spend`/`class_spend` arrive precomputed — a fresh proposal walk
    /// in [`Self::admit`], an incrementally maintained ledger fold in
    /// [`Self::admit_ledgered`]; the two are bitwise identical — and
    /// all accounting stays in f64 until [`Self::tally`] narrows the
    /// edges (f32 accumulators lose real pennies at 10k tenants).
    ///
    /// With `indexed` (the default) the rescue/repair/economic
    /// sequences come from priority heaps filled during the single
    /// pass-0 walk — per class for the knapsack passes, so Gold drains
    /// before Silver before Bronze exactly as the class-major sort did
    /// — with already-decided entries skipped lazily at pop. Every heap
    /// order is strict (tenant id breaks the last tie), so pop
    /// sequences equal the [`Self::sorted_reference`] sequences element
    /// for element; ordering work drops from three O(N log N) sorts
    /// over all slots to O(D log D) over the cost-increasing movers,
    /// which the fleet's dirty queue keeps proportional to *activity*.
    fn admit_planning(
        &self,
        proposals: &[Proposal],
        spend: f64,
        class_spend: [f64; 3],
    ) -> Admission {
        let n = proposals.len();
        let base_spend = spend;
        let budget = self.budget as f64;
        let mut spend = spend;
        // per-class spend, indexed by rank (bronze, silver, gold)
        let mut class_spend = class_spend;
        let mut verdicts = vec![Verdict::DeniedBudget; n];
        let mut chosen: Vec<Option<usize>> = vec![None; n];

        // Admission epsilon: shed funding targets exact deficits, so a
        // funded move lands exactly on the budget boundary in real
        // arithmetic — widening noise from the f32 proposal costs must
        // not flip those admissions. 1e-4 is three orders below the
        // cheapest tier step (0.08/h), so no real overrun can slip
        // through, and it stays well inside the fleet-level
        // [`super::BUDGET_EPS`].
        const FIT_EPS: f64 = 1e-4;
        // a cost delta fits when the fleet budget holds and — for
        // envelope-checked (economic) admissions — the class stays
        // within its envelope plus burst credits (the same
        // [`ClassEnvelopes::class_headroom`] the fleet's budget hints
        // are derived from)
        let fits = |spend: f64, class_spend: &[f64; 3], class: PriorityClass, delta: f64,
                    check_env: bool| {
            if spend + delta > budget + FIT_EPS {
                return false;
            }
            if check_env && delta > 0.0 {
                if let Some(e) = &self.envelopes {
                    let cs = [
                        money::narrow(class_spend[0]),
                        money::narrow(class_spend[1]),
                        money::narrow(class_spend[2]),
                    ];
                    if delta > e.class_headroom(class, &cs, self.budget) as f64 + FIT_EPS {
                        return false;
                    }
                }
            }
            true
        };

        // actuate option `ci` (candidate, or shed when `shed`) of
        // proposal `i`
        macro_rules! take {
            ($i:expr, $ci:expr, $shed:expr) => {{
                let p = &proposals[$i];
                let opt =
                    if $shed { &p.sheds[$ci] } else { &p.candidates[$ci] };
                let delta = (opt.cost_to - p.cost_from) as f64;
                spend += delta;
                class_spend[p.class.rank() as usize] += delta;
                chosen[$i] = Some($ci);
            }};
        }

        // shed offers from tenants still holding or awaiting the
        // economic pass: bronze yields first, least objective sacrifice
        // first, tenant id last. All-or-nothing: sheds actuate only
        // when their combined savings cover the deficit, so no tenant
        // is pushed down without funding an admission.
        macro_rules! fund {
            ($deficit:expr) => {{
                let deficit: f64 = $deficit;
                let mut offers: Vec<usize> = (0..n)
                    .filter(|&j| {
                        matches!(verdicts[j], Verdict::Hold | Verdict::DeniedBudget)
                            // never scale down a tenant that is itself
                            // repairing its SLA, even if a caller hands
                            // us a repair proposal carrying shed offers
                            && !proposals[j].is_repair()
                            && proposals[j]
                                .sheds
                                .first()
                                .map_or(false, |s| s.cost_to < proposals[j].cost_from)
                    })
                    .collect();
                offers.sort_by(|&a, &b| {
                    let (pa, pb) = (&proposals[a], &proposals[b]);
                    pa.class
                        .rank()
                        .cmp(&pb.class.rank())
                        .then(pa.sheds[0].gain.total_cmp(&pb.sheds[0].gain))
                        .then(pa.tenant.cmp(&pb.tenant))
                });
                let capacity: f64 = offers
                    .iter()
                    .map(|&j| (proposals[j].cost_from - proposals[j].sheds[0].cost_to) as f64)
                    .sum();
                if capacity >= deficit - 1e-6 {
                    let mut freed = 0.0f64;
                    for j in offers {
                        if freed >= deficit - 1e-6 {
                            break;
                        }
                        verdicts[j] = Verdict::AdmittedShed;
                        freed += (proposals[j].cost_from - proposals[j].sheds[0].cost_to) as f64;
                        take!(j, 0, true);
                    }
                }
            }};
        }

        // walk proposal `i`'s candidate list; admit the first option
        // that fits, drawing shed funding for the preferred candidate
        // when allowed. Returns true when something was admitted.
        macro_rules! try_admit {
            ($i:expr, $first:expr, $rest:expr, $check_env:expr, $can_fund:expr) => {{
                let i: usize = $i;
                let p = &proposals[i];
                let mut admitted = verdicts[i] != Verdict::DeniedBudget;
                // (skip proposals a funding pass already decided)
                for ci in 0..p.candidates.len() {
                    if admitted {
                        break;
                    }
                    let delta = (p.candidates[ci].cost_to - p.cost_from) as f64;
                    if fits(spend, &class_spend, p.class, delta, $check_env) {
                        verdicts[i] = if ci == 0 { $first } else { $rest };
                        take!(i, ci, false);
                        admitted = true;
                        break;
                    }
                    if $can_fund && ci == 0 {
                        let deficit = (spend + delta) - budget;
                        if deficit > 0.0 {
                            fund!(deficit);
                            if fits(spend, &class_spend, p.class, delta, $check_env) {
                                verdicts[i] = $first;
                                take!(i, ci, false);
                                admitted = true;
                                break;
                            }
                        }
                    }
                }
                admitted
            }};
        }

        // pass 0: holds + cost-non-increasing best moves. The same walk
        // indexes every remaining (cost-increasing) mover into the
        // later passes' priority heaps — the only proposals those
        // passes can touch; entries a pass decides are skipped lazily
        // when a later pop surfaces them.
        let mut rescue_heap: BinaryHeap<RescueKey> = BinaryHeap::new();
        let mut repair_heaps: [BinaryHeap<HeapKey>; 3] =
            [BinaryHeap::new(), BinaryHeap::new(), BinaryHeap::new()];
        let mut econ_heaps: [BinaryHeap<HeapKey>; 3] =
            [BinaryHeap::new(), BinaryHeap::new(), BinaryHeap::new()];
        for (i, p) in proposals.iter().enumerate() {
            if !p.is_move() {
                verdicts[i] = Verdict::Hold;
            } else if p.cost_delta() <= 0.0 {
                verdicts[i] = Verdict::AdmittedShrink;
                take!(i, 0, false);
            } else if self.indexed {
                if p.sla_violating && p.denial_streak >= self.fairness_k {
                    rescue_heap.push(RescueKey {
                        streak: p.denial_streak,
                        class_rank: p.class.rank(),
                        density: p.density(),
                        tenant: p.tenant,
                        idx: i,
                    });
                }
                let rank = p.class.rank() as usize;
                if p.is_repair() {
                    repair_heaps[rank].push(HeapKey::of(i, p));
                } else {
                    econ_heaps[rank].push(HeapKey::of(i, p));
                }
            }
        }

        // pass 1: fairness rescues — candidate walks + shed funding,
        // envelope-exempt
        let mut unmet_repair = false;
        if self.indexed {
            while let Some(r) = rescue_heap.pop() {
                let i = r.idx;
                if verdicts[i] != Verdict::DeniedBudget {
                    continue;
                }
                if !try_admit!(i, Verdict::AdmittedRescue, Verdict::AdmittedRescue, false, true) {
                    verdicts[i] = Verdict::DeniedRescueUnaffordable;
                    unmet_repair = true;
                }
            }
        } else {
            for i in self.rescue_order(proposals, &verdicts) {
                if !try_admit!(i, Verdict::AdmittedRescue, Verdict::AdmittedRescue, false, true) {
                    verdicts[i] = Verdict::DeniedRescueUnaffordable;
                    unmet_repair = true;
                }
            }
        }

        // pass 2: SLA repairs fleet-wide ahead of economic moves,
        // envelope-exempt, shed-fundable. Gold drains before Silver
        // before Bronze — class is the knapsack order's major key, so
        // per-class heaps popped in rank order equal the global sort.
        if self.indexed {
            for rank in (0..3).rev() {
                while let Some(k) = repair_heaps[rank].pop() {
                    let i = k.idx;
                    if verdicts[i] != Verdict::DeniedBudget {
                        continue;
                    }
                    if !try_admit!(i, Verdict::Admitted, Verdict::AdmittedDegraded, false, true) {
                        unmet_repair = true;
                    }
                }
            }
        } else {
            let mut repairs: Vec<usize> = (0..n)
                .filter(|&i| verdicts[i] == Verdict::DeniedBudget && proposals[i].is_repair())
                .collect();
            repairs.sort_by(|&a, &b| Self::knapsack_key(&proposals[a], &proposals[b]));
            for i in repairs {
                if !try_admit!(i, Verdict::Admitted, Verdict::AdmittedDegraded, false, true) {
                    unmet_repair = true;
                }
            }
        }

        // pass 3: economic knapsack — envelope-checked, frozen while
        // any SLA repair went unmet this tick. With no unmet repair
        // every repair mover was decided above, so the economic heaps
        // (non-repair movers) cover exactly the reference's remainder.
        if !unmet_repair {
            if self.indexed {
                for rank in (0..3).rev() {
                    while let Some(k) = econ_heaps[rank].pop() {
                        let i = k.idx;
                        if verdicts[i] != Verdict::DeniedBudget {
                            continue;
                        }
                        try_admit!(i, Verdict::Admitted, Verdict::AdmittedDegraded, true, false);
                    }
                }
            } else {
                let mut rest: Vec<usize> = (0..n)
                    .filter(|&i| verdicts[i] == Verdict::DeniedBudget)
                    .collect();
                rest.sort_by(|&a, &b| Self::knapsack_key(&proposals[a], &proposals[b]));
                for i in rest {
                    try_admit!(i, Verdict::Admitted, Verdict::AdmittedDegraded, true, false);
                }
            }
        }

        Self::tally(proposals, verdicts, chosen, base_spend, spend)
    }

    /// Starved SLA-violating proposals, most-starved first.
    fn rescue_order(&self, proposals: &[Proposal], verdicts: &[Verdict]) -> Vec<usize> {
        let mut rescue: Vec<usize> = (0..proposals.len())
            .filter(|&i| {
                verdicts[i] == Verdict::DeniedBudget
                    && proposals[i].sla_violating
                    && proposals[i].denial_streak >= self.fairness_k
            })
            .collect();
        rescue.sort_by(|&a, &b| {
            let (pa, pb) = (&proposals[a], &proposals[b]);
            pb.denial_streak
                .cmp(&pa.denial_streak)
                .then(pb.class.rank().cmp(&pa.class.rank()))
                .then(pb.density().total_cmp(&pa.density()))
                .then(pa.tenant.cmp(&pb.tenant))
        });
        rescue
    }

    /// Total knapsack order: class rank desc, density desc, cheaper
    /// first, tenant id asc.
    fn knapsack_key(pa: &Proposal, pb: &Proposal) -> std::cmp::Ordering {
        pb.class
            .rank()
            .cmp(&pa.class.rank())
            .then(pb.density().total_cmp(&pa.density()))
            .then(pa.cost_delta().total_cmp(&pb.cost_delta()))
            .then(pa.tenant.cmp(&pb.tenant))
    }

    fn tally(
        proposals: &[Proposal],
        verdicts: Vec<Verdict>,
        chosen: Vec<Option<usize>>,
        base_spend: f64,
        spend: f64,
    ) -> Admission {
        let admitted_moves = proposals
            .iter()
            .zip(&verdicts)
            .filter(|(p, v)| {
                v.admitted() && p.is_move() && !matches!(v, Verdict::Hold | Verdict::AdmittedShed)
            })
            .count();
        let denied_moves = verdicts.iter().filter(|v| v.denied()).count();
        Admission {
            rescues: verdicts.iter().filter(|&&v| v == Verdict::AdmittedRescue).count(),
            rescue_denials: verdicts
                .iter()
                .filter(|&&v| v == Verdict::DeniedRescueUnaffordable)
                .count(),
            degraded_moves: verdicts
                .iter()
                .filter(|&&v| v == Verdict::AdmittedDegraded)
                .count(),
            shed_moves: verdicts.iter().filter(|&&v| v == Verdict::AdmittedShed).count(),
            verdicts,
            chosen,
            base_spend: money::narrow(base_spend),
            projected_spend: money::narrow(spend),
            admitted_moves,
            denied_moves,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::tenant::{Candidate, PriorityClass};
    use crate::plane::Configuration;

    fn candidate(to: Configuration, cost_to: f32, gain: f32) -> Candidate {
        Candidate::priced(to, cost_to, gain)
    }

    fn proposal(tenant: usize, class: PriorityClass, cost_from: f32, cost_to: f32) -> Proposal {
        Proposal {
            tenant,
            class,
            from: Configuration::new(0, 0),
            cost_from,
            current_score: 0.0,
            emergency: false,
            sla_violating: false,
            denial_streak: 0,
            fallback: false,
            candidates: vec![candidate(Configuration::new(1, 1), cost_to, 10.0)],
            sheds: Vec::new(),
        }
    }

    fn hold(tenant: usize, cost: f32) -> Proposal {
        Proposal {
            tenant,
            class: PriorityClass::Silver,
            from: Configuration::new(1, 1),
            cost_from: cost,
            current_score: 0.0,
            emergency: false,
            sla_violating: false,
            denial_streak: 0,
            fallback: false,
            candidates: Vec::new(),
            sheds: Vec::new(),
        }
    }

    #[test]
    fn holds_and_shrinks_always_admitted() {
        let arb = BudgetArbiter::new(1.0, 3);
        let ps = vec![hold(0, 0.4), proposal(1, PriorityClass::Bronze, 0.5, 0.3)];
        let adm = arb.admit(&ps);
        assert_eq!(adm.verdicts[0], Verdict::Hold);
        assert_eq!(adm.verdicts[1], Verdict::AdmittedShrink);
        assert!((adm.projected_spend - 0.7).abs() < 1e-6);
    }

    #[test]
    fn budget_is_never_exceeded() {
        let arb = BudgetArbiter::new(2.0, 3);
        let ps = vec![
            proposal(0, PriorityClass::Gold, 0.5, 1.2),
            proposal(1, PriorityClass::Gold, 0.5, 1.2),
            proposal(2, PriorityClass::Gold, 0.5, 1.2),
        ];
        let adm = arb.admit(&ps);
        assert!(adm.projected_spend <= 2.0 + 1e-6);
        // only one 0.7 increase fits on top of the 1.5 base
        assert_eq!(adm.admitted_moves, 0);
        let arb = BudgetArbiter::new(2.3, 3);
        let adm = arb.admit(&ps);
        assert_eq!(adm.admitted_moves, 1);
        assert_eq!(adm.denied_moves, 2);
    }

    #[test]
    fn higher_class_wins_the_last_slot() {
        let arb = BudgetArbiter::new(1.7, 3);
        let ps = vec![
            proposal(0, PriorityClass::Bronze, 0.5, 1.2),
            proposal(1, PriorityClass::Gold, 0.5, 1.2),
        ];
        let adm = arb.admit(&ps);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
        assert_eq!(adm.verdicts[1], Verdict::Admitted);
    }

    #[test]
    fn rescue_preempts_higher_class_greedy() {
        // Bronze has starved past K while violating; Gold's economic move
        // competes for the same headroom — the rescue goes first.
        let arb = BudgetArbiter::new(1.7, 2);
        let mut bronze = proposal(0, PriorityClass::Bronze, 0.5, 1.2);
        bronze.sla_violating = true;
        bronze.denial_streak = 2;
        let gold = proposal(1, PriorityClass::Gold, 0.5, 1.2);
        let adm = arb.admit(&[bronze, gold]);
        assert_eq!(adm.verdicts[0], Verdict::AdmittedRescue);
        assert_eq!(adm.verdicts[1], Verdict::DeniedBudget);
        assert_eq!(adm.rescues, 1);
    }

    #[test]
    fn unaffordable_rescue_is_reported() {
        let arb = BudgetArbiter::new(1.0, 1);
        let mut p = proposal(0, PriorityClass::Bronze, 0.8, 4.0);
        p.sla_violating = true;
        p.denial_streak = 5;
        let adm = arb.admit(&[p]);
        assert_eq!(adm.verdicts[0], Verdict::DeniedRescueUnaffordable);
        assert_eq!(adm.rescue_denials, 1);
        assert!(adm.projected_spend <= 1.0);
    }

    #[test]
    fn emergencies_outrank_economic_moves_within_class() {
        let arb = BudgetArbiter::new(1.7, 3);
        let mut emergency = proposal(0, PriorityClass::Silver, 0.5, 1.2);
        emergency.emergency = true;
        emergency.candidates[0].gain = 0.1;
        let economic = proposal(1, PriorityClass::Silver, 0.5, 1.2);
        let adm = arb.admit(&[economic, emergency]);
        assert_eq!(adm.verdicts[1], Verdict::Admitted);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
    }

    #[test]
    fn repairs_outrank_economic_moves_across_classes() {
        // a Bronze SLA repair beats a Gold economic move fleet-wide
        let arb = BudgetArbiter::new(1.7, 3);
        let mut bronze = proposal(0, PriorityClass::Bronze, 0.5, 1.2);
        bronze.sla_violating = true;
        let gold = proposal(1, PriorityClass::Gold, 0.5, 1.2);
        let adm = arb.admit(&[gold, bronze.clone()]);
        assert_eq!(adm.verdicts[1], Verdict::Admitted);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
        // ...but the flat baseline (PR-2) admits Gold first
        let flat = BudgetArbiter::flat(1.7, 3);
        let adm = flat.admit(&[gold, bronze]);
        assert_eq!(adm.verdicts[0], Verdict::Admitted);
        assert_eq!(adm.verdicts[1], Verdict::DeniedBudget);
    }

    #[test]
    fn overspent_fleet_admits_only_shrinks() {
        let arb = BudgetArbiter::new(1.0, 3);
        let ps = vec![
            proposal(0, PriorityClass::Gold, 1.0, 1.5),
            proposal(1, PriorityClass::Gold, 0.8, 0.4),
        ];
        let adm = arb.admit(&ps);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
        assert_eq!(adm.verdicts[1], Verdict::AdmittedShrink);
        assert!(adm.projected_spend < adm.base_spend);
    }

    #[test]
    fn first_choice_degrades_to_a_cheaper_candidate() {
        // budget fits the +0.4 alternative but not the +1.0 first choice
        let arb = BudgetArbiter::new(1.4, 3);
        let mut p = proposal(0, PriorityClass::Silver, 0.5, 1.5);
        p.sla_violating = true; // repair walks are exercised hardest
        p.candidates.push(candidate(Configuration::new(1, 0), 0.9, 4.0));
        let adm = arb.admit(&[p.clone()]);
        assert_eq!(adm.verdicts[0], Verdict::AdmittedDegraded);
        assert_eq!(adm.chosen[0], Some(1));
        assert!((adm.projected_spend - 0.9).abs() < 1e-6);
        assert_eq!(adm.degraded_moves, 1);
        // flat baseline denies outright
        let adm = BudgetArbiter::flat(1.4, 3).admit(&[p]);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
    }

    #[test]
    fn sheds_fund_sla_repairs_all_or_nothing() {
        // the funded admission lands exactly on the budget boundary —
        // FIT_EPS must absorb the f32 summation noise there
        let arb = BudgetArbiter::new(2.0, 3);
        // repairing tenant needs +0.5 but only +0.3 headroom exists;
        // the holder offers a 0.2 shed — together they fit exactly
        let mut repair = proposal(0, PriorityClass::Bronze, 0.7, 1.2);
        repair.sla_violating = true;
        let mut holder = hold(1, 1.0);
        holder.sheds.push(candidate(Configuration::new(1, 0), 0.8, 0.5));
        let adm = arb.admit(&[repair.clone(), holder.clone()]);
        assert_eq!(adm.verdicts[0], Verdict::Admitted);
        assert_eq!(adm.verdicts[1], Verdict::AdmittedShed);
        assert_eq!(adm.chosen[1], Some(0));
        assert_eq!(adm.shed_moves, 1);
        assert!(adm.projected_spend <= 2.0 + 1e-6);
        // a deficit the sheds cannot cover actuates nothing
        let mut big = repair.clone();
        big.candidates[0].cost_to = 3.0;
        big.candidates.truncate(1);
        let adm = arb.admit(&[big, holder]);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
        assert_eq!(adm.verdicts[1], Verdict::Hold, "no shed without funding an admission");
    }

    #[test]
    fn unmet_repair_freezes_economic_upgrades() {
        let arb = BudgetArbiter::new(2.0, 3);
        // the repair needs +1.5 (cannot fit), the economic +0.1 (could)
        let mut repair = proposal(0, PriorityClass::Bronze, 0.9, 2.4);
        repair.sla_violating = true;
        let economic = proposal(1, PriorityClass::Gold, 0.9, 1.0);
        let adm = arb.admit(&[repair, economic.clone()]);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
        assert_eq!(
            adm.verdicts[1],
            Verdict::DeniedBudget,
            "economic upgrades are frozen while a repair starves"
        );
        // without the starving repair the same economic move is admitted
        let adm = arb.admit(&[economic]);
        assert_eq!(adm.verdicts[0], Verdict::Admitted);
    }

    #[test]
    fn envelopes_cap_economic_spending_with_burst_credits() {
        let env = ClassEnvelopes::new(0.5, 0.3, 0.2);
        let arb = BudgetArbiter::new(10.0, 3).with_envelopes(env);
        assert!((env.envelope(PriorityClass::Gold, 10.0) - 5.0).abs() < 1e-6);
        // gold fully consumes its 5.0 envelope; silver uses 0.5 of 3.0,
        // so bronze (envelope 2.0) may borrow half of silver's unused
        // 2.5 => headroom 2.0 + 1.25 - 0.4 spent. A +2.6 economic move
        // fits the envelope (and the 10.0 budget with 4.1 headroom)...
        let mut gold = hold(1, 5.0);
        gold.class = PriorityClass::Gold;
        let mut silver = hold(2, 0.5);
        silver.class = PriorityClass::Silver;
        let fits = proposal(0, PriorityClass::Bronze, 0.4, 3.0);
        let adm = arb.admit(&[fits, gold.clone(), silver.clone()]);
        assert_eq!(adm.verdicts[0], Verdict::Admitted);
        // ...but +3.0 exceeds envelope + burst (3.4 > 3.25) while the
        // fleet budget alone would have allowed it: envelope-denied
        let over = proposal(0, PriorityClass::Bronze, 0.4, 3.4);
        let adm = arb.admit(&[over.clone(), gold.clone(), silver.clone()]);
        assert_eq!(adm.verdicts[0], Verdict::DeniedBudget);
        let no_env = BudgetArbiter::new(10.0, 3);
        let adm = no_env.admit(&[over.clone(), gold.clone(), silver.clone()]);
        assert_eq!(adm.verdicts[0], Verdict::Admitted, "budget alone admits");
        // SLA repairs ignore envelopes entirely
        let mut repair = over;
        repair.sla_violating = true;
        let adm = arb.admit(&[repair, gold, silver]);
        assert_eq!(adm.verdicts[0], Verdict::Admitted);
    }

    #[test]
    fn adapter_is_identity_without_contention() {
        let base = ClassEnvelopes::fixed(0.5, 0.3, 0.2);
        let mut ad = EnvelopeAdapter::new(base);
        for _ in 0..5 {
            assert_eq!(ad.observe([0.0; 3]), base);
        }
        assert_eq!(ad.ewma(), [0.0; 3]);
    }

    #[test]
    fn adapter_grows_the_contended_class_share() {
        let base = ClassEnvelopes::fixed(0.5, 0.3, 0.2);
        let mut ad = EnvelopeAdapter::new(base);
        // bronze (rank 0) carries all the contention for a while
        let mut env = base;
        for _ in 0..20 {
            env = ad.observe([3.0, 1.0, 0.0]);
        }
        assert!(
            env.share(PriorityClass::Bronze) > base.share(PriorityClass::Bronze),
            "contended bronze must gain share: {} vs {}",
            env.share(PriorityClass::Bronze),
            base.share(PriorityClass::Bronze)
        );
        assert!(env.share(PriorityClass::Gold) < base.share(PriorityClass::Gold));
        // shares stay a distribution
        let sum: f32 = PriorityClass::ALL.iter().map(|&c| env.share(c)).sum();
        assert!((sum - 1.0).abs() < 1e-6);
        // contention gone: the EWMA decays back toward the base split
        for _ in 0..200 {
            env = ad.observe([0.0, 0.0, 0.0]);
        }
        let drift =
            (env.share(PriorityClass::Bronze) - base.share(PriorityClass::Bronze)).abs();
        assert!(drift < 0.06, "shares must decay toward base, drift {drift}");
    }

    #[test]
    fn envelope_parse_and_normalize() {
        let e = ClassEnvelopes::parse("default").unwrap();
        assert!((e.share(PriorityClass::Gold) - 0.5).abs() < 1e-6);
        let e = ClassEnvelopes::parse("2:1:1").unwrap();
        assert!((e.share(PriorityClass::Gold) - 0.5).abs() < 1e-6);
        assert!((e.share(PriorityClass::Silver) - 0.25).abs() < 1e-6);
        assert!(ClassEnvelopes::parse("1:0:1").is_none());
        assert!(ClassEnvelopes::parse("nope").is_none());
    }
}
