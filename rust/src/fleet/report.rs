//! Fleet-level metrics aggregation: per-tenant summaries, per-class
//! rollups (p95 latency, total cost, denial counts), and text/CSV
//! renderers for the CLI, example, and bench.
//!
//! Since PR 6 the p99 columns come from *mergeable* log-bucketed
//! histograms ([`crate::metrics::LatencyHistogram`]): each tenant's
//! history may span several suspend/resume segments, and class rollups
//! merge the per-tenant sketches instead of concatenating raw samples —
//! the first brick of the ROADMAP's mergeable-sketch pipeline.

use std::fmt::Write as _;

use crate::metrics::Summary;

use super::tenant::{PriorityClass, Tenant};
use super::FleetTick;

/// Nearest-rank percentile over unsorted samples (0 when empty).
/// One quickselect partition (`select_nth_unstable_by`, expected O(n))
/// instead of a full sort — nearest-rank needs a single order
/// statistic, and the seeded pin test below holds this path equal to
/// the old sort-based one.
pub fn percentile(xs: &[f32], q: f64) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    let rank = ((q / 100.0) * v.len() as f64).ceil() as usize;
    let idx = rank.clamp(1, v.len()) - 1;
    let (_, nth, _) = v.select_nth_unstable_by(idx, f32::total_cmp);
    *nth
}

/// One tenant's end-of-run rollup.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub class: PriorityClass,
    pub summary: Summary,
    /// p95 of measured (queueing-corrected / DES) latency.
    pub p95_latency: f32,
    /// p95 of raw analytical latency (what the SLA bound governs).
    pub p95_latency_raw: f32,
    /// The tenant's latency SLA bound.
    pub sla_l_max: f32,
    pub denied: usize,
    pub rescues: usize,
    /// Moves admitted as a lower-ranked candidate (degradations).
    pub degraded: usize,
    /// Shed offers actuated to fund other tenants' SLA repairs.
    pub sheds: usize,
    pub max_denial_streak: usize,
    /// Hourly cost of the final configuration.
    pub final_cost: f32,
    /// p99 of measured latency from the merged histogram — spans every
    /// suspend/resume segment of a serverless tenant's history.
    pub p99_latency: f32,
    /// Ticks spent at storage-only cost (0 for always-on tenants).
    pub suspended_ticks: usize,
    /// Admitted wakes (0 for always-on tenants).
    pub resumes: usize,
}

impl TenantReport {
    /// Whether the tenant's p95 raw latency met its SLA bound.
    pub fn p95_within_sla(&self) -> bool {
        self.p95_latency_raw <= self.sla_l_max
    }
}

/// Per-priority-class rollup.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: PriorityClass,
    pub tenants: usize,
    /// p95 over every step latency of every tenant in the class.
    pub p95_latency: f32,
    pub p95_latency_raw: f32,
    /// p99 from the class-merged latency histograms (merge of each
    /// member's segment-merged sketch).
    pub p99_latency: f32,
    pub total_cost: f64,
    pub denied: usize,
    pub rescues: usize,
    pub violations: usize,
}

/// The whole fleet's end-of-run report.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub budget: f32,
    pub peak_spend: f32,
    pub total_cost: f64,
    pub admitted_moves: usize,
    pub denied_moves: usize,
    pub tenants: Vec<TenantReport>,
    pub classes: Vec<ClassReport>,
}

impl FleetReport {
    pub fn class(&self, class: PriorityClass) -> Option<&ClassReport> {
        self.classes.iter().find(|c| c.class == class)
    }

    /// Whether fleet spend stayed within the budget at every tick.
    pub fn within_budget(&self) -> bool {
        self.peak_spend <= self.budget + super::BUDGET_EPS
    }
}

/// Aggregate tenants + tick timeline into a [`FleetReport`].
pub fn fleet_report(tenants: &[Tenant], ticks: &[FleetTick], budget: f32) -> FleetReport {
    let tenant_reports: Vec<TenantReport> = tenants
        .iter()
        .map(|t| {
            // streaming tenants answer p95 from their O(1) latency
            // sketches; exact tenants keep the raw-sample path
            let (p95, p95_raw) = match t.streaming() {
                Some(s) => (s.p95() as f32, s.p95_raw() as f32),
                None => {
                    let lat: Vec<f32> = t.records().iter().map(|r| r.latency).collect();
                    let raw: Vec<f32> = t.records().iter().map(|r| r.latency_raw).collect();
                    (percentile(&lat, 95.0), percentile(&raw, 95.0))
                }
            };
            TenantReport {
                name: t.name().to_string(),
                class: t.class(),
                summary: t.summary(),
                p95_latency: p95,
                p95_latency_raw: p95_raw,
                sla_l_max: t.sla().l_max,
                denied: t.denied_total,
                rescues: t.rescued_total,
                degraded: t.degraded_total,
                sheds: t.shed_total,
                max_denial_streak: t.max_denial_streak,
                final_cost: t.cost(),
                p99_latency: t.merged_histogram().p99() as f32,
                suspended_ticks: t.serverless().map_or(0, |s| s.suspended_ticks),
                resumes: t.serverless().map_or(0, |s| s.resumes),
            }
        })
        .collect();

    FleetReport {
        budget,
        peak_spend: ticks.iter().map(|t| t.spend).fold(0.0, f32::max),
        total_cost: tenant_reports.iter().map(|t| t.summary.total_cost).sum(),
        admitted_moves: ticks.iter().map(|t| t.admitted_moves).sum(),
        denied_moves: ticks.iter().map(|t| t.denied_moves).sum(),
        tenants: tenant_reports,
        classes: class_reports(tenants),
    }
}

/// Per-class rollups over the fleet, shared by [`fleet_report`] and
/// [`fleet_rollup`] so the two paths agree bit for bit (same member
/// iteration order, same f64 accumulation order).
fn class_reports(tenants: &[Tenant]) -> Vec<ClassReport> {
    PriorityClass::ALL
        .iter()
        .filter_map(|&class| {
            let members: Vec<&Tenant> =
                tenants.iter().filter(|t| t.class() == class).collect();
            class_report(class, &members)
        })
        .collect()
}

/// One class's rollup from its members (`None` when the class is
/// unpopulated).
fn class_report(class: PriorityClass, members: &[&Tenant]) -> Option<ClassReport> {
    if members.is_empty() {
        return None;
    }
    // class p95: when every member streams, merge their
    // sketches (O(buckets) per tenant); otherwise concatenate
    // the exact samples as before
    let (p95, p95_raw) = if members.iter().all(|t| t.streaming().is_some()) {
        let first = members[0].streaming().expect("checked above");
        let mut lat_h = first.latency_histogram().clone();
        let mut raw_h = first.raw_latency_histogram().clone();
        for m in &members[1..] {
            let s = m.streaming().expect("checked above");
            lat_h.merge(s.latency_histogram());
            raw_h.merge(s.raw_latency_histogram());
        }
        (lat_h.quantile(0.95) as f32, raw_h.quantile(0.95) as f32)
    } else {
        let lat: Vec<f32> = members
            .iter()
            .flat_map(|t| t.records().iter().map(|r| r.latency))
            .collect();
        let raw: Vec<f32> = members
            .iter()
            .flat_map(|t| t.records().iter().map(|r| r.latency_raw))
            .collect();
        (percentile(&lat, 95.0), percentile(&raw, 95.0))
    };
    // class p99: merge the members' sketches — O(buckets) per
    // tenant instead of concatenating every raw sample
    let mut class_hist = members[0].merged_histogram();
    for m in &members[1..] {
        class_hist.merge(&m.merged_histogram());
    }
    Some(ClassReport {
        class,
        tenants: members.len(),
        p95_latency: p95,
        p95_latency_raw: p95_raw,
        p99_latency: class_hist.p99() as f32,
        total_cost: members.iter().map(|t| t.summary().total_cost).sum(),
        denied: members.iter().map(|t| t.denied_total).sum(),
        rescues: members.iter().map(|t| t.rescued_total).sum(),
        violations: members.iter().map(|t| t.summary().violations).sum(),
    })
}

/// The fleet report without the per-tenant rows: class rollups and
/// fleet totals only, computed straight from the tenants' O(1)
/// summaries and mergeable sketches. At 100k tenants materializing one
/// [`TenantReport`] per tenant (strings, summaries, percentiles) is
/// the report-side bottleneck named in the ROADMAP; a streaming fleet
/// only needs this rollup, and its numbers are pinned **bitwise equal**
/// to [`fleet_report`]'s class/total fields (shared helpers, identical
/// iteration order) by `rollup_matches_the_exact_report_on_a_512_tenant_fleet`.
#[derive(Debug, Clone)]
pub struct FleetRollup {
    pub budget: f32,
    pub peak_spend: f32,
    pub total_cost: f64,
    pub admitted_moves: usize,
    pub denied_moves: usize,
    pub classes: Vec<ClassReport>,
}

impl FleetRollup {
    pub fn class(&self, class: PriorityClass) -> Option<&ClassReport> {
        self.classes.iter().find(|c| c.class == class)
    }

    /// Whether fleet spend stayed within the budget at every tick.
    pub fn within_budget(&self) -> bool {
        self.peak_spend <= self.budget + super::BUDGET_EPS
    }
}

/// Aggregate tenants + tick timeline into a [`FleetRollup`] without
/// materializing per-tenant report rows.
pub fn fleet_rollup(tenants: &[Tenant], ticks: &[FleetTick], budget: f32) -> FleetRollup {
    FleetRollup {
        budget,
        peak_spend: ticks.iter().map(|t| t.spend).fold(0.0, f32::max),
        total_cost: tenants.iter().map(|t| t.summary().total_cost).sum(),
        admitted_moves: ticks.iter().map(|t| t.admitted_moves).sum(),
        denied_moves: ticks.iter().map(|t| t.denied_moves).sum(),
        classes: class_reports(tenants),
    }
}

/// Human-readable fleet table (classes then tenants).
pub fn table(report: &FleetReport) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "fleet: budget {:.2}/h  peak spend {:.2}/h ({})  total cost {:.1}  moves admitted {} denied {}",
        report.budget,
        report.peak_spend,
        if report.within_budget() { "within budget" } else { "OVER BUDGET" },
        report.total_cost,
        report.admitted_moves,
        report.denied_moves,
    );
    let _ = writeln!(
        out,
        "\n{:<8} {:>7} {:>10} {:>12} {:>10} {:>10} {:>8} {:>8} {:>8}",
        "class", "tenants", "p95 lat", "p95 raw lat", "p99 lat", "cost", "denied", "rescues",
        "viol."
    );
    for c in &report.classes {
        let _ = writeln!(
            out,
            "{:<8} {:>7} {:>10.3} {:>12.3} {:>10.3} {:>10.1} {:>8} {:>8} {:>8}",
            c.class.label(),
            c.tenants,
            c.p95_latency,
            c.p95_latency_raw,
            c.p99_latency,
            c.total_cost,
            c.denied,
            c.rescues,
            c.violations
        );
    }
    let _ = writeln!(
        out,
        "\n{:<12} {:<8} {:>10} {:>12} {:>7} {:>9} {:>8} {:>8} {:>9} {:>6} {:>10} {:>9} {:>7}",
        "tenant",
        "class",
        "p95 lat",
        "p95 raw lat",
        "sla",
        "avg cost",
        "denied",
        "rescues",
        "degraded",
        "sheds",
        "max streak",
        "susp.tks",
        "resumes"
    );
    for t in &report.tenants {
        let _ = writeln!(
            out,
            "{:<12} {:<8} {:>10.3} {:>12.3} {:>7.2} {:>9.3} {:>8} {:>8} {:>9} {:>6} {:>10} {:>9} {:>7}",
            t.name,
            t.class.label(),
            t.p95_latency,
            t.p95_latency_raw,
            t.sla_l_max,
            t.summary.avg_cost,
            t.denied,
            t.rescues,
            t.degraded,
            t.sheds,
            t.max_denial_streak,
            t.suspended_ticks,
            t.resumes
        );
    }
    out
}

/// Human-readable rollup table (fleet totals + class rows; no
/// per-tenant section — that is the point).
pub fn rollup_table(rollup: &FleetRollup) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "fleet: budget {:.2}/h  peak spend {:.2}/h ({})  total cost {:.1}  moves admitted {} denied {}",
        rollup.budget,
        rollup.peak_spend,
        if rollup.within_budget() { "within budget" } else { "OVER BUDGET" },
        rollup.total_cost,
        rollup.admitted_moves,
        rollup.denied_moves,
    );
    let _ = writeln!(
        out,
        "\n{:<8} {:>7} {:>10} {:>12} {:>10} {:>10} {:>8} {:>8} {:>8}",
        "class", "tenants", "p95 lat", "p95 raw lat", "p99 lat", "cost", "denied", "rescues",
        "viol."
    );
    for c in &rollup.classes {
        let _ = writeln!(
            out,
            "{:<8} {:>7} {:>10.3} {:>12.3} {:>10.3} {:>10.1} {:>8} {:>8} {:>8}",
            c.class.label(),
            c.tenants,
            c.p95_latency,
            c.p95_latency_raw,
            c.p99_latency,
            c.total_cost,
            c.denied,
            c.rescues,
            c.violations
        );
    }
    out
}

/// Per-tenant CSV (machine-readable twin of [`table`]).
pub fn csv(report: &FleetReport) -> String {
    let mut out = String::from(
        "tenant,class,p95_latency,p95_latency_raw,p99_latency,sla_l_max,avg_cost,total_cost,violations,denied,rescues,degraded,sheds,max_denial_streak,suspended_ticks,resumes\n",
    );
    for t in &report.tenants {
        let _ = writeln!(
            out,
            "{},{},{:.4},{:.4},{:.4},{:.2},{:.4},{:.2},{},{},{},{},{},{},{},{}",
            t.name,
            t.class.label(),
            t.p95_latency,
            t.p95_latency_raw,
            t.p99_latency,
            t.sla_l_max,
            t.summary.avg_cost,
            t.summary.total_cost,
            t.summary.violations,
            t.denied,
            t.rescues,
            t.degraded,
            t.sheds,
            t.max_denial_streak,
            t.suspended_ticks,
            t.resumes
        );
    }
    out
}

/// Spend timeline CSV (`step,spend,projected,admitted,denied,rescues,
/// degraded,sheds,suspended,resuming,resume_ends,fresh_proposals,
/// planning_micros` — the last two are the PR-7 planning-cost columns:
/// how many tenants actually re-proposed and how long the planning
/// phase took).
/// Default seed for `fleet --ticks-sample` (any fixed value works; a
/// named one keeps CLI runs replayable).
pub const TICKS_SAMPLE_SEED: u64 = 0x71C5_5EED;

/// Bound a tick timeline to at most `cap` rows with the shared
/// Algorithm-R reservoir (`cap == 0` keeps every tick). Rows stay in
/// step order, so a 10240-tenant run's per-tick output no longer grows
/// with tick count.
pub fn sample_ticks(ticks: &[FleetTick], cap: usize, seed: u64) -> Vec<FleetTick> {
    crate::metrics::reservoir_sample(ticks, cap, seed)
}

/// [`ticks_csv`] over a reservoir-bounded timeline.
pub fn ticks_csv_sampled(ticks: &[FleetTick], cap: usize, seed: u64) -> String {
    ticks_csv(&sample_ticks(ticks, cap, seed))
}

pub fn ticks_csv(ticks: &[FleetTick]) -> String {
    let mut out = String::from(
        "step,spend,projected_spend,admitted,denied,rescues,degraded,sheds,suspended,resuming,resume_ends,fresh_proposals,planning_micros\n",
    );
    for t in ticks {
        let _ = writeln!(
            out,
            "{},{:.4},{:.4},{},{},{},{},{},{},{},{},{},{}",
            t.step,
            t.spend,
            t.projected_spend,
            t.admitted_moves,
            t.denied_moves,
            t.rescues,
            t.degraded_moves,
            t.shed_moves,
            t.suspended,
            t.resuming,
            t.resume_ends,
            t.fresh_proposals,
            t.planning_micros
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::fleet::{FleetSimulator, TenantSpec};
    use crate::workload::TraceBuilder;

    fn run_fleet() -> (crate::fleet::FleetResult, f32) {
        let cfg = ModelConfig::default_paper();
        let base = TraceBuilder::paper(&cfg);
        let specs = vec![
            TenantSpec::from_config(&cfg, "gold-0", PriorityClass::Gold, base.clone()),
            TenantSpec::from_config(&cfg, "silver-0", PriorityClass::Silver, base.shifted(17)),
            TenantSpec::from_config(&cfg, "bronze-0", PriorityClass::Bronze, base.shifted(33)),
        ];
        let budget = 7.5f32;
        let mut fleet = FleetSimulator::new(&cfg, specs, budget, 3);
        (fleet.run(50), budget)
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        assert_eq!(percentile(&xs, 95.0), 95.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&[2.0], 95.0), 2.0);
        assert_eq!(percentile(&[], 95.0), 0.0);
    }

    /// The old sort-based implementation, kept as the oracle for the
    /// quickselect rewrite.
    fn percentile_sorted(xs: &[f32], q: f64) -> f32 {
        if xs.is_empty() {
            return 0.0;
        }
        let mut v = xs.to_vec();
        v.sort_by(f32::total_cmp);
        let rank = ((q / 100.0) * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1]
    }

    #[test]
    fn quickselect_percentile_equals_sort_based_path() {
        let mut rng = crate::workload::XorShift64::new(0xC0FFEE);
        for len in [1usize, 2, 3, 7, 50, 333, 1000] {
            let xs: Vec<f32> = (0..len)
                .map(|_| (rng.next_f64() * 10.0 - 5.0) as f32)
                .collect();
            for q in [0.0, 1.0, 37.5, 50.0, 90.0, 95.0, 99.0, 100.0] {
                assert_eq!(
                    percentile(&xs, q).to_bits(),
                    percentile_sorted(&xs, q).to_bits(),
                    "len {len} q {q}"
                );
            }
        }
        // duplicates and non-finite-free extremes
        let xs = vec![1.0f32; 100];
        assert_eq!(percentile(&xs, 99.0), 1.0);
    }

    #[test]
    fn sampled_ticks_are_bounded_ordered_and_deterministic() {
        let (res, _) = run_fleet();
        let all = sample_ticks(&res.ticks, 0, TICKS_SAMPLE_SEED);
        assert_eq!(all.len(), res.ticks.len(), "cap 0 keeps everything");
        let some = sample_ticks(&res.ticks, 10, TICKS_SAMPLE_SEED);
        assert_eq!(some.len(), 10);
        assert!(some.windows(2).all(|w| w[0].step < w[1].step));
        assert_eq!(
            some.iter().map(|t| t.step).collect::<Vec<_>>(),
            sample_ticks(&res.ticks, 10, TICKS_SAMPLE_SEED)
                .iter()
                .map(|t| t.step)
                .collect::<Vec<_>>(),
            "same seed, same sample"
        );
        let csv = ticks_csv_sampled(&res.ticks, 10, TICKS_SAMPLE_SEED);
        assert_eq!(csv.lines().count(), 11, "header + cap rows");
    }

    #[test]
    fn streaming_fleet_report_stays_close_to_exact() {
        let cfg = ModelConfig::default_paper();
        let base = TraceBuilder::paper(&cfg);
        let mk = |i: usize| {
            TenantSpec::from_config(
                &cfg,
                &format!("t-{i}"),
                PriorityClass::ALL[i % 3],
                base.shifted(i * 7),
            )
        };
        let specs: Vec<TenantSpec> = (0..6).map(mk).collect();
        let mut exact = FleetSimulator::new(&cfg, specs.clone(), 15.0, 3);
        let mut stream = FleetSimulator::new(&cfg, specs, 15.0, 3);
        stream.enable_streaming_metrics(16);
        let re = exact.run(80);
        let rs = stream.run(80);
        for (a, b) in re.report.tenants.iter().zip(&rs.report.tenants) {
            assert_eq!(a.summary, b.summary, "streaming summary drifted for {}", a.name);
            if a.p95_latency > 0.0 {
                let rel = (a.p95_latency - b.p95_latency).abs() / a.p95_latency;
                assert!(rel < 0.05, "{}: p95 {} vs {}", a.name, a.p95_latency, b.p95_latency);
            }
            assert_eq!(a.p99_latency, b.p99_latency, "p99 path is shared");
        }
    }

    #[test]
    fn rollup_matches_the_exact_report_on_a_512_tenant_fleet() {
        let cfg = ModelConfig::default_paper();
        let base = TraceBuilder::paper(&cfg);
        let n = 512usize;
        let specs: Vec<TenantSpec> = (0..n)
            .map(|i| {
                TenantSpec::from_config(
                    &cfg,
                    format!("t-{i}"),
                    PriorityClass::ALL[i % 3],
                    base.shifted(i * base.len() / n),
                )
            })
            .collect();
        let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
        fleet.enable_streaming_metrics(16);
        let res = fleet.run(40);
        let rollup = fleet_rollup(fleet.tenants(), &res.ticks, 1.0e6);
        // totals: bitwise (same f64 accumulation order)
        assert_eq!(rollup.total_cost.to_bits(), res.report.total_cost.to_bits());
        assert_eq!(rollup.peak_spend.to_bits(), res.report.peak_spend.to_bits());
        assert_eq!(rollup.admitted_moves, res.report.admitted_moves);
        assert_eq!(rollup.denied_moves, res.report.denied_moves);
        assert_eq!(rollup.budget, res.report.budget);
        assert_eq!(rollup.within_budget(), res.report.within_budget());
        // class rows: bitwise equal field by field (shared helper)
        assert_eq!(rollup.classes.len(), res.report.classes.len());
        for (a, b) in rollup.classes.iter().zip(&res.report.classes) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.tenants, b.tenants);
            assert_eq!(a.p95_latency.to_bits(), b.p95_latency.to_bits());
            assert_eq!(a.p95_latency_raw.to_bits(), b.p95_latency_raw.to_bits());
            assert_eq!(a.p99_latency.to_bits(), b.p99_latency.to_bits());
            assert_eq!(a.total_cost.to_bits(), b.total_cost.to_bits());
            assert_eq!(a.denied, b.denied);
            assert_eq!(a.rescues, b.rescues);
            assert_eq!(a.violations, b.violations);
        }
        // the rollup renderer's shared header lines match table()'s
        let rt = rollup_table(&rollup);
        let ft = table(&res.report);
        assert_eq!(rt.lines().next(), ft.lines().next(), "fleet summary line diverged");
        assert!(rt.lines().count() < ft.lines().count(), "rollup must skip tenant rows");
    }

    #[test]
    fn report_covers_every_class_and_tenant() {
        let (res, budget) = run_fleet();
        assert_eq!(res.report.tenants.len(), 3);
        assert_eq!(res.report.classes.len(), 3);
        assert!(res.report.within_budget());
        assert!(res.report.peak_spend <= budget + 1e-3);
        for c in PriorityClass::ALL {
            assert!(res.report.class(c).is_some());
        }
    }

    #[test]
    fn totals_are_consistent() {
        let (res, _) = run_fleet();
        let class_cost: f64 = res.report.classes.iter().map(|c| c.total_cost).sum();
        assert!((class_cost - res.report.total_cost).abs() < 1e-6);
        let tick_moves: usize = res.ticks.iter().map(|t| t.admitted_moves).sum();
        assert_eq!(tick_moves, res.report.admitted_moves);
    }

    #[test]
    fn p99_comes_from_merged_histograms() {
        let (res, _) = run_fleet();
        let member_p99: Vec<f32> = res.report.tenants.iter().map(|t| t.p99_latency).collect();
        assert!(member_p99.iter().all(|&p| p > 0.0));
        for c in &res.report.classes {
            // a merged sketch's quantile lies between its members'
            // extremes (here classes have one member each, so it is
            // exactly that member's p99)
            assert!(c.p99_latency > 0.0);
            let lo = member_p99.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = member_p99.iter().cloned().fold(0.0, f32::max);
            assert!(c.p99_latency >= lo * 0.999 && c.p99_latency <= hi * 1.001);
        }
    }

    #[test]
    fn serverless_counters_flow_into_the_report() {
        let cfg = ModelConfig::default_paper();
        let specs = crate::serverless::mostly_idle_specs(&cfg, 8, 0.75);
        let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
        fleet.enable_serverless(Default::default());
        let res = fleet.run(100);
        let suspended: usize = res.report.tenants.iter().map(|t| t.suspended_ticks).sum();
        assert!(suspended > 0, "idle tenants never slept");
        let resumed: Vec<_> =
            res.report.tenants.iter().filter(|t| t.resumes > 0).collect();
        assert!(!resumed.is_empty(), "no tenant ever woke");
        // a suspended-then-resumed tenant's merged history still
        // yields percentiles (the segments merged, not dropped)
        assert!(resumed.iter().any(|t| t.p99_latency > 0.0));
    }

    #[test]
    fn renderers_mention_every_tenant() {
        let (res, _) = run_fleet();
        let t = table(&res.report);
        let c = csv(&res.report);
        for name in ["gold-0", "silver-0", "bronze-0"] {
            assert!(t.contains(name));
            assert!(c.contains(name));
        }
        assert_eq!(csv(&res.report).lines().count(), 4);
        let tc = ticks_csv(&res.ticks);
        assert_eq!(tc.lines().count(), 51);
        let header = tc.lines().next().unwrap();
        assert!(header.ends_with("fresh_proposals,planning_micros"));
        // the first tick proposes the whole fleet (nothing cached yet)
        assert_eq!(res.ticks[0].fresh_proposals, 3);
    }
}
