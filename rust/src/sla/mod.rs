//! SLA specification, feasibility, and violation accounting (paper
//! §IV.C, §V.E).
//!
//! Feasibility (the *planner's* filter) uses the latency bound and a
//! buffered throughput requirement `lambda_req * b_sla`; violation
//! accounting (the *auditor*) charges a step when the served
//! configuration misses the latency bound or the raw requirement —
//! the buffer is planning headroom, not part of the contract.


use crate::config::ModelConfig;

/// The SLA contract.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlaSpec {
    /// Maximum acceptable (raw analytical) latency L_max.
    pub l_max: f32,
    /// Throughput planning buffer b_sla (>= 1 keeps headroom).
    pub b_sla: f32,
}

impl SlaSpec {
    pub fn new(l_max: f32, b_sla: f32) -> Self {
        Self { l_max, b_sla }
    }

    pub fn from_config(cfg: &ModelConfig) -> Self {
        Self::new(cfg.sla.l_max, cfg.sla.b_sla)
    }

    /// The planner's minimum acceptable throughput for a demand level.
    pub fn t_min(&self, lambda_req: f32) -> f32 {
        lambda_req * self.b_sla
    }

    /// Planner-side feasibility (paper IV.C).
    pub fn feasible(&self, latency: f32, throughput: f32, lambda_req: f32) -> bool {
        latency <= self.l_max && throughput >= self.t_min(lambda_req)
    }

    /// Auditor-side violation of a *served* step.
    pub fn audit(&self, raw_latency: f32, throughput: f32, lambda_req: f32) -> Violation {
        Violation {
            latency: raw_latency > self.l_max,
            throughput: throughput < lambda_req,
        }
    }
}

/// Decomposed SLA violation for one served step (paper V.E).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Violation {
    pub latency: bool,
    pub throughput: bool,
}

impl Violation {
    pub fn any(&self) -> bool {
        self.latency || self.throughput
    }
}

/// Running violation tally over a simulation (paper Table I column).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ViolationCounter {
    pub steps: usize,
    pub violated_steps: usize,
    pub latency_violations: usize,
    pub throughput_violations: usize,
}

impl ViolationCounter {
    pub fn record(&mut self, v: Violation) {
        self.steps += 1;
        if v.any() {
            self.violated_steps += 1;
        }
        if v.latency {
            self.latency_violations += 1;
        }
        if v.throughput {
            self.throughput_violations += 1;
        }
    }

    /// Fraction of steps in violation.
    pub fn rate(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.violated_steps as f64 / self.steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sla() -> SlaSpec {
        SlaSpec::new(5.0, 1.15)
    }

    #[test]
    fn feasibility_uses_buffered_throughput() {
        let s = sla();
        assert!(s.feasible(4.0, 1150.0, 1000.0));
        assert!(!s.feasible(4.0, 1100.0, 1000.0)); // meets raw, not buffer
        assert!(!s.feasible(5.1, 99999.0, 1000.0));
    }

    #[test]
    fn audit_uses_raw_requirement() {
        let s = sla();
        // planner-infeasible but not an audit violation (buffer zone)
        let v = s.audit(4.0, 1100.0, 1000.0);
        assert!(!v.any());
        let v = s.audit(6.0, 900.0, 1000.0);
        assert!(v.latency && v.throughput);
    }

    #[test]
    fn boundary_conditions() {
        let s = sla();
        assert!(s.feasible(5.0, 1150.0, 1000.0)); // L == L_max passes
        let v = s.audit(5.0, 1000.0, 1000.0); // equality is not violation
        assert!(!v.any());
    }

    #[test]
    fn counter_decomposes() {
        let s = sla();
        let mut c = ViolationCounter::default();
        c.record(s.audit(6.0, 2000.0, 1000.0)); // latency only
        c.record(s.audit(1.0, 500.0, 1000.0)); // throughput only
        c.record(s.audit(6.0, 500.0, 1000.0)); // both
        c.record(s.audit(1.0, 2000.0, 1000.0)); // none
        assert_eq!(c.steps, 4);
        assert_eq!(c.violated_steps, 3);
        assert_eq!(c.latency_violations, 2);
        assert_eq!(c.throughput_violations, 2);
        assert!((c.rate() - 0.75).abs() < 1e-12);
    }
}
