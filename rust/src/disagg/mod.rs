//! Disaggregated Scaling Plane (paper §VIII, final extension):
//! "serverless and disaggregated architectures, where compute, memory,
//! storage, and network resources may be scaled independently. Such
//! systems may require a higher-dimensional extension of the Scaling
//! Plane."
//!
//! This module is that extension: a four-dimensional configuration
//! space `(H, C, M, S)` — node count × compute tier × memory tier ×
//! storage tier. Every combination synthesizes a virtual [`Tier`]
//! (cpu+bandwidth from C, ram from M, iops from S, cost additive), so
//! the paper's §III surfaces apply unchanged. DIAGONALSCALE generalizes
//! to the 3^4-candidate hyper-local neighborhood.
//!
//! Because the coupled 2-D ladder is a *subspace* of this plane (the
//! "matched" combos), the disaggregated optimum can only be equal or
//! better — the `ablations` bench quantifies the cost savings.
//!
//! **Relation to [`crate::serverless`].** This module detaches the
//! storage *axis inside a provisioned node* — every combo still pays
//! for H live replicas. The serverless tier takes the detachment one
//! step further: [`crate::serverless::StorageService`] moves the
//! durable pages off the nodes entirely, so the storage bill survives
//! compute scale-to-zero (H = 0) while every provisioned axis here
//! goes away with the nodes. [`DisaggPlane::detached_storage_cost`] is
//! the bridge: the per-node storage-axis price that a suspended tenant
//! stops paying and the shared service replaces with its per-GB-hour
//! rate.

use crate::config::{ModelConfig, SurfaceConfig};
use crate::metrics::{Recorder, StepRecord, Summary};
use crate::plane::Tier;
use crate::sla::SlaSpec;
use crate::surfaces::queueing;
use crate::workload::Trace;

/// One independently scalable axis: named steps with a value and cost.
#[derive(Debug, Clone, PartialEq)]
pub struct Axis {
    pub name: &'static str,
    /// (value, cost) per step, ascending.
    pub steps: Vec<(f32, f32)>,
}

impl Axis {
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

/// A point in the 4-D plane, as indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DisaggConfig {
    pub h_idx: usize,
    pub c_idx: usize,
    pub m_idx: usize,
    pub s_idx: usize,
}

impl DisaggConfig {
    pub fn new(h_idx: usize, c_idx: usize, m_idx: usize, s_idx: usize) -> Self {
        Self { h_idx, c_idx, m_idx, s_idx }
    }

    /// Index distance per axis `(dH, dC, dM, dS)`.
    pub fn distance(&self, o: &DisaggConfig) -> (usize, usize, usize, usize) {
        (
            self.h_idx.abs_diff(o.h_idx),
            self.c_idx.abs_diff(o.c_idx),
            self.m_idx.abs_diff(o.m_idx),
            self.s_idx.abs_diff(o.s_idx),
        )
    }
}

/// The 4-D plane: H values plus three resource axes.
#[derive(Debug, Clone)]
pub struct DisaggPlane {
    h_values: Vec<u32>,
    /// compute: value = cpu cores; bandwidth rides along at
    /// `bw_per_cpu` Gbps per core (NICs scale with instance compute).
    compute: Axis,
    memory: Axis,
    storage: Axis,
    bw_per_cpu: f32,
}

impl DisaggPlane {
    pub fn new(h_values: Vec<u32>, compute: Axis, memory: Axis, storage: Axis, bw_per_cpu: f32) -> Self {
        assert!(!h_values.is_empty());
        assert!(!compute.is_empty() && !memory.is_empty() && !storage.is_empty());
        Self { h_values, compute, memory, storage, bw_per_cpu }
    }

    /// Derive the disaggregated plane from the paper's coupled tiers:
    /// each axis gets the tier ladder's values, with the bundle price
    /// split 50% compute / 30% memory / 20% storage.
    pub fn from_config(cfg: &ModelConfig) -> Self {
        let tiers = &cfg.plane.tiers;
        let compute = Axis {
            name: "compute",
            steps: tiers.iter().map(|t| (t.cpu, 0.5 * t.cost)).collect(),
        };
        let memory = Axis {
            name: "memory",
            steps: tiers.iter().map(|t| (t.ram, 0.3 * t.cost)).collect(),
        };
        let storage = Axis {
            name: "storage",
            steps: tiers.iter().map(|t| (t.iops, 0.2 * t.cost)).collect(),
        };
        let bw_per_cpu = tiers[0].bandwidth / tiers[0].cpu;
        Self::new(cfg.plane.h_values.clone(), compute, memory, storage, bw_per_cpu)
    }

    pub fn n_h(&self) -> usize {
        self.h_values.len()
    }

    pub fn axes(&self) -> (&Axis, &Axis, &Axis) {
        (&self.compute, &self.memory, &self.storage)
    }

    /// Total number of configurations.
    pub fn len(&self) -> usize {
        self.n_h() * self.compute.len() * self.memory.len() * self.storage.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn contains(&self, c: &DisaggConfig) -> bool {
        c.h_idx < self.n_h()
            && c.c_idx < self.compute.len()
            && c.m_idx < self.memory.len()
            && c.s_idx < self.storage.len()
    }

    pub fn h_value(&self, c: &DisaggConfig) -> u32 {
        self.h_values[c.h_idx]
    }

    /// Synthesize the virtual tier for a combo.
    pub fn tier_for(&self, c: &DisaggConfig) -> Tier {
        let (cpu, c_cost) = self.compute.steps[c.c_idx];
        let (ram, m_cost) = self.memory.steps[c.m_idx];
        let (iops, s_cost) = self.storage.steps[c.s_idx];
        Tier {
            name: format!("c{}m{}s{}", c.c_idx, c.m_idx, c.s_idx),
            cpu,
            ram,
            bandwidth: cpu * self.bw_per_cpu,
            iops,
            cost: c_cost + m_cost + s_cost,
        }
    }

    /// The "matched" combo corresponding to coupled tier index `v`.
    pub fn matched(&self, h_idx: usize, v_idx: usize) -> DisaggConfig {
        DisaggConfig::new(h_idx, v_idx, v_idx, v_idx)
    }

    /// Iterate all configurations in (H, C, M, S)-major order.
    pub fn iter(&self) -> impl Iterator<Item = DisaggConfig> + '_ {
        let (nc, nm, ns) = (self.compute.len(), self.memory.len(), self.storage.len());
        (0..self.n_h()).flat_map(move |h| {
            (0..nc).flat_map(move |c| {
                (0..nm).flat_map(move |m| (0..ns).map(move |s| DisaggConfig::new(h, c, m, s)))
            })
        })
    }

    /// Hyper-local neighborhood: every in-bounds ±1 combination on the
    /// four axes (<= 81 candidates, self included), in iteration order.
    pub fn neighbors(&self, cur: &DisaggConfig) -> Vec<DisaggConfig> {
        let mut out = Vec::with_capacity(81);
        for dh in -1i32..=1 {
            let h = cur.h_idx as i32 + dh;
            if h < 0 || h >= self.n_h() as i32 {
                continue;
            }
            for dc in -1i32..=1 {
                let c = cur.c_idx as i32 + dc;
                if c < 0 || c >= self.compute.len() as i32 {
                    continue;
                }
                for dm in -1i32..=1 {
                    let m = cur.m_idx as i32 + dm;
                    if m < 0 || m >= self.memory.len() as i32 {
                        continue;
                    }
                    for ds in -1i32..=1 {
                        let s = cur.s_idx as i32 + ds;
                        if s < 0 || s >= self.storage.len() as i32 {
                            continue;
                        }
                        out.push(DisaggConfig::new(
                            h as usize, c as usize, m as usize, s as usize,
                        ));
                    }
                }
            }
        }
        out
    }

    /// The storage-axis share of a combo's fleet-wide hourly price:
    /// `H * cost(S)`. This is exactly the slice of the bill that the
    /// serverless tier replaces with the shared
    /// [`crate::serverless::StorageService`] per-GB-hour rate when a
    /// tenant suspends — the compute and memory axes vanish with the
    /// nodes, the storage obligation does not.
    pub fn detached_storage_cost(&self, c: &DisaggConfig) -> f32 {
        self.h_values[c.h_idx] as f32 * self.storage.steps[c.s_idx].1
    }

    /// One-step scale-up on every axis (fallback).
    pub fn fallback_up(&self, cur: &DisaggConfig) -> DisaggConfig {
        DisaggConfig::new(
            (cur.h_idx + 1).min(self.n_h() - 1),
            (cur.c_idx + 1).min(self.compute.len() - 1),
            (cur.m_idx + 1).min(self.memory.len() - 1),
            (cur.s_idx + 1).min(self.storage.len() - 1),
        )
    }
}

/// Surfaces + Algorithm 1 over the 4-D plane.
pub struct DisaggModel {
    plane: DisaggPlane,
    consts: SurfaceConfig,
    write_ratio: f32,
    sla: SlaSpec,
    /// Rebalance penalty weights: H heaviest (data movement), then the
    /// resource axes (rolling restarts).
    pub reb: [f32; 4],
}

impl DisaggModel {
    pub fn from_config(cfg: &ModelConfig) -> Self {
        Self {
            plane: DisaggPlane::from_config(cfg),
            consts: cfg.surfaces,
            write_ratio: cfg.write_ratio(),
            sla: SlaSpec::from_config(cfg),
            reb: [cfg.policy.reb_h, cfg.policy.reb_v, cfg.policy.reb_v, cfg.policy.reb_v],
        }
    }

    pub fn plane(&self) -> &DisaggPlane {
        &self.plane
    }

    fn coord_latency(&self, h: u32) -> f32 {
        let s = &self.consts;
        let log_h = (h as f32).ln();
        s.eta * log_h + s.mu * (s.theta * log_h).exp()
    }

    /// All five §III surfaces at a 4-D configuration.
    pub fn evaluate(&self, c: &DisaggConfig, lambda_req: f32) -> crate::surfaces::SurfacePoint {
        let t = self.plane.tier_for(c);
        let h = self.plane.h_value(c);
        let s = &self.consts;
        let l_node = s.a / t.cpu + s.b / t.ram + s.c / t.bandwidth + s.d / t.iops_k();
        let l_coord = self.coord_latency(h);
        let latency = l_node + l_coord;
        let phi = 1.0 / (1.0 + s.omega * (h as f32).ln());
        let throughput = h as f32 * s.kappa * t.min_resource() * phi;
        let cost = h as f32 * t.cost;
        let lambda_w = lambda_req * self.write_ratio;
        let coordination = s.rho * l_coord * lambda_w / throughput;
        let objective =
            s.alpha * latency + s.beta * cost + s.gamma * coordination - s.delta * throughput;
        crate::surfaces::SurfacePoint { latency, throughput, cost, coordination, objective }
    }

    pub fn feasible(&self, c: &DisaggConfig, lambda_req: f32) -> bool {
        let p = self.evaluate(c, lambda_req);
        self.sla.feasible(p.latency, p.throughput, lambda_req)
    }

    fn penalty(&self, from: &DisaggConfig, to: &DisaggConfig) -> f32 {
        let (dh, dc, dm, ds) = from.distance(to);
        self.reb[0] * dh as f32
            + self.reb[1] * dc as f32
            + self.reb[2] * dm as f32
            + self.reb[3] * ds as f32
    }

    /// Algorithm 1 generalized to the 4-D neighborhood.
    pub fn decide(&self, cur: &DisaggConfig, lambda_req: f32) -> (DisaggConfig, bool) {
        let mut best: Option<(DisaggConfig, f32)> = None;
        for cand in self.plane.neighbors(cur) {
            if !self.feasible(&cand, lambda_req) {
                continue;
            }
            let score = self.evaluate(&cand, lambda_req).objective + self.penalty(cur, &cand);
            if best.map_or(true, |(_, b)| score < b) {
                best = Some((cand, score));
            }
        }
        match best {
            Some((c, _)) => (c, false),
            None => (self.plane.fallback_up(cur), true),
        }
    }

    /// Serve-then-move simulation over a trace (the 4-D twin of
    /// [`crate::simulator::Simulator`]); returns `(records, summary,
    /// fallbacks)` with the 2-D record type (config projected to
    /// `(h_idx, c_idx)` for trajectory plots).
    pub fn simulate(&self, trace: &Trace, start: DisaggConfig) -> (Vec<StepRecord>, Summary, usize) {
        assert!(self.plane.contains(&start));
        let mut recorder = Recorder::with_capacity(trace.len());
        let mut fallbacks = 0usize;
        let mut cur = start;
        for (t, w) in trace.points.iter().enumerate() {
            let p = self.evaluate(&cur, w.lambda_req);
            let l_eff =
                queueing::effective_latency(p.latency, p.throughput, w.lambda_req, self.consts.u_max);
            let s = &self.consts;
            let obj_eff =
                s.alpha * l_eff + s.beta * p.cost + s.gamma * p.coordination - s.delta * p.throughput;
            recorder.push(StepRecord {
                step: t,
                config: crate::plane::Configuration::new(cur.h_idx, cur.c_idx),
                lambda_req: w.lambda_req,
                latency: l_eff,
                latency_raw: p.latency,
                throughput: p.throughput,
                cost: p.cost,
                objective: obj_eff,
                violation: self.sla.audit(p.latency, p.throughput, w.lambda_req),
            });
            let (next, fb) = self.decide(&cur, w.lambda_req);
            if fb {
                fallbacks += 1;
            }
            cur = next;
        }
        let summary = recorder.summary();
        (recorder.records().to_vec(), summary, fallbacks)
    }
}

/// Wide grid width shared with the `surfaces_wide` artifact
/// (`python/compile/defaults.py::WIDE`): 4x4x4 (C, M, S) combos.
pub const WIDE: usize = 64;

/// Flatten the 4-D plane into the wide-kernel ABI:
/// `(hs[GRID], tiers[WIDE*5], mask[GRID*WIDE], combos[WIDE])` where
/// column `j` holds combo `(c, m, s) = (j/16, (j/4)%4, j%4)`.
pub fn wide_grid_arrays(plane: &DisaggPlane) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<DisaggConfig>) {
    let g = crate::GRID;
    let (nc, nm, ns) = {
        let (c, m, s) = plane.axes();
        (c.len(), m.len(), s.len())
    };
    assert_eq!(nc * nm * ns, WIDE, "wide ABI expects a 4x4x4 combo space");
    let mut hs = vec![1.0f32; g];
    for (i, h) in (0..plane.n_h()).map(|i| (i, plane.h_values[i])) {
        hs[i] = h as f32;
    }
    let mut tiers = vec![1.0f32; WIDE * 5];
    let mut combos = Vec::with_capacity(WIDE);
    for j in 0..WIDE {
        let cfg = DisaggConfig::new(0, j / (nm * ns), (j / ns) % nm, j % ns);
        let t = plane.tier_for(&cfg);
        tiers[j * 5] = t.cpu;
        tiers[j * 5 + 1] = t.ram;
        tiers[j * 5 + 2] = t.bandwidth;
        tiers[j * 5 + 3] = t.iops_k();
        tiers[j * 5 + 4] = t.cost;
        combos.push(cfg);
    }
    let mut mask = vec![0.0f32; g * WIDE];
    for i in 0..plane.n_h() {
        for j in 0..WIDE {
            mask[i * WIDE + j] = 1.0;
        }
    }
    (hs, tiers, mask, combos)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{PolicyKind, Simulator};
    use crate::workload::TraceBuilder;

    fn model() -> DisaggModel {
        DisaggModel::from_config(&ModelConfig::default_paper())
    }

    #[test]
    fn plane_has_256_configs() {
        let m = model();
        assert_eq!(m.plane().len(), 4 * 4 * 4 * 4);
        assert_eq!(m.plane().iter().count(), 256);
    }

    #[test]
    fn matched_combo_equals_coupled_tier() {
        // the matched combo reproduces the coupled tier's resources and
        // total cost exactly (cost split sums back to the bundle price)
        let cfg = ModelConfig::default_paper();
        let m = model();
        for v in 0..4 {
            let t2 = &cfg.plane.tiers[v];
            let t4 = m.plane().tier_for(&m.plane().matched(0, v));
            assert_eq!(t4.cpu, t2.cpu);
            assert_eq!(t4.ram, t2.ram);
            assert_eq!(t4.iops, t2.iops);
            assert!((t4.bandwidth - t2.bandwidth).abs() < 1e-5);
            assert!((t4.cost - t2.cost).abs() < 1e-6);
        }
    }

    #[test]
    fn matched_surfaces_equal_coupled_surfaces() {
        let cfg = ModelConfig::default_paper();
        let coupled = crate::surfaces::SurfaceModel::from_config(&cfg);
        let m = model();
        for h in 0..4 {
            for v in 0..4 {
                let p2 = coupled.evaluate(&crate::plane::Configuration::new(h, v), 9000.0);
                let p4 = m.evaluate(&m.plane().matched(h, v), 9000.0);
                assert!((p2.latency - p4.latency).abs() < 1e-4);
                assert!((p2.throughput - p4.throughput).abs() < 0.5);
                assert!((p2.cost - p4.cost).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn interior_neighborhood_is_81() {
        let m = model();
        let n = m.plane().neighbors(&DisaggConfig::new(1, 1, 1, 1));
        assert_eq!(n.len(), 81);
        let corner = m.plane().neighbors(&DisaggConfig::new(0, 0, 0, 0));
        assert_eq!(corner.len(), 16); // 2^4
    }

    #[test]
    fn decisions_feasible_or_fallback() {
        let m = model();
        for lam in [1000.0, 6000.0, 16000.0, 1e9] {
            let (next, fb) = m.decide(&DisaggConfig::new(1, 1, 1, 1), lam);
            assert!(m.plane().contains(&next));
            if !fb {
                assert!(m.feasible(&next, lam), "lam={lam}");
            }
        }
    }

    #[test]
    fn disaggregation_never_costs_more_than_coupled() {
        // the coupled ladder is a subspace: per-step chosen cost under
        // the same trace must satisfy sum(disagg) <= sum(coupled) + eps
        let cfg = ModelConfig::default_paper();
        let trace = TraceBuilder::paper(&cfg);
        let coupled = Simulator::new(&cfg).run(PolicyKind::Diagonal, &trace);
        let m = model();
        let start = m.plane().matched(cfg.policy.start[0], cfg.policy.start[1]);
        let (_, summary, _) = m.simulate(&trace, start);
        assert!(
            summary.avg_cost <= coupled.summary.avg_cost + 1e-3,
            "disagg {} vs coupled {}",
            summary.avg_cost,
            coupled.summary.avg_cost
        );
        // and it must not pay for that with SLA violations
        assert!(summary.violations <= coupled.summary.violations + 1);
    }

    #[test]
    fn disagg_exploits_the_bottleneck_structure() {
        // under throughput pressure only the min-resource matters; the
        // 4-D policy should avoid maxing non-bottleneck axes
        let m = model();
        let (_, summary, _) = m.simulate(
            &TraceBuilder::paper(&ModelConfig::default_paper()),
            m.plane().matched(1, 1),
        );
        assert!(summary.steps == 50);
        assert!(summary.violations <= 5);
    }

    #[test]
    fn detached_storage_cost_is_the_s_axis_slice() {
        // independent of the compute/memory indices, scales with H,
        // and sums with the other axes back to the full combo price
        let m = model();
        let p = m.plane();
        let a = DisaggConfig::new(1, 0, 0, 2);
        let b = DisaggConfig::new(1, 3, 3, 2);
        assert!((p.detached_storage_cost(&a) - p.detached_storage_cost(&b)).abs() < 1e-6);
        let lo = DisaggConfig::new(0, 1, 1, 1);
        let hi = DisaggConfig::new(2, 1, 1, 1);
        assert!(p.detached_storage_cost(&hi) > p.detached_storage_cost(&lo));
        let (cax, max_, _) = p.axes();
        let full = p.h_value(&a) as f32 * p.tier_for(&a).cost;
        let rest = p.h_value(&a) as f32 * (cax.steps[a.c_idx].1 + max_.steps[a.m_idx].1);
        assert!((p.detached_storage_cost(&a) + rest - full).abs() < 1e-4);
    }

    #[test]
    fn simulate_is_deterministic() {
        let cfg = ModelConfig::default_paper();
        let trace = TraceBuilder::paper(&cfg);
        let m = model();
        let a = m.simulate(&trace, m.plane().matched(1, 1));
        let b = m.simulate(&trace, m.plane().matched(1, 1));
        assert_eq!(a.0, b.0);
    }
}
