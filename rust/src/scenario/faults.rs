//! Fault-schedule generators: zone outages, correlated failure
//! storms, and rolling restarts. A schedule is a plain list of
//! [`FaultEvent`]s — (tenant, tick, node) triples — that
//! [`crate::fleet::FleetSimulator::schedule_faults`] layers onto each
//! tenant's DES calendar through the existing
//! [`crate::fleet::Tenant::schedule_node_failure`] path. Nothing here
//! touches the event substrate directly, so schedules compose with any
//! run length and any repair policy.

use crate::metrics::hll::hash_u64;
use crate::workload::XorShift64;

/// One scheduled node failure: tenant `tenant` loses node index
/// `node` at tick `at_tick` (the failure lands mid-interval, so the
/// tick's serve sees it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub tenant: usize,
    pub at_tick: usize,
    pub node: usize,
}

/// A deterministic tenant-node → availability-zone assignment. Real
/// placements stripe each tenant's replicas across zones; this model
/// hashes (tenant, node) into one of `zones` buckets so a zone outage
/// hits exactly the nodes mapped to it — different tenants lose
/// different node indices, and some tenants (all replicas elsewhere)
/// are untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ZoneMap {
    zones: u64,
    seed: u64,
}

impl ZoneMap {
    pub fn new(zones: u64, seed: u64) -> Self {
        assert!(zones > 0, "need at least one zone");
        Self { zones, seed }
    }

    /// The zone hosting `tenant`'s node `node`. Pure in (self, tenant,
    /// node): the same map always answers the same.
    pub fn zone_of(&self, tenant: usize, node: usize) -> u64 {
        hash_u64(self.seed ^ ((tenant as u64) << 20) ^ node as u64) % self.zones
    }

    /// Zone `zone` goes dark at `at_tick`: every (tenant, node) pair
    /// in `0..tenants` × `0..nodes_per_tenant` that maps to the zone
    /// fails at the same instant. The correlated-failure shape a
    /// per-tenant availability model cannot produce.
    pub fn zone_outage(
        &self,
        tenants: usize,
        nodes_per_tenant: usize,
        zone: u64,
        at_tick: usize,
    ) -> Vec<FaultEvent> {
        let mut out = Vec::new();
        for tenant in 0..tenants {
            for node in 0..nodes_per_tenant {
                if self.zone_of(tenant, node) == zone {
                    out.push(FaultEvent { tenant, at_tick, node });
                }
            }
        }
        out
    }
}

/// A correlated failure storm: a seeded ~`fraction` subset of the
/// fleet each loses node 0, spread uniformly over
/// `[at_tick, at_tick + width)`. Unlike a zone outage the victims are
/// independent across tenants — the "bad kernel rollout" shape.
pub fn failure_storm(
    tenants: usize,
    fraction: f64,
    at_tick: usize,
    width: usize,
    seed: u64,
) -> Vec<FaultEvent> {
    let width = width.max(1);
    let mut rng = XorShift64::new(seed);
    let mut out = Vec::new();
    for tenant in 0..tenants {
        let hit = rng.next_f64() < fraction;
        let offset = rng.below(width as u64) as usize;
        if hit {
            out.push(FaultEvent { tenant, at_tick: at_tick + offset, node: 0 });
        }
    }
    out
}

/// A maintenance sweep: every tenant loses node 0 exactly once,
/// staggered `stride` ticks apart starting at `start_tick` — the
/// rolling-restart schedule operators actually run. Fully
/// deterministic, no seed.
pub fn rolling_restart(tenants: usize, start_tick: usize, stride: usize) -> Vec<FaultEvent> {
    (0..tenants)
        .map(|tenant| FaultEvent { tenant, at_tick: start_tick + tenant * stride.max(1), node: 0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zone_outage_hits_exactly_the_mapped_pairs() {
        let zones = ZoneMap::new(3, 0xABCD);
        let faults = zones.zone_outage(16, 4, 1, 25);
        assert!(!faults.is_empty());
        for f in &faults {
            assert_eq!(zones.zone_of(f.tenant, f.node), 1);
            assert_eq!(f.at_tick, 25);
        }
        // completeness: every mapped pair is present
        let expected = (0..16)
            .flat_map(|t| (0..4).map(move |n| (t, n)))
            .filter(|&(t, n)| zones.zone_of(t, n) == 1)
            .count();
        assert_eq!(faults.len(), expected);
    }

    #[test]
    fn zone_outage_spares_zone_free_tenants_entirely() {
        let zones = ZoneMap::new(3, 0xABCD);
        let faults = zones.zone_outage(32, 2, 0, 10);
        // with 2 nodes over 3 zones, some tenant has neither node in
        // zone 0 — the outage must not touch it
        let spared = (0..32)
            .find(|&t| (0..2).all(|n| zones.zone_of(t, n) != 0))
            .expect("some tenant should dodge the zone");
        assert!(faults.iter().all(|f| f.tenant != spared));
    }

    #[test]
    fn failure_storm_stays_inside_its_window_and_fraction() {
        let faults = failure_storm(64, 0.5, 20, 6, 0x5EED);
        assert!(!faults.is_empty());
        for f in &faults {
            assert!((20..26).contains(&f.at_tick));
            assert_eq!(f.node, 0);
        }
        // seeded half-ish of the fleet: generous but bounded
        assert!((16..=48).contains(&faults.len()), "got {}", faults.len());
        assert_eq!(faults, failure_storm(64, 0.5, 20, 6, 0x5EED));
    }

    #[test]
    fn rolling_restart_staggers_every_tenant_once() {
        let faults = rolling_restart(5, 10, 2);
        assert_eq!(faults.len(), 5);
        for (i, f) in faults.iter().enumerate() {
            assert_eq!(f.tenant, i);
            assert_eq!(f.at_tick, 10 + 2 * i);
        }
    }
}
