//! The serverless fleet-shape builders, moved here from
//! `serverless/mod.rs` so all scenario construction lives in one
//! place. `crate::serverless` re-exports them, so every existing call
//! site (tests, benches, CLI) is unchanged.

use crate::config::ModelConfig;
use crate::fleet::{PriorityClass, TenantSpec};
use crate::workload::TraceBuilder;

/// Classes cycle Gold/Silver/Bronze across a fleet, so every cohort
/// spans every class.
pub(crate) fn class_for(i: usize) -> PriorityClass {
    match i % 3 {
        0 => PriorityClass::Gold,
        1 => PriorityClass::Silver,
        _ => PriorityClass::Bronze,
    }
}

/// The pinned mostly-idle scenario: `n` tenants of which
/// `round(n * idle_fraction)` are idle nearly all the time — zero
/// demand except one short burst per cycle, staggered so wakes do not
/// collide — while the rest carry the paper trace phase-shifted.
/// Classes cycle Gold/Silver/Bronze across the whole fleet, so idle
/// tenants span every class.
pub fn mostly_idle_specs(cfg: &ModelConfig, n: usize, idle_fraction: f32) -> Vec<TenantSpec> {
    assert!(n > 0, "fleet needs at least one tenant");
    assert!((0.0..=1.0).contains(&idle_fraction), "idle_fraction in [0, 1]");
    let b = TraceBuilder::from_config(cfg);
    let base = TraceBuilder::paper(cfg);
    let steps = base.len();
    let idle = ((n as f32 * idle_fraction).round() as usize).min(n);
    let active = n - idle;
    (0..n)
        .map(|i| {
            let trace = if i < active {
                base.shifted(i * steps / active.max(1))
            } else {
                let j = i - active;
                b.spike(0.0, 30.0, (j * steps) / idle.max(1), 3, steps)
            };
            TenantSpec::from_config(cfg, format!("t{i}"), class_for(i), trace)
        })
        .collect()
}

/// The pinned wake-storm scenario: like [`mostly_idle_specs`] but every
/// idle tenant's burst lands at the *same* tick `storm_at` for
/// `storm_width` ticks — a correlated burst that wakes the whole
/// suspended cohort at once, stressing cold-start queueing and the
/// arbiter's class-ordered repair pass.
pub fn wake_storm_specs(
    cfg: &ModelConfig,
    n: usize,
    idle_fraction: f32,
    storm_at: usize,
    storm_width: usize,
) -> Vec<TenantSpec> {
    assert!(n > 0, "fleet needs at least one tenant");
    assert!((0.0..=1.0).contains(&idle_fraction), "idle_fraction in [0, 1]");
    let b = TraceBuilder::from_config(cfg);
    let base = TraceBuilder::paper(cfg);
    let steps = base.len().max(storm_at + storm_width + 10);
    let idle = ((n as f32 * idle_fraction).round() as usize).min(n);
    let active = n - idle;
    (0..n)
        .map(|i| {
            let trace = if i < active {
                base.shifted(i * base.len() / active.max(1))
            } else {
                b.spike(0.0, 30.0, storm_at, storm_width, steps)
            };
            TenantSpec::from_config(cfg, format!("t{i}"), class_for(i), trace)
        })
        .collect()
}

/// The fixed-activity scale scenario behind the 10k-tenant bench: the
/// active set does **not** grow with fleet size. `active` tenants carry
/// the phase-shifted paper trace, `bursty` tenants spike periodically
/// (staggered, so they park, wake through priced cold starts, and park
/// again), and every remaining tenant sees constant zero demand — it
/// parks once after the initial idle window and never moves again.
/// Under a dirty-queue control plane, per-tick planning work on this
/// fleet must therefore approach `active + bursty + O(refresh)`
/// regardless of `n` — the sublinearity the tier-2 scale test pins.
pub fn sparse_activity_specs(
    cfg: &ModelConfig,
    n: usize,
    active: usize,
    bursty: usize,
) -> Vec<TenantSpec> {
    assert!(n > 0, "fleet needs at least one tenant");
    assert!(active + bursty <= n, "cohorts cannot exceed the fleet");
    let b = TraceBuilder::from_config(cfg);
    let base = TraceBuilder::paper(cfg);
    let steps = base.len();
    (0..n)
        .map(|i| {
            let trace = if i < active {
                base.shifted(i * steps / active.max(1))
            } else if i < active + bursty {
                let j = i - active;
                b.spike(0.0, 30.0, (j * steps) / bursty.max(1), 3, steps)
            } else {
                b.constant(0.0, steps)
            };
            TenantSpec::from_config(cfg, format!("t{i}"), class_for(i), trace)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mostly_idle_specs_shape() {
        let cfg = ModelConfig::default_paper();
        let specs = mostly_idle_specs(&cfg, 16, 0.75);
        assert_eq!(specs.len(), 16);
        // 12 idle tenants: zero demand outside their 3-tick burst
        let idle: Vec<_> = specs[4..].iter().collect();
        assert_eq!(idle.len(), 12);
        for s in &idle {
            let zero = s.trace.points.iter().filter(|w| w.lambda_req == 0.0).count();
            assert!(zero >= s.trace.len() - 3, "{} not mostly idle", s.name);
        }
        // active tenants carry real load every tick
        for s in &specs[..4] {
            assert!(s.trace.points.iter().all(|w| w.lambda_req > 0.0));
        }
        // classes span the idle cohort too
        assert!(idle.iter().any(|s| s.class == PriorityClass::Gold));
        assert!(idle.iter().any(|s| s.class == PriorityClass::Bronze));
    }

    #[test]
    fn wake_storm_bursts_are_correlated() {
        let cfg = ModelConfig::default_paper();
        let specs = wake_storm_specs(&cfg, 20, 0.9, 30, 4);
        let idle = &specs[2..];
        assert_eq!(idle.len(), 18);
        for s in idle {
            assert_eq!(s.trace.points[29].lambda_req, 0.0);
            assert!(s.trace.points[30].lambda_req > 0.0, "{} misses the storm", s.name);
            assert!(s.trace.points[33].lambda_req > 0.0);
            assert_eq!(s.trace.points[35].lambda_req, 0.0);
        }
    }
}
