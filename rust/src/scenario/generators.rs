//! Composable trace generators for production workload shapes:
//! diurnal+weekly composites, correlated flash crowds, and
//! heavy-tailed Pareto tenant sizes. Everything is deterministic in
//! its seed (the [`XorShift64`] stream is the only randomness source)
//! and composes with the [`TraceBuilder`] families — generators here
//! return plain [`Trace`]s / [`TenantSpec`]s, nothing scenario-shaped
//! leaks into the workload layer.

use crate::config::ModelConfig;
use crate::fleet::TenantSpec;
use crate::workload::{Trace, TraceBuilder, WorkloadPoint, XorShift64};

use super::class_for;

fn point(cfg: &ModelConfig, intensity: f32) -> WorkloadPoint {
    WorkloadPoint::new(intensity.max(0.0) * cfg.workload.thr_factor, cfg.write_ratio())
}

/// Diurnal sinusoid between `lo` and `hi` intensity with period
/// `day` ticks, modulated by a 7-day seasonal envelope of relative
/// amplitude `week_amp` (0 disables the weekly component and the
/// result matches [`TraceBuilder::sine`] shapes).
pub fn diurnal_weekly(
    cfg: &ModelConfig,
    lo: f32,
    hi: f32,
    day: usize,
    week_amp: f32,
    steps: usize,
) -> Trace {
    let mid = (lo + hi) / 2.0;
    let amp = (hi - lo) / 2.0;
    let day = day.max(1);
    let points = (0..steps)
        .map(|t| {
            let dp = t as f32 / day as f32 * std::f32::consts::TAU;
            let wp = t as f32 / (7 * day) as f32 * std::f32::consts::TAU;
            let i = (mid + amp * dp.sin()) * (1.0 + week_amp * wp.sin());
            point(cfg, i)
        })
        .collect();
    Trace { name: "diurnal-weekly".into(), points }
}

/// One draw of `n` correlated participation flags with marginal
/// probability `p` and pairwise correlation `rho`, via the standard
/// mixture construction: a common Bernoulli(`p`) event is drawn once,
/// and each tenant copies it with probability `sqrt(rho)` or draws its
/// own independent Bernoulli(`p`) otherwise. The indicator correlation
/// between any two tenants is then exactly `rho` (both must copy the
/// common draw: `sqrt(rho)^2`). Every tenant consumes exactly two rng
/// values, so the stream stays aligned regardless of outcomes.
pub fn correlated_flags(n: usize, p: f64, rho: f64, rng: &mut XorShift64) -> Vec<bool> {
    let m = rho.clamp(0.0, 1.0).sqrt();
    let common = rng.next_f64() < p;
    (0..n)
        .map(|_| {
            let copies = rng.next_f64() < m;
            let own = rng.next_f64() < p;
            if copies {
                common
            } else {
                own
            }
        })
        .collect()
}

/// [`correlated_flags`] conditioned on the regional event firing
/// (`common = true`): the crowd-membership draw presets use, so a
/// named flash-crowd scenario always contains its crowd. Marginal
/// participation becomes `sqrt(rho) + (1 - sqrt(rho)) * p`.
pub fn crowd_members(n: usize, p: f64, rho: f64, rng: &mut XorShift64) -> Vec<bool> {
    let m = rho.clamp(0.0, 1.0).sqrt();
    (0..n)
        .map(|_| {
            let copies = rng.next_f64() < m;
            let own = rng.next_f64() < p;
            copies || own
        })
        .collect()
}

/// Add `add` intensity on `[at, at + width)` — the overlay the flash
/// crowd applies on top of a baseline trace. Both demand fields shift
/// together so the write ratio is preserved.
pub fn overlay_spike(cfg: &ModelConfig, trace: &Trace, add: f32, at: usize, width: usize) -> Trace {
    let thr = add.max(0.0) * cfg.workload.thr_factor;
    let points = trace
        .points
        .iter()
        .enumerate()
        .map(|(t, pt)| {
            if t >= at && t < at + width {
                WorkloadPoint {
                    lambda_req: pt.lambda_req + thr,
                    lambda_w: pt.lambda_w + thr * cfg.write_ratio(),
                }
            } else {
                *pt
            }
        })
        .collect();
    Trace { name: format!("{}+spike", trace.name), points }
}

/// Scale every demand point by `factor` (tenant-size scaling).
pub fn scale_trace(trace: &Trace, factor: f32) -> Trace {
    let points = trace
        .points
        .iter()
        .map(|p| WorkloadPoint { lambda_req: p.lambda_req * factor, lambda_w: p.lambda_w * factor })
        .collect();
    Trace { name: format!("{}x{factor}", trace.name), points }
}

/// One Pareto(`alpha`, `x_min`) draw by inverse transform:
/// `x_min * u^(-1/alpha)` with `u` uniform on `(0, 1]`. Heavy-tailed
/// for small `alpha` (infinite variance below 2, infinite mean below
/// 1) — the classic tenant-size distribution.
pub fn pareto(rng: &mut XorShift64, alpha: f64, x_min: f64) -> f64 {
    assert!(alpha > 0.0 && x_min > 0.0, "pareto needs positive parameters");
    let u = 1.0 - rng.next_f64(); // (0, 1]
    x_min * u.powf(-1.0 / alpha)
}

/// `n` seeded Pareto sizes, clamped at `cap` so a single astronomically
/// large draw cannot dwarf the plane's feasible range. Most draws land
/// near `x_min`; the tail is pinned by `tests/prop_scenario.rs`.
pub fn pareto_sizes(n: usize, alpha: f64, x_min: f64, cap: f64, seed: u64) -> Vec<f64> {
    let mut rng = XorShift64::new(seed);
    (0..n).map(|_| pareto(&mut rng, alpha, x_min).min(cap)).collect()
}

/// The flash-crowd fleet: a shared diurnal baseline (one region — no
/// phase shifting), and a crowd drawn with pairwise correlation `rho`
/// that all spike at the same tick `at` for `width` ticks. Classes
/// cycle Gold/Silver/Bronze.
pub fn flash_crowd_specs(
    cfg: &ModelConfig,
    n: usize,
    rho: f64,
    at: usize,
    width: usize,
    steps: usize,
    seed: u64,
) -> Vec<TenantSpec> {
    assert!(n > 0, "fleet needs at least one tenant");
    let mut rng = XorShift64::new(seed);
    let base = diurnal_weekly(cfg, 40.0, 100.0, 24, 0.0, steps);
    let members = crowd_members(n, 0.15, rho, &mut rng);
    (0..n)
        .map(|i| {
            let trace = if members[i] {
                overlay_spike(cfg, &base, 80.0, at, width)
            } else {
                base.clone()
            };
            TenantSpec::from_config(cfg, format!("t{i}"), class_for(i), trace)
        })
        .collect()
}

/// The black-friday fleet: a full week of diurnal+weekly seasonality
/// with a strongly correlated spike landing at the weekly peak (tick
/// `7 * 24 / 4`, where the weekly envelope tops out).
pub fn black_friday_specs(
    cfg: &ModelConfig,
    n: usize,
    rho: f64,
    steps: usize,
    seed: u64,
) -> Vec<TenantSpec> {
    assert!(n > 0, "fleet needs at least one tenant");
    let mut rng = XorShift64::new(seed);
    let base = diurnal_weekly(cfg, 40.0, 110.0, 24, 0.3, steps);
    let at = (7 * 24) / 4;
    let members = crowd_members(n, 0.2, rho, &mut rng);
    (0..n)
        .map(|i| {
            let trace = if members[i] {
                overlay_spike(cfg, &base, 70.0, at, 6)
            } else {
                base.clone()
            };
            TenantSpec::from_config(cfg, format!("t{i}"), class_for(i), trace)
        })
        .collect()
}

/// The heavy-tail fleet: the paper trace phase-shifted per tenant and
/// scaled by the given (Pareto-drawn) sizes — most tenants tiny, a few
/// near full size: the shared-host packing regime.
pub fn heavy_tail_specs(cfg: &ModelConfig, sizes: &[f64], _seed: u64) -> Vec<TenantSpec> {
    assert!(!sizes.is_empty(), "fleet needs at least one tenant");
    let base = TraceBuilder::paper(cfg);
    let n = sizes.len();
    sizes
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let trace = scale_trace(&base.shifted(i * base.len() / n), s as f32);
            TenantSpec::from_config(cfg, format!("t{i}"), class_for(i), trace)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_weekly_is_bounded_and_modulated() {
        let cfg = ModelConfig::default_paper();
        let t = diurnal_weekly(&cfg, 40.0, 100.0, 24, 0.3, 7 * 24);
        assert_eq!(t.len(), 168);
        let thr = cfg.workload.thr_factor;
        for p in &t.points {
            assert!(p.lambda_req >= 0.0);
            assert!(p.lambda_req <= 100.0 * 1.3 * thr * 1.001);
        }
        // the weekly envelope makes the late-week daily peak differ
        // from the early-week one
        let peak = |day: usize| {
            t.points[day * 24..(day + 1) * 24]
                .iter()
                .map(|p| p.lambda_req)
                .fold(0.0f32, f32::max)
        };
        assert!((peak(1) - peak(5)).abs() > 1.0, "weekly modulation missing");
        // week_amp = 0 collapses to a pure diurnal sine
        let flat = diurnal_weekly(&cfg, 40.0, 100.0, 24, 0.0, 48);
        assert!((flat.points[0].lambda_req - flat.points[24].lambda_req).abs() < 1e-3);
    }

    #[test]
    fn overlay_spike_adds_only_inside_the_window() {
        let cfg = ModelConfig::default_paper();
        let base = diurnal_weekly(&cfg, 40.0, 100.0, 24, 0.0, 40);
        let t = overlay_spike(&cfg, &base, 80.0, 10, 4);
        for i in 0..40 {
            let d = t.points[i].lambda_req - base.points[i].lambda_req;
            if (10..14).contains(&i) {
                assert!((d - 80.0 * cfg.workload.thr_factor).abs() < 1e-3, "step {i}");
            } else {
                assert_eq!(d, 0.0, "step {i} leaked the spike");
            }
        }
    }

    #[test]
    fn crowd_members_all_join_at_full_correlation() {
        let mut rng = XorShift64::new(9);
        let flags = crowd_members(32, 0.1, 1.0, &mut rng);
        assert!(flags.iter().all(|&f| f), "rho = 1 must take everyone");
    }

    #[test]
    fn pareto_draws_sit_above_x_min_and_respect_the_cap() {
        let sizes = pareto_sizes(500, 1.3, 0.05, 1.0, 0xFEED);
        assert!(sizes.iter().all(|&s| (0.05..=1.0).contains(&s)));
        // heavy tail: some draws hit the cap, most stay small
        assert!(sizes.iter().filter(|&&s| s >= 1.0).count() >= 1);
        let small = sizes.iter().filter(|&&s| s < 0.15).count();
        assert!(small > 250, "most tenants should be near x_min, got {small}");
    }

    #[test]
    fn scale_trace_scales_both_fields() {
        let cfg = ModelConfig::default_paper();
        let base = TraceBuilder::paper(&cfg);
        let t = scale_trace(&base, 0.25);
        assert_eq!(t.points[0].lambda_req, base.points[0].lambda_req * 0.25);
        assert_eq!(t.points[0].lambda_w, base.points[0].lambda_w * 0.25);
    }
}
