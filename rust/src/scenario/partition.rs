//! Hypergraph-flavored shard-affinity model for partition-aware data
//! movement. Each tenant's dataset is split over a few shards, each
//! tagged with a **co-access hyperedge** — transactions that touch a
//! shard tend to touch every shard on its edge, so partitioners
//! co-locate edges (the hypergraph-partitioning result from the
//! transactional-workload literature). For migration pricing that
//! means: when a tenant moves to a destination where some resident
//! already carries one of its hyperedges, the shards on that edge are
//! effectively co-located/replicated there and do **not** need to be
//! shipped. Moved GB is the weight of the shards whose edges no
//! resident shares — always ≤ the flat per-tenant GB, with equality
//! exactly when nothing is shared (empty or disjoint destinations).
//!
//! [`crate::placement::PlacementSim`] prices migration windows through
//! [`ShardModel::moved_gb`] when a model is attached
//! (`set_shard_model`); the default stays the flat `tenant_gb`
//! baseline so the pinned PR-4 numbers are untouched.

use std::collections::BTreeSet;

use crate::workload::XorShift64;

/// Per-tenant shard list: `(hyperedge, gb)` pairs. Deterministic in
/// its generation seed.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardModel {
    tenants: Vec<Vec<(u64, f64)>>,
}

impl ShardModel {
    /// Seeded model over tenants with dataset sizes `gbs`. Each tenant
    /// gets `shards_per_tenant` shards; shard k carries a Zipf-ish
    /// `1/(k+1)` share of the tenant's GB (a few hot shards own most
    /// of the data, matching skewed production layouts), and each
    /// shard is assigned a hyperedge uniformly from `0..hyperedges`.
    /// Fewer hyperedges → more cross-tenant sharing → cheaper moves.
    pub fn generate(gbs: &[f64], shards_per_tenant: usize, hyperedges: u64, seed: u64) -> Self {
        assert!(shards_per_tenant > 0, "need at least one shard per tenant");
        assert!(hyperedges > 0, "need at least one hyperedge");
        let mut rng = XorShift64::new(seed);
        let norm: f64 = (0..shards_per_tenant).map(|k| 1.0 / (k + 1) as f64).sum();
        let tenants = gbs
            .iter()
            .map(|&gb| {
                (0..shards_per_tenant)
                    .map(|k| {
                        let edge = rng.below(hyperedges);
                        (edge, gb * (1.0 / (k + 1) as f64) / norm)
                    })
                    .collect()
            })
            .collect();
        Self { tenants }
    }

    /// [`ShardModel::generate`] with every tenant at the same
    /// `tenant_gb` — the drop-in partition-aware counterpart of the
    /// flat [`crate::placement::MigrationPlanner`] baseline.
    pub fn uniform(
        n: usize,
        tenant_gb: f64,
        shards_per_tenant: usize,
        hyperedges: u64,
        seed: u64,
    ) -> Self {
        Self::generate(&vec![tenant_gb; n], shards_per_tenant, hyperedges, seed)
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// The tenant's shards as `(hyperedge, gb)` pairs.
    pub fn shards(&self, tenant: usize) -> &[(u64, f64)] {
        &self.tenants[tenant]
    }

    /// Total dataset size — what the flat baseline would ship on every
    /// move.
    pub fn total_gb(&self, tenant: usize) -> f64 {
        self.tenants[tenant].iter().map(|&(_, gb)| gb).sum()
    }

    /// Data that must actually move when `tenant` migrates to a
    /// destination hosting `residents`: the summed GB of the shards
    /// whose hyperedge no resident (other than the tenant itself)
    /// already carries. Invariants, pinned in `tests/prop_scenario.rs`:
    /// `moved_gb ≤ total_gb` always, with equality when `residents` is
    /// empty or shares no edge.
    pub fn moved_gb(&self, tenant: usize, residents: &[usize]) -> f64 {
        let present: BTreeSet<u64> = residents
            .iter()
            .filter(|&&r| r != tenant && r < self.tenants.len())
            .flat_map(|&r| self.tenants[r].iter().map(|&(e, _)| e))
            .collect();
        self.tenants[tenant]
            .iter()
            .filter(|(e, _)| !present.contains(e))
            .map(|&(_, gb)| gb)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_shards_conserve_tenant_gb() {
        let m = ShardModel::generate(&[10.0, 2.5, 0.0], 6, 4, 0xC0DE);
        assert_eq!(m.n_tenants(), 3);
        assert!((m.total_gb(0) - 10.0).abs() < 1e-9);
        assert!((m.total_gb(1) - 2.5).abs() < 1e-9);
        assert_eq!(m.total_gb(2), 0.0);
        // Zipf-ish skew: the first shard is the largest
        let s = m.shards(0);
        assert!(s[0].1 > s[5].1);
    }

    #[test]
    fn empty_destination_moves_everything() {
        let m = ShardModel::uniform(4, 2.0, 6, 4, 0xC0DE);
        for t in 0..4 {
            assert_eq!(m.moved_gb(t, &[]), m.total_gb(t));
            // self-residency never discounts the move
            assert_eq!(m.moved_gb(t, &[t]), m.total_gb(t));
        }
    }

    #[test]
    fn shared_edges_discount_the_move_and_never_inflate_it() {
        // one hyperedge: every shard shares, so any occupied
        // destination means nothing moves
        let one = ShardModel::uniform(4, 2.0, 6, 1, 0xC0DE);
        assert_eq!(one.moved_gb(0, &[1]), 0.0);
        // many edges: moved ≤ total for every resident set
        let m = ShardModel::uniform(6, 2.0, 6, 64, 0xC0DE);
        for t in 0..6 {
            for r in 0..6 {
                let moved = m.moved_gb(t, &[r]);
                assert!(moved <= m.total_gb(t) + 1e-12);
            }
        }
    }

    #[test]
    fn model_is_deterministic_in_its_seed() {
        let a = ShardModel::generate(&[5.0, 1.0], 6, 4, 7);
        let b = ShardModel::generate(&[5.0, 1.0], 6, 4, 7);
        assert_eq!(a, b);
        let c = ShardModel::generate(&[5.0, 1.0], 6, 4, 8);
        assert_ne!(a, c, "different seeds should shuffle edges");
    }
}
