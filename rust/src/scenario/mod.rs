//! Scenario subsystem: the single source of workloads and fault
//! schedules for fleet, placement, and serverless runs.
//!
//! Every pinned result before this module ran on phase-shifted copies
//! of one seasonal trace. Production fleets are not that polite: flash
//! crowds land on many tenants at once (regional events), tenant sizes
//! are heavy-tailed, weekly seasonality modulates the diurnal cycle,
//! and failures arrive correlated — a zone outage takes a node from
//! every tenant mapped to the zone. This module generates those shapes
//! deterministically (seeded through [`crate::workload::XorShift64`],
//! never the wall clock) and packages them as **named presets** the
//! CLI exposes via `fleet --scenario <name>` / `placement --scenario
//! <name>`.
//!
//! Four pieces:
//!
//! * [`generators`] — composable trace generators: diurnal+weekly
//!   composites, correlated flash crowds (a cross-tenant correlation
//!   coefficient realized by a seeded mixture construction), and
//!   heavy-tailed Pareto tenant sizes.
//! * [`partition`] — the hypergraph-flavored shard-affinity model:
//!   each tenant's dataset is split over shards tagged with co-access
//!   hyperedges, so a reconfiguration's data-movement GB depends on
//!   *which* shards actually move ([`ShardModel::moved_gb`]), not just
//!   how much data the tenant owns. [`crate::placement::PlacementSim`]
//!   prices migration windows through it when
//!   [`crate::placement::PlacementSim::set_shard_model`] is called
//!   (default off — the flat `tenant_gb` baseline keeps the pinned
//!   PR-4 numbers).
//! * [`faults`] — fault-schedule generators (zone outages, correlated
//!   failure storms, rolling restarts) that layer onto the fleet's DES
//!   calendars through the existing
//!   [`crate::fleet::Tenant::schedule_node_failure`] path
//!   ([`crate::fleet::FleetSimulator::schedule_faults`]).
//! * Named [`preset`]s — each ships with a pinned planning-vs-flat or
//!   packed-vs-dedicated comparison test in `tests/prop_scenario.rs`
//!   (see `CONTRIBUTING.md`: a preset without a pinned comparison is
//!   not a preset).
//!
//! The serverless spec builders ([`mostly_idle_specs`],
//! [`wake_storm_specs`], [`sparse_activity_specs`]) moved here from
//! `serverless/mod.rs` so all scenario construction lives in one place;
//! `crate::serverless` re-exports them for compatibility.

pub mod faults;
pub mod generators;
pub mod partition;
mod specs;

pub use faults::{failure_storm, rolling_restart, FaultEvent, ZoneMap};
pub use generators::{
    black_friday_specs, correlated_flags, crowd_members, diurnal_weekly, flash_crowd_specs,
    heavy_tail_specs, overlay_spike, pareto, pareto_sizes, scale_trace,
};
pub use partition::ShardModel;
pub use specs::{mostly_idle_specs, sparse_activity_specs, wake_storm_specs};

pub(crate) use specs::class_for;

use crate::config::ModelConfig;
use crate::fleet::TenantSpec;
use crate::workload::TraceBuilder;

/// A fully materialized scenario: tenant specs, a fault schedule for
/// the DES calendars, the natural run length, and (optionally) the
/// shard-affinity model placement runs price data movement through.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// The preset name (stamped into explain-v1 and metrics-v1).
    pub name: &'static str,
    /// The seed every generator in this scenario derived from.
    pub seed: u64,
    /// Natural run length (ticks).
    pub steps: usize,
    pub specs: Vec<TenantSpec>,
    /// Node failures to layer onto the fleet's DES calendars.
    pub faults: Vec<FaultEvent>,
    /// Shard-affinity model for partition-aware migration pricing
    /// (`None` keeps the flat `tenant_gb` baseline).
    pub shards: Option<ShardModel>,
}

/// Every named preset, in CLI help order.
pub const PRESETS: &[&str] = &[
    "flash-crowd",
    "black-friday",
    "heavy-tail",
    "zone-outage",
    "failure-storm",
    "rolling-restart",
];

/// Default tenant count when the CLI does not pass `--tenants`.
pub const DEFAULT_TENANTS: usize = 12;

/// Default scenario seed (any fixed value works; a named one keeps CLI
/// runs replayable).
pub const DEFAULT_SEED: u64 = 0x5CE7_A210;

/// Materialize a named preset. Returns `None` for unknown names (the
/// CLI prints [`PRESETS`]). Every preset is deterministic in
/// `(name, cfg, n, seed)`.
pub fn preset(name: &str, cfg: &ModelConfig, n: usize, seed: u64) -> Option<Scenario> {
    assert!(n > 0, "scenario needs at least one tenant");
    match name {
        // A regional event: diurnal baseline, then a correlated spike
        // hits the crowd members all at the same tick.
        "flash-crowd" => {
            let steps = 60;
            let specs = flash_crowd_specs(cfg, n, 0.8, 30, 4, steps, seed);
            Some(Scenario {
                name: "flash-crowd",
                seed,
                steps,
                specs,
                faults: Vec::new(),
                shards: None,
            })
        }
        // A full week of diurnal+weekly seasonality with a strongly
        // correlated demand spike at the weekly peak.
        "black-friday" => {
            let steps = 7 * 24;
            let specs = black_friday_specs(cfg, n, 0.9, steps, seed);
            Some(Scenario {
                name: "black-friday",
                seed,
                steps,
                specs,
                faults: Vec::new(),
                shards: None,
            })
        }
        // Pareto-sized tenants: most tiny, a few huge — the packing
        // regime — with dataset shares proportional to size feeding the
        // shard-affinity model.
        "heavy-tail" => {
            let steps = TraceBuilder::paper(cfg).len();
            let sizes = pareto_sizes(n, 1.3, 0.05, 1.0, seed ^ 0x517E5);
            let specs = heavy_tail_specs(cfg, &sizes, seed);
            let gbs: Vec<f64> = sizes.iter().map(|s| s * 20.0).collect();
            let shards = ShardModel::generate(&gbs, 6, 4, seed ^ 0x5BA2D);
            Some(Scenario {
                name: "heavy-tail",
                seed,
                steps,
                specs,
                faults: Vec::new(),
                shards: Some(shards),
            })
        }
        // One availability zone dies at peak load: every tenant whose
        // nodes map to the zone loses them at the same instant.
        "zone-outage" => {
            let steps = TraceBuilder::paper(cfg).len();
            let specs = paper_shifted_specs(cfg, n);
            let zones = ZoneMap::new(3, seed ^ 0x20ED);
            let faults = zones.zone_outage(n, 4, 0, 25);
            Some(Scenario { name: "zone-outage", seed, steps, specs, faults, shards: None })
        }
        // A correlated failure storm: a seeded subset of the fleet each
        // loses a node inside a short window.
        "failure-storm" => {
            let steps = TraceBuilder::paper(cfg).len();
            let specs = paper_shifted_specs(cfg, n);
            let faults = failure_storm(n, 0.5, 20, 6, seed ^ 0xF0A3);
            Some(Scenario { name: "failure-storm", seed, steps, specs, faults, shards: None })
        }
        // Maintenance sweep: one node per tenant, staggered — the
        // rolling-restart shape operators actually schedule.
        "rolling-restart" => {
            let specs = paper_shifted_specs(cfg, n);
            let faults = rolling_restart(n, 10, 2);
            let steps = TraceBuilder::paper(cfg).len().max(10 + 2 * n + 5);
            Some(Scenario { name: "rolling-restart", seed, steps, specs, faults, shards: None })
        }
        _ => None,
    }
}

/// The pre-scenario default fleet shape (phase-shifted paper traces,
/// classes cycling Gold/Silver/Bronze) — the baseline the fault
/// presets overlay their schedules on.
pub fn paper_shifted_specs(cfg: &ModelConfig, n: usize) -> Vec<TenantSpec> {
    assert!(n > 0, "fleet needs at least one tenant");
    let base = TraceBuilder::paper(cfg);
    (0..n)
        .map(|i| {
            TenantSpec::from_config(
                cfg,
                format!("t{i}"),
                class_for(i),
                base.shifted(i * base.len() / n),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_materializes() {
        let cfg = ModelConfig::default_paper();
        for name in PRESETS {
            let sc = preset(name, &cfg, 8, DEFAULT_SEED)
                .unwrap_or_else(|| panic!("preset {name} missing"));
            assert_eq!(sc.name, *name);
            assert_eq!(sc.specs.len(), 8);
            assert!(sc.steps > 0);
            for s in &sc.specs {
                assert!(!s.trace.is_empty(), "{} has an empty trace", s.name);
            }
        }
        assert!(preset("no-such-scenario", &cfg, 8, DEFAULT_SEED).is_none());
    }

    #[test]
    fn fault_presets_schedule_inside_the_run() {
        let cfg = ModelConfig::default_paper();
        for name in ["zone-outage", "failure-storm", "rolling-restart"] {
            let sc = preset(name, &cfg, 8, DEFAULT_SEED).unwrap();
            assert!(!sc.faults.is_empty(), "{name} scheduled no faults");
            for f in &sc.faults {
                assert!(f.tenant < 8);
                assert!(f.at_tick < sc.steps, "{name} fault after the run ends");
            }
        }
    }

    #[test]
    fn presets_are_deterministic_in_the_seed() {
        let cfg = ModelConfig::default_paper();
        for name in PRESETS {
            let a = preset(name, &cfg, 6, 7).unwrap();
            let b = preset(name, &cfg, 6, 7).unwrap();
            assert_eq!(a.specs, b.specs, "{name} specs drifted");
            assert_eq!(a.faults, b.faults, "{name} faults drifted");
        }
    }
}
