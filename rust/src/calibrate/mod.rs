//! Online surface calibration (paper §VIII, fourth extension): fit the
//! analytical surface constants from observations of a *real* system —
//! here, the Phase-2 cluster substrate — "while retaining the
//! interpretability of the Scaling Plane model".
//!
//! Identifiability note: cloud tier ladders are near-proportional
//! (doubling cpu doubles ram/bw/iops), which makes the four per-resource
//! coefficients of `L_node = a/cpu + b/ram + c/bw + d/iops_k` mutually
//! collinear — they cannot be separated from observations of such a
//! ladder. The latency fit therefore estimates a single *node scale*
//! `s` against the prior shape (`a..d` all scale by `s`), plus the
//! coordination terms:
//!
//! `L = s * L_node_prior(V) + eta * ln H + mu * H^theta`
//!
//! is linear in `(s, eta, mu)` once `theta` is fixed, so we grid-search
//! `theta` and solve ordinary least squares at each step. Throughput:
//! `T = H kappa m / (1 + omega ln H)` rearranges to
//! `H m / T = 1/kappa + (omega/kappa) ln H` — linear in `ln H`.

mod lstsq;

pub use lstsq::{rmse, solve_normal_equations};

use crate::config::SurfaceConfig;
use crate::plane::{Configuration, ScalingPlane};

/// One observation from a running system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub config: Configuration,
    /// Measured mean latency.
    pub latency: f64,
    /// Measured saturation throughput (ops per unit time).
    pub throughput: f64,
}

/// Calibrated latency constants plus fit quality.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyFit {
    /// Multiplier on the prior node-latency coefficients (a..d).
    pub node_scale: f64,
    pub eta: f64,
    pub mu: f64,
    pub theta: f64,
    /// Root-mean-square residual of the fit.
    pub rmse: f64,
}

/// Calibrated throughput constants plus fit quality.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThroughputFit {
    pub kappa: f64,
    pub omega: f64,
    pub rmse: f64,
}

/// Accumulates observations and produces fits against a prior model.
#[derive(Debug, Clone)]
pub struct Calibrator {
    prior: SurfaceConfig,
    /// (l_node_prior, h, hm) per observation.
    features: Vec<(f64, f64, f64)>,
    raw: Vec<Observation>,
}

impl Calibrator {
    pub fn new(prior: SurfaceConfig) -> Self {
        Self { prior, features: Vec::new(), raw: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.raw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    pub fn observe(&mut self, plane: &ScalingPlane, obs: Observation) {
        let t = plane.tier(&obs.config);
        let h = plane.h_value(&obs.config) as f64;
        let p = &self.prior;
        let l_node_prior = p.a as f64 / t.cpu as f64
            + p.b as f64 / t.ram as f64
            + p.c as f64 / t.bandwidth as f64
            + p.d as f64 / t.iops_k() as f64;
        self.features
            .push((l_node_prior, h, h * t.min_resource() as f64));
        self.raw.push(obs);
    }

    /// Fit the latency surface; requires at least 3 observations.
    pub fn fit_latency(&self) -> Option<LatencyFit> {
        if self.raw.len() < 3 {
            return None;
        }
        let ys: Vec<f64> = self.raw.iter().map(|o| o.latency).collect();
        let mut best: Option<LatencyFit> = None;
        // theta grid: the paper's power exponent is near 1.
        for ti in 0..41 {
            let theta = 0.8 + 0.02 * ti as f64;
            let rows: Vec<[f64; 3]> = self
                .features
                .iter()
                .map(|&(ln, h, _)| [ln, h.ln(), (theta * h.ln()).exp()])
                .collect();
            let Some(x) = solve_normal_equations(&rows, &ys) else {
                continue;
            };
            let fit = LatencyFit {
                node_scale: x[0],
                eta: x[1],
                mu: x[2],
                theta,
                rmse: rmse(&rows, &ys, &x),
            };
            if best.as_ref().map_or(true, |b| fit.rmse < b.rmse) {
                best = Some(fit);
            }
        }
        best
    }

    /// Fit the throughput surface; requires at least 2 observations.
    pub fn fit_throughput(&self) -> Option<ThroughputFit> {
        if self.raw.len() < 2 {
            return None;
        }
        // y = Hm/T = 1/kappa + (omega/kappa) ln H
        let rows: Vec<[f64; 2]> = self
            .features
            .iter()
            .map(|&(_, h, _)| [1.0, h.ln()])
            .collect();
        let ys: Vec<f64> = self
            .features
            .iter()
            .zip(&self.raw)
            .map(|(&(_, _, hm), o)| hm / o.throughput.max(1e-12))
            .collect();
        let x = solve_normal_equations(&rows, &ys)?;
        if x[0].abs() < 1e-12 {
            return None;
        }
        let kappa = 1.0 / x[0];
        let omega = x[1] * kappa;
        Some(ThroughputFit { kappa, omega, rmse: rmse(&rows, &ys, &x) })
    }

    /// Produce a [`SurfaceConfig`] with fitted values replacing the
    /// analytical priors (unfitted fields keep the prior).
    pub fn calibrated_config(&self) -> SurfaceConfig {
        let mut out = self.prior;
        if let Some(l) = self.fit_latency() {
            out.a = (self.prior.a as f64 * l.node_scale) as f32;
            out.b = (self.prior.b as f64 * l.node_scale) as f32;
            out.c = (self.prior.c as f64 * l.node_scale) as f32;
            out.d = (self.prior.d as f64 * l.node_scale) as f32;
            out.eta = l.eta as f32;
            out.mu = l.mu as f32;
            out.theta = l.theta as f32;
        }
        if let Some(t) = self.fit_throughput() {
            out.kappa = t.kappa as f32;
            out.omega = t.omega as f32;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::surfaces::SurfaceModel;

    /// Feed the calibrator samples from the *analytical* model and check
    /// it recovers the constants (self-consistency).
    #[test]
    fn recovers_analytical_constants() {
        let cfg = ModelConfig::default_paper();
        let model = SurfaceModel::from_config(&cfg);
        let plane = cfg.plane();
        let mut cal = Calibrator::new(cfg.surfaces);
        for c in plane.iter() {
            cal.observe(
                &plane,
                Observation {
                    config: c,
                    latency: model.latency(&c) as f64,
                    throughput: model.throughput(&c) as f64,
                },
            );
        }
        let lat = cal.fit_latency().unwrap();
        // f32 model evaluation + theta grid resolution bound the fit
        assert!(lat.rmse < 0.01, "rmse={}", lat.rmse);
        assert!((lat.node_scale - 1.0).abs() < 0.02, "scale={}", lat.node_scale);
        assert!((lat.eta - 1.0).abs() < 0.1, "eta={}", lat.eta);
        let thr = cal.fit_throughput().unwrap();
        assert!((thr.kappa - 585.0).abs() / 585.0 < 0.02, "kappa={}", thr.kappa);
        assert!((thr.omega - 0.25).abs() < 0.02, "omega={}", thr.omega);
    }

    #[test]
    fn too_few_observations_returns_none() {
        let cfg = ModelConfig::default_paper();
        let plane = cfg.plane();
        let mut cal = Calibrator::new(cfg.surfaces);
        assert!(cal.fit_latency().is_none());
        assert!(cal.fit_throughput().is_none());
        cal.observe(
            &plane,
            Observation { config: Configuration::new(0, 0), latency: 1.0, throughput: 100.0 },
        );
        assert!(cal.fit_latency().is_none());
    }

    #[test]
    fn calibrated_config_replaces_fitted_fields() {
        let cfg = ModelConfig::default_paper();
        let model = SurfaceModel::from_config(&cfg);
        let plane = cfg.plane();
        let mut cal = Calibrator::new(cfg.surfaces);
        for c in plane.iter() {
            cal.observe(
                &plane,
                Observation {
                    config: c,
                    // a system whose node-local path is 2x slower than
                    // the prior believes, same coordination behaviour
                    latency: (2.0 * model.node_latency(plane.tier(&c))
                        + model.coord_latency(plane.h_value(&c)))
                        as f64,
                    throughput: model.throughput(&c) as f64,
                },
            );
        }
        let out = cal.calibrated_config();
        assert!(
            (out.a - 2.0 * cfg.surfaces.a).abs() / cfg.surfaces.a < 0.1,
            "a={} prior={}",
            out.a,
            cfg.surfaces.a
        );
        assert!((out.d - 2.0 * cfg.surfaces.d).abs() / cfg.surfaces.d < 0.1);
        // untouched fields keep priors
        assert_eq!(out.alpha, cfg.surfaces.alpha);
        assert_eq!(out.u_max, cfg.surfaces.u_max);
    }

    #[test]
    fn noisy_observations_still_fit_reasonably() {
        let cfg = ModelConfig::default_paper();
        let model = SurfaceModel::from_config(&cfg);
        let plane = cfg.plane();
        let mut cal = Calibrator::new(cfg.surfaces);
        let mut rng = crate::workload::XorShift64::new(5);
        for _ in 0..4 {
            for c in plane.iter() {
                let noise = 1.0 + 0.05 * (rng.next_f64() - 0.5);
                cal.observe(
                    &plane,
                    Observation {
                        config: c,
                        latency: model.latency(&c) as f64 * noise,
                        throughput: model.throughput(&c) as f64 * noise,
                    },
                );
            }
        }
        let lat = cal.fit_latency().unwrap();
        assert!(lat.rmse < 0.2, "rmse={}", lat.rmse);
        assert!((lat.node_scale - 1.0).abs() < 0.2);
        let thr = cal.fit_throughput().unwrap();
        assert!((thr.kappa - 585.0).abs() / 585.0 < 0.1);
    }
}
