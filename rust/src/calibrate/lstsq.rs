//! Minimal ordinary-least-squares solver: form the normal equations
//! `(XᵀX) β = Xᵀy` and solve by Gaussian elimination with partial
//! pivoting. N is tiny here (≤ 6), so numerics are unproblematic.

/// Solve `min ||X β - y||²`. Returns `None` when the normal matrix is
/// singular (under-determined system).
pub fn solve_normal_equations<const N: usize>(
    rows: &[[f64; N]],
    ys: &[f64],
) -> Option<[f64; N]> {
    assert_eq!(rows.len(), ys.len());
    if rows.len() < N {
        return None;
    }
    // Normal matrix and RHS.
    let mut ata = [[0.0f64; N]; N];
    let mut aty = [0.0f64; N];
    for (r, &y) in rows.iter().zip(ys) {
        for i in 0..N {
            aty[i] += r[i] * y;
            for j in 0..N {
                ata[i][j] += r[i] * r[j];
            }
        }
    }
    gauss_solve(&mut ata, &mut aty)
}

/// In-place Gaussian elimination with partial pivoting.
fn gauss_solve<const N: usize>(a: &mut [[f64; N]; N], b: &mut [f64; N]) -> Option<[f64; N]> {
    for col in 0..N {
        // pivot
        let pivot = (col..N).max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))?;
        if a[pivot][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        // eliminate
        for row in col + 1..N {
            let f = a[row][col] / a[col][col];
            for k in col..N {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    // back substitution
    let mut x = [0.0f64; N];
    for col in (0..N).rev() {
        let mut s = b[col];
        for k in col + 1..N {
            s -= a[col][k] * x[k];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

/// Root-mean-square residual of a fit.
pub fn rmse<const N: usize>(rows: &[[f64; N]], ys: &[f64], x: &[f64; N]) -> f64 {
    if ys.is_empty() {
        return 0.0;
    }
    let mut s = 0.0;
    for (r, &y) in rows.iter().zip(ys) {
        let pred: f64 = r.iter().zip(x).map(|(a, b)| a * b).sum();
        s += (pred - y) * (pred - y);
    }
    (s / ys.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_fit() {
        // y = 3 + 2x
        let rows: Vec<[f64; 2]> = (0..10).map(|i| [1.0, i as f64]).collect();
        let ys: Vec<f64> = (0..10).map(|i| 3.0 + 2.0 * i as f64).collect();
        let x = solve_normal_equations(&rows, &ys).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-9);
        assert!((x[1] - 2.0).abs() < 1e-9);
        assert!(rmse(&rows, &ys, &x) < 1e-9);
    }

    #[test]
    fn overdetermined_noisy_fit() {
        let rows: Vec<[f64; 2]> = (0..100).map(|i| [1.0, i as f64]).collect();
        let ys: Vec<f64> = (0..100)
            .map(|i| 1.0 + 0.5 * i as f64 + if i % 2 == 0 { 0.1 } else { -0.1 })
            .collect();
        let x = solve_normal_equations(&rows, &ys).unwrap();
        assert!((x[0] - 1.0).abs() < 0.1);
        assert!((x[1] - 0.5).abs() < 0.01);
    }

    #[test]
    fn singular_matrix_is_none() {
        // duplicate column -> singular
        let rows: Vec<[f64; 2]> = (0..10).map(|i| [i as f64, i as f64]).collect();
        let ys: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert!(solve_normal_equations(&rows, &ys).is_none());
    }

    #[test]
    fn underdetermined_is_none() {
        let rows: Vec<[f64; 3]> = vec![[1.0, 2.0, 3.0]];
        let ys = vec![1.0];
        assert!(solve_normal_equations(&rows, &ys).is_none());
    }

    #[test]
    fn three_variable_exact() {
        // y = 1*x0 - 2*x1 + 0.5*x2 over a non-degenerate design
        let rows: Vec<[f64; 3]> = (0..20)
            .map(|i| {
                let t = i as f64;
                [1.0, t, t * t]
            })
            .collect();
        let truth = [1.0, -2.0, 0.5];
        let ys: Vec<f64> = rows
            .iter()
            .map(|r| r.iter().zip(&truth).map(|(a, b)| a * b).sum())
            .collect();
        let x = solve_normal_equations(&rows, &ys).unwrap();
        for (got, want) in x.iter().zip(&truth) {
            assert!((got - want).abs() < 1e-6);
        }
    }
}
