//! Event-driven DES core: the binary-heap event calendar and the
//! [`EventSim`] engine that replaces [`super::ClusterSim`]'s per-op
//! Monte-Carlo sampling loop on the hot path.
//!
//! The physics is identical to the sampling engine — c-server queueing
//! nodes behind a consistent-hash ring, quorum writes, round-robin
//! reads, timeout-based shedding — but the mechanics differ where the
//! sampling loop burns time:
//!
//! * **Calendar, not recomputation.** Rebalance-end, restart-end, and
//!   compaction-start/end transitions are *scheduled events* popped
//!   from a binary heap as simulated time passes, instead of per-step
//!   window arithmetic and per-node compaction-phase recomputation.
//!   Transitions take effect mid-interval at their exact event time.
//! * **Allocation-free hot path.** Shard→replica sets are precomputed
//!   into a flat table at reconfiguration time (the per-op consistent-
//!   hash lookup disappears), and quorum selection runs over a reusable
//!   scratch buffer — the sampling engine allocates three `Vec`s per
//!   sampled op.
//! * **No thinning.** Every arrival is simulated;
//!   [`ClusterParams::max_ops_per_step`] is a sampling-engine knob.
//!   At equal offered load the two engines consume the RNG in the same
//!   order, so below the sampling cap (and with compaction disabled)
//!   their measurements coincide; the `prop_cluster` suite pins the
//!   parity.
//!
//! Per-seed determinism holds: same seed + same inputs → identical
//! event order (heap ties break on schedule order) and identical
//! measurements.

use std::collections::BinaryHeap;

use crate::config::ModelConfig;
use crate::metrics::LatencyHistogram;
use crate::plane::{Configuration, ScalingPlane};
use crate::workload::{WorkloadPoint, XorShift64};

use super::rebalance;
use super::ring::HashRing;
use super::{
    ClusterParams, ClusterStepMetrics, Node, RebalancePlan, Substrate, SubstrateStatus,
};

/// A discrete event on the cluster calendar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A shard-movement window closes: full capacity restored.
    RebalanceEnd,
    /// A rolling-restart window closes: full capacity restored.
    RestartEnd,
    /// A cross-tenant migration window closes (scheduled by the
    /// placement layer on shared-cluster calendars —
    /// [`crate::placement::SharedCluster`]; [`EventSim`] never emits
    /// it and treats a stray one as a plain window close).
    MigrationEnd,
    /// A serverless cold-start window closes: tenant `tenant`'s pages
    /// are read back from the storage tier and it serves again
    /// (scheduled by the fleet layer on its own calendar —
    /// [`crate::fleet::FleetSimulator`] with
    /// [`crate::serverless`] enabled; [`EventSim`] never emits it and
    /// treats a stray one as a plain window close).
    ResumeEnd { tenant: usize },
    /// `node` fails at the scheduled time and serves nothing until the
    /// next reconfiguration (calendar-injected failure — see
    /// [`super::Substrate::schedule_failure`]).
    NodeFail { node: usize },
    /// `node` enters its periodic background-compaction window.
    CompactionStart { node: usize },
    /// `node` leaves its compaction window (and the next one is
    /// scheduled one period later).
    CompactionEnd { node: usize },
}

#[derive(Debug, Clone, Copy)]
struct Scheduled {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    // Reversed so std's max-heap pops the earliest entry first; the
    // seq tie-break keeps same-time events in schedule order, which
    // makes runs reproducible per seed.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Binary-heap event calendar: earliest (time, schedule-order) first.
#[derive(Debug, Clone, Default)]
pub struct EventCalendar {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventCalendar {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn schedule(&mut self, time: f64, event: Event) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { time, seq, event });
    }

    /// Earliest pending event time, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.time)
    }

    /// Pop the earliest event if it is due at or before `t`.
    pub fn pop_due(&mut self, t: f64) -> Option<(f64, Event)> {
        if self.heap.peek().map_or(false, |s| s.time <= t) {
            self.heap.pop().map(|s| (s.time, s.event))
        } else {
            None
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

/// The event-driven cluster engine. Public surface mirrors
/// [`super::ClusterSim`]; both implement [`Substrate`].
pub struct EventSim {
    plane: ScalingPlane,
    kappa: f32,
    write_ratio: f64,
    params: ClusterParams,
    current: Configuration,
    nodes: Vec<Node>,
    time: f64,
    rng: XorShift64,
    rr: usize,
    /// Cumulative zipf CDF over shards (empty when access is uniform).
    zipf_cdf: Vec<f64>,
    calendar: EventCalendar,
    /// Rebalance/restart capacity multiplier (1.0 = healthy); the
    /// window closes when its end event fires. A later `apply` replaces
    /// the window outright (rebuild clears the calendar), matching the
    /// sampling engine's `degraded_until` overwrite.
    window_deg: f64,
    /// Per-node compaction multiplier (1.0 = not compacting).
    compaction_deg: Vec<f64>,
    /// Flat shard→replica table (`shards * repl` node ids, primary
    /// first), rebuilt on reconfiguration: the hot path never touches
    /// the hash ring.
    replica_table: Vec<u32>,
    /// Effective replication factor (capped by cluster size).
    repl: usize,
    /// Scratch buffer of completion delays for the op in flight, kept
    /// sorted by insertion — never reallocated between ops.
    scratch: Vec<f64>,
    /// `shards - 1` when the shard count is a power of two: uniform
    /// sampling then uses a mask instead of a modulo (same value the
    /// sampling engine's `below()` computes, minus the division).
    shard_mask: Option<u64>,
    /// Any node failed since the last reconfiguration; false keeps the
    /// hot path on the no-liveness-check fast lane.
    any_down: bool,
    /// Cached earliest calendar entry (`+inf` when empty), so the
    /// per-arrival due-event check is one float compare.
    next_event: f64,
    /// Conservation counters (offered = completed + dropped).
    pub total_offered: f64,
    pub total_completed: f64,
    pub total_dropped: f64,
}

impl EventSim {
    pub fn new(cfg: &ModelConfig, params: ClusterParams, seed: u64) -> Self {
        let plane = cfg.plane();
        let start = Configuration::new(cfg.policy.start[0], cfg.policy.start[1]);
        let mut sim = Self {
            plane,
            kappa: cfg.surfaces.kappa,
            write_ratio: cfg.write_ratio() as f64,
            params,
            current: start,
            nodes: Vec::new(),
            time: 0.0,
            rng: XorShift64::new(seed),
            rr: 0,
            zipf_cdf: Vec::new(),
            calendar: EventCalendar::new(),
            window_deg: 1.0,
            compaction_deg: Vec::new(),
            replica_table: Vec::new(),
            repl: 1,
            scratch: Vec::new(),
            shard_mask: params
                .shards
                .is_power_of_two()
                .then_some(params.shards as u64 - 1),
            any_down: false,
            next_event: f64::INFINITY,
            total_offered: 0.0,
            total_completed: 0.0,
            total_dropped: 0.0,
        };
        sim.zipf_cdf = super::zipf_shard_cdf(sim.params.shards, sim.params.zipf_s);
        sim.rebuild();
        sim
    }

    /// Replace the node fleet for the current configuration, precompute
    /// the shard→replica table, and re-seed the compaction schedule.
    fn rebuild(&mut self) {
        let h = self.plane.h_value(&self.current) as usize;
        let tier = self.plane.tier(&self.current).clone();
        self.nodes = (0..h).map(|_| Node::new(&tier, self.kappa)).collect();
        self.repl = self.params.replication.min(h).max(1);
        let ring = HashRing::new(h);
        self.replica_table.clear();
        self.replica_table.reserve(self.params.shards * self.repl);
        for s in 0..self.params.shards as u64 {
            for r in ring.replicas(s, self.repl) {
                self.replica_table.push(r as u32);
            }
        }
        self.scratch = Vec::with_capacity(self.repl);
        self.any_down = false;
        // a reconfiguration replaces the fleet: stale window/compaction
        // events would reference the old node set, so reset the
        // calendar and re-seed (apply() schedules its window after)
        self.calendar.clear();
        self.window_deg = 1.0;
        self.compaction_deg = vec![1.0; h];
        self.seed_compaction();
        self.refresh_degradations();
        self.next_event = self.calendar.peek_time().unwrap_or(f64::INFINITY);
    }

    /// Schedule each node's next compaction transition from the same
    /// staggered phase the sampling engine derives per step.
    fn seed_compaction(&mut self) {
        let period = self.params.compaction_period;
        if period <= 0.0 {
            return;
        }
        let n = self.nodes.len().max(1) as f64;
        for i in 0..self.nodes.len() {
            let phase = (self.time + i as f64 * period / n) % period;
            if phase < self.params.compaction_duration {
                self.compaction_deg[i] = self.params.compaction_degradation;
                self.calendar.schedule(
                    self.time + self.params.compaction_duration - phase,
                    Event::CompactionEnd { node: i },
                );
            } else {
                self.calendar
                    .schedule(self.time + period - phase, Event::CompactionStart { node: i });
            }
        }
    }

    fn refresh_degradations(&mut self) {
        for (i, n) in self.nodes.iter_mut().enumerate() {
            n.set_degradation(self.window_deg * self.compaction_deg[i]);
        }
    }

    /// Fire one calendar event at its scheduled time.
    fn fire(&mut self, at: f64, ev: Event) {
        match ev {
            Event::RebalanceEnd
            | Event::RestartEnd
            | Event::MigrationEnd
            | Event::ResumeEnd { .. } => {
                // a popped end always belongs to the open window:
                // rebuild() clears the calendar on every apply(), so
                // stale end-events from superseded windows cannot exist
                self.window_deg = 1.0;
            }
            Event::NodeFail { node } => {
                if node < self.nodes.len() {
                    self.nodes[node].up = false;
                    self.any_down = true;
                }
            }
            Event::CompactionStart { node } => {
                if node < self.compaction_deg.len() {
                    self.compaction_deg[node] = self.params.compaction_degradation;
                    self.calendar.schedule(
                        at + self.params.compaction_duration,
                        Event::CompactionEnd { node },
                    );
                }
            }
            Event::CompactionEnd { node } => {
                if node < self.compaction_deg.len() {
                    self.compaction_deg[node] = 1.0;
                    let gap = (self.params.compaction_period
                        - self.params.compaction_duration)
                        .max(0.0);
                    self.calendar.schedule(at + gap, Event::CompactionStart { node });
                }
            }
        }
        self.refresh_degradations();
    }

    /// Drain every calendar entry due at or before `t`, then refresh
    /// the cached next-event time.
    fn drain_due(&mut self, t: f64) {
        while let Some((te, ev)) = self.calendar.pop_due(t) {
            self.fire(te, ev);
        }
        self.next_event = self.calendar.peek_time().unwrap_or(f64::INFINITY);
    }

    /// Sample a shard id: uniform, or zipfian when `zipf_s > 0` (same
    /// RNG consumption and values as the sampling engine — the mask is
    /// exactly `below()`'s modulo for power-of-two shard counts).
    #[inline]
    fn sample_shard(&mut self) -> usize {
        if self.zipf_cdf.is_empty() {
            if let Some(mask) = self.shard_mask {
                (self.rng.next_u64() & mask) as usize
            } else {
                self.rng.below(self.params.shards as u64) as usize
            }
        } else {
            let u = self.rng.next_f64();
            self.zipf_cdf.partition_point(|&c| c < u)
        }
    }

    pub fn current(&self) -> Configuration {
        self.current
    }

    pub fn params(&self) -> &ClusterParams {
        &self.params
    }

    pub fn time(&self) -> f64 {
        self.time
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Pending calendar entries (diagnostics / tests).
    pub fn pending_events(&self) -> usize {
        self.calendar.len()
    }

    /// Aggregate healthy capacity (ops per unit time).
    pub fn capacity(&self) -> f64 {
        self.nodes.iter().map(|n| n.capacity()).sum::<f64>() * self.window_deg
    }

    /// Reconfigure the cluster; physical transition costs match the
    /// sampling engine exactly (shared [`rebalance::plan_reconfiguration`]),
    /// but the window *closes* at its event time mid-interval instead
    /// of at the next step boundary.
    ///
    /// Queueing backlog carries across the reconfiguration: surviving
    /// node slots (index < min(old H, new H)) inherit their servers'
    /// remaining busy time, so work queued before a resize still
    /// delays ops after it instead of vanishing with the node rebuild
    /// (the ROADMAP DES open item). Nodes that disappear shed their
    /// queues with their shards — the rebalance window prices that
    /// disruption. The legacy sampling engine keeps its wipe-on-apply
    /// behaviour; the cross-engine parity suite only pins trajectories
    /// and utilization, both backlog-independent.
    pub fn apply(&mut self, next: Configuration) -> RebalancePlan {
        assert!(self.plane.contains(&next), "config out of plane");
        if next == self.current {
            return RebalancePlan::none();
        }
        let plan =
            rebalance::plan_reconfiguration(&self.plane, &self.current, &next, &self.params);
        let carried: Vec<Vec<f64>> =
            self.nodes.iter().map(|n| n.server_backlog(self.time)).collect();
        self.current = next;
        self.rebuild();
        for (i, node) in self.nodes.iter_mut().enumerate() {
            if let Some(backlog) = carried.get(i) {
                node.inherit_backlog(backlog, self.time);
            }
        }
        if plan.duration > 0.0 {
            self.window_deg = plan.degradation;
            let end = if plan.moved_shards > 0 {
                Event::RebalanceEnd
            } else {
                Event::RestartEnd
            };
            self.calendar.schedule(self.time + plan.duration, end);
            self.refresh_degradations();
            self.next_event = self.calendar.peek_time().unwrap_or(f64::INFINITY);
        }
        plan
    }

    /// Inject a node failure: node `idx` serves nothing until the next
    /// reconfiguration (failure-injection tests).
    pub fn fail_node(&mut self, idx: usize) {
        if let Some(n) = self.nodes.get_mut(idx) {
            n.up = false;
            self.any_down = true;
        }
    }

    /// Schedule a node failure on the calendar: `node` goes down at
    /// simulated time `at` — mid-interval, at its exact event time,
    /// like every other calendar transition. A reconfiguration before
    /// `at` clears the calendar, superseding the failure along with
    /// the node set it referenced.
    pub fn schedule_node_failure(&mut self, at: f64, node: usize) {
        self.calendar.schedule(at, Event::NodeFail { node });
        self.next_event = self.calendar.peek_time().unwrap_or(f64::INFINITY);
    }

    /// Simulate one workload interval, firing due calendar events at
    /// their exact times between arrivals.
    pub fn step(&mut self, w: WorkloadPoint) -> ClusterStepMetrics {
        let interval = self.params.interval;
        let t0 = self.time;
        let t1 = t0 + interval;
        let offered = w.lambda_req as f64 * interval;
        let degraded = self.window_deg < 1.0;

        // every arrival is simulated — `scale` only absorbs the
        // rounding of a fractional offered count onto whole ops
        let n_ops = (offered.round() as usize).max(1);
        let scale = offered / n_ops as f64;

        let mut hist = LatencyHistogram::new(1e-5);
        let mut dropped = 0usize;
        let timeout = self.params.sla_latency * 10.0;
        let quorum = self.repl / 2 + 1;
        let h = self.nodes.len();
        let write_net = self.params.net_latency
            + self.params.write_coord_overhead * ((h as f64).ln() + 1.0);

        for i in 0..n_ops {
            let t = t0 + interval * (i as f64 + self.rng.next_f64()) / n_ops as f64;
            if self.next_event <= t {
                self.drain_due(t);
            }
            let base = self.sample_shard() * self.repl;
            let is_write = self.rng.next_f64() < self.write_ratio;
            let lat = if is_write {
                // quorum write: wait for the majority of replica acks
                self.scratch.clear();
                if !self.any_down {
                    for k in 0..self.repl {
                        let r = self.replica_table[base + k] as usize;
                        let delay = self.nodes[r].serve_delay(t, &mut self.rng);
                        let pos = self.scratch.partition_point(|&x| x <= delay);
                        self.scratch.insert(pos, delay);
                    }
                } else {
                    for k in 0..self.repl {
                        let r = self.replica_table[base + k] as usize;
                        if self.nodes[r].up {
                            let delay = self.nodes[r].serve_delay(t, &mut self.rng);
                            let pos = self.scratch.partition_point(|&x| x <= delay);
                            self.scratch.insert(pos, delay);
                        }
                    }
                }
                if self.scratch.is_empty() {
                    dropped += 1;
                    continue;
                }
                let q = quorum.min(self.scratch.len());
                write_net + self.scratch[q - 1]
            } else {
                // read: round-robin over live replicas
                let node = if !self.any_down {
                    self.rr = self.rr.wrapping_add(1);
                    // constant-divisor modulo for the common factors
                    let pick = match self.repl {
                        1 => 0,
                        2 => self.rr & 1,
                        3 => self.rr % 3,
                        r => self.rr % r,
                    };
                    self.replica_table[base + pick] as usize
                } else {
                    let mut live = 0usize;
                    for k in 0..self.repl {
                        if self.nodes[self.replica_table[base + k] as usize].up {
                            live += 1;
                        }
                    }
                    if live == 0 {
                        dropped += 1;
                        continue;
                    }
                    self.rr = self.rr.wrapping_add(1);
                    let mut pick = self.rr % live;
                    let mut node = usize::MAX;
                    for k in 0..self.repl {
                        let r = self.replica_table[base + k] as usize;
                        if self.nodes[r].up {
                            if pick == 0 {
                                node = r;
                                break;
                            }
                            pick -= 1;
                        }
                    }
                    node
                };
                self.params.net_latency + self.nodes[node].serve_delay(t, &mut self.rng)
            };
            if lat > timeout {
                dropped += 1;
            } else {
                hist.record(lat);
            }
        }

        // fire whatever else falls inside this interval
        if self.next_event <= t1 {
            self.drain_due(t1);
        }

        self.time = t1;
        let completed = hist.len() as f64 * scale;
        let dropped_scaled = dropped as f64 * scale;
        self.total_offered += offered;
        self.total_completed += completed;
        self.total_dropped += dropped_scaled;

        let cap = self.capacity();
        ClusterStepMetrics {
            offered,
            completed,
            dropped: dropped_scaled,
            avg_latency: hist.mean(),
            p99_latency: hist.p99(),
            p999_latency: hist.p999(),
            utilization: if cap > 0.0 { offered / (cap * interval) } else { f64::INFINITY },
            degraded,
        }
    }
}

impl Substrate for EventSim {
    fn current(&self) -> Configuration {
        EventSim::current(self)
    }

    fn step(&mut self, w: WorkloadPoint) -> ClusterStepMetrics {
        EventSim::step(self, w)
    }

    fn apply(&mut self, next: Configuration) -> RebalancePlan {
        EventSim::apply(self, next)
    }

    fn observe(&self) -> SubstrateStatus {
        SubstrateStatus {
            time: self.time,
            nodes: self.nodes.len(),
            capacity: self.capacity(),
            degraded: self.window_deg < 1.0,
            total_offered: self.total_offered,
            total_completed: self.total_completed,
            total_dropped: self.total_dropped,
        }
    }

    fn params(&self) -> &ClusterParams {
        EventSim::params(self)
    }

    fn schedule_failure(&mut self, at: f64, node: usize) -> bool {
        EventSim::schedule_node_failure(self, at, node);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(seed: u64) -> EventSim {
        let cfg = ModelConfig::default_paper();
        EventSim::new(&cfg, ClusterParams::default(), seed)
    }

    fn point(lam: f32) -> WorkloadPoint {
        WorkloadPoint::new(lam, 0.3)
    }

    #[test]
    fn calendar_pops_in_time_then_schedule_order() {
        let mut cal = EventCalendar::new();
        cal.schedule(2.0, Event::RebalanceEnd);
        cal.schedule(1.0, Event::RestartEnd);
        cal.schedule(1.0, Event::CompactionStart { node: 0 });
        assert_eq!(cal.peek_time(), Some(1.0));
        assert_eq!(cal.pop_due(5.0), Some((1.0, Event::RestartEnd)));
        assert_eq!(cal.pop_due(5.0), Some((1.0, Event::CompactionStart { node: 0 })));
        // not yet due
        assert_eq!(cal.pop_due(1.5), None);
        assert_eq!(cal.pop_due(2.0), Some((2.0, Event::RebalanceEnd)));
        assert!(cal.is_empty());
    }

    #[test]
    fn starts_at_config_with_right_node_count() {
        let s = sim(1);
        assert_eq!(s.current(), Configuration::new(1, 1));
        assert_eq!(s.n_nodes(), 2);
        assert_eq!(s.pending_events(), 0); // compaction disabled
    }

    #[test]
    fn conservation_without_thinning() {
        let mut s = sim(2);
        // above the sampling engine's default cap: the event engine
        // still simulates every arrival and conserves exactly
        for _ in 0..5 {
            s.step(point(25_000.0));
        }
        let total = s.total_completed + s.total_dropped;
        assert!(
            (s.total_offered - total).abs() < 1e-6 * s.total_offered,
            "offered={} completed+dropped={}",
            s.total_offered,
            total
        );
    }

    #[test]
    fn light_load_completes_everything_quickly() {
        let mut s = sim(3);
        let m = s.step(point(500.0));
        assert_eq!(m.dropped, 0.0);
        assert!(m.avg_latency < ClusterParams::default().sla_latency);
        assert!(m.utilization < 0.3);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = sim(9);
        let mut b = sim(9);
        a.apply(Configuration::new(2, 1));
        b.apply(Configuration::new(2, 1));
        for _ in 0..5 {
            assert_eq!(a.step(point(4000.0)), b.step(point(4000.0)));
        }
    }

    #[test]
    fn rebalance_window_closes_via_event() {
        let mut s = sim(6);
        let plan = s.apply(Configuration::new(3, 1)); // H=2 -> H=8
        assert!(plan.moved_shards > 0 && plan.duration > 0.0);
        assert_eq!(s.pending_events(), 1);
        let m = s.step(point(1000.0));
        assert!(m.degraded);
        // default shard_gb keeps the window inside one interval
        assert!(plan.duration < s.params().interval);
        assert_eq!(s.pending_events(), 0);
        let m2 = s.step(point(1000.0));
        assert!(!m2.degraded);
    }

    #[test]
    fn vertical_resize_restores_capacity_after_restart_window() {
        let mut s = sim(5);
        let before = s.capacity();
        let plan = s.apply(Configuration::new(1, 3)); // medium -> xlarge
        assert_eq!(plan.moved_shards, 0);
        assert!(plan.duration > 0.0);
        for _ in 0..3 {
            s.step(point(100.0));
        }
        assert!(s.capacity() > 3.0 * before);
    }

    #[test]
    fn compaction_cycles_through_scheduled_events() {
        let cfg = ModelConfig::default_paper();
        let mut s = EventSim::new(
            &cfg,
            ClusterParams {
                compaction_period: 4.0,
                compaction_duration: 2.0,
                compaction_degradation: 0.3,
                ..ClusterParams::default()
            },
            22,
        );
        // one pending transition per node at all times
        assert_eq!(s.pending_events(), s.n_nodes());
        let lat: Vec<f64> = (0..12).map(|_| s.step(point(3800.0)).avg_latency).collect();
        assert_eq!(s.pending_events(), s.n_nodes());
        let hi = lat.iter().cloned().fold(0.0, f64::max);
        let lo = lat.iter().cloned().fold(f64::MAX, f64::min);
        assert!(hi > 2.0 * lo, "compaction cycles visible: {lat:?}");
    }

    #[test]
    fn scheduled_node_failure_fires_at_its_calendar_time() {
        let mut s = sim(11);
        let interval = s.params().interval;
        // failure scheduled mid-second-interval: the first step must
        // not see it, the step containing the event pops and fires it
        s.schedule_node_failure(1.5 * interval, 0);
        assert_eq!(s.pending_events(), 1);
        s.step(point(1000.0));
        assert_eq!(s.pending_events(), 1, "failure must not fire early");
        assert!(s.nodes[0].up);
        let m = s.step(point(1000.0));
        assert_eq!(s.pending_events(), 0);
        assert!(!s.nodes[0].up, "node must be down after its event fired");
        assert!(m.completed > 0.0, "survivor keeps serving");
    }

    #[test]
    fn node_failure_sheds_load_but_survivors_serve() {
        let mut s = sim(10);
        s.fail_node(0);
        let m = s.step(point(3000.0));
        assert!(m.completed > 0.0);
        s.fail_node(1);
        let m = s.step(point(1000.0));
        assert_eq!(m.completed, 0.0);
        assert!(m.dropped > 0.0);
    }

    #[test]
    fn zipf_skew_imbalances_node_load() {
        let cfg = ModelConfig::default_paper();
        let mut uniform = EventSim::new(&cfg, ClusterParams::default(), 20);
        let mut skewed = EventSim::new(
            &cfg,
            ClusterParams { zipf_s: 1.2, ..ClusterParams::default() },
            20,
        );
        let imbalance = |s: &mut EventSim| {
            s.apply(Configuration::new(3, 1)); // H=8, medium
            for _ in 0..20 {
                s.step(point(12_000.0));
            }
            let served: Vec<u64> = s.nodes.iter().map(|n| n.served).collect();
            let max = *served.iter().max().unwrap() as f64;
            let min = *served.iter().min().unwrap() as f64;
            max / min.max(1.0)
        };
        let iu = imbalance(&mut uniform);
        let is = imbalance(&mut skewed);
        assert!(is > 1.3 * iu, "zipf must imbalance node load: {is:.2} vs {iu:.2}");
    }

    #[test]
    fn backlog_survives_step_boundaries() {
        // pins the *pre-existing* invariant that server free-times
        // persist across plain step() boundaries (nodes are reused, no
        // rebuild) — the PR-4 change extends the same guarantee across
        // apply(), covered by the two resize tests below
        let mut s = sim(30);
        s.step(point(30_000.0));
        let m = s.step(point(200.0));
        assert!(
            m.dropped > 0.9 * m.offered,
            "carried backlog must delay step-2 ops: {m:?}"
        );
        // a fresh cluster at the same trickle sheds nothing
        let mut fresh = sim(30);
        let f = fresh.step(point(200.0));
        assert_eq!(f.dropped, 0.0);
    }

    #[test]
    fn vertical_resize_carries_queue_backlog() {
        // build a deep queue, then resize medium -> large: surviving
        // nodes must inherit their servers' remaining busy time, so the
        // first post-resize interval still sheds (before PR 4 the
        // rebuild silently wiped the queue)
        let mut s = sim(31);
        s.step(point(30_000.0));
        s.apply(Configuration::new(1, 2));
        let m = s.step(point(200.0));
        assert!(
            m.dropped > 0.9 * m.offered,
            "backlog must survive the resize: {m:?}"
        );
        // same resize without prior load serves the trickle cleanly
        let mut fresh = sim(31);
        fresh.apply(Configuration::new(1, 2));
        let f = fresh.step(point(200.0));
        assert_eq!(f.dropped, 0.0);
    }

    #[test]
    fn horizontal_shrink_keeps_surviving_nodes_backlog() {
        // H=2 -> H=1 under backlog: the surviving node keeps its queue
        let mut s = sim(32);
        s.step(point(30_000.0));
        s.apply(Configuration::new(0, 1));
        let m = s.step(point(100.0));
        assert!(m.dropped > 0.5 * m.offered, "survivor kept no backlog: {m:?}");
    }

    #[test]
    fn observe_reports_conservation_counters() {
        let mut s = sim(12);
        s.step(point(2000.0));
        let st = Substrate::observe(&s);
        assert_eq!(st.nodes, 2);
        assert!((st.total_offered - 2000.0).abs() < 1e-9);
        assert!(
            (st.total_offered - st.total_completed - st.total_dropped).abs()
                < 1e-6 * st.total_offered
        );
        assert!(st.capacity > 0.0);
        assert!(!st.degraded);
    }
}
