//! A simulated database node: a c-server FIFO queue (M/G/c-style) whose
//! service rate derives from its vertical tier. Vertical scaling raises
//! per-node capacity; queueing delay emerges naturally as utilization
//! approaches it — the behaviour the paper's §VIII queueing extension
//! models analytically.

use crate::plane::Tier;
use crate::workload::XorShift64;

/// Simulated node state.
#[derive(Debug, Clone)]
pub struct Node {
    /// Parallel servers (one per CPU core).
    servers: Vec<f64>,
    /// Mean service time per op at this tier.
    mean_service: f64,
    /// Capacity multiplier (< 1.0 while rebalancing or restarting).
    degradation: f64,
    /// Ops served (for conservation checks).
    pub served: u64,
    /// Node is up.
    pub up: bool,
}

impl Node {
    /// `kappa` is the tier->throughput scale from the surface model: a
    /// node serves `kappa * min_resource` ops per unit time across
    /// `cpu` parallel servers.
    pub fn new(tier: &Tier, kappa: f32) -> Self {
        let total_rate = (kappa * tier.min_resource()) as f64;
        let servers = (tier.cpu.round().max(1.0)) as usize;
        Self {
            servers: vec![0.0; servers],
            mean_service: servers as f64 / total_rate,
            degradation: 1.0,
            served: 0,
            up: true,
        }
    }

    /// Total service rate (ops per unit time) at full health.
    pub fn capacity(&self) -> f64 {
        self.servers.len() as f64 / self.mean_service
    }

    pub fn set_degradation(&mut self, factor: f64) {
        // lower bound only guards against division by zero: arrival
        // thinning can push the effective factor far below 1 (see
        // ClusterSim::step)
        self.degradation = factor.clamp(1e-9, 1.0);
    }

    pub fn degradation(&self) -> f64 {
        self.degradation
    }

    /// Serve an op arriving at time `t`; returns its completion time.
    /// FIFO to the earliest-free server; service time is exponential
    /// around the (possibly degraded) mean.
    #[inline]
    pub fn serve(&mut self, t: f64, rng: &mut XorShift64) -> f64 {
        debug_assert!(self.up, "serve() on a down node");
        // manual first-min scan (service times are never NaN): this is
        // the innermost loop of both substrate engines
        let mut idx = 0usize;
        let mut free_at = self.servers[0];
        for (i, &f) in self.servers.iter().enumerate().skip(1) {
            if f < free_at {
                idx = i;
                free_at = f;
            }
        }
        let start = t.max(free_at);
        let service = rng.exp(self.mean_service / self.degradation);
        let done = start + service;
        self.servers[idx] = done;
        self.served += 1;
        done
    }

    /// Serve an op and return its completion *delay* (`serve(t) - t`)
    /// — the hot-path form the event engine records directly.
    #[inline]
    pub fn serve_delay(&mut self, t: f64, rng: &mut XorShift64) -> f64 {
        self.serve(t, rng) - t
    }

    /// Earliest time any server frees up (backpressure signal).
    pub fn earliest_free(&self) -> f64 {
        self.servers.iter().copied().fold(f64::MAX, f64::min)
    }

    /// Remaining busy time per server at `t` (0 for idle servers) —
    /// the queue backlog a reconfiguration carries forward.
    pub fn server_backlog(&self, t: f64) -> Vec<f64> {
        self.servers.iter().map(|&f| (f - t).max(0.0)).collect()
    }

    /// Inherit queued-work backlog from a predecessor node's servers:
    /// remaining busy durations are assigned longest-first onto the
    /// least-loaded server (LPT), so total backlog is conserved even
    /// when the server count changes across tiers. Existing state is
    /// replaced (the node is freshly built at `t`).
    pub fn inherit_backlog(&mut self, backlog: &[f64], t: f64) {
        let mut rem: Vec<f64> = backlog.iter().copied().filter(|&b| b > 0.0).collect();
        rem.sort_by(|a, b| b.total_cmp(a));
        for f in &mut self.servers {
            *f = t;
        }
        for b in rem {
            let mut idx = 0usize;
            let mut min = self.servers[0];
            for (i, &f) in self.servers.iter().enumerate().skip(1) {
                if f < min {
                    idx = i;
                    min = f;
                }
            }
            self.servers[idx] += b;
        }
    }

    /// Queue depth proxy: servers busy past time `t`.
    pub fn busy_servers(&self, t: f64) -> usize {
        self.servers.iter().filter(|&&f| f > t).count()
    }

    /// Reset queue state for a new interval (service continuity kept).
    pub fn decay_to(&mut self, t: f64) {
        for f in &mut self.servers {
            *f = f.max(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier() -> Tier {
        Tier {
            name: "medium".into(),
            cpu: 4.0,
            ram: 8.0,
            bandwidth: 5.0,
            iops: 6000.0,
            cost: 0.2,
        }
    }

    #[test]
    fn capacity_scales_with_tier() {
        let small = Tier { cpu: 2.0, ram: 4.0, bandwidth: 2.5, iops: 3000.0, ..tier() };
        let n_small = Node::new(&small, 585.0);
        let n_med = Node::new(&tier(), 585.0);
        assert!((n_small.capacity() - 2.0 * 585.0).abs() < 1e-6);
        assert!((n_med.capacity() - 4.0 * 585.0).abs() < 1e-6);
    }

    #[test]
    fn light_load_latency_is_service_time() {
        let mut n = Node::new(&tier(), 585.0);
        let mut rng = XorShift64::new(1);
        // widely spaced arrivals: no queueing
        let mut total = 0.0;
        let k = 2000;
        for i in 0..k {
            let t = i as f64 * 10.0;
            total += n.serve(t, &mut rng) - t;
        }
        let mean = total / k as f64;
        let expect = 4.0 / (4.0 * 585.0); // cpu / total_rate
        assert!((mean - expect).abs() / expect < 0.1, "mean={mean} expect={expect}");
    }

    #[test]
    fn overload_builds_queue() {
        let mut n = Node::new(&tier(), 585.0);
        let mut rng = XorShift64::new(2);
        // arrival rate 2x capacity: completion times run away
        let cap = n.capacity();
        let dt = 1.0 / (2.0 * cap);
        let mut last_latency = 0.0;
        for i in 0..5000 {
            let t = i as f64 * dt;
            last_latency = n.serve(t, &mut rng) - t;
        }
        // queue of ~2500 ops at rate `cap`
        assert!(last_latency > 1000.0 * dt);
    }

    #[test]
    fn degradation_slows_service() {
        let mut healthy = Node::new(&tier(), 585.0);
        let mut degraded = Node::new(&tier(), 585.0);
        degraded.set_degradation(0.5);
        let mut r1 = XorShift64::new(3);
        let mut r2 = XorShift64::new(3);
        let mut h = 0.0;
        let mut d = 0.0;
        for i in 0..2000 {
            let t = i as f64 * 10.0;
            h += healthy.serve(t, &mut r1) - t;
            d += degraded.serve(t, &mut r2) - t;
        }
        assert!(d > 1.8 * h, "degraded mean {d} vs healthy {h}");
    }

    #[test]
    fn serve_delay_matches_serve() {
        let mut a = Node::new(&tier(), 585.0);
        let mut b = Node::new(&tier(), 585.0);
        let mut r1 = XorShift64::new(5);
        let mut r2 = XorShift64::new(5);
        for i in 0..100 {
            let t = i as f64 * 0.001;
            assert_eq!(a.serve(t, &mut r1) - t, b.serve_delay(t, &mut r2));
        }
    }

    #[test]
    fn served_counter_increments() {
        let mut n = Node::new(&tier(), 585.0);
        let mut rng = XorShift64::new(4);
        for i in 0..10 {
            n.serve(i as f64, &mut rng);
        }
        assert_eq!(n.served, 10);
    }
}
