//! Rebalance planning: when the coordinator changes the node count, the
//! consistent-hash ring changes and shards must move. Data movement
//! takes time proportional to moved bytes over aggregate bandwidth and
//! degrades donor/recipient nodes while in flight — the physical cost
//! behind the paper's rebalance penalty `R` (§IV.D) and the reason H
//! moves are penalized twice as much as V moves.

use super::ring::HashRing;

/// A planned rebalance operation.
#[derive(Debug, Clone, PartialEq)]
pub struct RebalancePlan {
    /// Shards whose primary changed (must move data).
    pub moved_shards: usize,
    /// Total shards.
    pub total_shards: usize,
    /// Wall-clock duration of the movement (synthetic time units).
    pub duration: f64,
    /// Capacity multiplier applied to every node while moving.
    pub degradation: f64,
}

impl RebalancePlan {
    pub fn none() -> Self {
        Self { moved_shards: 0, total_shards: 0, duration: 0.0, degradation: 1.0 }
    }

    pub fn is_noop(&self) -> bool {
        self.moved_shards == 0
    }
}

/// Plan the movement implied by changing the ring from `old_nodes` to
/// `new_nodes` physical nodes.
///
/// * `shard_gb` — data per shard;
/// * `agg_bandwidth_gbps` — cluster aggregate network bandwidth available
///   for movement (a fraction of the new tier's bandwidth);
/// * `degradation` — service-capacity multiplier while moving.
pub fn plan_h_change(
    old_nodes: usize,
    new_nodes: usize,
    total_shards: usize,
    shard_gb: f64,
    agg_bandwidth_gbps: f64,
    degradation: f64,
) -> RebalancePlan {
    if old_nodes == new_nodes {
        return RebalancePlan::none();
    }
    let old = HashRing::new(old_nodes);
    let new = HashRing::new(new_nodes);
    let moved = (0..total_shards as u64)
        .filter(|&s| old.primary(s) != new.primary(s))
        .count();
    let bytes = moved as f64 * shard_gb;
    let duration = if agg_bandwidth_gbps > 0.0 { bytes / agg_bandwidth_gbps } else { 0.0 };
    RebalancePlan { moved_shards: moved, total_shards, duration, degradation }
}

/// Plan a vertical resize: no shard movement (same ring), but nodes
/// restart in a rolling fashion — a short uniform degradation window.
pub fn plan_v_change(n_nodes: usize, restart_time: f64, degradation: f64) -> RebalancePlan {
    RebalancePlan {
        moved_shards: 0,
        total_shards: 0,
        duration: restart_time * n_nodes as f64,
        degradation,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_size_is_noop() {
        let p = plan_h_change(4, 4, 64, 1.0, 10.0, 0.7);
        assert!(p.is_noop());
        assert_eq!(p.duration, 0.0);
    }

    #[test]
    fn growth_moves_minority_of_shards() {
        let p = plan_h_change(4, 8, 256, 1.0, 10.0, 0.7);
        assert!(p.moved_shards > 0);
        assert!(
            p.moved_shards < 256 * 3 / 4,
            "consistent hashing should move a minority: {}",
            p.moved_shards
        );
        assert!(p.duration > 0.0);
    }

    #[test]
    fn duration_scales_with_shard_size() {
        let small = plan_h_change(2, 4, 64, 1.0, 10.0, 0.7);
        let big = plan_h_change(2, 4, 64, 4.0, 10.0, 0.7);
        assert!((big.duration - 4.0 * small.duration).abs() < 1e-9);
    }

    #[test]
    fn duration_inverse_in_bandwidth() {
        let slow = plan_h_change(2, 4, 64, 1.0, 5.0, 0.7);
        let fast = plan_h_change(2, 4, 64, 1.0, 20.0, 0.7);
        assert!(slow.duration > fast.duration);
    }

    #[test]
    fn vertical_resize_moves_nothing() {
        let p = plan_v_change(4, 0.05, 0.8);
        assert_eq!(p.moved_shards, 0);
        assert!((p.duration - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bigger_h_jump_moves_more() {
        let one = plan_h_change(4, 8, 512, 1.0, 10.0, 0.7);
        let two = plan_h_change(1, 8, 512, 1.0, 10.0, 0.7);
        assert!(two.moved_shards > one.moved_shards);
    }
}
