//! Rebalance planning: when the coordinator changes the node count, the
//! consistent-hash ring changes and shards must move. Data movement
//! takes time proportional to moved bytes over aggregate bandwidth and
//! degrades donor/recipient nodes while in flight — the physical cost
//! behind the paper's rebalance penalty `R` (§IV.D) and the reason H
//! moves are penalized twice as much as V moves.

use super::ring::HashRing;
use super::ClusterParams;
use crate::plane::{Configuration, ScalingPlane};

/// A planned rebalance operation.
#[derive(Debug, Clone, PartialEq)]
pub struct RebalancePlan {
    /// Shards whose primary changed (must move data).
    pub moved_shards: usize,
    /// Total shards.
    pub total_shards: usize,
    /// Wall-clock duration of the movement (synthetic time units).
    pub duration: f64,
    /// Capacity multiplier applied to every node while moving.
    pub degradation: f64,
}

impl RebalancePlan {
    pub fn none() -> Self {
        Self { moved_shards: 0, total_shards: 0, duration: 0.0, degradation: 1.0 }
    }

    pub fn is_noop(&self) -> bool {
        self.moved_shards == 0
    }
}

/// Plan the movement implied by changing the ring from `old_nodes` to
/// `new_nodes` physical nodes.
///
/// * `shard_gb` — data per shard;
/// * `agg_bandwidth_gbps` — cluster aggregate network bandwidth available
///   for movement (a fraction of the new tier's bandwidth);
/// * `degradation` — service-capacity multiplier while moving.
pub fn plan_h_change(
    old_nodes: usize,
    new_nodes: usize,
    total_shards: usize,
    shard_gb: f64,
    agg_bandwidth_gbps: f64,
    degradation: f64,
) -> RebalancePlan {
    if old_nodes == new_nodes {
        return RebalancePlan::none();
    }
    let old = HashRing::new(old_nodes);
    let new = HashRing::new(new_nodes);
    let moved = (0..total_shards as u64)
        .filter(|&s| old.primary(s) != new.primary(s))
        .count();
    let bytes = moved as f64 * shard_gb;
    let duration = if agg_bandwidth_gbps > 0.0 { bytes / agg_bandwidth_gbps } else { 0.0 };
    RebalancePlan { moved_shards: moved, total_shards, duration, degradation }
}

/// Plan a vertical resize: no shard movement (same ring), but nodes
/// restart in a rolling fashion — a short uniform degradation window.
pub fn plan_v_change(n_nodes: usize, restart_time: f64, degradation: f64) -> RebalancePlan {
    RebalancePlan {
        moved_shards: 0,
        total_shards: 0,
        duration: restart_time * n_nodes as f64,
        degradation,
    }
}

/// Plan the full physical transition between two plane configurations:
/// shard movement for H changes plus a rolling restart for tier
/// changes, merged into one degradation window (durations add, the
/// deeper degradation wins). Shared by every [`super::Substrate`]
/// engine so sampling, event-driven, and analytical modes pay
/// identical transition costs.
pub fn plan_reconfiguration(
    plane: &ScalingPlane,
    from: &Configuration,
    to: &Configuration,
    params: &ClusterParams,
) -> RebalancePlan {
    let old_h = plane.h_value(from) as usize;
    let new_h = plane.h_value(to) as usize;
    let new_tier = plane.tier(to);

    let mut plan = if old_h != new_h {
        let agg_bw = new_h as f64 * new_tier.bandwidth as f64 * params.move_bandwidth_frac;
        plan_h_change(
            old_h,
            new_h,
            params.shards,
            params.shard_gb,
            agg_bw,
            params.rebalance_degradation,
        )
    } else {
        RebalancePlan::none()
    };
    if plane.tier(from).name != new_tier.name {
        let restart = plan_v_change(new_h, params.restart_time, params.restart_degradation);
        plan.duration += restart.duration;
        plan.degradation = plan.degradation.min(restart.degradation);
        if plan.total_shards == 0 {
            plan.total_shards = restart.total_shards;
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_size_is_noop() {
        let p = plan_h_change(4, 4, 64, 1.0, 10.0, 0.7);
        assert!(p.is_noop());
        assert_eq!(p.duration, 0.0);
    }

    #[test]
    fn growth_moves_minority_of_shards() {
        let p = plan_h_change(4, 8, 256, 1.0, 10.0, 0.7);
        assert!(p.moved_shards > 0);
        assert!(
            p.moved_shards < 256 * 3 / 4,
            "consistent hashing should move a minority: {}",
            p.moved_shards
        );
        assert!(p.duration > 0.0);
    }

    #[test]
    fn duration_scales_with_shard_size() {
        let small = plan_h_change(2, 4, 64, 1.0, 10.0, 0.7);
        let big = plan_h_change(2, 4, 64, 4.0, 10.0, 0.7);
        assert!((big.duration - 4.0 * small.duration).abs() < 1e-9);
    }

    #[test]
    fn duration_inverse_in_bandwidth() {
        let slow = plan_h_change(2, 4, 64, 1.0, 5.0, 0.7);
        let fast = plan_h_change(2, 4, 64, 1.0, 20.0, 0.7);
        assert!(slow.duration > fast.duration);
    }

    #[test]
    fn vertical_resize_moves_nothing() {
        let p = plan_v_change(4, 0.05, 0.8);
        assert_eq!(p.moved_shards, 0);
        assert!((p.duration - 0.2).abs() < 1e-12);
    }

    #[test]
    fn bigger_h_jump_moves_more() {
        let one = plan_h_change(4, 8, 512, 1.0, 10.0, 0.7);
        let two = plan_h_change(1, 8, 512, 1.0, 10.0, 0.7);
        assert!(two.moved_shards > one.moved_shards);
    }

    #[test]
    fn reconfiguration_plans_match_axis_components() {
        let plane = crate::config::ModelConfig::default_paper().plane();
        let params = ClusterParams::default();
        let from = Configuration::new(1, 1);

        let same = plan_reconfiguration(&plane, &from, &from, &params);
        assert!(same.is_noop());
        assert_eq!(same.duration, 0.0);

        let h_only = plan_reconfiguration(&plane, &from, &Configuration::new(2, 1), &params);
        assert!(h_only.moved_shards > 0);
        assert!((h_only.degradation - params.rebalance_degradation).abs() < 1e-12);

        let v_only = plan_reconfiguration(&plane, &from, &Configuration::new(1, 2), &params);
        assert_eq!(v_only.moved_shards, 0);
        assert!((v_only.duration - params.restart_time * 2.0).abs() < 1e-12);

        // a diagonal move pays both: shard movement plus the restart,
        // degraded at the deeper of the two factors
        let diag = plan_reconfiguration(&plane, &from, &Configuration::new(2, 2), &params);
        assert_eq!(diag.moved_shards, h_only.moved_shards);
        assert!(diag.duration > v_only.duration);
        let deepest = params.rebalance_degradation.min(params.restart_degradation);
        assert!((diag.degradation - deepest).abs() < 1e-12);
    }
}
