//! Phase-2 substrate: a discrete-event simulated distributed database.
//!
//! The paper's evaluation is purely analytical (§V.A) and defers real
//! deployments (CockroachDB / Cassandra / YugabyteDB under YCSB) to
//! future work (§VIII). Per the substitution rule in DESIGN.md, this
//! module implements that missing substrate: a cluster of c-server
//! queueing nodes behind a consistent-hash ring with replicated,
//! quorum-acknowledged writes, rolling restarts for vertical resizes,
//! and bandwidth-limited shard movement for horizontal resizes. The
//! coordinator drives it with the *same* policy code path the
//! analytical simulator uses — observe, score neighbors, actuate.
//!
//! Two physical engines share this model behind the [`Substrate`]
//! trait:
//!
//! * [`ClusterSim`] — the original per-op *sampling* engine. It thins
//!   arrivals above [`ClusterParams::max_ops_per_step`] (stretching
//!   service times to preserve utilization) and recomputes compaction
//!   windows per node per step.
//! * [`events::EventSim`] — the event-driven engine: a binary-heap
//!   [`events::EventCalendar`] schedules rebalance-end / restart-end /
//!   compaction-start / compaction-end transitions, every arrival is
//!   simulated (no thinning), and the hot path is allocation-free
//!   (precomputed shard→replica tables and reusable scratch buffers).
//!
//! Every layer above (coordinator, fleet tenants) is generic over
//! [`Substrate`], so analytical, sampling-backed, and event-backed
//! instances mix freely in one run (`--substrate` on the CLI).

pub mod events;
pub mod node;
pub mod rebalance;
pub mod ring;

pub use events::{Event, EventCalendar, EventSim};
pub use node::Node;
pub use rebalance::RebalancePlan;
pub use ring::HashRing;


use crate::config::ModelConfig;
use crate::plane::{Configuration, ScalingPlane};
use crate::workload::{WorkloadPoint, XorShift64};

/// Tunables of the cluster substrate.
#[derive(Debug, Clone, Copy)]
pub struct ClusterParams {
    /// Number of data shards on the ring.
    pub shards: usize,
    /// Replication factor (capped by cluster size); the write quorum
    /// is a majority of the effective replica set.
    pub replication: usize,
    /// Data per shard (GB), for rebalance duration.
    pub shard_gb: f64,
    /// Fraction of aggregate bandwidth available to shard movement.
    pub move_bandwidth_frac: f64,
    /// Node capacity multiplier while a rebalance is in flight.
    pub rebalance_degradation: f64,
    /// Rolling-restart time per node on a vertical resize.
    pub restart_time: f64,
    /// Capacity multiplier during the restart window.
    pub restart_degradation: f64,
    /// One-way network hop latency (synthetic seconds).
    pub net_latency: f64,
    /// Extra commit overhead per write, scaled by ln(H)+1.
    pub write_coord_overhead: f64,
    /// Ops sampled per step at most (arrivals above this are scaled).
    /// Sampling-engine ([`ClusterSim`]) knob only: the event-driven
    /// [`events::EventSim`] simulates every arrival and ignores it.
    pub max_ops_per_step: usize,
    /// Duration of one workload step (synthetic seconds).
    pub interval: f64,
    /// Measured-latency SLA bound for violation accounting.
    pub sla_latency: f64,
    /// Zipf exponent for key/shard popularity (0.0 = uniform access;
    /// ~0.99 = YCSB-default skew). Hot shards concentrate load on their
    /// replica sets, so skew raises tail latency at equal utilization.
    pub zipf_s: f64,
    /// Background compaction: every `compaction_period` seconds each
    /// node spends `compaction_duration` at `compaction_degradation`
    /// capacity (LSM-style maintenance; staggered across nodes).
    pub compaction_period: f64,
    pub compaction_duration: f64,
    pub compaction_degradation: f64,
}

impl Default for ClusterParams {
    fn default() -> Self {
        Self {
            shards: 128,
            replication: 3,
            // small-corpus default: ~6 GB total data, so a horizontal
            // rebalance degrades the cluster for a fraction of a step
            // rather than whole phases (raise for heavier datasets)
            shard_gb: 0.05,
            move_bandwidth_frac: 0.2,
            rebalance_degradation: 0.7,
            restart_time: 0.02,
            restart_degradation: 0.8,
            net_latency: 0.0004,
            write_coord_overhead: 0.0006,
            // high enough that the paper-scale traces (peak 16k ops per
            // interval) run unthinned: thinning preserves utilization
            // but inflates per-op service time in measured latency
            max_ops_per_step: 20_000,
            interval: 1.0,
            sla_latency: 0.02,
            zipf_s: 0.0,
            compaction_period: 0.0, // disabled by default
            compaction_duration: 0.5,
            compaction_degradation: 0.85,
        }
    }
}

impl ClusterParams {
    /// YCSB-flavored preset: zipfian access + periodic compaction.
    pub fn ycsb_like() -> Self {
        Self {
            zipf_s: 0.99,
            compaction_period: 10.0,
            ..Self::default()
        }
    }
}

/// Cumulative zipf CDF over `shards` (empty when `zipf_s <= 0`, i.e.
/// uniform access). Shared by both substrate engines so their shard
/// sampling stays bit-identical.
pub(crate) fn zipf_shard_cdf(shards: usize, zipf_s: f64) -> Vec<f64> {
    if zipf_s <= 0.0 {
        return Vec::new();
    }
    let mut acc = 0.0;
    let mut cdf: Vec<f64> = (0..shards)
        .map(|j| {
            acc += 1.0 / ((j + 1) as f64).powf(zipf_s);
            acc
        })
        .collect();
    let total = *cdf.last().expect("at least one shard");
    for v in &mut cdf {
        *v /= total;
    }
    cdf
}

/// Cheap status snapshot of a substrate between steps (the `observe`
/// half of the control loop's observe → plan → actuate cycle).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubstrateStatus {
    /// Simulated time (synthetic seconds).
    pub time: f64,
    /// Node count currently deployed.
    pub nodes: usize,
    /// Aggregate healthy capacity (ops per unit time), degradation
    /// windows included.
    pub capacity: f64,
    /// A rebalance/restart window is currently open.
    pub degraded: bool,
    /// Conservation counters (offered = completed + dropped).
    pub total_offered: f64,
    pub total_completed: f64,
    pub total_dropped: f64,
}

/// A physical (or pseudo-physical) execution substrate the control
/// layers drive: the coordinator and fleet tenants are generic over
/// this trait, so analytical, sampling-backed, and event-backed
/// instances are interchangeable — and mixable within one fleet run.
pub trait Substrate {
    /// Configuration currently deployed.
    fn current(&self) -> Configuration;
    /// Serve one workload interval and measure it.
    fn step(&mut self, w: WorkloadPoint) -> ClusterStepMetrics;
    /// Actuate a reconfiguration, paying the physical transition cost.
    fn apply(&mut self, next: Configuration) -> RebalancePlan;
    /// Status snapshot between steps.
    fn observe(&self) -> SubstrateStatus;
    /// The physics parameters this substrate audits against.
    fn params(&self) -> &ClusterParams;
    /// Schedule a node failure at simulated time `at` on the
    /// substrate's event calendar, if it has one (failure injection;
    /// the fleet forwards through [`crate::fleet::Tenant`]). Returns
    /// whether the failure was scheduled — engines without a calendar
    /// ignore the request and return false.
    fn schedule_failure(&mut self, _at: f64, _node: usize) -> bool {
        false
    }
}

/// Which substrate engine to build (CLI `--substrate`, fleet attach).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubstrateKind {
    /// Legacy per-op sampling engine ([`ClusterSim`]).
    Sampling,
    /// Event-driven engine ([`events::EventSim`]).
    Des,
    /// Thin wrapper over the Phase-1 analytical surfaces
    /// ([`crate::simulator::AnalyticalSubstrate`]).
    Analytical,
}

impl SubstrateKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sampling" | "legacy" => Some(Self::Sampling),
            "des" | "event" | "events" => Some(Self::Des),
            "analytical" => Some(Self::Analytical),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Sampling => "sampling",
            Self::Des => "des",
            Self::Analytical => "analytical",
        }
    }
}

/// Measured metrics for one simulated step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterStepMetrics {
    /// Offered load (ops) this interval.
    pub offered: f64,
    /// Ops completed within the interval budget.
    pub completed: f64,
    /// Ops that blew the latency timeout (shed / failed).
    pub dropped: f64,
    /// Mean end-to-end latency of completed ops.
    pub avg_latency: f64,
    /// 99th-percentile latency.
    pub p99_latency: f64,
    /// 99.9th-percentile latency.
    pub p999_latency: f64,
    /// Offered load / aggregate capacity.
    pub utilization: f64,
    /// Whether a rebalance/restart window overlapped this step.
    pub degraded: bool,
}

/// The per-op sampling cluster engine (legacy path; see
/// [`events::EventSim`] for the event-driven engine).
pub struct ClusterSim {
    plane: ScalingPlane,
    kappa: f32,
    write_ratio: f64,
    params: ClusterParams,
    current: Configuration,
    nodes: Vec<Node>,
    ring: HashRing,
    time: f64,
    degraded_until: f64,
    degradation: f64,
    rng: XorShift64,
    rr: usize,
    /// Cumulative zipf CDF over shards (empty when access is uniform).
    zipf_cdf: Vec<f64>,
    /// Conservation counters (offered = completed + dropped).
    pub total_offered: f64,
    pub total_completed: f64,
    pub total_dropped: f64,
}

impl ClusterSim {
    pub fn new(cfg: &ModelConfig, params: ClusterParams, seed: u64) -> Self {
        let plane = cfg.plane();
        let start = Configuration::new(cfg.policy.start[0], cfg.policy.start[1]);
        let mut sim = Self {
            kappa: cfg.surfaces.kappa,
            write_ratio: cfg.write_ratio() as f64,
            params,
            current: start,
            nodes: Vec::new(),
            ring: HashRing::new(1),
            time: 0.0,
            degraded_until: 0.0,
            degradation: 1.0,
            rng: XorShift64::new(seed),
            rr: 0,
            zipf_cdf: Vec::new(),
            total_offered: 0.0,
            total_completed: 0.0,
            total_dropped: 0.0,
            plane,
        };
        sim.zipf_cdf = zipf_shard_cdf(sim.params.shards, sim.params.zipf_s);
        sim.rebuild();
        sim
    }

    fn rebuild(&mut self) {
        let h = self.plane.h_value(&self.current) as usize;
        let tier = self.plane.tier(&self.current).clone();
        self.nodes = (0..h).map(|_| Node::new(&tier, self.kappa)).collect();
        self.ring = HashRing::new(h);
    }

    /// Sample a shard id: uniform, or zipfian when `zipf_s > 0`.
    fn sample_shard(&mut self) -> u64 {
        if self.zipf_cdf.is_empty() {
            self.rng.below(self.params.shards as u64)
        } else {
            let u = self.rng.next_f64();
            self.zipf_cdf.partition_point(|&c| c < u) as u64
        }
    }

    /// Extra degradation on `node` at time `t` from staggered background
    /// compaction (1.0 = none).
    fn compaction_factor(&self, node: usize, t: f64) -> f64 {
        if self.params.compaction_period <= 0.0 {
            return 1.0;
        }
        // stagger nodes across the period
        let phase = (t + node as f64 * self.params.compaction_period
            / self.nodes.len().max(1) as f64)
            % self.params.compaction_period;
        if phase < self.params.compaction_duration {
            self.params.compaction_degradation
        } else {
            1.0
        }
    }

    pub fn current(&self) -> Configuration {
        self.current
    }

    pub fn params(&self) -> &ClusterParams {
        &self.params
    }

    pub fn time(&self) -> f64 {
        self.time
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Aggregate healthy capacity (ops per unit time).
    pub fn capacity(&self) -> f64 {
        let deg = if self.time < self.degraded_until { self.degradation } else { 1.0 };
        self.nodes.iter().map(|n| n.capacity()).sum::<f64>() * deg
    }

    /// Reconfigure the cluster. Horizontal changes trigger shard
    /// movement; vertical changes trigger a rolling restart. Returns
    /// the rebalance plan that was scheduled.
    pub fn apply(&mut self, next: Configuration) -> RebalancePlan {
        assert!(self.plane.contains(&next), "config out of plane");
        if next == self.current {
            return RebalancePlan::none();
        }
        let plan =
            rebalance::plan_reconfiguration(&self.plane, &self.current, &next, &self.params);
        self.current = next;
        self.rebuild();
        if plan.duration > 0.0 {
            self.degraded_until = self.time + plan.duration;
            self.degradation = plan.degradation;
        }
        plan
    }

    /// Inject a node failure: node `idx` serves nothing until the next
    /// reconfiguration (failure-injection tests).
    pub fn fail_node(&mut self, idx: usize) {
        if let Some(n) = self.nodes.get_mut(idx) {
            n.up = false;
        }
    }

    /// Simulate one workload interval.
    pub fn step(&mut self, w: WorkloadPoint) -> ClusterStepMetrics {
        let interval = self.params.interval;
        let t0 = self.time;
        let offered = w.lambda_req as f64 * interval;
        let degraded = t0 < self.degraded_until;
        let deg = if degraded { self.degradation } else { 1.0 };
        for n in &mut self.nodes {
            n.set_degradation(deg);
            n.decay_to(t0);
        }

        // Sample arrivals (cap for speed; results scaled back). To keep
        // the queueing physics intact under thinning, each sampled op
        // stands for `scale` real ops: service times are stretched by
        // `scale` so utilization (arrival rate x service time / servers)
        // is preserved exactly.
        let n_samples = (offered.round() as usize).min(self.params.max_ops_per_step).max(1);
        let scale = offered / n_samples as f64;
        // staggered background compaction (per-node extra degradation)
        let compaction: Vec<f64> = (0..self.nodes.len())
            .map(|i| self.compaction_factor(i, t0))
            .collect();
        let thin = if scale > 1.0 { scale } else { 1.0 };
        for (i, n) in self.nodes.iter_mut().enumerate() {
            n.set_degradation(deg * compaction[i] / thin);
        }
        let mut hist = crate::metrics::LatencyHistogram::new(1e-5);
        let mut dropped = 0usize;
        let timeout = self.params.sla_latency * 10.0;
        let repl = self.params.replication.min(self.nodes.len());
        let quorum = repl / 2 + 1;
        let h = self.nodes.len();
        let write_net = self.params.net_latency
            + self.params.write_coord_overhead * ((h as f64).ln() + 1.0);

        for i in 0..n_samples {
            let t = t0 + interval * (i as f64 + self.rng.next_f64()) / n_samples as f64;
            let shard = self.sample_shard();
            let replicas = self.ring.replicas(shard, repl);
            let is_write = self.rng.next_f64() < self.write_ratio;
            let lat = if is_write {
                // quorum write: wait for the majority of replica acks
                let live: Vec<usize> = replicas
                    .iter()
                    .copied()
                    .filter(|&r| self.nodes[r].up)
                    .collect();
                let mut finishes: Vec<f64> = live
                    .into_iter()
                    .map(|r| self.nodes[r].serve(t, &mut self.rng) - t)
                    .collect();
                if finishes.is_empty() {
                    dropped += 1;
                    continue;
                }
                finishes.sort_by(f64::total_cmp);
                let q = quorum.min(finishes.len());
                write_net + finishes[q - 1]
            } else {
                // read: round-robin over live replicas
                let live: Vec<usize> = replicas
                    .iter()
                    .copied()
                    .filter(|&r| self.nodes[r].up)
                    .collect();
                if live.is_empty() {
                    dropped += 1;
                    continue;
                }
                self.rr = self.rr.wrapping_add(1);
                let node = live[self.rr % live.len()];
                self.params.net_latency + (self.nodes[node].serve(t, &mut self.rng) - t)
            };
            if lat > timeout {
                dropped += 1;
            } else {
                hist.record(lat);
            }
        }

        self.time = t0 + interval;
        let completed = hist.len() as f64 * scale;
        let dropped_scaled = dropped as f64 * scale;
        self.total_offered += offered;
        self.total_completed += completed;
        self.total_dropped += dropped_scaled;

        let cap = self.capacity();
        ClusterStepMetrics {
            offered,
            completed,
            dropped: dropped_scaled,
            avg_latency: hist.mean(),
            p99_latency: hist.p99(),
            p999_latency: hist.p999(),
            utilization: if cap > 0.0 { offered / (cap * interval) } else { f64::INFINITY },
            degraded,
        }
    }
}

impl Substrate for ClusterSim {
    fn current(&self) -> Configuration {
        ClusterSim::current(self)
    }

    fn step(&mut self, w: WorkloadPoint) -> ClusterStepMetrics {
        ClusterSim::step(self, w)
    }

    fn apply(&mut self, next: Configuration) -> RebalancePlan {
        ClusterSim::apply(self, next)
    }

    fn observe(&self) -> SubstrateStatus {
        SubstrateStatus {
            time: self.time,
            nodes: self.nodes.len(),
            capacity: self.capacity(),
            degraded: self.time < self.degraded_until,
            total_offered: self.total_offered,
            total_completed: self.total_completed,
            total_dropped: self.total_dropped,
        }
    }

    fn params(&self) -> &ClusterParams {
        ClusterSim::params(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(seed: u64) -> ClusterSim {
        let cfg = ModelConfig::default_paper();
        ClusterSim::new(&cfg, ClusterParams::default(), seed)
    }

    fn point(lam: f32) -> WorkloadPoint {
        WorkloadPoint::new(lam, 0.3)
    }

    #[test]
    fn starts_at_config_with_right_node_count() {
        let s = sim(1);
        assert_eq!(s.current(), Configuration::new(1, 1)); // (H=2, medium)
        assert_eq!(s.n_nodes(), 2);
    }

    #[test]
    fn conservation_offered_equals_completed_plus_dropped() {
        let mut s = sim(2);
        for _ in 0..10 {
            s.step(point(3000.0));
        }
        let total = s.total_completed + s.total_dropped;
        assert!(
            (s.total_offered - total).abs() < 1e-6 * s.total_offered.max(1.0),
            "offered={} completed+dropped={}",
            s.total_offered,
            total
        );
    }

    #[test]
    fn light_load_completes_everything_quickly() {
        let mut s = sim(3);
        let m = s.step(point(500.0));
        assert!(m.dropped == 0.0, "dropped={}", m.dropped);
        assert!(m.avg_latency < ClusterParams::default().sla_latency);
        assert!(m.utilization < 0.3);
    }

    #[test]
    fn overload_drops_or_slows() {
        let mut s = sim(4);
        // 2 medium nodes: capacity ~ 2*4*585 = 4680 ops/s; offer 4x
        let mut metrics = Vec::new();
        for _ in 0..5 {
            metrics.push(s.step(point(20_000.0)));
        }
        let last = metrics.last().unwrap();
        assert!(last.utilization > 1.0);
        assert!(
            last.dropped > 0.0 || last.avg_latency > ClusterParams::default().sla_latency,
            "overload must surface as drops or latency"
        );
    }

    #[test]
    fn vertical_scale_raises_capacity_without_moving_shards() {
        let mut s = sim(5);
        let before = s.capacity();
        let plan = s.apply(Configuration::new(1, 3)); // medium -> xlarge
        assert_eq!(plan.moved_shards, 0);
        assert!(plan.duration > 0.0); // rolling restart
        // after the degradation window, capacity is 4x (16 vs 4 cpus)
        for _ in 0..3 {
            s.step(point(100.0));
        }
        assert!(s.capacity() > 3.0 * before);
    }

    #[test]
    fn horizontal_scale_moves_shards_and_degrades() {
        let mut s = sim(6);
        let plan = s.apply(Configuration::new(3, 1)); // H=2 -> H=8
        assert!(plan.moved_shards > 0);
        assert!(plan.duration > 0.0);
        let m = s.step(point(1000.0));
        assert!(m.degraded);
    }

    #[test]
    fn bigger_cluster_absorbs_more() {
        let mut small = sim(7);
        let mut big = sim(7);
        big.apply(Configuration::new(3, 3));
        // burn through the rebalance window
        for _ in 0..30 {
            big.step(point(100.0));
            small.step(point(100.0));
        }
        let lam = 30_000.0;
        let ms = small.step(point(lam));
        let mb = big.step(point(lam));
        assert!(mb.completed > ms.completed);
        assert!(mb.utilization < ms.utilization);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = sim(9);
        let mut b = sim(9);
        for _ in 0..5 {
            let ma = a.step(point(4000.0));
            let mb = b.step(point(4000.0));
            assert_eq!(ma, mb);
        }
    }

    #[test]
    fn zipf_skew_raises_tail_latency() {
        // skew concentrates load on the hot shards' replica sets: with
        // 8 nodes, per-node served-op imbalance must clearly exceed the
        // uniform case (the tail-latency effect follows from queueing).
        let cfg = ModelConfig::default_paper();
        let mut uniform = ClusterSim::new(&cfg, ClusterParams::default(), 20);
        let mut skewed = ClusterSim::new(
            &cfg,
            ClusterParams { zipf_s: 1.2, ..ClusterParams::default() },
            20,
        );
        let imbalance = |s: &mut ClusterSim| {
            s.apply(Configuration::new(3, 1)); // H=8, medium
            for _ in 0..20 {
                s.step(point(12_000.0));
            }
            let served: Vec<u64> = s.nodes.iter().map(|n| n.served).collect();
            let max = *served.iter().max().unwrap() as f64;
            let min = *served.iter().min().unwrap() as f64;
            max / min.max(1.0)
        };
        let iu = imbalance(&mut uniform);
        let is = imbalance(&mut skewed);
        assert!(
            is > 1.3 * iu,
            "zipf must imbalance node load: skewed {is:.2} vs uniform {iu:.2}"
        );
    }

    #[test]
    fn zipf_sampler_is_skewed_and_in_range() {
        let cfg = ModelConfig::default_paper();
        let mut s = ClusterSim::new(
            &cfg,
            ClusterParams { zipf_s: 0.99, ..ClusterParams::default() },
            21,
        );
        let mut counts = vec![0usize; s.params.shards];
        for _ in 0..20_000 {
            counts[s.sample_shard() as usize] += 1;
        }
        // shard 0 is the hottest; the bottom half is cold
        assert!(counts[0] > counts[s.params.shards / 2] * 5);
        assert!(counts.iter().sum::<usize>() == 20_000);
    }

    #[test]
    fn compaction_windows_degrade_capacity_periodically() {
        let cfg = ModelConfig::default_paper();
        let mut s = ClusterSim::new(
            &cfg,
            ClusterParams {
                compaction_period: 4.0,
                compaction_duration: 2.0,
                compaction_degradation: 0.3,
                ..ClusterParams::default()
            },
            22,
        );
        // near-capacity load: compaction windows must show up as higher
        // latency in some steps than others
        let lat: Vec<f64> = (0..12).map(|_| s.step(point(3800.0)).avg_latency).collect();
        let hi = lat.iter().cloned().fold(0.0, f64::max);
        let lo = lat.iter().cloned().fold(f64::MAX, f64::min);
        assert!(hi > 2.0 * lo, "compaction cycles visible: {lat:?}");
    }

    #[test]
    fn ycsb_preset_is_still_controllable() {
        let cfg = ModelConfig::default_paper();
        let mut c = crate::coordinator::native_coordinator(
            &cfg,
            Box::new(crate::policy::DiagonalScale::diagonal()),
            ClusterParams::ycsb_like(),
            23,
        );
        let trace = crate::workload::TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        let s = crate::coordinator::summarize(&reports);
        assert!(s.completed_ratio > 0.85, "completed={}", s.completed_ratio);
    }

    #[test]
    fn p999_at_least_p99() {
        let mut s = sim(24);
        let m = s.step(point(4000.0));
        assert!(m.p999_latency >= m.p99_latency);
    }

    #[test]
    fn node_failure_sheds_load() {
        let mut s = sim(10);
        s.fail_node(0);
        let m = s.step(point(3000.0));
        // some reads/writes still succeed on the surviving replicas
        assert!(m.completed > 0.0);
    }

    #[test]
    fn failing_all_nodes_drops_everything() {
        let mut s = sim(11);
        s.fail_node(0);
        s.fail_node(1);
        let m = s.step(point(1000.0));
        assert_eq!(m.completed, 0.0);
        assert!(m.dropped > 0.0);
    }
}
