//! Consistent-hash ring: maps shards to replica sets of nodes, Dynamo
//! style (paper §II.A), with virtual nodes for balance. Used by the
//! Phase-2 cluster substrate to decide shard placement and by the
//! rebalancer to compute data movement between configurations.

/// Virtual nodes per physical node (balance vs ring size).
const VNODES: usize = 64;

/// 64-bit mix hash (splitmix64 finalizer) — deterministic placement.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// A consistent-hash ring over `n_nodes` physical nodes.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// (position, node) sorted by position.
    points: Vec<(u64, usize)>,
    n_nodes: usize,
}

impl HashRing {
    pub fn new(n_nodes: usize) -> Self {
        assert!(n_nodes > 0);
        let mut points = Vec::with_capacity(n_nodes * VNODES);
        for node in 0..n_nodes {
            for v in 0..VNODES {
                points.push((mix((node as u64) << 32 | v as u64), node));
            }
        }
        points.sort_unstable();
        Self { points, n_nodes }
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// First `replicas` *distinct* nodes clockwise from the shard's hash
    /// — the shard's replica set (primary first).
    pub fn replicas(&self, shard: u64, replicas: usize) -> Vec<usize> {
        let replicas = replicas.min(self.n_nodes);
        let h = mix(shard.wrapping_mul(0x9E3779B97F4A7C15));
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut out = Vec::with_capacity(replicas);
        let mut i = start;
        while out.len() < replicas {
            let (_, node) = self.points[i % self.points.len()];
            if !out.contains(&node) {
                out.push(node);
            }
            i += 1;
        }
        out
    }

    /// Primary node for a shard.
    pub fn primary(&self, shard: u64) -> usize {
        self.replicas(shard, 1)[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_placement() {
        let a = HashRing::new(4);
        let b = HashRing::new(4);
        for s in 0..100 {
            assert_eq!(a.replicas(s, 3), b.replicas(s, 3));
        }
    }

    #[test]
    fn replicas_distinct_and_bounded() {
        let ring = HashRing::new(4);
        for s in 0..200 {
            let r = ring.replicas(s, 3);
            assert_eq!(r.len(), 3);
            let mut sorted = r.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 3, "replicas must be distinct");
            assert!(r.iter().all(|&n| n < 4));
        }
    }

    #[test]
    fn replication_capped_by_cluster_size() {
        let ring = HashRing::new(2);
        assert_eq!(ring.replicas(7, 3).len(), 2);
        let ring = HashRing::new(1);
        assert_eq!(ring.replicas(7, 3), vec![0]);
    }

    #[test]
    fn roughly_balanced() {
        let ring = HashRing::new(4);
        let mut counts = [0usize; 4];
        for s in 0..4096 {
            counts[ring.primary(s)] += 1;
        }
        for &c in &counts {
            // each node should own 25% +- 12% of primaries
            assert!(c > 4096 / 4 - 500 && c < 4096 / 4 + 500, "counts={counts:?}");
        }
    }

    #[test]
    fn minimal_movement_on_growth() {
        // consistent hashing: growing 4 -> 5 nodes should move far fewer
        // than half of the primaries.
        let a = HashRing::new(4);
        let b = HashRing::new(5);
        let moved = (0..4096)
            .filter(|&s| a.primary(s) != b.primary(s))
            .count();
        assert!(moved < 4096 / 2, "moved={moved}");
        assert!(moved > 0);
    }
}
