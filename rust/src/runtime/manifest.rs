//! Artifact manifest: `python/compile/aot.py` emits
//! `artifacts/manifest.json` describing every entry point (file, arg
//! shapes, output arity) plus the packed-parameter ABI version; the
//! engine validates it before compiling anything.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::config::ABI_VERSION;
use crate::util::json;

/// One AOT entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct EntryPoint {
    pub file: String,
    /// Argument shapes, in call order.
    pub args: Vec<Vec<usize>>,
    pub num_outputs: usize,
}

/// The whole manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub abi_version: u64,
    pub grid: usize,
    pub params_len: usize,
    pub neighbor_rows: usize,
    pub neighbor_cols: usize,
    pub rec_len: usize,
    pub entry_points: BTreeMap<String, EntryPoint>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.as_ref().display()
            )
        })?;
        Self::from_json(&text).context("parsing manifest.json")
    }

    /// Parse from JSON text (the shape `aot.py` emits).
    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let field = |k: &str| {
            v.get(k)
                .and_then(json::Value::as_usize)
                .ok_or_else(|| anyhow!("manifest missing numeric field `{k}`"))
        };
        let mut entry_points = BTreeMap::new();
        let eps = v
            .get("entry_points")
            .and_then(json::Value::as_object)
            .ok_or_else(|| anyhow!("manifest missing `entry_points`"))?;
        for (name, ep) in eps {
            let file = ep
                .get("file")
                .and_then(json::Value::as_str)
                .ok_or_else(|| anyhow!("entry `{name}` missing `file`"))?
                .to_string();
            let args = ep
                .get("args")
                .and_then(json::Value::as_array)
                .ok_or_else(|| anyhow!("entry `{name}` missing `args`"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_array()
                        .ok_or_else(|| anyhow!("entry `{name}`: bad arg shape"))?
                        .iter()
                        .map(|d| {
                            d.as_usize()
                                .ok_or_else(|| anyhow!("entry `{name}`: bad dim"))
                        })
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            let num_outputs = ep
                .get("num_outputs")
                .and_then(json::Value::as_usize)
                .ok_or_else(|| anyhow!("entry `{name}` missing `num_outputs`"))?;
            entry_points.insert(name.clone(), EntryPoint { file, args, num_outputs });
        }
        Ok(Self {
            abi_version: v
                .get("abi_version")
                .and_then(json::Value::as_u64)
                .ok_or_else(|| anyhow!("manifest missing `abi_version`"))?,
            grid: field("grid")?,
            params_len: field("params_len")?,
            neighbor_rows: field("neighbor_rows")?,
            neighbor_cols: field("neighbor_cols")?,
            rec_len: field("rec_len")?,
            entry_points,
        })
    }

    /// Check the artifact ABI matches what this crate was built for.
    pub fn validate(&self) -> Result<()> {
        if self.abi_version != ABI_VERSION {
            return Err(anyhow!(
                "artifact ABI v{} != crate ABI v{ABI_VERSION}: re-run `make artifacts`",
                self.abi_version
            ));
        }
        if self.grid != crate::GRID {
            return Err(anyhow!("artifact grid {} != {}", self.grid, crate::GRID));
        }
        if self.params_len != crate::PARAMS_LEN {
            return Err(anyhow!(
                "artifact params_len {} != {}",
                self.params_len,
                crate::PARAMS_LEN
            ));
        }
        if self.rec_len != crate::REC_LEN {
            return Err(anyhow!(
                "artifact rec_len {} != {}",
                self.rec_len,
                crate::REC_LEN
            ));
        }
        for required in ["surfaces", "neighbor", "queueing"] {
            if !self.entry_points.contains_key(required) {
                return Err(anyhow!("manifest missing entry point `{required}`"));
            }
        }
        if self.trace_lengths().is_empty() {
            return Err(anyhow!("manifest has no policy_trace_<T> entry points"));
        }
        Ok(())
    }

    /// Compiled `policy_trace` lengths, ascending.
    pub fn trace_lengths(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entry_points
            .keys()
            .filter_map(|k| k.strip_prefix("policy_trace_"))
            .filter_map(|t| t.parse().ok())
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let mut entry_points = BTreeMap::new();
        entry_points.insert(
            "surfaces".into(),
            EntryPoint { file: "surfaces.hlo.txt".into(), args: vec![vec![8]], num_outputs: 5 },
        );
        entry_points.insert(
            "neighbor".into(),
            EntryPoint { file: "neighbor.hlo.txt".into(), args: vec![], num_outputs: 2 },
        );
        entry_points.insert(
            "queueing".into(),
            EntryPoint { file: "queueing.hlo.txt".into(), args: vec![], num_outputs: 7 },
        );
        entry_points.insert(
            "policy_trace_50".into(),
            EntryPoint { file: "policy_trace_50.hlo.txt".into(), args: vec![], num_outputs: 1 },
        );
        entry_points.insert(
            "policy_trace_200".into(),
            EntryPoint { file: "policy_trace_200.hlo.txt".into(), args: vec![], num_outputs: 1 },
        );
        Manifest {
            abi_version: ABI_VERSION,
            grid: crate::GRID,
            params_len: crate::PARAMS_LEN,
            neighbor_rows: 16,
            neighbor_cols: 16,
            rec_len: crate::REC_LEN,
            entry_points,
        }
    }

    #[test]
    fn valid_manifest_passes() {
        sample().validate().unwrap();
    }

    #[test]
    fn wrong_abi_rejected() {
        let mut m = sample();
        m.abi_version = 999;
        assert!(m.validate().is_err());
    }

    #[test]
    fn wrong_grid_rejected() {
        let mut m = sample();
        m.grid = 4;
        assert!(m.validate().is_err());
    }

    #[test]
    fn missing_entry_point_rejected() {
        let mut m = sample();
        m.entry_points.remove("surfaces");
        assert!(m.validate().is_err());
    }

    #[test]
    fn trace_lengths_sorted() {
        assert_eq!(sample().trace_lengths(), vec![50, 200]);
    }

    #[test]
    fn missing_trace_rejected() {
        let mut m = sample();
        m.entry_points.remove("policy_trace_50");
        m.entry_points.remove("policy_trace_200");
        assert!(m.validate().is_err());
    }

    #[test]
    fn parses_aot_json_shape() {
        let text = r#"{
          "abi_version": 1, "grid": 8, "params_len": 32,
          "neighbor_rows": 16, "neighbor_cols": 16, "rec_len": 8,
          "entry_points": {
            "surfaces": {"file": "surfaces.hlo.txt",
                         "args": [[8],[8,5],[32],[8,8]], "num_outputs": 5}
          }
        }"#;
        let m = Manifest::from_json(text).unwrap();
        assert_eq!(m.abi_version, 1);
        assert_eq!(m.entry_points["surfaces"].args[1], vec![8, 5]);
        assert_eq!(m.entry_points["surfaces"].num_outputs, 5);
    }

    #[test]
    fn malformed_json_is_error() {
        assert!(Manifest::from_json("{").is_err());
        assert!(Manifest::from_json("{}").is_err());
    }

    #[test]
    fn missing_file_is_helpful_error() {
        let err = Manifest::load("/nonexistent/manifest.json").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
