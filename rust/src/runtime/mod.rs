//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the rust decision
//! path. Python never runs here — the artifacts are plain HLO text,
//! compiled once per process by the PJRT CPU client.
//!
//! Pattern follows `/opt/xla-example/src/bin/load_hlo.rs`:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.

// This module keys executables by entry-point name and never iterates
// for decisions, so HashMap's unordered iteration is harmless here —
// it is the one module allowlisted from simlint's
// d2-no-unordered-iteration rule and clippy's disallowed_types.
#![allow(clippy::disallowed_types)]

mod manifest;

pub use manifest::{EntryPoint, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::{grid_arrays, pack_params, ModelConfig, MoveFlags};
use crate::workload::Trace;
use crate::{GRID, PARAMS_LEN, REC_LEN};

/// All five surfaces over the padded grid, as returned by the
/// `surfaces` artifact (row-major `GRID x GRID`, padding zeroed).
#[derive(Debug, Clone, PartialEq)]
pub struct SurfaceGrids {
    pub latency: Vec<f32>,
    pub throughput: Vec<f32>,
    pub cost: Vec<f32>,
    pub coordination: Vec<f32>,
    pub objective: Vec<f32>,
}

/// Row-major grid lookup at plane indices.
pub fn grid_at(grid: &[f32], h_idx: usize, v_idx: usize) -> f32 {
    grid[h_idx * GRID + v_idx]
}

/// One decoded `policy_trace` step record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub h_idx: usize,
    pub v_idx: usize,
    pub latency: f32,
    pub throughput: f32,
    pub cost: f32,
    pub objective: f32,
    pub latency_violation: bool,
    pub throughput_violation: bool,
}

/// The PJRT engine: one compiled executable per artifact entry point.
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Engine {
    /// Load every artifact listed in `<dir>/manifest.json` and compile
    /// it on the PJRT CPU client. Validates the manifest ABI.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        manifest.validate()?;
        // fail on missing artifact files before spinning up PJRT: a
        // clearer error, and no client is created for a doomed load
        for (name, ep) in &manifest.entry_points {
            let path = dir.join(&ep.file);
            if !path.exists() {
                return Err(anyhow!(
                    "artifact {} (entry `{name}`) not found — run `make artifacts` first",
                    path.display()
                ));
            }
        }
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let mut executables = HashMap::new();
        for (name, ep) in &manifest.entry_points {
            let path = dir.join(&ep.file);
            let exe = Self::compile_file(&client, &path)
                .with_context(|| format!("compiling artifact `{name}`"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Self { client, manifest, executables, dir })
    }

    /// Default artifact location (`artifacts/` at the repo root or the
    /// `DIAGONAL_SCALE_ARTIFACTS` env override).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("DIAGONAL_SCALE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    fn compile_file(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
        // existence is pre-checked in `load` (before the client exists)
        let proto = HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| anyhow!("PJRT compile {}: {e}", path.display()))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn has_entry(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute an entry point with positional literal arguments and
    /// decompose the (always-tupled) result.
    pub fn execute(&self, name: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown entry point `{name}`"))?;
        let ep = &self.manifest.entry_points[name];
        if args.len() != ep.args.len() {
            return Err(anyhow!(
                "`{name}` expects {} args, got {}",
                ep.args.len(),
                args.len()
            ));
        }
        let result = exe
            .execute::<Literal>(args)
            .map_err(|e| anyhow!("executing `{name}`: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching `{name}` result: {e}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("decomposing `{name}` result: {e}"))?;
        if parts.len() != ep.num_outputs {
            return Err(anyhow!(
                "`{name}` returned {} outputs, manifest says {}",
                parts.len(),
                ep.num_outputs
            ));
        }
        Ok(parts)
    }

    fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))
    }

    /// Upload host data to a device-resident buffer (done once for the
    /// static grid arguments — the §Perf buffer-reuse optimization).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host->device upload: {e}"))
    }

    /// Execute an entry point with pre-uploaded device buffers (hot
    /// path: skips per-call literal creation for static arguments).
    pub fn execute_buffers(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown entry point `{name}`"))?;
        let ep = &self.manifest.entry_points[name];
        if args.len() != ep.args.len() {
            return Err(anyhow!(
                "`{name}` expects {} args, got {}",
                ep.args.len(),
                args.len()
            ));
        }
        let result = exe
            .execute_b::<&PjRtBuffer>(args)
            .map_err(|e| anyhow!("executing `{name}`: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching `{name}` result: {e}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("decomposing `{name}` result: {e}"))?;
        if parts.len() != ep.num_outputs {
            return Err(anyhow!(
                "`{name}` returned {} outputs, manifest says {}",
                parts.len(),
                ep.num_outputs
            ));
        }
        Ok(parts)
    }
}

/// High-level typed facade over the engine for the Diagonal Scaling
/// entry points, with the static grid literals built once per model
/// config (hot-path friendly: only the parameter vector changes per
/// decision).
pub struct SurfaceEngine {
    engine: Engine,
    hs: Literal,
    tiers: Literal,
    mask: Literal,
    /// Device-resident copies of the static grid arguments (§Perf
    /// buffer reuse: uploaded once, reused on every hot-path call).
    hs_buf: PjRtBuffer,
    tiers_buf: PjRtBuffer,
    mask_buf: PjRtBuffer,
    cfg: ModelConfig,
}

impl SurfaceEngine {
    pub fn new(engine: Engine, cfg: &ModelConfig) -> Result<Self> {
        if cfg.plane.grid != GRID {
            return Err(anyhow!(
                "config grid {} != artifact grid {GRID}",
                cfg.plane.grid
            ));
        }
        let (hs, tiers, mask) = grid_arrays(cfg);
        let hs_buf = engine.upload(&hs, &[GRID])?;
        let tiers_buf = engine.upload(&tiers, &[GRID, 5])?;
        let mask_buf = engine.upload(&mask, &[GRID, GRID])?;
        Ok(Self {
            hs: Literal::vec1(&hs),
            tiers: Literal::vec1(&tiers)
                .reshape(&[GRID as i64, 5])
                .map_err(|e| anyhow!("tiers reshape: {e}"))?,
            mask: Literal::vec1(&mask)
                .reshape(&[GRID as i64, GRID as i64])
                .map_err(|e| anyhow!("mask reshape: {e}"))?,
            hs_buf,
            tiers_buf,
            mask_buf,
            engine,
            cfg: cfg.clone(),
        })
    }

    /// Load from the default artifact dir with a config.
    pub fn from_config(cfg: &ModelConfig) -> Result<Self> {
        Self::new(Engine::load(Engine::default_dir())?, cfg)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    fn params_literal(&self, lambda_req: f32, moves: MoveFlags) -> Literal {
        let p = pack_params(&self.cfg, lambda_req, moves);
        Literal::vec1(&p)
    }

    /// Evaluate the five surfaces over the plane via the AOT kernel.
    /// Hot path: the static grid arguments live on-device; only the
    /// 32-float parameter vector is transferred per call.
    pub fn surfaces(&self, lambda_req: f32) -> Result<SurfaceGrids> {
        let p = pack_params(&self.cfg, lambda_req, MoveFlags::DIAGONAL);
        let params = self.engine.upload(&p, &[PARAMS_LEN])?;
        let out = self.engine.execute_buffers(
            "surfaces",
            &[&self.hs_buf, &self.tiers_buf, &params, &self.mask_buf],
        )?;
        let mut grids: Vec<Vec<f32>> = out
            .iter()
            .map(Engine::to_vec_f32)
            .collect::<Result<_>>()?;
        let objective = grids.pop().unwrap();
        let coordination = grids.pop().unwrap();
        let cost = grids.pop().unwrap();
        let throughput = grids.pop().unwrap();
        let latency = grids.pop().unwrap();
        Ok(SurfaceGrids { latency, throughput, cost, coordination, objective })
    }

    /// Evaluate the five surfaces over the *wide* disaggregated plane
    /// (paper VIII; `surfaces_wide` artifact). Arrays follow the
    /// `disagg::wide_grid_arrays` ABI; returns five row-major
    /// `GRID x W` grids `(L, T, C, K, F)`.
    pub fn surfaces_wide(
        &self,
        hs: &[f32],
        tiers: &[f32],
        mask: &[f32],
        lambda_req: f32,
    ) -> Result<Vec<Vec<f32>>> {
        let ep = self
            .engine
            .manifest
            .entry_points
            .get("surfaces_wide")
            .ok_or_else(|| anyhow!("artifacts lack `surfaces_wide` — re-run `make artifacts`"))?;
        let w = ep.args[1][0] as i64;
        if tiers.len() != (w * 5) as usize || mask.len() != GRID * w as usize {
            return Err(anyhow!("wide arrays must be {w}x5 tiers and {GRID}x{w} mask"));
        }
        let params = self.params_literal(lambda_req, MoveFlags::DIAGONAL);
        let out = self.engine.execute(
            "surfaces_wide",
            &[
                Literal::vec1(hs),
                Literal::vec1(tiers)
                    .reshape(&[w, 5])
                    .map_err(|e| anyhow!("wide tiers reshape: {e}"))?,
                params,
                Literal::vec1(mask)
                    .reshape(&[GRID as i64, w])
                    .map_err(|e| anyhow!("wide mask reshape: {e}"))?,
            ],
        )?;
        out.iter().map(Engine::to_vec_f32).collect()
    }

    /// Utilization-corrected latency grid (paper VIII):
    /// `(l_final, saturated)` plus the five raw surfaces.
    pub fn queueing(&self, lambda_req: f32) -> Result<(Vec<f32>, Vec<f32>, SurfaceGrids)> {
        let p = pack_params(&self.cfg, lambda_req, MoveFlags::DIAGONAL);
        let params = self.engine.upload(&p, &[PARAMS_LEN])?;
        let out = self.engine.execute_buffers(
            "queueing",
            &[&self.hs_buf, &self.tiers_buf, &params, &self.mask_buf],
        )?;
        let v: Vec<Vec<f32>> = out
            .iter()
            .map(Engine::to_vec_f32)
            .collect::<Result<_>>()?;
        let [l_final, sat, lat, thr, cost, coord, obj]: [Vec<f32>; 7] =
            v.try_into().map_err(|_| anyhow!("queueing arity"))?;
        Ok((
            l_final,
            sat,
            SurfaceGrids {
                latency: lat,
                throughput: thr,
                cost,
                coordination: coord,
                objective: obj,
            },
        ))
    }

    /// Run the whole Algorithm-1 simulation inside XLA (the
    /// `policy_trace_T` artifacts). The trace length must fit one of
    /// the compiled lengths; shorter traces are zero-padded and
    /// truncated on return.
    pub fn policy_trace(
        &self,
        trace: &Trace,
        moves: MoveFlags,
        start: (usize, usize),
    ) -> Result<Vec<TraceRecord>> {
        let steps = trace.len();
        let compiled = self
            .engine
            .manifest
            .trace_lengths()
            .into_iter()
            .filter(|&t| t >= steps)
            .min()
            .ok_or_else(|| {
                anyhow!("no policy_trace artifact can hold {steps} steps")
            })?;
        let name = format!("policy_trace_{compiled}");

        let mut flat = trace.to_flat();
        flat.resize(compiled * 2, 0.0);
        let trace_lit = Literal::vec1(&flat)
            .reshape(&[compiled as i64, 2])
            .map_err(|e| anyhow!("trace reshape: {e}"))?;
        let start_lit = Literal::vec1(&[start.0 as f32, start.1 as f32]);
        let params = self.params_literal(0.0, moves); // per-step lambda in trace

        let out = self.engine.execute(
            &name,
            &[
                self.hs.clone(),
                self.tiers.clone(),
                params,
                self.mask.clone(),
                trace_lit,
                start_lit,
            ],
        )?;
        let recs = Engine::to_vec_f32(&out[0])?;
        if recs.len() != compiled * REC_LEN {
            return Err(anyhow!(
                "policy_trace returned {} floats, expected {}",
                recs.len(),
                compiled * REC_LEN
            ));
        }
        Ok(recs
            .chunks_exact(REC_LEN)
            .take(steps)
            .map(|c| TraceRecord {
                h_idx: c[0] as usize,
                v_idx: c[1] as usize,
                latency: c[2],
                throughput: c[3],
                cost: c[4],
                objective: c[5],
                latency_violation: c[6] > 0.5,
                throughput_violation: c[7] > 0.5,
            })
            .collect())
    }

    /// Score a padded candidate batch via the `neighbor` artifact.
    /// `cand` is row-major `[rows, cols]` as documented in
    /// `python/compile/defaults.py`; returns `(scores, feasible)`.
    pub fn neighbor_scores(
        &self,
        cand: &[f32],
        lambda_req: f32,
        moves: MoveFlags,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let ep = &self.engine.manifest.entry_points["neighbor"];
        let rows = ep.args[0][0] as i64;
        let cols = ep.args[0][1] as i64;
        if cand.len() != (rows * cols) as usize {
            return Err(anyhow!(
                "candidate batch must be {}x{} floats",
                rows,
                cols
            ));
        }
        // hot path: direct host->device uploads, no literal roundtrip
        let cand_buf = self
            .engine
            .upload(cand, &[rows as usize, cols as usize])?;
        let p = pack_params(&self.cfg, lambda_req, moves);
        let params = self.engine.upload(&p, &[PARAMS_LEN])?;
        let out = self
            .engine
            .execute_buffers("neighbor", &[&cand_buf, &params])?;
        Ok((Engine::to_vec_f32(&out[0])?, Engine::to_vec_f32(&out[1])?))
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Sanity check: parameter-vector agreement between the config
    /// packing and the artifact manifest.
    pub fn check_abi(&self) -> Result<()> {
        let m = &self.engine.manifest;
        if m.params_len != PARAMS_LEN {
            return Err(anyhow!(
                "artifact params_len {} != crate PARAMS_LEN {PARAMS_LEN}",
                m.params_len
            ));
        }
        Ok(())
    }
}
