//! The single sanctioned f64 → f32 edge for monetary values.
//!
//! Money (spend, cost, budget headroom) accumulates in `f64`
//! everywhere in this crate: PR 7's hand-written mirror caught a real
//! f32 running-sum drift (> 1e-3 over 10k tiny costs, pinned by
//! `fleet::tests`), and simlint's `n1-money-in-f64` rule now flags any
//! f32 money accumulator or ad-hoc `as f32` narrowing of a money
//! identifier. Reporting surfaces (`FleetTick::spend`,
//! `AdmissionReport`, `RebalanceBundle`, budget hints) still carry
//! f32 for size; they must narrow **here**, once, after the f64
//! accumulation is complete, so every rounding site is greppable.

/// Narrow a fully-accumulated f64 monetary value to the f32 carried by
/// reporting structs. Semantically identical to `as f32` (round to
/// nearest); the point is that this is the *only* place the crate is
/// allowed to do it.
#[inline]
pub fn narrow(money: f64) -> f32 {
    // simlint: allow(n1-money-in-f64): this function IS the single sanctioned narrowing edge.
    money as f32
}

#[cfg(test)]
mod tests {
    use super::narrow;

    #[test]
    fn narrow_matches_primitive_cast() {
        for v in [0.0, 1.5, 0.1, 1e-9, 123456.789, f64::MAX, -7.25] {
            assert_eq!(narrow(v).to_bits(), (v as f32).to_bits());
        }
    }
}
