//! Small in-tree substrates for functionality usually pulled from
//! crates.io: this repo builds fully offline against the `xla` crate's
//! vendored closure, so config parsing (TOML), manifest parsing (JSON),
//! and the test/bench scaffolding are implemented here from scratch.

pub mod json;
pub mod money;
pub mod toml;
