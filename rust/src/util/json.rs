//! Minimal JSON parser + writer for the artifact manifest
//! (`artifacts/manifest.json`). Supports objects, arrays, strings with
//! the common escapes, numbers, booleans, and null — the full grammar
//! the manifest needs, with precise error offsets.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object()?.get(key)
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing characters at offset {pos}");
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        bail!("expected `{}` at offset {pos}", c as char)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of input"),
        Some(b'{') => parse_object(b, pos),
        Some(b'[') => parse_array(b, pos),
        Some(b'"') => Ok(Value::Str(parse_string(b, pos)?)),
        Some(b't') => parse_lit(b, pos, "true", Value::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false", Value::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null", Value::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Value) -> Result<Value> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("invalid literal at offset {pos}")
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Value> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow!("invalid number `{s}` at offset {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(
                            b.get(*pos + 1..*pos + 5).ok_or_else(|| anyhow!("bad \\u"))?,
                        )?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).ok_or_else(|| anyhow!("bad \\u"))?);
                        *pos += 4;
                    }
                    _ => bail!("bad escape at offset {pos}"),
                }
                *pos += 1;
            }
            Some(&c) => {
                // copy a full UTF-8 sequence
                let s = &b[*pos..];
                let len = utf8_len(c);
                out.push_str(std::str::from_utf8(&s[..len.min(s.len())])?);
                *pos += len;
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Value> {
    expect(b, pos, b'[')?;
    let mut out = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Value::Array(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Value::Array(out));
            }
            _ => bail!("expected `,` or `]` at offset {pos}"),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Value> {
    expect(b, pos, b'{')?;
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Value::Object(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        out.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Value::Object(out));
            }
            _ => bail!("expected `,` or `}}` at offset {pos}"),
        }
    }
}

/// Serialize a [`Value`] (compact, keys sorted — used by tests to write
/// synthetic manifests).
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => {
            let _ = write!(out, "{b}");
        }
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Array(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Value::Str(k.clone()), out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_object().unwrap().is_empty());
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn roundtrips() {
        let text = r#"{"abi_version":1,"entry_points":{"surfaces":{"args":[[8],[8,5]],"file":"s.hlo.txt","num_outputs":5}},"grid":8}"#;
        let v = parse(text).unwrap();
        assert_eq!(to_string(&v), text);
    }

    #[test]
    fn parses_a_real_manifest_shape() {
        let text = r#"{
          "abi_version": 1,
          "grid": 8,
          "params_len": 32,
          "neighbor_rows": 16,
          "neighbor_cols": 16,
          "rec_len": 8,
          "entry_points": {
            "surfaces": {"file": "surfaces.hlo.txt", "args": [[8],[8,5],[32],[8,8]], "num_outputs": 5}
          }
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("abi_version").unwrap().as_u64(), Some(1));
        let ep = v.get("entry_points").unwrap().get("surfaces").unwrap();
        assert_eq!(ep.get("num_outputs").unwrap().as_usize(), Some(5));
        let args = ep.get("args").unwrap().as_array().unwrap();
        assert_eq!(args[1].as_array().unwrap()[1].as_usize(), Some(5));
    }
}
