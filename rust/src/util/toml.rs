//! Minimal TOML parser covering the subset used by
//! `config/default.toml`: `[section]`, nested `[a.b]`, array-of-tables
//! `[[a.b]]`, and `key = value` with strings, integers, floats,
//! booleans, and flat arrays. Comments (`#`) and blank lines are
//! skipped. Not a general TOML implementation — see the tests for the
//! supported grammar.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
    Table(BTreeMap<String, Value>),
    /// Array of tables (`[[x]]`).
    TableArray(Vec<BTreeMap<String, Value>>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<f32> {
        self.as_f64().map(|f| f as f32)
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_table_array(&self) -> Option<&[BTreeMap<String, Value>]> {
        match self {
            Value::TableArray(t) => Some(t),
            _ => None,
        }
    }

    /// Dotted-path lookup into nested tables.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }
}

/// Parse TOML text into a root [`Value::Table`].
pub fn parse(text: &str) -> Result<Value> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    // Path of the currently open [section]; empty = root. The bool
    // marks whether it is the latest element of a [[table array]].
    let mut section: Vec<String> = Vec::new();
    let mut in_array_tail = false;

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| anyhow!("TOML line {}: {m}: `{raw}`", lineno + 1);

        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let path: Vec<String> = name.split('.').map(|s| s.trim().to_string()).collect();
            push_table_array(&mut root, &path).map_err(|e| err(&e.to_string()))?;
            section = path;
            in_array_tail = true;
        } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.split('.').map(|s| s.trim().to_string()).collect();
            in_array_tail = false;
            ensure_table(&mut root, &section).map_err(|e| err(&e.to_string()))?;
        } else if let Some(eq) = line.find('=') {
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(line[eq + 1..].trim()).map_err(|e| err(&e.to_string()))?;
            let table = open_table(&mut root, &section, in_array_tail)
                .map_err(|e| err(&e.to_string()))?;
            if table.insert(key.clone(), value).is_some() {
                return Err(err(&format!("duplicate key `{key}`")));
            }
        } else {
            return Err(err("expected `[section]` or `key = value`"));
        }
    }
    Ok(Value::Table(root))
}

fn strip_comment(line: &str) -> &str {
    // no escape handling needed: strings in our configs never contain #
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::Array(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value `{s}`")
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Value>> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Value::Table(BTreeMap::new()));
        cur = match entry {
            Value::Table(t) => t,
            Value::TableArray(v) => v.last_mut().expect("table arrays are never empty"),
            _ => bail!("`{part}` is not a table"),
        };
    }
    Ok(cur)
}

fn push_table_array(root: &mut BTreeMap<String, Value>, path: &[String]) -> Result<()> {
    let (last, parents) = path.split_last().ok_or_else(|| anyhow!("empty path"))?;
    let parent = ensure_table(root, parents)?;
    match parent
        .entry(last.clone())
        .or_insert_with(|| Value::TableArray(Vec::new()))
    {
        Value::TableArray(v) => {
            v.push(BTreeMap::new());
            Ok(())
        }
        _ => bail!("`{last}` is not an array of tables"),
    }
}

fn open_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    section: &[String],
    in_array_tail: bool,
) -> Result<&'a mut BTreeMap<String, Value>> {
    if !in_array_tail {
        return ensure_table(root, section);
    }
    let (last, parents) = section.split_last().ok_or_else(|| anyhow!("empty section"))?;
    let parent = ensure_table(root, parents)?;
    match parent.get_mut(last) {
        Some(Value::TableArray(v)) => Ok(v.last_mut().expect("non-empty")),
        _ => bail!("`{last}` is not an array of tables"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let v = parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = -3\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("c").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("e").unwrap().as_i64(), Some(-3));
    }

    #[test]
    fn int_promotes_to_float_via_accessor() {
        let v = parse("x = 4\n").unwrap();
        assert_eq!(v.get("x").unwrap().as_f32(), Some(4.0));
    }

    #[test]
    fn parses_sections_and_nested_paths() {
        let v = parse("[a]\nx = 1\n[a.b]\ny = 2\n").unwrap();
        assert_eq!(v.get("a.x").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("a.b.y").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn parses_arrays() {
        let v = parse("xs = [1, 2, 4, 8]\nys = [1.5, 2.5]\nempty = []\n").unwrap();
        let xs = v.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 4);
        assert_eq!(xs[3].as_i64(), Some(8));
        assert_eq!(v.get("empty").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn parses_table_arrays() {
        let text = "[[t]]\nname = \"a\"\n[[t]]\nname = \"b\"\nv = 2\n";
        let v = parse(text).unwrap();
        let ts = v.get("t").unwrap().as_table_array().unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0]["name"].as_str(), Some("a"));
        assert_eq!(ts[1]["v"].as_i64(), Some(2));
    }

    #[test]
    fn nested_table_arrays_under_section() {
        let text = "[p]\nk = 1\n[[p.tiers]]\nname = \"small\"\n[[p.tiers]]\nname = \"big\"\n";
        let v = parse(text).unwrap();
        let tiers = v.get("p.tiers").unwrap().as_table_array().unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[1]["name"].as_str(), Some("big"));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let v = parse("# hello\n\na = 1  # trailing\nb = \"x # y\"\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x # y"));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn garbage_rejected_with_line_number() {
        let err = parse("a = 1\nnot a line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }

    #[test]
    fn parses_the_bundled_default_config() {
        let text = include_str!("../../../config/default.toml");
        let v = parse(text).unwrap();
        assert_eq!(v.get("surfaces.kappa").unwrap().as_f64(), Some(585.0));
        assert_eq!(v.get("plane.h_values").unwrap().as_array().unwrap().len(), 4);
        assert_eq!(v.get("plane.tiers").unwrap().as_table_array().unwrap().len(), 4);
        assert_eq!(v.get("policy.plan_queue").unwrap().as_bool(), Some(false));
    }
}
