//! The bin-packer: first-fit-decreasing seeding plus DIAGONALSCALE-style
//! local search over placement moves — migrate a tenant, merge two
//! clusters, split a cluster, resize a host — minimizing fleet cost
//! subject to every hosted tenant's SLA.
//!
//! Feasibility is interference-aware: a host is feasible for a tenant
//! set when its throughput covers the *buffered* total demand and the
//! latency surface, inflated by the contention penalty at the implied
//! utilization, stays within the tightest hosted `l_max`. Any move that
//! changes a cluster's shape (gaining tenants, or a config change) is
//! additionally checked against the **transition guard**: the window
//! opened by the implied migration/rebalance degrades capacity, and a
//! plan that only works at full health would violate SLAs for the whole
//! window. This is why the packer will consolidate twelve small tenants
//! onto a host one notch larger than the steady-state optimum, and why
//! it refuses the last downsize that a window could not absorb —
//! hysteresis, not a bug.
//!
//! All enumeration orders are fixed (clusters by position, tenants by
//! id, the plane row-major), so packing is deterministic: same inputs,
//! same placement.

use std::sync::Arc;

use crate::plane::Configuration;
use crate::surfaces::SurfaceModel;

use super::interference::contention_factor;
use super::PlacementConfig;

/// Per-tenant planning inputs: the demand each tenant must be hosted
/// for (the fleet plans against the peak over its lookahead horizon)
/// and its latency bound.
#[derive(Debug, Clone)]
pub struct PackInput {
    /// Planning demand per tenant (ops per unit time).
    pub demand: Vec<f64>,
    /// Per-tenant latency bound (`SlaSpec::l_max`).
    pub l_max: Vec<f32>,
    /// Throughput planning buffer (`SlaSpec::b_sla`).
    pub b_sla: f64,
}

impl PackInput {
    pub fn len(&self) -> usize {
        self.demand.len()
    }

    pub fn is_empty(&self) -> bool {
        self.demand.is_empty()
    }

    /// Total planning demand of a tenant set.
    pub fn lam_sum(&self, tenants: &[usize]) -> f64 {
        tenants.iter().map(|&t| self.demand[t]).sum()
    }

    /// Tightest latency bound across a tenant set.
    pub fn lmax_min(&self, tenants: &[usize]) -> f64 {
        tenants
            .iter()
            .map(|&t| self.l_max[t] as f64)
            .fold(f64::INFINITY, f64::min)
    }
}

/// One shared cluster as the packer plans it: a host configuration and
/// the tenants co-located on it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedCluster {
    pub config: Configuration,
    /// Hosted tenant ids, sorted ascending.
    pub tenants: Vec<usize>,
}

/// A full fleet placement: every tenant on exactly one cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub clusters: Vec<PlannedCluster>,
}

impl Placement {
    /// One cluster per tenant at a common start config (the un-packed
    /// baseline every simulation starts from).
    pub fn dedicated(n: usize, config: Configuration) -> Self {
        Self {
            clusters: (0..n)
                .map(|t| PlannedCluster { config, tenants: vec![t] })
                .collect(),
        }
    }

    /// Index of the cluster hosting `tenant`, if any.
    pub fn host_of(&self, tenant: usize) -> Option<usize> {
        self.clusters.iter().position(|c| c.tenants.contains(&tenant))
    }

    /// Every tenant id in 0..n hosted exactly once.
    pub fn hosts_all(&self, n: usize) -> bool {
        let mut seen = vec![0usize; n];
        for c in &self.clusters {
            for &t in &c.tenants {
                if t >= n {
                    return false;
                }
                seen[t] += 1;
            }
        }
        seen.iter().all(|&k| k == 1)
    }

    /// Total planning demand hosted (conserved by every packer move).
    pub fn total_demand(&self, input: &PackInput) -> f64 {
        self.clusters.iter().map(|c| input.lam_sum(&c.tenants)).sum()
    }

    /// Σ host hourly cost.
    pub fn cost(&self, model: &SurfaceModel) -> f32 {
        self.clusters.iter().map(|c| model.cost(&c.config)).sum()
    }
}

/// FFD seeding + local search over placement moves.
pub struct Packer {
    model: Arc<SurfaceModel>,
    pcfg: PlacementConfig,
}

impl Packer {
    pub fn new(model: Arc<SurfaceModel>, pcfg: PlacementConfig) -> Self {
        Self { model, pcfg }
    }

    pub fn model(&self) -> &SurfaceModel {
        &self.model
    }

    /// Host feasibility for a tenant set at full health: buffered total
    /// demand within throughput, contention-inflated latency within the
    /// tightest hosted bound.
    pub fn steady_feasible(&self, cfg: &Configuration, lam: f64, lmax: f64, input: &PackInput) -> bool {
        self.feasible(cfg, lam, lmax, input, 1.0)
    }

    /// Host feasibility *during a migration/rebalance window*: capacity
    /// degraded by the transition guard must still carry the plan.
    pub fn transition_feasible(
        &self,
        cfg: &Configuration,
        lam: f64,
        lmax: f64,
        input: &PackInput,
    ) -> bool {
        self.feasible(cfg, lam, lmax, input, self.pcfg.transition_guard)
    }

    fn feasible(&self, cfg: &Configuration, lam: f64, lmax: f64, input: &PackInput, deg: f64) -> bool {
        let cap = self.model.throughput(cfg) as f64 * deg;
        if cap < lam * input.b_sla {
            return false;
        }
        let util = if cap > 0.0 { lam / cap } else { f64::INFINITY };
        let factor = contention_factor(util, self.pcfg.knee, self.pcfg.contention);
        self.model.latency(cfg) as f64 * factor <= lmax
    }

    /// Cheapest plane config hosting the set (row-major tie-break),
    /// `None` if nothing on the plane is feasible.
    pub fn cheapest_host(
        &self,
        lam: f64,
        lmax: f64,
        input: &PackInput,
        guard: bool,
    ) -> Option<Configuration> {
        let deg = if guard { self.pcfg.transition_guard } else { 1.0 };
        let mut best: Option<Configuration> = None;
        for c in self.model.plane().iter() {
            if self.feasible(&c, lam, lmax, input, deg)
                && best.map_or(true, |b| self.model.cost(&c) < self.model.cost(&b))
            {
                best = Some(c);
            }
        }
        best
    }

    /// Config for a cluster whose shape changes: the transition-guarded
    /// cheapest, falling back to the steady cheapest (violate through
    /// the window rather than forever), falling back to the
    /// violation-minimizing max-throughput config.
    pub fn sizing(&self, lam: f64, lmax: f64, input: &PackInput) -> Configuration {
        if let Some(c) = self.cheapest_host(lam, lmax, input, true) {
            return c;
        }
        if let Some(c) = self.cheapest_host(lam, lmax, input, false) {
            return c;
        }
        let mut best = Configuration::new(0, 0);
        for c in self.model.plane().iter() {
            if self.model.throughput(&c) > self.model.throughput(&best) {
                best = c;
            }
        }
        best
    }

    /// First-fit-decreasing seed: tenants by planning demand descending
    /// (id ascending on ties), each into the first cluster that stays
    /// steady-feasible with it, opening a new cluster otherwise.
    pub fn ffd(&self, input: &PackInput) -> Placement {
        let mut order: Vec<usize> = (0..input.len()).collect();
        order.sort_by(|&a, &b| {
            input.demand[b].total_cmp(&input.demand[a]).then(a.cmp(&b))
        });
        let mut clusters: Vec<PlannedCluster> = Vec::new();
        for t in order {
            let mut placed = false;
            for c in clusters.iter_mut() {
                let mut members = c.tenants.clone();
                members.push(t);
                let lam = input.lam_sum(&members);
                let lmax = input.lmax_min(&members);
                if let Some(cfg) = self.cheapest_host(lam, lmax, input, false) {
                    members.sort_unstable();
                    c.config = cfg;
                    c.tenants = members;
                    placed = true;
                    break;
                }
            }
            if !placed {
                let cfg = self.sizing(input.demand[t], input.l_max[t] as f64, input);
                clusters.push(PlannedCluster { config: cfg, tenants: vec![t] });
            }
        }
        Placement { clusters }
    }

    /// Merge clusters `i` and `j` (j's tenants migrate onto i), resizing
    /// the union under the transition guard. `None` when no feasible
    /// host exists for the union.
    pub fn merge(&self, p: &Placement, i: usize, j: usize, input: &PackInput) -> Option<Placement> {
        if i == j || i >= p.clusters.len() || j >= p.clusters.len() {
            return None;
        }
        let mut union = p.clusters[i].tenants.clone();
        union.extend_from_slice(&p.clusters[j].tenants);
        union.sort_unstable();
        let cfg =
            self.cheapest_host(input.lam_sum(&union), input.lmax_min(&union), input, true)?;
        let mut out = p.clone();
        out.clusters[i] = PlannedCluster { config: cfg, tenants: union };
        out.clusters.remove(j);
        Some(out)
    }

    /// Split cluster `i` into two halves (alternating by planning
    /// demand); the half that stays keeps the cheaper of its current
    /// config and a guarded downsize, the leaving half is sized under
    /// the transition guard. `None` for singletons or when the leaving
    /// half has no feasible host.
    pub fn split(&self, p: &Placement, i: usize, input: &PackInput) -> Option<Placement> {
        let cl = p.clusters.get(i)?;
        if cl.tenants.len() < 2 {
            return None;
        }
        let mut bydem = cl.tenants.clone();
        bydem.sort_by(|&a, &b| {
            input.demand[b].total_cmp(&input.demand[a]).then(a.cmp(&b))
        });
        let mut stay: Vec<usize> = bydem.iter().copied().step_by(2).collect();
        let mut leave: Vec<usize> = bydem.iter().copied().skip(1).step_by(2).collect();
        stay.sort_unstable();
        leave.sort_unstable();
        let stay_cfg = self.keep_or_downsize(&cl.config, &stay, input);
        let leave_cfg =
            self.cheapest_host(input.lam_sum(&leave), input.lmax_min(&leave), input, true)?;
        let mut out = p.clone();
        out.clusters[i] = PlannedCluster { config: stay_cfg, tenants: stay };
        out.clusters.push(PlannedCluster { config: leave_cfg, tenants: leave });
        Some(out)
    }

    /// For a cluster that only *loses* tenants: keeping the current
    /// config is transition-free, so take the cheaper of that (when
    /// still steady-feasible) and a guarded downsize.
    fn keep_or_downsize(
        &self,
        current: &Configuration,
        members: &[usize],
        input: &PackInput,
    ) -> Configuration {
        let lam = input.lam_sum(members);
        let lmax = input.lmax_min(members);
        let down = self.cheapest_host(lam, lmax, input, true);
        match down {
            Some(d) if self.model.cost(&d) < self.model.cost(current) => d,
            _ if self.feasible(current, lam, lmax, input, 1.0) => *current,
            Some(d) => d,
            None => self.sizing(lam, lmax, input),
        }
    }

    /// Best-improvement local search from `start` (the live placement:
    /// its configs are what is deployed). Every accepted move strictly
    /// lowers Σ host cost + `migration_penalty` × tenants moved, so the
    /// search terminates and never shuffles tenants for free.
    pub fn improve(&self, start: &Placement, input: &PackInput) -> Placement {
        let mut clusters: Vec<PlannedCluster> = start
            .clusters
            .iter()
            .filter(|c| !c.tenants.is_empty())
            .cloned()
            .collect();
        let penalty = self.pcfg.migration_penalty;

        for _ in 0..self.pcfg.search_rounds {
            let n = clusters.len();
            // (delta, placement after the move)
            let mut best: Option<(f32, Vec<PlannedCluster>)> = None;
            let mut consider = |delta: f32, next: Vec<PlannedCluster>| {
                if delta < -1e-4 && best.as_ref().map_or(true, |(d, _)| delta < *d) {
                    best = Some((delta, next));
                }
            };
            let p = Placement { clusters: clusters.clone() };

            // resize: the cheapest steady config that also survives its
            // own reconfiguration window
            for i in 0..n {
                let cl = &clusters[i];
                let lam = input.lam_sum(&cl.tenants);
                let lmax = input.lmax_min(&cl.tenants);
                if let Some(s) = self.cheapest_host(lam, lmax, input, false) {
                    if s != cl.config
                        && self.model.cost(&s) < self.model.cost(&cl.config)
                        && self.transition_feasible(&s, lam, lmax, input)
                    {
                        let mut next = clusters.clone();
                        next[i].config = s;
                        consider(self.model.cost(&s) - self.model.cost(&cl.config), next);
                    }
                }
            }

            // migrate: one tenant from i to j; the source keeps-or-
            // downsizes, the destination resizes under the guard
            for i in 0..n {
                let from_cost = self.model.cost(&clusters[i].config);
                for &t in &clusters[i].tenants {
                    let src: Vec<usize> =
                        clusters[i].tenants.iter().copied().filter(|&x| x != t).collect();
                    let (src_cfg, src_cost) = if src.is_empty() {
                        (None, 0.0)
                    } else {
                        let c = self.keep_or_downsize(&clusters[i].config, &src, input);
                        (Some(c), self.model.cost(&c))
                    };
                    for j in 0..n {
                        if j == i {
                            continue;
                        }
                        let mut dst = clusters[j].tenants.clone();
                        dst.push(t);
                        dst.sort_unstable();
                        let Some(dst_cfg) = self.cheapest_host(
                            input.lam_sum(&dst),
                            input.lmax_min(&dst),
                            input,
                            true,
                        ) else {
                            continue;
                        };
                        let dst_cost = self.model.cost(&dst_cfg);
                        let delta = (src_cost + dst_cost)
                            - (from_cost + self.model.cost(&clusters[j].config))
                            + penalty;
                        if delta < -1e-4 {
                            let mut next = clusters.clone();
                            next[j] = PlannedCluster { config: dst_cfg, tenants: dst };
                            match src_cfg {
                                Some(c) => {
                                    next[i] = PlannedCluster { config: c, tenants: src.clone() }
                                }
                                None => {
                                    next.remove(i);
                                }
                            }
                            consider(delta, next);
                        }
                    }
                }
            }

            // merge i+j / split i
            for i in 0..n {
                for j in (i + 1)..n {
                    if let Some(m) = self.merge(&p, i, j, input) {
                        let delta = m.cost(&self.model) - p.cost(&self.model)
                            + penalty * clusters[j].tenants.len() as f32;
                        consider(delta, m.clusters);
                    }
                }
                if let Some(s) = self.split(&p, i, input) {
                    let moved = clusters[i].tenants.len() / 2;
                    let delta =
                        s.cost(&self.model) - p.cost(&self.model) + penalty * moved as f32;
                    consider(delta, s.clusters);
                }
            }

            match best {
                Some((_, next)) => clusters = next,
                None => break,
            }
        }
        Placement { clusters }
    }

    /// FFD seed + local search — packing from scratch (tests, tools).
    pub fn pack(&self, input: &PackInput) -> Placement {
        let seed = self.ffd(input);
        self.improve(&seed, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::testkit::forall;

    fn fixture() -> (Packer, PackInput) {
        let cfg = ModelConfig::default_paper();
        let model = Arc::new(SurfaceModel::from_config(&cfg));
        let packer = Packer::new(model, PlacementConfig::default());
        // 12 small tenants, demand 400..800 ops/unit time
        let demand: Vec<f64> = (0..12).map(|i| 100.0 * (4 + (i % 5)) as f64).collect();
        let input = PackInput {
            demand,
            l_max: vec![cfg.sla.l_max; 12],
            b_sla: cfg.sla.b_sla as f64,
        };
        (packer, input)
    }

    #[test]
    fn ffd_hosts_every_tenant_feasibly() {
        let (packer, input) = fixture();
        let p = packer.ffd(&input);
        assert!(p.hosts_all(12));
        for c in &p.clusters {
            let lam = input.lam_sum(&c.tenants);
            let lmax = input.lmax_min(&c.tenants);
            assert!(
                packer.steady_feasible(&c.config, lam, lmax, &input),
                "FFD produced an infeasible host: {:?}",
                c
            );
        }
    }

    #[test]
    fn local_search_only_lowers_cost_and_keeps_everyone_hosted() {
        let (packer, input) = fixture();
        let seed = packer.ffd(&input);
        let packed = packer.improve(&seed, &input);
        assert!(packed.hosts_all(12));
        assert!(packed.cost(packer.model()) <= seed.cost(packer.model()) + 1e-6);
        assert!(
            (packed.total_demand(&input) - seed.total_demand(&input)).abs() < 1e-9,
            "moves must conserve demand"
        );
    }

    #[test]
    fn packing_small_tenants_beats_dedicated_on_cost() {
        let (packer, input) = fixture();
        // dedicated baseline: cheapest feasible host per tenant alone
        let dedicated: f32 = (0..12)
            .map(|t| {
                let cfg = packer.sizing(input.demand[t], input.l_max[t] as f64, &input);
                packer.model().cost(&cfg)
            })
            .sum();
        let packed = packer.pack(&input).cost(packer.model());
        assert!(
            packed < dedicated,
            "packing must be cheaper: packed {packed} vs dedicated {dedicated}"
        );
    }

    #[test]
    fn packing_is_deterministic() {
        let (packer, input) = fixture();
        assert_eq!(packer.pack(&input), packer.pack(&input));
    }

    #[test]
    fn merge_and_split_conserve_tenants_and_demand() {
        let (packer, input) = fixture();
        let p = packer.pack(&input);
        let d0 = p.total_demand(&input);
        if p.clusters.len() >= 2 {
            if let Some(m) = packer.merge(&p, 0, 1, &input) {
                assert!(m.hosts_all(12));
                assert!((m.total_demand(&input) - d0).abs() < 1e-9);
                // split the merged cluster back apart
                if let Some(s) = packer.split(&m, 0, &input) {
                    assert!(s.hosts_all(12));
                    assert!((s.total_demand(&input) - d0).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn transition_guard_is_stricter_than_steady() {
        let (packer, input) = fixture();
        forall(200, 0x6A12D, |_, rng| {
            let lam = rng.range_f64(100.0, 20_000.0);
            for c in packer.model().plane().iter().collect::<Vec<_>>() {
                if packer.transition_feasible(&c, lam, 5.0, &input) {
                    assert!(
                        packer.steady_feasible(&c, lam, 5.0, &input),
                        "guarded feasibility must imply steady feasibility"
                    );
                }
            }
        });
    }

    #[test]
    fn sizing_falls_back_to_max_throughput_when_nothing_clears() {
        let (packer, input) = fixture();
        // demand beyond every plane config: fall back, never panic
        let cfg = packer.sizing(1.0e9, 5.0, &input);
        let t_best = packer
            .model()
            .plane()
            .iter()
            .map(|c| packer.model().throughput(&c))
            .fold(0.0f32, f32::max);
        assert_eq!(packer.model().throughput(&cfg), t_best);
    }
}
