//! The placement-mode fleet driver: shared clusters serve co-located
//! tenants (fair shares + contention), the packer replans on a cadence,
//! and every placement action — reactive host resizes and the packer's
//! rebalance bundles — walks through the fleet's [`BudgetArbiter`] as a
//! budget-consuming proposal before it actuates. Consolidation bundles
//! that *save* money admit as shrinks; emergency upsizes compete for
//! budget like any SLA repair, with the arbiter's rescue machinery fed
//! by per-cluster denial streaks.
//!
//! Tick semantics are serve-then-move, exactly like the fleet and the
//! Phase-1 simulator: the placement that served tick *t* is what tick
//! *t* pays for; admitted actions actuate for *t + 1*, and the
//! degradation windows they open (migrations in flight, hosts
//! restarting) cover the following ticks until their calendar events
//! fire.
//!
//! Planning demand is the peak over the next
//! [`PlacementConfig::plan_horizon`] trace points — seasonal one-period
//! lookahead (exact for the fleet's cyclic traces, the same premise as
//! `ForecastKind::Seasonal`), so hosts are sized for what the window
//! will actually see, not for the demand that just ended.

use std::sync::Arc;

use crate::cluster::{rebalance, ClusterParams, Event};
use crate::config::ModelConfig;
use crate::fleet::{BudgetArbiter, Candidate, PriorityClass, Proposal, TenantSpec};
use crate::metrics::{Hll, Recorder, StepRecord, Summary};
use crate::plane::Configuration;
use crate::scenario::ShardModel;
use crate::sla::Violation;
use crate::surfaces::{queueing, SurfaceModel};
use crate::util::money;
use crate::workload::{Trace, TraceBuilder, WorkloadPoint};

use super::interference::{contention_factor, fair_shares};
use super::migration::{ClusterRef, MigrationPlanner, PlannedMigration, RebalanceBundle};
use super::packer::{PackInput, Packer, Placement, PlannedCluster};
use super::{class_weight, PlacementConfig, SharedCluster};

/// One placement tick's fleet-level outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlacementTick {
    pub step: usize,
    /// Σ hourly cost of the host configurations that served this tick.
    pub spend: f32,
    /// Live shared clusters at serve time.
    pub clusters: usize,
    /// Clusters that served inside an open degradation window.
    pub degraded_clusters: usize,
    /// Tenant SLA violations this tick.
    pub violations: usize,
    /// Tenant migrations actuated this tick.
    pub migrations: usize,
    pub admitted_moves: usize,
    pub denied_moves: usize,
}

/// End-of-run rollup for one tenant in placement mode.
#[derive(Debug, Clone)]
pub struct TenantPlacementReport {
    pub name: String,
    pub class: PriorityClass,
    /// Final host cluster id.
    pub host: usize,
    pub summary: Summary,
}

/// The placement run's end-of-run report.
#[derive(Debug, Clone)]
pub struct PlacementReport {
    pub budget: f32,
    pub peak_spend: f32,
    /// Σ per-tick spend (hourly cost × ticks served).
    pub total_cost: f64,
    pub final_clusters: usize,
    pub migrations: usize,
    pub tenants: Vec<TenantPlacementReport>,
}

impl PlacementReport {
    pub fn within_budget(&self) -> bool {
        self.peak_spend <= self.budget + crate::fleet::BUDGET_EPS
    }

    /// Human-readable table: totals, then one row per tenant.
    pub fn table(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "placement: budget {:.2}/h  peak spend {:.2}/h ({})  total cost {:.1}  clusters {}  migrations {}",
            self.budget,
            self.peak_spend,
            if self.within_budget() { "within budget" } else { "OVER BUDGET" },
            self.total_cost,
            self.final_clusters,
            self.migrations,
        );
        let _ = writeln!(
            out,
            "\n{:<12} {:<8} {:>5} {:>10} {:>10} {:>9} {:>6}",
            "tenant", "class", "host", "avg lat", "avg thpt", "avg cost", "viol."
        );
        for t in &self.tenants {
            let _ = writeln!(
                out,
                "{:<12} {:<8} {:>5} {:>10.3} {:>10.1} {:>9.3} {:>6}",
                t.name,
                t.class.label(),
                t.host,
                t.summary.avg_latency,
                t.summary.avg_throughput,
                t.summary.avg_cost,
                t.summary.violations,
            );
        }
        out
    }
}

/// A complete placement run: per-tick timeline plus the final report.
#[derive(Debug, Clone)]
pub struct PlacementResult {
    pub ticks: Vec<PlacementTick>,
    pub report: PlacementReport,
}

impl PlacementResult {
    /// Σ per-tick spend — the fleet cost the run paid (the single
    /// source is the report; ticks carry the same spends).
    pub fn total_cost(&self) -> f64 {
        self.report.total_cost
    }

    /// Σ tenant SLA violations across ticks (independent of recording).
    pub fn total_violations(&self) -> usize {
        self.ticks.iter().map(|t| t.violations).sum()
    }

    pub fn total_migrations(&self) -> usize {
        self.ticks.iter().map(|t| t.migrations).sum()
    }

    pub fn peak_spend(&self) -> f32 {
        self.ticks.iter().map(|t| t.spend).fold(0.0, f32::max)
    }

    pub fn within_budget(&self, budget: f32) -> bool {
        self.peak_spend() <= budget + crate::fleet::BUDGET_EPS
    }

    /// Any tick served inside a degradation window (migrations were
    /// actually priced through the calendar, not just bookkept).
    pub fn any_degraded_tick(&self) -> bool {
        self.ticks.iter().any(|t| t.degraded_clusters > 0)
    }
}

/// A planned action for one tick, aligned 1:1 with the proposal batch
/// handed to the arbiter.
enum PlannedAction {
    /// Cluster (by index) requests nothing.
    Hold(usize),
    /// Cluster (by index) resizes its host along a *ranked* candidate
    /// list (preferred target first, then — for emergencies whose
    /// target is several plane steps away — a one-step stepping stone,
    /// so a tight budget degrades the repair instead of denying it);
    /// `emergency` marks SLA repairs (current config infeasible or
    /// tenants violating).
    Resize { cluster: usize, candidates: Vec<Candidate>, emergency: bool },
    /// The packer's full rebalance, all-or-nothing.
    Bundle(RebalanceBundle),
}

/// The configuration one plane step from `from` toward `to` on each
/// axis (equal to `to` when already adjacent).
fn step_toward(from: &Configuration, to: &Configuration) -> Configuration {
    let step = |a: usize, b: usize| match b.cmp(&a) {
        std::cmp::Ordering::Greater => a + 1,
        std::cmp::Ordering::Less => a - 1,
        std::cmp::Ordering::Equal => a,
    };
    Configuration::new(step(from.h_idx, to.h_idx), step(from.v_idx, to.v_idx))
}

/// Drives shared clusters, the packer, and the budget arbiter over the
/// tenants' traces.
pub struct PlacementSim {
    model: Arc<SurfaceModel>,
    specs: Vec<TenantSpec>,
    weights: Vec<f64>,
    recorders: Vec<Recorder>,
    recording: bool,
    last_violation: Vec<bool>,
    clusters: Vec<SharedCluster>,
    next_cluster_id: usize,
    arbiter: BudgetArbiter,
    params: ClusterParams,
    pcfg: PlacementConfig,
    packer: Packer,
    planner: MigrationPlanner,
    /// Partition-aware migration pricing: when set, a move ships only
    /// the shards whose hyperedge no destination resident already
    /// carries ([`ShardModel::moved_gb`]). `None` (the default) keeps
    /// the flat `tenant_gb` baseline and the pinned PR-4 numbers.
    shards: Option<ShardModel>,
    /// Σ data actually shipped by actuated migrations (GB).
    moved_gb_total: f64,
    packed: bool,
    b_sla: f64,
    step: usize,
    /// Distinct host-cluster ids any placement action (resize,
    /// migration, create) ever touched — observation only, exported
    /// via [`Self::export_metrics`].
    hosts_hll: Hll,
}

impl PlacementSim {
    /// Build a placement-mode fleet. `packed` enables the packer's
    /// replan cadence; `false` keeps the one-cluster-per-tenant
    /// baseline (reactive resizes only) for A/B comparisons.
    pub fn new(
        cfg: &ModelConfig,
        specs: Vec<TenantSpec>,
        arbiter: BudgetArbiter,
        params: ClusterParams,
        pcfg: PlacementConfig,
        packed: bool,
    ) -> Self {
        assert!(!specs.is_empty(), "placement needs at least one tenant");
        let model = Arc::new(SurfaceModel::from_config(cfg));
        for s in &specs {
            assert!(model.plane().contains(&s.start), "tenant start outside plane");
            assert!(!s.trace.is_empty(), "tenant {} has an empty trace", s.name);
        }
        // the transition guard must mirror the degradation the windows
        // will actually apply — derive it from the live ClusterParams
        // so non-default physics cannot diverge from the packer's
        // window-feasibility checks
        let mut pcfg = pcfg;
        pcfg.transition_guard = params.rebalance_degradation.min(params.restart_degradation);
        let clusters: Vec<SharedCluster> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| SharedCluster::new(i, s.start, vec![i]))
            .collect();
        let weights: Vec<f64> = specs.iter().map(|s| class_weight(s.class)).collect();
        let b_sla = specs.iter().map(|s| s.sla.b_sla as f64).fold(1.0, f64::max);
        let n = specs.len();
        Self {
            packer: Packer::new(Arc::clone(&model), pcfg),
            planner: MigrationPlanner::new(pcfg.tenant_gb),
            shards: None,
            moved_gb_total: 0.0,
            model,
            specs,
            weights,
            recorders: (0..n).map(|_| Recorder::new()).collect(),
            recording: true,
            last_violation: vec![false; n],
            next_cluster_id: n,
            clusters,
            arbiter,
            params,
            pcfg,
            packed,
            b_sla,
            step: 0,
            hosts_hll: Hll::default(),
        }
    }

    /// Packed placement under a budget (the tentpole mode).
    pub fn packed(
        cfg: &ModelConfig,
        specs: Vec<TenantSpec>,
        budget: f32,
        fairness_k: usize,
        pcfg: PlacementConfig,
    ) -> Self {
        Self::new(
            cfg,
            specs,
            BudgetArbiter::new(budget, fairness_k),
            ClusterParams::default(),
            pcfg,
            true,
        )
    }

    /// One-cluster-per-tenant baseline under the same budget and
    /// reactive sizing (the A/B control).
    pub fn dedicated(
        cfg: &ModelConfig,
        specs: Vec<TenantSpec>,
        budget: f32,
        fairness_k: usize,
        pcfg: PlacementConfig,
    ) -> Self {
        Self::new(
            cfg,
            specs,
            BudgetArbiter::new(budget, fairness_k),
            ClusterParams::default(),
            pcfg,
            false,
        )
    }

    pub fn clusters(&self) -> &[SharedCluster] {
        &self.clusters
    }

    /// Opt in to partition-aware migration pricing: each actuated move
    /// ships only the shards whose hyperedge no destination resident
    /// already carries, so co-access overlap discounts the window. The
    /// model must cover every tenant.
    pub fn set_shard_model(&mut self, shards: ShardModel) {
        assert!(
            shards.n_tenants() >= self.specs.len(),
            "shard model must cover every tenant"
        );
        self.shards = Some(shards);
    }

    /// Σ data shipped by actuated migrations so far (GB). Under the
    /// flat baseline this is exactly `migrations × tenant_gb`; with a
    /// shard model attached it is the partition-aware (≤) volume.
    pub fn total_moved_gb(&self) -> f64 {
        self.moved_gb_total
    }

    pub fn arbiter(&self) -> &BudgetArbiter {
        &self.arbiter
    }

    /// Current fleet spend (Σ host hourly costs). Accumulated in f64
    /// and narrowed once at the edge, like all money in this crate.
    pub fn spend(&self) -> f32 {
        money::narrow(self.clusters.iter().map(|c| self.model.cost(&c.config()) as f64).sum())
    }

    /// Register placement-mode gauges into the pull-based export
    /// registry: live host count, the distinct-hosts-touched sketch
    /// estimate, and the current fleet spend.
    pub fn export_metrics(&self, reg: &mut crate::metrics::MetricsRegistry) {
        use crate::metrics::names;
        reg.set(names::PLACEMENT_HOSTS, &[], self.clusters.len() as f64);
        reg.set(names::PLACEMENT_HOSTS_TOUCHED_ESTIMATE, &[], self.hosts_hll.estimate());
        reg.set(names::PLACEMENT_SPEND_HOURLY, &[], self.spend() as f64);
        reg.set(names::PLACEMENT_MOVED_GB, &[], self.moved_gb_total);
    }

    /// Live host cluster id of a tenant, if hosted.
    pub fn host_of(&self, tenant: usize) -> Option<usize> {
        self.clusters
            .iter()
            .find(|c| c.tenants().binary_search(&tenant).is_ok())
            .map(|c| c.id())
    }

    /// Every tenant hosted by exactly one live cluster (the same
    /// invariant [`Placement::hosts_all`] checks for planned
    /// placements).
    pub fn assignment_valid(&self) -> bool {
        self.live_placement().hosts_all(self.specs.len())
    }

    /// Whether a tenant's last served tick violated its SLA.
    pub fn tenant_violating(&self, tenant: usize) -> bool {
        self.last_violation.get(tenant).copied().unwrap_or(false)
    }

    /// Disable per-step recording (benchmark mode: bounded memory).
    pub fn set_recording(&mut self, on: bool) {
        self.recording = on;
    }

    /// Longest tenant trace (the natural run length).
    pub fn longest_trace(&self) -> usize {
        self.specs.iter().map(|s| s.trace.len()).max().unwrap_or(0)
    }

    fn demand_at(&self, tenant: usize, t: usize) -> f64 {
        let tr = &self.specs[tenant].trace;
        tr.points[t % tr.len()].lambda_req as f64
    }

    /// Planning inputs for a tick: peak demand over the lookahead
    /// horizon per tenant.
    fn plan_input(&self, t: usize) -> PackInput {
        let h = self.pcfg.plan_horizon.max(1);
        let demand: Vec<f64> = (0..self.specs.len())
            .map(|i| (1..=h).map(|k| self.demand_at(i, t + k)).fold(0.0f64, f64::max))
            .collect();
        PackInput {
            demand,
            l_max: self.specs.iter().map(|s| s.sla.l_max).collect(),
            b_sla: self.b_sla,
        }
    }

    fn cluster_index(&self, id: usize) -> Option<usize> {
        self.clusters.iter().position(|c| c.id() == id)
    }

    fn live_placement(&self) -> Placement {
        Placement {
            clusters: self
                .clusters
                .iter()
                .map(|c| PlannedCluster { config: c.config(), tenants: c.tenants().to_vec() })
                .collect(),
        }
    }

    /// Reactive per-cluster sizing as a *ranked candidate list*: an
    /// economic downsize that survives its own window, or an emergency
    /// repair when the current config no longer clears the planning
    /// demand — followed, for multi-step emergency jumps, by a one-step
    /// stepping stone toward the target so the arbiter can degrade the
    /// repair under a tight budget instead of flat-denying it.
    fn resize_candidates(&self, ci: usize, input: &PackInput) -> Option<(Vec<Candidate>, bool)> {
        let cl = &self.clusters[ci];
        let members = cl.tenants();
        if members.is_empty() {
            return None;
        }
        let lam = input.lam_sum(members);
        let lmax = input.lmax_min(members);
        let current = cl.config();
        let cost_from = self.model.cost(&current);
        let priced = |to: Configuration| {
            let cost_to = self.model.cost(&to);
            Candidate::priced(to, cost_to, (cost_from - cost_to).max(0.0))
        };
        let current_ok = self.packer.steady_feasible(&current, lam, lmax, input);
        if let Some(s) = self.packer.cheapest_host(lam, lmax, input, false) {
            if s != current
                && self.model.cost(&s) < cost_from
                && self.packer.transition_feasible(&s, lam, lmax, input)
            {
                // cheaper and window-safe: take it (also repairs if the
                // current config was infeasible); already the cheapest,
                // so no alternative ranks behind it
                return Some((vec![priced(s)], !current_ok || cl.violating));
            }
        }
        if !current_ok {
            let z = self.packer.sizing(lam, lmax, input);
            if z != current {
                let mut candidates = vec![priced(z)];
                let stone = step_toward(&current, &z);
                if stone != z && stone != current {
                    candidates.push(priced(stone));
                }
                return Some((candidates, true));
            }
        }
        None
    }

    /// Diff the live placement against a packer target: migrations,
    /// resizes, creates, and the hourly-cost edge, priced as one
    /// all-or-nothing bundle.
    fn diff(&self, target: &Placement) -> RebalanceBundle {
        let n_live = self.clusters.len();
        // tenant -> live host id
        let mut host = vec![usize::MAX; self.specs.len()];
        for cl in &self.clusters {
            for &t in cl.tenants() {
                host[t] = cl.id();
            }
        }
        // match target clusters to live clusters by max member overlap
        // (first maximum wins — deterministic)
        let mut used = vec![false; n_live];
        let mut matched: Vec<Option<usize>> = Vec::with_capacity(target.clusters.len());
        for tc in &target.clusters {
            let mut best: Option<usize> = None;
            let mut best_ov = 0usize;
            for (ci, cl) in self.clusters.iter().enumerate() {
                if used[ci] {
                    continue;
                }
                let ov = tc
                    .tenants
                    .iter()
                    .filter(|&t| cl.tenants().binary_search(t).is_ok())
                    .count();
                if ov > best_ov {
                    best_ov = ov;
                    best = Some(ci);
                }
            }
            if let Some(ci) = best {
                used[ci] = true;
            }
            matched.push(best);
        }

        let mut migrations: Vec<PlannedMigration> = Vec::new();
        let mut resizes: Vec<(usize, Configuration)> = Vec::new();
        let mut creates: Vec<(Configuration, Vec<usize>)> = Vec::new();
        let mut affected = vec![false; n_live];
        let mut target_cfg: Vec<Option<Configuration>> = vec![None; n_live];

        for (ti, tc) in target.clusters.iter().enumerate() {
            match matched[ti] {
                Some(ci) => {
                    target_cfg[ci] = Some(tc.config);
                    let cl = &self.clusters[ci];
                    if tc.config != cl.config() {
                        resizes.push((cl.id(), tc.config));
                        affected[ci] = true;
                    }
                    for &x in &tc.tenants {
                        if cl.tenants().binary_search(&x).is_err() {
                            migrations.push(PlannedMigration {
                                tenant: x,
                                from: host[x],
                                to: ClusterRef::Existing(cl.id()),
                            });
                            affected[ci] = true;
                            if let Some(si) = self.cluster_index(host[x]) {
                                affected[si] = true;
                            }
                        }
                    }
                }
                None => {
                    let k = creates.len();
                    creates.push((tc.config, tc.tenants.clone()));
                    for &x in &tc.tenants {
                        migrations.push(PlannedMigration {
                            tenant: x,
                            from: host[x],
                            to: ClusterRef::New(k),
                        });
                        if let Some(si) = self.cluster_index(host[x]) {
                            affected[si] = true;
                        }
                    }
                }
            }
        }
        // live clusters no target cluster matched lose every tenant
        for ci in 0..n_live {
            if !used[ci] {
                affected[ci] = true;
            }
        }

        let mut cost_from = 0.0f64;
        let mut cost_to = 0.0f64;
        for ci in 0..n_live {
            if !affected[ci] {
                continue;
            }
            cost_from += self.model.cost(&self.clusters[ci].config()) as f64;
            if used[ci] {
                let cfg = target_cfg[ci].unwrap_or_else(|| self.clusters[ci].config());
                cost_to += self.model.cost(&cfg) as f64;
            }
            // unmatched (retiring) clusters contribute 0 to cost_to
        }
        for (cfg, _) in &creates {
            cost_to += self.model.cost(cfg) as f64;
        }
        RebalanceBundle {
            migrations,
            resizes,
            creates,
            cost_from: money::narrow(cost_from),
            cost_to: money::narrow(cost_to),
        }
    }

    /// Live cluster indices a bundle touches.
    fn bundle_affected(&self, b: &RebalanceBundle) -> Vec<bool> {
        let mut affected = vec![false; self.clusters.len()];
        for (id, _) in &b.resizes {
            if let Some(ci) = self.cluster_index(*id) {
                affected[ci] = true;
            }
        }
        for m in &b.migrations {
            if let Some(ci) = self.cluster_index(m.from) {
                affected[ci] = true;
            }
            if let ClusterRef::Existing(id) = m.to {
                if let Some(ci) = self.cluster_index(id) {
                    affected[ci] = true;
                }
            }
        }
        affected
    }

    fn highest_class(&self, tenants: &[usize]) -> PriorityClass {
        tenants
            .iter()
            .map(|&t| self.specs[t].class)
            .max()
            .unwrap_or(PriorityClass::Bronze)
    }

    fn proposal_for(&self, slot: usize, action: &PlannedAction) -> Proposal {
        match action {
            PlannedAction::Hold(ci) => {
                let cl = &self.clusters[*ci];
                Proposal {
                    tenant: slot,
                    class: self.highest_class(cl.tenants()),
                    from: cl.config(),
                    cost_from: self.model.cost(&cl.config()),
                    current_score: 0.0,
                    emergency: false,
                    sla_violating: cl.violating,
                    denial_streak: cl.denial_streak,
                    fallback: false,
                    candidates: Vec::new(),
                    sheds: Vec::new(),
                }
            }
            PlannedAction::Resize { cluster, candidates, emergency } => {
                let cl = &self.clusters[*cluster];
                Proposal {
                    tenant: slot,
                    class: self.highest_class(cl.tenants()),
                    from: cl.config(),
                    cost_from: self.model.cost(&cl.config()),
                    current_score: 0.0,
                    emergency: *emergency,
                    sla_violating: cl.violating,
                    denial_streak: cl.denial_streak,
                    fallback: false,
                    candidates: candidates.clone(),
                    sheds: Vec::new(),
                }
            }
            PlannedAction::Bundle(b) => {
                let affected = self.bundle_affected(b);
                let mut class = PriorityClass::Bronze;
                let mut violating = false;
                let mut streak = 0usize;
                // `from` is the first affected cluster's config (the
                // arbiter only reads costs, but reporting should point
                // at a cluster the bundle actually touches)
                let mut from: Option<Configuration> = None;
                for (ci, cl) in self.clusters.iter().enumerate() {
                    if !affected[ci] {
                        continue;
                    }
                    class = class.max(self.highest_class(cl.tenants()));
                    violating |= cl.violating;
                    streak = streak.max(cl.denial_streak);
                    if from.is_none() {
                        from = Some(cl.config());
                    }
                }
                let to = b
                    .resizes
                    .first()
                    .map(|(_, cfg)| *cfg)
                    .or_else(|| b.creates.first().map(|(cfg, _)| *cfg))
                    .or(from)
                    .unwrap_or_else(|| Configuration::new(0, 0));
                Proposal {
                    tenant: slot,
                    class,
                    from: from.unwrap_or_else(|| Configuration::new(0, 0)),
                    cost_from: b.cost_from,
                    current_score: 0.0,
                    emergency: violating,
                    sla_violating: violating,
                    denial_streak: streak,
                    fallback: false,
                    candidates: vec![Candidate::priced(
                        to,
                        b.cost_to,
                        (b.cost_from - b.cost_to).max(0.0),
                    )],
                    sheds: Vec::new(),
                }
            }
        }
    }

    /// Apply a host reconfiguration, opening its degradation window on
    /// the cluster calendar (active from the next tick, exactly like
    /// the substrate engines' serve-then-move accounting).
    fn actuate_resize(&mut self, ci: usize, next: Configuration, time: f64) {
        let from = self.clusters[ci].config();
        if next == from {
            return;
        }
        let plan =
            rebalance::plan_reconfiguration(self.model.plane(), &from, &next, &self.params);
        let end = time + self.params.interval + plan.duration;
        self.hosts_hll.insert_u64(self.clusters[ci].id() as u64);
        let cl = &mut self.clusters[ci];
        cl.set_config(next);
        if plan.duration > 0.0 {
            let ev = if plan.moved_shards > 0 { Event::RebalanceEnd } else { Event::RestartEnd };
            cl.open_window(end, plan.degradation, ev);
        }
    }

    /// Actuate an admitted rebalance bundle: resizes first, then new
    /// clusters, then tenant migrations — each migration opening a
    /// priced window on its destination's calendar. Returns the number
    /// of migrations actuated.
    fn actuate_bundle(&mut self, b: &RebalanceBundle, time: f64) -> usize {
        for (id, cfg) in &b.resizes {
            if let Some(ci) = self.cluster_index(*id) {
                self.actuate_resize(ci, *cfg, time);
            }
        }
        let mut new_ids = Vec::with_capacity(b.creates.len());
        for (cfg, _) in &b.creates {
            let id = self.next_cluster_id;
            self.next_cluster_id += 1;
            self.clusters.push(SharedCluster::new(id, *cfg, Vec::new()));
            self.hosts_hll.insert_u64(id as u64);
            new_ids.push(id);
        }
        let t_act = time + self.params.interval;
        let mut moved = 0usize;
        for m in &b.migrations {
            let dest_id = match m.to {
                ClusterRef::Existing(id) => id,
                ClusterRef::New(k) => new_ids[k],
            };
            // resolve the destination BEFORE touching the source, so an
            // unresolvable migration leaves the tenant hosted where it
            // was instead of silently dropping it
            let Some(di) = self.cluster_index(dest_id) else {
                debug_assert!(false, "bundle migration to unknown cluster {dest_id}");
                continue;
            };
            if let Some(si) = self.cluster_index(m.from) {
                self.clusters[si].remove_tenant(m.tenant);
                self.hosts_hll.insert_u64(m.from as u64);
            }
            self.hosts_hll.insert_u64(dest_id as u64);
            let dest_cfg = self.clusters[di].config();
            // partition-aware: only the shards no destination resident
            // shares a hyperedge with actually ship (residents read
            // BEFORE the tenant lands)
            let gb = match &self.shards {
                Some(sm) => sm.moved_gb(m.tenant, self.clusters[di].tenants()),
                None => self.planner.tenant_gb,
            };
            let w = self.planner.price_gb(self.model.plane(), &dest_cfg, &self.params, gb);
            self.moved_gb_total += w.data_gb;
            self.clusters[di].add_tenant(m.tenant);
            if w.duration > 0.0 {
                self.clusters[di].open_window(
                    t_act + w.duration,
                    w.degradation,
                    Event::MigrationEnd,
                );
            }
            moved += 1;
        }
        self.clusters.retain(|c| !c.is_empty());
        moved
    }

    /// One placement tick: drain calendars, serve every cluster (fair
    /// shares + contention), plan, admit through the arbiter, actuate.
    pub fn tick(&mut self) -> PlacementTick {
        let t = self.step;
        let interval = self.params.interval;
        let time = t as f64 * interval;
        let u_max = self.model.constants().u_max;

        // ---- serve ----
        let mut spend = 0.0f64;
        let mut violations = 0usize;
        let mut degraded_clusters = 0usize;
        for ci in 0..self.clusters.len() {
            self.clusters[ci].drain_due(time);
            let deg = self.clusters[ci].degradation();
            let cfg = self.clusters[ci].config();
            let members: Vec<usize> = self.clusters[ci].tenants().to_vec();
            if deg < 1.0 {
                degraded_clusters += 1;
            }
            let host_cost = self.model.cost(&cfg);
            spend += host_cost as f64;
            if members.is_empty() {
                continue;
            }
            let demands: Vec<f64> = members.iter().map(|&i| self.demand_at(i, t)).collect();
            let weights: Vec<f64> = members.iter().map(|&i| self.weights[i]).collect();
            let offered: Vec<f64> = demands.iter().map(|d| d * interval).collect();
            let lam_total: f64 = demands.iter().sum();
            let cap = self.model.throughput(&cfg) as f64 * deg;
            let alloc = fair_shares(cap * interval, &offered, &weights);
            let util = if cap > 0.0 { lam_total / cap } else { f64::INFINITY };
            let factor = contention_factor(util, self.pcfg.knee, self.pcfg.contention);
            let lat_raw = self.model.latency(&cfg) as f64 * factor;
            let lat_eff = queueing::effective_latency(
                self.model.latency(&cfg),
                cap as f32,
                lam_total as f32,
                u_max,
            ) as f64
                * factor;
            // the reported objective uses the SAME latency the tenants
            // actually saw (degraded capacity + contention), so
            // packed-vs-dedicated objective comparisons are not biased
            // on exactly the ticks where packing hurts
            let host_obj = {
                let s = self.model.constants();
                let p = self.model.evaluate(&cfg, lam_total as f32);
                s.alpha * lat_eff as f32 + s.beta * p.cost + s.gamma * p.coordination
                    - s.delta * p.throughput
            };
            let mut any_viol = false;
            for (k, &i) in members.iter().enumerate() {
                // cost/objective are billed by *usage* (demand share),
                // not by fair-share weight: class weights decide who
                // keeps throughput under shortage, not who pays more
                let share = if lam_total > 0.0 {
                    (demands[k] / lam_total) as f32
                } else {
                    1.0 / members.len() as f32
                };
                let viol = Violation {
                    latency: lat_raw > self.specs[i].sla.l_max as f64,
                    throughput: alloc[k] < offered[k] - 1e-9,
                };
                self.last_violation[i] = viol.any();
                if viol.any() {
                    violations += 1;
                    any_viol = true;
                }
                if self.recording {
                    self.recorders[i].push(StepRecord {
                        step: t,
                        config: cfg,
                        lambda_req: demands[k] as f32,
                        latency: lat_eff as f32,
                        latency_raw: lat_raw as f32,
                        throughput: (alloc[k] / interval) as f32,
                        cost: host_cost * share,
                        objective: host_obj * share,
                        violation: viol,
                    });
                }
            }
            self.clusters[ci].violating = any_viol;
        }
        let live_clusters = self.clusters.len();

        // ---- plan ----
        let input = self.plan_input(t);
        let mut actions: Vec<PlannedAction> = Vec::new();
        let bundle = if self.packed && t % self.pcfg.replan_every.max(1) == 0 {
            let target = self.packer.improve(&self.live_placement(), &input);
            let b = self.diff(&target);
            if b.is_empty() {
                None
            } else {
                Some(b)
            }
        } else {
            None
        };
        let affected = match &bundle {
            Some(b) => self.bundle_affected(b),
            None => vec![false; self.clusters.len()],
        };
        for ci in 0..self.clusters.len() {
            if affected[ci] {
                continue; // the bundle owns this cluster's tick
            }
            match self.resize_candidates(ci, &input) {
                Some((candidates, emergency)) => {
                    actions.push(PlannedAction::Resize { cluster: ci, candidates, emergency })
                }
                None => actions.push(PlannedAction::Hold(ci)),
            }
        }
        // the bundle goes LAST: Hold/Resize actions address clusters by
        // index, and only actuate_bundle may retire clusters (retain),
        // so index-addressed actions must all actuate before it
        if let Some(b) = bundle {
            actions.push(PlannedAction::Bundle(b));
        }

        // ---- admit + actuate ----
        let proposals: Vec<Proposal> = actions
            .iter()
            .enumerate()
            .map(|(slot, a)| self.proposal_for(slot, a))
            .collect();
        let adm = self.arbiter.admit(&proposals);
        let mut migrations = 0usize;
        let mut admitted_moves = 0usize;
        let mut denied_moves = 0usize;
        for (slot, action) in actions.iter().enumerate() {
            let v = adm.verdicts[slot];
            match action {
                PlannedAction::Hold(ci) => {
                    self.clusters[*ci].denial_streak = 0;
                }
                PlannedAction::Resize { cluster, candidates, .. } => {
                    if v.admitted() {
                        // the arbiter's walk picks which ranked candidate
                        // actuates (0 = preferred target, 1 = the
                        // degradation stepping stone)
                        let ci = adm.chosen[slot].expect("admitted resize has a choice");
                        self.actuate_resize(*cluster, candidates[ci].to, time);
                        self.clusters[*cluster].denial_streak = 0;
                        admitted_moves += 1;
                    } else {
                        denied_moves += 1;
                        let cl = &mut self.clusters[*cluster];
                        if cl.violating {
                            cl.denial_streak += 1;
                        } else {
                            cl.denial_streak = 0;
                        }
                    }
                }
                PlannedAction::Bundle(b) => {
                    if v.admitted() {
                        migrations += self.actuate_bundle(b, time);
                        admitted_moves += 1;
                    } else {
                        // today's packer only emits cost-decreasing
                        // bundles (always admitted as shrinks); this
                        // branch guards future packers that propose
                        // paid rebalances under a tight budget
                        denied_moves += 1;
                        let affected = self.bundle_affected(b);
                        for (ci, touched) in affected.iter().enumerate() {
                            if *touched && self.clusters[ci].violating {
                                self.clusters[ci].denial_streak += 1;
                            }
                        }
                    }
                }
            }
        }

        self.step += 1;
        PlacementTick {
            step: t,
            spend: money::narrow(spend),
            clusters: live_clusters,
            degraded_clusters,
            violations,
            migrations,
            admitted_moves,
            denied_moves,
        }
    }

    /// Run `steps` ticks (traces repeat cyclically) and aggregate.
    pub fn run(&mut self, steps: usize) -> PlacementResult {
        let ticks: Vec<PlacementTick> = (0..steps).map(|_| self.tick()).collect();
        let tenants: Vec<TenantPlacementReport> = self
            .specs
            .iter()
            .enumerate()
            .map(|(i, s)| TenantPlacementReport {
                name: s.name.clone(),
                class: s.class,
                host: self.host_of(i).unwrap_or(usize::MAX),
                summary: self.recorders[i].summary(),
            })
            .collect();
        let report = PlacementReport {
            budget: self.arbiter.budget,
            peak_spend: ticks.iter().map(|t| t.spend).fold(0.0, f32::max),
            total_cost: ticks.iter().map(|t| t.spend as f64).sum(),
            final_clusters: self.clusters.len(),
            migrations: ticks.iter().map(|t| t.migrations).sum(),
            tenants,
        };
        PlacementResult { ticks, report }
    }
}

/// The *pinned* co-location scenario: `n` small tenants with constant
/// demands cycling 400..800 ops/unit time (intensities `4 + i % 5`),
/// classes cycling Gold/Silver/Bronze. One definition shared by the
/// acceptance test, the sim unit tests, and the CI-smoked example, so
/// "the pinned scenario" means exactly one thing everywhere.
pub fn constant_tenant_specs(cfg: &ModelConfig, n: usize) -> Vec<TenantSpec> {
    let b = TraceBuilder::from_config(cfg);
    (0..n)
        .map(|i| {
            let class = match i % 3 {
                0 => PriorityClass::Gold,
                1 => PriorityClass::Silver,
                _ => PriorityClass::Bronze,
            };
            TenantSpec::from_config(
                cfg,
                format!("t{i:02}"),
                class,
                b.constant((4 + (i % 5)) as f32, 1),
            )
        })
        .collect()
}

/// The co-location scenario family: `n` small tenants, each the paper
/// timeline scaled by `scale` and phase-shifted so peaks stagger,
/// classes cycling Gold/Silver/Bronze — shared by the CLI, the example,
/// the bench, and the tests.
pub fn small_tenant_specs(cfg: &ModelConfig, n: usize, scale: f32) -> Vec<TenantSpec> {
    let base = TraceBuilder::paper(cfg);
    (0..n)
        .map(|i| {
            let class = match i % 3 {
                0 => PriorityClass::Gold,
                1 => PriorityClass::Silver,
                _ => PriorityClass::Bronze,
            };
            let shifted = base.shifted(i * base.len() / n.max(1));
            let points: Vec<WorkloadPoint> = shifted
                .points
                .iter()
                .map(|p| WorkloadPoint {
                    lambda_req: p.lambda_req * scale,
                    lambda_w: p.lambda_w * scale,
                })
                .collect();
            let trace = Trace { name: format!("{}x{scale}", shifted.name), points };
            TenantSpec::from_config(cfg, format!("small-{i:02}"), class, trace)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn constant_specs(cfg: &ModelConfig, n: usize) -> Vec<TenantSpec> {
        constant_tenant_specs(cfg, n)
    }

    #[test]
    fn starts_dedicated_and_serves() {
        let cfg = ModelConfig::default_paper();
        let mut sim = PlacementSim::dedicated(
            &cfg,
            constant_specs(&cfg, 4),
            1.0e6,
            3,
            PlacementConfig::default(),
        );
        assert_eq!(sim.clusters().len(), 4);
        assert!(sim.assignment_valid());
        let tick = sim.tick();
        assert_eq!(tick.clusters, 4);
        assert_eq!(tick.migrations, 0);
        assert!(sim.assignment_valid());
    }

    #[test]
    fn packed_mode_consolidates_small_tenants() {
        let cfg = ModelConfig::default_paper();
        let mut sim = PlacementSim::packed(
            &cfg,
            constant_specs(&cfg, 12),
            1.0e6,
            3,
            PlacementConfig::default(),
        );
        let res = sim.run(20);
        assert!(sim.assignment_valid());
        assert!(
            sim.clusters().len() < 12,
            "packing never consolidated: {} clusters",
            sim.clusters().len()
        );
        assert!(res.total_migrations() > 0);
        assert!(res.any_degraded_tick(), "migrations must open priced windows");
    }

    #[test]
    fn dedicated_mode_never_migrates() {
        let cfg = ModelConfig::default_paper();
        let mut sim = PlacementSim::dedicated(
            &cfg,
            constant_specs(&cfg, 6),
            1.0e6,
            3,
            PlacementConfig::default(),
        );
        let res = sim.run(30);
        assert_eq!(res.total_migrations(), 0);
        assert_eq!(sim.clusters().len(), 6);
    }

    #[test]
    fn deterministic_runs() {
        let cfg = ModelConfig::default_paper();
        let build = || {
            PlacementSim::packed(
                &cfg,
                small_tenant_specs(&cfg, 8, 0.1),
                1.0e6,
                3,
                PlacementConfig::default(),
            )
        };
        let a = build().run(40);
        let b = build().run(40);
        assert_eq!(a.ticks, b.ticks);
    }

    #[test]
    fn step_toward_moves_one_index_per_axis() {
        let a = Configuration::new(0, 3);
        let b = Configuration::new(2, 1);
        assert_eq!(step_toward(&a, &b), Configuration::new(1, 2));
        assert_eq!(step_toward(&b, &a), Configuration::new(1, 2));
        assert_eq!(step_toward(&a, &a), a);
        assert_eq!(step_toward(&Configuration::new(1, 1), &Configuration::new(2, 1)), b);
    }

    /// PR-5: reactive emergency repairs are ranked candidate lists, not
    /// single moves — a multi-step jump carries a one-step stepping
    /// stone behind it so a tight budget degrades the repair instead of
    /// flat-denying it.
    #[test]
    fn emergency_resize_ranks_a_stepping_stone_behind_the_target() {
        let cfg = ModelConfig::default_paper();
        let b = TraceBuilder::from_config(&cfg);
        let mut specs = constant_tenant_specs(&cfg, 1);
        specs[0].trace = b.constant(160.0, 4);
        specs[0].start = Configuration::new(0, 0);
        let sim =
            PlacementSim::dedicated(&cfg, specs, 1.0e6, 3, PlacementConfig::default());
        let input = sim.plan_input(0);
        let (cands, emergency) =
            sim.resize_candidates(0, &input).expect("an infeasible host must propose a repair");
        assert!(emergency);
        let target = cands[0].to;
        let cur = Configuration::new(0, 0);
        let (dh, dv) = cur.index_distance(&target);
        assert!(dh.max(dv) > 1, "scenario must need a multi-step jump, got {target:?}");
        assert_eq!(cands.len(), 2, "a stepping stone must rank behind the target");
        let stone = cands[1].to;
        let (sh, sv) = cur.index_distance(&stone);
        assert!(sh <= 1 && sv <= 1, "stone is one plane step from current");
        assert!(cands[1].cost_to < cands[0].cost_to, "stone degrades the spend");
    }

    #[test]
    fn spend_respects_a_tight_budget() {
        let cfg = ModelConfig::default_paper();
        // start spend is 12 × 0.4 = 4.8/h; a 5.0/h budget admits the
        // consolidation shrinks but denies expensive upsizes
        let budget = 5.0f32;
        let mut sim = PlacementSim::packed(
            &cfg,
            constant_specs(&cfg, 12),
            budget,
            3,
            PlacementConfig::default(),
        );
        let res = sim.run(40);
        assert!(res.within_budget(budget), "peak {}", res.peak_spend());
    }

    #[test]
    fn scenario_specs_scale_and_stagger() {
        let cfg = ModelConfig::default_paper();
        let specs = small_tenant_specs(&cfg, 12, 0.1);
        assert_eq!(specs.len(), 12);
        // scaled: the paper's 6000 low phase becomes 600
        assert!((specs[0].trace.points[0].lambda_req - 600.0).abs() < 1e-3);
        // staggered: tenant 6 starts in a different phase than tenant 0
        assert!(
            (specs[0].trace.points[0].lambda_req - specs[6].trace.points[0].lambda_req).abs()
                > 1.0
        );
    }
}
