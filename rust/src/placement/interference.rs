//! Interference model for shared clusters: weighted max-min fair
//! capacity shares plus a contention penalty on the latency surface
//! once total host utilization crosses a knee.
//!
//! Co-located tenants are not isolated: they draw from one host's
//! capacity and they inflate each other's tail latency as the host
//! runs hot. The model here is deliberately the simplest thing with
//! both properties — a water-filling allocator splits observed
//! capacity (so a class-weighted tenant keeps throughput under
//! shortage), and a piecewise-linear penalty multiplies the latency
//! surface above the knee (so packing tenants onto a hot host has a
//! latency price the packer must respect).

/// Weighted max-min fair (water-filling) allocation of `capacity`
/// among `demands` with positive `weights`.
///
/// Properties (pinned by the tests below and `prop_placement`):
/// * `alloc[i] <= demands[i]` — nobody receives more than they asked;
/// * `sum(alloc) <= capacity` — the host is never oversubscribed;
/// * if `sum(demands) <= capacity`, everyone is fully satisfied;
/// * under shortage, leftover capacity splits in proportion to the
///   weights among the still-unsatisfied tenants (higher class keeps
///   throughput first).
pub fn fair_shares(capacity: f64, demands: &[f64], weights: &[f64]) -> Vec<f64> {
    assert_eq!(demands.len(), weights.len());
    let n = demands.len();
    let mut alloc = vec![0.0f64; n];
    let mut active: Vec<bool> = demands.iter().map(|&d| d > 0.0).collect();
    let mut cap = capacity.max(0.0);
    // every round either fully satisfies at least one tenant or splits
    // the remainder and stops, so n rounds always suffice
    for _ in 0..n {
        let wsum: f64 = (0..n).filter(|&i| active[i]).map(|i| weights[i]).sum();
        if wsum <= 0.0 || cap <= 1e-12 {
            break;
        }
        // saturation test against one capacity snapshot (the shares of
        // this round), so the outcome is order-independent
        let sat: Vec<usize> = (0..n)
            .filter(|&i| active[i] && demands[i] - alloc[i] <= cap * weights[i] / wsum + 1e-12)
            .collect();
        if sat.is_empty() {
            // every active tenant is capacity-bound: split what is left
            // by weight and stop
            for i in 0..n {
                if active[i] {
                    alloc[i] += cap * weights[i] / wsum;
                }
            }
            break;
        }
        for i in sat {
            cap -= demands[i] - alloc[i];
            alloc[i] = demands[i];
            active[i] = false;
        }
    }
    alloc
}

/// Latency multiplier for a host at `util` = total demand / capacity:
/// 1.0 below the `knee`, rising linearly with `slope` above it. Every
/// co-located tenant pays it — the contention price of sharing.
pub fn contention_factor(util: f64, knee: f64, slope: f64) -> f64 {
    1.0 + slope * (util - knee).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::forall;
    use crate::workload::XorShift64;

    fn uniform(rng: &mut XorShift64, lo: f64, hi: f64) -> f64 {
        rng.range_f64(lo, hi)
    }

    #[test]
    fn underload_satisfies_everyone_exactly() {
        let a = fair_shares(1000.0, &[100.0, 300.0, 200.0], &[1.0, 2.0, 4.0]);
        assert_eq!(a, vec![100.0, 300.0, 200.0]);
    }

    #[test]
    fn shortage_splits_by_weight_after_satisfying_small_demands() {
        // gold (w=4) asks 800 of 1000: its weighted share is exactly
        // 800, so it saturates; bronze gets the remaining 200
        let a = fair_shares(1000.0, &[800.0, 800.0], &[4.0, 1.0]);
        assert!((a[0] - 800.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 200.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn deep_shortage_is_weight_proportional() {
        let a = fair_shares(300.0, &[1000.0, 1000.0, 1000.0], &[1.0, 1.0, 2.0]);
        assert!((a[0] - 75.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 75.0).abs() < 1e-9, "{a:?}");
        assert!((a[2] - 150.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn zero_demand_tenants_get_nothing() {
        let a = fair_shares(100.0, &[0.0, 50.0], &[4.0, 1.0]);
        assert_eq!(a[0], 0.0);
        assert_eq!(a[1], 50.0);
    }

    #[test]
    fn allocation_invariants_hold_for_random_inputs() {
        forall(500, 0xFA125, |_, rng| {
            let n = 1 + rng.below(8) as usize;
            let cap = uniform(rng, 0.0, 5000.0);
            let demands: Vec<f64> = (0..n).map(|_| uniform(rng, 0.0, 1500.0)).collect();
            let weights: Vec<f64> =
                (0..n).map(|_| [1.0, 2.0, 4.0][rng.below(3) as usize]).collect();
            let alloc = fair_shares(cap, &demands, &weights);
            let total: f64 = alloc.iter().sum();
            assert!(total <= cap + 1e-6, "oversubscribed: {total} > {cap}");
            for (a, d) in alloc.iter().zip(&demands) {
                assert!(*a <= d + 1e-9, "over-served: {a} > {d}");
                assert!(*a >= 0.0);
            }
            if demands.iter().sum::<f64>() <= cap {
                for (a, d) in alloc.iter().zip(&demands) {
                    assert!((a - d).abs() < 1e-6, "underload must satisfy: {a} vs {d}");
                }
            }
        });
    }

    #[test]
    fn contention_is_flat_below_the_knee_and_linear_above() {
        assert_eq!(contention_factor(0.0, 0.7, 2.0), 1.0);
        assert_eq!(contention_factor(0.7, 0.7, 2.0), 1.0);
        assert!((contention_factor(0.8, 0.7, 2.0) - 1.2).abs() < 1e-12);
        assert!((contention_factor(1.0, 0.7, 2.0) - 1.6).abs() < 1e-12);
        // monotone in utilization
        let mut prev = 0.0;
        for u in [0.0, 0.5, 0.7, 0.75, 0.9, 1.2] {
            let f = contention_factor(u, 0.7, 2.0);
            assert!(f >= prev);
            prev = f;
        }
    }
}
