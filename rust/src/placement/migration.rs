//! Migration pricing: a tenant move is a rebalance event on the DES
//! calendar, not bookkeeping. The data moved is the tenant's dataset
//! share; the transfer runs over the destination host's movement
//! bandwidth (the same `move_bandwidth_frac` slice the substrate
//! engines grant shard rebalancing), and while it is in flight the
//! destination serves at `rebalance_degradation` capacity — so packing
//! decisions pay a latency price on the ticks the move spans.

use crate::cluster::ClusterParams;
use crate::plane::{Configuration, ScalingPlane};

/// Where a migration lands: an existing live cluster (by id) or the
/// `k`-th cluster a rebalance bundle creates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterRef {
    Existing(usize),
    New(usize),
}

/// One planned tenant move.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlannedMigration {
    pub tenant: usize,
    /// Live cluster id the tenant leaves.
    pub from: usize,
    pub to: ClusterRef,
}

/// The degradation window a migration opens on its destination.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationWindow {
    /// Data moved (the tenant's dataset share, GB).
    pub data_gb: f64,
    /// Wall-clock transfer time (synthetic seconds) over the host's
    /// movement bandwidth.
    pub duration: f64,
    /// Capacity multiplier on the destination while in flight.
    pub degradation: f64,
}

/// Diff between the live placement and a packer target, priced as a
/// single budget-consuming action: migrations to actuate, host
/// resizes, clusters to create, and the hourly-cost edge the budget
/// arbiter admits or defers.
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceBundle {
    pub migrations: Vec<PlannedMigration>,
    /// Existing cluster id → new host config.
    pub resizes: Vec<(usize, Configuration)>,
    /// New clusters to open: config + the tenants migrating in.
    pub creates: Vec<(Configuration, Vec<usize>)>,
    /// Σ current hourly cost of the clusters the bundle touches.
    pub cost_from: f32,
    /// Σ target hourly cost of the same clusters (retired ones count 0).
    pub cost_to: f32,
}

impl RebalanceBundle {
    pub fn is_empty(&self) -> bool {
        self.migrations.is_empty() && self.resizes.is_empty() && self.creates.is_empty()
    }

    /// Hourly-cost delta the arbiter accounts for (negative bundles are
    /// consolidation savings and admit as shrinks).
    pub fn cost_delta(&self) -> f32 {
        self.cost_to - self.cost_from
    }
}

/// Prices tenant moves against a host's movement bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct MigrationPlanner {
    /// Dataset share per tenant (GB) — what a migration must move.
    pub tenant_gb: f64,
}

impl MigrationPlanner {
    pub fn new(tenant_gb: f64) -> Self {
        assert!(tenant_gb >= 0.0, "dataset share cannot be negative");
        Self { tenant_gb }
    }

    /// The window one tenant move opens on a destination at `dest`:
    /// `tenant_gb` over the host's aggregate movement bandwidth
    /// (`H × tier bandwidth × move_bandwidth_frac`), degraded at the
    /// substrate's rebalance factor while in flight.
    pub fn price(
        &self,
        plane: &ScalingPlane,
        dest: &Configuration,
        params: &ClusterParams,
    ) -> MigrationWindow {
        self.price_gb(plane, dest, params, self.tenant_gb)
    }

    /// [`MigrationPlanner::price`] for an explicit data volume — the
    /// partition-aware path, where a
    /// [`crate::scenario::ShardModel`] has already determined which
    /// shards actually move (`gb ≤ tenant_gb`). A zero-GB move (every
    /// shard's hyperedge already present at the destination) opens no
    /// window.
    pub fn price_gb(
        &self,
        plane: &ScalingPlane,
        dest: &Configuration,
        params: &ClusterParams,
        gb: f64,
    ) -> MigrationWindow {
        let h = plane.h_value(dest) as f64;
        let bw = h * plane.tier(dest).bandwidth as f64 * params.move_bandwidth_frac;
        let duration = if bw > 0.0 { gb / bw } else { 0.0 };
        MigrationWindow { data_gb: gb, duration, degradation: params.rebalance_degradation }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn plane() -> ScalingPlane {
        ModelConfig::default_paper().plane()
    }

    #[test]
    fn bigger_hosts_absorb_migrations_faster() {
        let planner = MigrationPlanner::new(2.0);
        let params = ClusterParams::default();
        let p = plane();
        let small = planner.price(&p, &Configuration::new(0, 1), &params);
        let big = planner.price(&p, &Configuration::new(2, 3), &params);
        assert!(small.duration > big.duration);
        assert_eq!(small.data_gb, 2.0);
        assert_eq!(small.degradation, params.rebalance_degradation);
    }

    #[test]
    fn duration_scales_linearly_with_dataset_share() {
        let params = ClusterParams::default();
        let p = plane();
        let dest = Configuration::new(1, 1);
        let one = MigrationPlanner::new(1.0).price(&p, &dest, &params);
        let four = MigrationPlanner::new(4.0).price(&p, &dest, &params);
        assert!((four.duration - 4.0 * one.duration).abs() < 1e-12);
    }

    #[test]
    fn exact_duration_formula() {
        // (H=1, medium): bw 5.0, move fraction 0.2 → 1.0 GB/s; 2 GB → 2 s
        let params = ClusterParams::default();
        let w = MigrationPlanner::new(2.0).price(&plane(), &Configuration::new(0, 1), &params);
        assert!((w.duration - 2.0).abs() < 1e-12, "duration {}", w.duration);
    }

    #[test]
    fn partial_shard_moves_price_strictly_less_than_the_flat_share() {
        let params = ClusterParams::default();
        let p = plane();
        let dest = Configuration::new(1, 1);
        let planner = MigrationPlanner::new(2.0);
        let flat = planner.price(&p, &dest, &params);
        let partial = planner.price_gb(&p, &dest, &params, 0.75);
        assert!(partial.duration < flat.duration);
        assert_eq!(partial.data_gb, 0.75);
        // the full volume through price_gb is exactly the flat path
        let full = planner.price_gb(&p, &dest, &params, 2.0);
        assert_eq!(full, flat);
        // nothing shared nowhere to ship: no window at all
        assert_eq!(planner.price_gb(&p, &dest, &params, 0.0).duration, 0.0);
    }

    #[test]
    fn bundle_cost_delta() {
        let b = RebalanceBundle {
            migrations: Vec::new(),
            resizes: Vec::new(),
            creates: Vec::new(),
            cost_from: 2.4,
            cost_to: 1.8,
        };
        assert!(b.is_empty());
        assert!((b.cost_delta() + 0.6).abs() < 1e-6);
    }
}
