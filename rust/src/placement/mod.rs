//! Cross-tenant placement: co-locate small tenants on shared physical
//! clusters and make scaling decisions placement-aware.
//!
//! The fleet layer (PR 1–3) scales N tenants under one budget but pays
//! the worst case on cost: one dedicated cluster per tenant, so every
//! small Bronze tenant carries a full per-node fixed cost. This module
//! is the control layer that wins that cost back:
//!
//! * [`SharedCluster`] hosts multiple tenants behind one host
//!   configuration. Observed capacity splits by weighted max-min fair
//!   shares ([`interference::fair_shares`]; Gold outweighs Silver
//!   outweighs Bronze), and every co-located tenant pays a contention
//!   penalty on the latency surface once total utilization crosses a
//!   knee ([`interference::contention_factor`]) — sharing is priced,
//!   not free.
//! * [`Packer`] plans placements: first-fit-decreasing seeding plus
//!   DIAGONALSCALE-style local search over {migrate tenant, merge
//!   clusters, split cluster, resize host}, minimizing fleet cost
//!   subject to every hosted tenant's SLA — including a *transition
//!   guard* that refuses plans which only work at full health (a
//!   migration window degrades the destination while data moves).
//! * [`MigrationPlanner`] prices each tenant move as a rebalance event
//!   on the cluster's DES calendar: data moved is the tenant's dataset
//!   share, transfer time runs over the host's movement bandwidth, and
//!   the destination serves degraded until the
//!   [`Event::MigrationEnd`](crate::cluster::Event::MigrationEnd)
//!   event fires — migrations have latency consequences.
//! * [`PlacementSim`] drives it end to end: serve → propose → admit →
//!   actuate, with every placement action (reactive host resizes and
//!   the packer's rebalance bundles) walking through the fleet's
//!   [`BudgetArbiter`](crate::fleet::BudgetArbiter) as a
//!   budget-consuming proposal. `PlacementSim::dedicated` keeps the
//!   one-cluster-per-tenant baseline for A/B runs; the pinned tests
//!   assert packing strictly lowers fleet cost at no more
//!   SLA-violation ticks on the 12-small-tenant scenario.
//!
//! Entry points: [`crate::fleet::FleetSimulator::with_placement`], the
//! `placement` CLI subcommand, `examples/placement_packing.rs`, and
//! `cargo bench --bench placement`.

pub mod interference;
pub mod migration;
pub mod packer;
pub mod sim;

pub use interference::{contention_factor, fair_shares};
pub use migration::{
    ClusterRef, MigrationPlanner, MigrationWindow, PlannedMigration, RebalanceBundle,
};
pub use packer::{PackInput, Packer, Placement, PlannedCluster};
pub use sim::{
    constant_tenant_specs, small_tenant_specs, PlacementReport, PlacementResult, PlacementSim,
    PlacementTick, TenantPlacementReport,
};

use crate::cluster::{Event, EventCalendar};
use crate::fleet::PriorityClass;
use crate::plane::Configuration;

/// Tunables of the placement subsystem.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlacementConfig {
    /// Utilization knee where the contention penalty starts.
    pub knee: f64,
    /// Latency-penalty slope above the knee.
    pub contention: f64,
    /// Dataset share per tenant (GB) — what a migration moves.
    pub tenant_gb: f64,
    /// Packer cadence: full replans every this many ticks.
    pub replan_every: usize,
    /// Planning lookahead: size hosts for the peak demand over the next
    /// this many ticks (seasonal one-period lookahead — the fleet's
    /// cyclic traces make it exact, mirroring `ForecastKind::Seasonal`).
    pub plan_horizon: usize,
    /// Local-search improvement rounds per replan.
    pub search_rounds: usize,
    /// Score penalty per tenant moved, so equal-cost shuffles never
    /// happen (a quarter of the smallest tier cost step).
    pub migration_penalty: f32,
    /// Capacity multiplier assumed while a transition window is open —
    /// plans must stay feasible at this degraded capacity.
    /// [`PlacementSim::new`] overrides it with
    /// `min(rebalance_degradation, restart_degradation)` from the live
    /// [`crate::cluster::ClusterParams`], so the guard always mirrors
    /// the windows the simulator actually opens; the default here only
    /// serves packers built standalone.
    pub transition_guard: f64,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        Self {
            knee: 0.7,
            contention: 2.0,
            tenant_gb: 2.0,
            replan_every: 4,
            plan_horizon: 4,
            search_rounds: 64,
            migration_penalty: 0.02,
            // matches ClusterParams::default(): min(rebalance 0.7,
            // restart 0.8)
            transition_guard: 0.7,
        }
    }
}

/// Fair-share weight of a priority class on a shared host: Gold
/// outweighs Silver outweighs Bronze 4:2:1, so under capacity shortage
/// the allocator satisfies higher classes first.
pub fn class_weight(class: PriorityClass) -> f64 {
    match class {
        PriorityClass::Gold => 4.0,
        PriorityClass::Silver => 2.0,
        PriorityClass::Bronze => 1.0,
    }
}

/// One shared physical cluster: a host configuration, the tenants
/// co-located on it, and the DES calendar of open degradation windows
/// (migrations in flight, reconfigurations rolling).
#[derive(Debug)]
pub struct SharedCluster {
    id: usize,
    config: Configuration,
    /// Hosted tenant ids, sorted ascending.
    tenants: Vec<usize>,
    calendar: EventCalendar,
    /// Open degradation windows as `(end time, factor)`; each entry
    /// leaves when its calendar event fires, so the live factor is
    /// always the min over the windows *still* open (a deep window
    /// closing restores the shallower survivor's factor).
    open: Vec<(f64, f64)>,
    /// Any hosted tenant violated its SLA on the last served tick.
    pub violating: bool,
    /// Consecutive denied repair proposals while violating (feeds the
    /// arbiter's fairness rescue, like a tenant's denial streak).
    pub denial_streak: usize,
}

impl SharedCluster {
    pub fn new(id: usize, config: Configuration, mut tenants: Vec<usize>) -> Self {
        tenants.sort_unstable();
        Self {
            id,
            config,
            tenants,
            calendar: EventCalendar::new(),
            open: Vec::new(),
            violating: false,
            denial_streak: 0,
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn config(&self) -> Configuration {
        self.config
    }

    pub fn tenants(&self) -> &[usize] {
        &self.tenants
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// A degradation window is currently open.
    pub fn degraded(&self) -> bool {
        !self.open.is_empty()
    }

    /// Current capacity multiplier: the deepest *still-open* window's
    /// factor (1.0 healthy).
    pub fn degradation(&self) -> f64 {
        self.open.iter().map(|&(_, d)| d).fold(1.0, f64::min)
    }

    /// Pending calendar entries (diagnostics / tests).
    pub fn pending_events(&self) -> usize {
        self.calendar.len()
    }

    /// Fire every window-close event due at or before `t`; the matching
    /// window entries leave, so the live factor recovers to the min of
    /// what remains open.
    pub(crate) fn drain_due(&mut self, t: f64) {
        while let Some((_, ev)) = self.calendar.pop_due(t) {
            match ev {
                Event::MigrationEnd | Event::RebalanceEnd | Event::RestartEnd => {}
                // compaction is owned by the substrate engines, never
                // scheduled on placement calendars
                Event::CompactionStart { .. } | Event::CompactionEnd { .. } => {}
            }
        }
        self.open.retain(|&(end, _)| end > t);
    }

    /// Open a degradation window closing at `end`. Overlapping windows
    /// stack: the cluster stays degraded until the last one closes, at
    /// the deepest factor among those still open.
    pub(crate) fn open_window(&mut self, end: f64, degradation: f64, event: Event) {
        self.open.push((end, degradation));
        self.calendar.schedule(end, event);
    }

    pub(crate) fn set_config(&mut self, config: Configuration) {
        self.config = config;
    }

    pub(crate) fn add_tenant(&mut self, tenant: usize) {
        if let Err(pos) = self.tenants.binary_search(&tenant) {
            self.tenants.insert(pos, tenant);
        }
    }

    pub(crate) fn remove_tenant(&mut self, tenant: usize) {
        if let Ok(pos) = self.tenants.binary_search(&tenant) {
            self.tenants.remove(pos);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_weights_are_ordered() {
        assert!(class_weight(PriorityClass::Gold) > class_weight(PriorityClass::Silver));
        assert!(class_weight(PriorityClass::Silver) > class_weight(PriorityClass::Bronze));
    }

    #[test]
    fn windows_stack_and_close_via_calendar_events() {
        let mut cl = SharedCluster::new(0, Configuration::new(1, 1), vec![2, 0, 1]);
        assert_eq!(cl.tenants(), &[0, 1, 2]);
        assert!(!cl.degraded());
        assert_eq!(cl.degradation(), 1.0);

        cl.open_window(1.5, 0.7, Event::MigrationEnd);
        cl.open_window(2.5, 0.8, Event::MigrationEnd);
        assert!(cl.degraded());
        // deepest open factor wins while both are open
        assert_eq!(cl.degradation(), 0.7);
        assert_eq!(cl.pending_events(), 2);

        cl.drain_due(1.0);
        assert!(cl.degraded(), "nothing due yet");
        assert_eq!(cl.degradation(), 0.7);
        cl.drain_due(1.5);
        assert!(cl.degraded(), "one window still open");
        assert_eq!(cl.pending_events(), 1);
        // the deep window closed: capacity recovers to the survivor's
        // factor, not the ratcheted minimum
        assert_eq!(cl.degradation(), 0.8);
        cl.drain_due(3.0);
        assert!(!cl.degraded());
        assert_eq!(cl.degradation(), 1.0);
        assert_eq!(cl.pending_events(), 0);
    }

    #[test]
    fn tenant_membership_stays_sorted_and_deduplicated() {
        let mut cl = SharedCluster::new(0, Configuration::new(1, 1), vec![5]);
        cl.add_tenant(3);
        cl.add_tenant(9);
        cl.add_tenant(3); // duplicate ignored
        assert_eq!(cl.tenants(), &[3, 5, 9]);
        cl.remove_tenant(5);
        assert_eq!(cl.tenants(), &[3, 9]);
        cl.remove_tenant(42); // absent: no-op
        assert_eq!(cl.tenants(), &[3, 9]);
    }
}
