//! [`AnalyticalSubstrate`]: the Phase-1 analytical surfaces behind the
//! [`Substrate`] trait — a fluid-model stand-in for the DES engines.
//!
//! `step` is O(1): completed load is `min(offered, capacity)` with the
//! capacity degraded inside rebalance/restart windows, and measured
//! latency is the §VIII utilization-corrected latency computed against
//! that (possibly degraded) capacity. There is no per-op randomness,
//! so every percentile collapses onto the fluid latency. Transition
//! costs come from the same [`rebalance::plan_reconfiguration`] the
//! physical engines pay, so a policy sees consistent actuation physics
//! whichever substrate backs it.
//!
//! Latency units: the analytical surfaces live on the paper's latency
//! scale (SLA bound `l_max`), while the DES engines emit synthetic
//! seconds (bound `params.sla_latency`). The wrapper maps its emitted
//! latencies onto the substrate scale — `l_max` lands exactly on
//! `params.sla_latency` — so violation audits are unchanged and
//! mixed-substrate fleet reports aggregate one consistent unit.

use std::sync::Arc;

use crate::cluster::{
    rebalance, ClusterParams, ClusterSim, ClusterStepMetrics, EventSim, RebalancePlan,
    Substrate, SubstrateKind, SubstrateStatus,
};
use crate::config::ModelConfig;
use crate::plane::Configuration;
use crate::surfaces::{queueing, SurfaceModel};
use crate::workload::WorkloadPoint;

/// Thin substrate over the analytical surface model. The model is
/// shared (`Arc`), so fleet tenants reuse one precomputed surface
/// table instead of cloning it per substrate.
pub struct AnalyticalSubstrate {
    model: Arc<SurfaceModel>,
    params: ClusterParams,
    current: Configuration,
    time: f64,
    degraded_until: f64,
    degradation: f64,
    /// Paper-scale → substrate-scale latency factor
    /// (`params.sla_latency / l_max`): the SLA bound maps onto the
    /// bound the substrate metrics are audited against.
    lat_scale: f64,
    /// Conservation counters (offered = completed + dropped).
    pub total_offered: f64,
    pub total_completed: f64,
    pub total_dropped: f64,
}

impl AnalyticalSubstrate {
    pub fn new(cfg: &ModelConfig, params: ClusterParams) -> Self {
        let start = Configuration::new(cfg.policy.start[0], cfg.policy.start[1]);
        Self::from_model(Arc::new(SurfaceModel::from_config(cfg)), params, start, cfg.sla.l_max)
    }

    /// Build from an existing (shared) model and a specific SLA latency
    /// bound — the fleet path, where tenants carry their own SLAs and
    /// already hold a constructed [`SurfaceModel`].
    pub fn from_model(
        model: Arc<SurfaceModel>,
        params: ClusterParams,
        start: Configuration,
        l_max: f32,
    ) -> Self {
        assert!(model.plane().contains(&start), "start config out of plane");
        assert!(l_max > 0.0, "SLA latency bound must be positive");
        Self {
            lat_scale: params.sla_latency / l_max as f64,
            model,
            params,
            current: start,
            time: 0.0,
            degraded_until: 0.0,
            degradation: 1.0,
            total_offered: 0.0,
            total_completed: 0.0,
            total_dropped: 0.0,
        }
    }

    pub fn model(&self) -> &SurfaceModel {
        &self.model
    }

    /// Aggregate capacity (ops per unit time), degradation included.
    pub fn capacity(&self) -> f64 {
        let deg = if self.time < self.degraded_until { self.degradation } else { 1.0 };
        self.model.throughput(&self.current) as f64 * deg
    }
}

impl Substrate for AnalyticalSubstrate {
    fn current(&self) -> Configuration {
        self.current
    }

    fn step(&mut self, w: WorkloadPoint) -> ClusterStepMetrics {
        let interval = self.params.interval;
        let t0 = self.time;
        let offered = w.lambda_req as f64 * interval;
        let degraded = t0 < self.degraded_until;
        let cap = self.capacity(); // ops per unit time
        let completed = offered.min(cap * interval);
        let dropped = offered - completed;

        let lat = queueing::effective_latency(
            self.model.latency(&self.current),
            cap as f32,
            w.lambda_req,
            self.model.constants().u_max,
        ) as f64
            * self.lat_scale;

        self.time = t0 + interval;
        self.total_offered += offered;
        self.total_completed += completed;
        self.total_dropped += dropped;

        ClusterStepMetrics {
            offered,
            completed,
            dropped,
            avg_latency: lat,
            // fluid model: no per-op distribution, so the tail
            // percentiles collapse onto the corrected latency
            p99_latency: lat,
            p999_latency: lat,
            utilization: if cap > 0.0 { offered / (cap * interval) } else { f64::INFINITY },
            degraded,
        }
    }

    fn apply(&mut self, next: Configuration) -> RebalancePlan {
        assert!(self.model.plane().contains(&next), "config out of plane");
        if next == self.current {
            return RebalancePlan::none();
        }
        let plan = rebalance::plan_reconfiguration(
            self.model.plane(),
            &self.current,
            &next,
            &self.params,
        );
        self.current = next;
        if plan.duration > 0.0 {
            self.degraded_until = self.time + plan.duration;
            self.degradation = plan.degradation;
        }
        plan
    }

    fn observe(&self) -> SubstrateStatus {
        SubstrateStatus {
            time: self.time,
            nodes: self.model.plane().h_value(&self.current) as usize,
            capacity: self.capacity(),
            degraded: self.time < self.degraded_until,
            total_offered: self.total_offered,
            total_completed: self.total_completed,
            total_dropped: self.total_dropped,
        }
    }

    fn params(&self) -> &ClusterParams {
        &self.params
    }
}

/// Build a boxed substrate of the requested kind — the one factory the
/// CLI and the fleet share, so mixed-substrate runs stay one-liners.
pub fn build_substrate(
    kind: SubstrateKind,
    cfg: &ModelConfig,
    params: ClusterParams,
    seed: u64,
) -> Box<dyn Substrate + Send> {
    match kind {
        SubstrateKind::Sampling => Box::new(ClusterSim::new(cfg, params, seed)),
        SubstrateKind::Des => Box::new(EventSim::new(cfg, params, seed)),
        SubstrateKind::Analytical => Box::new(AnalyticalSubstrate::new(cfg, params)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sub() -> AnalyticalSubstrate {
        let cfg = ModelConfig::default_paper();
        AnalyticalSubstrate::new(&cfg, ClusterParams::default())
    }

    #[test]
    fn conserves_and_completes_under_light_load() {
        let mut s = sub();
        for _ in 0..10 {
            let m = s.step(WorkloadPoint::new(1000.0, 0.3));
            assert_eq!(m.dropped, 0.0);
            assert!(m.utilization < 1.0);
        }
        assert!(
            (s.total_offered - s.total_completed - s.total_dropped).abs()
                < 1e-9 * s.total_offered
        );
    }

    #[test]
    fn overload_drops_the_excess_exactly() {
        let mut s = sub();
        let cap = s.capacity();
        let m = s.step(WorkloadPoint::new(2.0 * cap as f32, 0.3));
        assert!(m.utilization > 1.9);
        assert!((m.completed - cap).abs() < 1e-3 * cap);
        assert!((m.dropped - (m.offered - m.completed)).abs() < 1e-9);
    }

    #[test]
    fn reconfiguration_opens_a_degradation_window() {
        let mut s = sub();
        let before = s.capacity();
        let plan = Substrate::apply(&mut s, Configuration::new(2, 1));
        assert!(plan.moved_shards > 0);
        assert!(s.observe().degraded);
        assert!(s.capacity() < before * 2.0); // degraded below full 2x jump
        // burn past the window: capacity settles at the new config
        for _ in 0..3 {
            s.step(WorkloadPoint::new(100.0, 0.3));
        }
        assert!(!s.observe().degraded);
        assert!(s.capacity() > before);
    }

    #[test]
    fn latency_inflates_with_utilization() {
        let mut a = sub();
        let mut b = sub();
        let low = a.step(WorkloadPoint::new(500.0, 0.3));
        let high = b.step(WorkloadPoint::new(3500.0, 0.3));
        assert!(high.avg_latency > low.avg_latency);
        assert_eq!(high.p99_latency, high.avg_latency);
    }

    #[test]
    fn latency_maps_paper_scale_onto_the_substrate_scale() {
        let cfg = ModelConfig::default_paper();
        let params = ClusterParams::default();
        let mut s = AnalyticalSubstrate::new(&cfg, params);
        let model = SurfaceModel::from_config(&cfg);
        let c = s.current();
        let m = s.step(WorkloadPoint::new(1000.0, 0.3));
        let l_eff = queueing::effective_latency(
            model.latency(&c),
            model.throughput(&c),
            1000.0,
            cfg.surfaces.u_max,
        ) as f64;
        // the SLA bound l_max lands exactly on params.sla_latency
        let expect = l_eff * params.sla_latency / cfg.sla.l_max as f64;
        assert!((m.avg_latency - expect).abs() < 1e-9 * expect.max(1e-9));
        // so the violation audit is unchanged by the unit mapping
        assert_eq!(
            m.avg_latency > params.sla_latency,
            l_eff > cfg.sla.l_max as f64
        );
    }

    #[test]
    fn factory_builds_every_kind_at_the_start_config() {
        let cfg = ModelConfig::default_paper();
        for kind in [SubstrateKind::Sampling, SubstrateKind::Des, SubstrateKind::Analytical] {
            let s = build_substrate(kind, &cfg, ClusterParams::default(), 7);
            assert_eq!(s.current(), Configuration::new(1, 1), "{kind:?}");
        }
    }
}
