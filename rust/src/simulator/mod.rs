//! The Phase-1 analytical simulator (paper §V): drives a policy over a
//! workload trace against the analytical surfaces and records the §V.E
//! metrics.
//!
//! Semantics (shared bit-for-bit with `python/compile/model.policy_trace`
//! and the numpy calibrator — see `python/compile/defaults.py`):
//!
//! * **serve-then-move** — the configuration carried into step *t*
//!   serves workload *t*; the decision made at *t* takes effect at
//!   *t + 1* (reconfiguration is not instantaneous).
//! * measured latency is the §VIII utilization-corrected latency; the
//!   reported objective uses it.
//! * violations audit raw latency against `l_max` and served throughput
//!   against the *raw* requirement.
//!
//! [`AnalyticalSubstrate`] re-exposes these surfaces behind the
//! [`crate::cluster::Substrate`] trait, so the coordinator and fleet
//! can drive the analytical model through the same observe → plan →
//! actuate loop as the physical DES engines.

mod substrate;

pub use substrate::{build_substrate, AnalyticalSubstrate};

use crate::config::{ModelConfig, MoveFlags};
use crate::metrics::{Recorder, StepRecord, Summary};
use crate::plane::Configuration;
use crate::policy::{Candidate, DiagonalScale, Policy, PolicyContext};
use crate::sla::SlaSpec;
use crate::surfaces::SurfaceModel;
use crate::workload::Trace;

/// The paper's three compared policies plus the extensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Diagonal,
    HorizontalOnly,
    VerticalOnly,
    Threshold,
    Oracle,
    /// Lookahead with the given depth (paper VIII).
    Lookahead(usize),
    Static,
}

impl PolicyKind {
    pub fn build(&self) -> Box<dyn Policy> {
        match self {
            PolicyKind::Diagonal => Box::new(DiagonalScale::diagonal()),
            PolicyKind::HorizontalOnly => Box::new(DiagonalScale::horizontal_only()),
            PolicyKind::VerticalOnly => Box::new(DiagonalScale::vertical_only()),
            PolicyKind::Threshold => Box::new(crate::policy::Threshold::default()),
            PolicyKind::Oracle => Box::new(crate::policy::Oracle),
            PolicyKind::Lookahead(d) => {
                Box::new(crate::policy::Lookahead::new(MoveFlags::DIAGONAL, *d))
            }
            PolicyKind::Static => Box::new(crate::policy::StaticPolicy),
        }
    }

    pub fn label(&self) -> String {
        match self {
            PolicyKind::Diagonal => "DiagonalScale".into(),
            PolicyKind::HorizontalOnly => "Horizontal-only".into(),
            PolicyKind::VerticalOnly => "Vertical-only".into(),
            PolicyKind::Threshold => "Threshold".into(),
            PolicyKind::Oracle => "Oracle".into(),
            PolicyKind::Lookahead(d) => format!("Lookahead-{d}"),
            PolicyKind::Static => "Static".into(),
        }
    }

    /// The three policies of the paper's evaluation (§V.D).
    pub fn paper_set() -> [PolicyKind; 3] {
        [PolicyKind::Diagonal, PolicyKind::HorizontalOnly, PolicyKind::VerticalOnly]
    }
}

/// One step's ranked-candidate capture: what the policy proposed, which
/// candidate won, and whether the Algorithm-1 fallback fired — the data
/// behind `simulate --explain` and the versioned
/// [`crate::report::explain_json`] schema.
#[derive(Debug, Clone)]
pub struct StepExplain {
    pub step: usize,
    pub demand: f32,
    pub fallback: bool,
    pub chosen: Configuration,
    /// Top-k ranked candidates of the step's proposal.
    pub candidates: Vec<Candidate>,
}

/// A complete run: the per-step records plus the summary.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub policy: String,
    pub records: Vec<StepRecord>,
    pub summary: Summary,
    /// Number of steps on which the Algorithm-1 fallback fired.
    pub fallbacks: usize,
}

impl RunResult {
    /// Trajectory through the plane — Figure 5's data.
    pub fn trajectory(&self) -> Vec<Configuration> {
        self.records.iter().map(|r| r.config).collect()
    }
}

/// Phase-1 analytical simulator.
pub struct Simulator {
    model: SurfaceModel,
    sla: SlaSpec,
    reb_h: f32,
    reb_v: f32,
    plan_queue: bool,
    start: Configuration,
}

impl Simulator {
    pub fn new(cfg: &ModelConfig) -> Self {
        Self {
            model: SurfaceModel::from_config(cfg),
            sla: SlaSpec::from_config(cfg),
            reb_h: cfg.policy.reb_h,
            reb_v: cfg.policy.reb_v,
            plan_queue: cfg.policy.plan_queue,
            start: Configuration::new(cfg.policy.start[0], cfg.policy.start[1]),
        }
    }

    /// Override the planner-queueing extension flag (ablation A5).
    pub fn with_plan_queue(mut self, on: bool) -> Self {
        self.plan_queue = on;
        self
    }

    /// Override the start configuration.
    pub fn with_start(mut self, start: Configuration) -> Self {
        assert!(self.model.plane().contains(&start));
        self.start = start;
        self
    }

    /// Override the rebalance weights (ablation A2).
    pub fn with_rebalance(mut self, reb_h: f32, reb_v: f32) -> Self {
        self.reb_h = reb_h;
        self.reb_v = reb_v;
        self
    }

    pub fn model(&self) -> &SurfaceModel {
        &self.model
    }

    pub fn sla(&self) -> &SlaSpec {
        &self.sla
    }

    pub fn start(&self) -> Configuration {
        self.start
    }

    /// Run one policy over a trace.
    pub fn run(&self, kind: PolicyKind, trace: &Trace) -> RunResult {
        let mut policy = kind.build();
        self.run_boxed(policy.as_mut(), &kind.label(), trace)
    }

    /// Run an arbitrary policy object over a trace.
    pub fn run_boxed(&self, policy: &mut dyn Policy, label: &str, trace: &Trace) -> RunResult {
        self.run_explained_boxed(policy, label, trace, 0).0
    }

    /// Run one policy over a trace, capturing the top-`k` ranked
    /// candidates of every step's proposal (`simulate --explain`).
    pub fn run_explained(
        &self,
        kind: PolicyKind,
        trace: &Trace,
        k: usize,
    ) -> (RunResult, Vec<StepExplain>) {
        let mut policy = kind.build();
        self.run_explained_boxed(policy.as_mut(), &kind.label(), trace, k)
    }

    /// [`Self::run_boxed`] plus the per-step top-`k` explain capture
    /// (`k == 0` skips the capture). The trajectory is identical either
    /// way: the decision *is* the proposal's top candidate.
    pub fn run_explained_boxed(
        &self,
        policy: &mut dyn Policy,
        label: &str,
        trace: &Trace,
        k: usize,
    ) -> (RunResult, Vec<StepExplain>) {
        let mut recorder = Recorder::with_capacity(trace.len());
        let mut fallbacks = 0usize;
        let mut explains: Vec<StepExplain> = Vec::new();
        let mut current = self.start;

        for (t, w) in trace.points.iter().enumerate() {
            // ---- serve + measure at the carried-in configuration ----
            let point = self.model.evaluate(&current, w.lambda_req);
            let lat_eff = self.model.effective_latency(&current, w.lambda_req);
            let obj_eff = self.model.effective_objective(&current, w.lambda_req);
            recorder.push(StepRecord {
                step: t,
                config: current,
                lambda_req: w.lambda_req,
                latency: lat_eff,
                latency_raw: point.latency,
                throughput: point.throughput,
                cost: point.cost,
                objective: obj_eff,
                violation: self.sla.audit(point.latency, point.throughput, w.lambda_req),
            });

            // ---- propose; the top candidate takes effect next step ---
            let ctx = PolicyContext {
                model: &self.model,
                sla: &self.sla,
                reb_h: self.reb_h,
                reb_v: self.reb_v,
                plan_queue: self.plan_queue,
                future: &trace.points[(t + 1).min(trace.len())..],
                budget: None,
            };
            let proposal = policy.propose(current, *w, &ctx);
            let d = proposal.decision();
            if k > 0 {
                explains.push(StepExplain {
                    step: t,
                    demand: w.lambda_req,
                    fallback: proposal.fallback,
                    chosen: d.next,
                    candidates: proposal.candidates.iter().take(k).copied().collect(),
                });
            }
            debug_assert!(self.model.plane().contains(&d.next));
            if d.fallback {
                fallbacks += 1;
            }
            current = d.next;
        }

        (
            RunResult {
                policy: label.to_string(),
                summary: recorder.summary(),
                records: recorder.records().to_vec(),
                fallbacks,
            },
            explains,
        )
    }

    /// Run the paper's three policies (Table I).
    pub fn run_paper_set(&self, trace: &Trace) -> Vec<RunResult> {
        PolicyKind::paper_set()
            .iter()
            .map(|k| self.run(*k, trace))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceBuilder;

    fn sim() -> (Simulator, Trace) {
        let cfg = ModelConfig::default_paper();
        let trace = TraceBuilder::paper(&cfg);
        (Simulator::new(&cfg), trace)
    }

    #[test]
    fn table_one_shape_holds() {
        let (sim, trace) = sim();
        let rs = sim.run_paper_set(&trace);
        let (ds, hz, vt) = (&rs[0].summary, &rs[1].summary, &rs[2].summary);
        // violations: DS < V < H (paper: 3 < 21 < 32)
        assert!(ds.violations < vt.violations);
        assert!(vt.violations < hz.violations);
        assert!(ds.violations <= 5);
        assert!(hz.violations >= 25);
        // latency: DS < V < H (paper: 4.05 < 4.89 < 13.06)
        assert!(ds.avg_latency < vt.avg_latency);
        assert!(vt.avg_latency < hz.avg_latency);
        // objective: DS < V < H (paper: 65.53 < 77.70 < 180.94)
        assert!(ds.avg_objective < vt.avg_objective);
        assert!(vt.avg_objective < hz.avg_objective);
        // cost premium: DS pays at least as much as the baselines
        assert!(ds.avg_cost >= vt.avg_cost);
        assert!(ds.avg_cost >= hz.avg_cost);
        // throughput: DS highest
        assert!(ds.avg_throughput > hz.avg_throughput);
    }

    #[test]
    fn records_cover_every_step() {
        let (sim, trace) = sim();
        let r = sim.run(PolicyKind::Diagonal, &trace);
        assert_eq!(r.records.len(), 50);
        assert_eq!(r.summary.steps, 50);
        assert!((r.summary.avg_required - 9600.0).abs() < 1.0);
    }

    #[test]
    fn first_step_serves_start_config() {
        let (sim, trace) = sim();
        let r = sim.run(PolicyKind::Diagonal, &trace);
        assert_eq!(r.records[0].config, sim.start());
    }

    #[test]
    fn axis_policies_respect_their_axis() {
        let (sim, trace) = sim();
        let h = sim.run(PolicyKind::HorizontalOnly, &trace);
        assert!(h.records.iter().all(|r| r.config.v_idx == 1));
        let v = sim.run(PolicyKind::VerticalOnly, &trace);
        assert!(v.records.iter().all(|r| r.config.h_idx == 1));
    }

    #[test]
    fn oracle_never_worse_on_violations() {
        let (sim, trace) = sim();
        let ds = sim.run(PolicyKind::Diagonal, &trace);
        let oracle = sim.run(PolicyKind::Oracle, &trace);
        assert!(oracle.summary.violations <= ds.summary.violations + 1);
    }

    #[test]
    fn deterministic() {
        let (sim, trace) = sim();
        let a = sim.run(PolicyKind::Diagonal, &trace);
        let b = sim.run(PolicyKind::Diagonal, &trace);
        assert_eq!(a.records, b.records);
    }

    #[test]
    fn static_policy_never_moves() {
        let (sim, trace) = sim();
        let r = sim.run(PolicyKind::Static, &trace);
        assert!(r.records.iter().all(|rec| rec.config == sim.start()));
    }

    #[test]
    fn lookahead_no_worse_than_greedy_on_spike() {
        let cfg = ModelConfig::default_paper();
        let sim = Simulator::new(&cfg);
        let b = TraceBuilder::from_config(&cfg);
        let trace = b.spike(60.0, 160.0, 10, 10, 30);
        let greedy = sim.run(PolicyKind::Diagonal, &trace);
        let ahead = sim.run(PolicyKind::Lookahead(3), &trace);
        assert!(ahead.summary.violations <= greedy.summary.violations);
    }
}
