//! Configuration system: every constant of the analytical model, the
//! plane geometry, the SLA, the policy weights, and the workload shape
//! live in a TOML file (`config/default.toml`).
//!
//! The same struct packs itself into the flat f32 parameter vector the
//! AOT-compiled kernels take at runtime (`pack_params`), so the native
//! rust surfaces and the HLO surfaces are always driven by identical
//! constants — a property the integration tests assert.

mod params;

pub use params::*;

use anyhow::{anyhow, Context, Result};
use std::path::Path;

use crate::plane::{ScalingPlane, Tier};
use crate::util::toml;

/// Vertical-tier entry as it appears in TOML.
#[derive(Debug, Clone, PartialEq)]
pub struct TierConfig {
    pub name: String,
    pub cpu: f32,
    pub ram: f32,
    pub bandwidth: f32,
    pub iops: f32,
    pub cost: f32,
}

/// `[plane]` section: the discrete configuration space (paper III.A).
#[derive(Debug, Clone, PartialEq)]
pub struct PlaneConfig {
    pub h_values: Vec<u32>,
    pub grid: usize,
    pub tiers: Vec<TierConfig>,
}

/// `[surfaces]` section: analytical-surface constants (paper III.B–F).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SurfaceConfig {
    pub a: f32,
    pub b: f32,
    pub c: f32,
    pub d: f32,
    pub eta: f32,
    pub mu: f32,
    pub theta: f32,
    pub kappa: f32,
    pub omega: f32,
    pub rho: f32,
    pub alpha: f32,
    pub beta: f32,
    pub gamma: f32,
    pub delta: f32,
    pub u_max: f32,
}

fn default_u_max() -> f32 {
    0.75
}

/// `[sla]` section: feasibility bounds (paper IV.C).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlaConfig {
    pub l_max: f32,
    pub b_sla: f32,
}

/// `[policy]` section: rebalance weights and start config (paper IV.D).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyConfig {
    pub reb_h: f32,
    pub reb_v: f32,
    pub start: [usize; 2],
    pub plan_queue: bool,
}

/// `[workload]` section: the paper's phased trace (paper V.C).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    pub phases: Vec<f32>,
    pub steps_per_phase: usize,
    pub thr_factor: f32,
    pub read_ratio: f32,
}

/// The full model configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub plane: PlaneConfig,
    pub surfaces: SurfaceConfig,
    pub sla: SlaConfig,
    pub policy: PolicyConfig,
    pub workload: WorkloadConfig,
}

impl ModelConfig {
    /// Load from a TOML file.
    pub fn from_path(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(
            || format!("reading config {}", path.as_ref().display()),
        )?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text (via the in-tree parser) and validate.
    pub fn from_toml(text: &str) -> Result<Self> {
        let v = toml::parse(text).context("parsing config TOML")?;
        let f32_at = |path: &str| -> Result<f32> {
            v.get(path)
                .and_then(toml::Value::as_f32)
                .ok_or_else(|| anyhow!("config missing numeric `{path}`"))
        };
        let usize_at = |path: &str| -> Result<usize> {
            v.get(path)
                .and_then(toml::Value::as_usize)
                .ok_or_else(|| anyhow!("config missing integer `{path}`"))
        };

        let h_values = v
            .get("plane.h_values")
            .and_then(toml::Value::as_array)
            .ok_or_else(|| anyhow!("config missing `plane.h_values`"))?
            .iter()
            .map(|x| {
                x.as_i64()
                    .and_then(|i| u32::try_from(i).ok())
                    .ok_or_else(|| anyhow!("plane.h_values must be positive integers"))
            })
            .collect::<Result<Vec<u32>>>()?;
        let grid = v
            .get("plane.grid")
            .and_then(toml::Value::as_usize)
            .unwrap_or(crate::GRID);
        let tiers = v
            .get("plane.tiers")
            .and_then(toml::Value::as_table_array)
            .ok_or_else(|| anyhow!("config missing `[[plane.tiers]]`"))?
            .iter()
            .map(|t| {
                let s = |k: &str| {
                    t.get(k)
                        .and_then(toml::Value::as_f32)
                        .ok_or_else(|| anyhow!("tier missing numeric `{k}`"))
                };
                Ok(TierConfig {
                    name: t
                        .get("name")
                        .and_then(toml::Value::as_str)
                        .ok_or_else(|| anyhow!("tier missing `name`"))?
                        .to_string(),
                    cpu: s("cpu")?,
                    ram: s("ram")?,
                    bandwidth: s("bandwidth")?,
                    iops: s("iops")?,
                    cost: s("cost")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let start_arr = v
            .get("policy.start")
            .and_then(toml::Value::as_array)
            .ok_or_else(|| anyhow!("config missing `policy.start`"))?;
        if start_arr.len() != 2 {
            return Err(anyhow!("policy.start must be [h_idx, v_idx]"));
        }
        let start = [
            start_arr[0]
                .as_usize()
                .ok_or_else(|| anyhow!("policy.start[0] must be an index"))?,
            start_arr[1]
                .as_usize()
                .ok_or_else(|| anyhow!("policy.start[1] must be an index"))?,
        ];

        let phases = v
            .get("workload.phases")
            .and_then(toml::Value::as_array)
            .ok_or_else(|| anyhow!("config missing `workload.phases`"))?
            .iter()
            .map(|x| {
                x.as_f32()
                    .ok_or_else(|| anyhow!("workload.phases must be numeric"))
            })
            .collect::<Result<Vec<f32>>>()?;

        let cfg = ModelConfig {
            plane: PlaneConfig { h_values, grid, tiers },
            surfaces: SurfaceConfig {
                a: f32_at("surfaces.a")?,
                b: f32_at("surfaces.b")?,
                c: f32_at("surfaces.c")?,
                d: f32_at("surfaces.d")?,
                eta: f32_at("surfaces.eta")?,
                mu: f32_at("surfaces.mu")?,
                theta: f32_at("surfaces.theta")?,
                kappa: f32_at("surfaces.kappa")?,
                omega: f32_at("surfaces.omega")?,
                rho: f32_at("surfaces.rho")?,
                alpha: f32_at("surfaces.alpha")?,
                beta: f32_at("surfaces.beta")?,
                gamma: f32_at("surfaces.gamma")?,
                delta: f32_at("surfaces.delta")?,
                u_max: v
                    .get("surfaces.u_max")
                    .and_then(toml::Value::as_f32)
                    .unwrap_or_else(default_u_max),
            },
            sla: SlaConfig { l_max: f32_at("sla.l_max")?, b_sla: f32_at("sla.b_sla")? },
            policy: PolicyConfig {
                reb_h: f32_at("policy.reb_h")?,
                reb_v: f32_at("policy.reb_v")?,
                start,
                plan_queue: v
                    .get("policy.plan_queue")
                    .and_then(toml::Value::as_bool)
                    .unwrap_or(false),
            },
            workload: WorkloadConfig {
                phases,
                steps_per_phase: usize_at("workload.steps_per_phase")?,
                thr_factor: f32_at("workload.thr_factor")?,
                read_ratio: f32_at("workload.read_ratio")?,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The built-in default configuration (compiled-in copy of
    /// `config/default.toml`; calibrated against the paper's Table I).
    pub fn default_paper() -> Self {
        Self::from_toml(include_str!("../../../config/default.toml"))
            .expect("bundled default.toml must parse")
    }

    /// Sanity-check invariants the rest of the crate relies on.
    pub fn validate(&self) -> Result<()> {
        if self.plane.h_values.is_empty() {
            return Err(anyhow!("plane.h_values must be non-empty"));
        }
        if self.plane.tiers.is_empty() {
            return Err(anyhow!("plane.tiers must be non-empty"));
        }
        if self.plane.h_values.len() > self.plane.grid
            || self.plane.tiers.len() > self.plane.grid
        {
            return Err(anyhow!(
                "plane exceeds padded grid ({}x{})",
                self.plane.grid,
                self.plane.grid
            ));
        }
        if self.plane.h_values.windows(2).any(|w| w[0] >= w[1]) {
            return Err(anyhow!("plane.h_values must be strictly increasing"));
        }
        for t in &self.plane.tiers {
            if t.cpu <= 0.0 || t.ram <= 0.0 || t.bandwidth <= 0.0 || t.iops <= 0.0 {
                return Err(anyhow!("tier {} has non-positive resources", t.name));
            }
            if t.cost < 0.0 {
                return Err(anyhow!("tier {} has negative cost", t.name));
            }
        }
        if !(0.0..1.0).contains(&self.surfaces.u_max) {
            return Err(anyhow!("surfaces.u_max must be in [0, 1)"));
        }
        if self.sla.b_sla <= 0.0 {
            return Err(anyhow!("sla.b_sla must be positive"));
        }
        let [h0, v0] = self.policy.start;
        if h0 >= self.plane.h_values.len() || v0 >= self.plane.tiers.len() {
            return Err(anyhow!("policy.start out of plane bounds"));
        }
        if self.workload.phases.is_empty() || self.workload.steps_per_phase == 0 {
            return Err(anyhow!("workload must have at least one phase step"));
        }
        if !(0.0..=1.0).contains(&self.workload.read_ratio) {
            return Err(anyhow!("workload.read_ratio must be in [0, 1]"));
        }
        Ok(())
    }

    /// Build the [`ScalingPlane`] described by `[plane]`.
    pub fn plane(&self) -> ScalingPlane {
        ScalingPlane::new(
            self.plane.h_values.clone(),
            self.plane
                .tiers
                .iter()
                .map(|t| Tier {
                    name: t.name.clone(),
                    cpu: t.cpu,
                    ram: t.ram,
                    bandwidth: t.bandwidth,
                    iops: t.iops,
                    cost: t.cost,
                })
                .collect(),
        )
    }

    /// Workload write fraction (`1 - read_ratio`).
    pub fn write_ratio(&self) -> f32 {
        1.0 - self.workload.read_ratio
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_parses_and_validates() {
        let cfg = ModelConfig::default_paper();
        assert_eq!(cfg.plane.h_values, vec![1, 2, 4, 8]);
        assert_eq!(cfg.plane.tiers.len(), 4);
        assert_eq!(cfg.plane.tiers[3].name, "xlarge");
        assert_eq!(cfg.policy.start, [1, 1]);
    }

    #[test]
    fn write_ratio_complements_read_ratio() {
        let cfg = ModelConfig::default_paper();
        assert!((cfg.write_ratio() - 0.3).abs() < 1e-6);
    }

    #[test]
    fn rejects_decreasing_h_values() {
        let mut cfg = ModelConfig::default_paper();
        cfg.plane.h_values = vec![4, 2];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_zero_resources() {
        let mut cfg = ModelConfig::default_paper();
        cfg.plane.tiers[0].cpu = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_out_of_bounds_start() {
        let mut cfg = ModelConfig::default_paper();
        cfg.policy.start = [9, 0];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_bad_u_max() {
        let mut cfg = ModelConfig::default_paper();
        cfg.surfaces.u_max = 1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn missing_field_is_a_clear_error() {
        let err = ModelConfig::from_toml("[plane]\nh_values = [1, 2]\n").unwrap_err();
        assert!(format!("{err:#}").contains("plane.tiers"));
    }

    #[test]
    fn file_on_disk_matches_bundled_default() {
        // the compiled-in copy and config/default.toml must not drift
        let disk = ModelConfig::from_path(
            concat!(env!("CARGO_MANIFEST_DIR"), "/config/default.toml"),
        )
        .unwrap();
        assert_eq!(disk, ModelConfig::default_paper());
    }
}
