//! Packed parameter vector: the ABI between the rust coordinator and the
//! AOT-compiled kernels.
//!
//! Index layout MUST mirror `python/compile/defaults.py` (the python side
//! documents the authoritative table; `aot.py` stamps an `abi_version`
//! into the artifact manifest and [`crate::runtime`] refuses mismatches).

use crate::config::ModelConfig;
use crate::PARAMS_LEN;

pub const P_A: usize = 0;
pub const P_B: usize = 1;
pub const P_C: usize = 2;
pub const P_D: usize = 3;
pub const P_ETA: usize = 4;
pub const P_MU: usize = 5;
pub const P_THETA: usize = 6;
pub const P_KAPPA: usize = 7;
pub const P_OMEGA: usize = 8;
pub const P_RHO: usize = 9;
pub const P_ALPHA: usize = 10;
pub const P_BETA: usize = 11;
pub const P_GAMMA: usize = 12;
pub const P_DELTA: usize = 13;
pub const P_LAMBDA_W: usize = 14;
pub const P_LAMBDA_REQ: usize = 15;
pub const P_B_SLA: usize = 16;
pub const P_L_MAX: usize = 17;
pub const P_REB_H: usize = 18;
pub const P_REB_V: usize = 19;
pub const P_N_H: usize = 20;
pub const P_N_V: usize = 21;
pub const P_ALLOW_DH: usize = 22;
pub const P_ALLOW_DV: usize = 23;
pub const P_U_MAX: usize = 24;
pub const P_WRITE_RATIO: usize = 25;
pub const P_PLAN_QUEUE: usize = 26;

/// ABI version expected in `artifacts/manifest.json` (bumped together
/// with `python/compile/aot.py::ABI_VERSION`).
pub const ABI_VERSION: u64 = 1;

/// Movement freedom of a policy in the plane (which axes it may change).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoveFlags {
    pub allow_dh: bool,
    pub allow_dv: bool,
}

impl MoveFlags {
    pub const DIAGONAL: Self = Self { allow_dh: true, allow_dv: true };
    pub const HORIZONTAL_ONLY: Self = Self { allow_dh: true, allow_dv: false };
    pub const VERTICAL_ONLY: Self = Self { allow_dh: false, allow_dv: true };
}

/// Pack the full parameter vector for a given workload point.
///
/// `lambda_req` is the workload-derived required throughput; the write
/// arrival rate is `lambda_req * write_ratio` (paper III.E / V.C).
pub fn pack_params(
    cfg: &ModelConfig,
    lambda_req: f32,
    moves: MoveFlags,
) -> [f32; PARAMS_LEN] {
    let s = &cfg.surfaces;
    let mut p = [0.0f32; PARAMS_LEN];
    p[P_A] = s.a;
    p[P_B] = s.b;
    p[P_C] = s.c;
    p[P_D] = s.d;
    p[P_ETA] = s.eta;
    p[P_MU] = s.mu;
    p[P_THETA] = s.theta;
    p[P_KAPPA] = s.kappa;
    p[P_OMEGA] = s.omega;
    p[P_RHO] = s.rho;
    p[P_ALPHA] = s.alpha;
    p[P_BETA] = s.beta;
    p[P_GAMMA] = s.gamma;
    p[P_DELTA] = s.delta;
    p[P_LAMBDA_W] = lambda_req * cfg.write_ratio();
    p[P_LAMBDA_REQ] = lambda_req;
    p[P_B_SLA] = cfg.sla.b_sla;
    p[P_L_MAX] = cfg.sla.l_max;
    p[P_REB_H] = cfg.policy.reb_h;
    p[P_REB_V] = cfg.policy.reb_v;
    p[P_N_H] = cfg.plane.h_values.len() as f32;
    p[P_N_V] = cfg.plane.tiers.len() as f32;
    p[P_ALLOW_DH] = if moves.allow_dh { 1.0 } else { 0.0 };
    p[P_ALLOW_DV] = if moves.allow_dv { 1.0 } else { 0.0 };
    p[P_U_MAX] = s.u_max;
    p[P_WRITE_RATIO] = cfg.write_ratio();
    p[P_PLAN_QUEUE] = if cfg.policy.plan_queue { 1.0 } else { 0.0 };
    p
}

/// Padded grid arrays for the kernel ABI: `(hs[G], tiers[G*5], mask[G*G])`
/// — row-major, mirroring `defaults.grid_arrays()`.
pub fn grid_arrays(cfg: &ModelConfig) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let g = cfg.plane.grid;
    let mut hs = vec![1.0f32; g]; // benign padding: log/pow stay finite
    for (i, h) in cfg.plane.h_values.iter().enumerate() {
        hs[i] = *h as f32;
    }
    let mut tiers = vec![1.0f32; g * 5]; // benign padding: no div-by-zero
    for (j, t) in cfg.plane.tiers.iter().enumerate() {
        tiers[j * 5] = t.cpu;
        tiers[j * 5 + 1] = t.ram;
        tiers[j * 5 + 2] = t.bandwidth;
        tiers[j * 5 + 3] = t.iops / 1000.0;
        tiers[j * 5 + 4] = t.cost;
    }
    let mut mask = vec![0.0f32; g * g];
    for i in 0..cfg.plane.h_values.len() {
        for j in 0..cfg.plane.tiers.len() {
            mask[i * g + j] = 1.0;
        }
    }
    (hs, tiers, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_params_defaults() {
        let cfg = ModelConfig::default_paper();
        let p = pack_params(&cfg, 10_000.0, MoveFlags::DIAGONAL);
        assert_eq!(p[P_KAPPA], 585.0);
        assert_eq!(p[P_LAMBDA_REQ], 10_000.0);
        assert!((p[P_LAMBDA_W] - 3_000.0).abs() < 0.5);
        assert_eq!(p[P_N_H], 4.0);
        assert_eq!(p[P_ALLOW_DH], 1.0);
        assert_eq!(p[P_ALLOW_DV], 1.0);
        assert_eq!(p[P_PLAN_QUEUE], 0.0);
    }

    #[test]
    fn move_flags_restrict_axes() {
        let cfg = ModelConfig::default_paper();
        let p = pack_params(&cfg, 1.0, MoveFlags::HORIZONTAL_ONLY);
        assert_eq!((p[P_ALLOW_DH], p[P_ALLOW_DV]), (1.0, 0.0));
        let p = pack_params(&cfg, 1.0, MoveFlags::VERTICAL_ONLY);
        assert_eq!((p[P_ALLOW_DH], p[P_ALLOW_DV]), (0.0, 1.0));
    }

    #[test]
    fn grid_arrays_padded_and_masked() {
        let cfg = ModelConfig::default_paper();
        let (hs, tiers, mask) = grid_arrays(&cfg);
        assert_eq!(hs.len(), 8);
        assert_eq!(&hs[..4], &[1.0, 2.0, 4.0, 8.0]);
        assert_eq!(&hs[4..], &[1.0; 4]);
        assert_eq!(tiers.len(), 40);
        assert_eq!(tiers[5 * 3 + 3], 24.0); // xlarge iops/1000
        assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), 16);
        assert_eq!(mask[0 * 8 + 0], 1.0);
        assert_eq!(mask[3 * 8 + 3], 1.0);
        assert_eq!(mask[4 * 8 + 0], 0.0);
        assert_eq!(mask[0 * 8 + 4], 0.0);
    }
}
