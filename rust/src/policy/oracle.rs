//! Oracle policy: per-step exhaustive search over the *entire* plane
//! with no locality constraint and no rebalance penalty. Not deployable
//! (it teleports across configurations), but it lower-bounds the
//! objective any local policy can reach and upper-bounds feasibility —
//! the ablation benches compare DIAGONALSCALE against it.

use crate::plane::Configuration;
use crate::workload::WorkloadPoint;

use super::{Candidate, Policy, PolicyContext, Proposal};

/// Exhaustive global-best policy (ablation upper bound).
#[derive(Debug, Default, Clone, Copy)]
pub struct Oracle;

impl Policy for Oracle {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn cacheable(&self) -> bool {
        true
    }

    fn propose(
        &mut self,
        current: Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> Proposal {
        let model = ctx.model;
        let current_score = ctx.hold_score(&current, workload);
        // the oracle's candidate set is the whole feasible plane, ranked
        // by objective — no locality, no rebalance penalty (infeasible
        // cells are omitted: the oracle has no stepping-stone story)
        let mut candidates: Vec<Candidate> = Vec::new();
        for c in model.plane().iter() {
            if !model.feasible(&c, workload.lambda_req, ctx.sla, ctx.plan_queue) {
                continue;
            }
            let score = if ctx.plan_queue {
                model.effective_objective(&c, workload.lambda_req)
            } else {
                model.evaluate(&c, workload.lambda_req).objective
            };
            candidates.push(Candidate {
                to: c,
                cost_to: model.cost(&c),
                score,
                raw: score,
                gain: (current_score - score).max(0.0),
            });
        }
        // stable on plane iteration order: the top is best_feasible's
        // strict-< argmin
        candidates.sort_by(|a, b| a.score.total_cmp(&b.score));
        let mut p = Proposal::ranked(current, model.cost(&current), current_score, candidates);
        if p.candidates.is_empty() {
            // nothing feasible anywhere: max out the plane
            let top = Configuration::new(model.plane().n_h() - 1, model.plane().n_v() - 1);
            p.promote_fallback(top, model.cost(&top));
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::policy::DiagonalScale;
    use crate::sla::SlaSpec;
    use crate::surfaces::SurfaceModel;

    fn fixture() -> (SurfaceModel, SlaSpec) {
        let cfg = ModelConfig::default_paper();
        (SurfaceModel::from_config(&cfg), SlaSpec::from_config(&cfg))
    }

    fn ctx<'a>(m: &'a SurfaceModel, s: &'a SlaSpec) -> PolicyContext<'a> {
        PolicyContext {
            model: m,
            sla: s,
            reb_h: 2.0,
            reb_v: 1.0,
            plan_queue: false,
            future: &[],
            budget: None,
        }
    }

    #[test]
    fn oracle_at_least_as_good_as_any_neighbor() {
        let (m, s) = fixture();
        let c = ctx(&m, &s);
        let w = WorkloadPoint::new(9000.0, 0.3);
        let mut oracle = Oracle;
        let od = oracle.decide(Configuration::new(1, 1), w, &c);
        assert!(!od.fallback);
        // objective of oracle's pick <= objective part of any feasible
        // neighbor's score
        let mut ds = DiagonalScale::diagonal();
        let dd = ds.decide(Configuration::new(1, 1), w, &c);
        let oracle_obj = m.evaluate(&od.next, w.lambda_req).objective;
        let ds_obj = m.evaluate(&dd.next, w.lambda_req).objective;
        assert!(oracle_obj <= ds_obj + 1e-3);
    }

    #[test]
    fn oracle_pick_is_feasible() {
        let (m, s) = fixture();
        let c = ctx(&m, &s);
        for lam in [1000.0, 6000.0, 10000.0, 16000.0] {
            let d = Oracle.decide(Configuration::new(0, 0), WorkloadPoint::new(lam, 0.3), &c);
            assert!(!d.fallback, "lam={lam}");
            assert!(m.feasible(&d.next, lam, &s, false));
        }
    }

    #[test]
    fn oracle_falls_back_to_top_corner() {
        let (m, s) = fixture();
        let c = ctx(&m, &s);
        let d = Oracle.decide(Configuration::new(0, 0), WorkloadPoint::new(1e9, 0.3), &c);
        assert!(d.fallback);
        assert_eq!(d.next, Configuration::new(3, 3));
    }
}
