//! Forecast-driven lookahead: the deployable form of the §VIII
//! multi-step extension. [`Lookahead`] needs the future; production
//! controllers don't get one, so this policy maintains its own demand
//! predictor ([`crate::forecast`]) and expands the lookahead tree over
//! *forecasted* workloads.

use crate::config::MoveFlags;
use crate::forecast::Forecaster;
use crate::plane::Configuration;
use crate::workload::WorkloadPoint;

use super::{Lookahead, Policy, PolicyContext, Proposal};

/// Lookahead over a self-maintained forecast.
pub struct ForecastLookahead<F: Forecaster> {
    inner: Lookahead,
    forecaster: F,
    /// Write ratio stamped onto forecasted points: seeded at
    /// construction, then carried forward from the last observed
    /// workload so mix drift in the trace reaches the planner.
    write_ratio: f32,
}

impl<F: Forecaster> ForecastLookahead<F> {
    pub fn new(moves: MoveFlags, depth: usize, forecaster: F, write_ratio: f32) -> Self {
        Self { inner: Lookahead::new(moves, depth), forecaster, write_ratio }
    }

    pub fn forecaster(&self) -> &F {
        &self.forecaster
    }

    /// The write ratio currently stamped onto forecasted points (the
    /// last observed mix, or the construction seed before any
    /// observation).
    pub fn write_ratio(&self) -> f32 {
        self.write_ratio
    }
}

impl<F: Forecaster> Policy for ForecastLookahead<F> {
    fn name(&self) -> &'static str {
        "forecast-lookahead"
    }

    fn propose(
        &mut self,
        current: Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> Proposal {
        self.forecaster.observe(workload.lambda_req as f64);
        if workload.lambda_req > 0.0 {
            self.write_ratio = workload.lambda_w / workload.lambda_req;
        }
        let horizon = self.inner.depth().saturating_sub(1);
        let future: Vec<WorkloadPoint> = self
            .forecaster
            .forecast_n(horizon)
            .into_iter()
            .map(|lam| WorkloadPoint::new(lam as f32, self.write_ratio))
            .collect();
        let fctx = PolicyContext {
            model: ctx.model,
            sla: ctx.sla,
            reb_h: ctx.reb_h,
            reb_v: ctx.reb_v,
            plan_queue: ctx.plan_queue,
            future: &future,
            budget: ctx.budget,
        };
        self.inner.propose(current, workload, &fctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::forecast::{Holt, SeasonalNaive};
    use crate::simulator::{PolicyKind, Simulator};
    use crate::workload::TraceBuilder;

    fn run_forecast_policy<F: Forecaster>(
        f: F,
        trace: &crate::workload::Trace,
    ) -> crate::simulator::RunResult {
        let cfg = ModelConfig::default_paper();
        let sim = Simulator::new(&cfg);
        let mut p = ForecastLookahead::new(MoveFlags::DIAGONAL, 3, f, cfg.write_ratio());
        sim.run_boxed(&mut p, "forecast-lookahead", trace)
    }

    #[test]
    fn holds_its_own_on_the_paper_trace() {
        let cfg = ModelConfig::default_paper();
        let trace = TraceBuilder::paper(&cfg);
        let sim = Simulator::new(&cfg);
        let greedy = sim.run(PolicyKind::Diagonal, &trace);
        let fl = run_forecast_policy(Holt::default_tuned(), &trace);
        // forecasting must not be catastrophically worse than reactive
        assert!(fl.summary.violations <= greedy.summary.violations + 3);
    }

    #[test]
    fn seasonal_forecast_anticipates_a_repeating_cycle() {
        let cfg = ModelConfig::default_paper();
        let sim = Simulator::new(&cfg);
        let b = TraceBuilder::from_config(&cfg);
        // three repetitions of a short spike cycle; the seasonal
        // forecaster learns the period after one cycle
        let one = b.spike(60.0, 160.0, 10, 5, 20);
        let mut points = one.points.clone();
        points.extend(one.points.iter().copied());
        points.extend(one.points.iter().copied());
        let trace = crate::workload::Trace { name: "cycle".into(), points };

        let greedy = sim.run(PolicyKind::Diagonal, &trace);
        let fl = run_forecast_policy(SeasonalNaive::new(20), &trace);
        // after the first cycle, seasonal lookahead pre-scales for the
        // spikes the greedy policy keeps tripping over
        assert!(
            fl.summary.violations <= greedy.summary.violations,
            "forecast {} vs greedy {}",
            fl.summary.violations,
            greedy.summary.violations
        );
    }

    #[test]
    fn write_ratio_tracks_observed_mix_drift() {
        let cfg = ModelConfig::default_paper();
        let model = crate::surfaces::SurfaceModel::from_config(&cfg);
        let sla = crate::sla::SlaSpec::from_config(&cfg);
        let ctx = crate::policy::PolicyContext {
            model: &model,
            sla: &sla,
            reb_h: 2.0,
            reb_v: 1.0,
            plan_queue: false,
            future: &[],
            budget: None,
        };
        let mut p =
            ForecastLookahead::new(MoveFlags::DIAGONAL, 3, Holt::default_tuned(), 0.3);
        assert!((p.write_ratio() - 0.3).abs() < 1e-6);
        let cur = crate::plane::Configuration::new(1, 1);
        // the observed trace drifts to a 60% write mix: forecasted
        // points must carry the drifted ratio, not the seed
        p.decide(cur, WorkloadPoint::new(5000.0, 0.6), &ctx);
        assert!((p.write_ratio() - 0.6).abs() < 1e-6);
        // a zero-demand observation keeps the last ratio
        p.decide(cur, WorkloadPoint::new(0.0, 0.6), &ctx);
        assert!((p.write_ratio() - 0.6).abs() < 1e-6);
    }

    #[test]
    fn decisions_stay_local() {
        let cfg = ModelConfig::default_paper();
        let trace = TraceBuilder::paper(&cfg);
        let run = run_forecast_policy(Holt::default_tuned(), &trace);
        for w in run.records.windows(2) {
            let (dh, dv) = w[0].config.index_distance(&w[1].config);
            assert!(dh <= 1 && dv <= 1);
        }
    }
}
