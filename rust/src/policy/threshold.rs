//! HPA-style reactive threshold baseline (the policy family the paper's
//! §I.A motivation argues against): scale out when utilization crosses
//! a high-water mark, scale in below a low-water mark, with no SLA
//! feasibility reasoning and no objective function.

use crate::plane::Configuration;
use crate::workload::WorkloadPoint;

use super::{Candidate, Policy, PolicyContext, Proposal};

/// Reactive utilization-threshold autoscaler.
///
/// * `u > high` — scale out (H+1); if H is maxed, scale up (V+1).
/// * `u < low`  — scale in (H-1) if that stays under `high`; else try
///   V-1; else stay.
#[derive(Debug, Clone, Copy)]
pub struct Threshold {
    pub high: f32,
    pub low: f32,
}

impl Default for Threshold {
    fn default() -> Self {
        // Kubernetes-ish defaults: target 80%, scale-in under 30%.
        Self { high: 0.8, low: 0.3 }
    }
}

impl Threshold {
    pub fn new(high: f32, low: f32) -> Self {
        assert!(low < high, "low watermark must be below high");
        Self { high, low }
    }

    fn utilization(&self, cfg: &Configuration, w: WorkloadPoint, ctx: &PolicyContext<'_>) -> f32 {
        w.lambda_req / ctx.model.throughput(cfg).max(f32::MIN_POSITIVE)
    }
}

impl Policy for Threshold {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn cacheable(&self) -> bool {
        true
    }

    fn propose(
        &mut self,
        current: Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> Proposal {
        let plane = ctx.model.plane();
        let u = self.utilization(&current, workload, ctx);
        let next = if u > self.high {
            if current.h_idx + 1 < plane.n_h() {
                Configuration::new(current.h_idx + 1, current.v_idx)
            } else if current.v_idx + 1 < plane.n_v() {
                Configuration::new(current.h_idx, current.v_idx + 1)
            } else {
                current
            }
        } else if u < self.low {
            // prefer shedding nodes; accept only if it stays healthy
            let mut cand = current;
            if current.h_idx > 0 {
                let c = Configuration::new(current.h_idx - 1, current.v_idx);
                if self.utilization(&c, workload, ctx) < self.high {
                    cand = c;
                }
            }
            if cand == current && current.v_idx > 0 {
                let c = Configuration::new(current.h_idx, current.v_idx - 1);
                if self.utilization(&c, workload, ctx) < self.high {
                    cand = c;
                }
            }
            cand
        } else {
            current
        };
        // the candidate score stays the plain objective (what decide
        // always reported — parity); the hold anchor honors the
        // plan-queue contract of `Proposal::current_score`
        let score = ctx.model.evaluate(&next, workload.lambda_req).objective;
        let current_score = ctx.hold_score(&current, workload);
        // threshold rules have no SLA reasoning and no alternatives: the
        // proposal is the single watermark-chosen target
        Proposal::ranked(
            current,
            ctx.model.cost(&current),
            current_score,
            vec![Candidate {
                to: next,
                cost_to: ctx.model.cost(&next),
                score,
                raw: score,
                gain: (current_score - score).max(0.0),
            }],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::sla::SlaSpec;
    use crate::surfaces::SurfaceModel;

    fn fixture() -> (SurfaceModel, SlaSpec) {
        let cfg = ModelConfig::default_paper();
        (SurfaceModel::from_config(&cfg), SlaSpec::from_config(&cfg))
    }

    fn ctx<'a>(model: &'a SurfaceModel, sla: &'a SlaSpec) -> PolicyContext<'a> {
        PolicyContext {
            model,
            sla,
            reb_h: 2.0,
            reb_v: 1.0,
            plan_queue: false,
            future: &[],
            budget: None,
        }
    }

    #[test]
    fn scales_out_under_pressure() {
        let (m, s) = fixture();
        let mut p = Threshold::default();
        let cur = Configuration::new(1, 1);
        let demand = m.throughput(&cur) * 0.95;
        let d = p.decide(cur, WorkloadPoint::new(demand, 0.3), &ctx(&m, &s));
        assert_eq!(d.next, Configuration::new(2, 1));
    }

    #[test]
    fn scales_up_when_h_maxed() {
        let (m, s) = fixture();
        let mut p = Threshold::default();
        let cur = Configuration::new(3, 1);
        let demand = m.throughput(&cur) * 0.95;
        let d = p.decide(cur, WorkloadPoint::new(demand, 0.3), &ctx(&m, &s));
        assert_eq!(d.next, Configuration::new(3, 2));
    }

    #[test]
    fn scales_in_when_idle() {
        let (m, s) = fixture();
        let mut p = Threshold::default();
        let cur = Configuration::new(2, 2);
        let d = p.decide(cur, WorkloadPoint::new(10.0, 0.3), &ctx(&m, &s));
        assert_eq!(d.next, Configuration::new(1, 2));
    }

    #[test]
    fn holds_in_band() {
        let (m, s) = fixture();
        let mut p = Threshold::default();
        let cur = Configuration::new(1, 1);
        let demand = m.throughput(&cur) * 0.5;
        let d = p.decide(cur, WorkloadPoint::new(demand, 0.3), &ctx(&m, &s));
        assert_eq!(d.next, cur);
    }

    #[test]
    fn saturated_top_corner_stays() {
        let (m, s) = fixture();
        let mut p = Threshold::default();
        let cur = Configuration::new(3, 3);
        let d = p.decide(cur, WorkloadPoint::new(1e9, 0.3), &ctx(&m, &s));
        assert_eq!(d.next, cur);
    }

    #[test]
    #[should_panic]
    fn rejects_inverted_watermarks() {
        Threshold::new(0.2, 0.8);
    }
}
