//! Multi-step lookahead (paper §VIII, third extension): instead of
//! scoring only immediate neighbors, expand the neighbor tree `depth`
//! steps into a demand forecast and pick the first move of the best
//! path. Reduces transient SLA violations during sudden spikes at the
//! cost of exponentially more candidate evaluations (9^depth worst
//! case, still trivially cheap on a 4x4 plane).

use crate::config::MoveFlags;
use crate::plane::Configuration;
use crate::workload::WorkloadPoint;
use crate::INFEASIBLE;

use super::{
    rebalance_penalty, BudgetHint, Candidate, DiagonalScale, Policy, PolicyContext, Proposal,
    BUDGET_PENALTY,
};

/// Per-level penalty charged to paths that pass through an infeasible
/// configuration — large enough to dominate any objective difference,
/// small enough that *fewer* infeasible levels always wins.
const INFEASIBLE_LEVEL_PENALTY: f32 = 1.0e12;

/// Lookahead controller over a demand forecast.
#[derive(Debug, Clone, Copy)]
pub struct Lookahead {
    moves: MoveFlags,
    depth: usize,
}

impl Lookahead {
    /// `depth = 1` is exactly DIAGONALSCALE (with path-penalty scoring);
    /// the paper suggests 2–3.
    pub fn new(moves: MoveFlags, depth: usize) -> Self {
        assert!(depth >= 1, "lookahead depth must be >= 1");
        Self { moves, depth }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Path score of moving from `current` to `cand` at forecast level
    /// 0 (demand `w`), with `remaining` further levels below, paired
    /// with the level-0 myopic score (`here`) so `propose` can reuse it
    /// as `Candidate::raw` when no forecast substitutes the workload.
    /// `budget` is the fleet headroom hint charged against level-0
    /// moves only (the one actually paid this tick); deeper levels are
    /// planned budget-blind.
    #[allow(clippy::too_many_arguments)]
    fn candidate_score(
        &self,
        current: Configuration,
        cand: Configuration,
        w: WorkloadPoint,
        future: &[WorkloadPoint],
        remaining: usize,
        ctx: &PolicyContext<'_>,
        budget: Option<BudgetHint>,
    ) -> (f32, f32) {
        let here = DiagonalScale::score_candidate(&current, &cand, w, ctx);
        let mut score = if here >= INFEASIBLE * 0.5 {
            // keep expanding through infeasible states but charge them
            INFEASIBLE_LEVEL_PENALTY
                + ctx.model.evaluate(&cand, w.lambda_req).objective
                + rebalance_penalty(&current, &cand, ctx.reb_h, ctx.reb_v)
        } else {
            here
        };
        if let Some(hint) = &budget {
            if !hint.fits(ctx.model.cost(&cand) - ctx.model.cost(&current)) {
                score += BUDGET_PENALTY;
            }
        }
        if remaining > 0 {
            if let Some((&next_w, rest)) = future.split_first() {
                let (_, tail) = self.path_score(cand, next_w, rest, remaining - 1, ctx, None);
                score += tail;
            }
        }
        (score, here)
    }

    /// Best achievable path score starting by moving from `current` at
    /// one forecast level (demand `w`), with `remaining` further levels
    /// below.
    fn path_score(
        &self,
        current: Configuration,
        w: WorkloadPoint,
        future: &[WorkloadPoint],
        remaining: usize,
        ctx: &PolicyContext<'_>,
        budget: Option<BudgetHint>,
    ) -> (Configuration, f32) {
        let plane = ctx.model.plane();
        let mut best: Option<(Configuration, f32)> = None;
        for cand in plane.neighbors(&current, self.moves.allow_dh, self.moves.allow_dv) {
            let (score, _) =
                self.candidate_score(current, cand, w, future, remaining, ctx, budget);
            if best.map_or(true, |(_, b)| score < b) {
                best = Some((cand, score));
            }
        }
        // neighbors() always includes `current` itself, so best is Some.
        best.expect("neighborhood is never empty")
    }
}

impl Policy for Lookahead {
    fn name(&self) -> &'static str {
        "lookahead"
    }

    fn cacheable(&self) -> bool {
        true
    }

    fn propose(
        &mut self,
        current: Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> Proposal {
        // Serve-then-move alignment: under the simulator's semantics the
        // configuration chosen NOW serves the NEXT step's demand, so when
        // a forecast exists, level-0 candidates are scored against
        // `future[0]` (what they will actually serve) and deeper levels
        // against `future[k]`. With no forecast this degrades to the
        // paper's reactive Algorithm 1 (score against current demand).
        let (w0, rest) = match ctx.future.split_first() {
            Some((&w0, rest)) => (w0, rest),
            None => (workload, ctx.future),
        };
        let plane = ctx.model.plane();
        // `raw` and the gain anchor speak to the *observed* demand even
        // when the ranking looks ahead: downstream negotiation (the
        // fleet's alternatives/sheds) reasons about this tick.
        let current_score = ctx.hold_score(&current, workload);
        let mut candidates: Vec<Candidate> = Vec::with_capacity(9);
        for cand in plane.neighbors(&current, self.moves.allow_dh, self.moves.allow_dv) {
            let (score, here) =
                self.candidate_score(current, cand, w0, rest, self.depth - 1, ctx, ctx.budget);
            // with no forecast the level-0 demand IS the observed
            // workload, so `here` already is the myopic score; only a
            // forecast-substituted w0 needs the extra evaluation
            let raw = if ctx.future.is_empty() {
                here
            } else {
                DiagonalScale::score_candidate(&current, &cand, workload, ctx)
            };
            let gain =
                if raw >= INFEASIBLE * 0.5 { 0.0 } else { (current_score - raw).max(0.0) };
            candidates.push(Candidate {
                to: cand,
                cost_to: ctx.model.cost(&cand),
                score,
                raw,
                gain,
            });
        }
        // stable sort keeps enumeration order on ties: the top entry is
        // the strict-< argmin of the path search
        candidates.sort_by(|a, b| a.score.total_cmp(&b.score));
        let mut p = Proposal::ranked(current, ctx.model.cost(&current), current_score, candidates);
        let top = p.candidates[0];
        if top.score >= INFEASIBLE_LEVEL_PENALTY * 0.5 {
            if top.to == current {
                // nothing feasible anywhere on the path: behave like the
                // Algorithm-1 fallback so we still make progress.
                let up = plane.fallback_up(&current, self.moves.allow_dh, self.moves.allow_dv);
                p.promote_fallback(up, ctx.model.cost(&up));
            } else {
                p.fallback = true;
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::sla::SlaSpec;
    use crate::surfaces::SurfaceModel;

    fn fixture() -> (SurfaceModel, SlaSpec) {
        let cfg = ModelConfig::default_paper();
        (SurfaceModel::from_config(&cfg), SlaSpec::from_config(&cfg))
    }

    fn ctx<'a>(
        m: &'a SurfaceModel,
        s: &'a SlaSpec,
        future: &'a [WorkloadPoint],
    ) -> PolicyContext<'a> {
        PolicyContext {
            model: m,
            sla: s,
            reb_h: 2.0,
            reb_v: 1.0,
            plan_queue: false,
            future,
            budget: None,
        }
    }

    #[test]
    fn depth_one_matches_diagonal_scale_when_feasible() {
        let (m, s) = fixture();
        let c = ctx(&m, &s, &[]);
        let w = WorkloadPoint::new(9000.0, 0.3);
        for h in 0..4 {
            for v in 0..4 {
                let cur = Configuration::new(h, v);
                let la = Lookahead::new(MoveFlags::DIAGONAL, 1).decide(cur, w, &c);
                let ds = DiagonalScale::diagonal().decide(cur, w, &c);
                if !ds.fallback {
                    assert_eq!(la.next, ds.next, "at ({h},{v})");
                }
            }
        }
    }

    #[test]
    fn anticipates_a_spike() {
        let (m, s) = fixture();
        // now: low demand; next step: a spike only (H=4, xlarge)-class
        // configs can absorb.
        let now = WorkloadPoint::new(6000.0, 0.3);
        let spike = WorkloadPoint::new(16000.0, 0.3);
        let future = [spike, spike];
        let c = ctx(&m, &s, &future);

        let cur = Configuration::new(1, 3); // (H=2, xlarge)
        let greedy = DiagonalScale::diagonal().decide(cur, now, &c);
        let mut la = Lookahead::new(MoveFlags::DIAGONAL, 3);
        let ahead = la.decide(cur, now, &c);
        // greedy downsizes into the cheap region, from where no single
        // step reaches a spike-feasible config; lookahead only accepts
        // positions that keep the spike reachable.
        let plane = m.plane();
        let reaches_spike = |from: &Configuration| {
            plane
                .neighbors(from, true, true)
                .iter()
                .any(|c| m.feasible(c, spike.lambda_req, &s, false))
        };
        assert!(!reaches_spike(&greedy.next), "greedy should be trapped");
        assert!(
            reaches_spike(&ahead.next),
            "lookahead {:?} must keep the spike reachable",
            ahead.next
        );
    }

    #[test]
    fn decision_is_always_a_neighbor() {
        let (m, s) = fixture();
        let future = [WorkloadPoint::new(16000.0, 0.3); 3];
        let c = ctx(&m, &s, &future);
        let mut la = Lookahead::new(MoveFlags::DIAGONAL, 3);
        for h in 0..4 {
            for v in 0..4 {
                let cur = Configuration::new(h, v);
                let d = la.decide(cur, WorkloadPoint::new(9000.0, 0.3), &c);
                let (dh, dv) = cur.index_distance(&d.next);
                assert!(dh <= 1 && dv <= 1);
            }
        }
    }

    #[test]
    fn impossible_demand_still_scales_up() {
        let (m, s) = fixture();
        let c = ctx(&m, &s, &[]);
        let mut la = Lookahead::new(MoveFlags::DIAGONAL, 2);
        let d = la.decide(Configuration::new(0, 0), WorkloadPoint::new(1e9, 0.3), &c);
        assert!(d.fallback);
        assert!(d.next.h_idx + d.next.v_idx > 0, "must move up");
    }

    #[test]
    #[should_panic]
    fn zero_depth_rejected() {
        Lookahead::new(MoveFlags::DIAGONAL, 0);
    }
}
