//! DIAGONALSCALE (paper Algorithm 1): SLA-aware local search over the
//! horizontal, vertical, and diagonal neighbors of the current
//! configuration.
//!
//! The same implementation restricted by [`MoveFlags`] yields the
//! horizontal-only and vertical-only baselines, which (per §V.D) use the
//! identical scoring and feasibility machinery but may only move on one
//! axis.
//!
//! Candidate iteration is row-major with strict `<` improvement — the
//! exact tie-breaking order of the AOT-compiled `policy_trace` kernel,
//! so native and HLO trajectories are identical.

use crate::config::MoveFlags;
use crate::plane::Configuration;
use crate::workload::WorkloadPoint;
use crate::INFEASIBLE;

use super::{rebalance_penalty, Candidate, Policy, PolicyContext, Proposal, BUDGET_PENALTY};

/// The paper's local-search autoscaler.
#[derive(Debug, Clone, Copy)]
pub struct DiagonalScale {
    moves: MoveFlags,
}

impl DiagonalScale {
    pub fn new(moves: MoveFlags) -> Self {
        Self { moves }
    }

    /// The full diagonal policy.
    pub fn diagonal() -> Self {
        Self::new(MoveFlags::DIAGONAL)
    }

    /// Horizontal-only baseline (changes only H).
    pub fn horizontal_only() -> Self {
        Self::new(MoveFlags::HORIZONTAL_ONLY)
    }

    /// Vertical-only baseline (changes only V).
    pub fn vertical_only() -> Self {
        Self::new(MoveFlags::VERTICAL_ONLY)
    }

    pub fn moves(&self) -> MoveFlags {
        self.moves
    }

    /// Score one candidate: SLA filter (IV.C) then objective plus the
    /// rebalance penalty (IV.D). Infeasible candidates score
    /// [`INFEASIBLE`].
    pub fn score_candidate(
        current: &Configuration,
        cand: &Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> f32 {
        if !ctx
            .model
            .feasible(cand, workload.lambda_req, ctx.sla, ctx.plan_queue)
        {
            return INFEASIBLE;
        }
        let obj = if ctx.plan_queue {
            ctx.model.effective_objective(cand, workload.lambda_req)
        } else {
            ctx.model.evaluate(cand, workload.lambda_req).objective
        };
        obj + rebalance_penalty(current, cand, ctx.reb_h, ctx.reb_v)
    }
}

impl Policy for DiagonalScale {
    fn name(&self) -> &'static str {
        match (self.moves.allow_dh, self.moves.allow_dv) {
            (true, true) => "diagonal-scale",
            (true, false) => "horizontal-only",
            (false, true) => "vertical-only",
            (false, false) => "frozen",
        }
    }

    fn cacheable(&self) -> bool {
        true
    }

    fn propose(
        &mut self,
        current: Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> Proposal {
        let plane = ctx.model.plane();
        let cur_cost = ctx.model.cost(&current);
        let current_score = ctx.hold_score(&current, workload);
        let mut candidates: Vec<Candidate> = Vec::with_capacity(9);
        let mut any_feasible = false;
        plane.for_each_neighbor(&current, self.moves.allow_dh, self.moves.allow_dv, |cand| {
            let raw = Self::score_candidate(&current, &cand, workload, ctx);
            let mut score = raw;
            if raw < INFEASIBLE * 0.5 {
                any_feasible = true;
                // Budget-aware planning: a feasible candidate whose cost
                // increase does not fit the fleet headroom is kept but
                // deprioritized, so the policy prefers the best
                // *affordable* move and escalates an unaffordable one
                // only when nothing affordable is feasible. No hint (the
                // single-cluster path) leaves the kernel-parity scoring
                // untouched. Infeasible candidates keep the sentinel
                // (Algorithm 1 line 6) and trail the ranking.
                if let Some(hint) = &ctx.budget {
                    if !hint.fits(ctx.model.cost(&cand) - cur_cost) {
                        score += BUDGET_PENALTY;
                    }
                }
            }
            let gain =
                if raw >= INFEASIBLE * 0.5 { 0.0 } else { (current_score - raw).max(0.0) };
            candidates.push(Candidate {
                to: cand,
                cost_to: ctx.model.cost(&cand),
                score,
                raw,
                gain,
            });
        });
        // Stable sort from row-major enumeration order: equal scores
        // keep the kernel's candidate order, so the top entry is
        // exactly the strict-< argmin the pre-proposal decide computed.
        candidates.sort_by(|a, b| a.score.total_cmp(&b.score));
        let mut p = Proposal::ranked(current, cur_cost, current_score, candidates);
        if !any_feasible {
            // Algorithm 1 line 18: one-step scale-up fallback along the
            // axes this policy may move.
            let up = plane.fallback_up(&current, self.moves.allow_dh, self.moves.allow_dv);
            p.promote_fallback(up, ctx.model.cost(&up));
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::sla::SlaSpec;
    use crate::surfaces::SurfaceModel;

    struct Fixture {
        model: SurfaceModel,
        sla: SlaSpec,
    }

    impl Fixture {
        fn new() -> Self {
            let cfg = ModelConfig::default_paper();
            Self {
                model: SurfaceModel::from_config(&cfg),
                sla: SlaSpec::from_config(&cfg),
            }
        }

        fn ctx(&self) -> PolicyContext<'_> {
            PolicyContext {
                model: &self.model,
                sla: &self.sla,
                reb_h: 2.0,
                reb_v: 1.0,
                plan_queue: false,
                future: &[],
                budget: None,
            }
        }
    }

    #[test]
    fn chooses_feasible_neighbor_under_load() {
        let f = Fixture::new();
        let mut p = DiagonalScale::diagonal();
        let d = p.decide(
            Configuration::new(1, 1),
            WorkloadPoint::new(6000.0, 0.3),
            &f.ctx(),
        );
        assert!(!d.fallback);
        assert!(f
            .model
            .feasible(&d.next, 6000.0, &f.sla, false));
    }

    #[test]
    fn fallback_when_nothing_feasible() {
        let f = Fixture::new();
        let mut p = DiagonalScale::diagonal();
        let cur = Configuration::new(0, 0);
        let d = p.decide(cur, WorkloadPoint::new(1e9, 0.3), &f.ctx());
        assert!(d.fallback);
        assert_eq!(d.next, Configuration::new(1, 1)); // diagonal step up
    }

    #[test]
    fn fallback_respects_axis_restriction() {
        let f = Fixture::new();
        let cur = Configuration::new(0, 0);
        let w = WorkloadPoint::new(1e9, 0.3);
        let d = DiagonalScale::horizontal_only().decide(cur, w, &f.ctx());
        assert_eq!(d.next, Configuration::new(1, 0));
        let d = DiagonalScale::vertical_only().decide(cur, w, &f.ctx());
        assert_eq!(d.next, Configuration::new(0, 1));
    }

    #[test]
    fn horizontal_only_never_changes_tier() {
        let f = Fixture::new();
        let mut p = DiagonalScale::horizontal_only();
        for lam in [100.0, 6000.0, 16000.0, 1e8] {
            let d = p.decide(Configuration::new(1, 2), WorkloadPoint::new(lam, 0.3), &f.ctx());
            assert_eq!(d.next.v_idx, 2, "lam={lam}");
        }
    }

    #[test]
    fn vertical_only_never_changes_nodes() {
        let f = Fixture::new();
        let mut p = DiagonalScale::vertical_only();
        for lam in [100.0, 6000.0, 16000.0, 1e8] {
            let d = p.decide(Configuration::new(2, 1), WorkloadPoint::new(lam, 0.3), &f.ctx());
            assert_eq!(d.next.h_idx, 2, "lam={lam}");
        }
    }

    #[test]
    fn scales_down_when_load_drops() {
        let f = Fixture::new();
        let mut p = DiagonalScale::diagonal();
        // trivial load from the top corner: cheaper neighbor must win
        let d = p.decide(Configuration::new(3, 3), WorkloadPoint::new(100.0, 0.3), &f.ctx());
        let cur_cost = f.model.cost(&Configuration::new(3, 3));
        assert!(f.model.cost(&d.next) < cur_cost);
    }

    #[test]
    fn stays_put_when_current_is_best() {
        // At the optimum for its demand the penalty makes self win.
        let f = Fixture::new();
        let mut p = DiagonalScale::diagonal();
        let first = p.decide(Configuration::new(1, 1), WorkloadPoint::new(6000.0, 0.3), &f.ctx());
        let second = p.decide(first.next, WorkloadPoint::new(6000.0, 0.3), &f.ctx());
        let third = p.decide(second.next, WorkloadPoint::new(6000.0, 0.3), &f.ctx());
        assert_eq!(second.next, third.next, "policy should converge");
    }

    #[test]
    fn decision_is_always_a_neighbor() {
        let f = Fixture::new();
        let mut p = DiagonalScale::diagonal();
        for h in 0..4 {
            for v in 0..4 {
                let cur = Configuration::new(h, v);
                let d = p.decide(cur, WorkloadPoint::new(9000.0, 0.3), &f.ctx());
                let (dh, dv) = cur.index_distance(&d.next);
                assert!(dh <= 1 && dv <= 1);
            }
        }
    }

    #[test]
    fn budget_hint_prefers_affordable_feasible_candidates() {
        use crate::policy::BudgetHint;
        let f = Fixture::new();
        let mut p = DiagonalScale::diagonal();
        // At (H=2, medium) under lambda 6000 holding is infeasible and
        // every feasible neighbor costs more: a zero-headroom hint
        // penalizes them all equally, so the decision matches the
        // unbudgeted one (the emergency still surfaces).
        let cur = Configuration::new(1, 1);
        let w = WorkloadPoint::new(6000.0, 0.3);
        let free = p.decide(cur, w, &f.ctx());
        let ctx_tight = PolicyContext { budget: Some(BudgetHint::new(1.0e9, 1.0e9)), ..f.ctx() };
        // an effectively unlimited hint never changes the decision
        assert_eq!(p.decide(cur, w, &ctx_tight).next, free.next);
        // zero headroom: cost increases are penalized, so if any
        // feasible non-increasing candidate exists it wins
        let ctx_zero = PolicyContext { budget: Some(BudgetHint::new(0.0, 0.0)), ..f.ctx() };
        let d = p.decide(cur, w, &ctx_zero);
        let model = &f.model;
        let affordable_feasible = model
            .plane()
            .neighbors(&cur, true, true)
            .into_iter()
            .any(|c| {
                model.cost(&c) <= model.cost(&cur)
                    && model.feasible(&c, w.lambda_req, &f.sla, false)
            });
        if affordable_feasible {
            assert!(model.cost(&d.next) <= model.cost(&cur), "picked {:?}", d.next);
        }
        assert!(!d.fallback);
        // At (H=2, large) under calm demand the objective-best neighbor
        // is the upgrade to (H=1, xlarge) (+0.1/h); holding still is the
        // best *free* feasible option. The hint must flip between them.
        let cur = Configuration::new(1, 2);
        let free = p.decide(cur, w, &f.ctx());
        assert_eq!(free.next, Configuration::new(0, 3));
        let ctx_rich = PolicyContext { budget: Some(BudgetHint::new(1.0e9, 1.0e9)), ..f.ctx() };
        assert_eq!(p.decide(cur, w, &ctx_rich).next, free.next);
        let d = p.decide(cur, w, &ctx_zero);
        assert_eq!(d.next, cur, "zero headroom must hold at (1,2)");
        assert!(!d.fallback);
        // when nothing affordable is feasible, the policy still
        // escalates to the unaffordable best (emergencies surface)
        let hot = WorkloadPoint::new(10_000.0, 0.3);
        let d = p.decide(Configuration::new(0, 3), hot, &ctx_zero);
        assert_eq!(d.next, Configuration::new(1, 3));
        assert!(!d.fallback);
    }

    #[test]
    fn infeasible_score_is_sentinel() {
        let f = Fixture::new();
        let ctx = f.ctx();
        let s = DiagonalScale::score_candidate(
            &Configuration::new(0, 0),
            &Configuration::new(0, 0),
            WorkloadPoint::new(1e9, 0.3),
            &ctx,
        );
        assert_eq!(s, INFEASIBLE);
    }
}
