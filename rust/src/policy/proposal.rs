//! The proposal vocabulary: a ranked candidate list is the native
//! output of every policy, not an afterthought bolted onto the fleet.
//!
//! Algorithm 1 already *enumerates and scores* the whole neighborhood
//! each tick; [`Proposal`] keeps that work instead of throwing away
//! everything but the argmin. A proposal carries, best ranking score
//! first:
//!
//! * the policy's preferred move (the old `decide` answer — always
//!   `candidates[0]`, pinned bit-identical by `rust/tests/prop_policy.rs`),
//! * every other scored candidate, feasible ones ahead of infeasible
//!   ones (infeasible entries score [`crate::INFEASIBLE`] and trail the
//!   list — they are stepping-stone vocabulary for SLA repairs, not
//!   actuation targets),
//! * and, on admission-side proposals (built by `fleet::Tenant` and
//!   `placement`), *shed offers*: feasible cost-decreasing moves a
//!   non-repairing tenant volunteers as funding for someone else's SLA
//!   repair.
//!
//! Each [`Candidate`] carries two scores and a gain:
//!
//! * `score` — the *ranking* score: objective + rebalance penalty, plus
//!   [`super::BUDGET_PENALTY`] when the move does not fit the budget
//!   hint, plus lookahead path penalties for multi-step policies. This
//!   is exactly what `decide` reports for the top candidate.
//! * `raw` — the budget-blind *myopic* score of the candidate for the
//!   observed workload (no budget penalty, no path terms);
//!   [`crate::INFEASIBLE`] when the configuration is SLA-infeasible for
//!   it. Downstream consumers (the fleet tenant's audit bookkeeping)
//!   rank alternatives and sheds by `raw`, so forecast-driven policies
//!   still negotiate in this-tick terms.
//! * `gain` — a non-negative weight whose meaning depends on the list
//!   it sits in: for move candidates it is the objective *improvement*
//!   claimed over holding (zero for fallbacks and stepping stones); for
//!   shed offers it is the objective *sacrifice* the downgrade costs
//!   its owner (the arbiter drains least-sacrifice offers first).

use crate::plane::Configuration;
use crate::INFEASIBLE;

use super::Decision;

/// Admission priority of a tenant. Ties in the arbiter's knapsack break
/// toward the higher class (`Bronze < Silver < Gold`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PriorityClass {
    Bronze,
    Silver,
    Gold,
}

impl PriorityClass {
    /// All classes, highest priority first.
    pub const ALL: [PriorityClass; 3] =
        [PriorityClass::Gold, PriorityClass::Silver, PriorityClass::Bronze];

    pub fn label(&self) -> &'static str {
        match self {
            PriorityClass::Gold => "gold",
            PriorityClass::Silver => "silver",
            PriorityClass::Bronze => "bronze",
        }
    }

    /// Numeric rank; higher admits first.
    pub fn rank(&self) -> u8 {
        match self {
            PriorityClass::Gold => 2,
            PriorityClass::Silver => 1,
            PriorityClass::Bronze => 0,
        }
    }

    /// Inverse of [`Self::rank`] (ranks above Gold clamp to Gold).
    pub fn from_rank(rank: u8) -> Self {
        match rank {
            0 => PriorityClass::Bronze,
            1 => PriorityClass::Silver,
            _ => PriorityClass::Gold,
        }
    }
}

impl Default for PriorityClass {
    /// Policy-side proposals default to the lowest class; the fleet
    /// tenant stamps the real one when it distills an admission
    /// proposal.
    fn default() -> Self {
        PriorityClass::Bronze
    }
}

/// One ranked option within a proposal: a target configuration with its
/// hourly cost, its ranking and myopic scores, and its claimed weight.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub to: Configuration,
    /// Hourly cost of the target configuration.
    pub cost_to: f32,
    /// Ranking score — what `decide` reports when this candidate tops
    /// the list (objective + rebalance penalty, budget/path penalties
    /// included; [`crate::INFEASIBLE`] when SLA-infeasible).
    pub score: f32,
    /// Budget-blind myopic score vs the observed workload
    /// ([`crate::INFEASIBLE`] when SLA-infeasible for it).
    pub raw: f32,
    /// Objective improvement (moves) or sacrifice (sheds); >= 0.
    pub gain: f32,
}

impl Candidate {
    /// An admission-side candidate priced by the caller (arbiter tests,
    /// placement bundles) whose planner scores are not meaningful.
    pub fn priced(to: Configuration, cost_to: f32, gain: f32) -> Self {
        Self { to, cost_to, score: 0.0, raw: 0.0, gain }
    }

    /// SLA-feasible for the workload it was scored against.
    pub fn feasible(&self) -> bool {
        self.raw < INFEASIBLE * 0.5
    }
}

/// Cap on ranked alternatives behind the best candidate in an
/// *admission* proposal — distilled lists stay short so the arbiter
/// walk is O(1) per tenant. Policy-side proposals are uncapped (the
/// whole scored neighborhood, at most 9 entries on the plane).
pub const MAX_ALTERNATIVES: usize = 3;

/// A ranked proposal — the outcome of one decision point.
///
/// Two conventions share this type, distinguished by who built it:
///
/// * **Policy proposals** ([`super::Policy::propose`]) rank *every*
///   scored candidate, holding (`from` itself) included; the list is
///   never empty and `candidates[0]` is exactly the old `decide`
///   answer ([`Self::decision`] reconstructs it). Fleet bookkeeping
///   fields (`tenant`, `class`, `denial_streak`, `sheds`) sit at their
///   defaults.
/// * **Admission proposals** (`fleet::Tenant::propose`, `placement`)
///   distill a policy proposal for the budget arbiter: candidates are
///   strict *moves* (an empty list means the tenant holds), capped at
///   1 + [`MAX_ALTERNATIVES`] (+ a repair stepping stone), and shed
///   offers are populated for non-repairing tenants.
#[derive(Debug, Clone, PartialEq)]
pub struct Proposal {
    /// Tenant slot in the fleet batch (0 for single-cluster proposals).
    pub tenant: usize,
    pub class: PriorityClass,
    pub from: Configuration,
    /// Hourly cost of the configuration currently serving.
    pub cost_from: f32,
    /// Budget-blind myopic score of holding `from` for the observed
    /// workload (plan-queue aware, never masked to INFEASIBLE) — the
    /// anchor `gain` values are measured against.
    pub current_score: f32,
    /// SLA emergency: the Algorithm-1 fallback fired, or the current
    /// configuration is planner-infeasible for this tick's demand.
    pub emergency: bool,
    /// The tenant's last served step violated its SLA.
    pub sla_violating: bool,
    /// Consecutive ticks this tenant has been denied while
    /// SLA-violating (the fairness guard's counter).
    pub denial_streak: usize,
    /// No candidate was SLA-feasible and the one-step scale-up fallback
    /// was taken (Algorithm 1 line 18); `candidates[0]` is the fallback.
    pub fallback: bool,
    /// Ranked candidates, best ranking score first. Policy proposals:
    /// the full scored neighborhood (holding included, infeasible
    /// entries trailing). Admission proposals: strict moves only; empty
    /// means the tenant holds.
    pub candidates: Vec<Candidate>,
    /// Feasible cost-decreasing fallbacks this (non-repairing) tenant
    /// offers as burst funding for other tenants' SLA repairs, least
    /// objective sacrifice first (each `gain` is that sacrifice). The
    /// arbiter draws at most the first offer per tick — configurations
    /// move one neighbor step per tick, and the deeper offers document
    /// the next rungs a multi-tick drain would take.
    pub sheds: Vec<Candidate>,
}

impl Proposal {
    /// A policy-side proposal: the ranked enumeration for one decision
    /// point, fleet bookkeeping fields at their defaults.
    pub fn ranked(
        from: Configuration,
        cost_from: f32,
        current_score: f32,
        candidates: Vec<Candidate>,
    ) -> Self {
        Self {
            tenant: 0,
            class: PriorityClass::default(),
            from,
            cost_from,
            current_score,
            emergency: false,
            sla_violating: false,
            denial_streak: 0,
            fallback: false,
            candidates,
            sheds: Vec::new(),
        }
    }

    /// The top-ranked candidate — `decide`'s answer on policy
    /// proposals, the preferred move on admission proposals.
    pub fn top(&self) -> Option<&Candidate> {
        self.candidates.first()
    }

    /// The preferred move, if the proposal is not a hold (admission
    /// naming for [`Self::top`]).
    pub fn best(&self) -> Option<&Candidate> {
        self.candidates.first()
    }

    /// Collapse the ranked list back to the single-answer [`Decision`]
    /// the pre-proposal API returned: the top candidate, or holding at
    /// `from` when the list is empty.
    pub fn decision(&self) -> Decision {
        match self.candidates.first() {
            Some(c) => Decision { next: c.to, score: c.score, fallback: self.fallback },
            None => {
                Decision { next: self.from, score: self.current_score, fallback: self.fallback }
            }
        }
    }

    /// Mark this proposal as an Algorithm-1 fallback: promote `up` (the
    /// one-step scale-up) to the top of the list at the
    /// [`crate::INFEASIBLE`] sentinel score, deduplicating the entry
    /// the enumeration already produced for it (its myopic `raw` and
    /// gain survive the promotion).
    pub fn promote_fallback(&mut self, up: Configuration, cost_up: f32) {
        let raw = self
            .candidates
            .iter()
            .position(|c| c.to == up)
            .map(|i| self.candidates.remove(i).raw)
            .unwrap_or(INFEASIBLE);
        let gain =
            if raw >= INFEASIBLE * 0.5 { 0.0 } else { (self.current_score - raw).max(0.0) };
        self.candidates.insert(
            0,
            Candidate { to: up, cost_to: cost_up, score: INFEASIBLE, raw, gain },
        );
        self.fallback = true;
    }

    /// Whether the candidate list is sorted by ranking score (best
    /// first). The promoted fallback head is exempt: it carries the
    /// sentinel score by construction.
    pub fn is_ranked(&self) -> bool {
        let skip = usize::from(self.fallback);
        let tail = self.candidates.get(skip..).unwrap_or(&[]);
        tail.windows(2)
            .all(|w| w[0].score.total_cmp(&w[1].score) != std::cmp::Ordering::Greater)
    }

    /// Whether the proposal requests any configuration change
    /// (admission convention: an empty list is a hold).
    pub fn is_move(&self) -> bool {
        !self.candidates.is_empty()
    }

    /// Marginal fleet cost of admitting the preferred move (0 for
    /// holds).
    pub fn cost_delta(&self) -> f32 {
        self.best().map_or(0.0, |c| c.cost_to - self.cost_from)
    }

    /// Whether this proposal repairs the tenant's own SLA (emergency or
    /// currently violating) — repair moves outrank economic moves
    /// fleet-wide and may draw shed funding.
    pub fn is_repair(&self) -> bool {
        self.emergency || self.sla_violating
    }

    /// Greedy-knapsack value density of the preferred move: claimed
    /// gain per added dollar. SLA emergencies outrank any economic
    /// move.
    pub fn density(&self) -> f32 {
        if self.emergency {
            return INFEASIBLE;
        }
        self.best().map_or(0.0, |c| c.gain / (c.cost_to - self.cost_from).max(1e-6))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(h: usize, v: usize, score: f32) -> Candidate {
        Candidate { to: Configuration::new(h, v), cost_to: 1.0, score, raw: score, gain: 0.0 }
    }

    #[test]
    fn decision_is_the_top_candidate() {
        let p = Proposal::ranked(
            Configuration::new(1, 1),
            0.4,
            7.0,
            vec![cand(2, 2, 1.0), cand(1, 1, 2.0)],
        );
        let d = p.decision();
        assert_eq!(d.next, Configuration::new(2, 2));
        assert_eq!(d.score, 1.0);
        assert!(!d.fallback);
        assert!(p.is_ranked());
    }

    #[test]
    fn empty_candidates_decide_to_hold() {
        let p = Proposal::ranked(Configuration::new(1, 2), 0.8, 5.0, Vec::new());
        let d = p.decision();
        assert_eq!(d.next, Configuration::new(1, 2));
        assert_eq!(d.score, 5.0);
        assert!(!p.is_move());
        assert_eq!(p.cost_delta(), 0.0);
    }

    #[test]
    fn promote_fallback_deduplicates_and_leads() {
        let mut p = Proposal::ranked(
            Configuration::new(0, 0),
            0.08,
            3.0,
            vec![cand(0, 0, INFEASIBLE), cand(1, 1, INFEASIBLE)],
        );
        p.promote_fallback(Configuration::new(1, 1), 0.4);
        assert!(p.fallback);
        assert_eq!(p.candidates.len(), 2, "the existing (1,1) entry was deduplicated");
        let d = p.decision();
        assert_eq!(d.next, Configuration::new(1, 1));
        assert_eq!(d.score, INFEASIBLE);
        assert!(d.fallback);
        // no duplicate configurations survive the promotion
        for (i, a) in p.candidates.iter().enumerate() {
            for b in &p.candidates[i + 1..] {
                assert_ne!(a.to, b.to);
            }
        }
    }

    #[test]
    fn priced_candidates_read_as_feasible() {
        let c = Candidate::priced(Configuration::new(1, 0), 0.2, 1.5);
        assert!(c.feasible());
        assert_eq!(c.gain, 1.5);
        assert!(!cand(0, 0, INFEASIBLE).feasible());
    }

    #[test]
    fn class_order_and_rank_agree() {
        assert!(PriorityClass::Bronze < PriorityClass::Silver);
        assert!(PriorityClass::Silver < PriorityClass::Gold);
        assert!(PriorityClass::Gold.rank() > PriorityClass::Bronze.rank());
        assert_eq!(PriorityClass::ALL[0], PriorityClass::Gold);
        assert_eq!(PriorityClass::from_rank(1), PriorityClass::Silver);
        assert_eq!(PriorityClass::default(), PriorityClass::Bronze);
    }
}
