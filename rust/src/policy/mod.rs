//! Autoscaling policies over the Scaling Plane — **proposal-first**.
//!
//! [`DiagonalScale`] is the paper's contribution (Algorithm 1); the same
//! implementation restricted to one axis yields the horizontal-only and
//! vertical-only baselines (§V.D). [`Threshold`] is the HPA-style
//! reactive strawman the paper's introduction argues against,
//! [`Oracle`] is the per-step global optimum (upper bound), and
//! [`Lookahead`] / [`ForecastLookahead`] are the §VIII multi-step
//! extensions. [`StaticPolicy`] never moves (do-nothing baseline).
//!
//! ## The decision vocabulary
//!
//! Algorithm 1 enumerates and scores a full candidate set every tick;
//! since PR 5 that set *is* the policy's output. [`Policy::propose`] is
//! the required method and returns a [`Proposal`] — the ranked
//! enumeration, best candidate first — while [`Policy::decide`] is a
//! provided method that collapses the proposal to its top candidate
//! (bit-identical to the pre-proposal `decide`, pinned by
//! `rust/tests/prop_policy.rs`). Everything downstream speaks this one
//! vocabulary:
//!
//! * the [`crate::coordinator`] walks the ranked list when a
//!   [`crate::coordinator::MoveGuard`] rejects the first choice
//!   (degradation instead of a frozen cluster),
//! * the fleet tenant distills the proposal into an admission proposal
//!   (strict moves, capped alternatives, an SLA-repair *stepping stone*
//!   that monotonically approaches the cheapest audit-clearing config,
//!   and *shed offers* — see [`proposal`]) without re-enumerating the
//!   neighborhood,
//! * and the budget arbiter walks candidates/sheds to degrade, fund,
//!   and re-negotiate instead of flat-denying.
//!
//! [`Candidate::score`] is the ranking score (`decide`'s reported
//! score: objective + rebalance penalty + budget/path penalties);
//! [`Candidate::raw`] is the budget-blind myopic score against the
//! observed workload; [`Candidate::gain`] claims the objective
//! improvement over holding (or, on shed offers, the sacrifice).
//! Infeasible candidates score [`crate::INFEASIBLE`] and trail the
//! list — stepping-stone vocabulary, not actuation targets.

mod diagonal;
mod forecast;
mod lookahead;
mod oracle;
pub mod proposal;
mod threshold;

pub use diagonal::DiagonalScale;
pub use forecast::ForecastLookahead;
pub use lookahead::Lookahead;
pub use oracle::Oracle;
pub use proposal::{Candidate, PriorityClass, Proposal, MAX_ALTERNATIVES};
pub use threshold::Threshold;

use crate::config::MoveFlags;
use crate::plane::Configuration;
use crate::sla::SlaSpec;
use crate::surfaces::SurfaceModel;
use crate::workload::WorkloadPoint;

/// Soft score penalty for candidates whose cost increase does not fit
/// the fleet budget hint: large enough to dominate any objective
/// difference, small enough that SLA feasibility (and the lookahead's
/// [`crate::INFEASIBLE`]-level path penalties) still outranks it. With
/// no hint in the context the penalty never applies and every policy is
/// bit-identical to its budget-blind form (kernel parity preserved).
pub const BUDGET_PENALTY: f32 = 1.0e11;

/// Fleet budget headroom handed to a tenant's policy so proposals are
/// shaped to what the arbiter can actually admit (cost-aware planning
/// inside the policy, not just filtering by the arbiter).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetHint {
    /// Fleet-wide headroom: budget minus current fleet spend.
    pub fleet_headroom: f32,
    /// Headroom within the tenant's class envelope, burst credits
    /// included (equals `fleet_headroom` when envelopes are off).
    pub class_headroom: f32,
}

impl BudgetHint {
    pub fn new(fleet_headroom: f32, class_headroom: f32) -> Self {
        Self { fleet_headroom, class_headroom }
    }

    /// Headroom a cost increase must fit into.
    pub fn headroom(&self) -> f32 {
        self.fleet_headroom.min(self.class_headroom)
    }

    /// Whether a move with this cost delta fits the hinted headroom.
    pub fn fits(&self, cost_delta: f32) -> bool {
        cost_delta <= self.headroom()
    }
}

/// Shared read-only state handed to a policy at each decision point.
pub struct PolicyContext<'a> {
    pub model: &'a SurfaceModel,
    pub sla: &'a SlaSpec,
    /// Rebalance penalty weights (paper IV.D).
    pub reb_h: f32,
    pub reb_v: f32,
    /// Planner uses queueing-corrected latency (paper VIII extension).
    pub plan_queue: bool,
    /// Future demand, if the controller has a forecast (used by
    /// [`Lookahead`]; empty for purely reactive policies).
    pub future: &'a [WorkloadPoint],
    /// Fleet budget headroom, if a budget arbiter governs this tenant
    /// (`None` outside the fleet: single-cluster runs are budget-blind).
    pub budget: Option<BudgetHint>,
}

impl PolicyContext<'_> {
    /// The budget-blind myopic score of holding `current` for
    /// `workload` (plan-queue aware, never masked to
    /// [`crate::INFEASIBLE`]) — the anchor proposals measure candidate
    /// gains against.
    pub fn hold_score(&self, current: &Configuration, workload: WorkloadPoint) -> f32 {
        if self.plan_queue {
            self.model.effective_objective(current, workload.lambda_req)
        } else {
            self.model.evaluate(current, workload.lambda_req).objective
        }
    }
}

/// The outcome of one decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub next: Configuration,
    /// Score of the chosen candidate (objective + rebalance penalty),
    /// or [`crate::INFEASIBLE`] when the fallback fired.
    pub score: f32,
    /// True when no candidate was SLA-feasible and the one-step
    /// scale-up fallback was taken (Algorithm 1 line 18).
    pub fallback: bool,
}

/// An autoscaling policy: a (possibly stateful) map from
/// (configuration, workload) to a ranked [`Proposal`].
///
/// `propose` is the required method; `decide` is provided and returns
/// the proposal's top candidate as a [`Decision`]. Implementations
/// must rank candidates best-score-first (stable on enumeration order,
/// so the top is exactly the strict-`<` argmin the AOT kernels
/// compute) and list each configuration at most once.
pub trait Policy {
    fn name(&self) -> &'static str;

    /// The full ranked proposal for one decision point.
    fn propose(
        &mut self,
        current: Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> Proposal;

    /// The top candidate as a [`Decision`] (provided; bit-identical to
    /// the pre-proposal `decide` for every in-tree policy).
    fn decide(
        &mut self,
        current: Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> Decision {
        self.propose(current, workload, ctx).decision()
    }

    /// Whether [`Policy::propose`] is a pure function of
    /// `(current, workload, ctx)` — no internal state observed or
    /// mutated — so the fleet's dirty queue may replay a cached hold
    /// instead of re-invoking it when none of those inputs changed.
    ///
    /// Defaults to `false`: a stateful policy (or any external
    /// implementor that doesn't audit its own purity) is re-run every
    /// tick, which is always correct, merely slower.
    /// [`ForecastLookahead`] keeps the default because `propose` feeds
    /// its demand predictor; skipping calls would change its forecasts.
    fn cacheable(&self) -> bool {
        false
    }
}

/// The paper IV.D rebalance penalty between two configurations:
/// `R = reb_h * |dH idx| + reb_v * |dV idx|`.
pub fn rebalance_penalty(
    from: &Configuration,
    to: &Configuration,
    reb_h: f32,
    reb_v: f32,
) -> f32 {
    let (dh, dv) = from.index_distance(to);
    reb_h * dh as f32 + reb_v * dv as f32
}

/// A policy that never moves — the "no autoscaling" baseline.
#[derive(Debug, Default)]
pub struct StaticPolicy;

impl Policy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn cacheable(&self) -> bool {
        true
    }

    fn propose(
        &mut self,
        current: Configuration,
        workload: WorkloadPoint,
        ctx: &PolicyContext<'_>,
    ) -> Proposal {
        // candidate score = the plain objective decide always reported
        // (parity); the hold anchor honors the plan-queue contract of
        // `Proposal::current_score`
        let obj = ctx.model.evaluate(&current, workload.lambda_req).objective;
        Proposal::ranked(
            current,
            ctx.model.cost(&current),
            ctx.hold_score(&current, workload),
            vec![Candidate {
                to: current,
                cost_to: ctx.model.cost(&current),
                score: obj,
                raw: obj,
                gain: 0.0,
            }],
        )
    }
}

/// Construct the paper's three compared policies (§V.D).
pub fn paper_policies() -> Vec<(MoveFlags, Box<dyn Policy>)> {
    vec![
        (MoveFlags::DIAGONAL, Box::new(DiagonalScale::new(MoveFlags::DIAGONAL))),
        (
            MoveFlags::HORIZONTAL_ONLY,
            Box::new(DiagonalScale::new(MoveFlags::HORIZONTAL_ONLY)),
        ),
        (
            MoveFlags::VERTICAL_ONLY,
            Box::new(DiagonalScale::new(MoveFlags::VERTICAL_ONLY)),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn rebalance_penalty_weights_h_double() {
        let cfg = ModelConfig::default_paper();
        let a = Configuration::new(1, 1);
        let h_move = Configuration::new(2, 1);
        let v_move = Configuration::new(1, 2);
        let rh = rebalance_penalty(&a, &h_move, cfg.policy.reb_h, cfg.policy.reb_v);
        let rv = rebalance_penalty(&a, &v_move, cfg.policy.reb_h, cfg.policy.reb_v);
        assert_eq!(rh, 2.0);
        assert_eq!(rv, 1.0);
        assert_eq!(rebalance_penalty(&a, &a, 2.0, 1.0), 0.0);
    }

    #[test]
    fn rebalance_penalty_symmetric() {
        let a = Configuration::new(0, 3);
        let b = Configuration::new(2, 1);
        assert_eq!(rebalance_penalty(&a, &b, 2.0, 1.0), rebalance_penalty(&b, &a, 2.0, 1.0));
        assert_eq!(rebalance_penalty(&a, &b, 2.0, 1.0), 6.0);
    }

    #[test]
    fn budget_hint_headroom_is_the_binding_minimum() {
        let h = BudgetHint::new(1.5, 0.4);
        assert_eq!(h.headroom(), 0.4);
        assert!(h.fits(0.4));
        assert!(!h.fits(0.41));
        // shrinks always fit
        assert!(h.fits(-1.0));
        assert!(BUDGET_PENALTY < crate::INFEASIBLE);
    }

    #[test]
    fn static_policy_never_moves() {
        let cfg = ModelConfig::default_paper();
        let model = SurfaceModel::from_config(&cfg);
        let sla = SlaSpec::from_config(&cfg);
        let ctx = PolicyContext {
            model: &model,
            sla: &sla,
            reb_h: 2.0,
            reb_v: 1.0,
            plan_queue: false,
            future: &[],
            budget: None,
        };
        let mut p = StaticPolicy;
        let c = Configuration::new(2, 2);
        let prop = p.propose(c, WorkloadPoint::new(1e9, 0.3), &ctx);
        assert_eq!(prop.candidates.len(), 1);
        let d = p.decide(c, WorkloadPoint::new(1e9, 0.3), &ctx);
        assert_eq!(d.next, c);
        assert!(!d.fallback);
        assert_eq!(prop.decision(), d);
    }
}
