//! The autoscaler control loop — the deployable form of the paper's
//! contribution. Each tick it: (1) serves the interval's demand on the
//! Phase-2 cluster substrate, (2) estimates demand (EWMA over observed
//! offered load), (3) runs the planning policy against the analytical
//! surfaces — natively or through the AOT-compiled PJRT kernels —
//! and (4) actuates the chosen configuration, paying the physical
//! rebalance cost.
//!
//! [`Coordinator::run_trace`] is the synchronous driver used by the
//! examples and benches; [`Coordinator::run_daemon`] wraps the same
//! tick in a channel-fed loop suitable for running on its own thread
//! (`std::sync::mpsc` — the offline build has no async runtime).
//!
//! The coordinator is generic over [`Substrate`], so the same control
//! loop drives the legacy sampling engine ([`ClusterSim`], the default
//! type parameter), the event-driven engine
//! ([`crate::cluster::EventSim`]), or the analytical wrapper
//! ([`crate::simulator::AnalyticalSubstrate`]).
//!
//! Since PR 5 the coordinator consumes ranked [`Proposal`]s rather than
//! single decisions: when a [`MoveGuard`] (spend caps, change windows —
//! anything that can veto an actuation) rejects the policy's first
//! choice, the coordinator *walks the alternative list* and actuates
//! the best admitted candidate instead of freezing
//! (degradation-aware stepping; [`TickReport::chosen_rank`] records
//! which rank actuated). It can also feed every [`Substrate::observe`]
//! snapshot into an online surface refit
//! ([`Coordinator::enable_online_calibration`], CLI
//! `cluster --calibrate-online`): measured latency/capacity
//! observations recalibrate the planning surfaces on the decision path
//! every few ticks, closing the ROADMAP's calibration loop for
//! single-cluster runs.

use std::sync::mpsc;

use anyhow::Result;

use crate::calibrate::{Calibrator, Observation};
use crate::cluster::{ClusterParams, ClusterSim, ClusterStepMetrics, EventSim, Substrate};
use crate::config::{MoveFlags, ModelConfig, SurfaceConfig};
use crate::plane::Configuration;
use crate::policy::{Candidate, Policy, PolicyContext, Proposal};
use crate::runtime::SurfaceEngine;
use crate::sla::SlaSpec;
use crate::surfaces::SurfaceModel;
use crate::workload::{Trace, WorkloadPoint};
use crate::INFEASIBLE;

/// Where neighbor scoring happens.
pub enum Backend {
    /// Native rust surfaces.
    Native(Box<dyn Policy + Send>),
    /// AOT-compiled Pallas kernels through PJRT (the `neighbor`
    /// artifact); Algorithm-1 argmin stays in rust.
    Hlo { engine: SurfaceEngine, moves: MoveFlags },
}

/// A veto on actuations: anything that can reject a candidate move —
/// spend caps, maintenance windows, external change control. The
/// coordinator walks the ranked candidate list and actuates the first
/// admitted entry; if the guard rejects everything it holds.
pub trait MoveGuard: Send {
    /// May the coordinator actuate `cand` from `from` this tick?
    fn admit(&mut self, from: &Configuration, cand: &Candidate) -> bool;
}

/// The simplest [`MoveGuard`]: reject any candidate whose hourly cost
/// exceeds a cap (a single-cluster spend ceiling).
#[derive(Debug, Clone, Copy)]
pub struct CostCapGuard {
    pub cap: f32,
}

impl MoveGuard for CostCapGuard {
    fn admit(&mut self, _from: &Configuration, cand: &Candidate) -> bool {
        cand.cost_to <= self.cap
    }
}

/// Minimum calibrator samples before the first online refit fires.
const MIN_CALIBRATION_OBS: usize = 8;
/// Minimum *distinct* configurations observed before a refit: with
/// fewer, the 3-parameter latency fit is exactly determined (any theta
/// interpolates the samples) and extrapolates arbitrarily badly.
const MIN_CALIBRATION_CONFIGS: usize = 4;

/// Online surface-refit state: observations stream in from the
/// substrate, the planning model is rebuilt on a cadence.
struct OnlineCalibration {
    cal: Calibrator,
    refit_every: usize,
    l_max: f32,
    u_max: f32,
    write_ratio: f32,
    observed: usize,
    refits: usize,
    /// Distinct configurations observed so far (the plane holds 16, so
    /// a Vec scan is cheaper than hashing).
    seen: Vec<Configuration>,
}

impl OnlineCalibration {
    /// Enough coverage for a well-posed refit: the latency fit is
    /// overdetermined and the throughput fit sees at least two distinct
    /// H values.
    fn coverage_ok(&self) -> bool {
        self.seen.len() >= MIN_CALIBRATION_CONFIGS
            && self.seen.iter().map(|c| c.h_idx).collect::<std::collections::BTreeSet<_>>().len()
                >= 2
    }
}

/// One coordinator tick's record.
#[derive(Debug, Clone)]
pub struct TickReport {
    pub step: usize,
    pub served_config: Configuration,
    pub next_config: Configuration,
    pub demand: f32,
    pub demand_estimate: f32,
    pub metrics: ClusterStepMetrics,
    pub rebalanced: bool,
    pub moved_shards: usize,
    /// Measured SLA violation: p99 over the bound, or throughput short.
    pub violation: bool,
    /// Rank of the actuated candidate in the ranked proposal (0 = the
    /// policy's first choice; higher = the guard degraded the move).
    /// `None` when a [`MoveGuard`] rejected every candidate and the
    /// coordinator held.
    pub chosen_rank: Option<usize>,
    /// Top-k ranked candidates for this tick's decision (empty unless
    /// [`Coordinator::set_explain`] enabled the dump).
    pub explain: Vec<Candidate>,
}

/// Aggregate over a coordinator run.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorSummary {
    pub steps: usize,
    pub violations: usize,
    pub avg_latency: f64,
    pub avg_p99: f64,
    pub completed_ratio: f64,
    pub total_moved_shards: usize,
    pub reconfigurations: usize,
}

/// What one planning pass produced: the configuration to actuate, the
/// rank it held in the proposal, and the optional explain dump.
struct Planned {
    next: Configuration,
    chosen_rank: Option<usize>,
    explain: Vec<Candidate>,
}

/// The control loop, generic over the substrate it drives.
pub struct Coordinator<S: Substrate = ClusterSim> {
    model: SurfaceModel,
    sla: SlaSpec,
    cluster: S,
    backend: Backend,
    guard: Option<Box<dyn MoveGuard>>,
    online: Option<OnlineCalibration>,
    explain_k: usize,
    reb_h: f32,
    reb_v: f32,
    plan_queue: bool,
    current: Configuration,
    ewma: f32,
    /// EWMA smoothing for the demand estimate.
    pub ewma_alpha: f32,
}

impl<S: Substrate> Coordinator<S> {
    pub fn new(cfg: &ModelConfig, cluster: S, backend: Backend) -> Self {
        let current = cluster.current();
        Self {
            model: SurfaceModel::from_config(cfg),
            sla: SlaSpec::from_config(cfg),
            cluster,
            backend,
            guard: None,
            online: None,
            explain_k: 0,
            reb_h: cfg.policy.reb_h,
            reb_v: cfg.policy.reb_v,
            plan_queue: cfg.policy.plan_queue,
            current,
            ewma: 0.0,
            ewma_alpha: 0.6,
        }
    }

    pub fn current(&self) -> Configuration {
        self.current
    }

    pub fn cluster(&self) -> &S {
        &self.cluster
    }

    /// Mutable access for failure injection and test orchestration.
    pub fn cluster_mut(&mut self) -> &mut S {
        &mut self.cluster
    }

    /// Install (or clear) an actuation guard. With a guard the
    /// coordinator walks each tick's ranked proposal and actuates the
    /// first candidate the guard admits — degradation-aware stepping
    /// instead of freezing on a rejected first choice.
    pub fn set_guard(&mut self, guard: Option<Box<dyn MoveGuard>>) {
        self.guard = guard;
    }

    /// Record the top-`k` ranked candidates of every tick's proposal in
    /// [`TickReport::explain`] (0 disables; CLI `cluster --explain`).
    pub fn set_explain(&mut self, k: usize) {
        self.explain_k = k;
    }

    /// Feed every substrate `observe()` snapshot into an online surface
    /// refit: measured (queueing-deflated, unit-mapped) latency plus
    /// observed capacity accumulate in a [`Calibrator`], and every
    /// `refit_every` undegraded ticks the planning model is rebuilt
    /// from the fitted constants — the ROADMAP's calibration-driven
    /// planning loop, scoped to single-cluster runs
    /// (CLI `cluster --calibrate-online`).
    pub fn enable_online_calibration(&mut self, cfg: &ModelConfig, refit_every: usize) {
        assert!(refit_every > 0, "refit cadence must be at least 1 tick");
        // HLO kernel constants are baked at artifact-compile time, so a
        // refit would recalibrate pricing/feasibility but not the kernel
        // scores — a silent half-calibrated ranking. Native only.
        assert!(
            matches!(self.backend, Backend::Native(_)),
            "online calibration requires the native backend"
        );
        self.online = Some(OnlineCalibration {
            cal: Calibrator::new(cfg.surfaces),
            refit_every,
            l_max: cfg.sla.l_max,
            u_max: cfg.surfaces.u_max,
            write_ratio: cfg.write_ratio(),
            observed: 0,
            refits: 0,
            seen: Vec::new(),
        });
    }

    /// How many online refits have replaced the planning surfaces.
    pub fn refits(&self) -> usize {
        self.online.as_ref().map_or(0, |o| o.refits)
    }

    /// The surface constants currently driving planning (the calibrated
    /// set once online refits have fired).
    pub fn planning_constants(&self) -> &SurfaceConfig {
        self.model.constants()
    }

    /// Walk a ranked proposal through the guard: first admitted
    /// candidate wins; with no guard the top candidate actuates
    /// unconditionally (bit-identical to the pre-proposal coordinator).
    fn walk(
        guard: &mut Option<Box<dyn MoveGuard>>,
        current: Configuration,
        p: &Proposal,
    ) -> (Configuration, Option<usize>) {
        let Some(g) = guard.as_mut() else {
            return (p.decision().next, Some(0));
        };
        for (rank, c) in p.candidates.iter().enumerate() {
            // trailing infeasible entries are stepping-stone vocabulary,
            // not actuation targets; only the promoted fallback head may
            // pass the guard at the sentinel score
            if !c.feasible() && !(p.fallback && rank == 0) {
                continue;
            }
            if g.admit(&current, c) {
                return (c.to, Some(rank));
            }
        }
        (current, None)
    }

    /// Plan the next configuration for an estimated demand.
    fn plan(&mut self, est: WorkloadPoint) -> Result<Planned> {
        let model = &self.model;
        let current = self.current;
        let explain_k = self.explain_k;
        match &mut self.backend {
            Backend::Native(policy) => {
                let ctx = PolicyContext {
                    model,
                    sla: &self.sla,
                    reb_h: self.reb_h,
                    reb_v: self.reb_v,
                    plan_queue: self.plan_queue,
                    future: &[],
                    budget: None,
                };
                let proposal = policy.propose(current, est, &ctx);
                let explain = proposal.candidates.iter().take(explain_k).copied().collect();
                let (next, chosen_rank) = Self::walk(&mut self.guard, current, &proposal);
                Ok(Planned { next, chosen_rank, explain })
            }
            Backend::Hlo { engine, moves } => {
                // Build the padded candidate batch for the `neighbor`
                // kernel, score on PJRT, rank in rust (stable sort keeps
                // row-major ties, so the top entry is the strict-<
                // argmin — matching the native policy exactly).
                let m = engine.engine().manifest();
                let (rows, cols) = (m.neighbor_rows, m.neighbor_cols);
                let plane = model.plane();
                let cands = plane.neighbors(&current, moves.allow_dh, moves.allow_dv);
                let mut batch = vec![0.0f32; rows * cols];
                for (i, c) in cands.iter().enumerate() {
                    let t = plane.tier(c);
                    let (dh, dv) = current.index_distance(c);
                    let row = &mut batch[i * cols..i * cols + 9];
                    row.copy_from_slice(&[
                        plane.h_value(c) as f32,
                        t.cpu,
                        t.ram,
                        t.bandwidth,
                        t.iops_k(),
                        t.cost,
                        dh as f32,
                        dv as f32,
                        1.0,
                    ]);
                }
                let (scores, _) = engine.neighbor_scores(&batch, est.lambda_req, *moves)?;
                let mut ranked: Vec<Candidate> = scores
                    .iter()
                    .take(cands.len())
                    .enumerate()
                    .filter(|(_, s)| **s < INFEASIBLE * 0.5)
                    .map(|(i, &s)| Candidate {
                        to: cands[i],
                        cost_to: model.cost(&cands[i]),
                        score: s,
                        raw: s,
                        gain: 0.0,
                    })
                    .collect();
                ranked.sort_by(|a, b| a.score.total_cmp(&b.score));
                let mut p = Proposal::ranked(current, model.cost(&current), 0.0, ranked);
                if p.candidates.is_empty() {
                    let up = plane.fallback_up(&current, moves.allow_dh, moves.allow_dv);
                    p.promote_fallback(up, model.cost(&up));
                }
                let explain = p.candidates.iter().take(explain_k).copied().collect();
                let (next, chosen_rank) = Self::walk(&mut self.guard, current, &p);
                Ok(Planned { next, chosen_rank, explain })
            }
        }
    }

    /// One control tick: serve, observe, plan, actuate.
    pub fn tick(&mut self, step: usize, demand: WorkloadPoint) -> Result<TickReport> {
        let served_config = self.current;
        let metrics = self.cluster.step(demand);

        // Demand estimate from the observed offered load.
        let observed = metrics.offered as f32;
        self.ewma = if step == 0 {
            observed
        } else {
            self.ewma_alpha * observed + (1.0 - self.ewma_alpha) * self.ewma
        };
        let est = WorkloadPoint::new(self.ewma, demand.lambda_w / demand.lambda_req.max(1e-9));

        // Online surface refit: fold this tick's measurement into the
        // calibrator before planning, so refits reach the decision path
        // the same tick they fire.
        if let Some(o) = &mut self.online {
            let status = self.cluster.observe();
            if !status.degraded {
                // undo the queueing inflation and the substrate unit
                // mapping so the calibrator sees raw paper-scale latency
                let u = metrics.utilization.min(o.u_max as f64);
                let raw_paper = metrics.avg_latency * (1.0 - u) * o.l_max as f64
                    / self.cluster.params().sla_latency;
                o.cal.observe(
                    self.model.plane(),
                    Observation {
                        config: served_config,
                        latency: raw_paper,
                        throughput: status.capacity,
                    },
                );
                o.observed += 1;
                if !o.seen.contains(&served_config) {
                    o.seen.push(served_config);
                }
                if o.observed % o.refit_every == 0
                    && o.cal.len() >= MIN_CALIBRATION_OBS
                    && o.coverage_ok()
                {
                    self.model = SurfaceModel::new(
                        self.model.plane().clone(),
                        o.cal.calibrated_config(),
                        o.write_ratio,
                    );
                    o.refits += 1;
                }
            }
        }

        let planned = self.plan(est)?;
        let plan = self.cluster.apply(planned.next);
        self.current = planned.next;

        let violation = metrics.p99_latency > self.cluster.params().sla_latency
            || metrics.completed < demand.lambda_req as f64 * 0.999;
        Ok(TickReport {
            step,
            served_config,
            next_config: planned.next,
            demand: demand.lambda_req,
            demand_estimate: self.ewma,
            metrics,
            rebalanced: !plan.is_noop() || plan.duration > 0.0,
            moved_shards: plan.moved_shards,
            violation,
            chosen_rank: planned.chosen_rank,
            explain: planned.explain,
        })
    }

    /// Drive a whole demand trace synchronously.
    pub fn run_trace(&mut self, trace: &Trace) -> Result<Vec<TickReport>> {
        trace
            .points
            .iter()
            .enumerate()
            .map(|(i, w)| self.tick(i, *w))
            .collect()
    }

    /// Daemon loop: consume demand observations from a channel until it
    /// closes; emit a report per tick on the report channel. Run it on
    /// its own thread with `std::thread::spawn(move || coord.run_daemon(..))`.
    pub fn run_daemon(
        mut self,
        demand_rx: mpsc::Receiver<WorkloadPoint>,
        report_tx: mpsc::Sender<TickReport>,
    ) -> Result<CoordinatorSummary> {
        let mut reports = Vec::new();
        let mut step = 0usize;
        while let Ok(w) = demand_rx.recv() {
            let r = self.tick(step, w)?;
            step += 1;
            // a closed report channel is not an error — keep controlling
            let _ = report_tx.send(r.clone());
            reports.push(r);
        }
        Ok(summarize(&reports))
    }
}

/// Aggregate tick reports.
pub fn summarize(reports: &[TickReport]) -> CoordinatorSummary {
    let n = reports.len();
    let nf = n.max(1) as f64;
    let offered: f64 = reports.iter().map(|r| r.metrics.offered).sum();
    let completed: f64 = reports.iter().map(|r| r.metrics.completed).sum();
    CoordinatorSummary {
        steps: n,
        violations: reports.iter().filter(|r| r.violation).count(),
        avg_latency: reports.iter().map(|r| r.metrics.avg_latency).sum::<f64>() / nf,
        avg_p99: reports.iter().map(|r| r.metrics.p99_latency).sum::<f64>() / nf,
        completed_ratio: if offered > 0.0 { completed / offered } else { 1.0 },
        total_moved_shards: reports.iter().map(|r| r.moved_shards).sum(),
        reconfigurations: reports
            .windows(2)
            .filter(|w| w[1].served_config != w[0].served_config)
            .count(),
    }
}

/// Register a coordinator run's rollup into the pull-based export
/// registry: run-level gauges from [`summarize`] plus a per-tick p99
/// latency sketch under `coordinator_p99_seconds`.
pub fn export_metrics(reports: &[TickReport], reg: &mut crate::metrics::MetricsRegistry) {
    use crate::metrics::{names, LATENCY_FLOOR};
    let s = summarize(reports);
    reg.set(names::COORDINATOR_STEPS, &[], s.steps as f64);
    reg.set(names::COORDINATOR_VIOLATIONS, &[], s.violations as f64);
    reg.set(names::COORDINATOR_RECONFIGURATIONS, &[], s.reconfigurations as f64);
    reg.set(names::COORDINATOR_MOVED_SHARDS, &[], s.total_moved_shards as f64);
    for r in reports {
        reg.observe(names::COORDINATOR_P99_SECONDS, &[], LATENCY_FLOOR, r.metrics.p99_latency);
    }
}

/// Convenience: coordinator with a native policy on a fresh
/// sampling-engine cluster.
pub fn native_coordinator(
    cfg: &ModelConfig,
    policy: Box<dyn Policy + Send>,
    params: ClusterParams,
    seed: u64,
) -> Coordinator<ClusterSim> {
    let cluster = ClusterSim::new(cfg, params, seed);
    Coordinator::new(cfg, cluster, Backend::Native(policy))
}

/// Convenience: coordinator with a native policy on a fresh
/// event-driven cluster.
pub fn event_coordinator(
    cfg: &ModelConfig,
    policy: Box<dyn Policy + Send>,
    params: ClusterParams,
    seed: u64,
) -> Coordinator<EventSim> {
    let cluster = EventSim::new(cfg, params, seed);
    Coordinator::new(cfg, cluster, Backend::Native(policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::DiagonalScale;
    use crate::workload::TraceBuilder;

    fn coordinator(seed: u64) -> Coordinator {
        let cfg = ModelConfig::default_paper();
        native_coordinator(
            &cfg,
            Box::new(DiagonalScale::diagonal()),
            ClusterParams::default(),
            seed,
        )
    }

    #[test]
    fn scales_up_through_the_paper_trace() {
        let cfg = ModelConfig::default_paper();
        let mut c = coordinator(1);
        let trace = TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        assert_eq!(reports.len(), 50);
        let s = summarize(&reports);
        // the controller must reconfigure at least around phase changes
        assert!(s.reconfigurations >= 2);
        // and keep the vast majority of steps healthy
        assert!(s.violations < 15, "violations={}", s.violations);
        assert!(s.completed_ratio > 0.9);
    }

    #[test]
    fn peak_config_stronger_than_idle_config() {
        let cfg = ModelConfig::default_paper();
        let mut c = coordinator(2);
        let trace = TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        let model = SurfaceModel::from_config(&cfg);
        let peak = &reports[28]; // late high phase
        let tail = &reports[49]; // late low phase
        assert!(
            model.throughput(&peak.served_config) > model.throughput(&tail.served_config),
            "peak {:?} vs tail {:?}",
            peak.served_config,
            tail.served_config
        );
    }

    #[test]
    fn event_substrate_drives_the_same_control_loop() {
        let cfg = ModelConfig::default_paper();
        let mut c = event_coordinator(
            &cfg,
            Box::new(DiagonalScale::diagonal()),
            ClusterParams::default(),
            1,
        );
        let trace = TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        let s = summarize(&reports);
        assert_eq!(s.steps, 50);
        assert!(s.reconfigurations >= 2);
        assert!(s.completed_ratio > 0.9, "completed={}", s.completed_ratio);
    }

    #[test]
    fn cost_cap_guard_degrades_or_holds() {
        let cfg = ModelConfig::default_paper();
        let mut c = coordinator(5);
        let cap = 0.9f32;
        c.set_guard(Some(Box::new(CostCapGuard { cap })));
        let trace = TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        let model = SurfaceModel::from_config(&cfg);
        for r in &reports {
            assert!(
                model.cost(&r.next_config) <= cap + 1e-6,
                "guard let {:?} through at {:.2}/h",
                r.next_config,
                model.cost(&r.next_config)
            );
        }
        // the paper's high phase wants configs beyond the cap: the
        // guard must have stepped down the ranked list or held
        assert!(
            reports.iter().any(|r| r.chosen_rank.map_or(true, |k| k > 0)),
            "guard never bit on the paper trace"
        );
    }

    #[test]
    fn explain_records_the_ranked_top_k() {
        let cfg = ModelConfig::default_paper();
        let mut c = coordinator(6);
        c.set_explain(3);
        let reports = c.run_trace(&TraceBuilder::paper(&cfg)).unwrap();
        for r in &reports {
            assert!(!r.explain.is_empty() && r.explain.len() <= 3);
            for w in r.explain.windows(2) {
                assert!(
                    w[0].score.total_cmp(&w[1].score) != std::cmp::Ordering::Greater,
                    "explain dump out of rank order"
                );
            }
            // no guard: the top-ranked candidate is what actuated
            assert_eq!(r.explain[0].to, r.next_config);
            assert_eq!(r.chosen_rank, Some(0));
        }
    }

    /// ROADMAP satellite: `observe()` snapshots feed an online surface
    /// refit on the decision path. Against the analytical substrate the
    /// measurements *are* the model, so the fitted constants must land
    /// back on the priors (self-consistency) while the control loop
    /// keeps reconfiguring.
    #[test]
    fn online_calibration_refits_on_the_decision_path() {
        use crate::simulator::AnalyticalSubstrate;
        let cfg = ModelConfig::default_paper();
        let sub = AnalyticalSubstrate::new(&cfg, ClusterParams::default());
        let mut c =
            Coordinator::new(&cfg, sub, Backend::Native(Box::new(DiagonalScale::diagonal())));
        c.enable_online_calibration(&cfg, 10);
        let trace = TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        assert!(c.refits() >= 1, "refit cadence never fired");
        let kappa = c.planning_constants().kappa;
        assert!(
            (kappa - cfg.surfaces.kappa).abs() / cfg.surfaces.kappa < 0.05,
            "kappa drifted under self-consistent data: {kappa}"
        );
        let s = summarize(&reports);
        assert_eq!(s.steps, 50);
        assert!(s.reconfigurations >= 2);
    }

    #[test]
    fn ewma_tracks_demand() {
        let mut c = coordinator(3);
        for i in 0..5 {
            c.tick(i, WorkloadPoint::new(4000.0, 0.3)).unwrap();
        }
        assert!((c.ewma - 4000.0).abs() < 400.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = ModelConfig::default_paper();
        let trace = TraceBuilder::paper(&cfg);
        let a = coordinator(7).run_trace(&trace).unwrap();
        let b = coordinator(7).run_trace(&trace).unwrap();
        let sa: Vec<_> = a.iter().map(|r| r.served_config).collect();
        let sb: Vec<_> = b.iter().map(|r| r.served_config).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn daemon_processes_channel() {
        let (dtx, drx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        // built inside the thread: Backend can hold !Send PJRT handles
        let handle = std::thread::spawn(move || coordinator(4).run_daemon(drx, rtx));
        for _ in 0..6 {
            dtx.send(WorkloadPoint::new(3000.0, 0.3)).unwrap();
        }
        drop(dtx);
        let mut got = 0;
        while rrx.recv().is_ok() {
            got += 1;
        }
        let summary = handle.join().unwrap().unwrap();
        assert_eq!(got, 6);
        assert_eq!(summary.steps, 6);
    }
}
