//! The autoscaler control loop — the deployable form of the paper's
//! contribution. Each tick it: (1) serves the interval's demand on the
//! Phase-2 cluster substrate, (2) estimates demand (EWMA over observed
//! offered load), (3) runs the planning policy against the analytical
//! surfaces — natively or through the AOT-compiled PJRT kernels —
//! and (4) actuates the chosen configuration, paying the physical
//! rebalance cost.
//!
//! [`Coordinator::run_trace`] is the synchronous driver used by the
//! examples and benches; [`Coordinator::run_daemon`] wraps the same
//! tick in a channel-fed loop suitable for running on its own thread
//! (`std::sync::mpsc` — the offline build has no async runtime).
//!
//! The coordinator is generic over [`Substrate`], so the same control
//! loop drives the legacy sampling engine ([`ClusterSim`], the default
//! type parameter), the event-driven engine
//! ([`crate::cluster::EventSim`]), or the analytical wrapper
//! ([`crate::simulator::AnalyticalSubstrate`]).

use std::sync::mpsc;

use anyhow::Result;

use crate::cluster::{ClusterParams, ClusterSim, ClusterStepMetrics, EventSim, Substrate};
use crate::config::{MoveFlags, ModelConfig};
use crate::plane::Configuration;
use crate::policy::{Policy, PolicyContext};
use crate::runtime::SurfaceEngine;
use crate::sla::SlaSpec;
use crate::surfaces::SurfaceModel;
use crate::workload::{Trace, WorkloadPoint};
use crate::INFEASIBLE;

/// Where neighbor scoring happens.
pub enum Backend {
    /// Native rust surfaces.
    Native(Box<dyn Policy + Send>),
    /// AOT-compiled Pallas kernels through PJRT (the `neighbor`
    /// artifact); Algorithm-1 argmin stays in rust.
    Hlo { engine: SurfaceEngine, moves: MoveFlags },
}

/// One coordinator tick's record.
#[derive(Debug, Clone)]
pub struct TickReport {
    pub step: usize,
    pub served_config: Configuration,
    pub next_config: Configuration,
    pub demand: f32,
    pub demand_estimate: f32,
    pub metrics: ClusterStepMetrics,
    pub rebalanced: bool,
    pub moved_shards: usize,
    /// Measured SLA violation: p99 over the bound, or throughput short.
    pub violation: bool,
}

/// Aggregate over a coordinator run.
#[derive(Debug, Clone, Copy)]
pub struct CoordinatorSummary {
    pub steps: usize,
    pub violations: usize,
    pub avg_latency: f64,
    pub avg_p99: f64,
    pub completed_ratio: f64,
    pub total_moved_shards: usize,
    pub reconfigurations: usize,
}

/// The control loop, generic over the substrate it drives.
pub struct Coordinator<S: Substrate = ClusterSim> {
    model: SurfaceModel,
    sla: SlaSpec,
    cluster: S,
    backend: Backend,
    reb_h: f32,
    reb_v: f32,
    plan_queue: bool,
    current: Configuration,
    ewma: f32,
    /// EWMA smoothing for the demand estimate.
    pub ewma_alpha: f32,
}

impl<S: Substrate> Coordinator<S> {
    pub fn new(cfg: &ModelConfig, cluster: S, backend: Backend) -> Self {
        let current = cluster.current();
        Self {
            model: SurfaceModel::from_config(cfg),
            sla: SlaSpec::from_config(cfg),
            cluster,
            backend,
            reb_h: cfg.policy.reb_h,
            reb_v: cfg.policy.reb_v,
            plan_queue: cfg.policy.plan_queue,
            current,
            ewma: 0.0,
            ewma_alpha: 0.6,
        }
    }

    pub fn current(&self) -> Configuration {
        self.current
    }

    pub fn cluster(&self) -> &S {
        &self.cluster
    }

    /// Mutable access for failure injection and test orchestration.
    pub fn cluster_mut(&mut self) -> &mut S {
        &mut self.cluster
    }

    /// Plan the next configuration for an estimated demand.
    fn plan(&mut self, est: WorkloadPoint) -> Result<Configuration> {
        match &mut self.backend {
            Backend::Native(policy) => {
                let ctx = PolicyContext {
                    model: &self.model,
                    sla: &self.sla,
                    reb_h: self.reb_h,
                    reb_v: self.reb_v,
                    plan_queue: self.plan_queue,
                    future: &[],
                    budget: None,
                };
                Ok(policy.decide(self.current, est, &ctx).next)
            }
            Backend::Hlo { engine, moves } => {
                // Build the padded candidate batch for the `neighbor`
                // kernel, score on PJRT, argmin in rust (row-major order,
                // strict <, matching the native policy exactly).
                let m = engine.engine().manifest();
                let (rows, cols) = (m.neighbor_rows, m.neighbor_cols);
                let plane = self.model.plane();
                let cands = plane.neighbors(&self.current, moves.allow_dh, moves.allow_dv);
                let mut batch = vec![0.0f32; rows * cols];
                for (i, c) in cands.iter().enumerate() {
                    let t = plane.tier(c);
                    let (dh, dv) = self.current.index_distance(c);
                    let row = &mut batch[i * cols..i * cols + 9];
                    row.copy_from_slice(&[
                        plane.h_value(c) as f32,
                        t.cpu,
                        t.ram,
                        t.bandwidth,
                        t.iops_k(),
                        t.cost,
                        dh as f32,
                        dv as f32,
                        1.0,
                    ]);
                }
                let (scores, _) =
                    engine.neighbor_scores(&batch, est.lambda_req, *moves)?;
                let mut best: Option<(usize, f32)> = None;
                for (i, &s) in scores.iter().take(cands.len()).enumerate() {
                    if s < INFEASIBLE * 0.5 && best.map_or(true, |(_, b)| s < b) {
                        best = Some((i, s));
                    }
                }
                Ok(match best {
                    Some((i, _)) => cands[i],
                    None => plane.fallback_up(&self.current, moves.allow_dh, moves.allow_dv),
                })
            }
        }
    }

    /// One control tick: serve, observe, plan, actuate.
    pub fn tick(&mut self, step: usize, demand: WorkloadPoint) -> Result<TickReport> {
        let served_config = self.current;
        let metrics = self.cluster.step(demand);

        // Demand estimate from the observed offered load.
        let observed = metrics.offered as f32;
        self.ewma = if step == 0 {
            observed
        } else {
            self.ewma_alpha * observed + (1.0 - self.ewma_alpha) * self.ewma
        };
        let est = WorkloadPoint::new(self.ewma, demand.lambda_w / demand.lambda_req.max(1e-9));

        let next = self.plan(est)?;
        let plan = self.cluster.apply(next);
        self.current = next;

        let violation = metrics.p99_latency > self.cluster.params().sla_latency
            || metrics.completed < demand.lambda_req as f64 * 0.999;
        Ok(TickReport {
            step,
            served_config,
            next_config: next,
            demand: demand.lambda_req,
            demand_estimate: self.ewma,
            metrics,
            rebalanced: !plan.is_noop() || plan.duration > 0.0,
            moved_shards: plan.moved_shards,
            violation,
        })
    }

    /// Drive a whole demand trace synchronously.
    pub fn run_trace(&mut self, trace: &Trace) -> Result<Vec<TickReport>> {
        trace
            .points
            .iter()
            .enumerate()
            .map(|(i, w)| self.tick(i, *w))
            .collect()
    }

    /// Daemon loop: consume demand observations from a channel until it
    /// closes; emit a report per tick on the report channel. Run it on
    /// its own thread with `std::thread::spawn(move || coord.run_daemon(..))`.
    pub fn run_daemon(
        mut self,
        demand_rx: mpsc::Receiver<WorkloadPoint>,
        report_tx: mpsc::Sender<TickReport>,
    ) -> Result<CoordinatorSummary> {
        let mut reports = Vec::new();
        let mut step = 0usize;
        while let Ok(w) = demand_rx.recv() {
            let r = self.tick(step, w)?;
            step += 1;
            // a closed report channel is not an error — keep controlling
            let _ = report_tx.send(r.clone());
            reports.push(r);
        }
        Ok(summarize(&reports))
    }
}

/// Aggregate tick reports.
pub fn summarize(reports: &[TickReport]) -> CoordinatorSummary {
    let n = reports.len();
    let nf = n.max(1) as f64;
    let offered: f64 = reports.iter().map(|r| r.metrics.offered).sum();
    let completed: f64 = reports.iter().map(|r| r.metrics.completed).sum();
    CoordinatorSummary {
        steps: n,
        violations: reports.iter().filter(|r| r.violation).count(),
        avg_latency: reports.iter().map(|r| r.metrics.avg_latency).sum::<f64>() / nf,
        avg_p99: reports.iter().map(|r| r.metrics.p99_latency).sum::<f64>() / nf,
        completed_ratio: if offered > 0.0 { completed / offered } else { 1.0 },
        total_moved_shards: reports.iter().map(|r| r.moved_shards).sum(),
        reconfigurations: reports
            .windows(2)
            .filter(|w| w[1].served_config != w[0].served_config)
            .count(),
    }
}

/// Convenience: coordinator with a native policy on a fresh
/// sampling-engine cluster.
pub fn native_coordinator(
    cfg: &ModelConfig,
    policy: Box<dyn Policy + Send>,
    params: ClusterParams,
    seed: u64,
) -> Coordinator<ClusterSim> {
    let cluster = ClusterSim::new(cfg, params, seed);
    Coordinator::new(cfg, cluster, Backend::Native(policy))
}

/// Convenience: coordinator with a native policy on a fresh
/// event-driven cluster.
pub fn event_coordinator(
    cfg: &ModelConfig,
    policy: Box<dyn Policy + Send>,
    params: ClusterParams,
    seed: u64,
) -> Coordinator<EventSim> {
    let cluster = EventSim::new(cfg, params, seed);
    Coordinator::new(cfg, cluster, Backend::Native(policy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::DiagonalScale;
    use crate::workload::TraceBuilder;

    fn coordinator(seed: u64) -> Coordinator {
        let cfg = ModelConfig::default_paper();
        native_coordinator(
            &cfg,
            Box::new(DiagonalScale::diagonal()),
            ClusterParams::default(),
            seed,
        )
    }

    #[test]
    fn scales_up_through_the_paper_trace() {
        let cfg = ModelConfig::default_paper();
        let mut c = coordinator(1);
        let trace = TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        assert_eq!(reports.len(), 50);
        let s = summarize(&reports);
        // the controller must reconfigure at least around phase changes
        assert!(s.reconfigurations >= 2);
        // and keep the vast majority of steps healthy
        assert!(s.violations < 15, "violations={}", s.violations);
        assert!(s.completed_ratio > 0.9);
    }

    #[test]
    fn peak_config_stronger_than_idle_config() {
        let cfg = ModelConfig::default_paper();
        let mut c = coordinator(2);
        let trace = TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        let model = SurfaceModel::from_config(&cfg);
        let peak = &reports[28]; // late high phase
        let tail = &reports[49]; // late low phase
        assert!(
            model.throughput(&peak.served_config) > model.throughput(&tail.served_config),
            "peak {:?} vs tail {:?}",
            peak.served_config,
            tail.served_config
        );
    }

    #[test]
    fn event_substrate_drives_the_same_control_loop() {
        let cfg = ModelConfig::default_paper();
        let mut c = event_coordinator(
            &cfg,
            Box::new(DiagonalScale::diagonal()),
            ClusterParams::default(),
            1,
        );
        let trace = TraceBuilder::paper(&cfg);
        let reports = c.run_trace(&trace).unwrap();
        let s = summarize(&reports);
        assert_eq!(s.steps, 50);
        assert!(s.reconfigurations >= 2);
        assert!(s.completed_ratio > 0.9, "completed={}", s.completed_ratio);
    }

    #[test]
    fn ewma_tracks_demand() {
        let mut c = coordinator(3);
        for i in 0..5 {
            c.tick(i, WorkloadPoint::new(4000.0, 0.3)).unwrap();
        }
        assert!((c.ewma - 4000.0).abs() < 400.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = ModelConfig::default_paper();
        let trace = TraceBuilder::paper(&cfg);
        let a = coordinator(7).run_trace(&trace).unwrap();
        let b = coordinator(7).run_trace(&trace).unwrap();
        let sa: Vec<_> = a.iter().map(|r| r.served_config).collect();
        let sb: Vec<_> = b.iter().map(|r| r.served_config).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn daemon_processes_channel() {
        let (dtx, drx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        // built inside the thread: Backend can hold !Send PJRT handles
        let handle = std::thread::spawn(move || coordinator(4).run_daemon(drx, rtx));
        for _ in 0..6 {
            dtx.send(WorkloadPoint::new(3000.0, 0.3)).unwrap();
        }
        drop(dtx);
        let mut got = 0;
        while rrx.recv().is_ok() {
            got += 1;
        }
        let summary = handle.join().unwrap().unwrap();
        assert_eq!(got, 6);
        assert_eq!(summary.steps, 6);
    }
}
