//! Demand forecasting: the missing piece between the paper's reactive
//! Algorithm 1 and its §VIII lookahead extension. Lookahead needs a
//! *future* — in production nobody hands the controller the trace, so
//! the coordinator forecasts it from observed demand.
//!
//! Three predictors, all O(1) per observation:
//!
//! * [`MovingAverage`] — robust flat-line baseline.
//! * [`Holt`] — double exponential smoothing (level + trend): tracks
//!   ramps, the dominant failure mode of reactive scaling.
//! * [`SeasonalNaive`] — repeats the value one period ago: exact for
//!   diurnal/periodic workloads.

/// A demand predictor consuming one observation per step.
pub trait Forecaster {
    /// Record an observed demand level.
    fn observe(&mut self, demand: f64);
    /// Forecast demand `horizon` steps ahead (1 = next step).
    fn forecast(&self, horizon: usize) -> f64;
    /// Convenience: forecasts for horizons `1..=n`.
    fn forecast_n(&self, n: usize) -> Vec<f64> {
        (1..=n).map(|h| self.forecast(h)).collect()
    }
}

// Boxed forecasters forward, so call sites that pick a predictor at
// runtime (fleet tenants, the CLI's --forecast flag) can drive
// `ForecastLookahead<Box<dyn Forecaster + Send>>` without a generic
// parameter per predictor kind.
impl<F: Forecaster + ?Sized> Forecaster for Box<F> {
    fn observe(&mut self, demand: f64) {
        (**self).observe(demand)
    }

    fn forecast(&self, horizon: usize) -> f64 {
        (**self).forecast(horizon)
    }
}

/// Simple moving average over a fixed window.
#[derive(Debug, Clone)]
pub struct MovingAverage {
    window: usize,
    buf: Vec<f64>,
    pos: usize,
    filled: bool,
}

impl MovingAverage {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        Self { window, buf: vec![0.0; window], pos: 0, filled: false }
    }
}

impl Forecaster for MovingAverage {
    fn observe(&mut self, demand: f64) {
        self.buf[self.pos] = demand;
        self.pos = (self.pos + 1) % self.window;
        if self.pos == 0 {
            self.filled = true;
        }
    }

    fn forecast(&self, _horizon: usize) -> f64 {
        let n = if self.filled { self.window } else { self.pos };
        if n == 0 {
            return 0.0;
        }
        self.buf[..if self.filled { self.window } else { self.pos }]
            .iter()
            .sum::<f64>()
            / n as f64
    }
}

/// Holt's linear method: `level + horizon * trend`.
#[derive(Debug, Clone)]
pub struct Holt {
    alpha: f64,
    beta: f64,
    level: f64,
    trend: f64,
    seen: usize,
}

impl Holt {
    pub fn new(alpha: f64, beta: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha) && (0.0..=1.0).contains(&beta));
        Self { alpha, beta, level: 0.0, trend: 0.0, seen: 0 }
    }

    /// Defaults tuned for step-phased traces: fast level, damped trend.
    pub fn default_tuned() -> Self {
        Self::new(0.7, 0.3)
    }
}

impl Forecaster for Holt {
    fn observe(&mut self, demand: f64) {
        if self.seen == 0 {
            self.level = demand;
            self.trend = 0.0;
        } else {
            let prev_level = self.level;
            self.level = self.alpha * demand + (1.0 - self.alpha) * (self.level + self.trend);
            self.trend = self.beta * (self.level - prev_level) + (1.0 - self.beta) * self.trend;
        }
        self.seen += 1;
    }

    fn forecast(&self, horizon: usize) -> f64 {
        // never forecast negative demand
        (self.level + horizon as f64 * self.trend).max(0.0)
    }
}

/// Seasonal naive: forecast(h) = observation one period before t+h.
#[derive(Debug, Clone)]
pub struct SeasonalNaive {
    period: usize,
    history: Vec<f64>,
}

impl SeasonalNaive {
    pub fn new(period: usize) -> Self {
        assert!(period > 0);
        Self { period, history: Vec::new() }
    }
}

impl Forecaster for SeasonalNaive {
    fn observe(&mut self, demand: f64) {
        self.history.push(demand);
    }

    fn forecast(&self, horizon: usize) -> f64 {
        if self.history.is_empty() {
            return 0.0;
        }
        let t = self.history.len() + horizon - 1; // index being forecast
        if t >= self.period {
            // value one period earlier, if observed
            let idx = t - self.period;
            if idx < self.history.len() {
                return self.history[idx];
            }
        }
        *self.history.last().unwrap()
    }
}

/// Mean absolute percentage error of a forecaster replayed over a trace
/// (one-step-ahead), for the forecast-quality bench.
pub fn mape_one_step(f: &mut dyn Forecaster, trace: &[f64]) -> f64 {
    let mut err = 0.0;
    let mut n = 0usize;
    for (i, &x) in trace.iter().enumerate() {
        if i > 0 && x.abs() > 1e-9 {
            err += ((f.forecast(1) - x) / x).abs();
            n += 1;
        }
        f.observe(x);
    }
    if n == 0 {
        0.0
    } else {
        err / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moving_average_flat_signal() {
        let mut f = MovingAverage::new(4);
        for _ in 0..10 {
            f.observe(100.0);
        }
        assert_eq!(f.forecast(1), 100.0);
        assert_eq!(f.forecast(5), 100.0);
    }

    #[test]
    fn moving_average_partial_window() {
        let mut f = MovingAverage::new(8);
        f.observe(10.0);
        f.observe(20.0);
        assert!((f.forecast(1) - 15.0).abs() < 1e-12);
    }

    #[test]
    fn holt_tracks_a_ramp() {
        let mut f = Holt::default_tuned();
        for t in 0..30 {
            f.observe(100.0 + 10.0 * t as f64);
        }
        // next value is 400; a trend-aware forecaster should be close
        let pred = f.forecast(1);
        assert!((pred - 400.0).abs() < 25.0, "pred={pred}");
        // and extrapolate further out
        assert!(f.forecast(5) > f.forecast(1));
    }

    #[test]
    fn holt_beats_moving_average_on_ramps() {
        let trace: Vec<f64> = (0..50).map(|t| 1000.0 + 100.0 * t as f64).collect();
        let holt = mape_one_step(&mut Holt::default_tuned(), &trace);
        let ma = mape_one_step(&mut MovingAverage::new(8), &trace);
        assert!(holt < ma, "holt {holt} vs ma {ma}");
    }

    #[test]
    fn holt_never_negative() {
        let mut f = Holt::default_tuned();
        for t in 0..20 {
            f.observe((1000.0 - 100.0 * t as f64).max(0.0));
        }
        assert!(f.forecast(10) >= 0.0);
    }

    #[test]
    fn seasonal_naive_exact_on_periodic_signal() {
        let mut f = SeasonalNaive::new(10);
        let signal: Vec<f64> = (0..40).map(|t| ((t % 10) * 100) as f64).collect();
        for &x in &signal[..30] {
            f.observe(x);
        }
        // forecast the next 10 steps: must repeat the period exactly
        for h in 1..=10 {
            assert_eq!(f.forecast(h), signal[29 + h]);
        }
    }

    #[test]
    fn seasonal_naive_beats_others_on_paper_like_cycle() {
        // two repetitions of a phased cycle
        let cycle: Vec<f64> = [60.0, 100.0, 160.0, 100.0, 60.0]
            .iter()
            .flat_map(|&v| std::iter::repeat(v * 100.0).take(10))
            .collect();
        let two: Vec<f64> = cycle.iter().chain(cycle.iter()).copied().collect();
        let sn = mape_one_step(&mut SeasonalNaive::new(50), &two);
        let ma = mape_one_step(&mut MovingAverage::new(8), &two);
        assert!(sn < ma, "seasonal {sn} vs ma {ma}");
    }

    #[test]
    fn forecast_n_lengths() {
        let mut f = Holt::default_tuned();
        f.observe(10.0);
        assert_eq!(f.forecast_n(3).len(), 3);
    }

    #[test]
    fn boxed_forecaster_forwards() {
        let mut b: Box<dyn Forecaster + Send> = Box::new(Holt::default_tuned());
        b.observe(100.0);
        b.observe(100.0);
        assert!((b.forecast(1) - 100.0).abs() < 1e-9);
        assert_eq!(b.forecast_n(3).len(), 3);
    }

    #[test]
    fn empty_forecasters_return_zero() {
        assert_eq!(MovingAverage::new(4).forecast(1), 0.0);
        assert_eq!(SeasonalNaive::new(4).forecast(1), 0.0);
    }
}
