//! Workload engine: the paper's 50-step phased timeline (§V.C) plus the
//! synthetic trace families used by the extended benchmarks (sine,
//! bursty, spike, ramp) and YCSB-style read/write mixes.

mod rng;

pub use rng::XorShift64;


use crate::config::ModelConfig;

/// One timestep of demand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadPoint {
    /// Required throughput lambda_req (synthetic ops per interval).
    pub lambda_req: f32,
    /// Write arrival rate lambda_w (paper III.E).
    pub lambda_w: f32,
}

impl WorkloadPoint {
    pub fn new(lambda_req: f32, write_ratio: f32) -> Self {
        Self { lambda_req, lambda_w: lambda_req * write_ratio }
    }
}

/// A demand trace: a finite sequence of workload points.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub name: String,
    pub points: Vec<WorkloadPoint>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Average required throughput (the paper reports 9600 for the
    /// default trace).
    pub fn avg_lambda_req(&self) -> f32 {
        if self.points.is_empty() {
            return 0.0;
        }
        self.points.iter().map(|p| p.lambda_req).sum::<f32>() / self.points.len() as f32
    }

    /// Flatten into the `f32[T, 2]` row-major layout the HLO
    /// `policy_trace` artifacts take.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.points.len() * 2);
        for p in &self.points {
            out.push(p.lambda_req);
            out.push(p.lambda_w);
        }
        out
    }

    /// Append another trace after this one (multi-day timelines, fleet
    /// scenario stitching). The result is named `<self>+<other>`.
    pub fn concat(&self, other: &Trace) -> Trace {
        let mut points = Vec::with_capacity(self.points.len() + other.points.len());
        points.extend_from_slice(&self.points);
        points.extend_from_slice(&other.points);
        Trace { name: format!("{}+{}", self.name, other.name), points }
    }

    /// Cyclic phase shift: the trace rotated left by `offset` steps, so
    /// `shifted.points[t] == self.points[(t + offset) % len]`. This is
    /// how the fleet builds phase-shifted per-tenant demand from one
    /// base timeline (tenants peak at different ticks).
    pub fn shifted(&self, offset: usize) -> Trace {
        if self.points.is_empty() {
            return self.clone();
        }
        let k = offset % self.points.len();
        let mut points = Vec::with_capacity(self.points.len());
        points.extend_from_slice(&self.points[k..]);
        points.extend_from_slice(&self.points[..k]);
        Trace { name: format!("{}@{k}", self.name), points }
    }

    /// Serialize as CSV (`step,lambda_req,lambda_w`) for interchange
    /// with external trace tooling.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,lambda_req,lambda_w\n");
        for (i, p) in self.points.iter().enumerate() {
            use std::fmt::Write as _;
            let _ = writeln!(out, "{i},{},{}", p.lambda_req, p.lambda_w);
        }
        out
    }

    /// Parse a CSV trace (the `to_csv` format; the `step` column is
    /// ignored so externally produced traces can use timestamps).
    pub fn from_csv(name: &str, text: &str) -> anyhow::Result<Self> {
        use anyhow::{anyhow, Context};
        let mut points = Vec::new();
        for (lineno, line) in text.lines().enumerate().skip(1) {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut cols = line.split(',');
            let _step = cols.next();
            let lambda_req: f32 = cols
                .next()
                .ok_or_else(|| anyhow!("line {}: missing lambda_req", lineno + 1))?
                .trim()
                .parse()
                .with_context(|| format!("line {}: bad lambda_req", lineno + 1))?;
            let lambda_w: f32 = cols
                .next()
                .ok_or_else(|| anyhow!("line {}: missing lambda_w", lineno + 1))?
                .trim()
                .parse()
                .with_context(|| format!("line {}: bad lambda_w", lineno + 1))?;
            if lambda_req < 0.0 || lambda_w < 0.0 {
                return Err(anyhow!("line {}: negative demand", lineno + 1));
            }
            points.push(WorkloadPoint { lambda_req, lambda_w });
        }
        if points.is_empty() {
            return Err(anyhow!("trace `{name}` has no data rows"));
        }
        Ok(Trace { name: name.to_string(), points })
    }

    /// Load a CSV trace from disk.
    pub fn from_csv_path(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        use anyhow::Context;
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading trace {}", p.display()))?;
        let name = p
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "trace".into());
        Self::from_csv(&name, &text)
    }
}

/// YCSB-style workload mixes (read fraction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mix {
    /// YCSB-A: update heavy (50/50).
    UpdateHeavy,
    /// YCSB-B: read mostly (95/5).
    ReadMostly,
    /// YCSB-C: read only.
    ReadOnly,
    /// The paper's default mixed workload (70/30).
    PaperMixed,
    Custom(f32),
}

impl Mix {
    pub fn read_ratio(&self) -> f32 {
        match self {
            Mix::UpdateHeavy => 0.5,
            Mix::ReadMostly => 0.95,
            Mix::ReadOnly => 1.0,
            Mix::PaperMixed => 0.7,
            Mix::Custom(r) => r.clamp(0.0, 1.0),
        }
    }

    pub fn write_ratio(&self) -> f32 {
        1.0 - self.read_ratio()
    }
}

/// Trace generators.
pub struct TraceBuilder {
    thr_factor: f32,
    write_ratio: f32,
}

impl TraceBuilder {
    pub fn new(thr_factor: f32, write_ratio: f32) -> Self {
        Self { thr_factor, write_ratio }
    }

    pub fn from_config(cfg: &ModelConfig) -> Self {
        Self::new(cfg.workload.thr_factor, cfg.write_ratio())
    }

    fn point(&self, intensity: f32) -> WorkloadPoint {
        WorkloadPoint::new(intensity * self.thr_factor, self.write_ratio)
    }

    /// The paper's phased timeline (§V.C): each phase intensity held for
    /// `steps_per_phase` steps.
    pub fn phased(&self, phases: &[f32], steps_per_phase: usize) -> Trace {
        let points = phases
            .iter()
            .flat_map(|&i| std::iter::repeat(self.point(i)).take(steps_per_phase))
            .collect();
        Trace { name: "phased".into(), points }
    }

    /// The exact paper trace for a config (low/med/high/med/low).
    pub fn paper(cfg: &ModelConfig) -> Trace {
        let b = Self::from_config(cfg);
        let mut t = b.phased(&cfg.workload.phases, cfg.workload.steps_per_phase);
        t.name = "paper-50".into();
        t
    }

    /// Constant demand.
    pub fn constant(&self, intensity: f32, steps: usize) -> Trace {
        Trace {
            name: "constant".into(),
            points: vec![self.point(intensity); steps],
        }
    }

    /// Diurnal-style sinusoid between `lo` and `hi` intensity.
    pub fn sine(&self, lo: f32, hi: f32, period: usize, steps: usize) -> Trace {
        let mid = (lo + hi) / 2.0;
        let amp = (hi - lo) / 2.0;
        let points = (0..steps)
            .map(|t| {
                let phase = t as f32 / period.max(1) as f32 * std::f32::consts::TAU;
                self.point(mid + amp * phase.sin())
            })
            .collect();
        Trace { name: "sine".into(), points }
    }

    /// Baseline demand with seeded random bursts (failure of smooth
    /// assumptions; exercises transient behaviour).
    pub fn bursty(
        &self,
        base: f32,
        burst: f32,
        burst_prob: f64,
        steps: usize,
        seed: u64,
    ) -> Trace {
        let mut rng = XorShift64::new(seed);
        let points = (0..steps)
            .map(|_| {
                let i = if rng.next_f64() < burst_prob { burst } else { base };
                self.point(i)
            })
            .collect();
        Trace { name: "bursty".into(), points }
    }

    /// A single sudden spike — the paper's §VII concern about one-step
    /// local search needing multiple steps to reach feasibility.
    pub fn spike(&self, base: f32, peak: f32, at: usize, width: usize, steps: usize) -> Trace {
        let points = (0..steps)
            .map(|t| {
                let i = if t >= at && t < at + width { peak } else { base };
                self.point(i)
            })
            .collect();
        Trace { name: "spike".into(), points }
    }

    /// Linear ramp from `lo` to `hi`.
    pub fn ramp(&self, lo: f32, hi: f32, steps: usize) -> Trace {
        let points = (0..steps)
            .map(|t| {
                let frac = if steps > 1 { t as f32 / (steps - 1) as f32 } else { 0.0 };
                self.point(lo + (hi - lo) * frac)
            })
            .collect();
        Trace { name: "ramp".into(), points }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn builder() -> TraceBuilder {
        TraceBuilder::new(100.0, 0.3)
    }

    #[test]
    fn paper_trace_matches_section_v_c() {
        let cfg = ModelConfig::default_paper();
        let t = TraceBuilder::paper(&cfg);
        assert_eq!(t.len(), 50);
        assert_eq!(t.points[0].lambda_req, 6000.0);
        assert_eq!(t.points[10].lambda_req, 10000.0);
        assert_eq!(t.points[20].lambda_req, 16000.0);
        assert_eq!(t.points[30].lambda_req, 10000.0);
        assert_eq!(t.points[49].lambda_req, 6000.0);
        // paper: average required throughput is 9600
        assert!((t.avg_lambda_req() - 9600.0).abs() < 1.0);
        // write rate is 30% of demand
        assert!((t.points[0].lambda_w - 1800.0).abs() < 0.5);
    }

    #[test]
    fn flat_layout_interleaves() {
        let t = builder().constant(10.0, 2);
        assert_eq!(t.to_flat(), vec![1000.0, 300.0, 1000.0, 300.0]);
    }

    #[test]
    fn sine_bounded() {
        let t = builder().sine(50.0, 150.0, 20, 100);
        for p in &t.points {
            assert!(p.lambda_req >= 4999.0 && p.lambda_req <= 15001.0);
        }
    }

    #[test]
    fn bursty_deterministic_per_seed() {
        let a = builder().bursty(60.0, 200.0, 0.2, 50, 7);
        let b = builder().bursty(60.0, 200.0, 0.2, 50, 7);
        let c = builder().bursty(60.0, 200.0, 0.2, 50, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn bursty_hits_both_levels() {
        let t = builder().bursty(60.0, 200.0, 0.3, 200, 42);
        let bursts = t.points.iter().filter(|p| p.lambda_req > 10_000.0).count();
        assert!(bursts > 20 && bursts < 120);
    }

    #[test]
    fn spike_placed_correctly() {
        let t = builder().spike(60.0, 300.0, 10, 5, 30);
        assert_eq!(t.points[9].lambda_req, 6000.0);
        assert_eq!(t.points[10].lambda_req, 30000.0);
        assert_eq!(t.points[14].lambda_req, 30000.0);
        assert_eq!(t.points[15].lambda_req, 6000.0);
    }

    #[test]
    fn ramp_endpoints() {
        let t = builder().ramp(10.0, 20.0, 11);
        assert_eq!(t.points[0].lambda_req, 1000.0);
        assert_eq!(t.points[10].lambda_req, 2000.0);
    }

    #[test]
    fn concat_appends_in_order() {
        let a = builder().constant(10.0, 3);
        let b = builder().ramp(20.0, 30.0, 2);
        let c = a.concat(&b);
        assert_eq!(c.len(), 5);
        assert_eq!(&c.points[..3], &a.points[..]);
        assert_eq!(&c.points[3..], &b.points[..]);
        assert_eq!(c.name, "constant+ramp");
    }

    #[test]
    fn shifted_rotates_cyclically() {
        let cfg = ModelConfig::default_paper();
        let t = TraceBuilder::paper(&cfg);
        let s = t.shifted(10);
        assert_eq!(s.len(), t.len());
        for i in 0..t.len() {
            assert_eq!(s.points[i], t.points[(i + 10) % t.len()], "step {i}");
        }
        // the shifted trace starts in the paper's medium phase
        assert_eq!(s.points[0].lambda_req, 10000.0);
        // same multiset of demand: averages agree
        assert!((s.avg_lambda_req() - t.avg_lambda_req()).abs() < 1e-3);
    }

    #[test]
    fn shift_by_len_or_zero_is_identity() {
        let t = builder().ramp(10.0, 20.0, 7);
        assert_eq!(t.shifted(0).points, t.points);
        assert_eq!(t.shifted(7).points, t.points);
        assert_eq!(t.shifted(14).points, t.points);
        assert_eq!(t.shifted(9).points, t.shifted(2).points);
    }

    #[test]
    fn csv_roundtrip() {
        let cfg = ModelConfig::default_paper();
        let t = TraceBuilder::paper(&cfg);
        let back = Trace::from_csv("paper-50", &t.to_csv()).unwrap();
        assert_eq!(t.points, back.points);
    }

    #[test]
    fn csv_rejects_garbage() {
        assert!(Trace::from_csv("x", "step,lambda_req,lambda_w\n").is_err());
        assert!(Trace::from_csv("x", "h\n1,abc,2\n").is_err());
        assert!(Trace::from_csv("x", "h\n1,5\n").is_err());
        assert!(Trace::from_csv("x", "h\n1,-5,1\n").is_err());
    }

    #[test]
    fn csv_ignores_step_column_and_blank_lines() {
        let t = Trace::from_csv("x", "ts,req,w\n1699999999,100,30\n\n1700000000,200,60\n")
            .unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.points[1].lambda_req, 200.0);
    }

    #[test]
    fn mixes() {
        assert_eq!(Mix::ReadOnly.write_ratio(), 0.0);
        assert!((Mix::PaperMixed.write_ratio() - 0.3).abs() < 1e-6);
        assert_eq!(Mix::Custom(2.0).read_ratio(), 1.0);
    }
}
