//! Tiny deterministic PRNG (xorshift64*) so traces and the cluster
//! simulator are reproducible without an external `rand` dependency.

/// xorshift64* — fast, seedable, good enough for workload synthesis.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            0
        } else {
            self.next_u64() % n
        }
    }

    /// Exponentially distributed with the given mean (for Poisson-ish
    /// inter-arrival times in the cluster simulator).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.next_f64().max(f64::MIN_POSITIVE);
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift64::new(42);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_covers_interval() {
        let mut r = XorShift64::new(42);
        let xs: Vec<f64> = (0..1000).map(|_| r.next_f64()).collect();
        assert!(xs.iter().any(|&x| x < 0.1));
        assert!(xs.iter().any(|&x| x > 0.9));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.05);
    }

    #[test]
    fn exp_positive_with_roughly_right_mean() {
        let mut r = XorShift64::new(7);
        let xs: Vec<f64> = (0..5000).map(|_| r.exp(2.0)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 2.0).abs() < 0.15);
    }

    #[test]
    fn below_bounds() {
        let mut r = XorShift64::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
        assert_eq!(r.below(0), 0);
    }
}
