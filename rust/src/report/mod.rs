//! Report generation: the Table I summary and the data behind every
//! figure (1–8) as CSV, plus ASCII heatmaps for terminal inspection.
//!
//! Each emitter returns a `String`; [`write_all_figures`] materializes
//! the full set into an output directory (used by
//! `examples/paper_repro.rs` and the benches).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::metrics::Summary;
use crate::simulator::RunResult;
use crate::surfaces::SurfaceModel;

/// Table I: one row per policy (paper §VI.A).
pub fn table1(rows: &[(String, Summary)]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<18} {:>9} {:>11} {:>9} {:>10} {:>9} {:>9}",
        "Policy", "Avg.Lat.", "Avg.Thr.", "Avg.Cost", "TotalCost", "Avg.Obj.", "SLAViol."
    );
    for (name, s) in rows {
        let _ = writeln!(
            out,
            "{:<18} {:>9.2} {:>11.2} {:>9.3} {:>10.1} {:>9.2} {:>9}",
            name, s.avg_latency, s.avg_throughput, s.avg_cost, s.total_cost,
            s.avg_objective, s.violations
        );
    }
    out
}

/// Table I as CSV (machine-readable twin).
pub fn table1_csv(rows: &[(String, Summary)]) -> String {
    let mut out = String::from(
        "policy,avg_latency,max_latency,avg_throughput,avg_required,avg_cost,total_cost,avg_objective,violations,latency_violations,throughput_violations\n",
    );
    for (name, s) in rows {
        let _ = writeln!(
            out,
            "{},{:.4},{:.4},{:.2},{:.2},{:.4},{:.2},{:.4},{},{},{}",
            name, s.avg_latency, s.max_latency, s.avg_throughput, s.avg_required,
            s.avg_cost, s.total_cost, s.avg_objective, s.violations,
            s.latency_violations, s.throughput_violations
        );
    }
    out
}

/// Which surface a heatmap shows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Surface {
    Cost,
    Latency,
    Throughput,
    Coordination,
    Objective,
}

impl Surface {
    fn value(&self, model: &SurfaceModel, c: &crate::plane::Configuration, lam: f32) -> f32 {
        let p = model.evaluate(c, lam);
        match self {
            Surface::Cost => p.cost,
            Surface::Latency => p.latency,
            Surface::Throughput => p.throughput,
            Surface::Coordination => p.coordination,
            Surface::Objective => p.objective,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Surface::Cost => "cost",
            Surface::Latency => "latency",
            Surface::Throughput => "throughput",
            Surface::Coordination => "coordination",
            Surface::Objective => "objective",
        }
    }
}

/// Version tag of the ranked-candidate explain schema. Bump when the
/// JSON shape below changes; consumers dispatch on the `schema` field.
///
/// PR 6 extends the schema *additively* (no version bump — consumers
/// that ignore unknown fields keep working): fleet step objects
/// emitted by [`fleet_explain_json`] may carry
///
/// * `"lifecycle"` — the proposing tenant's serverless lifecycle at
///   proposal time (`"active"`, `"draining"`, `"suspended"`,
///   `"resuming"`); absent for always-on tenants.
/// * `"resume_end"` — for admitted wakes, the tick at which the
///   cold-start window scheduled on the fleet's DES calendar closes;
///   absent on every other verdict.
///
/// PR 7 adds (additively, same rules) top-level sampling fields to
/// fleet dumps produced under an explain reservoir
/// (`fleet --explain-sample`):
///
/// * `"sample_cap"` — the reservoir size; `steps` is then a uniform
///   sample of all move records, not the complete log.
/// * `"seen"` — how many move records the run offered to the
///   reservoir (the sampling denominator; equals `steps.length` on
///   unsampled runs). Both are absent when the log is unbounded.
///
/// PR 10 adds the top-level `"scenario"` field to fleet dumps of
/// scenario-driven runs (`fleet --scenario <name>`): the preset name
/// that generated the workloads and fault schedule. Absent otherwise.
pub const EXPLAIN_SCHEMA: &str = "diagonal-scale/explain-v1";

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// The ranked candidates of a run as versioned JSON
/// ([`EXPLAIN_SCHEMA`]): one entry per step carrying the proposal's
/// top-k candidates — target, hourly cost, ranking/myopic scores,
/// claimed gain, SLA feasibility — plus the chosen move and the
/// fallback flag. Hand-rolled emitter: the offline vendor set has no
/// serde.
pub fn explain_json(policy: &str, steps: &[crate::simulator::StepExplain]) -> String {
    let mut out = String::new();
    let _ = write!(
        out,
        "{{\"schema\":\"{}\",\"policy\":\"{}\",\"steps\":[",
        EXPLAIN_SCHEMA,
        json_escape(policy)
    );
    for (i, s) in steps.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"step\":{},\"demand\":{},\"fallback\":{},\"chosen\":{{\"h\":{},\"v\":{}}},\"candidates\":[",
            s.step, s.demand, s.fallback, s.chosen.h_idx, s.chosen.v_idx
        );
        for (j, c) in s.candidates.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"h\":{},\"v\":{},\"cost\":{},\"score\":{},\"raw\":{},\"gain\":{},\"feasible\":{}}}",
                c.to.h_idx,
                c.to.v_idx,
                c.cost_to,
                c.score,
                c.raw,
                c.gain,
                c.feasible()
            );
        }
        out.push_str("]}");
    }
    out.push_str("]}");
    out
}

/// Fleet admission decisions as versioned JSON ([`EXPLAIN_SCHEMA`]):
/// one entry per captured proposal with tenant, class, verdict, ranked
/// candidates, and — additively since PR 6 — the proposing tenant's
/// serverless `lifecycle` and, for admitted wakes, the `resume_end`
/// tick of the cold-start window opened on the fleet's DES calendar
/// (both omitted when absent, so pre-PR-6 consumers parse unchanged).
pub fn fleet_explain_json(records: &[crate::fleet::ExplainRecord]) -> String {
    fleet_explain_json_sampled(records, 0, records.len() as u64)
}

/// [`fleet_explain_json`] for reservoir-sampled logs: stamps the
/// additive PR-7 `sample_cap` / `seen` fields so consumers know
/// `steps` is a uniform sample (`sample_cap` = 0 means unbounded and
/// emits neither field).
pub fn fleet_explain_json_sampled(
    records: &[crate::fleet::ExplainRecord],
    sample_cap: usize,
    seen: u64,
) -> String {
    fleet_explain_json_scenario(records, sample_cap, seen, None)
}

/// [`fleet_explain_json_sampled`] with the additive top-level
/// `scenario` field: the named preset (`fleet --scenario <name>`) that
/// generated the run's workloads and fault schedule. Omitted when the
/// run was not scenario-driven, so pre-scenario consumers parse
/// unchanged.
pub fn fleet_explain_json_scenario(
    records: &[crate::fleet::ExplainRecord],
    sample_cap: usize,
    seen: u64,
    scenario: Option<&str>,
) -> String {
    let mut out = String::new();
    let _ = write!(out, "{{\"schema\":\"{EXPLAIN_SCHEMA}\",\"kind\":\"fleet\"");
    if let Some(name) = scenario {
        let _ = write!(out, ",\"scenario\":\"{}\"", json_escape(name));
    }
    if sample_cap > 0 {
        let _ = write!(out, ",\"sample_cap\":{sample_cap},\"seen\":{seen}");
    }
    let _ = write!(out, ",\"steps\":[");
    for (i, r) in records.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"step\":{},\"tenant\":{},\"class\":\"{}\",\"verdict\":\"{:?}\",\"from\":{{\"h\":{},\"v\":{}}}",
            r.step, r.tenant, r.class.label(), r.verdict, r.from.h_idx, r.from.v_idx
        );
        if let Some(lc) = r.lifecycle {
            let _ = write!(out, ",\"lifecycle\":\"{lc}\"");
        }
        if let Some(end) = r.resume_end {
            let _ = write!(out, ",\"resume_end\":{end}");
        }
        let _ = write!(out, ",\"sheds\":{},\"candidates\":[", r.sheds);
        for (j, c) in r.candidates.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"h\":{},\"v\":{},\"cost\":{},\"score\":{},\"raw\":{},\"gain\":{},\"feasible\":{}}}",
                c.to.h_idx,
                c.to.v_idx,
                c.cost_to,
                c.score,
                c.raw,
                c.gain,
                c.feasible()
            );
        }
        out.push_str("]}");
    }
    out.push_str("]}");
    out
}

/// Heatmap over the plane as CSV: rows H, columns V (figures 1, 2, 4).
pub fn heatmap_csv(model: &SurfaceModel, surface: Surface, lambda_req: f32) -> String {
    let plane = model.plane();
    let mut out = String::from("h");
    for t in plane.tiers() {
        let _ = write!(out, ",{}", t.name);
    }
    out.push('\n');
    for (i, h) in plane.h_values().iter().enumerate() {
        let _ = write!(out, "{h}");
        for j in 0..plane.n_v() {
            let c = crate::plane::Configuration::new(i, j);
            let _ = write!(out, ",{:.4}", surface.value(model, &c, lambda_req));
        }
        out.push('\n');
    }
    out
}

/// Long-form surface dump `(h, tier, value)` — figure 3's 3-D surface.
pub fn surface_csv(model: &SurfaceModel, surface: Surface, lambda_req: f32) -> String {
    let plane = model.plane();
    let mut out = String::from("h,tier,value\n");
    for c in plane.iter() {
        let _ = writeln!(
            out,
            "{},{},{:.4}",
            plane.h_value(&c),
            plane.tier(&c).name,
            surface.value(model, &c, lambda_req)
        );
    }
    out
}

/// ASCII heatmap for terminal output (quickstart example).
pub fn heatmap_ascii(model: &SurfaceModel, surface: Surface, lambda_req: f32) -> String {
    let plane = model.plane();
    let mut vals = Vec::with_capacity(plane.len());
    for c in plane.iter() {
        vals.push(surface.value(model, &c, lambda_req));
    }
    let (lo, hi) = vals
        .iter()
        .fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '@'];
    let mut out = format!("{} surface (lambda_req={lambda_req})\n", surface.name());
    let _ = writeln!(
        out,
        "      {}",
        plane
            .tiers()
            .iter()
            .map(|t| format!("{:>8}", t.name))
            .collect::<String>()
    );
    for (i, h) in plane.h_values().iter().enumerate() {
        let _ = write!(out, "H={h:<3} ");
        for j in 0..plane.n_v() {
            let v = vals[i * plane.n_v() + j];
            let norm = if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
            let idx = ((norm * (shades.len() - 1) as f32).round() as usize)
                .min(shades.len() - 1);
            let _ = write!(out, " {:>5.1} {}", v, shades[idx]);
        }
        out.push('\n');
    }
    out
}

/// Policy trajectories (figure 5): step, per-policy (H, tier).
pub fn trajectories_csv(runs: &[RunResult], model: &SurfaceModel) -> String {
    let plane = model.plane();
    let mut out = String::from("step");
    for r in runs {
        let _ = write!(out, ",{}_h,{}_tier", r.policy, r.policy);
    }
    out.push('\n');
    let steps = runs.iter().map(|r| r.records.len()).max().unwrap_or(0);
    for t in 0..steps {
        let _ = write!(out, "{t}");
        for r in runs {
            match r.records.get(t) {
                Some(rec) => {
                    let _ = write!(
                        out,
                        ",{},{}",
                        plane.h_value(&rec.config),
                        plane.tier(&rec.config).name
                    );
                }
                None => out.push_str(",,"),
            }
        }
        out.push('\n');
    }
    out
}

/// A per-step metric across policies (figures 6, 7, 8).
pub fn timeseries_csv(runs: &[RunResult], metric: Metric) -> String {
    let mut out = String::from("step");
    for r in runs {
        let _ = write!(out, ",{}", r.policy);
    }
    out.push('\n');
    let steps = runs.iter().map(|r| r.records.len()).max().unwrap_or(0);
    for t in 0..steps {
        let _ = write!(out, "{t}");
        for r in runs {
            match r.records.get(t) {
                Some(rec) => {
                    let _ = write!(out, ",{:.4}", metric.value(rec));
                }
                None => out.push(','),
            }
        }
        out.push('\n');
    }
    out
}

/// Time-series metric selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Latency,
    Cost,
    Objective,
    Throughput,
}

impl Metric {
    fn value(&self, rec: &crate::metrics::StepRecord) -> f32 {
        match self {
            Metric::Latency => rec.latency,
            Metric::Cost => rec.cost,
            Metric::Objective => rec.objective,
            Metric::Throughput => rec.throughput,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Metric::Latency => "latency",
            Metric::Cost => "cost",
            Metric::Objective => "objective",
            Metric::Throughput => "throughput",
        }
    }
}

/// Emit every paper artifact (Table I + figures 1–8) into `dir`.
pub fn write_all_figures(
    dir: impl AsRef<Path>,
    model: &SurfaceModel,
    runs: &[RunResult],
    default_lambda: f32,
) -> Result<Vec<String>> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let rows: Vec<(String, Summary)> =
        runs.iter().map(|r| (r.policy.clone(), r.summary)).collect();
    let files: Vec<(&str, String)> = vec![
        ("table1.txt", table1(&rows)),
        ("table1.csv", table1_csv(&rows)),
        ("fig1_cost_heatmap.csv", heatmap_csv(model, Surface::Cost, default_lambda)),
        ("fig2_latency_heatmap.csv", heatmap_csv(model, Surface::Latency, default_lambda)),
        ("fig3_latency_surface.csv", surface_csv(model, Surface::Latency, default_lambda)),
        ("fig4_objective_heatmap.csv", heatmap_csv(model, Surface::Objective, default_lambda)),
        ("fig5_trajectories.csv", trajectories_csv(runs, model)),
        ("fig6_latency_over_time.csv", timeseries_csv(runs, Metric::Latency)),
        ("fig7_cost_over_time.csv", timeseries_csv(runs, Metric::Cost)),
        ("fig8_objective_over_time.csv", timeseries_csv(runs, Metric::Objective)),
    ];
    let mut written = Vec::new();
    for (name, content) in files {
        let path = dir.join(name);
        std::fs::write(&path, content)?;
        written.push(path.display().to_string());
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::simulator::Simulator;
    use crate::workload::TraceBuilder;

    fn runs() -> (SurfaceModel, Vec<RunResult>) {
        let cfg = ModelConfig::default_paper();
        let sim = Simulator::new(&cfg);
        let trace = TraceBuilder::paper(&cfg);
        let model = SurfaceModel::from_config(&cfg);
        (model, sim.run_paper_set(&trace))
    }

    #[test]
    fn table1_has_three_rows() {
        let (_, runs) = runs();
        let rows: Vec<_> = runs.iter().map(|r| (r.policy.clone(), r.summary)).collect();
        let t = table1(&rows);
        assert_eq!(t.lines().count(), 4); // header + 3 policies
        assert!(t.contains("DiagonalScale"));
        assert!(t.contains("Horizontal-only"));
        assert!(t.contains("Vertical-only"));
    }

    #[test]
    fn heatmap_csv_dimensions() {
        let (model, _) = runs();
        let csv = heatmap_csv(&model, Surface::Cost, 10_000.0);
        let lines: Vec<_> = csv.lines().collect();
        assert_eq!(lines.len(), 5); // header + 4 H rows
        assert_eq!(lines[0], "h,small,medium,large,xlarge");
        assert!(lines[1].starts_with("1,"));
        assert!(lines[4].starts_with("8,"));
    }

    #[test]
    fn surface_csv_is_long_form() {
        let (model, _) = runs();
        let csv = surface_csv(&model, Surface::Latency, 10_000.0);
        assert_eq!(csv.lines().count(), 17); // header + 16 cells
    }

    #[test]
    fn timeseries_has_a_column_per_policy() {
        let (_, runs) = runs();
        let csv = timeseries_csv(&runs, Metric::Latency);
        let header = csv.lines().next().unwrap();
        assert_eq!(header.split(',').count(), 4);
        assert_eq!(csv.lines().count(), 51);
    }

    #[test]
    fn trajectories_track_h_and_tier() {
        let (model, runs) = runs();
        let csv = trajectories_csv(&runs, &model);
        assert_eq!(csv.lines().next().unwrap().split(',').count(), 7);
        assert_eq!(csv.lines().count(), 51);
    }

    #[test]
    fn ascii_heatmap_mentions_every_tier() {
        let (model, _) = runs();
        let art = heatmap_ascii(&model, Surface::Latency, 10_000.0);
        for t in ["small", "medium", "large", "xlarge"] {
            assert!(art.contains(t));
        }
    }

    #[test]
    fn explain_json_is_versioned_and_carries_ranked_candidates() {
        let cfg = ModelConfig::default_paper();
        let sim = Simulator::new(&cfg);
        let trace = TraceBuilder::paper(&cfg);
        let (run, steps) = sim.run_explained(crate::simulator::PolicyKind::Diagonal, &trace, 3);
        assert_eq!(steps.len(), 50);
        let json = explain_json(&run.policy, &steps);
        assert!(json.starts_with(&format!("{{\"schema\":\"{EXPLAIN_SCHEMA}\"")));
        assert!(json.contains("\"policy\":\"DiagonalScale\""));
        assert!(json.contains("\"candidates\":["));
        assert!(json.contains("\"feasible\":true"));
        // structurally sound: balanced braces/brackets, one step object
        // per simulation step
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count(),
            "unbalanced braces"
        );
        assert_eq!(json.matches("\"step\":").count(), 50);
        // the explained trajectory is the plain run, bit for bit
        let plain = sim.run(crate::simulator::PolicyKind::Diagonal, &trace);
        assert_eq!(plain.records, run.records);
        for (s, rec) in steps.iter().zip(plain.records.iter().skip(1)) {
            assert_eq!(s.chosen, rec.config, "explain chose a different trajectory");
        }
    }

    #[test]
    fn fleet_explain_json_carries_lifecycle_fields() {
        let cfg = ModelConfig::default_paper();
        let specs = crate::serverless::mostly_idle_specs(&cfg, 8, 0.75);
        let mut fleet = crate::fleet::FleetSimulator::new(&cfg, specs, 1.0e6, 3);
        fleet.enable_serverless(Default::default());
        fleet.enable_explain(3);
        fleet.run(100);
        let json = fleet_explain_json(fleet.explain_log());
        assert!(json.starts_with(&format!("{{\"schema\":\"{EXPLAIN_SCHEMA}\"")));
        assert!(json.contains("\"kind\":\"fleet\""));
        // the additive PR-6 fields: wake proposals carry the suspended
        // lifecycle, and admitted wakes stamp their cold-start window
        assert!(json.contains("\"lifecycle\":\"suspended\""), "no wake captured");
        assert!(json.contains("\"resume_end\":"), "no cold-start window in explain");
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn sampled_fleet_explain_carries_reservoir_fields() {
        let cfg = ModelConfig::default_paper();
        let specs = crate::serverless::mostly_idle_specs(&cfg, 8, 0.75);
        let mut fleet = crate::fleet::FleetSimulator::new(&cfg, specs, 1.0e6, 3);
        fleet.enable_serverless(Default::default());
        fleet.enable_explain(3);
        fleet.set_explain_sample(5);
        fleet.run(100);
        let log = fleet.explain_log();
        assert!(log.len() <= 5, "reservoir exceeded its cap: {}", log.len());
        assert!(fleet.explain_seen() > 5, "scenario produced too few move records");
        let json =
            fleet_explain_json_sampled(log, fleet.explain_sample_cap(), fleet.explain_seen());
        assert!(json.contains("\"sample_cap\":5"));
        assert!(json.contains(&format!("\"seen\":{}", fleet.explain_seen())));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        // unsampled dumps stay bit-identical to the pre-PR-7 shape
        let plain = fleet_explain_json(log);
        assert!(!plain.contains("sample_cap"));
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("plain"), "plain");
    }

    #[test]
    fn write_all_figures_materializes_ten_files() {
        let (model, runs) = runs();
        let dir = crate::testkit::TempDir::new().unwrap();
        let files = write_all_figures(dir.path(), &model, &runs, 10_000.0).unwrap();
        assert_eq!(files.len(), 10);
        for f in files {
            assert!(std::fs::metadata(&f).unwrap().len() > 0);
        }
    }
}
