//! The Scaling Plane (paper §III.A): the two-dimensional discrete
//! configuration space `(H, V)` of node counts × vertical resource
//! tiers, and the local neighborhood used by Algorithm 1 (§IV.B).


/// A vertical resource tier: per-node CPU, RAM, network bandwidth,
/// storage IOPS, and hourly cost (paper §III.A).
#[derive(Debug, Clone, PartialEq)]
pub struct Tier {
    pub name: String,
    pub cpu: f32,
    pub ram: f32,
    pub bandwidth: f32,
    pub iops: f32,
    pub cost: f32,
}

impl Tier {
    /// IOPS in thousands, the unit the latency/throughput surfaces use.
    pub fn iops_k(&self) -> f32 {
        self.iops / 1000.0
    }

    /// The binding resource: `min(cpu, ram, bandwidth, iops/1000)`
    /// (paper §III.D, the T_node bottleneck).
    pub fn min_resource(&self) -> f32 {
        self.cpu
            .min(self.ram)
            .min(self.bandwidth)
            .min(self.iops_k())
    }
}

/// A point in the Scaling Plane, stored as *indices* into the discrete
/// H and V lists (the paper's "previous/next valid value" neighborhood
/// is index-adjacency).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Configuration {
    pub h_idx: usize,
    pub v_idx: usize,
}

impl Configuration {
    pub fn new(h_idx: usize, v_idx: usize) -> Self {
        Self { h_idx, v_idx }
    }

    /// Index-space distance components `(|dH|, |dV|)` to another config
    /// — the inputs to the rebalance penalty (paper §IV.D).
    pub fn index_distance(&self, other: &Configuration) -> (usize, usize) {
        (
            self.h_idx.abs_diff(other.h_idx),
            self.v_idx.abs_diff(other.v_idx),
        )
    }
}

/// The full discrete plane: H values, tiers, and neighbor generation.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingPlane {
    h_values: Vec<u32>,
    tiers: Vec<Tier>,
}

impl ScalingPlane {
    pub fn new(h_values: Vec<u32>, tiers: Vec<Tier>) -> Self {
        assert!(!h_values.is_empty() && !tiers.is_empty());
        Self { h_values, tiers }
    }

    pub fn n_h(&self) -> usize {
        self.h_values.len()
    }

    pub fn n_v(&self) -> usize {
        self.tiers.len()
    }

    /// Total number of deployable configurations (paper: 4 × 4 = 16).
    pub fn len(&self) -> usize {
        self.n_h() * self.n_v()
    }

    pub fn is_empty(&self) -> bool {
        false // both axes are non-empty by construction
    }

    pub fn h_value(&self, cfg: &Configuration) -> u32 {
        self.h_values[cfg.h_idx]
    }

    pub fn tier(&self, cfg: &Configuration) -> &Tier {
        &self.tiers[cfg.v_idx]
    }

    pub fn h_values(&self) -> &[u32] {
        &self.h_values
    }

    pub fn tiers(&self) -> &[Tier] {
        &self.tiers
    }

    pub fn contains(&self, cfg: &Configuration) -> bool {
        cfg.h_idx < self.n_h() && cfg.v_idx < self.n_v()
    }

    /// Iterate every configuration in row-major (H-major) order — the
    /// shared tie-breaking order of the whole stack.
    pub fn iter(&self) -> impl Iterator<Item = Configuration> + '_ {
        (0..self.n_h()).flat_map(move |h| {
            (0..self.n_v()).map(move |v| Configuration::new(h, v))
        })
    }

    /// The Algorithm-1 neighborhood of `cfg` (paper §IV.B): the current
    /// configuration plus every in-bounds combination of
    /// previous/next H and previous/next V, optionally restricted to
    /// one axis. Emitted in row-major order, self included; at most 9.
    pub fn neighbors(
        &self,
        cfg: &Configuration,
        allow_dh: bool,
        allow_dv: bool,
    ) -> Vec<Configuration> {
        let mut out = Vec::with_capacity(9);
        for dh in -1i32..=1 {
            if dh != 0 && !allow_dh {
                continue;
            }
            let h = cfg.h_idx as i32 + dh;
            if h < 0 || h >= self.n_h() as i32 {
                continue;
            }
            for dv in -1i32..=1 {
                if dv != 0 && !allow_dv {
                    continue;
                }
                let v = cfg.v_idx as i32 + dv;
                if v < 0 || v >= self.n_v() as i32 {
                    continue;
                }
                out.push(Configuration::new(h as usize, v as usize));
            }
        }
        out
    }

    /// Allocation-free neighborhood visit in row-major order — the
    /// simulator's hot loop (same candidate set as [`Self::neighbors`]).
    #[inline]
    pub fn for_each_neighbor(
        &self,
        cfg: &Configuration,
        allow_dh: bool,
        allow_dv: bool,
        mut f: impl FnMut(Configuration),
    ) {
        let h_lo = if allow_dh { cfg.h_idx.saturating_sub(1) } else { cfg.h_idx };
        let h_hi = if allow_dh { (cfg.h_idx + 1).min(self.n_h() - 1) } else { cfg.h_idx };
        let v_lo = if allow_dv { cfg.v_idx.saturating_sub(1) } else { cfg.v_idx };
        let v_hi = if allow_dv { (cfg.v_idx + 1).min(self.n_v() - 1) } else { cfg.v_idx };
        for h in h_lo..=h_hi {
            for v in v_lo..=v_hi {
                f(Configuration::new(h, v));
            }
        }
    }

    /// One-step scale-up fallback (Algorithm 1 line 18): move +1 on each
    /// axis the policy may change, clamped to the plane boundary.
    pub fn fallback_up(
        &self,
        cfg: &Configuration,
        allow_dh: bool,
        allow_dv: bool,
    ) -> Configuration {
        Configuration::new(
            if allow_dh {
                (cfg.h_idx + 1).min(self.n_h() - 1)
            } else {
                cfg.h_idx
            },
            if allow_dv {
                (cfg.v_idx + 1).min(self.n_v() - 1)
            } else {
                cfg.v_idx
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn plane() -> ScalingPlane {
        ModelConfig::default_paper().plane()
    }

    #[test]
    fn sixteen_configurations() {
        let p = plane();
        assert_eq!(p.len(), 16);
        assert_eq!(p.iter().count(), 16);
    }

    #[test]
    fn interior_neighborhood_is_nine() {
        let p = plane();
        let n = p.neighbors(&Configuration::new(1, 1), true, true);
        assert_eq!(n.len(), 9);
        assert!(n.contains(&Configuration::new(1, 1))); // self included
        assert!(n.contains(&Configuration::new(0, 0)));
        assert!(n.contains(&Configuration::new(2, 2)));
    }

    #[test]
    fn corner_neighborhood_is_four() {
        let p = plane();
        let n = p.neighbors(&Configuration::new(0, 0), true, true);
        assert_eq!(n.len(), 4);
        let n = p.neighbors(&Configuration::new(3, 3), true, true);
        assert_eq!(n.len(), 4);
    }

    #[test]
    fn axis_restricted_neighborhoods() {
        let p = plane();
        let n = p.neighbors(&Configuration::new(1, 1), true, false);
        assert_eq!(n.len(), 3);
        assert!(n.iter().all(|c| c.v_idx == 1));
        let n = p.neighbors(&Configuration::new(1, 1), false, true);
        assert_eq!(n.len(), 3);
        assert!(n.iter().all(|c| c.h_idx == 1));
    }

    #[test]
    fn neighbors_in_row_major_order() {
        let p = plane();
        let n = p.neighbors(&Configuration::new(2, 2), true, true);
        let flat: Vec<usize> = n.iter().map(|c| c.h_idx * 8 + c.v_idx).collect();
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        assert_eq!(flat, sorted);
    }

    #[test]
    fn fallback_clamps_at_boundary() {
        let p = plane();
        let top = Configuration::new(3, 3);
        assert_eq!(p.fallback_up(&top, true, true), top);
        let mid = Configuration::new(1, 2);
        assert_eq!(p.fallback_up(&mid, true, true), Configuration::new(2, 3));
        assert_eq!(p.fallback_up(&mid, true, false), Configuration::new(2, 2));
        assert_eq!(p.fallback_up(&mid, false, true), Configuration::new(1, 3));
    }

    #[test]
    fn min_resource_is_bottleneck() {
        let p = plane();
        // every default tier is cpu-bound (cpu == min)
        for t in p.tiers() {
            assert_eq!(t.min_resource(), t.cpu);
        }
    }

    #[test]
    fn index_distance() {
        let a = Configuration::new(0, 3);
        let b = Configuration::new(2, 1);
        assert_eq!(a.index_distance(&b), (2, 2));
        assert_eq!(b.index_distance(&a), (2, 2));
        assert_eq!(a.index_distance(&a), (0, 0));
    }
}
