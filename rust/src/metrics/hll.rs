//! Dependency-free HyperLogLog cardinality sketch (Flajolet et al.
//! 2007) for the fleet-scale counters the exact sets cannot afford:
//! distinct active tenants per window, distinct configurations visited,
//! distinct hosts touched by placement.
//!
//! Design constraints (see `CONTRIBUTING.md` / simlint):
//!
//! * **Deterministic hashing** — no `std::collections::hash_map::
//!   RandomState`. Integers go through [`hash_u64`] (an FxHash-style
//!   multiply–xor finisher, the splitmix64 output permutation); byte
//!   strings through [`FxHasher64`], a rotate–xor–multiply fold with the
//!   FxHash constant. Same input, same sketch, every process (simlint
//!   d2 bans the unordered std hasher from decision code anyway).
//! * **Dense registers** — a flat `Vec<u8>` of `m = 2^p` six-bit-range
//!   registers, not a map: O(m) memory, O(1) insert, O(m) estimate,
//!   trivially mergeable by register-wise max.
//!
//! The standard error of the estimator is `1.04/sqrt(m)`;
//! `rust/tests/metrics_hll.rs` property-pins relative error within
//! three standard errors against exact sets across seeded cardinalities
//! from 10 to 100k.

/// FxHash-style avalanche for a single 64-bit value (the splitmix64
/// output permutation). Bijective, so distinct keys never collide
/// before bucketing.
#[inline]
pub fn hash_u64(v: u64) -> u64 {
    let mut x = v.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Deterministic byte-stream hasher: rotate–xor–multiply with the
/// FxHash constant, finished through [`hash_u64`]. Not cryptographic —
/// just stable and well-mixed enough for register bucketing.
#[derive(Debug, Clone, Default)]
pub struct FxHasher64 {
    state: u64,
    len: u64,
}

const FX_SEED: u64 = 0x517c_c1b7_2722_0a95;

impl FxHasher64 {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state = (self.state.rotate_left(5) ^ u64::from(b)).wrapping_mul(FX_SEED);
        }
        self.len += bytes.len() as u64;
    }

    pub fn finish(&self) -> u64 {
        hash_u64(self.state ^ self.len)
    }
}

/// Convenience: hash a byte slice in one call.
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h = FxHasher64::new();
    h.write(bytes);
    h.finish()
}

/// Dense HyperLogLog with `2^p` one-byte registers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hll {
    p: u32,
    registers: Vec<u8>,
}

/// Default precision: `m = 1024` registers (1 KiB), standard error
/// `1.04/sqrt(1024) ≈ 3.25%` — plenty for fleet-size cardinalities.
pub const DEFAULT_PRECISION: u32 = 10;

impl Default for Hll {
    fn default() -> Self {
        Self::new(DEFAULT_PRECISION)
    }
}

impl Hll {
    /// `p` index bits, `m = 2^p` registers. Valid range 4..=16.
    pub fn new(p: u32) -> Self {
        assert!((4..=16).contains(&p), "hll precision must be in 4..=16, got {p}");
        Self { p, registers: vec![0u8; 1 << p] }
    }

    pub fn precision(&self) -> u32 {
        self.p
    }

    /// Register count `m`.
    pub fn m(&self) -> usize {
        self.registers.len()
    }

    /// Standard error of [`estimate`](Self::estimate): `1.04/sqrt(m)`.
    pub fn standard_error(&self) -> f64 {
        1.04 / (self.m() as f64).sqrt()
    }

    /// True iff no value has ever been inserted.
    pub fn is_empty(&self) -> bool {
        self.registers.iter().all(|&r| r == 0)
    }

    /// Insert a pre-hashed 64-bit value: top `p` bits pick the
    /// register, the rank of the first set bit in the rest updates it.
    pub fn insert_hash(&mut self, h: u64) {
        let idx = (h >> (64 - self.p)) as usize;
        let rest = h << self.p;
        let rho = (rest.leading_zeros() + 1).min(64 - self.p + 1) as u8;
        if rho > self.registers[idx] {
            self.registers[idx] = rho;
        }
    }

    pub fn insert_u64(&mut self, v: u64) {
        self.insert_hash(hash_u64(v));
    }

    pub fn insert_bytes(&mut self, bytes: &[u8]) {
        self.insert_hash(hash_bytes(bytes));
    }

    /// Bias-corrected cardinality estimate with the standard
    /// linear-counting correction for the small range.
    pub fn estimate(&self) -> f64 {
        let m = self.m() as f64;
        let mut inv_sum = 0.0f64;
        let mut zeros = 0usize;
        for &r in &self.registers {
            inv_sum += (-(f64::from(r))).exp2();
            if r == 0 {
                zeros += 1;
            }
        }
        let alpha = match self.m() {
            16 => 0.673,
            32 => 0.697,
            64 => 0.709,
            mm => 0.7213 / (1.0 + 1.079 / mm as f64),
        };
        let raw = alpha * m * m / inv_sum;
        if raw <= 2.5 * m && zeros > 0 {
            // Linear counting: far more accurate while registers are
            // mostly empty.
            m * (m / zeros as f64).ln()
        } else {
            raw
        }
    }

    /// Union: register-wise max. `estimate(A ∪ B)` from merged sketches
    /// is exactly the sketch of the concatenated streams.
    pub fn merge(&mut self, other: &Hll) {
        assert_eq!(self.p, other.p, "cannot merge hll sketches of different precision");
        for (a, &b) in self.registers.iter_mut().zip(&other.registers) {
            *a = (*a).max(b);
        }
    }

    /// Reset all registers (start a new counting window).
    pub fn clear(&mut self) {
        self.registers.iter_mut().for_each(|r| *r = 0);
    }
}

/// A fixed ring of per-window HLL sketches: one **open** sketch
/// accepting inserts, plus the last `cap` **closed** windows retained
/// for merged lookback queries ("distinct tenants active over the last
/// W windows"). [`HllWindowRing::rotate`] closes the open window —
/// returning its estimate, the per-window gauge — pushes it onto the
/// ring, and evicts the oldest window past `cap`. Memory is a strict
/// `(cap + 1) × 2^p` bytes regardless of run length; the single
/// clear-on-rotate sketch this replaces kept only the open window, so
/// the merged lookback estimate was impossible to export.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HllWindowRing {
    open: Hll,
    /// Closed windows, oldest first, at most `cap`.
    ring: Vec<Hll>,
    cap: usize,
    rotations: u64,
}

impl HllWindowRing {
    /// Ring retaining the last `cap` closed windows, each a sketch of
    /// precision `p`.
    pub fn new(cap: usize, p: u32) -> Self {
        assert!(cap > 0, "window ring needs room for at least one closed window");
        Self { open: Hll::new(p), ring: Vec::with_capacity(cap), cap, rotations: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Windows closed so far (monotonic, not bounded by the capacity).
    pub fn rotations(&self) -> u64 {
        self.rotations
    }

    /// Insert into the still-open window.
    pub fn insert_u64(&mut self, v: u64) {
        self.open.insert_u64(v);
    }

    /// Estimate of the still-open window.
    pub fn open_estimate(&self) -> f64 {
        self.open.estimate()
    }

    /// True iff nothing has been inserted since the last rotation.
    pub fn open_is_empty(&self) -> bool {
        self.open.is_empty()
    }

    /// Retained closed windows, oldest first.
    pub fn closed_windows(&self) -> &[Hll] {
        &self.ring
    }

    /// Close the open window: push it onto the ring (evicting the
    /// oldest past capacity), start a fresh open sketch, and return the
    /// closed window's estimate.
    pub fn rotate(&mut self) -> f64 {
        let est = self.open.estimate();
        let closed = std::mem::replace(&mut self.open, Hll::new(self.open.precision()));
        if self.ring.len() == self.cap {
            self.ring.remove(0);
        }
        self.ring.push(closed);
        self.rotations += 1;
        est
    }

    /// Cardinality of the union of every retained closed window — the
    /// "distinct actives over the last W windows" gauge. Register-max
    /// merge, so this equals the estimate of one sketch fed all the
    /// retained streams.
    pub fn merged_estimate(&self) -> f64 {
        let Some(first) = self.ring.first() else {
            return 0.0;
        };
        let mut merged = first.clone();
        for w in &self.ring[1..] {
            merged.merge(w);
        }
        merged.estimate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_sketch_estimates_zero() {
        let h = Hll::default();
        assert!(h.is_empty());
        assert_eq!(h.estimate(), 0.0);
    }

    #[test]
    fn duplicates_do_not_grow_the_estimate() {
        let mut h = Hll::default();
        for _ in 0..10_000 {
            h.insert_u64(42);
        }
        let est = h.estimate();
        assert!(est >= 0.9 && est <= 1.1, "estimate for one distinct value: {est}");
    }

    #[test]
    fn small_cardinalities_are_near_exact() {
        let mut h = Hll::default();
        for v in 0..100u64 {
            h.insert_u64(v);
        }
        let est = h.estimate();
        // 3 standard errors at m=1024 is 9.75%; this seed sits at ~5.8%.
        assert!((est - 100.0).abs() / 100.0 < 0.0975, "estimate: {est}");
    }

    #[test]
    fn merge_equals_union_of_streams() {
        let mut a = Hll::default();
        let mut b = Hll::default();
        let mut union = Hll::default();
        for v in 0..500u64 {
            a.insert_u64(v);
            union.insert_u64(v);
        }
        for v in 300..900u64 {
            b.insert_u64(v);
            union.insert_u64(v);
        }
        a.merge(&b);
        assert_eq!(a, union);
    }

    #[test]
    fn clear_resets_to_empty() {
        let mut h = Hll::default();
        h.insert_u64(7);
        assert!(!h.is_empty());
        h.clear();
        assert!(h.is_empty());
        assert_eq!(h.estimate(), 0.0);
    }

    #[test]
    fn byte_hasher_is_deterministic_and_spreads() {
        assert_eq!(hash_bytes(b"host-0"), hash_bytes(b"host-0"));
        assert_ne!(hash_bytes(b"host-0"), hash_bytes(b"host-1"));
        assert_ne!(hash_bytes(b""), hash_bytes(b"\0"));
    }

    #[test]
    fn precision_bounds_are_enforced() {
        let h = Hll::new(4);
        assert_eq!(h.m(), 16);
        let h = Hll::new(16);
        assert_eq!(h.m(), 1 << 16);
    }
}
