//! Time-series recording and summary statistics (paper §V.E: average
//! latency, max latency, average/total cost, average objective, SLA
//! violations decomposed into latency and throughput violations), plus
//! the sublinear observability layer:
//!
//! * [`LatencyHistogram`] — log-bucketed percentile sketch (mergeable).
//! * [`StreamingRecorder`] — O(1)-memory replacement for [`Recorder`]:
//!   summary accumulators + latency sketches + an Algorithm-R exemplar
//!   reservoir. [`Recorder`] stays as the exact oracle it is pinned
//!   against.
//! * [`hll`] — dependency-free HyperLogLog cardinality sketches for
//!   distinct-active-tenants / configurations / hosts counting.
//! * [`registry`] — pull-based export: counters, gauges, and histogram
//!   series rendered as Prometheus text or `diagonal-scale/metrics-v1`
//!   JSON, with the name set pinned in [`names`] /
//!   `config/metrics_v1.names`.

mod histogram;
pub mod hll;
pub mod names;
pub mod registry;
mod streaming;

pub use histogram::LatencyHistogram;
pub use hll::{Hll, HllWindowRing};
pub use registry::{MetricsRegistry, METRICS_SCHEMA};
pub use streaming::{reservoir_sample, StreamingRecorder};

use crate::plane::Configuration;
use crate::sla::{Violation, ViolationCounter};

/// Resolution floor shared by the per-tenant latency sketches: 10 µs
/// in seconds-scale latency units. Values below (idle/suspended steps
/// record zero latency) land in the underflow bucket and report as the
/// floor.
pub const LATENCY_FLOOR: f64 = 1e-5;

/// Everything measured for one served simulation step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepRecord {
    pub step: usize,
    pub config: Configuration,
    /// Node count and tier index are implied by `config`; the demand:
    pub lambda_req: f32,
    /// Measured (utilization-corrected) latency (paper VIII model).
    pub latency: f32,
    /// Raw analytical latency (what the planner/SLA bound sees).
    pub latency_raw: f32,
    pub throughput: f32,
    pub cost: f32,
    /// Reported objective (uses measured latency).
    pub objective: f32,
    pub violation: Violation,
}

/// Aggregate over a whole run — one Table I row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub steps: usize,
    pub avg_latency: f64,
    pub max_latency: f64,
    pub avg_throughput: f64,
    pub avg_required: f64,
    pub avg_cost: f64,
    pub total_cost: f64,
    pub avg_objective: f64,
    pub violations: usize,
    pub latency_violations: usize,
    pub throughput_violations: usize,
}

/// Accumulates [`StepRecord`]s and produces a [`Summary`].
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    records: Vec<StepRecord>,
    counter: ViolationCounter,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { records: Vec::with_capacity(n), counter: ViolationCounter::default() }
    }

    pub fn push(&mut self, rec: StepRecord) {
        self.counter.record(rec.violation);
        self.records.push(rec);
    }

    pub fn records(&self) -> &[StepRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn summary(&self) -> Summary {
        let n = self.records.len();
        let nf = n.max(1) as f64;
        let sum = |f: fn(&StepRecord) -> f64| -> f64 {
            self.records.iter().map(f).sum::<f64>()
        };
        Summary {
            steps: n,
            avg_latency: sum(|r| r.latency as f64) / nf,
            max_latency: self
                .records
                .iter()
                .map(|r| r.latency as f64)
                .fold(0.0, f64::max),
            avg_throughput: sum(|r| r.throughput as f64) / nf,
            avg_required: sum(|r| r.lambda_req as f64) / nf,
            avg_cost: sum(|r| r.cost as f64) / nf,
            total_cost: sum(|r| r.cost as f64),
            avg_objective: sum(|r| r.objective as f64) / nf,
            violations: self.counter.violated_steps,
            latency_violations: self.counter.latency_violations,
            throughput_violations: self.counter.throughput_violations,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, lat: f32, cost: f32, viol: bool) -> StepRecord {
        StepRecord {
            step,
            config: Configuration::new(1, 1),
            lambda_req: 1000.0,
            latency: lat,
            latency_raw: lat,
            throughput: 2000.0,
            cost,
            objective: 10.0 * lat,
            violation: Violation { latency: false, throughput: viol },
        }
    }

    #[test]
    fn empty_summary_is_zero() {
        let s = Recorder::new().summary();
        assert_eq!(s.steps, 0);
        assert_eq!(s.avg_latency, 0.0);
        assert_eq!(s.violations, 0);
    }

    #[test]
    fn averages_and_totals() {
        let mut r = Recorder::new();
        r.push(rec(0, 2.0, 1.0, false));
        r.push(rec(1, 4.0, 3.0, true));
        let s = r.summary();
        assert_eq!(s.steps, 2);
        assert!((s.avg_latency - 3.0).abs() < 1e-9);
        assert!((s.max_latency - 4.0).abs() < 1e-9);
        assert!((s.avg_cost - 2.0).abs() < 1e-9);
        assert!((s.total_cost - 4.0).abs() < 1e-9);
        assert_eq!(s.violations, 1);
        assert_eq!(s.throughput_violations, 1);
        assert_eq!(s.latency_violations, 0);
    }

    #[test]
    fn total_cost_is_avg_times_steps() {
        let mut r = Recorder::new();
        for i in 0..50 {
            r.push(rec(i, 1.0, 1.6, false));
        }
        let s = r.summary();
        assert!((s.total_cost - s.avg_cost * 50.0).abs() < 1e-6);
    }
}
