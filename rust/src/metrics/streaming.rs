//! Bounded-memory recording: the [`StreamingRecorder`] replaces the
//! exact [`Recorder`](super::Recorder)'s unbounded `Vec<StepRecord>`
//! with O(1)-per-tenant state — the [`Summary`](super::Summary)
//! accumulators folded per push (bit-identical to the exact recorder's
//! per-field folds, which also run in push order), two mergeable
//! [`LatencyHistogram`]s for measured and raw latency quantiles, and a
//! seeded Algorithm-R reservoir of exemplar [`StepRecord`]s (the same
//! treatment PR 7 gave the explain log with `--explain-sample`).
//!
//! The exact [`Recorder`](super::Recorder) stays as the oracle:
//! `rust/tests/metrics_stream.rs` property-pins streaming `Summary`,
//! p95, and p99 against it on random fleets, and pins retained record
//! count constant in tick count.

use super::{LatencyHistogram, StepRecord, Summary, LATENCY_FLOOR};
use crate::sla::ViolationCounter;
use crate::workload::XorShift64;

/// O(1)-memory per-tenant recorder: summary accumulators + latency
/// sketches + an Algorithm-R exemplar reservoir.
#[derive(Debug, Clone)]
pub struct StreamingRecorder {
    steps: usize,
    sum_latency: f64,
    max_latency: f64,
    sum_throughput: f64,
    sum_required: f64,
    sum_cost: f64,
    sum_objective: f64,
    counter: ViolationCounter,
    hist: LatencyHistogram,
    hist_raw: LatencyHistogram,
    reservoir: Vec<StepRecord>,
    cap: usize,
    seen: u64,
    rng: XorShift64,
}

impl StreamingRecorder {
    /// `cap` exemplar records are retained (0 keeps none); `seed`
    /// drives the reservoir replacement draws, so runs replay exactly.
    pub fn new(cap: usize, seed: u64) -> Self {
        Self {
            steps: 0,
            sum_latency: 0.0,
            max_latency: 0.0,
            sum_throughput: 0.0,
            sum_required: 0.0,
            sum_cost: 0.0,
            sum_objective: 0.0,
            counter: ViolationCounter::default(),
            hist: LatencyHistogram::new(LATENCY_FLOOR),
            hist_raw: LatencyHistogram::new(LATENCY_FLOOR),
            reservoir: Vec::with_capacity(cap),
            cap,
            seen: 0,
            rng: XorShift64::new(seed),
        }
    }

    pub fn push(&mut self, rec: StepRecord) {
        self.counter.record(rec.violation);
        self.steps += 1;
        self.sum_latency += rec.latency as f64;
        self.max_latency = self.max_latency.max(rec.latency as f64);
        self.sum_throughput += rec.throughput as f64;
        self.sum_required += rec.lambda_req as f64;
        self.sum_cost += rec.cost as f64;
        self.sum_objective += rec.objective as f64;
        self.hist.record(rec.latency as f64);
        self.hist_raw.record(rec.latency_raw as f64);

        // Algorithm R (Vitter): every record survives with probability
        // cap/seen, independent of stream length.
        self.seen += 1;
        if self.cap == 0 {
            return;
        }
        if self.reservoir.len() < self.cap {
            self.reservoir.push(rec);
        } else {
            let j = self.rng.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.reservoir[j as usize] = rec;
            }
        }
    }

    /// Records pushed so far (the stream length, not the sample size).
    pub fn len(&self) -> usize {
        self.steps
    }

    pub fn is_empty(&self) -> bool {
        self.steps == 0
    }

    /// Records currently retained — bounded by `cap` regardless of
    /// stream length (the memory pin in `rust/tests/metrics_stream.rs`).
    pub fn retained(&self) -> usize {
        self.reservoir.len()
    }

    /// The exemplar reservoir: a uniform sample of the stream, in
    /// arrival-replacement order.
    pub fn sample(&self) -> &[StepRecord] {
        &self.reservoir
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Measured-latency sketch (all pushed records, zeros in the
    /// underflow bucket).
    pub fn latency_histogram(&self) -> &LatencyHistogram {
        &self.hist
    }

    /// Raw (planner-visible) latency sketch.
    pub fn raw_latency_histogram(&self) -> &LatencyHistogram {
        &self.hist_raw
    }

    pub fn p95(&self) -> f64 {
        self.hist.quantile(0.95)
    }

    pub fn p95_raw(&self) -> f64 {
        self.hist_raw.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.hist.quantile(0.99)
    }

    /// Same field-by-field arithmetic as the exact recorder's
    /// `summary()` (sequential f64 folds in push order), so the two
    /// agree bitwise on identical streams.
    pub fn summary(&self) -> Summary {
        let nf = self.steps.max(1) as f64;
        Summary {
            steps: self.steps,
            avg_latency: self.sum_latency / nf,
            max_latency: self.max_latency,
            avg_throughput: self.sum_throughput / nf,
            avg_required: self.sum_required / nf,
            avg_cost: self.sum_cost / nf,
            total_cost: self.sum_cost,
            avg_objective: self.sum_objective / nf,
            violations: self.counter.violated_steps,
            latency_violations: self.counter.latency_violations,
            throughput_violations: self.counter.throughput_violations,
        }
    }
}

/// One-shot Algorithm-R reservoir over a finished slice: returns up to
/// `cap` items, in original order. Shared by `fleet --ticks-sample`
/// (bounding per-tick report rows) and tests.
pub fn reservoir_sample<T: Clone>(items: &[T], cap: usize, seed: u64) -> Vec<T> {
    if cap == 0 || items.len() <= cap {
        return items.to_vec();
    }
    let mut rng = XorShift64::new(seed);
    let mut idx: Vec<usize> = (0..cap).collect();
    for i in cap..items.len() {
        let j = (rng.next_u64() % (i as u64 + 1)) as usize;
        if j < cap {
            idx[j] = i;
        }
    }
    idx.sort_unstable();
    idx.into_iter().map(|i| items[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::super::Recorder;
    use super::*;
    use crate::plane::Configuration;
    use crate::sla::Violation;

    fn rec(step: usize, lat: f32, cost: f32, viol: bool) -> StepRecord {
        StepRecord {
            step,
            config: Configuration::new(1, 1),
            lambda_req: 1000.0,
            latency: lat,
            latency_raw: lat * 0.9,
            throughput: 2000.0,
            cost,
            objective: 10.0 * lat,
            violation: Violation { latency: viol, throughput: false },
        }
    }

    #[test]
    fn summary_matches_exact_recorder_bitwise() {
        let mut exact = Recorder::new();
        let mut stream = StreamingRecorder::new(8, 7);
        let mut rng = XorShift64::new(99);
        for i in 0..500 {
            let r = rec(i, rng.next_f64() as f32 * 0.02, 1.0 + (i % 7) as f32, i % 11 == 0);
            exact.push(r);
            stream.push(r);
        }
        let (a, b) = (exact.summary(), stream.summary());
        assert_eq!(a, b, "streaming summary must equal the exact oracle");
    }

    #[test]
    fn reservoir_is_bounded_and_full_below_cap() {
        let mut s = StreamingRecorder::new(16, 1);
        for i in 0..10 {
            s.push(rec(i, 0.01, 1.0, false));
        }
        assert_eq!(s.retained(), 10);
        for i in 10..5000 {
            s.push(rec(i, 0.01, 1.0, false));
        }
        assert_eq!(s.retained(), 16);
        assert_eq!(s.len(), 5000);
        assert_eq!(s.seen(), 5000);
    }

    #[test]
    fn zero_cap_keeps_summary_but_no_exemplars() {
        let mut s = StreamingRecorder::new(0, 1);
        for i in 0..100 {
            s.push(rec(i, 0.01, 1.0, false));
        }
        assert_eq!(s.retained(), 0);
        assert_eq!(s.summary().steps, 100);
    }

    #[test]
    fn one_shot_reservoir_preserves_order_and_bound() {
        let items: Vec<usize> = (0..1000).collect();
        let sample = reservoir_sample(&items, 50, 0xABCD);
        assert_eq!(sample.len(), 50);
        assert!(sample.windows(2).all(|w| w[0] < w[1]), "must stay in stream order");
        let identity = reservoir_sample(&items, 0, 1);
        assert_eq!(identity, items, "cap 0 means no sampling");
        let small = reservoir_sample(&items[..10], 50, 1);
        assert_eq!(small.len(), 10);
    }

    #[test]
    fn quantiles_track_the_stream() {
        let mut s = StreamingRecorder::new(4, 3);
        for i in 0..1000 {
            s.push(rec(i, 0.001 + (i as f32) * 1e-5, 1.0, false));
        }
        let p95 = s.p95();
        // exact nearest-rank p95 of the ramp is ~0.001 + 950e-5 ≈ 0.0105
        assert!((p95 - 0.0105).abs() / 0.0105 < 0.08, "p95: {p95}");
    }
}
