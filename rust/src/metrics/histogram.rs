//! Log-bucketed latency histogram (HdrHistogram-style, fixed memory):
//! the cluster substrate records every sampled op's latency here, so
//! percentile queries (p50/p99/p999) are O(buckets) with bounded
//! relative error instead of requiring a sort of all samples.

/// Buckets spaced at `2^(k/SUBDIV)` between `min_value` and
/// `min_value * 2^(BUCKETS/SUBDIV)` — ≈ 9% relative resolution.
const SUBDIV: usize = 8;
const BUCKETS: usize = 256;

/// Fixed-size log histogram over positive values.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    min_value: f64,
    counts: [u64; BUCKETS],
    underflow: u64,
    overflow: u64,
    total: u64,
    sum: f64,
    max: f64,
}

impl LatencyHistogram {
    /// `min_value` is the resolution floor (values below land in the
    /// underflow bucket and report as `min_value`).
    pub fn new(min_value: f64) -> Self {
        assert!(min_value > 0.0);
        Self {
            min_value,
            counts: [0; BUCKETS],
            underflow: 0,
            overflow: 0,
            total: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    fn bucket_of(&self, v: f64) -> Option<usize> {
        if v < self.min_value {
            return None;
        }
        let k = ((v / self.min_value).log2() * SUBDIV as f64).floor();
        if k < 0.0 {
            None
        } else {
            Some(k as usize)
        }
    }

    /// Lower edge of bucket `k`.
    fn bucket_value(&self, k: usize) -> f64 {
        self.min_value * 2f64.powf(k as f64 / SUBDIV as f64)
    }

    /// Geometric midpoint of bucket `k` — the unbiased representative
    /// of a log-spaced bucket. Reporting the lower edge instead would
    /// bias every quantile systematically low by up to one bucket
    /// width (~9%); the midpoint halves the worst case to ~±4.4%.
    fn bucket_midpoint(&self, k: usize) -> f64 {
        self.bucket_value(k) * 2f64.powf(0.5 / SUBDIV as f64)
    }

    pub fn record(&mut self, v: f64) {
        self.total += 1;
        self.sum += v;
        self.max = self.max.max(v);
        match self.bucket_of(v) {
            None => self.underflow += 1,
            Some(k) if k < BUCKETS => self.counts[k] += 1,
            Some(_) => self.overflow += 1,
        }
    }

    pub fn len(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Value at quantile `q` in [0, 1] (geometric bucket midpoint —
    /// within half a bucket width, ≈ ±4.4%, of the true value; clamped
    /// to the observed maximum so quantiles never exceed `max()`).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = self.underflow;
        if seen >= target {
            return self.min_value;
        }
        for (k, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.bucket_midpoint(k).min(self.max);
            }
        }
        self.max
    }

    /// Sum of all recorded values (exact, not bucketed).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// The resolution floor this histogram was built with.
    pub fn floor(&self) -> f64 {
        self.min_value
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }

    /// Merge another histogram (same `min_value`) into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        assert_eq!(self.min_value, other.min_value, "incompatible histograms");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    pub fn reset(&mut self) {
        *self = Self::new(self.min_value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::XorShift64;

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new(1e-4);
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn single_value_quantiles() {
        let mut h = LatencyHistogram::new(1e-4);
        h.record(0.01);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!((v - 0.01).abs() / 0.01 < 0.1, "q={q} v={v}");
        }
    }

    #[test]
    fn quantiles_within_bucket_resolution() {
        let mut h = LatencyHistogram::new(1e-5);
        let mut rng = XorShift64::new(7);
        let mut values: Vec<f64> = (0..20_000).map(|_| rng.exp(0.002)).collect();
        for &v in &values {
            h.record(v);
        }
        values.sort_by(f64::total_cmp);
        // midpoint reporting: within half a bucket width (~4.4%) of the
        // exact sample quantile, plus nearest-rank slack — 8% is tight
        // against the former lower-edge bias of up to ~9%
        for q in [0.5, 0.9, 0.99] {
            let exact = values[((q * values.len() as f64) as usize).min(values.len() - 1)];
            let approx = h.quantile(q);
            assert!(
                (approx - exact).abs() / exact < 0.08,
                "q={q}: approx {approx} vs exact {exact}"
            );
        }
        assert!((h.mean() - 0.002).abs() < 0.0002);
    }

    #[test]
    fn monotone_quantiles() {
        let mut h = LatencyHistogram::new(1e-4);
        let mut rng = XorShift64::new(3);
        for _ in 0..5000 {
            h.record(rng.exp(0.01));
        }
        assert!(h.p50() <= h.p99());
        assert!(h.p99() <= h.p999());
        assert!(h.p999() <= h.max());
    }

    #[test]
    fn underflow_and_overflow_counted() {
        let mut h = LatencyHistogram::new(1.0);
        h.record(1e-9); // underflow
        h.record(1e12); // overflow
        assert_eq!(h.len(), 2);
        assert_eq!(h.quantile(0.25), 1.0); // underflow reports the floor
        assert!(h.quantile(1.0) >= 1e12 * 0.9);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = LatencyHistogram::new(1e-4);
        let mut b = LatencyHistogram::new(1e-4);
        let mut both = LatencyHistogram::new(1e-4);
        let mut rng = XorShift64::new(5);
        for i in 0..2000 {
            let v = rng.exp(0.005);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.len(), both.len());
        assert_eq!(a.p99(), both.p99());
        assert!((a.mean() - both.mean()).abs() < 1e-12);
    }

    #[test]
    fn prop_merge_then_quantile_equals_record_all() {
        // property: for any value stream and any random k-way split into
        // shard histograms, merging the shards is indistinguishable from
        // recording everything into one histogram. Counts are integers
        // and max is an exact max-of-maxes, so every quantile must match
        // EXACTLY — not approximately. This is what lets the fleet fold
        // suspended tenants' archived segments into live p99s.
        for seed in [1u64, 42, 1234, 98765] {
            let mut rng = XorShift64::new(seed);
            let k = 2 + (rng.next_u64() % 7) as usize; // 2..=8 shards
            let n = 500 + (rng.next_u64() % 4000) as usize;
            let mut shards: Vec<LatencyHistogram> =
                (0..k).map(|_| LatencyHistogram::new(1e-4)).collect();
            let mut all = LatencyHistogram::new(1e-4);
            for _ in 0..n {
                // heavy-tailed mix so underflow/overflow paths get hit
                let v = match rng.next_u64() % 10 {
                    0 => 1e-6,          // underflow
                    1 => 1e9,           // overflow
                    _ => rng.exp(0.004) // body
                };
                shards[(rng.next_u64() % k as u64) as usize].record(v);
                all.record(v);
            }
            let mut merged = shards.remove(0);
            for s in &shards {
                merged.merge(s);
            }
            assert_eq!(merged.len(), all.len(), "seed={seed}");
            assert_eq!(merged.max(), all.max(), "seed={seed}");
            for q in [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0] {
                assert_eq!(merged.quantile(q), all.quantile(q), "seed={seed} q={q}");
            }
            // sums accumulate in a different order: bit-exactness is not
            // guaranteed, only tight relative agreement
            assert!((merged.mean() - all.mean()).abs() / all.mean() < 1e-12, "seed={seed}");
        }
    }

    #[test]
    #[should_panic]
    fn merge_rejects_incompatible() {
        let mut a = LatencyHistogram::new(1e-4);
        let b = LatencyHistogram::new(1e-3);
        a.merge(&b);
    }
}
