//! The `diagonal-scale/metrics-v1` name table.
//!
//! Every metric the registry exposes is declared here as a `&str`
//! const and listed in [`ALL`] with its kind (and, for histograms, its
//! bucket floor). The name set is **additive-only** and snapshot-pinned
//! in `config/metrics_v1.names`, exactly like the explain-v1 keys:
//! simlint's `s2-metrics-additivity` rule diffs the consts in this file
//! against the snapshot on every push, and
//! `rust/tests/metrics_export.rs` round-trips the rendered exposition
//! against both. Add a metric → add the const, the [`ALL`] entry, and
//! the snapshot line, in one commit.

use super::LATENCY_FLOOR;

/// How a metric accumulates, and therefore how it renders.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotonically increasing u64.
    Counter,
    /// Last-write-wins f64.
    Gauge,
    /// [`LatencyHistogram`](super::LatencyHistogram) sketch, rendered
    /// as a Prometheus summary (quantile series + `_count`/`_sum`).
    Histogram,
}

/// One pinned metric: name, kind, histogram floor (ignored unless
/// [`MetricKind::Histogram`]), and help text for the exposition.
#[derive(Debug, Clone, Copy)]
pub struct MetricDef {
    pub name: &'static str,
    pub kind: MetricKind,
    pub floor: f64,
    pub help: &'static str,
}

// Fleet control plane (registered every tick by `FleetSimulator::tick`).
pub const FLEET_TICKS_TOTAL: &str = "fleet_ticks_total";
pub const FLEET_TENANTS: &str = "fleet_tenants";
pub const FLEET_SPEND_HOURLY: &str = "fleet_spend_hourly";
pub const FLEET_PROJECTED_SPEND_HOURLY: &str = "fleet_projected_spend_hourly";
pub const FLEET_MOVES_ADMITTED_TOTAL: &str = "fleet_moves_admitted_total";
pub const FLEET_MOVES_DENIED_TOTAL: &str = "fleet_moves_denied_total";
pub const FLEET_RESCUES_TOTAL: &str = "fleet_rescues_total";
pub const FLEET_RESCUE_DENIALS_TOTAL: &str = "fleet_rescue_denials_total";
pub const FLEET_MOVES_DEGRADED_TOTAL: &str = "fleet_moves_degraded_total";
pub const FLEET_SHEDS_TOTAL: &str = "fleet_sheds_total";
pub const FLEET_FRESH_PROPOSALS_TOTAL: &str = "fleet_fresh_proposals_total";
pub const FLEET_VIOLATION_TICKS_TOTAL: &str = "fleet_violation_ticks_total";
pub const FLEET_SUSPENDED_TENANTS: &str = "fleet_suspended_tenants";
pub const FLEET_RESUMING_TENANTS: &str = "fleet_resuming_tenants";
pub const FLEET_RESUME_ENDS_TOTAL: &str = "fleet_resume_ends_total";
pub const FLEET_PLANNING_SECONDS: &str = "fleet_planning_seconds";

// Fleet cardinality sketches (`metrics::hll`).
pub const FLEET_ACTIVE_TENANTS_WINDOW: &str = "fleet_active_tenants_window";
pub const FLEET_ACTIVE_TENANTS_RING: &str = "fleet_active_tenants_ring";
pub const FLEET_ACTIVE_TENANTS_ESTIMATE: &str = "fleet_active_tenants_estimate";
pub const FLEET_CONFIGS_VISITED_ESTIMATE: &str = "fleet_configs_visited_estimate";

// Scenario subsystem (stamped when a named preset drives the run).
pub const SCENARIO_ACTIVE: &str = "scenario_active";
pub const SCENARIO_FAULTS_TOTAL: &str = "scenario_faults_total";

// Fleet observation cost + latency rollup (set by `export_metrics`).
pub const FLEET_RETAINED_RECORDS: &str = "fleet_retained_records";
pub const FLEET_LATENCY_SECONDS: &str = "fleet_latency_seconds";

// Budget arbiter.
pub const ARBITER_BUDGET_HOURLY: &str = "arbiter_budget_hourly";
pub const ARBITER_FAIRNESS_K: &str = "arbiter_fairness_k";
pub const ARBITER_PLANNING: &str = "arbiter_planning";
pub const ARBITER_ENVELOPE_SHARE: &str = "arbiter_envelope_share";

// Serverless tier (storage service + tenant lifecycle counters).
pub const SERVERLESS_STORAGE_GB: &str = "serverless_storage_gb";
pub const SERVERLESS_STORAGE_COST_HOURLY: &str = "serverless_storage_cost_hourly";
pub const SERVERLESS_REGISTERED_TENANTS: &str = "serverless_registered_tenants";
pub const SERVERLESS_COLD_START_TICKS: &str = "serverless_cold_start_ticks";
pub const SERVERLESS_RESUMES: &str = "serverless_resumes";
pub const SERVERLESS_SUSPENDS: &str = "serverless_suspends";

// Placement (shared-host bin-packing).
pub const PLACEMENT_HOSTS: &str = "placement_hosts";
pub const PLACEMENT_HOSTS_TOUCHED_ESTIMATE: &str = "placement_hosts_touched_estimate";
pub const PLACEMENT_SPEND_HOURLY: &str = "placement_spend_hourly";
pub const PLACEMENT_MOVED_GB: &str = "placement_moved_gb";

// Single-cluster coordinator loop.
pub const COORDINATOR_STEPS: &str = "coordinator_steps";
pub const COORDINATOR_VIOLATIONS: &str = "coordinator_violations";
pub const COORDINATOR_RECONFIGURATIONS: &str = "coordinator_reconfigurations";
pub const COORDINATOR_MOVED_SHARDS: &str = "coordinator_moved_shards";
pub const COORDINATOR_P99_SECONDS: &str = "coordinator_p99_seconds";

/// Floor for the planning-latency sketch: 1 µs, in seconds.
pub const PLANNING_FLOOR: f64 = 1e-6;

const fn counter(name: &'static str, help: &'static str) -> MetricDef {
    MetricDef { name, kind: MetricKind::Counter, floor: 0.0, help }
}

const fn gauge(name: &'static str, help: &'static str) -> MetricDef {
    MetricDef { name, kind: MetricKind::Gauge, floor: 0.0, help }
}

const fn histogram(name: &'static str, floor: f64, help: &'static str) -> MetricDef {
    MetricDef { name, kind: MetricKind::Histogram, floor, help }
}

/// Every pinned metric, in exposition order. `MetricsRegistry::
/// declare_all` pre-registers each one so the exposition always
/// carries the full pinned name set, even for subsystems that are off
/// in a given run.
pub const ALL: &[MetricDef] = &[
    counter(FLEET_TICKS_TOTAL, "fleet ticks simulated"),
    gauge(FLEET_TENANTS, "tenant databases under fleet control"),
    gauge(FLEET_SPEND_HOURLY, "hourly fleet spend after the last tick"),
    gauge(FLEET_PROJECTED_SPEND_HOURLY, "hourly spend if every admitted move actuates"),
    counter(FLEET_MOVES_ADMITTED_TOTAL, "scaling moves admitted by the arbiter"),
    counter(FLEET_MOVES_DENIED_TOTAL, "scaling moves denied outright"),
    counter(FLEET_RESCUES_TOTAL, "SLA-repair moves funded by sheds"),
    counter(FLEET_RESCUE_DENIALS_TOTAL, "SLA-repair moves the budget could not fund"),
    counter(FLEET_MOVES_DEGRADED_TOTAL, "moves degraded to a cheaper ranked alternative"),
    counter(FLEET_SHEDS_TOTAL, "volunteered sheds actuated"),
    counter(FLEET_FRESH_PROPOSALS_TOTAL, "proposals recomputed (dirty-queue misses)"),
    counter(FLEET_VIOLATION_TICKS_TOTAL, "tenant-ticks served in SLA violation"),
    gauge(FLEET_SUSPENDED_TENANTS, "tenants parked at scale-to-zero"),
    gauge(FLEET_RESUMING_TENANTS, "tenants inside a cold-start window"),
    counter(FLEET_RESUME_ENDS_TOTAL, "cold-start windows completed"),
    histogram(FLEET_PLANNING_SECONDS, PLANNING_FLOOR, "per-tick planning wall time"),
    gauge(FLEET_ACTIVE_TENANTS_WINDOW, "HLL distinct active tenants, last closed window"),
    gauge(FLEET_ACTIVE_TENANTS_RING, "HLL distinct active tenants over the retained window ring"),
    gauge(FLEET_ACTIVE_TENANTS_ESTIMATE, "HLL distinct tenants active at least once"),
    gauge(SCENARIO_ACTIVE, "1 when a named scenario preset drives the run"),
    gauge(SCENARIO_FAULTS_TOTAL, "fault events the scenario scheduled onto DES calendars"),
    gauge(FLEET_CONFIGS_VISITED_ESTIMATE, "HLL distinct (tenant, config) pairs served"),
    gauge(FLEET_RETAINED_RECORDS, "step records held in memory across all tenants"),
    histogram(FLEET_LATENCY_SECONDS, LATENCY_FLOOR, "measured per-step latency, merged across tenants"),
    gauge(ARBITER_BUDGET_HOURLY, "hourly budget the arbiter admits against"),
    gauge(ARBITER_FAIRNESS_K, "starvation-guard threshold"),
    gauge(ARBITER_PLANNING, "1 when degradation/shed planning is on"),
    gauge(ARBITER_ENVELOPE_SHARE, "per-class discretionary spend share"),
    gauge(SERVERLESS_STORAGE_GB, "tenant pages parked in shared storage"),
    gauge(SERVERLESS_STORAGE_COST_HOURLY, "hourly bill for parked storage"),
    gauge(SERVERLESS_REGISTERED_TENANTS, "tenants registered with the storage service"),
    gauge(SERVERLESS_COLD_START_TICKS, "ticks spent inside cold-start windows"),
    gauge(SERVERLESS_RESUMES, "suspend->active wakes completed"),
    gauge(SERVERLESS_SUSPENDS, "active->suspended parks completed"),
    gauge(PLACEMENT_HOSTS, "shared hosts currently live"),
    gauge(PLACEMENT_HOSTS_TOUCHED_ESTIMATE, "HLL distinct hosts touched by placement actions"),
    gauge(PLACEMENT_SPEND_HOURLY, "hourly cost of the packed host set"),
    gauge(PLACEMENT_MOVED_GB, "data shipped by migrations (shard-priced when a model is set)"),
    gauge(COORDINATOR_STEPS, "trace steps driven by the coordinator"),
    gauge(COORDINATOR_VIOLATIONS, "coordinator steps in SLA violation"),
    gauge(COORDINATOR_RECONFIGURATIONS, "coordinator reconfigurations applied"),
    gauge(COORDINATOR_MOVED_SHARDS, "shards moved by coordinator rebalances"),
    histogram(COORDINATOR_P99_SECONDS, LATENCY_FLOOR, "per-step p99 latency seen by the coordinator"),
];

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn names_are_unique_and_well_formed() {
        let mut seen = BTreeSet::new();
        for def in ALL {
            assert!(seen.insert(def.name), "duplicate metric name {}", def.name);
            assert!(
                def.name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
                "metric name {} must be snake_case ascii",
                def.name
            );
            if def.kind == MetricKind::Histogram {
                assert!(def.floor > 0.0, "histogram {} needs a positive floor", def.name);
            }
        }
    }

    #[test]
    fn table_matches_the_pinned_snapshot_on_disk() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/config/metrics_v1.names");
        let snapshot = std::fs::read_to_string(path).expect("config/metrics_v1.names");
        let pinned: BTreeSet<&str> = snapshot
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .collect();
        let declared: BTreeSet<&str> = ALL.iter().map(|d| d.name).collect();
        assert_eq!(
            declared, pinned,
            "metrics names and config/metrics_v1.names diverged (additive-only: add to both)"
        );
    }
}
