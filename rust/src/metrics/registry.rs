//! Pull-based metrics export: a [`MetricsRegistry`] of counters,
//! gauges, and [`LatencyHistogram`] sketches keyed by name + sorted
//! label pairs in `BTreeMap`s (simlint d2: deterministic iteration, so
//! the exposition text is byte-stable across processes).
//!
//! Subsystems push into the registry each tick (fleet, arbiter) or at
//! export time (serverless, placement, coordinator); consumers pull a
//! rendered snapshot — Prometheus text exposition via
//! [`render_prometheus`](MetricsRegistry::render_prometheus) (wired to
//! `fleet --metrics-out <path>`) or the versioned
//! `diagonal-scale/metrics-v1` JSON via
//! [`render_json`](MetricsRegistry::render_json). Metric names are
//! pinned in [`names`](super::names) / `config/metrics_v1.names`.

use std::collections::{BTreeMap, BTreeSet};

use super::names::{self, MetricKind};
use super::LatencyHistogram;

/// Version tag for the JSON rendering.
pub const METRICS_SCHEMA: &str = "diagonal-scale/metrics-v1";

/// One time series: metric name plus sorted label pairs.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct SeriesKey {
    name: String,
    labels: Vec<(String, String)>,
}

fn series(name: &str, labels: &[(&str, &str)]) -> SeriesKey {
    let mut labels: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    labels.sort();
    SeriesKey { name: name.to_string(), labels }
}

/// Deterministic pull-based metric store.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<SeriesKey, u64>,
    gauges: BTreeMap<SeriesKey, f64>,
    hists: BTreeMap<SeriesKey, LatencyHistogram>,
    help: BTreeMap<String, &'static str>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-register every pinned metric from [`names::ALL`] with a
    /// zero/empty default series, so the exposition always round-trips
    /// the full `config/metrics_v1.names` set even when a subsystem is
    /// off in this run.
    pub fn declare_all(&mut self) {
        for def in names::ALL {
            self.help.insert(def.name.to_string(), def.help);
            let key = series(def.name, &[]);
            match def.kind {
                MetricKind::Counter => {
                    self.counters.entry(key).or_insert(0);
                }
                MetricKind::Gauge => {
                    self.gauges.entry(key).or_insert(0.0);
                }
                MetricKind::Histogram => {
                    self.hists.entry(key).or_insert_with(|| LatencyHistogram::new(def.floor));
                }
            }
        }
    }

    /// Add `delta` to a counter (created at zero on first touch).
    pub fn inc(&mut self, name: &str, labels: &[(&str, &str)], delta: u64) {
        *self.counters.entry(series(name, labels)).or_insert(0) += delta;
    }

    /// Set a gauge (last write wins).
    pub fn set(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.gauges.insert(series(name, labels), value);
    }

    /// Record one observation into a histogram series, creating it
    /// with `floor` as its bucket floor on first touch.
    pub fn observe(&mut self, name: &str, labels: &[(&str, &str)], floor: f64, value: f64) {
        self.hists
            .entry(series(name, labels))
            .or_insert_with(|| LatencyHistogram::new(floor))
            .record(value);
    }

    /// Merge a pre-built sketch into a histogram series (exact
    /// merge-then-quantile; floors must match).
    pub fn merge_sketch(&mut self, name: &str, labels: &[(&str, &str)], sketch: &LatencyHistogram) {
        match self.hists.entry(series(name, labels)) {
            std::collections::btree_map::Entry::Vacant(e) => {
                e.insert(sketch.clone());
            }
            std::collections::btree_map::Entry::Occupied(mut e) => {
                e.get_mut().merge(sketch);
            }
        }
    }

    pub fn counter_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        self.counters.get(&series(name, labels)).copied()
    }

    pub fn gauge_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.gauges.get(&series(name, labels)).copied()
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<&LatencyHistogram> {
        self.hists.get(&series(name, labels))
    }

    /// Distinct metric names currently registered (label sets ignored).
    pub fn metric_names(&self) -> BTreeSet<String> {
        self.counters
            .keys()
            .chain(self.gauges.keys())
            .chain(self.hists.keys())
            .map(|k| k.name.clone())
            .collect()
    }

    /// Series count across all kinds.
    pub fn len(&self) -> usize {
        self.counters.len() + self.gauges.len() + self.hists.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fold `other` into `self`: counters add, gauges take the other's
    /// value, histograms merge. Lets standalone subsystem registries
    /// (e.g. a coordinator run) combine into one exposition.
    pub fn merge_from(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.hists {
            match self.hists.entry(k.clone()) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(h.clone());
                }
                std::collections::btree_map::Entry::Occupied(mut e) => {
                    e.get_mut().merge(h);
                }
            }
        }
        for (k, v) in &other.help {
            self.help.entry(k.clone()).or_insert(v);
        }
    }

    fn render_series_name(out: &mut String, key: &SeriesKey, extra: Option<(&str, &str)>) {
        out.push_str(&key.name);
        let mut pairs: Vec<(&str, &str)> =
            key.labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
        if let Some(kv) = extra {
            pairs.push(kv);
        }
        if !pairs.is_empty() {
            out.push('{');
            for (i, (k, v)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(k);
                out.push_str("=\"");
                out.push_str(v);
                out.push('"');
            }
            out.push('}');
        }
    }

    fn render_type_header(&self, out: &mut String, name: &str, kind: &str, last: &mut String) {
        if last == name {
            return;
        }
        if let Some(help) = self.help.get(name) {
            out.push_str("# HELP ");
            out.push_str(name);
            out.push(' ');
            out.push_str(help);
            out.push('\n');
        }
        out.push_str("# TYPE ");
        out.push_str(name);
        out.push(' ');
        out.push_str(kind);
        out.push('\n');
        *last = name.to_string();
    }

    /// Prometheus text exposition (format 0.0.4). Histograms render as
    /// summaries: `{quantile="0.5|0.95|0.99"}` plus `_count`/`_sum`.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last = String::new();
        for (key, v) in &self.counters {
            self.render_type_header(&mut out, &key.name, "counter", &mut last);
            Self::render_series_name(&mut out, key, None);
            out.push_str(&format!(" {v}\n"));
        }
        for (key, v) in &self.gauges {
            self.render_type_header(&mut out, &key.name, "gauge", &mut last);
            Self::render_series_name(&mut out, key, None);
            out.push_str(&format!(" {v}\n"));
        }
        for (key, h) in &self.hists {
            self.render_type_header(&mut out, &key.name, "summary", &mut last);
            for (label, q) in [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)] {
                Self::render_series_name(&mut out, key, Some(("quantile", label)));
                out.push_str(&format!(" {}\n", h.quantile(q)));
            }
            let mut counted = key.clone();
            counted.name.push_str("_count");
            Self::render_series_name(&mut out, &counted, None);
            out.push_str(&format!(" {}\n", h.len()));
            let mut summed = key.clone();
            summed.name.push_str("_sum");
            Self::render_series_name(&mut out, &summed, None);
            out.push_str(&format!(" {}\n", h.sum()));
        }
        out
    }

    fn render_labels_json(labels: &[(String, String)]) -> String {
        let mut out = String::from("{");
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":\"{}\"", escape(k), escape(v)));
        }
        out.push('}');
        out
    }

    /// Versioned machine-readable rendering (`diagonal-scale/metrics-v1`).
    pub fn render_json(&self) -> String {
        let mut out = format!("{{\"schema\":\"{METRICS_SCHEMA}\",\"counters\":[");
        for (i, (key, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"labels\":{},\"value\":{v}}}",
                escape(&key.name),
                Self::render_labels_json(&key.labels)
            ));
        }
        out.push_str("],\"gauges\":[");
        for (i, (key, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"labels\":{},\"value\":{}}}",
                escape(&key.name),
                Self::render_labels_json(&key.labels),
                json_f64(*v)
            ));
        }
        out.push_str("],\"histograms\":[");
        for (i, (key, h)) in self.hists.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"labels\":{},\"count\":{},\"sum\":{},\"max\":{},\
                 \"p50\":{},\"p95\":{},\"p99\":{}}}",
                escape(&key.name),
                Self::render_labels_json(&key.labels),
                h.len(),
                json_f64(h.sum()),
                json_f64(h.max()),
                json_f64(h.quantile(0.5)),
                json_f64(h.quantile(0.95)),
                json_f64(h.quantile(0.99))
            ));
        }
        out.push_str("]}");
        out
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON has no NaN/Inf literals; clamp them to null-safe zero.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut reg = MetricsRegistry::new();
        reg.inc("requests_total", &[], 2);
        reg.inc("requests_total", &[], 3);
        reg.set("temperature", &[("zone", "a")], 1.5);
        reg.set("temperature", &[("zone", "a")], 2.5);
        assert_eq!(reg.counter_value("requests_total", &[]), Some(5));
        assert_eq!(reg.gauge_value("temperature", &[("zone", "a")]), Some(2.5));
        assert_eq!(reg.gauge_value("temperature", &[("zone", "b")]), None);
    }

    #[test]
    fn label_order_does_not_matter() {
        let mut reg = MetricsRegistry::new();
        reg.inc("m", &[("a", "1"), ("b", "2")], 1);
        reg.inc("m", &[("b", "2"), ("a", "1")], 1);
        assert_eq!(reg.counter_value("m", &[("a", "1"), ("b", "2")]), Some(2));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn declare_all_round_trips_the_pinned_names() {
        let mut reg = MetricsRegistry::new();
        reg.declare_all();
        let rendered = reg.render_prometheus();
        for def in names::ALL {
            assert!(
                rendered.lines().any(|l| {
                    l.starts_with(def.name)
                        && l[def.name.len()..].starts_with([' ', '{', '_'].as_ref())
                }),
                "declared metric {} missing from exposition",
                def.name
            );
        }
        assert_eq!(reg.metric_names().len(), names::ALL.len());
    }

    #[test]
    fn exposition_is_deterministic_and_typed() {
        let mut reg = MetricsRegistry::new();
        reg.inc("a_total", &[("class", "gold")], 7);
        reg.set("b_now", &[], 0.25);
        reg.observe("c_seconds", &[], 1e-5, 0.01);
        reg.observe("c_seconds", &[], 1e-5, 0.02);
        let text = reg.render_prometheus();
        assert_eq!(text, reg.clone().render_prometheus());
        assert!(text.contains("# TYPE a_total counter"));
        assert!(text.contains("a_total{class=\"gold\"} 7"));
        assert!(text.contains("# TYPE b_now gauge"));
        assert!(text.contains("# TYPE c_seconds summary"));
        assert!(text.contains("c_seconds_count 2"));
    }

    #[test]
    fn json_rendering_carries_the_schema_tag() {
        let mut reg = MetricsRegistry::new();
        reg.inc("a_total", &[], 1);
        reg.observe("lat", &[("class", "gold")], 1e-5, 0.004);
        let json = reg.render_json();
        assert!(json.starts_with("{\"schema\":\"diagonal-scale/metrics-v1\""));
        assert!(json.contains("\"name\":\"a_total\""));
        assert!(json.contains("\"labels\":{\"class\":\"gold\"}"));
        assert!(json.contains("\"count\":1"));
    }

    #[test]
    fn merge_from_adds_counters_and_merges_sketches() {
        let mut a = MetricsRegistry::new();
        let mut b = MetricsRegistry::new();
        a.inc("n_total", &[], 2);
        b.inc("n_total", &[], 3);
        a.observe("lat", &[], 1e-5, 0.01);
        b.observe("lat", &[], 1e-5, 0.03);
        a.merge_from(&b);
        assert_eq!(a.counter_value("n_total", &[]), Some(5));
        assert_eq!(a.histogram("lat", &[]).unwrap().len(), 2);
    }
}
