//! Property-testing and test-support helpers (offline substitute for
//! `proptest`/`tempfile`): seeded random case generation with failing-
//! seed reporting, and a self-cleaning temporary directory.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::workload::XorShift64;

/// Run `cases` randomized property checks. The closure receives a
/// seeded RNG per case; panics are re-raised with the case index and
/// seed so failures reproduce deterministically.
pub fn forall(cases: usize, seed: u64, mut f: impl FnMut(usize, &mut XorShift64)) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = XorShift64::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(case, &mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {case_seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Uniform f32 in [lo, hi).
pub fn uniform(rng: &mut XorShift64, lo: f32, hi: f32) -> f32 {
    rng.range_f64(lo as f64, hi as f64) as f32
}

/// Random element of a slice.
pub fn choice<'a, T>(rng: &mut XorShift64, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len() as u64) as usize]
}

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temporary directory removed on drop (offline `tempfile` stand-in).
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> std::io::Result<Self> {
        let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "diagonal-scale-test-{}-{}",
            std::process::id(),
            n
        ));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(10, 1, |_, _| count += 1);
        assert_eq!(count, 10);
    }

    #[test]
    fn forall_seeds_are_deterministic() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        forall(5, 2, |_, rng| a.push(rng.next_u64()));
        forall(5, 2, |_, rng| b.push(rng.next_u64()));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall(10, 3, |case, _| assert!(case < 5));
    }

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let d = TempDir::new().unwrap();
            p = d.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("x"), "y").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = XorShift64::new(4);
        for _ in 0..100 {
            let x = uniform(&mut rng, 2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
        }
    }
}
