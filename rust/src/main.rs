//! `diagonal-scale` — the leader binary: CLI over the Phase-1 analytical
//! simulator, the Phase-2 cluster coordinator, the surface/heatmap
//! reports, and the PJRT runtime.
//!
//! ```text
//! diagonal-scale simulate [--extra P]...   # Table I over the paper trace
//! diagonal-scale surfaces [--lambda N]     # ASCII heatmaps (figs 1/2/4)
//! diagonal-scale figures [--out DIR]       # all paper figure CSVs
//! diagonal-scale cluster [--policy P] [--substrate S] [--seed N]  # Phase-2 run
//! diagonal-scale trace-hlo [--artifacts DIR]       # Table I via PJRT
//! diagonal-scale daemon [--steps N] [--seed N]     # threaded autoscaler
//! diagonal-scale fleet [--tenants N] [--budget F] [--serverless B]  # fleet
//! diagonal-scale placement [--tenants N] [--mode M]  # shared-cluster packing
//! ```
//!
//! Global flag: `--config <path.toml>` (defaults to the bundled paper
//! config). The CLI is hand-rolled: the offline vendor set has no clap.

use std::sync::mpsc;

use anyhow::{anyhow, bail, Result};

use diagonal_scale::cluster::{ClusterParams, ClusterSim, EventSim, Substrate, SubstrateKind};
use diagonal_scale::config::{ModelConfig, MoveFlags};
use diagonal_scale::coordinator::{self, Backend, Coordinator};
use diagonal_scale::fleet::{self, FleetSimulator, PriorityClass, TenantSpec};
use diagonal_scale::placement::{self, PlacementConfig, PlacementSim};
use diagonal_scale::policy::{DiagonalScale, Lookahead, Oracle, Policy, StaticPolicy, Threshold};
use diagonal_scale::report::{self, Surface};
use diagonal_scale::runtime::{Engine, SurfaceEngine};
use diagonal_scale::scenario;
use diagonal_scale::serverless::{self, ServerlessParams};
use diagonal_scale::simulator::{AnalyticalSubstrate, PolicyKind, Simulator};
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::workload::TraceBuilder;

const USAGE: &str = "\
diagonal-scale — Diagonal Scaling reproduction (paper CS.DC 2025)

USAGE: diagonal-scale [--config <file.toml>] <COMMAND> [flags]

COMMANDS:
  simulate    Phase-1 analytical simulation: Table I over the paper trace
                [--extra <policy>]... add threshold|oracle|lookahead|static
                [--explain <k>] print each step's top-k ranked candidates
                                of the DiagonalScale proposal (0 = off)
                [--explain-out <file.json>] write the explain dump as
                                versioned JSON (diagonal-scale/explain-v1;
                                requires --explain)
  surfaces    ASCII heatmaps of the analytical surfaces (figures 1/2/4)
                [--lambda <f32>] demand level (default 10000)
  figures     Emit Table I + every figure CSV
                [--out <dir>] output directory (default out/)
  cluster     Drive a Phase-2 substrate with the coordinator
                [--policy <p>] diagonal|horizontal|vertical|threshold|
                               oracle|lookahead|static (default diagonal)
                [--substrate <s>] des|sampling|analytical (default des)
                [--seed <u64>] (default 42)
                [--explain <k>] print each tick's top-k ranked candidates
                                (0 = off)
                [--cost-cap <f32>/h] guard: never actuate a config above
                                this hourly cost — the coordinator walks
                                the ranked alternatives instead
                [--calibrate-online <bool>] refit the planning surfaces
                                from observe() snapshots on the decision
                                path (default false)
                [--refit-every <n>] online-calibration refit cadence in
                                ticks (default 10)
  trace-hlo   Run Table I through the AOT-compiled PJRT policy_trace
                [--artifacts <dir>] (default artifacts/)
  daemon      Threaded autoscaler daemon on a synthetic demand feed
                [--steps <n>] (default 100)  [--seed <u64>] (default 42)
  fleet       Multi-tenant fleet under a shared cost budget
                [--tenants <n>] (default 8)
                [--budget <f32>/h] (default 2.2 per tenant)
                [--steps <n>] (default 100)
                [--k <n>] fairness guard K (default 3)
                [--envelopes <g:s:b|default|off>] per-class budget
                                  envelopes with burst credits
                                  (default off)
                [--forecast <holt|seasonal|off>] per-tenant demand
                                  forecasting behind the proposals
                                  (default off)
                [--planning <bool>] candidate-list walks + shed
                                  re-negotiation (default true; false =
                                  the PR-2 flat-denial arbiter)
                [--adaptive-envelopes <bool>] re-derive class shares
                                  each tick from an EWMA of observed
                                  per-class contention (denials +
                                  violation ticks); uses --envelopes as
                                  the base split, or the default split
                                  when unset (default false)
                [--cluster <bool>] back tenants with a physical substrate
                [--substrate <s>] des|sampling|analytical — back tenants
                                  with this engine (implies --cluster
                                  true; default des)
                [--seed <u64>] (default 42, substrate modes only)
                [--scenario <name>] build the fleet from a named
                                  scenario preset (trace specs + fault
                                  schedule): flash-crowd, black-friday,
                                  heavy-tail, zone-outage,
                                  failure-storm, rolling-restart.
                                  Fault presets auto-attach the DES
                                  substrate; the preset also sets the
                                  default --steps
                [--serverless <bool>] scale-to-zero tier: tenants park
                                  their pages on a shared storage
                                  service, suspend when idle, and wake
                                  through priced cold-start windows on
                                  the DES calendar (default false)
                [--idle-fraction <f32>] fraction of tenants that are
                                  mostly idle (default 0.75; requires
                                  --serverless true)
                [--wake-storm <tick>] align every idle tenant's burst
                                  at this tick — a correlated storm
                                  that wakes the whole suspended
                                  cohort at once (requires
                                  --serverless true)
                [--explain <k>] print each moving tenant's top-k ranked
                                  candidates per tick (0 = off); with
                                  --serverless, lines carry the
                                  lifecycle state and the cold-start
                                  window's end tick
                [--explain-out <file.json>] write the fleet explain
                                  dump as versioned JSON
                                  (diagonal-scale/explain-v1 with the
                                  additive lifecycle/resume_end
                                  fields; requires --explain)
                [--explain-sample <n>] cap the explain log at n records
                                  via deterministic reservoir sampling
                                  (0 = unbounded; JSON dumps then carry
                                  the additive sample_cap/seen fields)
                [--dirty-planning <bool>] activity-proportional control
                                  plane: clean tenants replay cached
                                  holds instead of re-proposing
                                  (default true; decisions are
                                  bit-identical either way).
                                  `--no-dirty-planning` is shorthand
                                  for `--dirty-planning false`
                [--refresh-k <n>] mandatory re-propose interval for
                                  cached holds, in ticks (default 256)
                [--stream-metrics <cap>] O(1)-memory observation: each
                                  tenant keeps streaming accumulators,
                                  a latency sketch, and a <cap>-record
                                  exemplar reservoir instead of the
                                  full step log (0 = exact recording,
                                  default)
                [--ticks-sample <k>] reservoir-bound the per-tick
                                  output to k rows (0 = all, default)
                [--rollup <bool>] print the compact class rollup
                                  (streaming-accumulator summaries,
                                  no per-tenant rows) instead of the
                                  full report table (default false)
                [--metrics-out <file>] write the run's metric registry
                                  as Prometheus text exposition
                [--metrics-json <file>] write the same registry as
                                  versioned JSON
                                  (diagonal-scale/metrics-v1)
  placement   Cross-tenant bin-packing onto shared clusters: small
              tenants co-locate behind shared hosts (fair shares +
              contention knee), the packer replans on a cadence, and
              migrations are priced as DES-calendar windows
                [--tenants <n>] (default 12)
                [--steps <n>] (default 100)
                [--budget <f32>/h] (default 1e9: uncapped)
                [--k <n>] fairness guard K (default 3)
                [--scale <f32>] demand scale vs the paper trace
                                  (default 0.1: small tenants)
                [--replan <n>] packer cadence in ticks (default 4)
                [--mode <m>] packed|dedicated|both (default both:
                                  A/B the packer against
                                  one-cluster-per-tenant)
                [--scenario <name>] build tenants from a scenario
                                  preset (heavy-tail pairs Pareto
                                  sizes with a shard-affinity map;
                                  any preset name is accepted)
                [--partition-aware <bool>] price migrations from the
                                  shard-affinity map's actually-moved
                                  GB instead of the flat per-tenant
                                  GB baseline (default false)
";

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got `{}`", argv[i]))?;
            let v = argv
                .get(i + 1)
                .ok_or_else(|| anyhow!("flag --{k} needs a value"))?;
            flags.push((k.to_string(), v.clone()));
            i += 2;
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("invalid value for --{key}: `{v}`")),
        }
    }
}

fn policy_kind(name: &str) -> Result<PolicyKind> {
    Ok(match name {
        "diagonal" => PolicyKind::Diagonal,
        "horizontal" => PolicyKind::HorizontalOnly,
        "vertical" => PolicyKind::VerticalOnly,
        "threshold" => PolicyKind::Threshold,
        "oracle" => PolicyKind::Oracle,
        "lookahead" => PolicyKind::Lookahead(3),
        "static" => PolicyKind::Static,
        other => bail!("unknown policy `{other}`"),
    })
}

fn policy_send(name: &str) -> Result<Box<dyn Policy + Send>> {
    Ok(match name {
        "diagonal" => Box::new(DiagonalScale::diagonal()),
        "horizontal" => Box::new(DiagonalScale::horizontal_only()),
        "vertical" => Box::new(DiagonalScale::vertical_only()),
        "threshold" => Box::new(Threshold::default()),
        "oracle" => Box::new(Oracle),
        "lookahead" => Box::new(Lookahead::new(MoveFlags::DIAGONAL, 3)),
        "static" => Box::new(StaticPolicy),
        other => bail!("unknown policy `{other}`"),
    })
}

fn substrate_kind(name: &str) -> Result<SubstrateKind> {
    SubstrateKind::parse(name)
        .ok_or_else(|| anyhow!("unknown substrate `{name}` (expected des|sampling|analytical)"))
}

/// One line per ranked candidate: `(h,v) score cost gain [infeasible]`.
fn candidate_line(cands: &[diagonal_scale::policy::Candidate]) -> String {
    cands
        .iter()
        .map(|c| {
            format!(
                "({},{}) s={:.6} c={:.2} g={:.2}{}",
                c.to.h_idx,
                c.to.v_idx,
                c.score,
                c.cost_to,
                c.gain,
                if c.feasible() { "" } else { " INFEASIBLE" }
            )
        })
        .collect::<Vec<_>>()
        .join("  |  ")
}

/// Coordinator knobs shared by every `cluster` substrate choice.
struct ClusterOpts {
    explain: usize,
    cost_cap: Option<f32>,
    calibrate: bool,
    refit_every: usize,
}

/// Run the coordinator over the paper trace on any substrate engine.
fn run_cluster<S: Substrate>(
    cfg: &ModelConfig,
    substrate: S,
    policy: Box<dyn Policy + Send>,
    label: &str,
    opts: &ClusterOpts,
) -> Result<()> {
    let mut coord = Coordinator::new(cfg, substrate, Backend::Native(policy));
    coord.set_explain(opts.explain);
    if let Some(cap) = opts.cost_cap {
        coord.set_guard(Some(Box::new(coordinator::CostCapGuard { cap })));
    }
    if opts.calibrate {
        coord.enable_online_calibration(cfg, opts.refit_every);
    }
    let trace = TraceBuilder::paper(cfg);
    let reports = coord.run_trace(&trace)?;
    if opts.explain > 0 {
        for r in &reports {
            println!(
                "tick {:>3}  demand {:>8.0}  -> ({},{}) rank {}  |  {}",
                r.step,
                r.demand,
                r.next_config.h_idx,
                r.next_config.v_idx,
                match r.chosen_rank {
                    Some(k) => k.to_string(),
                    None => "held".to_string(),
                },
                candidate_line(&r.explain),
            );
        }
    }
    let s = coordinator::summarize(&reports);
    println!(
        "cluster run [{label}]: steps={} violations={} avg_lat={:.4} p99={:.4} completed={:.1}% moved_shards={} reconfigs={}",
        s.steps,
        s.violations,
        s.avg_latency,
        s.avg_p99,
        100.0 * s.completed_ratio,
        s.total_moved_shards,
        s.reconfigurations
    );
    if opts.calibrate {
        let k = coord.planning_constants().kappa;
        println!(
            "online calibration: {} refits  kappa {:.1} (prior {:.1})",
            coord.refits(),
            k,
            cfg.surfaces.kappa
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();

    // global --config may appear before the subcommand
    let mut config_path: Option<String> = None;
    if argv.first().map(String::as_str) == Some("--config") {
        if argv.len() < 2 {
            bail!("--config needs a value");
        }
        config_path = Some(argv[1].clone());
        argv.drain(..2);
    }
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    // the one bare (valueless) flag: rewrite it into the `--key value`
    // shape the tiny parser expects
    let argv: Vec<String> = argv
        .iter()
        .flat_map(|a| {
            if a == "--no-dirty-planning" {
                vec!["--dirty-planning".to_string(), "false".to_string()]
            } else {
                vec![a.clone()]
            }
        })
        .collect();
    let args = Args::parse(&argv[1..])?;
    if let Some(c) = args.get("config") {
        config_path = Some(c.to_string());
    }
    let cfg = match &config_path {
        Some(p) => ModelConfig::from_path(p)?,
        None => ModelConfig::default_paper(),
    };

    match cmd.as_str() {
        "simulate" => {
            let sim = Simulator::new(&cfg);
            let trace = TraceBuilder::paper(&cfg);
            let mut runs = sim.run_paper_set(&trace);
            for extra in args.get_all("extra") {
                runs.push(sim.run(policy_kind(extra)?, &trace));
            }
            let rows: Vec<_> = runs.iter().map(|r| (r.policy.clone(), r.summary)).collect();
            println!("{}", report::table1(&rows));
            let explain: usize = args.parse_num("explain", 0)?;
            if explain > 0 {
                let (run, steps) = sim.run_explained(PolicyKind::Diagonal, &trace, explain);
                for s in &steps {
                    println!(
                        "step {:>3}  demand {:>8.0}  -> ({},{}){}  |  {}",
                        s.step,
                        s.demand,
                        s.chosen.h_idx,
                        s.chosen.v_idx,
                        if s.fallback { " FALLBACK" } else { "" },
                        candidate_line(&s.candidates),
                    );
                }
                if let Some(path) = args.get("explain-out") {
                    std::fs::write(path, report::explain_json(&run.policy, &steps))?;
                    println!("wrote {path} ({})", report::EXPLAIN_SCHEMA);
                }
            } else if args.get("explain-out").is_some() {
                bail!("--explain-out requires --explain <k>");
            }
        }
        "surfaces" => {
            let lambda: f32 = args.parse_num("lambda", 10000.0)?;
            let model = SurfaceModel::from_config(&cfg);
            for s in [Surface::Cost, Surface::Latency, Surface::Throughput, Surface::Objective] {
                println!("{}", report::heatmap_ascii(&model, s, lambda));
            }
        }
        "figures" => {
            let out = args.get("out").unwrap_or("out");
            let sim = Simulator::new(&cfg);
            let trace = TraceBuilder::paper(&cfg);
            let runs = sim.run_paper_set(&trace);
            let model = SurfaceModel::from_config(&cfg);
            for f in report::write_all_figures(out, &model, &runs, 10000.0)? {
                println!("wrote {f}");
            }
        }
        "cluster" => {
            let seed: u64 = args.parse_num("seed", 42)?;
            let policy = policy_send(args.get("policy").unwrap_or("diagonal"))?;
            let kind = substrate_kind(args.get("substrate").unwrap_or("des"))?;
            let params = ClusterParams::default();
            let opts = ClusterOpts {
                explain: args.parse_num("explain", 0)?,
                cost_cap: match args.get("cost-cap") {
                    None => None,
                    Some(_) => Some(args.parse_num("cost-cap", 0.0)?),
                },
                calibrate: args.parse_num("calibrate-online", false)?,
                refit_every: args.parse_num("refit-every", 10)?,
            };
            match kind {
                SubstrateKind::Des => run_cluster(
                    &cfg,
                    EventSim::new(&cfg, params, seed),
                    policy,
                    kind.label(),
                    &opts,
                )?,
                SubstrateKind::Sampling => run_cluster(
                    &cfg,
                    ClusterSim::new(&cfg, params, seed),
                    policy,
                    kind.label(),
                    &opts,
                )?,
                SubstrateKind::Analytical => run_cluster(
                    &cfg,
                    AnalyticalSubstrate::new(&cfg, params),
                    policy,
                    kind.label(),
                    &opts,
                )?,
            }
        }
        "trace-hlo" => {
            let artifacts = args.get("artifacts").unwrap_or("artifacts");
            let engine = SurfaceEngine::new(Engine::load(artifacts)?, &cfg)?;
            engine.check_abi()?;
            let trace = TraceBuilder::paper(&cfg);
            let start = (cfg.policy.start[0], cfg.policy.start[1]);
            println!(
                "platform: {}  artifacts: {artifacts}",
                engine.engine().platform_name()
            );
            for (name, moves) in [
                ("DiagonalScale", MoveFlags::DIAGONAL),
                ("Horizontal-only", MoveFlags::HORIZONTAL_ONLY),
                ("Vertical-only", MoveFlags::VERTICAL_ONLY),
            ] {
                let recs = engine.policy_trace(&trace, moves, start)?;
                let n = recs.len() as f64;
                let avg_lat: f64 = recs.iter().map(|r| r.latency as f64).sum::<f64>() / n;
                let avg_cost: f64 = recs.iter().map(|r| r.cost as f64).sum::<f64>() / n;
                let avg_obj: f64 = recs.iter().map(|r| r.objective as f64).sum::<f64>() / n;
                let viol = recs
                    .iter()
                    .filter(|r| r.latency_violation || r.throughput_violation)
                    .count();
                println!(
                    "{name:<18} lat={avg_lat:7.2} cost={avg_cost:6.3} obj={avg_obj:8.2} viol={viol}"
                );
            }
        }
        "daemon" => {
            let steps: usize = args.parse_num("steps", 100)?;
            let seed: u64 = args.parse_num("seed", 42)?;
            let (dtx, drx) = mpsc::channel();
            let (rtx, rrx) = mpsc::channel();
            // Construct the coordinator inside the thread: the Backend
            // enum can hold PJRT handles, which are not Send.
            let cfg_daemon = cfg.clone();
            let handle = std::thread::spawn(move || {
                let cluster = ClusterSim::new(&cfg_daemon, ClusterParams::default(), seed);
                let coord = Coordinator::new(
                    &cfg_daemon,
                    cluster,
                    Backend::Native(Box::new(DiagonalScale::diagonal())),
                );
                coord.run_daemon(drx, rtx)
            });
            let builder = TraceBuilder::from_config(&cfg);
            let trace = builder.sine(60.0, 160.0, 20, steps);
            let feeder = std::thread::spawn(move || {
                for p in trace.points {
                    if dtx.send(p).is_err() {
                        break;
                    }
                }
            });
            while let Ok(r) = rrx.recv() {
                println!(
                    "step {:>3}  demand {:>8.0}  cfg ({},{})  p99 {:.4}s  viol={}",
                    r.step,
                    r.demand,
                    r.served_config.h_idx,
                    r.served_config.v_idx,
                    r.metrics.p99_latency,
                    r.violation
                );
            }
            feeder.join().expect("feeder thread");
            let summary = handle.join().expect("daemon thread")?;
            println!("daemon summary: {summary:?}");
        }
        "fleet" => {
            let n: usize = args.parse_num("tenants", 8)?;
            if n == 0 {
                bail!("--tenants must be at least 1");
            }
            let seed: u64 = args.parse_num("seed", 42)?;
            let sc = match args.get("scenario") {
                None => None,
                Some(name) => Some(scenario::preset(name, &cfg, n, seed).ok_or_else(|| {
                    anyhow!(
                        "unknown --scenario `{name}` (expected one of: {})",
                        scenario::PRESETS.join(", ")
                    )
                })?),
            };
            // a preset carries its own natural horizon (e.g. a whole
            // simulated week for black-friday); --steps still overrides
            let steps: usize = args.parse_num("steps", sc.as_ref().map_or(100, |s| s.steps))?;
            let k: usize = args.parse_num("k", 3)?;
            let budget: f32 = args.parse_num("budget", 2.2 * n as f32)?;
            // an explicit --substrate implies physical backing, so the
            // flag is never silently ignored
            let substrate_flag = args.get("substrate");
            let attach: bool = args.parse_num("cluster", false)? || substrate_flag.is_some();
            let kind = substrate_kind(substrate_flag.unwrap_or("des"))?;

            let serverless_on: bool = args.parse_num("serverless", false)?;
            if !serverless_on
                && (args.get("idle-fraction").is_some() || args.get("wake-storm").is_some())
            {
                bail!("--idle-fraction / --wake-storm require --serverless true");
            }
            if sc.is_some() && serverless_on {
                bail!("--scenario and --serverless are mutually exclusive (presets carry their own specs)");
            }
            let idle_fraction: f32 = args.parse_num("idle-fraction", 0.75)?;
            if !(0.0..=1.0).contains(&idle_fraction) {
                bail!("--idle-fraction must be in [0, 1]");
            }

            // Classes: top quarter Gold, next quarter Silver, rest
            // Bronze; traces are the paper timeline phase-shifted so
            // tenant peaks stagger across the fleet. Serverless runs
            // use the pinned mostly-idle / wake-storm scenarios
            // instead (round-robin classes, idle tenants bursty).
            let specs: Vec<TenantSpec> = if let Some(sc) = &sc {
                sc.specs.clone()
            } else if serverless_on {
                match args.get("wake-storm") {
                    Some(_) => serverless::wake_storm_specs(
                        &cfg,
                        n,
                        idle_fraction,
                        args.parse_num("wake-storm", 25)?,
                        3,
                    ),
                    None => serverless::mostly_idle_specs(&cfg, n, idle_fraction),
                }
            } else {
                let base = TraceBuilder::paper(&cfg);
                (0..n)
                    .map(|i| {
                        let class = if 4 * i < n {
                            PriorityClass::Gold
                        } else if 2 * i < n {
                            PriorityClass::Silver
                        } else {
                            PriorityClass::Bronze
                        };
                        TenantSpec::from_config(
                            &cfg,
                            format!("tenant-{i:02}"),
                            class,
                            base.shifted(i * base.len() / n),
                        )
                    })
                    .collect()
            };

            let planning: bool = args.parse_num("planning", true)?;
            let mut arb = if planning {
                fleet::BudgetArbiter::new(budget, k)
            } else {
                fleet::BudgetArbiter::flat(budget, k)
            };
            match args.get("envelopes") {
                None | Some("off") => {}
                Some(spec) => {
                    if !planning {
                        bail!("--envelopes requires --planning true (the flat arbiter ignores envelopes)");
                    }
                    arb = arb.with_envelopes(
                        fleet::ClassEnvelopes::parse(spec).ok_or_else(|| {
                            anyhow!("invalid --envelopes `{spec}` (expected g:s:b or default)")
                        })?,
                    )
                }
            }
            let mut fleetsim = FleetSimulator::with_arbiter(&cfg, specs, arb);
            // the CLI reports real planning latency per tick (the
            // default planning clock is deterministically zero)
            fleetsim.use_wall_clock();
            if serverless_on {
                fleetsim.enable_serverless(ServerlessParams::default());
            }
            if args.parse_num("adaptive-envelopes", false)? {
                if !planning {
                    bail!("--adaptive-envelopes requires --planning true");
                }
                fleetsim.enable_adaptive_envelopes();
            }
            match args.get("forecast") {
                None | Some("off") => {}
                Some(name) => {
                    let kind = fleet::ForecastKind::parse(name).ok_or_else(|| {
                        anyhow!("unknown --forecast `{name}` (expected holt|seasonal|off)")
                    })?;
                    fleetsim.enable_forecasts(kind, 3);
                }
            }
            // fault presets need substrate engines to land their node
            // failures on, so a scenario with a schedule implies the
            // attach even without --cluster/--substrate
            let has_faults = sc.as_ref().map_or(false, |s| !s.faults.is_empty());
            if attach || has_faults {
                fleetsim.attach_substrates(&cfg, ClusterParams::default(), seed, kind);
            }
            if let Some(sc) = &sc {
                let accepted =
                    fleetsim.schedule_faults(&sc.faults, ClusterParams::default().interval);
                fleetsim.set_scenario(sc.name, accepted);
                println!(
                    "scenario `{}`: {} tenants, {} steps, {} of {} fault events scheduled",
                    sc.name,
                    n,
                    steps,
                    accepted,
                    sc.faults.len()
                );
            }
            fleetsim.set_dirty_planning(args.parse_num("dirty-planning", true)?);
            let refresh_k: usize = args.parse_num("refresh-k", fleet::REFRESH_K)?;
            if refresh_k == 0 {
                bail!("--refresh-k must be at least 1");
            }
            fleetsim.set_refresh_k(refresh_k);
            let explain: usize = args.parse_num("explain", 0)?;
            fleetsim.enable_explain(explain);
            let explain_sample: usize = args.parse_num("explain-sample", 0)?;
            if explain_sample > 0 && explain == 0 {
                bail!("--explain-sample requires --explain <k>");
            }
            fleetsim.set_explain_sample(explain_sample);
            let stream_metrics: usize = args.parse_num("stream-metrics", 0)?;
            if stream_metrics > 0 {
                fleetsim.enable_streaming_metrics(stream_metrics);
            }
            let ticks_sample: usize = args.parse_num("ticks-sample", 0)?;
            let res = fleetsim.run(steps);
            if explain > 0 {
                for r in fleetsim.explain_log() {
                    let lc = match (r.lifecycle, r.resume_end) {
                        (Some(l), Some(u)) => format!(" lc={l}→t{u}"),
                        (Some(l), None) => format!(" lc={l}"),
                        _ => String::new(),
                    };
                    println!(
                        "tick {:>4}  tenant {:>3} [{:<6}] ({},{}) {:?}{lc} sheds={}  |  {}",
                        r.step,
                        r.tenant,
                        r.class.label(),
                        r.from.h_idx,
                        r.from.v_idx,
                        r.verdict,
                        r.sheds,
                        candidate_line(&r.candidates),
                    );
                }
                if let Some(path) = args.get("explain-out") {
                    std::fs::write(
                        path,
                        report::fleet_explain_json_scenario(
                            fleetsim.explain_log(),
                            fleetsim.explain_sample_cap(),
                            fleetsim.explain_seen(),
                            sc.as_ref().map(|s| s.name),
                        ),
                    )?;
                    println!("wrote {path} ({})", report::EXPLAIN_SCHEMA);
                }
            } else if args.get("explain-out").is_some() {
                bail!("--explain-out requires --explain <k>");
            }
            let shown = fleet::report::sample_ticks(
                &res.ticks,
                ticks_sample,
                fleet::report::TICKS_SAMPLE_SEED,
            );
            if shown.len() < res.ticks.len() {
                println!("(ticks sampled: showing {} of {})", shown.len(), res.ticks.len());
            }
            for t in &shown {
                let sl = if serverless_on {
                    format!(
                        "  susp {:>2}  resuming {:>2}  wakes {}",
                        t.suspended, t.resuming, t.resume_ends
                    )
                } else {
                    String::new()
                };
                println!(
                    "tick {:>4}  spend {:>7.2} / {budget:<7.2}  admitted {:>2}  denied {:>2}  rescues {}  degraded {}  sheds {}  fresh {:>4}  planning_micros {:>6}{sl}",
                    t.step, t.spend, t.admitted_moves, t.denied_moves, t.rescues,
                    t.degraded_moves, t.shed_moves, t.fresh_proposals, t.planning_micros
                );
            }
            if let Some(storage) = fleetsim.storage() {
                println!(
                    "\nstorage service: {:.1} GB parked @ {:.4}/GB-hour = {:.4}/h",
                    storage.total_gb(),
                    storage.params().storage_price_gb_hour,
                    storage.total_storage_cost(),
                );
            }
            if args.parse_num("rollup", false)? {
                let roll = fleet::report::fleet_rollup(fleetsim.tenants(), &res.ticks, budget);
                println!("\n{}", fleet::report::rollup_table(&roll));
            } else {
                println!("\n{}", fleet::report::table(&res.report));
            }
            if let Some(path) = args.get("metrics-out") {
                std::fs::write(path, fleetsim.export_metrics().render_prometheus())?;
                println!("wrote {path} (prometheus text)");
            }
            if let Some(path) = args.get("metrics-json") {
                std::fs::write(path, fleetsim.export_metrics().render_json())?;
                println!("wrote {path} ({})", diagonal_scale::metrics::METRICS_SCHEMA);
            }
            if !res.within_budget(budget) {
                bail!("fleet spend exceeded the budget (peak {:.2})", res.peak_spend());
            }
        }
        "placement" => {
            let n: usize = args.parse_num("tenants", 12)?;
            if n == 0 {
                bail!("--tenants must be at least 1");
            }
            let seed = scenario::DEFAULT_SEED;
            let sc = match args.get("scenario") {
                None => None,
                Some(name) => Some(scenario::preset(name, &cfg, n, seed).ok_or_else(|| {
                    anyhow!(
                        "unknown --scenario `{name}` (expected one of: {})",
                        scenario::PRESETS.join(", ")
                    )
                })?),
            };
            let steps: usize = args.parse_num("steps", sc.as_ref().map_or(100, |s| s.steps))?;
            let budget: f32 = args.parse_num("budget", 1.0e9)?;
            let k: usize = args.parse_num("k", 3)?;
            let scale: f32 = args.parse_num("scale", 0.1)?;
            let mode = args.get("mode").unwrap_or("both");
            if !matches!(mode, "packed" | "dedicated" | "both") {
                bail!("unknown --mode `{mode}` (expected packed|dedicated|both)");
            }
            let pcfg = PlacementConfig {
                replan_every: args.parse_num("replan", 4)?,
                ..PlacementConfig::default()
            };
            // partition-aware pricing: the preset's shard map when it
            // ships one (heavy-tail), else a seeded uniform map at the
            // flat tenant_gb so the comparison stays apples-to-apples
            let partition_aware: bool = args.parse_num("partition-aware", false)?;
            let shard_model = if partition_aware {
                Some(match sc.as_ref().and_then(|s| s.shards.as_ref()) {
                    Some(sm) => sm.clone(),
                    None => scenario::ShardModel::uniform(n, pcfg.tenant_gb, 6, 4, seed),
                })
            } else {
                None
            };
            let specs = || match &sc {
                Some(sc) => sc.specs.clone(),
                None => placement::small_tenant_specs(&cfg, n, scale),
            };
            if let Some(sc) = &sc {
                println!("scenario `{}`: {} tenants, {} steps", sc.name, n, steps);
            }

            let mut runs: Vec<(&str, placement::PlacementResult, f64)> = Vec::new();
            if mode != "packed" {
                let mut ded = PlacementSim::dedicated(&cfg, specs(), budget, k, pcfg);
                if let Some(sm) = &shard_model {
                    ded.set_shard_model(sm.clone());
                }
                let r = ded.run(steps);
                runs.push(("dedicated", r, ded.total_moved_gb()));
            }
            if mode != "dedicated" {
                let mut packed = PlacementSim::packed(&cfg, specs(), budget, k, pcfg);
                if let Some(sm) = &shard_model {
                    packed.set_shard_model(sm.clone());
                }
                let r = packed.run(steps);
                runs.push(("packed", r, packed.total_moved_gb()));
            }
            for (label, res, moved) in &runs {
                println!("== {label} ==");
                for t in &res.ticks {
                    println!(
                        "tick {:>4}  spend {:>7.2}  clusters {:>2}  degraded {:>2}  migrations {:>2}  admitted {:>2}  denied {:>2}  viol {:>2}",
                        t.step, t.spend, t.clusters, t.degraded_clusters, t.migrations,
                        t.admitted_moves, t.denied_moves, t.violations
                    );
                }
                println!("\n{}", res.report.table());
                let pricing = if partition_aware {
                    " (partition-aware shard pricing)"
                } else {
                    ""
                };
                println!("moved data: {moved:.2} GB{pricing}");
                if !res.within_budget(budget) {
                    bail!("{label} placement exceeded the budget (peak {:.2})", res.peak_spend());
                }
            }
            if runs.len() == 2 {
                let (ded, packed) = (&runs[0].1, &runs[1].1);
                println!(
                    "A/B: packed cost {:.1} vs dedicated {:.1} ({:.0}% of dedicated), \
                     violations {} vs {}, migrations {}",
                    packed.total_cost(),
                    ded.total_cost(),
                    100.0 * packed.total_cost() / ded.total_cost().max(1e-9),
                    packed.total_violations(),
                    ded.total_violations(),
                    packed.total_migrations(),
                );
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("{USAGE}");
            bail!("unknown command `{other}`");
        }
    }
    Ok(())
}
