//! Placement invariants: every tenant is hosted exactly once, host
//! capacity is never exceeded by the fair-share allocator, packing is
//! deterministic per seed, merge/split round-trips preserve the tenant
//! multiset and total demand — and the PR-4 acceptance pin: packed
//! placement strictly lowers fleet cost at no more SLA-violation ticks
//! than dedicated clusters on the 12-small-tenant scenario, with
//! migrations priced through the DES event calendar.

use std::sync::Arc;

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{FleetSimulator, TenantSpec};
use diagonal_scale::placement::{
    constant_tenant_specs, fair_shares, PackInput, Packer, PlacementConfig, PlacementSim,
};
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::testkit::forall;
use diagonal_scale::workload::XorShift64;

fn packer(cfg: &ModelConfig) -> Packer {
    Packer::new(
        Arc::new(SurfaceModel::from_config(cfg)),
        PlacementConfig::default(),
    )
}

/// Random single-tenant-feasible demands (every tenant can be hosted
/// alone somewhere on the plane).
fn rand_input(cfg: &ModelConfig, rng: &mut XorShift64, n: usize) -> PackInput {
    PackInput {
        demand: (0..n).map(|_| rng.range_f64(50.0, 18_000.0)).collect(),
        l_max: vec![cfg.sla.l_max; n],
        b_sla: cfg.sla.b_sla as f64,
    }
}

/// The pinned 12-small-tenant scenario: constant demands 400..800,
/// classes cycling Gold/Silver/Bronze (the one shared definition).
fn pinned_specs(cfg: &ModelConfig) -> Vec<TenantSpec> {
    constant_tenant_specs(cfg, 12)
}

#[test]
fn every_tenant_hosted_exactly_once_and_hosts_feasible() {
    let cfg = ModelConfig::default_paper();
    let packer = packer(&cfg);
    forall(60, 0x9AC4, |_, rng| {
        let n = 1 + rng.below(24) as usize;
        let input = rand_input(&cfg, rng, n);
        let p = packer.pack(&input);
        assert!(p.hosts_all(n), "packing lost or duplicated a tenant");
        for c in &p.clusters {
            assert!(!c.tenants.is_empty(), "packer emitted an empty cluster");
            let lam = input.lam_sum(&c.tenants);
            let lmax = input.lmax_min(&c.tenants);
            assert!(
                packer.steady_feasible(&c.config, lam, lmax, &input),
                "host over capacity: {:?} lam {lam}",
                c
            );
        }
    });
}

#[test]
fn fair_shares_never_exceed_host_capacity() {
    forall(300, 0xCAB5, |_, rng| {
        let n = 1 + rng.below(10) as usize;
        let cap = rng.range_f64(0.0, 30_000.0);
        let demands: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 8_000.0)).collect();
        let weights: Vec<f64> =
            (0..n).map(|_| [1.0, 2.0, 4.0][rng.below(3) as usize]).collect();
        let alloc = fair_shares(cap, &demands, &weights);
        assert!(alloc.iter().sum::<f64>() <= cap + 1e-6, "host capacity exceeded");
        for (a, d) in alloc.iter().zip(&demands) {
            assert!(*a <= d + 1e-9 && *a >= 0.0);
        }
    });
}

#[test]
fn packing_is_deterministic_per_seed() {
    let cfg = ModelConfig::default_paper();
    let packer = packer(&cfg);
    forall(30, 0xDE7E12, |_, rng| {
        let seed = rng.next_u64();
        let n = 2 + rng.below(16) as usize;
        let build = |s: u64| {
            let mut r = XorShift64::new(s);
            let input = rand_input(&cfg, &mut r, n);
            (packer.pack(&input), input)
        };
        let (a, _) = build(seed);
        let (b, _) = build(seed);
        assert_eq!(a, b, "same seed must pack identically");
    });
}

#[test]
fn merge_split_round_trips_preserve_demand_and_tenants() {
    let cfg = ModelConfig::default_paper();
    let packer = packer(&cfg);
    forall(40, 0x5B117, |_, rng| {
        let n = 4 + rng.below(12) as usize;
        let input = rand_input(&cfg, rng, n);
        let p = packer.pack(&input);
        let d0 = p.total_demand(&input);
        // merge every adjacent pair that merges; then split what splits
        if p.clusters.len() >= 2 {
            let i = rng.below(p.clusters.len() as u64) as usize;
            let j = (i + 1) % p.clusters.len();
            let (i, j) = (i.min(j), i.max(j));
            if i != j {
                if let Some(m) = packer.merge(&p, i, j, &input) {
                    assert!(m.hosts_all(n), "merge lost a tenant");
                    assert!(
                        (m.total_demand(&input) - d0).abs() < 1e-9 * d0.max(1.0),
                        "merge changed total demand"
                    );
                    if let Some(s) = packer.split(&m, i, &input) {
                        assert!(s.hosts_all(n), "split lost a tenant");
                        assert!(
                            (s.total_demand(&input) - d0).abs() < 1e-9 * d0.max(1.0),
                            "split changed total demand"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn placement_sim_keeps_assignment_valid_over_a_run() {
    let cfg = ModelConfig::default_paper();
    let mut sim = PlacementSim::packed(
        &cfg,
        diagonal_scale::placement::small_tenant_specs(&cfg, 10, 0.1),
        1.0e9,
        3,
        PlacementConfig::default(),
    );
    for _ in 0..60 {
        sim.tick();
        assert!(sim.assignment_valid(), "a tick left a tenant unhosted");
    }
}

/// The PR-4 acceptance pin. On 12 small constant-demand tenants:
/// packed placement must cost strictly less than one-cluster-per-
/// tenant (the f64 mirror of the analytical model puts it at ~51.6 vs
/// ~98.4 over 40 ticks — a wide margin), at no more SLA-violation
/// ticks, with real migrations whose windows actually degrade serving
/// ticks (priced through the DES event calendar), deterministically.
#[test]
fn packed_beats_dedicated_on_the_pinned_12_tenant_scenario() {
    let cfg = ModelConfig::default_paper();
    let pcfg = PlacementConfig::default();
    let steps = 40;

    let mut dedicated = PlacementSim::dedicated(&cfg, pinned_specs(&cfg), 1.0e6, 3, pcfg);
    let ded = dedicated.run(steps);

    let build_packed =
        || FleetSimulator::with_placement(&cfg, pinned_specs(&cfg), 1.0e6, 3, pcfg);
    let packed = build_packed().run(steps);

    assert!(
        packed.total_cost() < ded.total_cost(),
        "packed must be strictly cheaper: {} vs {}",
        packed.total_cost(),
        ded.total_cost()
    );
    // the mirror puts the packed fleet at ~52% of dedicated; leave slack
    assert!(
        packed.total_cost() < 0.85 * ded.total_cost(),
        "packing should save substantially: {} vs {}",
        packed.total_cost(),
        ded.total_cost()
    );
    assert!(
        packed.total_violations() <= ded.total_violations(),
        "packed violated more: {} vs {}",
        packed.total_violations(),
        ded.total_violations()
    );
    assert!(packed.total_migrations() > 0, "consolidation never migrated");
    assert!(
        packed.any_degraded_tick(),
        "migrations were never priced through the calendar"
    );
    assert_eq!(ded.total_migrations(), 0, "dedicated baseline must not migrate");

    // deterministic end to end
    let again = build_packed().run(steps);
    assert_eq!(packed.ticks, again.ticks);
}
